"""Autotuner + PE-sim invariants (the paper's §IV dynamics)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import autotuner, pesim


def zipf_loads(n_rows, alpha, seed, total=5000):
    rng = np.random.default_rng(seed)
    w = np.arange(1, n_rows + 1, dtype=np.float64) ** (-alpha)
    w /= w.sum()
    loads = np.maximum(1, np.round(w * total))
    rng.shuffle(loads)
    return loads


# ---- pesim -----------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.integers(4, 200), st.integers(0, 4), st.integers(0, 2**16))
def test_interval_makespan_bounds(n, hops, seed):
    load = zipf_loads(n, 1.0, seed)
    mk = pesim.interval_makespan(load, hops)
    assert mk >= load.sum() / n - 1e-9          # can't beat perfect balance
    assert mk <= load.max() + 1e-9              # smoothing never hurts
    if hops == 0:
        assert mk == load.max()


@settings(max_examples=20, deadline=None)
@given(st.integers(8, 100), st.integers(0, 2**16))
def test_makespan_monotone_in_hops(n, seed):
    load = zipf_loads(n, 1.2, seed)
    mks = [pesim.interval_makespan(load, h) for h in range(4)]
    assert all(a >= b - 1e-9 for a, b in zip(mks, mks[1:]))


def test_utilization_balanced_is_one():
    load = np.full(16, 10.0)
    assert abs(pesim.utilization(load, 0) - 1.0) < 1e-9


# ---- autotuner --------------------------------------------------------------

def test_work_conservation():
    row_nnz = zipf_loads(600, 1.1, 0)
    design = autotuner.designs_for("cora")["D"]
    state, _ = autotuner.run_autotuning(row_nnz, 64, design, n_rounds=8)
    loads = state.loads(row_nnz, 64)
    np.testing.assert_allclose(loads.sum(), row_nnz.sum(), rtol=1e-9)


def test_design_ordering():
    """Rebalancing designs must dominate the static baseline (Fig. 14)."""
    row_nnz = zipf_loads(2000, 1.1, 1, total=40000)
    utils = {}
    for name, cfg in autotuner.designs_for("cora").items():
        utils[name], _ = autotuner.converged_utilization(row_nnz, 256, cfg)
    assert utils["baseline"] < utils["A"] <= utils["B"] + 0.05
    assert utils["baseline"] < utils["C"]
    assert utils["D"] > 2 * utils["baseline"]


def test_convergence_fig17():
    """Utilization converges within ~10 rounds and ends above start."""
    row_nnz = zipf_loads(1500, 1.2, 2, total=30000)
    design = autotuner.designs_for("nell")["D"]
    _, log = autotuner.run_autotuning(row_nnz, 128, design, n_rounds=12)
    assert log[-1].utilization > log[0].utilization
    tail = [r.utilization for r in log[-3:]]
    assert max(tail) - min(tail) < 0.1  # converged


def test_evil_row_triggers_remap():
    row_nnz = np.ones(512)
    row_nnz[7] = 2000.0  # one evil row
    design = autotuner.designs_for("cora")["D"]
    state, log = autotuner.run_autotuning(row_nnz, 64, design, n_rounds=6)
    assert 7 in state.split_rows  # the evil row was partitioned
    assert sum(r.n_remaps for r in log) >= 1


def test_total_cycles_reuses_converged_config():
    row_nnz = zipf_loads(800, 1.0, 3)
    design = autotuner.designs_for("cora")["D"]
    few = autotuner.total_cycles(row_nnz, 64, design, n_output_cols=16)
    many = autotuner.total_cycles(row_nnz, 64, design, n_output_cols=160)
    # after convergence, marginal cost per column is the converged makespan
    assert many < few * 10.5  # sub-linear warmup amortization
    assert many > few


def test_autotuner_agrees_with_oracle_schedule():
    """DESIGN.md §2: the iterative tuner and the one-shot schedule builder
    converge to comparable balance on a power-law workload — the schedule
    IS the converged configuration, computed directly."""
    from repro.core import schedule
    from repro.graphs import synth

    ds = synth.make_dataset("nell", scale=16)
    rn = np.asarray(np.bincount(np.asarray(ds.adj.row),
                                minlength=ds.num_nodes), np.float64)
    design = autotuner.designs_for("nell")["D"]
    tuner_util, _ = autotuner.converged_utilization(rn, 128, design)
    sched = schedule.build_balanced_schedule(ds.adj, 64, 32)
    # both should report strong balance on the same matrix
    assert tuner_util > 0.55
    assert sched.utilization > 0.85
    # and both should dominate their static baselines
    base_util, _ = autotuner.converged_utilization(
        rn, 128, autotuner.designs_for("nell")["baseline"])
    naive = schedule.build_naive_schedule(ds.adj, 64, 32)
    assert tuner_util > base_util
    assert sched.utilization > naive.utilization
