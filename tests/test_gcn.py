"""GCN end-to-end: AWB engine == reference, learnability, serving engine."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gcn, schedule
from repro.graphs import synth


def _setup(name="cora", scale=4, seed=0):
    ds = synth.make_dataset(name, seed=seed, scale=scale)
    cfg = gcn.GCNConfig(ds.num_features, 16, ds.num_classes)
    params = gcn.init_params(cfg, jax.random.PRNGKey(seed))
    return ds, cfg, params


def test_forward_awb_matches_reference():
    ds, cfg, params = _setup()
    x = jnp.asarray(ds.features)
    ref = gcn.forward(params, ds.adj, x)
    for builder in (schedule.build_balanced_schedule,
                    schedule.build_naive_schedule):
        sched = builder(ds.adj, 64, 32)
        got = gcn.forward_awb(params, ds.adj, x, sched)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=1e-3)


def test_gcn_learns_teacher_labels():
    from repro.training import optimizer as opt_mod

    ds, cfg, params = _setup("citeseer", scale=4, seed=1)
    x = jnp.asarray(ds.features)
    labels = jnp.asarray(ds.labels)
    ocfg = opt_mod.AdamWConfig(lr=0.05, warmup_steps=5, total_steps=60,
                               weight_decay=0.0)
    state = opt_mod.adamw_init(params)
    val_grad = jax.jit(jax.value_and_grad(
        lambda p: gcn.loss_fn(p, ds.adj, x, labels)))
    losses = []
    for _ in range(60):
        loss, g = val_grad(params)
        params, state, _ = opt_mod.adamw_update(ocfg, g, state,
                                                param_dtype=jnp.float32)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.3
    acc = float(gcn.accuracy(params, ds.adj, x, labels))
    assert acc > 1.0 / ds.num_classes + 0.15  # well above chance


def test_gcn_mask_loss():
    ds, cfg, params = _setup()
    x = jnp.asarray(ds.features)
    labels = jnp.asarray(ds.labels)
    mask = jnp.zeros(ds.num_nodes).at[:10].set(1.0)
    full = gcn.loss_fn(params, ds.adj, x, labels)
    masked = gcn.loss_fn(params, ds.adj, x, labels, mask=mask)
    assert np.isfinite(float(full)) and np.isfinite(float(masked))
    assert abs(float(full) - float(masked)) > 1e-6


def test_schedule_reuse_across_layers():
    """One converged schedule serves every layer & request (the paper's
    'A is constant' amortization) — same object, multiple dense operands."""
    ds, cfg, params = _setup("pubmed", scale=16)
    sched = schedule.build_balanced_schedule(ds.adj, 64, 32)
    x = jnp.asarray(ds.features)
    spmm_fn = gcn.make_schedule_spmm(sched)
    h1 = spmm_fn(x @ params["w0"])
    h2 = spmm_fn(jax.nn.relu(h1) @ params["w1"])
    assert h1.shape == (ds.num_nodes, 16)
    assert h2.shape == (ds.num_nodes, ds.num_classes)
