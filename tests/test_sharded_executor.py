"""ShardedScheduleExecutor: distributed equivalence with the single-device
executor, (fingerprint, mesh) cache semantics with zero transfers on the
hit path, the shared shard-splitting helper, and profiler shard stats.

The multi-device tests run sharded programs on 8 forced host-platform
devices in a subprocess (the unit-test process stays single-device, per
conftest) and are tagged with the ``distributed`` marker — they still run
in default CI; `-m "not distributed"` deselects them.
"""
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import csc as fmt, executor as exe, profiler, schedule, spmm
from repro.graphs import synth
from repro.sharding import schedule_shard

SRC = str(Path(__file__).resolve().parents[1] / "src")


@pytest.fixture(autouse=True)
def _fresh_caches():
    exe.clear_caches()
    yield
    exe.clear_caches()


def _graph(n=300, density=0.03, alpha=0.9, seed=7):
    return synth.power_law_adjacency(n, density, alpha, seed=seed)


def _b(n, k=8, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((n, k)).astype(np.float32))


def _run(script: str) -> str:
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=900)
    assert r.returncode == 0, \
        f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    return r.stdout


# ---------------------------------------------------------------------------
# Single-process (1 device): the sharded executor degenerates correctly
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("routing", [exe.GATHER, exe.ONEHOT])
def test_one_device_shard_matches_plain(routing):
    a = _graph(seed=21)
    b = _b(a.shape[0], seed=21)
    plain = exe.get_executor(a, nnz_per_step=32, rows_per_window=16,
                             routing=routing)
    sharded = exe.get_executor(a, nnz_per_step=32, rows_per_window=16,
                               routing=routing, n_devices=1)
    assert isinstance(sharded, exe.ShardedScheduleExecutor)
    assert sharded is not plain  # coexist under distinct (fp, mesh) keys
    np.testing.assert_allclose(np.asarray(sharded.spmm(b)),
                               np.asarray(plain.spmm(b)), atol=1e-5)
    # repeat request is a pure cache hit on the same object
    assert exe.get_executor(a, nnz_per_step=32, rows_per_window=16,
                            routing=routing, n_devices=1) is sharded


def test_sharded_executor_validates_operand_rows():
    a = _graph(seed=22)
    ex = exe.get_executor(a, n_devices=1)
    with pytest.raises(ValueError, match="schedule expects"):
        ex.spmm(_b(a.shape[0] + 3))


def test_sharded_executor_rejects_oversubscribed_mesh():
    a = _graph(seed=23)
    with pytest.raises(ValueError, match="device"):
        exe.get_executor(a, n_devices=len(jax.devices()) + 1)
    # still raises with a warm cache: the oversubscribed count must not
    # silently alias the full-device cache entry
    exe.get_executor(a, n_devices=len(jax.devices()))
    with pytest.raises(ValueError, match="device"):
        exe.get_executor(a, n_devices=len(jax.devices()) + 1)


def test_contradictory_mesh_and_n_devices_rejected():
    from jax.sharding import Mesh
    a = _graph(seed=27)
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("dev",))
    with pytest.raises(ValueError, match="contradicts"):
        exe.get_executor(a, n_devices=2, mesh=mesh)
    # consistent pair is fine
    ex = exe.get_executor(a, n_devices=1, mesh=mesh)
    assert isinstance(ex, exe.ShardedScheduleExecutor)


# ---------------------------------------------------------------------------
# Shared shard-splitting helper + profiler regression
# ---------------------------------------------------------------------------

def test_device_step_ranges_delegates_to_shared_helper():
    s = schedule.build_balanced_schedule(_graph(seed=24), 32, 16)
    for d in (1, 2, 3, 8, s.n_steps + 5):
        np.testing.assert_array_equal(
            s.device_step_ranges(d),
            schedule_shard.split_step_ranges(s.n_steps, d))


def test_profiler_shard_stats_sum_to_full_schedule():
    """Regression for the profiler's former hand-rolled range slicing:
    shard stats must partition the schedule exactly — steps, nnz, and
    issued slots all sum to the full schedule's."""
    a = _graph(400, 0.04, 1.0, seed=25)
    s = schedule.build_balanced_schedule(a, 32, 16)
    for d in (1, 2, 5, 8):
        report = profiler.shard_report(s, d)
        assert len(report) == d
        assert sum(r["steps"] for r in report) == s.n_steps
        assert sum(r["nnz"] for r in report) == s.nnz
        assert sum(r["issued_slots"] for r in report) == s.issued_slots
        loads = profiler.device_loads(s, d)
        np.testing.assert_array_equal(
            loads, [r["steps"] for r in report])
        assert loads.max() - loads.min() <= 1


def test_shard_schedule_stacks_pad_with_noop_steps():
    a = _graph(seed=26)
    s = schedule.build_balanced_schedule(a, 32, 16)
    d = 3  # n_steps rarely divisible by 3 → padded shards
    shards = schedule_shard.shard_schedule(s, d)
    assert shards.val.shape == (d, shards.steps_per_shard, s.nnz_per_step)
    sizes = shards.ranges[:, 1] - shards.ranges[:, 0]
    for dev in range(d):
        # trailing padding steps carry zero values → accumulate nothing
        assert not shards.val[dev, sizes[dev]:].any()
    assert int(shards.nnz.sum()) == s.nnz


# ---------------------------------------------------------------------------
# Distributed equivalence on 8 forced host devices (subprocess)
# ---------------------------------------------------------------------------

SCRIPT_EQUIV = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, %r)
import numpy as np, jax, jax.numpy as jnp
from repro.core import csc as fmt, executor as exe, schedule, spmm
from repro.graphs import synth
assert len(jax.devices()) == 8

a = synth.power_law_adjacency(300, 0.03, 0.9, seed=7)
rng = np.random.default_rng(0)
b = jnp.asarray(rng.standard_normal((300, 8)).astype(np.float32))
ref = np.asarray(exe.get_executor(a, nnz_per_step=32, rows_per_window=16,
                                  routing=exe.GATHER).spmm(b))
np.testing.assert_allclose(ref, np.asarray(spmm.spmm_coo(a, b)), atol=1e-4)
for routing in (exe.GATHER, exe.ONEHOT):
    for d in (1, 2, 4, 8):
        ex = exe.get_executor(a, nnz_per_step=32, rows_per_window=16,
                              routing=routing, n_devices=d)
        assert ex.n_devices == d and ex.routing == routing
        np.testing.assert_allclose(np.asarray(ex.spmm(b)), ref, atol=2e-4,
                                   err_msg=f"{routing} x {d}")
print("EQUIV OK")

# evil rows whose chunks cross shard boundaries: the psum epilogue must
# reunite partial sums of one output row computed on different devices
n = 96
dense = np.zeros((n, n), np.float32)
dense[5, :] = rng.standard_normal(n)
dense[7, :] = rng.standard_normal(n)
dense[rng.integers(0, n, 60), rng.integers(0, n, 60)] = 1.0
ae = fmt.coo_from_dense(dense)
be = jnp.asarray(rng.standard_normal((n, 5)).astype(np.float32))
s = schedule.build_balanced_schedule(ae, 8, 8)
assert s.n_evil_chunks >= 8
evil_lo = s.n_steps - s.n_evil_chunks  # evil chunks occupy the step tail
for routing in (exe.GATHER, exe.ONEHOT):
    for d in (2, 4, 8):
        ranges = s.device_step_ranges(d)
        n_evil_devs = int(((ranges[:, 1] > evil_lo)
                           & (ranges[:, 0] < s.n_steps)).sum())
        assert n_evil_devs >= 2, (d, n_evil_devs)  # chunks really do cross
        ex = exe.executor_for_schedule(s, n_devices=d, routing=routing)
        np.testing.assert_allclose(np.asarray(ex.spmm(be)),
                                   dense @ np.asarray(be), atol=1e-4,
                                   err_msg=f"evil {routing} x {d}")
print("EVIL OK")
""" % (SRC,)


SCRIPT_FORWARD_AUTOTUNE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, %r)
import numpy as np, jax, jax.numpy as jnp
from repro.core import executor as exe, gcn
from repro.graphs import synth
assert len(jax.devices()) == 8

ds = synth.make_dataset("cora", scale=4)
cfg = gcn.GCNConfig(ds.num_features, 16, ds.num_classes)
params = gcn.init_params(cfg, jax.random.PRNGKey(0))
x = jnp.asarray(ds.features)
ref = np.asarray(gcn.forward(params, ds.adj, x))
for d in (2, 4, 8):
    got = np.asarray(gcn.forward_awb(params, ds.adj, x, n_devices=d))
    np.testing.assert_allclose(got, ref, atol=1e-3, err_msg=f"forward x {d}")
print("FORWARD OK")

# the default autotune sweep measures sharded candidates on a multi-device
# host, and an explicit sharded sweep point round-trips through
# TunedConfig -> autotuned_executor
a = synth.power_law_adjacency(300, 0.03, 0.9, seed=7)
cands = exe.sharded_sweep(a, exe.sharded_device_counts(), force=True)
assert {c["n_devices"] for c in cands} == {2, 4, 8}
# minimum-work gate: a graph this small fields no perf-elective sharded
# candidate, and the default autotune sweep therefore stays single-device
assert exe.sharded_sweep(a, exe.sharded_device_counts()) == []
cfg_t = exe.autotune(a, (300, 8), iters=1, warmup=1)
assert cfg_t.measured_us > 0
assert cfg_t.n_devices is None
sweep = [dict(nnz_per_step=32, rows_per_window=16, cols_per_block=None,
              window_nnz=None, routing=exe.GATHER, n_devices=4)]
cfg4 = exe.autotune(a, (300, 8), sweep=sweep, iters=1, warmup=1)
assert cfg4.n_devices == 4
ex4 = exe.autotuned_executor(a, (300, 8), sweep=sweep, iters=1, warmup=1)
assert isinstance(ex4, exe.ShardedScheduleExecutor) and ex4.n_devices == 4
print("AUTOTUNE OK")
""" % (SRC,)


SCRIPT_CACHE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, %r)
import numpy as np, jax, jax.numpy as jnp
from repro.core import executor as exe
from repro.graphs import synth
assert len(jax.devices()) == 8

a = synth.power_law_adjacency(300, 0.03, 0.9, seed=7)
rng = np.random.default_rng(0)
b = jnp.asarray(rng.standard_normal((300, 8)).astype(np.float32))

# (fingerprint, mesh) keying: hit on repeat, miss across mesh shapes,
# plain and sharded coexist
ex2 = exe.get_executor(a, n_devices=2)
assert exe.get_executor(a, n_devices=2) is ex2
ex4 = exe.get_executor(a, n_devices=4)
assert ex4 is not ex2
plain = exe.get_executor(a)
assert plain is not ex2 and plain is not ex4
assert exe.get_executor(a) is plain
# same matrix content, different COO object -> same fingerprint -> hit
from repro.core import csc as fmt
a2 = fmt.COO(jnp.asarray(np.asarray(a.row).copy()),
             jnp.asarray(np.asarray(a.col).copy()),
             jnp.asarray(np.asarray(a.val).copy()), a.shape)
assert exe.get_executor(a2, n_devices=2) is ex2
print("KEYING OK")

# zero host->device transfers on the hit path: after warm-up, repeated
# sharded calls must never re-upload schedule bytes
ex2.spmm(b).block_until_ready()  # trace + compile + upload
transfers = []
orig_asarray, orig_put = jnp.asarray, jax.device_put
def counting_asarray(*args, **kw):
    transfers.append(("asarray", args[0].__class__.__name__))
    return orig_asarray(*args, **kw)
def counting_put(*args, **kw):
    transfers.append(("device_put", args[0].__class__.__name__))
    return orig_put(*args, **kw)
jnp.asarray, jax.device_put = counting_asarray, counting_put
try:
    again = exe.get_executor(a, n_devices=2)
    assert again is ex2
    for _ in range(3):
        again.spmm(b).block_until_ready()
finally:
    jnp.asarray, jax.device_put = orig_asarray, orig_put
assert transfers == [], transfers
print("ZERO-TRANSFER OK")
""" % (SRC,)


@pytest.mark.distributed
def test_sharded_spmm_matches_single_device_all_shard_counts():
    out = _run(SCRIPT_EQUIV)
    assert "EQUIV OK" in out and "EVIL OK" in out


@pytest.mark.distributed
def test_sharded_forward_and_autotune_sweep():
    out = _run(SCRIPT_FORWARD_AUTOTUNE)
    assert "FORWARD OK" in out and "AUTOTUNE OK" in out


@pytest.mark.distributed
def test_mesh_cache_keying_and_zero_transfer_hit_path():
    out = _run(SCRIPT_CACHE)
    assert "KEYING OK" in out and "ZERO-TRANSFER OK" in out
