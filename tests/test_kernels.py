"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (assignment
requirement: per-kernel allclose against ref.py across shapes & dtypes)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import schedule, spmm
from repro.graphs import synth
from repro.kernels import flash_attention as fa
from repro.kernels import ops, ref, spmm_pallas


# ---------------------------------------------------------------------------
# AWB SpMM kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,density,alpha", [
    (64, 0.05, 0.8), (200, 0.02, 1.1), (123, 0.08, 0.6)])
@pytest.mark.parametrize("kdim", [5, 16, 24])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_spmm_kernel_sweep(n, density, alpha, kdim, dtype):
    a = synth.power_law_adjacency(n, density, alpha, seed=n)
    rng = np.random.default_rng(n)
    b = jnp.asarray(rng.standard_normal((n, kdim)).astype(np.float32))
    gold = np.asarray(spmm.spmm_coo(a, b))
    s = schedule.build_balanced_schedule(a, 32, 16)
    got = np.asarray(spmm_pallas.spmm_balanced(
        s, b.astype(dtype), ktile=8).astype(jnp.float32))
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(got, gold, atol=tol * max(
        1.0, np.abs(gold).max()))


@pytest.mark.parametrize("builder", [schedule.build_balanced_schedule,
                                     schedule.build_naive_schedule])
def test_spmm_kernel_both_schedules(builder):
    a = synth.power_law_adjacency(150, 0.04, 1.0, seed=9)
    rng = np.random.default_rng(9)
    b = jnp.asarray(rng.standard_normal((150, 12)).astype(np.float32))
    s = builder(a, 16, 8)
    got = np.asarray(spmm_pallas.spmm_balanced(s, b, ktile=8))
    np.testing.assert_allclose(got, np.asarray(spmm.spmm_coo(a, b)),
                               atol=1e-4)


def test_spmm_kernel_blocked_and_evil():
    a = synth.power_law_adjacency(96, 0.1, 1.2, seed=4)
    rng = np.random.default_rng(4)
    b = jnp.asarray(rng.standard_normal((96, 9)).astype(np.float32))
    s = schedule.build_balanced_schedule(a, 16, 8, cols_per_block=32,
                                         evil_threshold=8)
    assert s.n_evil_chunks > 0
    got = np.asarray(spmm_pallas.spmm_balanced(s, b, ktile=8))
    np.testing.assert_allclose(got, np.asarray(spmm.spmm_coo(a, b)),
                               atol=1e-4)


def test_ops_spmm_backend_switch():
    a = synth.power_law_adjacency(60, 0.05, 0.8, seed=5)
    rng = np.random.default_rng(5)
    b = jnp.asarray(rng.standard_normal((60, 8)).astype(np.float32))
    s = schedule.build_balanced_schedule(a, 16, 8)
    x1 = np.asarray(ops.spmm(s, b, backend="xla"))
    x2 = np.asarray(ops.spmm(s, b, backend="pallas_interpret", ktile=8))
    np.testing.assert_allclose(x1, x2, atol=1e-4)


# ---------------------------------------------------------------------------
# Flash attention kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,sq,sk,h,hkv,d", [
    (2, 32, 32, 4, 4, 16),
    (1, 48, 48, 8, 2, 32),   # GQA
    (2, 16, 64, 4, 1, 16),   # decode-style continuation
    (1, 40, 40, 2, 2, 16),   # non-multiple of block
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(b, sq, sk, h, hkv, d, causal):
    rng = np.random.default_rng(b * sq + h)
    def t(shape):
        return jnp.asarray(rng.standard_normal(shape).astype(np.float32))
    q, k, v = t((b, sq, h, d)), t((b, sk, hkv, d)), t((b, sk, hkv, d))
    out = fa.flash_attention(q, k, v, causal=causal, block_q=16, block_k=16)
    gold = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(gold), atol=2e-5)


@pytest.mark.parametrize("window", [8, 24])
def test_flash_attention_window(window):
    rng = np.random.default_rng(window)
    def t(shape):
        return jnp.asarray(rng.standard_normal(shape).astype(np.float32))
    q, k, v = t((1, 64, 4, 16)), t((1, 64, 2, 16)), t((1, 64, 2, 16))
    out = fa.flash_attention(q, k, v, causal=True, window=window,
                             block_q=16, block_k=16)
    gold = ref.attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(gold), atol=2e-5)


def test_flash_attention_bf16():
    rng = np.random.default_rng(7)
    def t(shape):
        return jnp.asarray(rng.standard_normal(shape).astype(np.float32))
    q, k, v = t((2, 32, 4, 16)), t((2, 32, 2, 16)), t((2, 32, 2, 16))
    out = fa.flash_attention(q.astype(jnp.bfloat16), k.astype(jnp.bfloat16),
                             v.astype(jnp.bfloat16), block_q=16, block_k=16)
    gold = ref.attention_ref(q, k, v)
    err = np.abs(np.asarray(out, np.float32) - np.asarray(gold)).max()
    assert err < 5e-2


def test_ops_attention_backends_agree():
    rng = np.random.default_rng(11)
    def t(shape):
        return jnp.asarray(rng.standard_normal(shape).astype(np.float32))
    q, k, v = t((1, 32, 4, 16)), t((1, 32, 4, 16)), t((1, 32, 4, 16))
    a1 = ops.attention(q, k, v, backend="xla")
    a2 = ops.attention(q, k, v, backend="pallas_interpret",
                       block_q=16, block_k=16)
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2), atol=2e-5)


def test_spmm_kernel_custom_vjp():
    """GCN training through the Pallas engine: the custom VJP (Aᵀ schedule)
    matches grads of the dense reference."""
    import jax
    from repro.core import csc as fmt

    a = synth.power_law_adjacency(80, 0.06, 0.9, seed=13)
    f = spmm_pallas.make_spmm_fn(a, nnz_per_step=16, rows_per_window=8,
                                 ktile=8)
    rng = np.random.default_rng(13)
    b = jnp.asarray(rng.standard_normal((80, 6)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((6, 6)).astype(np.float32))

    def loss_kernel(b):
        return jnp.sum(jnp.tanh(f(b @ w)) ** 2)

    dense_a = fmt.coo_to_dense(a)

    def loss_dense(b):
        return jnp.sum(jnp.tanh(dense_a @ (b @ w)) ** 2)

    g1 = jax.grad(loss_kernel)(b)
    g2 = jax.grad(loss_dense)(b)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-4)
