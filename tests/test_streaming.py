"""Streaming graph updates (DESIGN.md §11): edge-delta application, the
value-only O(|delta|) schedule patch, incremental schedule repair,
scoped executor re-upload, the engine's versioned zero-gap swap, and the
serving-lifecycle correctness sweep that rode along (remove-with-pending
failure semantics, EWMA resets, store builder versioning, perf-gate
math)."""
import json
import os
import subprocess
import sys
import threading

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from benchmarks import check_regression as gate  # noqa: E402
from repro.core import csc, executor as exe, gcn, schedule  # noqa: E402
from repro.graphs import synth  # noqa: E402
from repro.serving.gcn_engine import (GCNServingEngine,  # noqa: E402
                                      RequestFailure, UnknownGraphError)
from repro.tuning import registry, runner  # noqa: E402
from repro.tuning import store as store_mod  # noqa: E402
from repro.tuning.store import TuningStore  # noqa: E402

SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")

N_NODES = 220
N_FEATS = 20
N_CLASSES = 5

FAST_SWEEP = [
    dict(nnz_per_step=64, rows_per_window=32, cols_per_block=None,
         window_nnz=None, routing=exe.GATHER),
    dict(nnz_per_step=128, rows_per_window=64, cols_per_block=None,
         window_nnz=None, routing=exe.GATHER),
]
FAST_KW = dict(iters=1, warmup=1, sweep=FAST_SWEEP, bf16_report=False)

SCHED_KW = dict(nnz_per_step=64, rows_per_window=32)


@pytest.fixture(autouse=True)
def _fresh_caches():
    registry.clear_caches()
    yield
    registry.clear_caches()


def _workload(seed):
    a = synth.power_law_adjacency(N_NODES, 0.03, 0.9, seed=seed)
    cfg = gcn.GCNConfig(N_FEATS, 16, N_CLASSES)
    params = gcn.init_params(cfg, jax.random.PRNGKey(seed))
    x = np.random.default_rng(seed).random((N_NODES, N_FEATS),
                                           ).astype(np.float32)
    return a, params, x


def _engine(root, **kw):
    kw.setdefault("autotune_kwargs", FAST_KW)
    return GCNServingEngine(store_root=root, **kw)


def _pinned_engine(root, cfg):
    """An engine whose sweep has exactly one candidate — the given
    config — so a fresh admission reproduces it deterministically (the
    bit-identity reference for repaired state)."""
    cand = dict(nnz_per_step=cfg.nnz_per_step,
                rows_per_window=cfg.rows_per_window,
                cols_per_block=cfg.cols_per_block,
                window_nnz=cfg.window_nnz,
                routing=cfg.routing,
                ktile=cfg.ktile)
    kw = dict(iters=1, warmup=1, sweep=[cand], bf16_report=False)
    return GCNServingEngine(store_root=root, autotune_kwargs=kw)


def _value_delta(coo, k, rng):
    row = np.asarray(coo.row)
    col = np.asarray(coo.col)
    idx = rng.choice(row.shape[0], size=min(k, row.shape[0]), replace=False)
    vals = (rng.random(idx.shape[0]) + 0.5).astype(np.float32)
    return csc.EdgeDelta(row[idx], col[idx], vals)


def _structural_delta(coo, n, k, rng):
    rows = rng.integers(0, n, k)
    cols = rng.integers(0, n, k)
    vals = (rng.random(k) + 0.1).astype(np.float32)
    return csc.EdgeDelta(rows, cols, vals)


def _dense(coo):
    m, n = coo.shape
    d = np.zeros((m, n), np.float64)
    row = np.asarray(coo.row)
    keep = row != csc.PAD_IDX
    d[row[keep], np.asarray(coo.col)[keep]] = np.asarray(coo.val)[keep]
    return d


def _schedules_equal(a, b):
    for f in schedule._ARRAY_FIELDS:
        if not np.array_equal(getattr(a, f), getattr(b, f)):
            return False
    return a.shape == b.shape


# ---------------------------------------------------------------------------
# apply_edge_delta
# ---------------------------------------------------------------------------

def test_apply_edge_delta_matches_dense_reference():
    a, _, _ = _workload(0)
    rng = np.random.default_rng(0)
    # a mixed delta: inserts, value overwrites, removals, and a no-op
    # removal of an absent edge, with a duplicate coordinate on top
    row = np.asarray(a.row)
    col = np.asarray(a.col)
    hit = rng.choice(row.shape[0], 6, replace=False)
    drow = np.concatenate([row[hit], rng.integers(0, N_NODES, 8), [3, 3]])
    dcol = np.concatenate([col[hit], rng.integers(0, N_NODES, 8), [7, 7]])
    dval = (rng.random(drow.shape[0]) + 0.1).astype(np.float32)
    dval[2] = 0.0          # remove an existing edge
    dval[-2] = 0.25        # duplicate coordinate: last write wins
    dval[-1] = 0.75
    delta = csc.EdgeDelta(drow, dcol, dval)

    ref = _dense(a)
    for r, c, v in zip(drow, dcol, dval):  # one-at-a-time semantics
        if v == 0.0:
            ref[r, c] = 0.0
        else:
            ref[r, c] = v
    out, rep = csc.apply_edge_delta(a, delta, with_report=True)
    np.testing.assert_array_equal(_dense(out), ref)
    # the report's histogram delta must reconcile with the nnz change
    assert rep.n_added - rep.n_removed == out.nnz - a.nnz
    assert rep.row_nnz_delta.sum() == out.nnz - a.nnz
    assert np.array_equal(rep.touched_rows, np.unique(drow))
    # row-major sortedness is the invariant every downstream consumer
    # (CSC conversion, schedule build, repair) relies on
    key = np.asarray(out.row, np.int64) * N_NODES + np.asarray(out.col)
    assert np.all(np.diff(key) > 0)


def test_apply_edge_delta_value_only_fast_branch():
    a, _, _ = _workload(1)
    rng = np.random.default_rng(1)
    delta = _value_delta(a, 12, rng)
    out, rep = csc.apply_edge_delta(a, delta, with_report=True)
    # structure untouched: coordinates identical, only values moved
    assert np.array_equal(np.asarray(out.row), np.asarray(a.row))
    assert np.array_equal(np.asarray(out.col), np.asarray(a.col))
    assert rep.n_added == 0 and rep.n_removed == 0
    assert rep.n_updated == 12
    assert np.all(rep.row_nnz_delta == 0)
    np.testing.assert_array_equal(_dense(out)[delta.row, delta.col],
                                  delta.val.astype(np.float64))


def test_apply_edge_delta_absent_removal_is_noop():
    a, _, _ = _workload(2)
    dense = _dense(a)
    absent = np.argwhere(dense == 0.0)[:5]
    delta = csc.EdgeDelta(absent[:, 0], absent[:, 1],
                          np.zeros(5, np.float32))
    out, rep = csc.apply_edge_delta(a, delta, with_report=True)
    np.testing.assert_array_equal(_dense(out), dense)
    assert rep.n_added == rep.n_removed == rep.n_updated == 0


# ---------------------------------------------------------------------------
# slot index + value-only schedule patch
# ---------------------------------------------------------------------------

def test_slot_entry_keys_indexes_every_nonzero():
    a, _, _ = _workload(3)
    sched = schedule.build_balanced_schedule(a, **SCHED_KW)
    keys, slots = schedule.slot_entry_keys(sched)
    want = (np.asarray(a.row, np.int64) * N_NODES
            + np.asarray(a.col, np.int64))
    pos = np.searchsorted(keys, want)
    assert np.all(keys[pos] == want)  # every edge has a slot
    np.testing.assert_array_equal(sched.val[slots[pos]], np.asarray(a.val))
    # padding slots (val == 0) are all masked to -1, so they can never
    # shadow a real coordinate in the lookup
    n_real = int(np.count_nonzero(sched.val != 0.0))
    assert int(np.count_nonzero(keys != -1)) == n_real


def test_value_patch_schedule_bit_identical_and_miss():
    a, _, _ = _workload(4)
    rng = np.random.default_rng(4)
    sched = schedule.build_balanced_schedule(a, **SCHED_KW)
    index = schedule.slot_entry_keys(sched)
    delta = _value_delta(a, 10, rng)
    new_coo = csc.apply_edge_delta(a, delta)
    patched = schedule.value_patch_schedule(
        sched, index, delta.row, delta.col, delta.val)
    assert patched is not None
    new_sched, slots = patched
    assert slots.shape == (10,)
    cold = schedule.build_balanced_schedule(new_coo, **SCHED_KW)
    assert _schedules_equal(new_sched, cold)
    # an entry absent from the graph misses the index -> None (caller
    # falls back to the generic repair)
    dense = _dense(a)
    r0, c0 = np.argwhere(dense == 0.0)[0]
    miss = schedule.value_patch_schedule(
        sched, index, np.array([r0]), np.array([c0]),
        np.array([1.0], np.float32))
    assert miss is None


def test_repair_schedule_bit_identical_structural():
    a, _, _ = _workload(5)
    rng = np.random.default_rng(5)
    per_row_old = np.bincount(np.asarray(a.row), minlength=N_NODES)
    delta = _structural_delta(a, N_NODES, 24, rng)
    new_coo, rep = csc.apply_edge_delta(a, delta, with_report=True)
    per_row_new = per_row_old.copy()
    per_row_new[rep.touched_rows] += rep.row_nnz_delta
    sched = schedule.build_balanced_schedule(a, **SCHED_KW)
    new_sched, stats = schedule.repair_schedule(
        sched, None, new_coo, rep.touched_rows,
        per_row_old=per_row_old, per_row_new=per_row_new, **SCHED_KW)
    cold = schedule.build_balanced_schedule(new_coo, **SCHED_KW)
    assert _schedules_equal(new_sched, cold)
    assert stats.windows_total == cold.n_windows


# ---------------------------------------------------------------------------
# executor splicing
# ---------------------------------------------------------------------------

def test_value_patched_executor_matches_fresh():
    a, _, _ = _workload(6)
    rng = np.random.default_rng(6)
    sched = schedule.build_balanced_schedule(a, **SCHED_KW)
    ex = exe.ScheduleExecutor(sched, routing=exe.GATHER)
    index = schedule.slot_entry_keys(sched)
    delta = _value_delta(a, 9, rng)
    new_sched, slots = schedule.value_patch_schedule(
        sched, index, delta.row, delta.col, delta.val)
    ex2 = exe.value_patched_executor(ex, new_sched, slots,
                                     new_sched.val[slots])
    assert ex2.scoped_upload
    assert ex2.device_bytes == ex.device_bytes
    fresh = exe.ScheduleExecutor(new_sched, routing=exe.GATHER)
    np.testing.assert_array_equal(np.asarray(ex2._val),
                                  np.asarray(fresh._val))
    b = np.random.default_rng(60).random((N_NODES, 16)).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(ex2.spmm(jnp.asarray(b))),
                                  np.asarray(fresh.spmm(jnp.asarray(b))))
    # empty patch: the device stream is shared outright, no upload
    ex3 = exe.value_patched_executor(ex, sched, np.zeros(0, np.int64),
                                     np.zeros(0, np.float32))
    assert ex3._val is ex._val


def test_repaired_executor_scoped_matches_fresh(monkeypatch):
    monkeypatch.setattr(exe, "SCOPED_UPLOAD_MIN_BYTES", 0)
    a, _, _ = _workload(7)
    rng = np.random.default_rng(7)
    per_row_old = np.bincount(np.asarray(a.row), minlength=N_NODES)
    sched = schedule.build_balanced_schedule(a, **SCHED_KW)
    ex = exe.ScheduleExecutor(sched, routing=exe.GATHER)
    delta = _structural_delta(a, N_NODES, 20, rng)
    new_coo, rep = csc.apply_edge_delta(a, delta, with_report=True)
    per_row_new = per_row_old.copy()
    per_row_new[rep.touched_rows] += rep.row_nnz_delta
    new_sched, stats = schedule.repair_schedule(
        sched, None, new_coo, rep.touched_rows,
        per_row_old=per_row_old, per_row_new=per_row_new, **SCHED_KW)
    ex2 = exe.repaired_executor(ex, new_sched, stats)
    fresh = exe.ScheduleExecutor(new_sched, routing=exe.GATHER)
    b = np.random.default_rng(70).random((N_NODES, 16)).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(ex2.spmm(jnp.asarray(b))),
                                  np.asarray(fresh.spmm(jnp.asarray(b))))


# ---------------------------------------------------------------------------
# engine update_graph
# ---------------------------------------------------------------------------

def test_update_graph_value_lane_report(tmp_path):
    a, params, x = _workload(8)
    rng = np.random.default_rng(8)
    eng = _engine(tmp_path)
    eng.add_graph("g", a, params)
    eng.infer("g", x)
    rep = eng.update_graph("g", _value_delta(eng._graphs["g"].coo, 8, rng))
    assert rep.repaired and not rep.fell_back
    assert rep.scoped_upload
    assert rep.revision == 1
    # the O(nnz) content fingerprint is deferred to the async persist
    # worker: the hot path reports an empty fingerprint but a real,
    # deterministic lineage hash
    assert rep.fingerprint == "" and rep.lineage != ""
    # a value patch reuses the entire step/window layout verbatim
    sched = eng._graphs["g"].sched
    assert rep.steps_reused == sched.n_steps
    assert rep.windows_reused == rep.windows_total == sched.n_windows
    assert eng.counters["graph_updates"] == 1
    assert eng.counters["update_retunes"] == 0


def test_update_graph_chain_bit_identical_to_cold_admission(tmp_path):
    a, params, x = _workload(9)
    rng = np.random.default_rng(9)
    eng = _engine(tmp_path / "hot")
    eng.add_graph("g", a, params)
    eng.infer("g", x)
    for i in range(6):  # alternate value-only and structural deltas
        coo = eng._graphs["g"].coo
        if i % 2 == 0:
            delta = _value_delta(coo, 8, rng)
        else:
            delta = _structural_delta(coo, N_NODES, 8, rng)
        rep = eng.update_graph("g", delta)
        assert rep.repaired and not rep.fell_back
    got = np.asarray(eng.infer("g", x))
    rec = eng._graphs["g"]
    ident = _pinned_engine(tmp_path / "cold", rec.config)
    ident.add_graph("g", rec.coo, params)
    want = np.asarray(ident.infer("g", x))
    assert np.array_equal(got, want)


def test_update_graph_drift_triggers_retune(tmp_path):
    a, params, x = _workload(10)
    rng = np.random.default_rng(10)
    eng = _engine(tmp_path, repair_drift_threshold=1e-9)
    eng.add_graph("g", a, params)
    eng.infer("g", x)
    rep = eng.update_graph("g", _value_delta(a, 8, rng))
    assert not rep.repaired and rep.fingerprint != ""
    assert eng.counters["update_retunes"] == 1
    rec = eng._graphs["g"]
    assert rec.drift_nnz == 0  # the re-tuned schedule is the new baseline
    assert rec.fingerprint == rep.fingerprint
    assert rec.lineage == rep.fingerprint  # lineage re-anchors at re-tune
    got = np.asarray(eng.infer("g", x))
    ident = _pinned_engine(tmp_path / "cold", rec.config)
    ident.add_graph("g", rec.coo, params)
    assert np.array_equal(got, np.asarray(ident.infer("g", x)))


def test_update_graph_errors_leave_state_unchanged(tmp_path):
    a, params, x = _workload(11)
    eng = _engine(tmp_path)
    eng.add_graph("g", a, params)
    ref = np.asarray(eng.infer("g", x))
    with pytest.raises(UnknownGraphError):
        eng.update_graph("nope", csc.EdgeDelta(
            np.array([0]), np.array([0]), np.array([1.0], np.float32)))
    with pytest.raises(ValueError, match="out of bounds"):
        eng.update_graph("g", csc.EdgeDelta(
            np.array([N_NODES]), np.array([0]),
            np.array([1.0], np.float32)))
    assert eng._graphs["g"].revision == 0
    assert np.array_equal(np.asarray(eng.infer("g", x)), ref)


def test_async_persist_backfills_fingerprint_and_warm_restarts(
        tmp_path, monkeypatch):
    a, params, x = _workload(12)
    rng = np.random.default_rng(12)
    eng = _engine(tmp_path)
    eng.add_graph("g", a, params)
    eng.infer("g", x)
    rep = eng.update_graph("g", _value_delta(a, 8, rng))
    assert rep.fingerprint == ""
    eng.drain_persists()
    rec = eng._graphs["g"]
    fp2 = registry.graph_fingerprint(rec.coo)
    assert rec.fingerprint == fp2  # back-filled by the worker
    # a restart admitting the mutated graph warm-starts from the entry
    # the worker persisted: zero measured sweeps, zero rebuilds
    registry.clear_caches()
    monkeypatch.setattr(runner, "measure_candidate",
                        lambda *a_, **k: pytest.fail("sweep on warm start"))
    monkeypatch.setattr(schedule, "build_balanced_schedule",
                        lambda *a_, **k: pytest.fail("rebuild on warm start"))
    eng2 = _engine(tmp_path)
    rep2 = eng2.add_graph("g", rec.coo, params)
    assert rep2.warm_start
    assert np.array_equal(np.asarray(eng2.infer("g", x)),
                          np.asarray(eng.infer("g", x)))


def test_update_graph_zero_gap_under_concurrent_infer(tmp_path):
    a, params, x = _workload(13)
    rng = np.random.default_rng(13)
    eng = _engine(tmp_path)
    eng.add_graph("g", a, params)
    eng.infer("g", x)
    stop = threading.Event()
    served, failures = [0], []

    def _background():
        while not stop.is_set():
            try:
                y = np.asarray(eng.infer("g", x))
                assert np.all(np.isfinite(y))
                served[0] += 1
            except Exception as e:  # pragma: no cover - the bug under test
                failures.append(repr(e))
                return

    th = threading.Thread(target=_background, daemon=True)
    th.start()
    for i in range(4):
        coo = eng._graphs["g"].coo
        delta = (_value_delta(coo, 8, rng) if i % 2 == 0
                 else _structural_delta(coo, N_NODES, 8, rng))
        eng.update_graph("g", delta)
    stop.set()
    th.join(timeout=60.0)
    assert not failures, failures
    assert served[0] > 0


def test_update_graph_on_evicted_graph_is_host_only(tmp_path):
    a, params, x = _workload(14)
    rng = np.random.default_rng(14)
    eng = _engine(tmp_path)
    eng.add_graph("g", a, params)
    eng.infer("g", x)
    eng._evict(eng._graphs["g"])
    assert eng._graphs["g"].executor is None
    rep = eng.update_graph("g", _value_delta(a, 8, rng))
    assert rep.repaired and not rep.scoped_upload
    assert eng._graphs["g"].executor is None  # no re-admission side effect
    got = np.asarray(eng.infer("g", x))  # re-admits the repaired schedule
    rec = eng._graphs["g"]
    ident = _pinned_engine(tmp_path / "cold", rec.config)
    ident.add_graph("g", rec.coo, params)
    assert np.array_equal(got, np.asarray(ident.infer("g", x)))


# ---------------------------------------------------------------------------
# lifecycle sweep: remove-with-pending, EWMA resets, budget-sweep break
# ---------------------------------------------------------------------------

def _accounting(eng):
    c = eng.counters
    pending = sum(len(q) for q in eng._pending.values())
    lhs = c["submitted"]
    rhs = (c["queue_served"] + c["shed"] + c["rejected"] + c["dropped"]
           + pending)
    return lhs, rhs


def test_remove_graph_with_pending_fails_them_typed(tmp_path):
    a, params, x = _workload(15)
    eng = _engine(tmp_path)
    eng.add_graph("g", a, params)
    for _ in range(3):
        assert eng.submit("g", x, deadline_s=60.0)
    lhs, rhs = _accounting(eng)
    assert lhs == rhs == 3
    with pytest.raises(RequestFailure) as ei:
        eng.remove_graph("g")
    assert ei.value.n_failed == 3
    assert ei.value.graph_id == "g"
    # settled exactly once, into `dropped`; the identity still holds
    assert eng.counters["dropped"] == 3
    lhs, rhs = _accounting(eng)
    assert lhs == rhs == 3
    # removal completed despite the raise: graph + queues + stats gone
    assert "g" not in eng.graphs
    assert "g" not in eng._pending and "g" not in eng._svc_ewma
    assert eng.device_bytes_in_use == 0
    with pytest.raises(UnknownGraphError):
        eng.remove_graph("g")
    assert eng.counters["dropped"] == 3  # no double settle


def test_remove_graph_without_pending_raises_nothing(tmp_path):
    a, params, x = _workload(16)
    eng = _engine(tmp_path)
    eng.add_graph("g", a, params)
    eng.infer("g", x)
    eng.remove_graph("g")
    assert eng.counters["dropped"] == 0
    assert eng.device_bytes_in_use == 0


def test_evict_resets_service_ewmas(tmp_path):
    a, params, x = _workload(17)
    eng = _engine(tmp_path)
    eng.add_graph("g", a, params)
    eng.infer("g", x)
    assert "g" in eng._svc_ewma  # infer measured a service time
    eng._svc_req_ewma["g"] = 0.5
    eng._calm_polls["g"] = 2
    eng._evict(eng._graphs["g"])
    # the EWMAs were measured under the old residency: a re-admitted
    # graph must re-measure, not shed requests off stale predictions
    assert "g" not in eng._svc_ewma
    assert "g" not in eng._svc_req_ewma
    assert "g" not in eng._calm_polls
    eng.infer("g", x)  # re-admission serves and re-measures
    assert "g" in eng._svc_ewma


def test_evict_over_budget_never_evicts_keep(tmp_path):
    a, params, x = _workload(18)
    eng = _engine(tmp_path)
    eng.add_graph("g", a, params)
    eng.infer("g", x)
    d = eng.placer.placement_of("g").device_index
    # inflate the kept graph's accounted footprint past the budget: the
    # sweep finds no replica and no victim besides `keep` and must break
    # out instead of spinning or evicting the graph it protects
    eng.placer.reaccount("g", eng.device_budget_bytes * 2)
    assert eng.placer.used[d] > eng.placer.budget
    eng._evict_over_budget("g")
    assert eng._graphs["g"].executor is not None
    assert eng.placer.used[d] > eng.placer.budget  # still over; no churn
    got = np.asarray(eng.infer("g", x))
    assert np.all(np.isfinite(got))


# ---------------------------------------------------------------------------
# store builder versioning
# ---------------------------------------------------------------------------

def test_store_key_varies_with_builder_version_and_revision(monkeypatch):
    st = TuningStore(root="/tmp/unused-root")
    base = st.key("fp", 16, device="cpu:x", mesh="1dev")
    rev = st.key("fp", 16, device="cpu:x", mesh="1dev", revision=3)
    assert base != rev
    monkeypatch.setattr(store_mod, "SCHEDULE_BUILDER_VERSION",
                        store_mod.SCHEDULE_BUILDER_VERSION + 1)
    bumped = st.key("fp", 16, device="cpu:x", mesh="1dev")
    assert bumped != base  # a builder bump orphans every old entry


def test_store_drops_mixed_builder_version_entries(tmp_path):
    a, _, _ = _workload(19)
    sched = schedule.build_balanced_schedule(a, **SCHED_KW)
    cfg = runner.autotune(a, (N_NODES, 16), store=None, **FAST_KW)
    st = TuningStore(root=tmp_path)
    good = st.key("fp-good", 16)
    stale = st.key("fp-stale", 16)
    st.save(good, cfg, sched)
    st.save(stale, cfg, sched)
    # rewrite one entry as if an older builder lineage produced it
    path = st.path(stale)
    with np.load(path, allow_pickle=False) as z:
        payload = {k: z[k] for k in z.files}
    payload["builder_version"] = np.asarray(
        store_mod.SCHEDULE_BUILDER_VERSION - 1, np.int64)
    np.savez(path, **payload)
    with pytest.warns(UserWarning, match="builder version"):
        assert st.load(stale) is None  # dropped to re-tune, never crash
    assert not path.exists()  # the stale corpse is unlinked
    got = st.load(good)  # the mixed store still serves current entries
    assert got is not None and _schedules_equal(got[1], sched)


def test_engine_retunes_through_stale_builder_entry(tmp_path):
    a, params, x = _workload(20)
    eng = _engine(tmp_path)
    eng.add_graph("g", a, params)
    ref = np.asarray(eng.infer("g", x))
    # corrupt the engine's own entry into a stale-builder one
    (entry,) = eng.store.entries()
    path = eng.store.path(entry)
    with np.load(path, allow_pickle=False) as z:
        payload = {k: z[k] for k in z.files}
    payload["builder_version"] = np.asarray(-7, np.int64)
    np.savez(path, **payload)
    registry.clear_caches()
    eng2 = _engine(tmp_path)
    with pytest.warns(UserWarning, match="builder version"):
        rep = eng2.add_graph("g", a, params)
    assert not rep.warm_start  # dropped to a measured re-tune
    assert eng2.counters["store_misses"] == 1
    np.testing.assert_allclose(np.asarray(eng2.infer("g", x)), ref,
                               atol=1e-3)


# ---------------------------------------------------------------------------
# perf-gate math (benchmarks/check_regression.py)
# ---------------------------------------------------------------------------

def _gate_rows():
    return [
        dict(name="serving/g/warm_start", us_per_call=100.0,
             derived="speedup=50.00x"),
        dict(name="autotune/g", us_per_call=100.0, derived=""),
        dict(name="serving/batched_throughput", us_per_call=50.0,
             derived=""),
        dict(name="serving/mesh8/mesh_throughput", us_per_call=100.0,
             derived=""),
        dict(name="serving/mesh8/hot_replicated", us_per_call=100.0,
             derived="speedup=2.00x;bit_identical=1"),
        dict(name="openloop/steady/p99", us_per_call=1000.0, derived=""),
        dict(name="openloop/steady/goodput", us_per_call=90.0,
             derived="identity=1;submitted=10;served=8;shed=1;rejected=1"),
        dict(name="openloop/steady_learned/goodput", us_per_call=88.0,
             derived="identity=1;submitted=10;served=8;shed=1;rejected=1"),
        dict(name="openloop/steady_learned/pred_err", us_per_call=40.0,
             derived="n_scored=24;n_samples=30;fallbacks=2;fitted=1"),
        dict(name="streaming/small_delta/repair", us_per_call=2000.0,
             derived="speedup=6.00x;bit_identical=1;rebuild_us=12000"),
        dict(name="streaming/zero_gap", us_per_call=500.0,
             derived="gap=0;updates=4;infers=20"),
        dict(name="reorder/g/none", us_per_call=100.0,
             derived="nnz=1000;steps=10;locality=0.400"),
        dict(name="reorder/g/island", us_per_call=95.0,
             derived="speedup_vs_none=1.05x;bit_identical=1;steps=9;"
                     "locality=0.350"),
        dict(name="reorder/g/sweep", us_per_call=95.0,
             derived="winner=island;accepted=1;speedup_vs_none=1.05x"),
        dict(name="reorder/h/sweep", us_per_call=100.0,
             derived="winner=none;accepted=0;speedup_vs_none=1.00x"),
    ]


def _gate_payload(smoke=True, **edits):
    rows = _gate_rows()
    for name, fields in edits.items():
        (row,) = [r for r in rows if r["name"] == name]
        row.update(fields)
    return dict(smoke=smoke, rows=rows)


def test_gate_identity_is_green():
    smoke = _gate_payload()
    ref = _gate_payload(smoke=False)
    assert gate.check(smoke, ref, tolerance=3.0) == []


def test_gate_zero_denominator_is_degenerate_not_crash():
    smoke = _gate_payload(**{
        "serving/batched_throughput": dict(us_per_call=0.0)})
    ref = _gate_payload(smoke=False)
    problems = gate.check(smoke, ref, tolerance=3.0)
    assert any(p.startswith("DEGENERATE") for p in problems)
    assert not any("ZeroDivision" in p for p in problems)
    # degenerate on the reference side too: still a report, not a crash
    problems = gate.check(_gate_payload(), _gate_payload(smoke=False, **{
        "serving/batched_throughput": dict(us_per_call=0.0)}), 3.0)
    assert any(p.startswith("DEGENERATE") for p in problems)


def test_gate_streaming_speedup_floor_and_bit_identity():
    ref = _gate_payload(smoke=False)
    # exactly at the floor (6.00 / 3.0 = 2.00): passes, not a regression
    at_floor = _gate_payload(**{"streaming/small_delta/repair": dict(
        derived="speedup=2.00x;bit_identical=1")})
    assert gate.check(at_floor, ref, tolerance=3.0) == []
    below = _gate_payload(**{"streaming/small_delta/repair": dict(
        derived="speedup=1.99x;bit_identical=1")})
    problems = gate.check(below, ref, tolerance=3.0)
    assert any("REGRESSION" in p and "incremental" in p for p in problems)
    flipped = _gate_payload(**{"streaming/small_delta/repair": dict(
        derived="speedup=6.00x;bit_identical=0")})
    problems = gate.check(flipped, ref, tolerance=3.0)
    assert any(p.startswith("CORRECTNESS") and "bit_identical" in p
               for p in problems)
    missing = dict(smoke=True, rows=[r for r in _gate_rows()
                                     if "streaming" not in r["name"]])
    problems = gate.check(missing, ref, tolerance=3.0)
    assert any("MISSING" in p and "small_delta" in p for p in problems)


def test_gate_zero_gap_hard():
    ref = _gate_payload(smoke=False)
    bad = _gate_payload(**{"streaming/zero_gap": dict(derived="gap=2")})
    problems = gate.check(bad, ref, tolerance=3.0)
    assert any(p.startswith("CORRECTNESS") and "zero_gap" in p
               for p in problems)
    nogap = _gate_payload(**{"streaming/zero_gap": dict(derived="")})
    problems = gate.check(nogap, ref, tolerance=3.0)
    assert any("no gap count" in p for p in problems)


def test_gate_p99_ceiling_edges():
    ref = _gate_payload(smoke=False)
    at = _gate_payload(**{"openloop/steady/p99": dict(us_per_call=3000.0)})
    assert gate.check(at, ref, tolerance=3.0) == []  # exactly at ceiling
    above = _gate_payload(**{
        "openloop/steady/p99": dict(us_per_call=3000.1)})
    problems = gate.check(above, ref, tolerance=3.0)
    assert any("REGRESSION" in p and "p99" in p for p in problems)


def test_gate_learned_head_to_head():
    ref = _gate_payload(smoke=False)
    # goodput below the smoke-internal heuristic floor (90 / 3.0 = 30)
    bad = _gate_payload(**{"openloop/steady_learned/goodput": dict(
        us_per_call=29.9)})
    problems = gate.check(bad, ref, tolerance=3.0)
    assert any("REGRESSION" in p and "learned-policy" in p for p in problems)
    # zero scored predictions: the accuracy report vouches for nothing
    unscored = _gate_payload(**{"openloop/steady_learned/pred_err": dict(
        derived="n_scored=0;n_samples=0;fallbacks=9;fitted=0")})
    problems = gate.check(unscored, ref, tolerance=3.0)
    assert any(p.startswith("DEGENERATE") and "pred_err" in p
               for p in problems)
    # error ceiling is max(absolute, tolerance x reference): with the
    # fixture reference at 40% the 150% absolute ceiling dominates
    wild = _gate_payload(**{"openloop/steady_learned/pred_err": dict(
        us_per_call=150.1)})
    problems = gate.check(wild, ref, tolerance=3.0)
    assert any("REGRESSION" in p and "prediction error" in p
               for p in problems)
    at_ceiling = _gate_payload(**{"openloop/steady_learned/pred_err": dict(
        us_per_call=150.0)})
    assert gate.check(at_ceiling, ref, tolerance=3.0) == []
    # both head-to-head rows absent: the gate reports itself blind
    missing = dict(smoke=True, rows=[r for r in _gate_rows()
                                     if "steady_learned" not in r["name"]])
    problems = gate.check(missing, ref, tolerance=3.0)
    assert any("MISSING" in p and "steady_learned" in p for p in problems)


def test_gate_pred_err_ceiling_scaled_by_reference():
    # when 3x the reference exceeds the 150% absolute floor the scaled
    # ceiling governs: reference at 60% -> ceiling 180%
    ref = _gate_payload(smoke=False, **{
        "openloop/steady_learned/pred_err": dict(us_per_call=60.0)})
    at = _gate_payload(**{"openloop/steady_learned/pred_err": dict(
        us_per_call=180.0)})
    assert gate.check(at, ref, tolerance=3.0) == []
    above = _gate_payload(**{"openloop/steady_learned/pred_err": dict(
        us_per_call=180.1)})
    problems = gate.check(above, ref, tolerance=3.0)
    assert any("REGRESSION" in p and "3x reference 60%" in p
               for p in problems)


def test_gate_pred_err_absolute_ceiling_without_reference_row():
    # a reference trajectory that predates the learned policy carries no
    # pred_err row: the 150% absolute ceiling applies, exactly-at passes
    ref = dict(smoke=False,
               rows=[r for r in _gate_rows()
                     if r["name"] != "openloop/steady_learned/pred_err"])
    at = _gate_payload(**{"openloop/steady_learned/pred_err": dict(
        us_per_call=150.0)})
    assert gate.check(at, ref, tolerance=3.0) == []
    above = _gate_payload(**{"openloop/steady_learned/pred_err": dict(
        us_per_call=150.1)})
    problems = gate.check(above, ref, tolerance=3.0)
    assert any("REGRESSION" in p and "absolute ceiling" in p
               for p in problems)


def test_gate_pred_err_missing_scored_count_is_degenerate():
    # a derived string with no n_scored= at all vouches for nothing,
    # same verdict as n_scored=0 -- and never a parse crash
    blank = _gate_payload(**{"openloop/steady_learned/pred_err": dict(
        derived="fitted=1")})
    problems = gate.check(blank, _gate_payload(smoke=False), tolerance=3.0)
    assert any(p.startswith("DEGENERATE") and "pred_err" in p
               for p in problems)


def test_gate_accounting_identity():
    ref = _gate_payload(smoke=False)
    bad = _gate_payload(**{"openloop/steady/goodput": dict(
        derived="identity=1;submitted=10;served=8;shed=1;rejected=0")})
    problems = gate.check(bad, ref, tolerance=3.0)
    assert any(p.startswith("CORRECTNESS") and "vanished" in p
               for p in problems)
    unasserted = _gate_payload(**{"openloop/steady/goodput": dict(
        derived="submitted=10;served=8;shed=1;rejected=1")})
    problems = gate.check(unasserted, ref, tolerance=3.0)
    assert any("identity=1" in p for p in problems)


def test_gate_reorder_bit_identity_winner_floor_and_diversity():
    ref = _gate_payload(smoke=False)
    flipped = _gate_payload(**{"reorder/g/island": dict(
        derived="speedup_vs_none=1.05x;bit_identical=0;steps=9;"
                "locality=0.350")})
    problems = gate.check(flipped, ref, tolerance=3.0)
    assert any(p.startswith("CORRECTNESS") and "reorder/g/island" in p
               for p in problems)
    # winner floor is 1/tolerance: 0.34x passes at tol 3, 0.33x trips
    at_floor = _gate_payload(**{"reorder/g/sweep": dict(
        derived="winner=island;accepted=1;speedup_vs_none=0.34x")})
    assert gate.check(at_floor, ref, tolerance=3.0) == []
    below = _gate_payload(**{"reorder/g/sweep": dict(
        derived="winner=island;accepted=1;speedup_vs_none=0.33x")})
    problems = gate.check(below, ref, tolerance=3.0)
    assert any("REGRESSION" in p and "measures slower" in p
               for p in problems)
    missing = dict(smoke=True, rows=[r for r in _gate_rows()
                                     if not r["name"].startswith("reorder/")])
    problems = gate.check(missing, ref, tolerance=3.0)
    assert any("MISSING" in p and "reorder" in p for p in problems)
    # a full-scale reference whose sweep always accepts (or always
    # rejects) is a degenerate trajectory: the axis stopped discriminating
    always = _gate_payload(smoke=False, **{"reorder/h/sweep": dict(
        derived="winner=degree;accepted=1;speedup_vs_none=1.01x")})
    problems = gate.check(_gate_payload(), always, tolerance=3.0)
    assert any(p.startswith("DEGENERATE") and "always accepts" in p
               for p in problems)


def test_gate_round_trips_through_json():
    smoke = json.loads(json.dumps(_gate_payload()))
    ref = json.loads(json.dumps(_gate_payload(smoke=False)))
    assert gate.check(smoke, ref, tolerance=3.0) == []


# ---------------------------------------------------------------------------
# sharded + replicated update bit-identity (8 forced host devices)
# ---------------------------------------------------------------------------

SCRIPT_STREAM = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, tempfile
sys.path.insert(0, %r)
import numpy as np, jax, jax.numpy as jnp
from repro.core import csc, executor as exe, gcn
from repro.graphs import synth
from repro.serving.gcn_engine import GCNServingEngine
from repro.serving.placement import REPLICATED, SHARDED, SINGLE
assert len(jax.devices()) == 8

SWEEP = [dict(nnz_per_step=64, rows_per_window=32, cols_per_block=None,
              window_nnz=None, routing=exe.GATHER)]
KW = dict(iters=1, warmup=1, sweep=SWEEP, bf16_report=False)

def pinned_kw(cfg):
    cand = dict(nnz_per_step=cfg.nnz_per_step,
                rows_per_window=cfg.rows_per_window,
                cols_per_block=cfg.cols_per_block,
                window_nnz=cfg.window_nnz, routing=cfg.routing,
                ktile=cfg.ktile)
    return dict(iters=1, warmup=1, sweep=[cand], bf16_report=False)

def value_delta(coo, k, rng):
    row, col = np.asarray(coo.row), np.asarray(coo.col)
    idx = rng.choice(row.shape[0], size=k, replace=False)
    vals = (rng.random(k) + 0.5).astype(np.float32)
    return csc.EdgeDelta(row[idx], col[idx], vals)

def structural_delta(n, k, rng):
    return csc.EdgeDelta(rng.integers(0, n, k), rng.integers(0, n, k),
                         (rng.random(k) + 0.1).astype(np.float32))

n = 3000
a = synth.power_law_adjacency(n, 0.01, 0.9, seed=99)
gcfg = gcn.GCNConfig(16, 16, 4)
params = gcn.init_params(gcfg, jax.random.PRNGKey(99))
x = np.random.default_rng(99).random((n, 16)).astype(np.float32)
budget = a.nnz * 4  # the graph cannot fit one device: routes SHARDED
rng = np.random.default_rng(17)

root = tempfile.mkdtemp(prefix="awb-stream-mesh-")
eng = GCNServingEngine(store_root=root, devices=8,
                       device_budget_bytes=budget, autotune_kwargs=KW)
rep = eng.add_graph("g", a, params)
assert rep.placement.kind == SHARDED
eng.infer("g", x)
for i in range(4):
    coo = eng._graphs["g"].coo
    delta = (value_delta(coo, 12, rng) if i %% 2 == 0
             else structural_delta(n, 12, rng))
    urep = eng.update_graph("g", delta)
    assert urep.repaired and not urep.fell_back, urep
got = np.asarray(eng.infer("g", x))
rec = eng._graphs["g"]
iroot = tempfile.mkdtemp(prefix="awb-stream-ident-")
ident = GCNServingEngine(store_root=iroot, devices=8,
                         device_budget_bytes=budget,
                         autotune_kwargs=pinned_kw(rec.config))
ident.add_graph("g", rec.coo, params)
want = np.asarray(ident.infer("g", x))
assert np.array_equal(got, want)
print("SHARDED UPDATE OK")

# --- replicated graph: the swap must splice every clone ------------------
n2 = 260
a2 = synth.power_law_adjacency(n2, 0.03, 0.9, seed=5)
p2 = gcn.init_params(gcfg, jax.random.PRNGKey(5))
x2 = np.random.default_rng(5).random((n2, 16)).astype(np.float32)
rroot = tempfile.mkdtemp(prefix="awb-stream-rep-")
eng2 = GCNServingEngine(store_root=rroot, devices=8, autotune_kwargs=KW)
eng2.add_graph("h", a2, p2)
eng2.infer("h", x2)
rec2 = eng2._graphs["h"]
assert eng2._grow_replica(rec2)
assert eng2.placer.placement_of("h").kind == REPLICATED
urep = eng2.update_graph("h", value_delta(rec2.coo, 10, rng))
assert urep.repaired and urep.scoped_upload
# both clones serve the patched values bit-identically
outs = [np.asarray(u.fwd(u.params, jnp.asarray(x2[None]))[0])
        for u in eng2._units(rec2)]
assert len(outs) == 2 and np.array_equal(outs[0], outs[1])
iroot2 = tempfile.mkdtemp(prefix="awb-stream-rident-")
ident2 = GCNServingEngine(store_root=iroot2,
                          autotune_kwargs=pinned_kw(rec2.config))
ident2.add_graph("h", rec2.coo, p2)
assert np.array_equal(outs[0], np.asarray(ident2.infer("h", x2)))
print("REPLICA UPDATE OK")

# --- collapse back to SINGLE resets the split-batch EWMAs ----------------
eng2._svc_ewma["h"] = 0.123
eng2._svc_req_ewma["h"] = 0.456
(shed_dev,) = [d for d in rec2.replicas]
eng2._drop_replica(rec2, shed_dev)
assert eng2.placer.placement_of("h").kind == SINGLE
assert "h" not in eng2._svc_ewma and "h" not in eng2._svc_req_ewma
print("COLLAPSE EWMA OK")
""" % (SRC,)


@pytest.mark.distributed
def test_sharded_and_replicated_updates_bit_identical():
    r = subprocess.run([sys.executable, "-c", SCRIPT_STREAM],
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, \
        f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    for tag in ("SHARDED UPDATE OK", "REPLICA UPDATE OK",
                "COLLAPSE EWMA OK"):
        assert tag in r.stdout
