"""Training substrate: optimizer, gradient compression, loss decrease."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding import collectives
from repro.training import optimizer as opt_mod


def test_adamw_decreases_quadratic():
    cfg = opt_mod.AdamWConfig(lr=0.1, warmup_steps=1, total_steps=100,
                              weight_decay=0.0, grad_clip=None)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt_mod.adamw_init(params)
    for _ in range(200):
        grads = {"w": 2 * state["master"]["w"]}  # d/dw w^2
        params, state, _ = opt_mod.adamw_update(cfg, grads, state,
                                                param_dtype=jnp.float32)
    assert float(jnp.abs(state["master"]["w"]).max()) < 0.15


def test_lr_schedule_shape():
    cfg = opt_mod.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                              min_lr_ratio=0.1)
    lrs = [float(opt_mod.lr_schedule(cfg, jnp.int32(s)))
           for s in [0, 5, 10, 55, 100]]
    assert lrs[1] < lrs[2]            # warmup rising
    assert lrs[2] >= lrs[3] >= lrs[4]  # cosine falling
    assert abs(lrs[4] - 0.1) < 1e-6    # floor


def test_grad_compression_error_feedback():
    rng = np.random.default_rng(0)
    grads = {"a": jnp.asarray(rng.standard_normal(1000).astype(np.float32))}
    ef = collectives.init_error_feedback(grads)
    q, scales, ef2 = collectives.compress_grads(grads, ef)
    assert q["a"].dtype == jnp.int8
    deq = collectives.decompress_grads(q, scales)
    # quantization error bounded by scale/2 per element
    err = np.abs(np.asarray(deq["a"] - grads["a"]))
    assert err.max() <= float(scales["a"]) * 0.51
    # error feedback carries exactly the residual
    np.testing.assert_allclose(np.asarray(ef2["a"]),
                               np.asarray(grads["a"] - deq["a"]), atol=1e-6)
    # accumulated EF over repeated compression of a constant gradient
    # converges in mean: sum of dequantized ≈ n * grad
    total = jnp.zeros(1000)
    ef = collectives.init_error_feedback(grads)
    n = 20
    for _ in range(n):
        q, s, ef = collectives.compress_grads(grads, ef)
        total = total + collectives.decompress_grads(q, s)["a"]
    np.testing.assert_allclose(np.asarray(total / n),
                               np.asarray(grads["a"]), atol=1e-2)


def test_grad_accum_matches_full_batch():
    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"]
        return jnp.mean((pred - batch["y"]) ** 2)

    rng = np.random.default_rng(1)
    params = {"w": jnp.asarray(rng.standard_normal((4, 2)).astype(np.float32))}
    batch = {"x": jnp.asarray(rng.standard_normal((8, 4)).astype(np.float32)),
             "y": jnp.asarray(rng.standard_normal((8, 2)).astype(np.float32))}
    g_full = jax.grad(loss_fn)(params, batch)
    g_acc, _ = collectives.grad_accum_microbatches(loss_fn, params, batch, 4)
    np.testing.assert_allclose(np.asarray(g_acc["w"]),
                               np.asarray(g_full["w"]), atol=1e-5)


def test_lm_loss_decreases():
    from repro.launch import train as train_mod

    losses = train_mod.main([
        "--arch", "qwen2-0.5b", "--reduced", "--steps", "25", "--batch",
        "4", "--seq", "32", "--lr", "2e-3", "--log-every", "100"])
    assert losses[-1] < losses[0] - 0.05
