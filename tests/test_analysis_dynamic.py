"""Runtime race-assertion mode (`repro.analysis.dynamic`, DESIGN.md
§14): the guarded() wrapper swaps the engine's locks for owner-tracking
ones and patches the annotated record classes so an unguarded write to
a swap-protected field is caught *as it happens*. The thread-fuzz here
drives concurrent update_graph + infer + submit/poll traffic and must
stay violation-free (the lock-discipline regression test for the
dispatch-vs-swap paths); the seeded twin proves the harness actually
catches a deliberately unguarded write."""
import threading

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.analysis.dynamic import (OwnedLock, RaceViolation,  # noqa: E402
                                    guarded)
from repro.core import csc, executor as exe, gcn  # noqa: E402
from repro.graphs import synth  # noqa: E402
from repro.serving.gcn_engine import GCNServingEngine  # noqa: E402
from repro.tuning import registry  # noqa: E402

N_NODES = 120
N_FEATS = 12
N_CLASSES = 4

FAST_KW = dict(
    iters=1,
    warmup=1,
    sweep=[
        dict(nnz_per_step=64, rows_per_window=32, cols_per_block=None,
             window_nnz=None, routing=exe.GATHER),
    ],
    bf16_report=False,
)


@pytest.fixture(autouse=True)
def _fresh_caches():
    registry.clear_caches()
    yield
    registry.clear_caches()


def _engine_with_graph(root, gid="g"):
    a = synth.power_law_adjacency(N_NODES, 0.04, 0.9, seed=7)
    cfg = gcn.GCNConfig(N_FEATS, 8, N_CLASSES)
    params = gcn.init_params(cfg, jax.random.PRNGKey(7))
    eng = GCNServingEngine(store_root=root, autotune_kwargs=FAST_KW)
    eng.add_graph(gid, a, params)
    x = np.random.default_rng(7).random((N_NODES, N_FEATS)).astype(np.float32)
    return eng, a, x


def _value_delta(coo, k, rng):
    row = np.asarray(coo.row)
    col = np.asarray(coo.col)
    idx = rng.choice(row.shape[0], size=min(k, row.shape[0]), replace=False)
    vals = (rng.random(idx.shape[0]) + 0.5).astype(np.float32)
    return csc.EdgeDelta(row[idx], col[idx], vals)


def _run_threads(workers):
    errors = []

    def wrap(fn):
        def run():
            try:
                fn()
            except Exception as e:  # pragma: no cover - surfaced via assert
                errors.append(e)

        return run

    threads = [threading.Thread(target=wrap(fn), name=name)
               for name, fn in workers]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120.0)
    assert not any(t.is_alive() for t in threads), "fuzz worker hung"
    return errors


def test_owned_lock_tracks_holder():
    lock = OwnedLock()
    assert not lock.held_by_me() and not lock.locked()
    with lock:
        assert lock.held_by_me() and lock.locked()
        seen = []
        t = threading.Thread(target=lambda: seen.append(lock.held_by_me()))
        t.start()
        t.join()
        assert seen == [False]
    assert not lock.held_by_me() and not lock.locked()


def test_thread_fuzz_clean(tmp_path):
    """Concurrent updates + sync serves + queued traffic under the race
    assertions: the engine's own lock discipline must produce zero
    violations. This is the regression test for the dispatch/poll
    executor-read paths racing update_graph's swap."""
    eng, a, x = _engine_with_graph(tmp_path)
    rounds = 12

    def updater():
        rng = np.random.default_rng(1)
        for _ in range(rounds):
            eng.update_graph("g", _value_delta(a, 6, rng))

    def server():
        for _ in range(rounds):
            out = eng.infer("g", x)
            assert np.asarray(out).shape == (N_NODES, N_CLASSES)

    def poller():
        for _ in range(rounds):
            eng.submit("g", x)
            eng.poll()
        eng.flush()

    with guarded(eng) as g:
        errors = _run_threads(
            [("updater", updater), ("server", server), ("poller", poller)]
        )
        eng.drain_persists()
    assert errors == []
    assert [v.render() for v in g.violations] == []


def test_thread_fuzz_catches_seeded_unguarded_write(tmp_path):
    """The same fuzz plus a rogue thread writing a guarded field without
    the lock — the harness must catch it (proves the assertions are
    armed, not vacuously green)."""
    eng, a, x = _engine_with_graph(tmp_path)
    rec = eng._graphs["g"]

    def rogue():
        rec.bytes = rec.bytes + 0  # unguarded write to a published record

    def server():
        for _ in range(4):
            eng.infer("g", x)

    with guarded(eng) as g:
        errors = _run_threads([("rogue", rogue), ("server", server)])
    assert errors == []
    assert any(
        v.cls == "_Resident" and v.field == "bytes" and v.lock == "_swap_lock"
        for v in g.violations
    ), [v.render() for v in g.violations]


def test_strict_mode_raises_at_the_faulting_write(tmp_path):
    eng, _a, _x = _engine_with_graph(tmp_path)
    rec = eng._graphs["g"]
    with guarded(eng, strict=True):
        with pytest.raises(RaceViolation, match="_swap_lock"):
            rec.fwd = rec.fwd
    # after exit the patch is gone: the same write is silent again
    rec.fwd = rec.fwd


def test_guarded_scope_restores_engine_state(tmp_path):
    eng, a, x = _engine_with_graph(tmp_path)
    plain_swap = eng._swap_lock
    with guarded(eng):
        assert isinstance(eng._swap_lock, OwnedLock)
        out = eng.infer("g", x)  # engine fully functional while armed
        assert np.asarray(out).shape == (N_NODES, N_CLASSES)
    assert eng._swap_lock is plain_swap
    assert "__setattr__" not in type(eng._graphs["g"]).__dict__


def test_concurrent_update_and_infer_outputs_stay_valid(tmp_path):
    """Functional face of the same regression: every serve during a
    storm of swaps returns a well-formed, finite output (no torn
    executor set, no missing executor)."""
    eng, a, x = _engine_with_graph(tmp_path)
    stop = threading.Event()

    def updater():
        rng = np.random.default_rng(2)
        while not stop.is_set():
            eng.update_graph("g", _value_delta(a, 4, rng))

    outs = []

    def server():
        try:
            for _ in range(20):
                outs.append(np.asarray(eng.infer("g", x)))
        finally:
            stop.set()

    errors = _run_threads([("updater", updater), ("server", server)])
    eng.drain_persists()
    assert errors == []
    assert len(outs) == 20
    for out in outs:
        assert out.shape == (N_NODES, N_CLASSES) and np.isfinite(out).all()
