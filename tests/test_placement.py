"""Mesh placement + deadline-aware serving acceptance.

Host-side ``MeshPlacer`` policy (bin-packing under per-device budgets,
sharded fallback, eviction-pressure rebalancing) is unit-tested without a
mesh; the engine-level acceptance — distinct-device placement, giant-graph
sharded admission, and restart warm-starts on an 8-way forced
host-platform mesh — runs in a subprocess under the ``distributed``
marker. Deadline scheduling (EDF order, auto-flush, miss accounting,
multi-failure flush restore) runs single-device in-process.
"""
import subprocess
import sys
import time
from pathlib import Path

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core import executor as exe, gcn, schedule  # noqa: E402
from repro.graphs import synth  # noqa: E402
from repro.serving.gcn_engine import (FlushError,  # noqa: E402
                                      GCNServingEngine, _Request)
from repro.serving.placement import (REPLICATED, SHARDED,  # noqa: E402
                                     SINGLE, MeshPlacer, Placement)
from repro.sharding import schedule_shard  # noqa: E402
from repro.tuning import registry  # noqa: E402

SRC = str(Path(__file__).resolve().parents[1] / "src")

N_NODES = 220
N_FEATS = 20
N_CLASSES = 5

FAST_SWEEP = [
    dict(nnz_per_step=64, rows_per_window=32, cols_per_block=None,
         window_nnz=None, routing=exe.GATHER),
    dict(nnz_per_step=128, rows_per_window=64, cols_per_block=None,
         window_nnz=None, routing=exe.GATHER),
]
FAST_KW = dict(iters=1, warmup=1, sweep=FAST_SWEEP, bf16_report=False)


@pytest.fixture(autouse=True)
def _fresh_caches():
    registry.clear_caches()
    yield
    registry.clear_caches()


def _workload(seed):
    a = synth.power_law_adjacency(N_NODES, 0.03, 0.9, seed=seed)
    cfg = gcn.GCNConfig(N_FEATS, 16, N_CLASSES)
    params = gcn.init_params(cfg, jax.random.PRNGKey(seed))
    x = np.random.default_rng(seed).random((N_NODES, N_FEATS),
                                           ).astype(np.float32)
    return a, params, x


def _engine(root, **kw):
    kw.setdefault("autotune_kwargs", FAST_KW)
    return GCNServingEngine(store_root=root, **kw)


# ---------------------------------------------------------------------------
# MeshPlacer policy (pure host-side — no mesh required)
# ---------------------------------------------------------------------------

def test_worst_fit_spreads_equal_graphs_across_devices():
    p = MeshPlacer(4, 1000)
    for i in range(4):
        pl = p.place(f"g{i}", 300)
        assert pl.kind == SINGLE
        p.account(f"g{i}", 300)
    assert sorted(pl.device_index for pl in p.placements.values()) == [
        0, 1, 2, 3]


def test_bin_packing_with_lru_eviction_never_exceeds_budget():
    """The engine's admission loop in miniature: place + account, evicting
    the least-recently-placed resident on any over-budget device. The
    per-device byte meter must never end a step over budget."""
    rng = np.random.default_rng(0)
    budget = 1000
    p = MeshPlacer(3, budget)
    order = []  # residency in admission order (the LRU stand-in)
    for i in range(40):
        gid = f"g{i}"
        nbytes = int(rng.integers(100, budget + 1))
        pl = p.place(gid, nbytes)
        assert pl.kind == SINGLE  # never over one device's budget here
        p.account(gid, nbytes)
        order.append((gid, nbytes))
        for d in range(p.n_devices):
            while p.used[d] > budget:
                victim = next(
                    (g for g, _ in order
                     if p.is_resident(g) and g != gid
                     and p.placements[g].device_index == d), None)
                assert victim is not None, "nothing left to evict"
                p.note_eviction(victim)
                p.unaccount(victim)
        assert all(p.used[d] <= budget for d in range(p.n_devices))
        assert all(u >= 0 for u in p.used)


def test_giant_graph_routes_sharded_only_on_multi_device_mesh():
    p = MeshPlacer(4, 1000)
    pl = p.place("giant", 5000)
    assert pl.kind == SHARDED and pl.n_devices == 4
    assert pl.device_indices == (0, 1, 2, 3)
    p.account("giant", 5000)
    assert all(u == 1250 for u in p.used)  # even ceil split
    p.unaccount("giant")
    assert all(u == 0 for u in p.used)
    # a 1-device mesh cannot shard: the graph stays single and the
    # engine's keep-active rule degrades to one-at-a-time rotation
    p1 = MeshPlacer(1, 1000)
    assert p1.place("giant", 5000).kind == SINGLE


def test_duplicate_place_or_account_rejected():
    p = MeshPlacer(2, 100)
    p.place("g", 10)
    with pytest.raises(ValueError, match="already placed"):
        p.place("g", 10)
    p.account("g", 10)
    with pytest.raises(ValueError, match="already accounted"):
        p.account("g", 10)
    p.forget("g")
    assert p.placements == {} and p.used == [0, 0]


def test_rebalance_triggers_on_concentrated_pressure_and_resets():
    p = MeshPlacer(2, 100, rebalance_after=3)
    p.place("a", 60)
    p.account("a", 60)       # a -> dev0
    p.place("b", 60)
    p.account("b", 60)       # b -> dev1 (worst fit)
    assert p.rebalance_target() is None
    # thrash graph a on device 0
    for _ in range(3):
        p.note_eviction("a")
        p.unaccount("a")
        p.account("a", 60)
    hot, cool = p.rebalance_target()
    assert (hot, cool) == (0, 1)
    p.move("a", cool)
    assert p.placements["a"].device_index == 1
    assert p.used == [0, 120]           # resident bytes moved with it
    assert p.evictions == [0, 0]        # pressure window reset
    assert p.n_rebalances == 1
    assert p.rebalance_target() is None


def test_sharded_graph_cannot_be_moved():
    p = MeshPlacer(2, 10)
    p.place("giant", 50)
    with pytest.raises(ValueError, match="sharded"):
        p.move("giant", 1)


def test_replica_grow_and_shrink_accounting():
    """add_replica lands on the coolest device, accounts one full clone
    footprint per replica device, and drop_replica frees exactly its
    device's share, collapsing to SINGLE at one remaining replica."""
    p = MeshPlacer(4, 1000)
    p.place("g", 300)
    p.account("g", 300)                  # primary on dev0
    assert p.replica_candidate("g") == 1
    assert p.add_replica("g", 300) == 1
    pl = p.placement_of("g")
    assert pl.kind == REPLICATED and pl.device_indices == (0, 1)
    assert pl.device_index == 0          # primary unchanged
    assert p.used == [300, 300, 0, 0]    # per-replica byte accounting
    p.place("other", 500)
    p.account("other", 500)              # worst-fit -> dev2
    assert p.replica_candidate("g") == 3  # coolest non-hosting device
    p.add_replica("g", 300, device_index=3)
    assert p.placement_of("g").device_indices == (0, 1, 3)
    assert p.used == [300, 300, 500, 300]
    pl = p.drop_replica("g", 1)
    assert pl.device_indices == (0, 3)
    assert p.used == [300, 0, 500, 300]
    pl = p.drop_replica("g", 3)
    assert pl.kind == SINGLE and pl.device_index == 0   # collapsed
    assert p.used == [300, 0, 500, 0]
    p.forget("g")
    assert p.used == [0, 0, 500, 0]


def test_replica_candidate_requires_room_for_the_clone():
    """Growth never evicts resident graphs to make space: with the
    clone's footprint passed, full devices are not candidates, and when
    nothing fits the candidate is None (the unfiltered query still names
    the coolest device)."""
    p = MeshPlacer(3, 1000)
    p.place("g", 400)
    p.account("g", 400)                  # dev0
    p.place("big", 900)
    p.account("big", 900)                # worst-fit -> dev1
    assert p.replica_candidate("g", 400) == 2    # dev1 has no room
    p.place("mid", 700)
    p.account("mid", 700)                # -> dev2
    assert p.replica_candidate("g", 400) is None  # nothing fits now
    assert p.replica_candidate("g") == 2          # unfiltered: coolest


def test_replica_unaccount_clears_every_device():
    p = MeshPlacer(3, 1000)
    p.place("g", 200)
    p.account("g", 200)
    p.add_replica("g", 200)
    p.add_replica("g", 200)
    assert p.used == [200, 200, 200]
    p.unaccount("g")
    assert p.used == [0, 0, 0] and not p.is_resident("g")


def test_replica_invariants_rejected():
    p = MeshPlacer(2, 1000)
    p.place("g", 100)
    with pytest.raises(ValueError, match="not resident"):
        p.add_replica("g", 100)          # must be admitted first
    p.account("g", 100)
    p.add_replica("g", 100)
    with pytest.raises(ValueError, match="already has a replica"):
        p.add_replica("g", 100)          # every device already hosts one
    with pytest.raises(ValueError, match="primary"):
        p.drop_replica("g", 0)
    with pytest.raises(ValueError, match="cannot move"):
        p.move("g", 1)                   # replicated graphs don't migrate
    p2 = MeshPlacer(2, 10)
    p2.place("giant", 50)                # sharded route
    p2.account("giant", 50)
    assert p2.replica_candidate("giant") is None
    with pytest.raises(ValueError, match="sharded"):
        p2.add_replica("giant", 50)


def test_device_report_lists_replicas_per_device():
    p = MeshPlacer(2, 1000)
    p.place("g", 100)
    p.account("g", 100)
    p.add_replica("g", 100)
    rep = p.device_report()
    assert rep[0]["resident"] == ["g"] and rep[1]["resident"] == ["g"]
    p.drop_replica("g", 1)
    rep = p.device_report()
    assert rep[0]["resident"] == ["g"] and rep[1]["resident"] == []


def test_shard_payload_bytes_matches_executor_footprint():
    """The placer's even-split accounting rests on the 12-bytes/slot
    padded-shard model; pin it to the real uploaded footprint so the
    model cannot drift from the executor."""
    a = synth.power_law_adjacency(300, 0.03, 0.9, seed=3)
    s = schedule.build_balanced_schedule(a, 32, 16)
    ex = exe.ShardedScheduleExecutor(s, n_devices=1, routing=exe.GATHER)
    assert int(schedule_shard.shard_payload_bytes(s, 1).sum()) == \
        ex.device_bytes
    # multi-device: the same arithmetic against the stacked shard layout
    # (equal padded shards — the even split IS the per-device slice)
    for d in (2, 3, 8):
        shards = schedule_shard.shard_schedule(s, d)
        per_dev = schedule_shard.shard_payload_bytes(s, d)
        assert per_dev.shape == (d,)
        assert (per_dev
                == shards.steps_per_shard * s.nnz_per_step * 12).all()


# ---------------------------------------------------------------------------
# Deadline-aware serving (single device, in-process)
# ---------------------------------------------------------------------------

def test_poll_serves_due_deadline_bit_identical_to_serve_batch(tmp_path):
    a, params, x = _workload(0)
    eng = _engine(tmp_path)
    eng.add_graph("g", a, params)
    xs = [x, x * 0.5, x + 0.1]
    for xi in xs:
        eng.submit("g", xi, deadline_s=60.0)
    # not due yet: deadline is a minute out and the service estimate is 0
    assert eng.poll() == {}
    assert eng.stats()["pending_requests"] == 3
    # due once the (injected) clock passes the deadline window
    out = eng.poll(now=time.monotonic() + 61.0)
    assert set(out) == {"g"} and out["g"].shape == (3, N_NODES, N_CLASSES)
    # acceptance: the auto-flushed batch is BIT-identical to the manual
    # serve_batch path (same jitted vmapped forward, same stacking)
    ref = eng.serve_batch("g", xs)
    assert np.array_equal(np.asarray(out["g"]), np.asarray(ref))
    # real deadline was a minute out: completion must have beaten it
    st = eng.stats()
    assert st["deadline_met"] == 3 and st["deadline_misses"] == 0
    assert st["latency_us_mean"] > 0 and st["pending_requests"] == 0


def test_service_time_estimate_dispatches_before_deadline(tmp_path):
    a, params, x = _workload(1)
    eng = _engine(tmp_path)
    eng.add_graph("g", a, params)
    eng.submit("g", x, deadline_s=60.0)
    now = time.monotonic()
    assert eng.poll(now=now) == {}  # 60s of slack, no service estimate
    # a measured 61s batch service time means the queue is already due:
    # waiting any longer guarantees a miss
    eng._svc_ewma["g"] = 61.0
    out = eng.poll(now=now)
    assert set(out) == {"g"}


def test_past_deadline_records_miss(tmp_path):
    a, params, x = _workload(2)
    eng = _engine(tmp_path)
    eng.add_graph("g", a, params)
    eng.submit("g", x, deadline_s=-1.0)  # already expired at submit
    out = eng.poll()
    assert set(out) == {"g"}
    assert eng.stats()["deadline_misses"] == 1
    assert eng.stats()["deadline_met"] == 0


def test_max_batch_threshold_auto_flushes(tmp_path):
    a, params, x = _workload(3)
    eng = _engine(tmp_path, max_batch=2)
    eng.add_graph("g", a, params)
    eng.submit("g", x)
    assert eng.stats()["pending_requests"] == 1
    eng.submit("g", x * 0.5)  # hits the threshold: batch serves now
    assert eng.stats()["pending_requests"] == 0
    assert eng.counters["batches"] == 1
    # the auto-flushed results await pickup by the next poll/flush
    out = eng.flush()
    assert out["g"].shape == (2, N_NODES, N_CLASSES)
    np.testing.assert_allclose(
        np.asarray(out["g"][1]),
        np.asarray(gcn.forward(params, a, jnp.asarray(x * 0.5))), atol=1e-3)


def test_flush_order_is_edf_then_graph_id_not_insertion(tmp_path):
    graphs = {f"g{i}": _workload(10 + i) for i in range(3)}
    eng = _engine(tmp_path)
    for gid, (a, params, x) in graphs.items():
        eng.add_graph(gid, a, params)
    # submission order g2, g0, g1; deadlines order the flush g1 < g0,
    # deadline-free g2 last — regardless of insertion order
    eng.submit("g2", graphs["g2"][2])
    eng.submit("g0", graphs["g0"][2], deadline_s=500.0)
    eng.submit("g1", graphs["g1"][2], deadline_s=100.0)
    order = []
    orig = eng._dispatch_batch

    def recording(graph_id, xs):
        order.append(graph_id)
        return orig(graph_id, xs)

    eng._dispatch_batch = recording
    eng.flush()
    assert order == ["g1", "g0", "g2"]


def test_flush_restores_multiple_failed_queues_in_order(tmp_path):
    """Satellite fix acceptance: several graphs failing in ONE flush all
    get their queues restored, at the front, in original order."""
    graphs = {f"g{i}": _workload(20 + i) for i in range(3)}
    eng = _engine(tmp_path)
    for gid, (a, params, x) in graphs.items():
        eng.add_graph(gid, a, params)
    for gid, (a, params, x) in graphs.items():
        eng.submit(gid, x)
        eng.submit(gid, x * 2.0)
    orig = eng._dispatch_batch

    def failing(graph_id, xs):
        if graph_id in ("g0", "g2"):
            raise RuntimeError(f"{graph_id} device fell over")
        return orig(graph_id, xs)

    eng._dispatch_batch = failing
    with pytest.raises(FlushError) as exc_info:
        eng.flush()
    err = exc_info.value
    assert set(err.failures) == {"g0", "g2"}
    assert set(err.partial) == {"g1"}
    assert err.partial["g1"].shape == (2, N_NODES, N_CLASSES)
    # both failed queues survived, original order intact
    for gid in ("g0", "g2"):
        q = eng._pending[gid]
        assert len(q) == 2
        np.testing.assert_array_equal(np.asarray(q[0].x), graphs[gid][2])
        np.testing.assert_array_equal(np.asarray(q[1].x),
                                      graphs[gid][2] * 2.0)
    assert "g1" not in eng._pending
    eng._dispatch_batch = orig
    out = eng.flush()
    assert set(out) == {"g0", "g2"}
    assert all(v.shape == (2, N_NODES, N_CLASSES) for v in out.values())


def test_restored_queue_front_ordering_with_new_submissions(tmp_path):
    """A failed queue must be restored AT THE FRONT: requests submitted
    after the failed flush retry must serve after the restored ones."""
    a, params, x = _workload(30)
    eng = _engine(tmp_path)
    eng.add_graph("g", a, params)
    eng.submit("g", x)
    orig = eng._dispatch_batch
    eng._dispatch_batch = lambda *a_, **k: (_ for _ in ()).throw(
        RuntimeError("boom"))
    with pytest.raises(FlushError):
        eng.flush()
    eng._dispatch_batch = orig
    eng.submit("g", x * 3.0)
    q = eng._pending["g"]
    np.testing.assert_array_equal(np.asarray(q[0].x), x)       # restored
    np.testing.assert_array_equal(np.asarray(q[1].x), x * 3.0)  # newer
    out = eng.flush()
    assert out["g"].shape == (2, N_NODES, N_CLASSES)


# ---------------------------------------------------------------------------
# poll()'s per-device load map (clock-injected, no real mesh: the map runs
# on placer indices only, so a stubbed placer + hand-built queues pin the
# dispatch decisions deterministically)
# ---------------------------------------------------------------------------

def _load_map_engine(tmp_path, placements):
    """Engine whose scheduler state is hand-built: a stubbed 2-device
    placer, injected service EWMAs, and a _serve_queues that records
    instead of serving."""
    eng = GCNServingEngine(store_root=tmp_path)
    eng.placer = MeshPlacer(2, 1 << 30)
    eng.placer.placements.update(placements)
    eng._serve_queues = lambda gids, now=None: {g: None for g in gids}
    return eng


def _queue(eng, gid, deadline):
    eng._pending.setdefault(gid, []).append(
        _Request(rid=0, x=None, submit_t=0.0, deadline=deadline))


def test_poll_load_map_stacks_colocated_queues(tmp_path):
    """Two queues on ONE device serialize: the tail queue's slack must
    absorb the cumulative service time of everything EDF-ahead of it on
    that device, so it dispatches earlier than its own estimate alone
    would suggest."""
    eng = _load_map_engine(tmp_path, {"a": Placement(SINGLE, 0, 1),
                                      "b": Placement(SINGLE, 0, 1)})
    eng._svc_ewma.update(a=10.0, b=10.0)
    _queue(eng, "a", deadline=1000.0)
    _queue(eng, "b", deadline=1001.0)
    # slack(a) = 1.5*10 + 0.01 -> due at 984.99
    # slack(b) = 1.5*(10 + 10) + 0.01 -> due at 970.99 (stacked behind a)
    assert eng.poll(now=969.0) == {}
    assert set(eng.poll(now=975.0)) == {"a", "b"}  # b due; a rides along


def test_poll_load_map_keeps_devices_independent(tmp_path):
    """The same two queues on DIFFERENT devices do not stack: each
    dispatches on its own estimate. A global (per-engine) accumulator
    would serve both a full stacked-slack early."""
    eng = _load_map_engine(tmp_path, {"a": Placement(SINGLE, 0, 1),
                                      "b": Placement(SINGLE, 1, 1)})
    eng._svc_ewma.update(a=10.0, b=10.0)
    _queue(eng, "a", deadline=1000.0)
    _queue(eng, "b", deadline=1001.0)
    assert eng.poll(now=975.0) == {}              # neither due yet
    assert set(eng.poll(now=985.5)) == {"a"}      # a due; b not (985.99)


def test_poll_load_map_sharded_occupies_every_device(tmp_path):
    """A sharded queue synchronizes the whole mesh at its psum: every
    device advances to its completion time, so a single-device queue
    behind it stacks even though they share no explicit device index."""
    eng = _load_map_engine(
        tmp_path, {"s": Placement(SHARDED, None, 2),
                   "b": Placement(SINGLE, 1, 1)})
    eng._svc_ewma.update(s=10.0, b=10.0)
    _queue(eng, "s", deadline=1000.0)
    _queue(eng, "b", deadline=1001.0)
    # b stacks behind s on device 1: due at 1001 - (1.5*20 + 0.01)
    assert set(eng.poll(now=975.0)) == {"s", "b"}


def test_poll_load_map_replicated_follows_least_loaded_replica(tmp_path):
    """Regression (ISSUE 5): the old load map overwrote every device of a
    multi-device placement with the max-ahead estimate. For a REPLICATED
    queue that is exactly wrong — the batch routes to the least-loaded
    clone, so a busy co-replica device must not drag the dispatch
    forward. Here the hot graph's replica on device 1 is idle: its queue
    is due from its own estimate (due at 1084.99), not from device 0's
    50 s backlog (which the old max-ahead rule would have turned into
    dispatch at 1009.99 — an hour-early batch-splitting waste)."""
    eng = _load_map_engine(
        tmp_path, {"busy": Placement(SINGLE, 0, 1),
                   "hot": Placement(REPLICATED, 0, 1, (0, 1))})
    eng._svc_ewma.update(busy=50.0, hot=10.0)
    _queue(eng, "busy", deadline=1000.0)
    _queue(eng, "hot", deadline=1100.0)
    out = eng.poll(now=1020.0)
    assert set(out) == {"busy"}, (
        "replicated queue dispatched off the busiest replica's backlog")
    assert set(eng.poll(now=1090.0)) == {"busy", "hot"}


def test_placement_survives_restart_warm_start(tmp_path):
    """Restart on the same store: zero sweeps, placements re-derived, and
    the deadline scheduler keeps serving."""
    graphs = {f"g{i}": _workload(40 + i) for i in range(2)}
    eng = _engine(tmp_path)
    refs = {}
    for gid, (a, params, x) in graphs.items():
        rep = eng.add_graph(gid, a, params)
        assert not rep.warm_start
        assert rep.placement.kind == SINGLE
        refs[gid] = np.asarray(eng.infer(gid, x))

    registry.clear_caches()  # ≈ restart (store survives)
    eng2 = _engine(tmp_path)
    for gid, (a, params, x) in graphs.items():
        rep = eng2.add_graph(gid, a, params)
        assert rep.warm_start and rep.tune_seconds == 0.0
        assert rep.placement.kind == SINGLE
    assert eng2.counters["store_hits"] == 2
    assert eng2.counters["store_misses"] == 0
    for gid, (a, params, x) in graphs.items():
        eng2.submit(gid, x, deadline_s=0.0)
    out = eng2.poll()
    assert set(out) == set(graphs)
    for gid in graphs:
        np.testing.assert_allclose(np.asarray(out[gid][0]), refs[gid],
                                   atol=1e-5)


def test_single_device_engine_keeps_default_placement_handle(tmp_path):
    """A graph placed on the process-default device gets a None handle —
    its uploads share the (schedule, None) cache with the registry and
    kernel paths instead of paying a duplicate pinned copy."""
    a, params, x = _workload(50)
    eng = _engine(tmp_path)
    eng.add_graph("g", a, params)
    rec = eng._graphs["g"]
    assert rec.executor.device is None
    out = eng.infer("g", x)
    assert out.devices() == {jax.devices()[0]}


# ---------------------------------------------------------------------------
# Mesh acceptance on 8 forced host devices (subprocess)
# ---------------------------------------------------------------------------

SCRIPT_MESH = r"""
import os, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, tempfile
sys.path.insert(0, %r)
import numpy as np, jax, jax.numpy as jnp
from repro.core import executor as exe, gcn
from repro.core.executor import ShardedScheduleExecutor
from repro.graphs import synth
from repro.serving.gcn_engine import GCNServingEngine
from repro.serving.placement import SHARDED, SINGLE
from repro.tuning import registry
assert len(jax.devices()) == 8

SWEEP = [dict(nnz_per_step=64, rows_per_window=32, cols_per_block=None,
              window_nnz=None, routing=exe.GATHER)]
KW = dict(iters=1, warmup=1, sweep=SWEEP, bf16_report=False)

def workload(n, density, seed):
    a = synth.power_law_adjacency(n, density, 0.9, seed=seed)
    cfg = gcn.GCNConfig(16, 16, 4)
    params = gcn.init_params(cfg, jax.random.PRNGKey(seed))
    x = np.random.default_rng(seed).random((n, 16)).astype(np.float32)
    return a, params, x

small = {f"g{i}": workload(260, 0.03, i) for i in range(4)}
giant = workload(3000, 0.01, 99)
est_small = max(a.nnz * 16 + 3000 for a, _, _ in small.values())
budget = 6 * est_small          # every small graph fits; the giant cannot
assert giant[0].nnz * 16 > budget

root = tempfile.mkdtemp(prefix="awb-placement-")
eng = GCNServingEngine(store_root=root, devices=8,
                       device_budget_bytes=budget, autotune_kwargs=KW)

# --- distinct-device bin-packing, verified via executor shardings --------
devs = {}
for gid, (a, params, x) in small.items():
    rep = eng.add_graph(gid, a, params)
    assert rep.placement.kind == SINGLE
    rec = eng._graphs[gid]
    (dev,) = eng.infer(gid, x).devices()
    assert dev == eng.devices[rep.placement.device_index]
    # default-device placements keep a None handle (shared upload cache);
    # every other mesh device is explicitly pinned
    assert rec.executor.device == (None if dev == jax.devices()[0] else dev)
    devs[gid] = dev
assert len(set(devs.values())) == 4, devs
print("DISTINCT OK", sorted(d.id for d in devs.values()))

# --- giant graph: sharded admission spanning the mesh --------------------
a_g, p_g, x_g = giant
rep = eng.add_graph("giant", a_g, p_g)
assert rep.placement.kind == SHARDED and rep.placement.n_devices == 8
rec = eng._graphs["giant"]
assert isinstance(rec.executor, ShardedScheduleExecutor)
assert rec.executor.n_devices == 8
assert rep.config.n_devices == 8
got = np.asarray(eng.infer("giant", x_g))
ref = np.asarray(gcn.forward(p_g, a_g, jnp.asarray(x_g)))
np.testing.assert_allclose(got, ref, atol=1e-3)
print("SHARDED OK")

# --- deadline auto-flush bit-identical to manual serve_batch -------------
xs = [x_g, x_g * 0.5]
for xi in xs:
    eng.submit("giant", xi, deadline_s=60.0)
for gid, (a, params, x) in small.items():
    eng.submit(gid, x, deadline_s=30.0)
assert eng.poll() == {}
out = eng.poll(now=time.monotonic() + 61.0)
assert set(out) == set(small) | {"giant"}
ref_b = eng.serve_batch("giant", xs)
assert np.array_equal(np.asarray(out["giant"]), np.asarray(ref_b))
for gid, (a, params, x) in small.items():
    ref_b = eng.serve_batch(gid, [x])
    assert np.array_equal(np.asarray(out[gid]), np.asarray(ref_b))
st = eng.stats()
assert st["deadline_met"] == 6 and st["deadline_misses"] == 0
print("DEADLINE OK")

# --- restart: both routes warm-start from the store ----------------------
registry.clear_caches()
eng2 = GCNServingEngine(store_root=root, devices=8,
                        device_budget_bytes=budget, autotune_kwargs=KW)
for gid, (a, params, x) in small.items():
    rep = eng2.add_graph(gid, a, params)
    assert rep.warm_start and rep.tune_seconds == 0.0
rep = eng2.add_graph("giant", a_g, p_g)
assert rep.warm_start and rep.placement.kind == SHARDED
assert eng2.counters["store_hits"] == 5
assert eng2.counters["store_misses"] == 0
got = np.asarray(eng2.infer("giant", x_g))
np.testing.assert_allclose(got, ref, atol=1e-3)
print("WARM OK")

# --- eviction pressure concentrated on one device triggers migration -----
registry.clear_caches()
per_graph = {gid: eng._graphs[gid].bytes for gid in small}
tight = int(max(per_graph.values()) * 1.3)   # one graph per device
assert all(a.nnz * 16 + 3000 <= tight for a, _, _ in small.values())
eng3 = GCNServingEngine(store_root=root, devices=2,
                        device_budget_bytes=tight, rebalance_after=3,
                        autotune_kwargs=KW)
refs = {}
for gid in ("g0", "g1", "g2"):
    a, params, x = small[gid]
    rep = eng3.add_graph(gid, a, params)
    assert rep.warm_start and rep.placement.kind == SINGLE
    refs[gid] = np.asarray(gcn.forward(params, a, jnp.asarray(x)))
# two of the three graphs share a device: alternating them thrashes it
# while the other device idles; the placer must notice the concentrated
# pressure and migrate one of the pair
placed = {gid: eng3.placer.placements[gid].device_index
          for gid in ("g0", "g1", "g2")}
shared = [d for d in set(placed.values())
          if sum(1 for v in placed.values() if v == d) == 2]
assert shared, placed
pair = sorted(g for g, d in placed.items() if d == shared[0])
for _ in range(6):
    for gid in pair:
        np.testing.assert_allclose(
            np.asarray(eng3.infer(gid, small[gid][2])), refs[gid],
            atol=1e-3)
assert eng3.counters["rebalances"] >= 1, eng3.stats()
assert eng3.counters["evictions"] >= 3
for gid in ("g0", "g1", "g2"):   # every graph still serves correctly
    np.testing.assert_allclose(
        np.asarray(eng3.infer(gid, small[gid][2])), refs[gid], atol=1e-3)
print("REBALANCE OK")
""" % (SRC,)


@pytest.mark.distributed
def test_mesh_placement_sharded_giant_and_deadline_acceptance():
    r = subprocess.run([sys.executable, "-c", SCRIPT_MESH],
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, \
        f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    for tag in ("DISTINCT OK", "SHARDED OK", "DEADLINE OK", "WARM OK",
                "REBALANCE OK"):
        assert tag in r.stdout


# ---------------------------------------------------------------------------
# Multi-replica serving of a hot graph (8 forced host devices, subprocess)
# ---------------------------------------------------------------------------

SCRIPT_REPLICA = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, tempfile
sys.path.insert(0, %r)
import numpy as np, jax, jax.numpy as jnp
from repro.core import executor as exe, gcn, schedule
from repro.graphs import synth
from repro.serving.gcn_engine import GCNServingEngine
from repro.serving.placement import REPLICATED, SINGLE
from repro.tuning import registry, runner
assert len(jax.devices()) == 8

SWEEP = [dict(nnz_per_step=64, rows_per_window=32, cols_per_block=None,
              window_nnz=None, routing=exe.GATHER)]
KW = dict(iters=1, warmup=1, sweep=SWEEP, bf16_report=False)

n = 300
a = synth.power_law_adjacency(n, 0.03, 0.9, seed=5)
cfg = gcn.GCNConfig(16, 16, 4)
params = gcn.init_params(cfg, jax.random.PRNGKey(5))
x = np.random.default_rng(5).random((n, 16)).astype(np.float32)
reqs = [x * (1.0 - 0.02 * i) for i in range(12)]
root = tempfile.mkdtemp(prefix="awb-replica-")

# --- single-replica reference: max_replicas=1 pins the pre-replica path --
ref_eng = GCNServingEngine(store_root=root, devices=8, max_replicas=1,
                           replicate_after_s=1e-6, autotune_kwargs=KW)
ref_eng.add_graph("hot", a, params)
ref = np.asarray(ref_eng.serve_batch("hot", reqs))
for r in reqs:
    ref_eng.submit("hot", r, deadline_s=0.0)
assert set(ref_eng.poll()) == {"hot"}
assert ref_eng.stats()["replicas"] == {}
assert ref_eng.counters["replicas_added"] == 0    # cap honoured
print("SINGLE OK")

# --- saturation grows replicas; growth is warm (no sweep, no rebuild) ----
registry.clear_caches()
eng = GCNServingEngine(store_root=root, devices=8, max_replicas=3,
                       replicate_after_s=1e-6, replica_shrink_after=2,
                       autotune_kwargs=KW)
rep = eng.add_graph("hot", a, params)
assert rep.warm_start
eng.serve_batch("hot", reqs[:2])          # prime the service EWMA
assert eng._svc_req_ewma["hot"] > 0
orig_measure = runner.measure_candidate
orig_build = schedule.build_balanced_schedule
runner.measure_candidate = lambda *a_, **k: (_ for _ in ()).throw(
    AssertionError("measured sweep during replica growth"))
schedule.build_balanced_schedule = lambda *a_, **k: (_ for _ in ()).throw(
    AssertionError("schedule rebuild during replica growth"))
outs = []
for _ in range(3):
    for r in reqs:
        eng.submit("hot", r, deadline_s=0.0)
    outs.append(np.asarray(eng.poll()["hot"]))
pl = eng.placer.placement_of("hot")
assert pl.kind == REPLICATED and len(set(pl.device_indices)) == 3, pl
assert eng.counters["replicas_added"] == 2
st = eng.stats()
assert st["replicas"] == {"hot": list(pl.device_indices)}
per_dev = {d["device"]: d["resident"] for d in st["per_device"]}
for d in pl.device_indices:
    assert "hot" in per_dev[d]
# secondary replicas are pinned executors on their own mesh devices
for d, unit in eng._graphs["hot"].replicas.items():
    assert unit.executor.device == eng.devices[d]
print("GROW OK", pl.device_indices)

# --- bit-identical logits no matter which replica served -----------------
for out in outs:
    assert out.shape == ref.shape
    assert np.array_equal(out, ref), "replica outputs diverged"
direct = np.asarray(eng.serve_batch("hot", reqs))  # splits across replicas
assert np.array_equal(direct, ref)
# a batch of one serves on the least-loaded clone, but the output still
# lands committed to the PRIMARY's device — which replica served must be
# unobservable, placement included
one = eng.serve_batch("hot", [x])
assert one.devices() == {eng.devices[0]}, one.devices()
print("BITIDENTICAL OK")

# --- budget sweep sheds a secondary replica before evicting a graph ------
runner.measure_candidate = orig_measure
schedule.build_balanced_schedule = orig_build
a2 = synth.power_law_adjacency(260, 0.03, 0.9, seed=6)
p2 = gcn.init_params(cfg, jax.random.PRNGKey(6))
x2 = np.random.default_rng(6).random((260, 16)).astype(np.float32)
eng.add_graph("cold", a2, p2)
eng.infer("cold", x2)               # cold is most-recently-served
sec = sorted(eng._graphs["hot"].replicas)[0]
drops = eng.counters["replicas_dropped"]
eng.placer.used[sec] += eng.placer.budget   # simulated pressure on sec
eng._evict_over_budget(keep="cold")
eng.placer.used[sec] -= eng.placer.budget
assert eng.counters["replicas_dropped"] == drops + 1
assert sec not in eng._graphs["hot"].replicas
assert eng._graphs["hot"].executor is not None   # hot was NOT evicted
assert eng.counters["evictions"] == 0            # nobody paid a full evict
print("SHED OK")

# --- shrink back under idle pressure -------------------------------------
bytes_replicated = eng.device_bytes_in_use
for _ in range(8):
    eng.poll()                            # empty queues: calm accumulates
pl = eng.placer.placement_of("hot")
assert pl.kind == SINGLE, pl
assert eng.counters["replicas_dropped"] == 2
assert eng.device_bytes_in_use < bytes_replicated
assert eng._graphs["hot"].replicas == {}
assert np.array_equal(np.asarray(eng.serve_batch("hot", reqs)), ref)
print("SHRINK OK")
""" % (SRC,)


@pytest.mark.distributed
def test_replicated_hot_graph_acceptance():
    r = subprocess.run([sys.executable, "-c", SCRIPT_REPLICA],
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, \
        f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    for tag in ("SINGLE OK", "GROW OK", "BITIDENTICAL OK", "SHED OK",
                "SHRINK OK"):
        assert tag in r.stdout
