"""The `repro.analysis` static-analysis subsystem (DESIGN.md §14):
fixture-proven rule coverage (every rule fires on its bad fixture and
stays silent on its good twin), the jit-site call-graph walk, waiver
matching + staleness, the CLI contract, and the repo-tree invariant the
CI lint job gates on (zero unwaived findings with the committed
waivers). Pure stdlib — no jax required."""
import ast
import re
import textwrap

import pytest

from repro.analysis import __main__ as cli
from repro.analysis import callgraph, counters, driver, jax_hazards, locks
from repro.analysis.findings import Finding, load_waivers, split_findings
from repro.analysis.modules import ModuleInfo

from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
FIXTURES = REPO / "tests" / "fixtures" / "analysis"
WAIVERS = REPO / "src" / "repro" / "analysis" / "waivers.toml"

_EXPECT_RE = re.compile(r"#\s*expect:\s*(.*)")


def _module(source, path="<test>.py"):
    return ModuleInfo(path, textwrap.dedent(source))


def _expected_rules(path):
    for line in path.read_text().splitlines()[:5]:
        m = _EXPECT_RE.search(line)
        if m:
            names = m.group(1).strip()
            if names.lower() == "none":
                return set()
            return {n.strip() for n in names.split(",") if n.strip()}
    raise AssertionError(f"{path} has no # expect: header")


# ---- fixtures: every rule fires on bad, stays silent on good ------------


@pytest.mark.parametrize(
    "fixture", sorted(FIXTURES.glob("*.py")), ids=lambda p: p.stem
)
def test_fixture_triggers_exactly_its_rules(fixture):
    expected = _expected_rules(fixture)
    fired = {f.rule for f in driver.analyze_file(fixture)}
    assert fired == expected


def test_every_rule_has_a_bad_fixture():
    covered = set()
    for fixture in FIXTURES.glob("bad_*.py"):
        covered |= _expected_rules(fixture)
    assert covered == set(driver.ALL_RULES)


def test_self_check_passes_on_committed_fixtures():
    assert driver.self_check(FIXTURES) == []


def test_self_check_fails_on_empty_dir(tmp_path):
    assert driver.self_check(tmp_path)  # "no fixtures found"


def test_self_check_requires_expect_header(tmp_path):
    (tmp_path / "f.py").write_text("x = 1\n")
    problems = driver.self_check(tmp_path)
    assert any("missing `# expect:`" in p for p in problems)


def test_self_check_rejects_unknown_rule(tmp_path):
    (tmp_path / "f.py").write_text("# expect: no-such-rule\n")
    problems = driver.self_check(tmp_path)
    assert any("unknown rules" in p for p in problems)


# ---- call-graph walk -----------------------------------------------------


def test_jit_roots_decorator_partial_and_wrapping_call():
    mod = _module(
        """
        import functools
        import jax

        @jax.jit
        def a(x):
            return x

        @functools.partial(jax.jit, static_argnames=("k",))
        def b(x, k):
            return x

        def c(x):
            return x

        cc = jax.jit(c)
        """
    )
    roots = {r.func.qualname: r for r in callgraph.find_jit_roots(mod)}
    assert set(roots) == {"a", "b", "c"}
    assert roots["b"].static_argnames == frozenset({"k"})


def test_reachability_follows_references_and_method_aliases():
    mod = _module(
        """
        import jax

        def helper(x):
            return x

        class Ex:
            def __init__(self, gather):
                self._impl = self._gather if gather else self._onehot
                self._fn = jax.jit(self._impl)

            def _gather(self, x):
                return helper(x)

            def _onehot(self, x):
                return x
        """
    )
    reach = callgraph.jit_reachable(mod)
    assert {"Ex._gather", "Ex._onehot", "helper"} <= set(reach)
    assert not reach["helper"].is_root


def test_nested_function_root_resolves_by_bare_name():
    mod = _module(
        """
        import jax

        def make():
            def step(x):
                return x
            return jax.jit(step)
        """
    )
    assert "make.step" in callgraph.jit_reachable(mod)


# ---- hazard pass ---------------------------------------------------------


def test_static_argnames_suppress_traced_branch():
    mod = _module(
        """
        import functools
        import jax

        @functools.partial(jax.jit, static_argnames=("flag",))
        def f(x, flag):
            if flag:
                return x
            if x:
                return -x
            return x
        """
    )
    found = [f for f in jax_hazards.check_module(mod)]
    assert len(found) == 1 and found[0].rule == "jax-traced-branch"
    assert "if" in found[0].message and found[0].line == 9


def test_taint_cleared_by_static_metadata():
    mod = _module(
        """
        import jax

        @jax.jit
        def f(x):
            n = x.shape[0]
            if n > 4:
                return x
            return float(n)
        """
    )
    assert jax_hazards.check_module(mod) == []


def test_helper_kwonly_params_are_static_but_root_kwonly_are_traced():
    mod = _module(
        """
        import jax

        @jax.jit
        def root(x, *, mode):
            if mode:
                return helper(x, flip=True)
            return x

        def helper(x, *, flip):
            if flip:
                return -x
            return x
        """
    )
    found = jax_hazards.check_module(mod)
    assert [f.symbol for f in found] == ["root"]


# ---- lock pass -----------------------------------------------------------


def test_guard_comment_on_multiline_declaration():
    mod = _module(
        """
        import threading

        class E:
            def __init__(self):
                self._lock = threading.Lock()
                self._slow: object = (
                    None  # guarded-by: _lock
                )

            def poke(self):
                return self._slow
        """
    )
    assert locks.collect_guarded(mod) == {"E": {"_slow": "_lock"}}
    found = locks.check_module(mod)
    assert [f.rule for f in found] == ["lock-guard"]


def test_lock_order_from_declaration_order():
    mod = _module(
        """
        import threading

        class E:
            def __init__(self):
                self._a = threading.Lock()
                self._b = threading.Lock()
        """
    )
    assert locks.lock_declaration_order(mod) == ["_a", "_b"]


def test_constructor_fresh_objects_exempt():
    mod = _module(
        """
        import threading

        class Rec:
            value: int = 0  # guarded-by: _lock

        class E:
            def __init__(self):
                self._lock = threading.Lock()

            def fresh(self):
                rec = Rec()
                rec.value = 3
                return rec
        """
    )
    assert locks.check_module(mod) == []


# ---- counter pass --------------------------------------------------------


def test_named_settlement_list_only_covers_named_counters():
    mod = _module(
        """
        class E:
            def __init__(self):
                self.counters = {"a": 0, "b": 0}

            # counter-settlement: a
            def settle(self):
                self.counters["a"] += 1
                self.counters["b"] += 1
        """
    )
    found = counters.check_module(mod)
    assert len(found) == 1 and "counters['b']" in found[0].message


def test_dict_swap_through_name_is_not_a_mutation():
    mod = _module(
        """
        class E:
            def grab(self):
                fresh = {}
                out, self.counters = self.counters, fresh
                return out
        """
    )
    # tuple-target reassignment from a Name: a swap, not a settlement
    assert counters.check_module(mod) == []


# ---- waivers -------------------------------------------------------------


def _finding(rule="lock-guard", path="src/x.py", symbol="E.m", line=3):
    return Finding(rule=rule, path=path, line=line, symbol=symbol, message="m")


def test_waiver_matches_by_suffix_and_reports_stale(tmp_path):
    toml = tmp_path / "w.toml"
    toml.write_text(
        '[[waiver]]\nrule = "lock-guard"\npath = "x.py"\n'
        'symbol = "E.m"\nreason = "by design"\n'
        '[[waiver]]\nrule = "lock-order"\npath = "gone.py"\n'
        'symbol = "E.n"\nreason = "stale entry"\n'
    )
    waivers = load_waivers(toml)
    unwaived, waived, stale = split_findings([_finding()], waivers)
    assert not unwaived and len(waived) == 1
    assert [w.path for w in stale] == ["gone.py"]


def test_waiver_requires_reason(tmp_path):
    toml = tmp_path / "w.toml"
    toml.write_text('[[waiver]]\nrule = "lock-guard"\npath = "x.py"\nsymbol = "s"\n')
    with pytest.raises(ValueError):
        load_waivers(toml)


# ---- CLI + repo-tree invariant ------------------------------------------


def test_cli_list_rules(capsys):
    assert cli.main(["--list-rules"]) == 0
    out = capsys.readouterr().out.split()
    assert set(out) == set(driver.ALL_RULES)


def test_cli_exit_one_on_findings(capsys):
    rc = cli.main([str(FIXTURES / "bad_counter.py"), "--no-waivers"])
    assert rc == 1
    assert "counter-settlement" in capsys.readouterr().out


def test_cli_json_output(capsys):
    import json

    rc = cli.main([str(FIXTURES / "bad_np_call.py"), "--no-waivers", "--json"])
    assert rc == 1
    payload = json.loads(capsys.readouterr().out)
    assert payload["unwaived"][0]["rule"] == "jax-np-call"


def test_repo_tree_has_no_unwaived_findings():
    report = driver.run_analysis(
        [str(REPO / "src"), str(REPO / "benchmarks")], WAIVERS
    )
    assert report.errors == []
    assert [f.render() for f in report.unwaived] == []
    assert report.stale_waivers == []
    # the engine waivers are real (still matching) — not dead weight
    assert report.waived, "expected the documented by-design waivers to match"


def test_engine_annotations_are_registered():
    """The gcn_engine annotations parse into the guarded-field map the
    dynamic mode shares (single source of truth)."""
    path = REPO / "src" / "repro" / "serving" / "gcn_engine.py"
    mod = ModuleInfo(str(path), path.read_text())
    guarded = locks.collect_guarded(mod)
    assert guarded["_Resident"] == {
        "fingerprint": "_swap_lock",
        "params": "_swap_lock",
        "executor": "_swap_lock",
        "fwd": "_swap_lock",
        "bytes": "_swap_lock",
        "replicas": "_swap_lock",
        "revision": "_swap_lock",
    }
    assert guarded["GCNServingEngine"] == {"_persist_thread": "_persist_spawn_lock"}
    assert locks.lock_declaration_order(mod) == [
        "_swap_lock",
        "_persist_spawn_lock",
    ]
