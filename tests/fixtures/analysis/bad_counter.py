# expect: counter-settlement
# An ad-hoc counter bump outside a settlement helper or finally block.
class Engine:
    def __init__(self):
        self.counters = {"served": 0}

    def serve(self):
        self.counters["served"] += 1
