# expect: none
# Counters may move inside an annotated settlement helper or a finally.
class Engine:
    def __init__(self):
        self.counters = {"served": 0, "failed": 0}

    # counter-settlement: served
    def _settle(self, n=1):
        self.counters["served"] += n

    def serve_risky(self):
        try:
            return 1
        finally:
            self.counters["failed"] += 1
