# expect: none
# Nested acquisition in declaration order is legal.
import threading


class Engine:
    def __init__(self):
        self._first = threading.Lock()
        self._second = threading.Lock()

    def ordered(self):
        with self._first:
            with self._second:
                return 1
