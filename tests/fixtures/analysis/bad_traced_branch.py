# expect: jax-traced-branch
# Python control flow on a traced argument raises at trace time.
import jax


@jax.jit
def entry(x, flag):
    if flag:
        return x
    return -x
