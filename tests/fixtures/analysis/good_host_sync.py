# expect: none
# float()/len() on static metadata is host data, not a tracer sync.
import jax


@jax.jit
def entry(x):
    scale = float(len(x.shape))
    return x * scale
