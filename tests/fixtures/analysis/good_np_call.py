# expect: none
# numpy in host-side code a jit site never reaches stays legal.
import jax
import numpy as np


def host_prep(x):
    return np.asarray(x, dtype=np.float32)


@jax.jit
def entry(x):
    return x * 2.0
