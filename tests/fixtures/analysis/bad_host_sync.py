# expect: jax-host-sync
# .item() on a value derived from a traced argument forces a
# device-to-host sync (taint must propagate through the assignment).
import jax


@jax.jit
def entry(x):
    y = x + 1
    return y.item()
