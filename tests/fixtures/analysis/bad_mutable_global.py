# expect: jax-mutable-global
# Reading a module-level mutable container inside a jit body bakes its
# trace-time contents into the compiled function.
import jax

_CACHE = {"scale": 2.0}


@jax.jit
def entry(x):
    return x * _CACHE["scale"]
