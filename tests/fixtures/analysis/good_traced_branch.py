# expect: none
# Branching on static_argnames-bound params and on static metadata
# (.ndim) is legal — the values are Python data at trace time.
import functools

import jax


@functools.partial(jax.jit, static_argnames=("causal",))
def entry(x, causal):
    if causal:
        return x
    if x.ndim == 2:
        return -x
    return x
