# expect: lock-order
# Acquiring an earlier-declared lock while holding a later one inverts
# the canonical (declaration) order — the deadlock recipe.
import threading


class Engine:
    def __init__(self):
        self._first = threading.Lock()
        self._second = threading.Lock()

    def inverted(self):
        with self._second:
            with self._first:
                return 1
