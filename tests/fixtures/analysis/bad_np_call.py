# expect: jax-np-call
# A numpy call inside a body reached *transitively* from a jit site:
# the call-graph walk must pull `helper` into the checked set.
import jax
import numpy as np


@jax.jit
def entry(x):
    return helper(x)


def helper(x):
    return np.tanh(x)
