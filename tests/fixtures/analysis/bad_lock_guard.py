# expect: lock-guard
# A guarded-by:-annotated field touched outside its lock.
import threading


class Engine:
    def __init__(self):
        self._lock = threading.Lock()
        self._state = 0  # guarded-by: _lock

    def bump(self):
        self._state += 1
