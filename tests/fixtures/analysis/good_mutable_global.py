# expect: none
# Immutable module constants are fine to close over.
import jax

SCALE = 2.0


@jax.jit
def entry(x):
    return x * SCALE
