# expect: none
# The same field, accessed under its lock — including through a typed
# container lookup on another instance.
import threading


class Record:
    value: int = 0  # guarded-by: _lock


class Engine:
    def __init__(self):
        self._lock = threading.Lock()
        self._state = 0  # guarded-by: _lock
        self._records: "dict[str, Record]" = {}

    def bump(self, key):
        with self._lock:
            self._state += 1
            rec = self._records.get(key)
            if rec is not None:
                rec.value += 1
