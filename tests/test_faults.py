"""Failure-injected dispatch recovery: the ``core.executor.FAULTS`` seam
drives device upload, batch dispatch, and per-replica chunk failures
through the serving engine's recovery paths — bounded exponential-backoff
dispatch retries, sibling-replica chunk retries (bit-identical logits),
typed ``RequestFailure``/``FlushError`` outcomes, and the invariant that
a failure never corrupts served-work counters or leaks outstanding-work
charges. Multi-replica recovery runs on an 8-way forced host-platform
mesh in a subprocess under the ``distributed`` marker."""
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core import executor as exe, gcn  # noqa: E402
from repro.core.executor import FAULTS, InjectedFault  # noqa: E402
from repro.graphs import synth  # noqa: E402
from repro.serving.gcn_engine import (FlushError,  # noqa: E402
                                      GCNServingEngine, RequestFailure)
from repro.tuning import registry  # noqa: E402

SRC = str(Path(__file__).resolve().parents[1] / "src")

N_NODES = 220
N_FEATS = 20
N_CLASSES = 5

FAST_SWEEP = [
    dict(nnz_per_step=64, rows_per_window=32, cols_per_block=None,
         window_nnz=None, routing=exe.GATHER),
    dict(nnz_per_step=128, rows_per_window=64, cols_per_block=None,
         window_nnz=None, routing=exe.GATHER),
]
FAST_KW = dict(iters=1, warmup=1, sweep=FAST_SWEEP, bf16_report=False)


@pytest.fixture(autouse=True)
def _fresh_state():
    registry.clear_caches()
    FAULTS.clear()
    yield
    FAULTS.clear()
    registry.clear_caches()


def _workload(seed):
    a = synth.power_law_adjacency(N_NODES, 0.03, 0.9, seed=seed)
    cfg = gcn.GCNConfig(N_FEATS, 16, N_CLASSES)
    params = gcn.init_params(cfg, jax.random.PRNGKey(seed))
    x = np.random.default_rng(seed).random((N_NODES, N_FEATS),
                                           ).astype(np.float32)
    return a, params, x


def _engine(root, **kw):
    kw.setdefault("autotune_kwargs", FAST_KW)
    return GCNServingEngine(store_root=root, **kw)


def _outstanding_settled(eng):
    assert all(v <= 1e-9 for v in eng._dev_outstanding.values()), \
        eng._dev_outstanding


def test_transient_dispatch_fault_retries_and_recovers(tmp_path,
                                                       monkeypatch):
    import repro.serving.gcn_engine as ge

    a, params, x = _workload(0)
    eng = _engine(tmp_path)
    eng.add_graph("g", a, params)
    ref = np.asarray(eng.serve_batch("g", [x]))
    delays = []
    monkeypatch.setattr(ge, "_sleep", delays.append)
    FAULTS.arm("dispatch", times=1, graph="g")
    out = np.asarray(eng.serve_batch("g", [x]))
    np.testing.assert_array_equal(out, ref)   # retry is unobservable
    assert delays == [eng.retry_backoff_s]
    assert eng.counters["dispatch_retries"] == 1
    assert FAULTS.fired == [("dispatch", "g", None)]
    _outstanding_settled(eng)


def test_persistent_dispatch_fault_bounded_backoff_then_raises(
        tmp_path, monkeypatch):
    import repro.serving.gcn_engine as ge

    a, params, x = _workload(1)
    eng = _engine(tmp_path, max_dispatch_retries=2, retry_backoff_s=0.01)
    eng.add_graph("g", a, params)
    eng.serve_batch("g", [x])                 # warm; prime EWMAs
    before = dict(eng.counters)
    delays = []
    monkeypatch.setattr(ge, "_sleep", delays.append)
    FAULTS.arm("dispatch", times=99, graph="g")
    with pytest.raises(InjectedFault):
        eng.serve_batch("g", [x])
    assert delays == [0.01, 0.02]             # exponential, then give up
    assert len(FAULTS.fired) == 3             # initial try + 2 retries
    assert eng.counters["dispatch_retries"] == before["dispatch_retries"] + 2
    assert eng.counters["batches"] == before["batches"]
    assert eng.counters["requests"] == before["requests"]
    _outstanding_settled(eng)
    FAULTS.clear()
    np.testing.assert_array_equal(              # engine fully recovers
        np.asarray(eng.serve_batch("g", [x])),
        np.asarray(eng.serve_batch("g", [x])))


def test_validation_errors_never_burn_retries(tmp_path, monkeypatch):
    import repro.serving.gcn_engine as ge

    a, params, x = _workload(2)
    eng = _engine(tmp_path)
    eng.add_graph("g", a, params)
    monkeypatch.setattr(ge, "_sleep",
                        lambda s: pytest.fail("backoff on a caller bug"))
    with pytest.raises(ValueError, match="nodes"):
        eng.serve_batch("g", [x[:-1]])
    assert eng.counters["dispatch_retries"] == 0


def test_queue_dispatch_fault_flusherror_restores_then_recovers(
        tmp_path, monkeypatch):
    import repro.serving.gcn_engine as ge

    a, params, x = _workload(3)
    eng = _engine(tmp_path)
    eng.add_graph("g", a, params)
    ref = np.asarray(eng.serve_batch("g", [x, x * 0.5]))
    eng.submit("g", x)
    eng.submit("g", x * 0.5)
    monkeypatch.setattr(ge, "_sleep", lambda s: None)
    FAULTS.arm("dispatch", times=99, graph="g")
    with pytest.raises(FlushError) as ei:
        eng.flush()
    assert set(ei.value.failures) == {"g"}
    assert len(eng._pending["g"]) == 2        # both requests survived
    st = eng.stats()
    assert st["submitted"] == st["queue_served"] + st["shed"] \
        + st["rejected"] + st["pending_requests"]
    _outstanding_settled(eng)
    FAULTS.clear()
    out = eng.flush()
    np.testing.assert_array_equal(np.asarray(out["g"]), ref)
    st = eng.stats()
    assert st["queue_served"] == 2 and st["pending_requests"] == 0


def test_upload_fault_on_readmission_recovers_via_retry(tmp_path,
                                                        monkeypatch):
    """An evicted graph's re-admission re-uploads its schedule; a
    transient upload failure mid re-admission is absorbed by the dispatch
    retry (nothing was charged or accounted by the failed attempt)."""
    import repro.serving.gcn_engine as ge

    g0, g1 = _workload(4), _workload(5)
    eng = _engine(tmp_path)
    eng.add_graph("g0", g0[0], g0[1])
    eng.add_graph("g1", g1[0], g1[1])
    per = max(r.bytes for r in eng._graphs.values())
    ref0 = np.asarray(eng.infer("g0", g0[2]))

    registry.clear_caches()
    eng2 = _engine(tmp_path, device_budget_bytes=int(per * 1.2))
    eng2.add_graph("g0", g0[0], g0[1])
    eng2.add_graph("g1", g1[0], g1[1])
    assert "g0" not in eng2.resident_graphs   # evicted by g1's admission
    monkeypatch.setattr(ge, "_sleep", lambda s: None)
    FAULTS.arm("upload", times=1)
    out = np.asarray(eng2.infer("g0", g0[2]))
    np.testing.assert_allclose(out, ref0, atol=1e-5)
    assert eng2.counters["dispatch_retries"] == 1
    assert eng2.counters["readmissions"] >= 1
    assert FAULTS.fired and FAULTS.fired[0][0] == "upload"
    _outstanding_settled(eng2)


def test_await_failure_rolls_back_per_chunk_and_surfaces_per_request(
        tmp_path, monkeypatch):
    """Satellite pin: an error in ``_await_batch`` settles the failed
    chunk's outstanding-work charge and restores exactly the failed
    requests — no leaked meter, no inflated counters, queue order kept."""
    import repro.serving.gcn_engine as ge

    a, params, x = _workload(6)
    eng = _engine(tmp_path)
    eng.add_graph("g", a, params)
    eng.serve_batch("g", [x])                 # prime EWMAs: est > 0
    assert eng._svc_req_ewma["g"] > 0
    r1 = eng.submit("g", x)
    r2 = eng.submit("g", x * 0.5)
    before = dict(eng.counters)
    monkeypatch.setattr(ge, "_block_until_ready",
                        lambda out: (_ for _ in ()).throw(
                            RuntimeError("async device fault")))
    with pytest.raises(FlushError):
        eng.flush()
    _outstanding_settled(eng)                 # the rollback under test
    restored = eng._pending["g"]
    assert [r.rid for r in restored] == [r1.rid, r2.rid]
    assert eng.counters["request_failures"] \
        == before["request_failures"] + 2
    assert eng.counters["batches"] == before["batches"]
    assert eng.counters["queue_served"] == before["queue_served"]
    monkeypatch.undo()
    out = eng.flush()
    assert out["g"].shape == (2, N_NODES, N_CLASSES)
    _outstanding_settled(eng)


def test_direct_path_raises_typed_request_failure(tmp_path, monkeypatch):
    import repro.serving.gcn_engine as ge

    a, params, x = _workload(7)
    eng = _engine(tmp_path)
    eng.add_graph("g", a, params)
    eng.serve_batch("g", [x])
    before = dict(eng.counters)
    cause = RuntimeError("async device fault")
    monkeypatch.setattr(ge, "_block_until_ready",
                        lambda out: (_ for _ in ()).throw(cause))
    with pytest.raises(RequestFailure) as ei:
        eng.serve_batch("g", [x, x * 0.5])
    e = ei.value
    assert isinstance(e, RuntimeError)        # backward compatible
    assert e.graph_id == "g" and e.n_failed == 2
    assert e.cause is cause and e.partial is None
    assert eng.counters["request_failures"] \
        == before["request_failures"] + 2
    assert eng.counters["batches"] == before["batches"]
    assert eng.counters["requests"] == before["requests"]
    _outstanding_settled(eng)


# ---------------------------------------------------------------------------
# Multi-replica fault recovery (8 forced host devices, subprocess)
# ---------------------------------------------------------------------------

SCRIPT_REPLICA_FAULTS = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, tempfile
sys.path.insert(0, %r)
import numpy as np, jax, jax.numpy as jnp
from repro.core import executor as exe, gcn
from repro.core.executor import FAULTS
from repro.graphs import synth
from repro.serving.gcn_engine import (FlushError, GCNServingEngine,
                                      RequestFailure)
from repro.serving.placement import REPLICATED
assert len(jax.devices()) == 8

SWEEP = [dict(nnz_per_step=64, rows_per_window=32, cols_per_block=None,
              window_nnz=None, routing=exe.GATHER)]
KW = dict(iters=1, warmup=1, sweep=SWEEP, bf16_report=False)

def identity(eng):
    st = eng.stats()
    assert st["submitted"] == (st["queue_served"] + st["shed"]
                               + st["rejected"] + st["pending_requests"]), st

n = 300
a = synth.power_law_adjacency(n, 0.03, 0.9, seed=5)
cfg = gcn.GCNConfig(16, 16, 4)
params = gcn.init_params(cfg, jax.random.PRNGKey(5))
x = np.random.default_rng(5).random((n, 16)).astype(np.float32)
reqs = [x * (1.0 - 0.02 * i) for i in range(12)]
root = tempfile.mkdtemp(prefix="awb-faults-")

eng = GCNServingEngine(store_root=root, devices=8, max_replicas=3,
                       replicate_after_s=1e-6,
                       replica_shrink_after=10**6, autotune_kwargs=KW)
eng.add_graph("hot", a, params)
ref = np.asarray(eng.serve_batch("hot", reqs))
for _ in range(3):                        # saturation grows the replicas
    for r in reqs:
        eng.submit("hot", r, deadline_s=0.0)
    eng.poll()
pl = eng.placer.placement_of("hot")
assert pl.kind == REPLICATED and len(pl.device_indices) == 3, pl

# --- one replica's chunk fails -> sibling retry, bit-identical logits ----
victim = sorted(eng._graphs["hot"].replicas)[0]
FAULTS.arm("replica_chunk", graph="hot", device=victim, times=1)
out = np.asarray(eng.serve_batch("hot", reqs))
assert np.array_equal(out, ref), "sibling retry changed the logits"
assert not FAULTS._armed                  # the fault fired
assert FAULTS.fired == [("replica_chunk", "hot", victim)]
assert eng.counters["chunk_retries"] >= 1
assert all(v <= 1e-9 for v in eng._dev_outstanding.values()), \
    eng._dev_outstanding
print("SIBLING OK")

# --- every clone poisoned: queue path fails typed, restores, recovers ----
FAULTS.clear()
for r in reqs:
    eng.submit("hot", r, deadline_s=0.0)
FAULTS.arm("replica_chunk", graph="hot", times=999)
try:
    eng.poll()
    raise SystemExit("expected FlushError")
except FlushError as e:
    assert set(e.failures) == {"hot"}
assert len(eng._pending["hot"]) == 12     # every request restored
assert all(v <= 1e-9 for v in eng._dev_outstanding.values())
identity(eng)
FAULTS.clear()
out = np.asarray(eng.poll()["hot"])
assert np.array_equal(out, ref)           # recovery is bit-identical
identity(eng)
print("POISON OK")

# --- direct path: typed RequestFailure, nothing counted served ----------
FAULTS.arm("replica_chunk", graph="hot", times=999)
before = dict(eng.counters)
try:
    eng.serve_batch("hot", reqs)
    raise SystemExit("expected RequestFailure")
except RequestFailure as e:
    assert e.n_failed == 12 and e.partial is None
assert eng.counters["batches"] == before["batches"]
assert eng.counters["requests"] == before["requests"]
FAULTS.clear()
print("TYPED OK")

# --- partial failure surfaces per-request, not per-batch -----------------
SENT = np.float32(12345.0)
bad = reqs[0].copy()
bad[0, 0] = SENT
orig_run = eng._run_unit
def poisoned(unit, gid, chunk):
    if np.any(np.asarray(chunk)[:, 0, 0] == SENT):
        raise RuntimeError("poisoned chunk")
    return orig_run(unit, gid, chunk)
eng._run_unit = poisoned                  # sentinel chunk fails anywhere
for r in [bad] + reqs[1:]:
    eng.submit("hot", r, deadline_s=0.0)
try:
    eng.poll()
    raise SystemExit("expected FlushError")
except FlushError as e:
    part = np.asarray(e.partial["hot"])
restored = eng._pending["hot"]
assert len(restored) == 4                 # exactly the poisoned chunk
assert float(np.asarray(restored[0].x)[0, 0]) == float(SENT)
assert np.array_equal(part, ref[4:])      # the other chunks delivered
assert all(v <= 1e-9 for v in eng._dev_outstanding.values())
identity(eng)
del eng._run_unit
out = np.asarray(eng.flush()["hot"])      # restored requests drain clean
assert out.shape == (4, n, 4)
identity(eng)
print("PARTIAL OK")
""" % (SRC,)


@pytest.mark.distributed
def test_replica_fault_recovery_acceptance():
    r = subprocess.run([sys.executable, "-c", SCRIPT_REPLICA_FAULTS],
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, \
        f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    for tag in ("SIBLING OK", "POISON OK", "TYPED OK", "PARTIAL OK"):
        assert tag in r.stdout
