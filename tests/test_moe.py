"""MoE: AWB placement properties + dispatch-layer invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import moe_balance
from repro.models import moe as moe_mod


# ---- placement balancer ------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.integers(8, 64), st.integers(2, 8), st.integers(0, 3),
       st.integers(0, 2**16))
def test_placement_properties(e, d, spare_per_dev, seed):
    load = moe_balance.zipf_expert_load(e, 10000, alpha=1.0, seed=seed)
    spd = -(-e // d) + spare_per_dev
    p = moe_balance.balance_placement(load, d, slots_per_device=spd)
    # every expert has >= 1 replica and replica counts match slot counts
    assert (p.replica_count >= 1).all()
    placed = p.slots[p.slots >= 0]
    counts = np.bincount(placed, minlength=e)
    np.testing.assert_array_equal(counts, p.replica_count)
    # no device exceeds its slots
    assert p.slots.shape == (d, spd)


def test_replication_fixes_evil_expert():
    load = np.ones(16)
    load[3] = 100.0  # evil expert
    static = moe_balance.imbalance(moe_balance.device_loads(
        moe_balance.static_placement(16, 4), load))
    bal = moe_balance.balance_placement(load, 4, slots_per_device=8)
    awb = moe_balance.imbalance(moe_balance.device_loads(bal, load))
    assert bal.replica_count[3] > 1
    assert awb < static / 2


def test_dispatch_plan_round_robins():
    load = np.array([100.0, 1, 1, 1])
    p = moe_balance.balance_placement(load, 2, slots_per_device=3)
    assign = np.zeros(10, np.int64)  # 10 tokens to the hot expert
    dev, slot = moe_balance.dispatch_plan(assign, p)
    r = int(p.replica_count[0])
    assert r > 1
    assert len(set(map(tuple, zip(dev, slot)))) == r  # spread over replicas


# ---- the MoE layer ----------------------------------------------------------

def _dims(**kw):
    d = dict(d_model=16, d_ff=8, n_experts=4, top_k=2,
             capacity_factor=64.0, activation="silu", glu=True, n_slots=0)
    d.update(kw)
    return moe_mod.MoEDims(**d)


def _dense_moe_reference(p, dims, x):
    """Route every token to its top-k experts densely (no capacity)."""
    b, s, d = x.shape
    xt = x.reshape(-1, d)
    logits = (xt @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    w, ids = jax.lax.top_k(probs, dims.top_k)
    w = w / w.sum(-1, keepdims=True)
    outs = []
    for e in range(dims.n_experts):
        h = xt @ p["w_in"][e]
        h = jax.nn.silu(xt @ p["w_gate"][e]) * h
        outs.append(h @ p["w_out"][e])
    dense = jnp.stack(outs, 1)  # [T, E, d]
    sel = jnp.take_along_axis(dense, ids[..., None], axis=1)
    out = (sel * w[..., None]).sum(1)
    return out.reshape(b, s, d)


def test_moe_matches_dense_reference():
    dims = _dims()
    p = moe_mod.init_moe_params(jax.random.PRNGKey(0), dims)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, dims.d_model))
    out, aux = moe_mod.moe_forward(p, dims, x)
    ref = _dense_moe_reference(p, dims, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
    assert float(aux) > 0


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_moe_output_invariant_to_placement(seed):
    """Replicas compute identical experts — any AWB placement must produce
    the same output when dropless (the evil-expert adder tree is exact)."""
    dims = _dims(n_slots=6)
    p = moe_mod.init_moe_params(jax.random.PRNGKey(0), dims)
    x = jax.random.normal(jax.random.PRNGKey(seed + 10), (2, 10, 16))
    base, _ = moe_mod.moe_forward(p, dims, x)
    load = moe_balance.zipf_expert_load(4, 1000, alpha=1.0, seed=seed)
    placement = moe_balance.balance_placement(load, 2, slots_per_device=3)
    tables = moe_mod.tables_from_placement(placement)
    got, _ = moe_mod.moe_forward(p, dims, x, placement=tables)
    np.testing.assert_allclose(np.asarray(got), np.asarray(base), atol=1e-5)


def test_capacity_drops_passthrough():
    """Tokens over capacity contribute nothing (residual passthrough)."""
    dims = _dims(capacity_factor=0.01)  # cap = 1 slot per expert
    p = moe_mod.init_moe_params(jax.random.PRNGKey(0), dims)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 16, 16))
    out, _ = moe_mod.moe_forward(p, dims, x)
    full, _ = moe_mod.moe_forward(p, dims, x, capacity_override=64)
    # dropped ⇒ strictly smaller contribution norm
    assert float(jnp.abs(out).sum()) < float(jnp.abs(full).sum())
