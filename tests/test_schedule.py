"""Schedule builder properties: correctness vs SpMM reference, work
conservation, utilization, evil-row handling.

Property-based (hypothesis) module: skipped wholesale when hypothesis is
absent. The non-property equivalence/correctness tests for the vectorized
builder live in ``test_schedule_equiv.py`` and always run."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import schedule, spmm
from repro.graphs import synth


@st.composite
def powerlaw_case(draw):
    n = draw(st.integers(24, 120))
    alpha = draw(st.sampled_from([0.6, 0.9, 1.2]))
    density = draw(st.sampled_from([0.01, 0.05, 0.12]))
    seed = draw(st.integers(0, 2**16))
    k = draw(st.sampled_from([8, 16, 33]))
    r = draw(st.sampled_from([4, 16]))
    return n, density, alpha, seed, k, r


@settings(max_examples=25, deadline=None)
@given(powerlaw_case(), st.booleans())
def test_schedule_matches_reference(case, balanced):
    n, density, alpha, seed, k, r = case
    a = synth.power_law_adjacency(n, density, alpha, seed=seed)
    build = (schedule.build_balanced_schedule if balanced
             else schedule.build_naive_schedule)
    s = build(a, nnz_per_step=k, rows_per_window=r)
    rng = np.random.default_rng(seed + 1)
    b = jnp.asarray(rng.standard_normal((n, 7)).astype(np.float32))
    ref = np.asarray(spmm.spmm_coo(a, b))
    got = np.asarray(schedule.execute_schedule_jnp(s, b))
    np.testing.assert_allclose(got, ref, atol=1e-4)
    # work conservation: every true non-zero occupies exactly one slot
    assert int((np.asarray(s.val) != 0).sum()) <= s.nnz
    assert s.nnz == a.nnz
    assert 0 < s.utilization <= 1.0


@settings(max_examples=15, deadline=None)
@given(powerlaw_case())
def test_balanced_vs_naive_on_powerlaw(case):
    """On imbalanced inputs AWB wins; on already-balanced tiny inputs it may
    pay bounded window-boundary fragmentation (the paper observes the same:
    Reddit's baseline is already ~90% utilized)."""
    n, density, alpha, seed, k, r = case
    a = synth.power_law_adjacency(n, density, alpha, seed=seed)
    bal = schedule.build_balanced_schedule(a, k, r)
    nv = schedule.build_naive_schedule(a, k, r)
    assert bal.n_steps <= int(nv.n_steps * 1.5) + 2  # bounded downside
    # strict win whenever the per-window imbalance is meaningful
    from repro.core import profiler
    if profiler.profile_matrix(a).gini > 0.5:
        assert bal.n_steps <= nv.n_steps


# (example-based schedule tests — evil rows, blocked mode, device ranges,
# blocked spmm, op orders — moved to test_schedule_equiv.py so they run
# even without hypothesis)
