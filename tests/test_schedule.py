"""Schedule builder properties: correctness vs SpMM reference, work
conservation, utilization, evil-row handling."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import csc as fmt, schedule, spmm
from repro.graphs import synth


@st.composite
def powerlaw_case(draw):
    n = draw(st.integers(24, 120))
    alpha = draw(st.sampled_from([0.6, 0.9, 1.2]))
    density = draw(st.sampled_from([0.01, 0.05, 0.12]))
    seed = draw(st.integers(0, 2**16))
    k = draw(st.sampled_from([8, 16, 33]))
    r = draw(st.sampled_from([4, 16]))
    return n, density, alpha, seed, k, r


@settings(max_examples=25, deadline=None)
@given(powerlaw_case(), st.booleans())
def test_schedule_matches_reference(case, balanced):
    n, density, alpha, seed, k, r = case
    a = synth.power_law_adjacency(n, density, alpha, seed=seed)
    build = (schedule.build_balanced_schedule if balanced
             else schedule.build_naive_schedule)
    s = build(a, nnz_per_step=k, rows_per_window=r)
    rng = np.random.default_rng(seed + 1)
    b = jnp.asarray(rng.standard_normal((n, 7)).astype(np.float32))
    ref = np.asarray(spmm.spmm_coo(a, b))
    got = np.asarray(schedule.execute_schedule_jnp(s, b))
    np.testing.assert_allclose(got, ref, atol=1e-4)
    # work conservation: every true non-zero occupies exactly one slot
    assert int((np.asarray(s.val) != 0).sum()) <= s.nnz
    assert s.nnz == a.nnz
    assert 0 < s.utilization <= 1.0


@settings(max_examples=15, deadline=None)
@given(powerlaw_case())
def test_balanced_vs_naive_on_powerlaw(case):
    """On imbalanced inputs AWB wins; on already-balanced tiny inputs it may
    pay bounded window-boundary fragmentation (the paper observes the same:
    Reddit's baseline is already ~90% utilized)."""
    n, density, alpha, seed, k, r = case
    a = synth.power_law_adjacency(n, density, alpha, seed=seed)
    bal = schedule.build_balanced_schedule(a, k, r)
    nv = schedule.build_naive_schedule(a, k, r)
    assert bal.n_steps <= int(nv.n_steps * 1.5) + 2  # bounded downside
    # strict win whenever the per-window imbalance is meaningful
    from repro.core import profiler
    if profiler.profile_matrix(a).gini > 0.5:
        assert bal.n_steps <= nv.n_steps


def test_evil_rows_split_and_merge():
    # one row holds half the matrix: must chunk + merge exactly
    n = 64
    rng = np.random.default_rng(0)
    dense = np.zeros((n, n), np.float32)
    dense[5, :] = rng.standard_normal(n)  # evil row
    dense[rng.integers(0, n, 40), rng.integers(0, n, 40)] = 1.0
    a = fmt.coo_from_dense(dense)
    s = schedule.build_balanced_schedule(a, nnz_per_step=8,
                                         rows_per_window=8)
    assert s.n_evil_chunks >= n // 8
    b = jnp.asarray(rng.standard_normal((n, 5)).astype(np.float32))
    got = np.asarray(schedule.execute_schedule_jnp(s, b))
    np.testing.assert_allclose(got, dense @ np.asarray(b), atol=1e-4)


def test_blocked_mode_correct():
    a = synth.power_law_adjacency(100, 0.05, 0.9, seed=3)
    s = schedule.build_balanced_schedule(a, 16, 8, cols_per_block=32)
    rng = np.random.default_rng(3)
    b = jnp.asarray(rng.standard_normal((100, 6)).astype(np.float32))
    ref = np.asarray(spmm.spmm_coo(a, b))
    np.testing.assert_allclose(
        np.asarray(schedule.execute_schedule_jnp(s, b)), ref, atol=1e-4)


def test_device_ranges_balanced():
    a = synth.power_law_adjacency(500, 0.02, 1.0, seed=1)
    s = schedule.build_balanced_schedule(a, 32, 16)
    ranges = s.device_step_ranges(8)
    sizes = ranges[:, 1] - ranges[:, 0]
    assert sizes.max() - sizes.min() <= 1
    assert ranges[0, 0] == 0 and ranges[-1, 1] == s.n_steps


def test_spmm_blocked_matches():
    a = synth.power_law_adjacency(80, 0.06, 0.8, seed=2)
    rng = np.random.default_rng(2)
    b = jnp.asarray(rng.standard_normal((80, 10)).astype(np.float32))
    ref = np.asarray(spmm.spmm_coo(a, b))
    got = np.asarray(spmm.spmm_coo_blocked(a, b, t=3))
    np.testing.assert_allclose(got, ref, atol=1e-4)


@pytest.mark.parametrize("order", ["o1", "o2"])
def test_flops_orders_positive(order):
    o1, o2 = spmm.flops_axw_orders(1000, (100, 50), (50, 8), 0.1)
    assert o1 > 0 and o2 > 0 and o1 > o2  # AxXW order always cheaper here
