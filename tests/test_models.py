"""Per-arch smoke tests (assignment: reduced config of the same family,
one forward/train step on CPU, output shapes + no NaNs) and decode
consistency across families."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import transformer as tr

ARCHS = configs.list_archs()


def _batch(cfg, key, b=2, s=12):
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab)}
    if cfg.encoder is not None:
        batch["source_embed"] = jax.random.normal(
            key, (b, cfg.encoder.max_source, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch):
    cfg = configs.get_reduced_config(arch)
    key = jax.random.PRNGKey(0)
    params = tr.init_params(cfg, key)
    batch = _batch(cfg, key)
    logits, aux = tr.model_forward(cfg, params, batch,
                                   compute_dtype=jnp.float32)
    assert logits.shape == (2, 12, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    """One grad step decreases nothing catastrophically: finite grads."""
    cfg = configs.get_reduced_config(arch)
    key = jax.random.PRNGKey(1)
    params = tr.init_params(cfg, key)
    batch = _batch(cfg, key)
    labels = jax.random.randint(key, (2, 12), 0, cfg.vocab)

    def loss_fn(p):
        logits, aux = tr.model_forward(cfg, p, batch,
                                       compute_dtype=jnp.float32)
        logp = jax.nn.log_softmax(logits, -1)
        nll = -jnp.take_along_axis(logp, labels[..., None], -1).mean()
        return nll + 0.01 * aux

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    gnorm = sum(float(jnp.abs(g).sum()) for g in jax.tree.leaves(grads))
    assert jnp.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "rwkv6-3b",
                                  "recurrentgemma-2b", "whisper-tiny",
                                  "qwen3-moe-30b-a3b"])
def test_decode_matches_forward(arch):
    cfg = configs.get_reduced_config(arch)
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    key = jax.random.PRNGKey(2)
    params = tr.init_params(cfg, key)
    b, s = 2, 12
    batch = _batch(cfg, key, b, s)
    toks = batch["tokens"]
    logits, _ = tr.model_forward(cfg, params, batch,
                                 compute_dtype=jnp.float32)
    pre = s - 3
    pb = dict(batch, tokens=toks[:, :pre])
    last, cache = tr.prefill(cfg, params, pb, max_seq=s,
                             compute_dtype=jnp.float32)
    errs = [float(jnp.abs(last[:, 0] - logits[:, pre - 1]).max())]
    for t in range(pre, s):
        step_logits, cache = tr.decode_step(cfg, params, cache, toks[:, t],
                                            jnp.int32(t),
                                            compute_dtype=jnp.float32)
        errs.append(float(jnp.abs(step_logits[:, 0] - logits[:, t]).max()))
    assert max(errs) < 2e-3, f"{arch}: {errs}"


def test_local_attention_ring_cache():
    """Windowed decode with a ring cache equals full-cache reference."""
    cfg = configs.get_reduced_config("recurrentgemma-2b")
    assert cfg.window is not None and cfg.window < 16
    key = jax.random.PRNGKey(3)
    params = tr.init_params(cfg, key)
    b, s = 1, 14  # > window so the ring wraps
    batch = _batch(cfg, key, b, s)
    toks = batch["tokens"]
    logits, _ = tr.model_forward(cfg, params, batch,
                                 compute_dtype=jnp.float32)
    _, cache = tr.prefill(cfg, params, dict(batch, tokens=toks[:, :4]),
                          max_seq=s, compute_dtype=jnp.float32)
    errs = []
    for t in range(4, s):
        step_logits, cache = tr.decode_step(cfg, params, cache, toks[:, t],
                                            jnp.int32(t),
                                            compute_dtype=jnp.float32)
        errs.append(float(jnp.abs(step_logits[:, 0] - logits[:, t]).max()))
    assert max(errs) < 2e-3


def test_param_counts_match_published():
    expect = {"qwen2-72b": 72.7e9, "deepseek-coder-33b": 33.3e9,
              "qwen2-0.5b": 0.49e9, "rwkv6-3b": 3.1e9,
              "qwen3-moe-30b-a3b": 30.5e9, "pixtral-12b": 12.2e9}
    for arch, n in expect.items():
        got = tr.count_params(configs.get_config(arch))
        assert abs(got - n) / n < 0.06, f"{arch}: {got / 1e9:.2f}B"


def test_sub_quadratic_flags():
    assert configs.get_config("rwkv6-3b").sub_quadratic
    assert configs.get_config("recurrentgemma-2b").sub_quadratic
    assert not configs.get_config("qwen2-72b").sub_quadratic
    ok, _ = configs.cell_supported(configs.get_config("qwen2-72b"),
                                   "long_500k")
    assert not ok
