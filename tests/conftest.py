import os
import sys

# keep jax on a single CPU device for unit tests (the dry-run sets its own
# device-count flag in a separate process); also keep threads bounded
os.environ.setdefault("XLA_FLAGS", "--xla_cpu_multi_thread_eigen=false")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture
def rng():
    return np.random.default_rng(0)
