"""Trace equivalence: ``HeuristicPolicy`` reproduces the pre-refactor
scheduling decisions decision-for-decision.

The oracle functions below are line-for-line transcriptions of the
engine code *before* the policy seam existed (``placer.place`` worst-fit
+ sharded routing, ``_update_replication`` with calm-poll hysteresis,
``_predicted_wait``'s EDF load-map absorption, ``poll``'s dueness cut,
``_serve_queues``'s EDF dispatch order and dispatch-time shed). The
suite replays them against ``HeuristicPolicy`` on hand-recorded
scenarios — single, replicated, and sharded-giant routes — and on a
randomized fuzz sweep, asserting bit-identical decisions everywhere.

Pure host-side: ``serving.policy`` imports no jax, so the whole suite
runs without devices (engine-level equivalence rides on the pre-existing
clock-injected suites in test_overload/test_placement/test_streaming,
which pin the same behaviors through the real engine)."""
import numpy as np
import pytest

from repro.serving.placement import REPLICATED, SHARDED, SINGLE
from repro.serving.policy import (
    GROW,
    HOLD,
    SHRINK,
    SVC_FLOOR_S,
    SVC_SAFETY,
    DispatchOrder,
    GraphState,
    HeuristicPolicy,
    PolicyState,
    ReplicaDecision,
    absorb_load,
)


# ---------------------------------------------------------------------------
# state builders
# ---------------------------------------------------------------------------

def G(gid, *, kind=SINGLE, dev=0, devs=None, depth=0, ed=float("inf"),
      ewma=0.0, req_ewma=0.0, calm=0, nbytes=1 << 20, resident=True,
      nnz=1000, rows=100):
    if devs is None:
        devs = (dev,) if kind == SINGLE else ()
    return GraphState(
        graph_id=gid, nnz=nnz, n_rows=rows, bytes=nbytes, resident=resident,
        kind=kind, device_index=None if kind == SHARDED else dev,
        device_indices=tuple(devs), queue_depth=depth, earliest_deadline=ed,
        svc_ewma=ewma, svc_req_ewma=req_ewma, calm_polls=calm)


def S(graphs, *, now=1000.0, n_devices=2, budget=64 << 20, used=None,
      max_replicas=None, replicate_after_s=0.25, shrink_after=3,
      max_batch=32):
    used = tuple(used or [0] * n_devices)
    return PolicyState(
        now=now, n_devices=n_devices, budget_bytes=budget, used_bytes=used,
        outstanding_s=tuple(0.0 for _ in range(n_devices)),
        max_replicas=n_devices if max_replicas is None else max_replicas,
        replicate_after_s=replicate_after_s,
        replica_shrink_after=shrink_after, max_batch=max_batch,
        graphs={g.graph_id: g for g in graphs})


# ---------------------------------------------------------------------------
# oracles: the pre-refactor engine code, transcribed verbatim onto the
# snapshot (placer.place / _update_replication / _predicted_wait / poll /
# _serve_queues, at commit d36f8ad)
# ---------------------------------------------------------------------------

def oracle_place(state, nbytes):
    if nbytes > state.budget_bytes and state.n_devices > 1:
        return (SHARDED, None)
    d = max(range(state.n_devices),
            key=lambda i: (state.budget_bytes - state.used_bytes[i], -i))
    return (SINGLE, d)


def oracle_replica_candidate(state, g):
    # placer.replica_candidate(gid, rec.bytes)
    if g.kind == SHARDED or not g.resident:
        return None
    free = [d for d in range(state.n_devices)
            if d not in g.device_indices
            and state.budget_bytes - state.used_bytes[d] >= g.bytes]
    if not free:
        return None
    return max(free, key=lambda d: (state.budget_bytes - state.used_bytes[d],
                                    -d))


def oracle_replication(state, gid):
    """The old ``_update_replication`` loop body, expressed as the
    (action, device, new_calm) triple the engine now applies."""
    g = state.graphs[gid]
    backlog = g.svc_req_ewma * g.queue_depth
    n_rep = len(g.device_indices)
    if backlog > state.replicate_after_s and n_rep < state.max_replicas:
        return (GROW, oracle_replica_candidate(state, g), None)
    if n_rep > 1 and backlog <= state.replicate_after_s / 4:
        calm = g.calm_polls + 1
        if calm >= state.replica_shrink_after:
            shed = max((d for d in g.device_indices if d != g.device_index),
                       key=lambda d: (state.used_bytes[d], d))
            return (SHRINK, shed, 0)
        return (HOLD, None, calm)
    return (HOLD, None, None)


def oracle_absorb(load, g, est):
    devs = g.device_indices
    if g.kind == REPLICATED:
        start = min(load.get(d, 0.0) for d in devs)
        done = start + est
        share = est / len(devs)
        for d in devs:
            load[d] = load.get(d, 0.0) + share
    else:
        start = max((load.get(d, 0.0) for d in devs), default=0.0)
        done = start + est
        for d in devs:
            load[d] = done
    return done


def oracle_predicted_wait(state, graph_id, deadline=None):
    g = state.graphs[graph_id]
    est = g.svc_ewma
    if g.kind is None:
        return est
    my_key = g.earliest_deadline
    if deadline is not None:
        my_key = min(my_key, deadline)
    load = {}
    order = sorted(((gid, s) for gid, s in state.graphs.items()
                    if s.queue_depth and gid != graph_id),
                   key=lambda t: (t[1].earliest_deadline, t[0]))
    for gid, s in order:
        if (s.earliest_deadline, gid) > (my_key, graph_id):
            continue
        if s.kind is None:
            continue
        oracle_absorb(load, s, s.svc_ewma)
    return oracle_absorb(load, g, est)


def oracle_due(state):
    """The old ``poll`` dueness cut (without the max_batch threshold
    union, which stayed engine-side)."""
    order = sorted(((gid, s) for gid, s in state.graphs.items()
                    if s.queue_depth),
                   key=lambda t: (t[1].earliest_deadline, t[0]))
    load, due_upto = {}, -1
    for i, (gid, s) in enumerate(order):
        done = oracle_absorb(load, s, s.svc_ewma)
        slack = SVC_SAFETY * done + SVC_FLOOR_S
        if s.earliest_deadline - slack <= state.now:
            due_upto = i
    return tuple(g for g, _ in order[:due_upto + 1])


def oracle_dispatch_order(state, ids):
    return tuple(sorted((g for g in ids if g in state.graphs),
                 key=lambda g: (state.graphs[g].earliest_deadline, g)))


# ---------------------------------------------------------------------------
# recorded scenarios
# ---------------------------------------------------------------------------

POL = HeuristicPolicy()


def assert_replication_equal(state, gid):
    want = oracle_replication(state, gid)
    got = POL.replication(state, gid)
    assert (got.action, got.device_index, got.calm_polls) == want, (
        gid, want, got)


def test_place_worst_fit_and_sharded_route():
    st = S([], used=[10 << 20, 5 << 20])
    assert POL.place(st, "g", 1 << 20) == \
        type(POL.place(st, "g", 1 << 20))(*oracle_place(st, 1 << 20))
    # worst-fit: device 1 has more free budget
    assert POL.place(st, "g", 1 << 20).device_index == 1
    # ties break to the lowest index
    st = S([], used=[7, 7, 7], n_devices=3)
    assert POL.place(st, "g", 4).device_index == 0
    # giant graph on a multi-device mesh -> sharded
    giant = (64 << 20) + 1
    assert POL.place(st, "g", giant).kind == SHARDED
    # ...but single on a 1-device mesh (engine degrades to rotation)
    st1 = S([], n_devices=1, used=[0])
    assert POL.place(st1, "g", giant).kind == SINGLE
    assert POL.place(st1, "g", giant).device_index == 0


def test_place_fuzz_matches_oracle():
    rng = np.random.default_rng(0)
    for _ in range(200):
        n = int(rng.integers(1, 5))
        budget = int(rng.integers(1, 1 << 22))
        used = [int(rng.integers(0, 1 << 22)) for _ in range(n)]
        st = S([], n_devices=n, budget=budget, used=used)
        nbytes = int(rng.integers(0, 1 << 23))
        dec = POL.place(st, "g", nbytes)
        assert (dec.kind, dec.device_index) == oracle_place(st, nbytes)


def test_replication_grow_onto_coolest_fitting_device():
    g = G("hot", depth=8, req_ewma=0.1, nbytes=4 << 20)  # backlog 0.8 s
    st = S([g], n_devices=4, used=[8 << 20, 1 << 20, 3 << 20, 2 << 20])
    assert_replication_equal(st, "hot")
    dec = POL.replication(st, "hot")
    assert dec.action == GROW and dec.device_index == 1  # most free budget
    assert dec.calm_polls is None  # grow clears the hysteresis counter


def test_replication_grow_skips_full_and_hosting_devices():
    # device 1 hosts a replica already; device 2 has no room -> device 3
    g = G("hot", kind=REPLICATED, dev=0, devs=(0, 1), depth=8, req_ewma=0.1,
          nbytes=4 << 20)
    full = (64 << 20) - (1 << 20)
    st = S([g], n_devices=4, used=[0, 0, full, 2 << 20])
    assert_replication_equal(st, "hot")
    assert POL.replication(st, "hot").device_index == 3
    # nothing fits anywhere -> GROW with device None (engine no-ops)
    st = S([g], n_devices=3, used=[0, 0, full])
    assert_replication_equal(st, "hot")
    dec = POL.replication(st, "hot")
    assert dec.action == GROW and dec.device_index is None


def test_replication_respects_max_replicas_and_sharded():
    g = G("hot", kind=REPLICATED, dev=0, devs=(0, 1), depth=50, req_ewma=1.0)
    st = S([g], max_replicas=2)
    assert_replication_equal(st, "hot")
    assert POL.replication(st, "hot").action == HOLD
    sharded = G("big", kind=SHARDED, devs=(0, 1), depth=50, req_ewma=1.0)
    assert POL.replication(S([sharded]), "big").action == HOLD
    # evicted graphs can be asked to grow but get no device
    ev = G("cold", depth=50, req_ewma=1.0, resident=False)
    dec = POL.replication(S([ev]), "cold")
    assert dec.action == GROW and dec.device_index is None


def test_replication_shrink_hysteresis_trace():
    """The recorded calm-poll sequence: two calm polls HOLD with the
    counter carried, the third SHRINKs the fullest secondary and resets
    the counter — exactly the old ``_calm_polls`` dance."""
    def at(calm):
        g = G("h", kind=REPLICATED, dev=0, devs=(0, 1, 2), depth=0,
              req_ewma=1.0, calm=calm)
        return S([g], n_devices=3, used=[5, 9, 7], shrink_after=3)

    for calm, want in [(0, (HOLD, None, 1)), (1, (HOLD, None, 2)),
                       (2, (SHRINK, 1, 0))]:  # device 1: fullest secondary
        assert_replication_equal(at(calm), "h")
        got = POL.replication(at(calm), "h")
        assert (got.action, got.device_index, got.calm_polls) == want
    # mid-zone backlog (between /4 and the grow bar): counter clears
    g = G("h", kind=REPLICATED, dev=0, devs=(0, 1), depth=1,
          req_ewma=0.1, calm=2)  # backlog 0.1: > 0.0625, <= 0.25
    dec = POL.replication(S([g]), "h")
    assert (dec.action, dec.calm_polls) == (HOLD, None)
    # shrink never sheds the primary: fullest device overall is 0 (the
    # primary), so device 2 (fuller secondary) goes
    g = G("h", kind=REPLICATED, dev=0, devs=(0, 1, 2), depth=0,
          req_ewma=1.0, calm=2)
    st = S([g], n_devices=3, used=[99, 3, 7])
    assert_replication_equal(st, "h")
    assert POL.replication(st, "h").device_index == 2


def test_predicted_wait_serializes_colocated_edf_ahead():
    """The recorded submit-shed scenario of test_overload, replayed pure:
    g1's earlier deadline serializes ahead of g2 on the same device, so
    g2's wait is both EWMAs stacked."""
    g1 = G("g1", depth=1, ed=1000.5, ewma=1.0)
    g2 = G("g2", depth=0, ewma=1.0)
    st = S([g1, g2], n_devices=1, used=[0])
    for dl in (1001.5, 1002.5, None):
        assert POL.predicted_wait(st, "g2", dl) == \
            oracle_predicted_wait(st, "g2", dl)
    assert POL.predicted_wait(st, "g2", 1001.5) == pytest.approx(2.0)
    # EDF-behind queues cannot delay us: g3's later deadline is skipped
    g3 = G("g3", depth=1, ed=5000.0, ewma=10.0)
    st = S([g1, g2, g3], n_devices=1, used=[0])
    assert POL.predicted_wait(st, "g2", 1001.5) == pytest.approx(2.0)
    assert POL.predicted_wait(st, "g2", 1001.5) == \
        oracle_predicted_wait(st, "g2", 1001.5)


def test_predicted_wait_replicated_splits_and_sharded_spans():
    busy = G("busy", depth=1, ed=1000.0, ewma=50.0)
    hot = G("hot", kind=REPLICATED, dev=0, devs=(0, 1), depth=0, ewma=10.0)
    st = S([busy, hot])
    # the replicated queue anchors on its idle replica (device 1), not
    # behind busy's 50 s backlog on device 0
    assert POL.predicted_wait(st, "hot", 1100.0) == \
        oracle_predicted_wait(st, "hot", 1100.0) == pytest.approx(10.0)
    big = G("big", kind=SHARDED, devs=(0, 1), depth=0, ewma=5.0)
    st = S([busy, big])
    # sharded starts when its busiest mesh device frees: behind busy
    assert POL.predicted_wait(st, "big", 1100.0) == \
        oracle_predicted_wait(st, "big", 1100.0) == pytest.approx(55.0)


def test_shed_on_submit_boundary_and_reason():
    g = G("g", depth=0, ewma=1.0)
    st = S([g], n_devices=1, used=[0], now=1000.0)
    dec = POL.shed_on_submit(st, "g", 1000.5)
    assert dec.shed and "predicted wait" in dec.reason
    assert dec.predicted_wait_s == pytest.approx(1.0)
    assert not POL.shed_on_submit(st, "g", 1001.5).shed
    # exactly at the boundary: now + wait == deadline is NOT shed
    assert not POL.shed_on_submit(st, "g", 1001.0).shed


def test_shed_at_dispatch_matches_old_gate():
    g = G("g", depth=1, ed=1000.05, ewma=0.0)
    st = S([g], n_devices=1, used=[0], now=1000.2)
    # old gate: now + est > deadline, est = svc_ewma (0.0 here)
    assert POL.shed_at_dispatch(st, "g", 1000.05).shed
    assert not POL.shed_at_dispatch(st, "g", 1000.2).shed
    st2 = S([G("g", depth=1, ed=1000.05, ewma=0.5)], n_devices=1,
            used=[0], now=1000.0)
    assert POL.shed_at_dispatch(st2, "g", 1000.05).shed
    assert not POL.shed_at_dispatch(st2, "g", 1000.6).shed


def test_due_queues_edf_prefix_trace():
    """The recorded load-map scenarios of test_placement, replayed pure:
    co-located queues stack, separate devices don't, sharded spans the
    mesh, replicated follows its least-loaded clone."""
    # stacked: a due at 984.99, b (behind a) due at 970.99
    a = G("a", dev=0, depth=1, ed=1000.0, ewma=10.0)
    b = G("b", dev=0, depth=1, ed=1001.0, ewma=10.0)
    st = S([a, b], now=969.0)
    assert POL.due_queues(st) == oracle_due(st) == ()
    st = S([a, b], now=975.0)
    assert POL.due_queues(st) == oracle_due(st) == ("a", "b")
    # independent devices: only a at 985.5
    b1 = G("b", dev=1, depth=1, ed=1001.0, ewma=10.0)
    st = S([a, b1], now=975.0)
    assert POL.due_queues(st) == oracle_due(st) == ()
    st = S([a, b1], now=985.5)
    assert POL.due_queues(st) == oracle_due(st) == ("a",)
    # sharded synchronizes the mesh: b stacks behind s on device 1
    s_ = G("s", kind=SHARDED, devs=(0, 1), depth=1, ed=1000.0, ewma=10.0)
    st = S([s_, b1], now=975.0)
    assert POL.due_queues(st) == oracle_due(st) == ("s", "b")
    # replicated follows the least-loaded replica
    busy = G("busy", dev=0, depth=1, ed=1000.0, ewma=50.0)
    hot = G("hot", kind=REPLICATED, dev=0, devs=(0, 1), depth=1,
            ed=1100.0, ewma=10.0)
    st = S([busy, hot], now=1020.0)
    assert POL.due_queues(st) == oracle_due(st) == ("busy",)
    st = S([busy, hot], now=1090.0)
    assert POL.due_queues(st) == oracle_due(st) == ("busy", "hot")


def test_dispatch_order_edf_ties_by_graph_id():
    gs = [G("z", depth=1, ed=5.0), G("a", depth=1, ed=5.0),
          G("m", depth=1, ed=1.0), G("q", depth=1)]
    st = S(gs)
    got = POL.dispatch_order(st, ["z", "a", "m", "q"])
    assert isinstance(got, DispatchOrder)
    assert got.graph_ids == oracle_dispatch_order(st, ["z", "a", "m", "q"])
    assert got.graph_ids == ("m", "a", "z", "q")


# ---------------------------------------------------------------------------
# randomized sweep across mixed meshes/routes
# ---------------------------------------------------------------------------

def _random_state(rng):
    n_dev = int(rng.integers(1, 5))
    budget = 64 << 20
    graphs = []
    for i in range(int(rng.integers(0, 6))):
        kind = rng.choice([SINGLE, REPLICATED, SHARDED]
                          if n_dev > 1 else [SINGLE])
        if kind == SINGLE:
            devs = (int(rng.integers(0, n_dev)),)
        elif kind == SHARDED:
            devs = tuple(range(n_dev))
        else:
            k = int(rng.integers(2, n_dev + 1))
            devs = tuple(int(d) for d in
                         rng.choice(n_dev, size=k, replace=False))
        graphs.append(G(
            f"g{i}", kind=kind, dev=devs[0], devs=devs,
            depth=int(rng.integers(0, 6)),
            ed=float("inf") if rng.random() < 0.3
            else 1000.0 + float(rng.random()) * 30.0,
            ewma=float(rng.random()) * 10.0,
            req_ewma=float(rng.random()),
            calm=int(rng.integers(0, 4)),
            nbytes=int(rng.integers(1, budget // 2)),
            resident=bool(rng.random() < 0.9)))
    used = [int(rng.integers(0, budget)) for _ in range(n_dev)]
    return S(graphs, now=1000.0 + float(rng.random()) * 40.0,
             n_devices=n_dev, used=used,
             max_replicas=int(rng.integers(1, n_dev + 1)),
             shrink_after=int(rng.integers(1, 4)))


def test_fuzz_all_decisions_match_oracle():
    rng = np.random.default_rng(42)
    for _ in range(300):
        st = _random_state(rng)
        ids = list(st.graphs)
        assert POL.due_queues(st) == oracle_due(st)
        pending = [g for g in ids if st.graphs[g].queue_depth]
        assert POL.dispatch_order(st, pending).graph_ids == \
            oracle_dispatch_order(st, pending)
        nbytes = int(rng.integers(0, (64 << 20) * 2))
        assert (POL.place(st, "new", nbytes).kind,
                POL.place(st, "new", nbytes).device_index) == \
            oracle_place(st, nbytes)
        for g in ids:
            s = st.graphs[g]
            if s.kind is not None and s.kind != SHARDED:
                got = POL.replication(st, g)
                assert isinstance(got, ReplicaDecision)
                assert (got.action, got.device_index, got.calm_polls) == \
                    oracle_replication(st, g)
            dl = None if rng.random() < 0.3 else \
                st.now + float(rng.random()) * 20.0
            assert POL.predicted_wait(st, g, dl) == \
                pytest.approx(oracle_predicted_wait(st, g, dl), abs=1e-12)
            if dl is not None:
                wait = oracle_predicted_wait(st, g, dl)
                assert POL.shed_on_submit(st, g, dl).shed == \
                    (st.now + wait > dl)
                assert POL.shed_at_dispatch(st, g, dl).shed == \
                    (st.now + s.svc_ewma > dl)


def test_absorb_load_shared_helper_matches_oracle():
    rng = np.random.default_rng(7)
    for _ in range(100):
        n_dev = int(rng.integers(1, 5))
        kind = rng.choice([SINGLE, REPLICATED, SHARDED])
        k = n_dev if kind == SHARDED else int(rng.integers(1, n_dev + 1))
        devs = tuple(int(d) for d in rng.choice(n_dev, size=k, replace=False))
        if kind == REPLICATED and not devs:
            continue
        g = G("g", kind=kind, dev=devs[0], devs=devs)
        la = {int(d): float(rng.random()) for d in
              rng.choice(n_dev, size=int(rng.integers(0, n_dev + 1)),
                         replace=False)}
        lb = dict(la)
        est = float(rng.random())
        assert absorb_load(la, kind, devs, est) == oracle_absorb(lb, g, est)
        assert la == lb
