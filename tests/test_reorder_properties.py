"""Property-based coverage of the row-remapping invariants (hypothesis).

Skipped wholesale when hypothesis is not installed — ``tests/test_reorder.py``
carries example-based twins of every property here, so the invariants stay
pinned either way."""
import numpy as np
import pytest

hyp = pytest.importorskip("hypothesis")
jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import csc, reorder  # noqa: E402
from repro.tuning import registry  # noqa: E402

SETTINGS = settings(max_examples=25, deadline=None)


def _random_coo(n, nnz, seed):
    rng = np.random.default_rng(seed)
    row = rng.integers(0, n, nnz)
    col = rng.integers(0, n, nnz)
    val = (rng.random(nnz) + 0.1).astype(np.float32)
    return csc.coo_from_arrays(row, col, val, (n, n))


def _dense(coo):
    d = np.zeros(coo.shape, np.float64)
    row = np.asarray(coo.row)
    keep = row != csc.PAD_IDX
    d[row[keep], np.asarray(coo.col)[keep]] = np.asarray(coo.val)[keep]
    return d


@SETTINGS
@given(m=st.integers(1, 200), seed=st.integers(0, 2**31 - 1))
def test_invert_permutation_is_involutive(m, seed):
    perm = np.random.default_rng(seed).permutation(m)
    inv = reorder.invert_permutation(perm)
    np.testing.assert_array_equal(inv[perm], np.arange(m))
    np.testing.assert_array_equal(
        np.asarray(reorder.invert_permutation(inv), np.int64), perm)


@SETTINGS
@given(n=st.integers(4, 120), nnz=st.integers(1, 400),
       seed=st.integers(0, 2**31 - 1),
       strat=st.sampled_from(reorder.REORDER_STRATEGIES))
def test_permutations_are_valid_and_permute_coo_matches_dense(
        n, nnz, seed, strat):
    a = _random_coo(n, nnz, seed)
    perm, inv = reorder.permutation(a, strat)
    np.testing.assert_array_equal(np.sort(perm), np.arange(n))
    np.testing.assert_array_equal(inv[perm], np.arange(n))
    np.testing.assert_array_equal(_dense(csc.permute_coo(a, perm)),
                                  _dense(a)[perm])


@SETTINGS
@given(n=st.integers(8, 100), nnz=st.integers(8, 300),
       k=st.integers(1, 6), seed=st.integers(0, 2**31 - 1),
       strat=st.sampled_from(reorder.REORDER_STRATEGIES))
def test_executor_round_trip_is_bit_identical(n, nnz, k, seed, strat):
    registry.clear_caches()
    a = _random_coo(n, nnz, seed)
    rng = np.random.default_rng(seed)
    b = jnp.asarray(rng.standard_normal((n, k)).astype(np.float32))
    ident = registry.get_executor(a, nnz_per_step=16, rows_per_window=8)
    ex = registry.get_executor(a, nnz_per_step=16, rows_per_window=8,
                               reorder=strat)
    np.testing.assert_array_equal(np.asarray(ex.spmm(b)),
                                  np.asarray(ident.spmm(b)))
