"""Sparse-format round trips (property-based)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import csc as fmt


def random_sparse(rng, m, n, density):
    a = (rng.random((m, n)) < density).astype(np.float32)
    return a * rng.standard_normal((m, n)).astype(np.float32)


@st.composite
def sparse_case(draw):
    m = draw(st.integers(1, 40))
    n = draw(st.integers(1, 40))
    density = draw(st.sampled_from([0.0, 0.02, 0.1, 0.5]))
    seed = draw(st.integers(0, 2**16))
    a = random_sparse(np.random.default_rng(seed), m, n, density)
    return a


@settings(max_examples=40, deadline=None)
@given(sparse_case())
def test_coo_roundtrip(a):
    got = np.asarray(fmt.coo_to_dense(fmt.coo_from_dense(a)))
    np.testing.assert_allclose(got, a, rtol=1e-6)


@settings(max_examples=40, deadline=None)
@given(sparse_case())
def test_csr_csc_roundtrip(a):
    coo = fmt.coo_from_dense(a)
    np.testing.assert_allclose(
        np.asarray(fmt.csr_to_dense(fmt.csr_from_coo(coo))), a, rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(fmt.csc_to_dense(fmt.csc_from_coo(coo))), a, rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(sparse_case())
def test_ell_roundtrip(a):
    got = np.asarray(fmt.ell_to_dense(fmt.ell_from_dense(a)))
    np.testing.assert_allclose(got, a, rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(sparse_case(), st.integers(0, 64))
def test_pad_coo_inert(a, extra):
    coo = fmt.coo_from_dense(a)
    padded = fmt.pad_coo(coo, coo.nnz + extra)
    np.testing.assert_allclose(np.asarray(fmt.coo_to_dense(padded)), a,
                               rtol=1e-6)
    # nnz histograms ignore padding
    assert int(fmt.row_nnz(padded).sum()) == coo.nnz


def test_row_col_nnz():
    a = np.zeros((4, 5), np.float32)
    a[0, :4] = 1
    a[2, 1] = 3
    coo = fmt.coo_from_dense(a)
    assert fmt.row_nnz(coo).tolist() == [4, 0, 1, 0]
    assert fmt.col_nnz(coo).tolist() == [1, 2, 1, 1, 0]
