"""The tuning subsystem: persistent store semantics (roundtrip, atomicity,
corruption fallback, key anatomy), the widened sweep (ktile + bf16), the
cycle-model pruner (logs, never discards the measured winner), and the
paper simulator reaching >90% converged utilization on power-law synth
degree distributions."""
import json

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core import autotuner, csc as fmt, executor as exe  # noqa: E402
from repro.core import schedule, spmm  # noqa: E402
from repro.graphs import synth  # noqa: E402
from repro.tuning import registry, runner, space  # noqa: E402
from repro.tuning.store import TuningStore, mesh_descriptor  # noqa: E402


@pytest.fixture(autouse=True)
def _fresh_caches():
    registry.clear_caches()
    yield
    registry.clear_caches()


def _graph(n=300, density=0.03, alpha=0.9, seed=7):
    return synth.power_law_adjacency(n, density, alpha, seed=seed)


def _b(n, k=12, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((n, k)).astype(np.float32))


# ---------------------------------------------------------------------------
# Store: roundtrip, atomicity, corruption, key anatomy
# ---------------------------------------------------------------------------

def test_store_roundtrip(tmp_path):
    a = _graph(seed=1)
    st = TuningStore(tmp_path)
    sched = schedule.build_balanced_schedule(a, 32, 16)
    cfg = space.TunedConfig(nnz_per_step=32, rows_per_window=16,
                            cols_per_block=None, window_nnz=None, ktile=128,
                            routing=exe.GATHER, measured_us=12.5,
                            utilization=sched.utilization,
                            cols_per_block_resolved=sched.cols_per_block,
                            bf16_max_err=1e-3)
    key = st.key(registry.graph_fingerprint(a), 12)
    assert st.load(key) is None
    st.save(key, cfg, sched)
    got_cfg, got_sched, got_perm = st.load(key)
    assert got_perm is None  # identity order → no permutation persisted
    assert got_cfg == cfg
    for f in ("win_id", "col_block", "val", "local_row", "local_col",
              "row_map"):
        np.testing.assert_array_equal(getattr(got_sched, f),
                                      getattr(sched, f))
    assert got_sched.shape == sched.shape
    assert got_sched.n_evil_chunks == sched.n_evil_chunks
    # no stray temp files survive a completed write
    assert [p.name for p in st.dir.glob("*.tmp")] == []
    assert st.entries() == [key]
    assert st.nbytes() > 0


def test_store_corrupted_entry_is_a_miss(tmp_path):
    a = _graph(seed=2)
    st = TuningStore(tmp_path)
    sched = schedule.build_balanced_schedule(a, 32, 16)
    cfg = space.TunedConfig(32, 16, None, None, 128, exe.GATHER, 1.0,
                            sched.utilization)
    key = st.key("fp", 8)
    path = st.save(key, cfg, sched)
    path.write_bytes(b"\x00garbage" * 32)
    with pytest.warns(UserWarning, match="corrupted"):
        assert st.load(key) is None
    assert not path.exists()  # corpse removed; next save re-creates


def test_store_rejects_inconsistent_schedule(tmp_path):
    """A syntactically-valid entry with torn geometry fails validation and
    falls back to a miss (schedule_from_arrays raises ValueError)."""
    a = _graph(seed=3)
    st = TuningStore(tmp_path)
    sched = schedule.build_balanced_schedule(a, 32, 16)
    cfg = space.TunedConfig(32, 16, None, None, 128, exe.GATHER, 1.0,
                            sched.utilization)
    key = st.key("fp2", 8)
    st.save(key, cfg, sched)
    with np.load(st.path(key), allow_pickle=False) as z:
        payload = dict(z)
    payload["val"] = payload["val"][:-5]  # truncate the slot values
    np.savez(open(st.path(key), "wb"), **payload)
    with pytest.warns(UserWarning, match="corrupted"):
        assert st.load(key) is None


def test_schedule_serialization_validates():
    a = _graph(seed=4)
    sched = schedule.build_balanced_schedule(a, 32, 16)
    arrays = schedule.schedule_to_arrays(sched)
    back = schedule.schedule_from_arrays(arrays)
    assert back.n_steps == sched.n_steps
    bad = dict(arrays)
    bad["meta"] = arrays["meta"].copy()
    bad["meta"][2] = 999  # nnz_per_step inconsistent with array lengths
    with pytest.raises(ValueError):
        schedule.schedule_from_arrays(bad)
    with pytest.raises(ValueError):
        schedule.schedule_from_arrays({"meta": arrays["meta"]})
    # a negative index would silently wrap in jnp — must fail validation
    bad = dict(arrays)
    bad["win_id"] = arrays["win_id"].copy()
    bad["win_id"][0] = -2
    with pytest.raises(ValueError, match="out-of-range"):
        schedule.schedule_from_arrays(bad)


def test_store_key_anatomy(tmp_path):
    """Every component of (graph, width, device kind, mesh, version) splits
    the keyspace."""
    st = TuningStore(tmp_path)
    base = st.key("fp", 16)
    assert st.key("fp", 16) == base            # deterministic
    assert st.key("other", 16) != base         # graph fingerprint
    assert st.key("fp", 32) != base            # probe width
    assert st.key("fp", 16, device="tpu:v5e") != base  # device kind
    assert st.key("fp", 16, mesh="8dev") != base       # mesh
    assert mesh_descriptor(1) == "1dev"
    # non-default sweeps fold their identity into the runner's store key
    k_full = runner.store_key(st, "fp", 16)
    k_swp = runner.store_key(st, "fp", 16,
                             sweep=[dict(nnz_per_step=8, rows_per_window=8,
                                         cols_per_block=None,
                                         window_nnz=None,
                                         routing=exe.GATHER)])
    assert k_full == st.key("fp", 16, mesh=mesh_descriptor(None))
    assert k_swp != k_full


def test_import_order_tuning_first():
    """``repro.tuning`` imported before ``repro.core`` must not trip the
    lazy re-export chain (regression: core/__init__'s eager from-imports
    re-entered the partially-initialized registry)."""
    import os
    import subprocess
    import sys

    import repro

    src = os.path.dirname(list(repro.__path__)[0])
    env = dict(os.environ, PYTHONPATH=src)
    code = ("import repro.tuning, repro.core; "
            "assert repro.core.get_executor is "
            "repro.tuning.registry.get_executor; "
            "from repro.core.executor import autotune, TunedConfig")
    proc = subprocess.run([sys.executable, "-c", code],
                          capture_output=True, text=True, env=env)
    assert proc.returncode == 0, proc.stderr


def test_store_env_root(tmp_path, monkeypatch):
    from repro.tuning import store as store_mod

    monkeypatch.setenv(store_mod.ENV_ROOT, str(tmp_path / "envroot"))
    st = TuningStore()
    assert str(st.root) == str(tmp_path / "envroot")


# ---------------------------------------------------------------------------
# Sweep breadth: ktile and bf16-accumulate candidates
# ---------------------------------------------------------------------------

def test_default_sweep_spans_ktile_and_bf16():
    a = _graph(600, 0.02, 0.9, seed=5)
    cand = space.default_sweep(a)
    ktiles = {c.get("ktile") for c in cand if c["routing"] == exe.GATHER}
    assert set(space.KTILE_CANDIDATES) <= ktiles
    assert any(c.get("bf16_accumulate") for c in cand)
    assert any(c["routing"] == exe.ONEHOT for c in cand)


def test_bf16_executor_matches_f32_loosely():
    a = _graph(seed=6)
    b = _b(300, seed=6)
    ref = np.asarray(spmm.spmm_coo(a, b))
    ex = registry.get_executor(a, nnz_per_step=32, rows_per_window=16,
                               bf16_accumulate=True)
    assert ex.bf16_accumulate
    got = np.asarray(ex.spmm(b)).astype(np.float32)
    # bf16 has ~8 mantissa bits: close but not f32-close
    np.testing.assert_allclose(got, ref, atol=0.1)
    assert np.abs(got - ref).max() > 0  # genuinely reduced precision


def test_autotune_attaches_bf16_error_report():
    a = _graph(seed=7)
    cfg = runner.autotune(a, (300, 8), iters=1, warmup=1)
    assert cfg.bf16_max_err is not None
    assert 0 < cfg.bf16_max_err < 0.5
    # the report is part of the persisted artifact
    d = json.loads(json.dumps(cfg.__dict__))
    assert d["bf16_max_err"] == cfg.bf16_max_err


def test_autotune_cache_keys_on_report_and_slack():
    """Regression: a report-less cached result must not be served to a
    caller asking for the bf16 report (and pruning settings are part of
    the cache identity)."""
    a = _graph(seed=18)
    cfg_no = runner.autotune(a, (300, 8), iters=1, warmup=1,
                             bf16_report=False)
    assert cfg_no.bf16_max_err is None
    cfg_yes = runner.autotune(a, (300, 8), iters=1, warmup=1)
    assert cfg_yes is not cfg_no
    assert cfg_yes.bf16_max_err is not None
    assert runner.autotune(a, (300, 8), iters=1, warmup=1,
                           prune_slack=2.0) is not cfg_yes


def test_store_entry_without_report_retuned_for_reporting_caller(tmp_path):
    a = _graph(seed=19)
    st = TuningStore(tmp_path)
    cfg_no = runner.autotune(a, (300, 8), iters=1, warmup=1,
                             bf16_report=False, store=st)
    assert cfg_no.bf16_max_err is None
    registry.clear_caches()  # ≈ restart
    cfg = runner.autotune(a, (300, 8), iters=1, warmup=1, store=st)
    assert cfg.bf16_max_err is not None  # re-tuned, report attached
    entry_cfg, _, _ = st.load(st.entries()[0])
    assert entry_cfg.bf16_max_err is not None  # and re-persisted


def test_bf16_wins_only_with_explicit_opt_in(monkeypatch):
    """A numerics change must never be a timing-noise outcome: even when
    the bf16 twin measures fastest, the default winner stays f32; with
    ``allow_bf16=True`` the twin may win."""
    a = _graph(seed=8)
    # deterministic "timings": bf16 executors are reported 10x faster
    monkeypatch.setattr(
        runner, "measure_candidate",
        lambda ex, b, iters, warmup: 10.0 if ex.bf16_accumulate else 100.0)
    cfg = runner.autotune(a, (300, 8), iters=1, warmup=1, bf16_report=False)
    assert not cfg.bf16_accumulate
    registry.clear_caches()
    cfg2 = runner.autotune(a, (300, 8), iters=1, warmup=1,
                           bf16_report=False, allow_bf16=True)
    assert cfg2.bf16_accumulate


# ---------------------------------------------------------------------------
# Cycle-model pruning
# ---------------------------------------------------------------------------

def test_prune_skips_unbalanced_candidate_and_logs(capsys):
    a = _graph(400, 0.02, 1.1, seed=8)
    good = dict(nnz_per_step=128, rows_per_window=64, cols_per_block=None,
                window_nnz=None, routing=exe.GATHER)
    # pathological: giant steps over tiny windows → almost all padding
    bad = dict(nnz_per_step=2048, rows_per_window=8, cols_per_block=None,
               window_nnz=None, routing=exe.GATHER)
    kept, n_pruned = runner.prune_sweep(a, [good, bad])
    assert n_pruned == 1 and kept == [good]
    out = capsys.readouterr().out
    assert "1/2 candidates skipped" in out  # no silent caps


@pytest.mark.parametrize("seed,n,density", [(9, 250, 0.03), (10, 400, 0.02)])
def test_pruner_never_discards_measured_winner(seed, n, density):
    """Acceptance: time the FULL sweep, then check the pruner would have
    kept the measured winner (same candidates, no timing noise between the
    two runs)."""
    a = _graph(n, density, 1.0, seed=seed)
    sweep = space.default_sweep(a)
    cfg = runner.autotune(a, (n, 8), sweep=sweep, iters=1, warmup=1,
                          prune=False, bf16_report=False,
                          include_onehot=True)
    kept, _ = runner.prune_sweep(a, sweep)
    winners = [c for c in kept
               if (c["nnz_per_step"], c["rows_per_window"],
                   str(c["cols_per_block"])) ==
               (cfg.nnz_per_step, cfg.rows_per_window,
                str(cfg.cols_per_block))
               and c["routing"] == cfg.routing]
    assert winners, (cfg, kept)


# ---------------------------------------------------------------------------
# Store-backed autotune: the restart path
# ---------------------------------------------------------------------------

def test_autotune_store_roundtrip_zero_sweeps(tmp_path, monkeypatch):
    a = _graph(seed=12)
    st = TuningStore(tmp_path)
    cfg = runner.autotune(a, (300, 8), iters=1, warmup=1, store=st)
    assert len(st.entries()) == 1

    registry.clear_caches()  # ≈ process restart
    monkeypatch.setattr(runner, "measure_candidate",
                        lambda *a_, **k: pytest.fail("measured on warm path"))
    monkeypatch.setattr(schedule, "build_balanced_schedule",
                        lambda *a_, **k: pytest.fail("rebuilt on warm path"))
    ex, cfg2 = runner.warm_tuned_executor(a, (300, 8), iters=1, warmup=1,
                                          store=st)
    assert cfg2 == cfg
    b = _b(300, 8, seed=12)
    np.testing.assert_allclose(np.asarray(ex.spmm(b)),
                               np.asarray(spmm.spmm_coo(a, b)), atol=1e-4)


def test_bf16_store_entries_never_reach_f32_callers(tmp_path, monkeypatch):
    """An ``allow_bf16=True`` run's persisted winner must not be served to
    a default (f32-only) caller: the key fold separates the entries, and
    the hit path double-checks."""
    a = _graph(seed=15)
    st = TuningStore(tmp_path)
    monkeypatch.setattr(
        runner, "measure_candidate",
        lambda ex, b, iters, warmup: 10.0 if ex.bf16_accumulate else 100.0)
    cfg_bf = runner.autotune(a, (300, 8), iters=1, warmup=1, store=st,
                             allow_bf16=True, bf16_report=False)
    assert cfg_bf.bf16_accumulate
    registry.clear_caches()  # ≈ restart
    cfg = runner.autotune(a, (300, 8), iters=1, warmup=1, store=st,
                          bf16_report=False)
    assert not cfg.bf16_accumulate
    # both objectives now coexist on disk under distinct keys
    assert len(st.entries()) == 2


def test_onehot_schedules_not_built_off_tpu(monkeypatch):
    """Eligibility runs before pruning: the pruner must not pay capped
    one-hot schedule builds for candidates that will never be timed."""
    if jax.default_backend() == "tpu":
        pytest.skip("one-hot candidates are eligible on TPU")
    a = _graph(600, 0.02, 0.9, seed=16)
    built = []
    orig = schedule.build_balanced_schedule

    def spy(a_, *args, **kw):
        built.append(kw.get("cols_per_block"))
        return orig(a_, *args, **kw)

    monkeypatch.setattr(schedule, "build_balanced_schedule", spy)
    runner.autotune(a, (600, 8), iters=1, warmup=1, bf16_report=False)
    assert "auto" not in built  # no capped one-hot builds were paid


def test_release_graph_purges_device_step_arrays():
    a = _graph(seed=17)
    fp = registry.graph_fingerprint(a)
    ex = registry.get_executor(a, nnz_per_step=16, rows_per_window=8,
                               routing=exe.ONEHOT)
    sched = ex.sched
    # keys are (schedule identity, placement device); release purges all
    assert [k for k in exe._DEVICE_STEPS if k[0] == id(sched)]
    registry.release_graph(fp)
    assert not [k for k in exe._DEVICE_STEPS if k[0] == id(sched)]
    assert not [k for k in registry._SCHEDULE_CACHE if k[0] == fp]
    assert not [k for k in registry._EXECUTOR_CACHE if k[0][0] == fp]


def test_executor_cache_keys_on_device_so_replicas_coexist():
    """The executor cache keys on (graph fingerprint, mesh, device):
    asking for the same graph pinned to a device is a different entry
    from the unpinned one — same-graph replicas coexist instead of the
    last-built replica evicting the others. Repeat requests per key are
    pure hits."""
    a = _graph(seed=18)
    dev = jax.devices()[0]
    unpinned = registry.get_executor(a, nnz_per_step=16, rows_per_window=8)
    pinned = registry.get_executor(a, nnz_per_step=16, rows_per_window=8,
                                   device=dev)
    assert unpinned is not pinned
    assert unpinned.device is None and pinned.device == dev
    assert pinned.sched is unpinned.sched        # one schedule build
    assert registry.get_executor(a, nnz_per_step=16, rows_per_window=8,
                                 device=dev) is pinned
    assert registry.get_executor(a, nnz_per_step=16,
                                 rows_per_window=8) is unpinned
    with pytest.raises(ValueError, match="cannot be combined"):
        registry.get_executor(a, nnz_per_step=16, rows_per_window=8,
                              device=dev, n_devices=1)
    # the identity-keyed per-schedule cache honours the same axis
    sched = unpinned.sched
    by_sched = registry.executor_for_schedule(sched, routing=exe.GATHER)
    by_sched_pinned = registry.executor_for_schedule(sched, device=dev,
                                                     routing=exe.GATHER)
    assert by_sched is not by_sched_pinned
    assert registry.executor_for_schedule(
        sched, device=dev, routing=exe.GATHER) is by_sched_pinned


def test_release_device_steps_scoped_to_one_device():
    """Dropping one replica's device copy must not purge the surviving
    replicas': release_device_steps(sched, device=...) is scoped, the
    no-argument form stays the catch-all."""
    a = _graph(seed=19)
    sched = registry.get_schedule(a, nnz_per_step=16, rows_per_window=8)
    dev = jax.devices()[0]
    exe.device_step_arrays(sched, None)
    exe.device_step_arrays(sched, dev)
    keys = [k for k in exe._DEVICE_STEPS if k[0] == id(sched)]
    assert len(keys) == 2
    exe.release_device_steps(sched, device=dev)
    keys = [k for k in exe._DEVICE_STEPS if k[0] == id(sched)]
    assert keys == [(id(sched), None)]
    exe.release_device_steps(sched)
    assert not [k for k in exe._DEVICE_STEPS if k[0] == id(sched)]


def test_autotune_cache_hit_still_populates_store(tmp_path):
    """Regression: an in-process _AUTOTUNE_CACHE hit must not skip store
    persistence — a second store on the same graph (e.g. two engines with
    different roots in one process) relies on the write-through."""
    a = _graph(seed=14)
    cfg = runner.autotune(a, (300, 8), iters=1, warmup=1)  # no store: cached
    st = TuningStore(tmp_path)
    cfg2 = runner.autotune(a, (300, 8), iters=1, warmup=1, store=st)
    assert cfg2 is cfg
    assert len(st.entries()) == 1                   # backfilled on the hit
    entry_cfg, _, _ = st.load(st.entries()[0])
    assert entry_cfg == cfg


def test_autotune_store_ignores_entry_for_bigger_mesh(tmp_path):
    """An entry tuned for a mesh this host can't provide is re-tuned, not
    served (the sharded executor would fail to build)."""
    a = _graph(seed=13)
    st = TuningStore(tmp_path)
    cfg = runner.autotune(a, (300, 8), iters=1, warmup=1, store=st)
    skey = runner.store_key(st, registry.graph_fingerprint(a), 8)
    import dataclasses

    sched = registry.get_schedule(a, **cfg.as_schedule_kwargs())
    # the default-sweep winner may carry a reorder axis; thread its
    # permutation through so the v2 payload validation stays satisfied
    perm = runner._winning_perm(a, cfg, registry.graph_fingerprint(a))
    st.save(skey, dataclasses.replace(cfg, n_devices=512), sched, perm)
    registry.clear_caches()
    runner._AUTOTUNE_CACHE.clear()
    cfg2 = runner.autotune(a, (300, 8), iters=1, warmup=1, store=st)
    assert cfg2.n_devices is None or cfg2.n_devices <= len(jax.devices())


# ---------------------------------------------------------------------------
# Paper simulator: converged utilization on power-law synth distributions
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,scale,n_pe", [
    ("cora", 4, 64), ("pubmed", 8, 128), ("nell", 16, 128)])
def test_run_autotuning_exceeds_90pct_util_on_powerlaw(name, scale, n_pe):
    """Acceptance: the §IV loop (smoothing + remote switching + evil-row
    remapping) converges past 90% utilization on every synthetic power-law
    degree distribution — the paper's Fig. 17 endpoint."""
    ds = synth.make_dataset(name, scale=scale)
    row_nnz = np.bincount(np.asarray(ds.adj.row),
                          minlength=ds.num_nodes).astype(np.float64)
    design = autotuner.designs_for(name)["D"]
    util, log = autotuner.converged_utilization(row_nnz, n_pe, design,
                                                n_rounds=12)
    assert util > 0.90, f"{name}: converged util {util:.2%}"
    # and it converged *upward* from the static start
    assert util >= log[0].utilization - 1e-9


def test_raw_powerlaw_adjacency_also_converges():
    """Same bar on a bare ``power_law_adjacency`` (no dataset calibration):
    the rebalancing loop, not the dataset constants, does the work."""
    a = synth.power_law_adjacency(4000, 0.005, 1.1, seed=3, max_degree=400)
    row_nnz = np.bincount(np.asarray(a.row), minlength=4000).astype(float)
    design = autotuner.DesignConfig("D", smoothing_hops=2,
                                    remote_switching=True,
                                    row_remapping=True)
    util, _ = autotuner.converged_utilization(row_nnz, 128, design,
                                              n_rounds=12)
    assert util > 0.90
