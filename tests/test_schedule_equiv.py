"""Vectorized schedule builder ⇔ seed loop implementation equivalence, plus
the example-based schedule tests (no hypothesis dependency — always runs).

The vectorized builders (searchsorted/cumsum/fancy-indexing) must produce
**bit-identical** schedules to the seed's Python ``while``/``for`` loops;
``_seed_*`` below is a faithful copy of the seed algorithm kept as the
reference oracle.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import csc as fmt, schedule, spmm
from repro.graphs import synth


# ---------------------------------------------------------------------------
# Seed reference implementation (pre-vectorization), verbatim algorithm
# ---------------------------------------------------------------------------

def _seed_group_layout(keys, k, uniform):
    ne = keys.shape[0]
    if ne == 0:
        return (np.zeros(0, np.int64), np.zeros(0, np.int64),
                np.zeros(0, np.int64), 0)
    new_group = np.empty(ne, bool)
    new_group[0] = True
    new_group[1:] = keys[1:] != keys[:-1]
    group_idx = np.cumsum(new_group) - 1
    group_start = np.maximum.accumulate(np.where(new_group, np.arange(ne), 0))
    pos_in_group = np.arange(ne) - group_start
    chunk_in_group = pos_in_group // k
    pos_in_chunk = pos_in_group % k
    n_groups = int(group_idx[-1]) + 1
    group_sizes = np.bincount(group_idx, minlength=n_groups)
    group_chunks = -(-group_sizes // k)
    if uniform:
        per_group = int(group_chunks.max())
        step_of_elem = group_idx * per_group + chunk_in_group
        n_steps = n_groups * per_group
        head_of_step = np.repeat(np.nonzero(new_group)[0], per_group)
    else:
        chunk_offset = np.concatenate([[0], np.cumsum(group_chunks)[:-1]])
        step_of_elem = chunk_offset[group_idx] + chunk_in_group
        n_steps = int(group_chunks.sum())
        head_of_step = np.nonzero(pos_in_chunk == 0)[0]
    return step_of_elem, pos_in_chunk, head_of_step, n_steps


def _seed_emit(row, col, val, shape, k, r, cb, window_of_row, window_start,
               evil_mask_row, uniform):
    m, n = shape
    n_colblocks = max(1, -(-n // cb))
    colblk = col // cb
    is_evil = evil_mask_row[row]
    n_reg_windows = int(window_start.shape[0])

    reg = np.nonzero(~is_evil)[0]
    rwin = window_of_row[row[reg]]
    reg_key = rwin * n_colblocks + colblk[reg]
    order = np.lexsort((col[reg], row[reg], reg_key))
    reg = reg[order]
    r_step, r_pos, r_head, n_reg_steps = _seed_group_layout(reg_key[order],
                                                            k, uniform)

    ev = np.nonzero(is_evil)[0]
    ev_key = row[ev] * n_colblocks + colblk[ev]
    order = np.lexsort((col[ev], ev_key))
    ev = ev[order]
    e_step, e_pos, e_head, n_evil_steps = _seed_group_layout(ev_key[order],
                                                             k, False)
    n_evil_chunks = n_evil_steps

    n_steps = max(1, n_reg_steps + n_evil_steps)
    n_evil_windows = -(-max(1, n_evil_chunks) // r) if n_evil_chunks else 0
    n_windows = max(1, n_reg_windows + n_evil_windows)

    sval = np.zeros(n_steps * k, np.float32)
    srow = np.zeros(n_steps * k, np.int32)
    scol = np.zeros(n_steps * k, np.int32)
    step_win = np.zeros(n_steps, np.int32)
    step_cb = np.zeros(n_steps, np.int32)
    row_map = np.full(n_windows * r, -1, np.int32)

    if reg.size:
        slots = r_step * k + r_pos
        sval[slots] = val[reg]
        w = window_of_row[row[reg]]
        srow[slots] = (row[reg] - window_start[w]).astype(np.int32)
        scol[slots] = (col[reg] - colblk[reg] * cb).astype(np.int32)
        head = reg[r_head]
        step_win[:n_reg_steps] = window_of_row[row[head]]
        step_cb[:n_reg_steps] = colblk[head]

    win_end = np.concatenate([window_start[1:], [m]]) if n_reg_windows else \
        np.zeros(0, np.int64)
    for w in range(n_reg_windows):
        cnt = int(min(win_end[w] - window_start[w], r))
        rows = np.arange(window_start[w], window_start[w] + cnt)
        vals_map = np.where(evil_mask_row[rows], -1, rows).astype(np.int32)
        row_map[w * r: w * r + cnt] = vals_map

    if ev.size:
        slots = (n_reg_steps + e_step) * k + e_pos
        sval[slots] = val[ev]
        srow[slots] = (e_step % r).astype(np.int32)
        scol[slots] = (col[ev] - colblk[ev] * cb).astype(np.int32)
        step_win[n_reg_steps:] = (n_reg_windows + e_step[e_head] // r
                                  ).astype(np.int32)
        step_cb[n_reg_steps:] = colblk[ev[e_head]]
        chunk_slot = n_reg_windows * r + np.arange(n_evil_chunks)
        row_map[chunk_slot] = row[ev[e_head]].astype(np.int32)

    return schedule.Schedule(
        win_id=step_win, col_block=step_cb, val=sval, local_row=srow,
        local_col=scol, row_map=row_map, shape=shape, nnz_per_step=k,
        rows_per_window=r, cols_per_block=cb, nnz=int(row.shape[0]),
        n_evil_chunks=int(n_evil_chunks),
    )


def _seed_clean(a):
    row = np.asarray(a.row, np.int64)
    col = np.asarray(a.col, np.int64)
    val = np.asarray(a.val, np.float32)
    keep = row != fmt.PAD_IDX
    return row[keep], col[keep], val[keep]


def seed_build_balanced(a, nnz_per_step=256, rows_per_window=64,
                        cols_per_block=None, evil_threshold=None):
    """The seed ``build_balanced_schedule``: host while-loop first fit."""
    m, n = a.shape
    row, col, val = _seed_clean(a)
    k, r = nnz_per_step, rows_per_window
    cb = n if cols_per_block is None else cols_per_block
    evil_t = evil_threshold if evil_threshold is not None else k

    per_row = np.bincount(row, minlength=m)
    evil_mask = per_row > evil_t

    reg_nnz = np.where(evil_mask, 0, per_row).astype(np.int64)
    cum = np.cumsum(reg_nnz)
    window_of_row = np.zeros(m, np.int64)
    window_start = [0]
    base, w = 0, 0
    while base < m:
        target = (cum[base - 1] if base else 0) + k
        hi = int(np.searchsorted(cum, target, side="right"))
        hi = min(max(hi, base + 1), base + r, m)
        window_of_row[base:hi] = w
        if hi < m:
            window_start.append(hi)
        base = hi
        w += 1
    window_start = np.asarray(window_start, np.int64)
    return _seed_emit(row, col, val, (m, n), k, r, cb, window_of_row,
                      window_start, evil_mask, uniform=False)


def seed_build_naive(a, nnz_per_step=256, rows_per_window=64,
                     cols_per_block=None):
    m, n = a.shape
    row, col, val = _seed_clean(a)
    r = rows_per_window
    cb = n if cols_per_block is None else cols_per_block
    window_of_row = np.arange(m, dtype=np.int64) // r
    window_start = np.arange(0, max(m, 1), r, dtype=np.int64)
    evil_mask = np.zeros(m, bool)
    return _seed_emit(row, col, val, (m, n), nnz_per_step, r, cb,
                      window_of_row, window_start, evil_mask, uniform=True)


# ---------------------------------------------------------------------------
# Equivalence: vectorized builders == seed loops, bit for bit
# ---------------------------------------------------------------------------

def assert_schedules_identical(s1, s2):
    for f in ("win_id", "col_block", "val", "local_row", "local_col",
              "row_map"):
        np.testing.assert_array_equal(np.asarray(getattr(s1, f)),
                                      np.asarray(getattr(s2, f)), err_msg=f)
    assert s1.shape == s2.shape
    assert s1.nnz == s2.nnz
    assert s1.n_evil_chunks == s2.n_evil_chunks
    assert s1.utilization == s2.utilization


def _cases():
    rng = np.random.default_rng(0)
    cases = [synth.power_law_adjacency(n, d, al, seed=sd)
             for n, d, al, sd in [(24, 0.05, 0.6, 1), (120, 0.12, 1.2, 2),
                                  (300, 0.02, 0.9, 3), (64, 0.3, 1.0, 4)]]
    # evil-row-dominated matrix
    dense = np.zeros((64, 64), np.float32)
    dense[5, :] = rng.standard_normal(64)
    dense[rng.integers(0, 64, 40), rng.integers(0, 64, 40)] = 1.0
    cases.append(fmt.coo_from_dense(dense))
    # padded COO
    cases.append(fmt.pad_coo(synth.power_law_adjacency(40, 0.1, 0.8, seed=9),
                             300))
    # deliberately unsorted COO (exercises the lexsort fallback)
    r_ = rng.integers(0, 50, 200).astype(np.int32)
    c_ = rng.integers(0, 50, 200).astype(np.int32)
    v_ = rng.random(200).astype(np.float32)
    cases.append(fmt.COO(jnp.asarray(r_), jnp.asarray(c_), jnp.asarray(v_),
                         (50, 50)))
    return cases


@pytest.mark.parametrize("k,r", [(8, 4), (16, 8), (33, 16)])
@pytest.mark.parametrize("cb", [None, 16])
def test_vectorized_balanced_equals_seed(k, r, cb):
    for a in _cases():
        assert_schedules_identical(
            seed_build_balanced(a, k, r, cols_per_block=cb),
            schedule.build_balanced_schedule(a, k, r, cols_per_block=cb))


@pytest.mark.parametrize("k,r", [(8, 4), (33, 16)])
@pytest.mark.parametrize("cb", [None, 16])
def test_vectorized_naive_equals_seed(k, r, cb):
    for a in _cases():
        assert_schedules_identical(
            seed_build_naive(a, k, r, cols_per_block=cb),
            schedule.build_naive_schedule(a, k, r, cols_per_block=cb))


def test_auto_cols_per_block_resolution():
    assert schedule.auto_cols_per_block(100) == 100
    assert schedule.auto_cols_per_block(4096) == schedule.AUTO_COLS_PER_BLOCK
    a = synth.power_law_adjacency(600, 0.02, 0.9, seed=11)
    s = schedule.build_balanced_schedule(a, 8, 16, cols_per_block="auto")
    assert s.cols_per_block == schedule.AUTO_COLS_PER_BLOCK
    # the coupled window budget keeps the blocked schedule usable
    assert s.utilization > 0.3
    rng = np.random.default_rng(11)
    b = jnp.asarray(rng.standard_normal((600, 6)).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(schedule.execute_schedule_jnp(s, b)),
        np.asarray(spmm.spmm_coo(a, b)), atol=1e-4)


def test_execute_matches_coo_on_evil_and_regular():
    """Vectorized-builder schedules execute to the COO reference on random
    graphs including evil rows (utilization preserved vs seed by the
    bit-identity tests above)."""
    for a in _cases():
        s = schedule.build_balanced_schedule(a, 16, 8)
        rng = np.random.default_rng(1)
        b = jnp.asarray(
            rng.standard_normal((a.shape[1], 7)).astype(np.float32))
        np.testing.assert_allclose(
            np.asarray(schedule.execute_schedule_jnp(s, b)),
            np.asarray(spmm.spmm_coo(a, b)), atol=1e-4)


# ---------------------------------------------------------------------------
# Example-based schedule tests (moved from test_schedule.py so they run
# without hypothesis)
# ---------------------------------------------------------------------------

def test_evil_rows_split_and_merge():
    # one row holds half the matrix: must chunk + merge exactly
    n = 64
    rng = np.random.default_rng(0)
    dense = np.zeros((n, n), np.float32)
    dense[5, :] = rng.standard_normal(n)  # evil row
    dense[rng.integers(0, n, 40), rng.integers(0, n, 40)] = 1.0
    a = fmt.coo_from_dense(dense)
    s = schedule.build_balanced_schedule(a, nnz_per_step=8,
                                         rows_per_window=8)
    assert s.n_evil_chunks >= n // 8
    b = jnp.asarray(rng.standard_normal((n, 5)).astype(np.float32))
    got = np.asarray(schedule.execute_schedule_jnp(s, b))
    np.testing.assert_allclose(got, dense @ np.asarray(b), atol=1e-4)


def test_blocked_mode_correct():
    a = synth.power_law_adjacency(100, 0.05, 0.9, seed=3)
    s = schedule.build_balanced_schedule(a, 16, 8, cols_per_block=32)
    rng = np.random.default_rng(3)
    b = jnp.asarray(rng.standard_normal((100, 6)).astype(np.float32))
    ref = np.asarray(spmm.spmm_coo(a, b))
    np.testing.assert_allclose(
        np.asarray(schedule.execute_schedule_jnp(s, b)), ref, atol=1e-4)


def test_device_ranges_balanced():
    a = synth.power_law_adjacency(500, 0.02, 1.0, seed=1)
    s = schedule.build_balanced_schedule(a, 32, 16)
    ranges = s.device_step_ranges(8)
    sizes = ranges[:, 1] - ranges[:, 0]
    assert sizes.max() - sizes.min() <= 1
    assert ranges[0, 0] == 0 and ranges[-1, 1] == s.n_steps


def test_spmm_blocked_matches():
    a = synth.power_law_adjacency(80, 0.06, 0.8, seed=2)
    rng = np.random.default_rng(2)
    b = jnp.asarray(rng.standard_normal((80, 10)).astype(np.float32))
    ref = np.asarray(spmm.spmm_coo(a, b))
    got = np.asarray(spmm.spmm_coo_blocked(a, b, t=3))
    np.testing.assert_allclose(got, ref, atol=1e-4)


@pytest.mark.parametrize("order", ["o1", "o2"])
def test_flops_orders_positive(order):
    o1, o2 = spmm.flops_axw_orders(1000, (100, 50), (50, 8), 0.1)
    assert o1 > 0 and o2 > 0 and o1 > o2  # AxXW order always cheaper here
