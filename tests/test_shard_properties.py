"""Shard-splitting properties (hypothesis): the contiguous step split
partitions all steps exactly once for arbitrary (n_steps, n_devices) —
including n_devices > n_steps — and shard work stays within one step
budget of the mean.

Property-based module: skipped wholesale when hypothesis is absent, like
the other property suites."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import schedule
from repro.graphs import synth
from repro.sharding import schedule_shard


@settings(max_examples=60, deadline=None)
@given(n_steps=st.integers(0, 5000), n_devices=st.integers(1, 128))
def test_split_partitions_steps_exactly_once(n_steps, n_devices):
    ranges = schedule_shard.split_step_ranges(n_steps, n_devices)
    assert ranges.shape == (n_devices, 2)
    # contiguous cover of [0, n_steps): starts at 0, ends at n_steps, each
    # shard begins where the previous ended, no shard runs backwards
    assert ranges[0, 0] == 0 and ranges[-1, 1] == n_steps
    np.testing.assert_array_equal(ranges[1:, 0], ranges[:-1, 1])
    sizes = ranges[:, 1] - ranges[:, 0]
    assert (sizes >= 0).all()         # n_devices > n_steps → empty shards
    assert int(sizes.sum()) == n_steps
    # equal-work split: step counts within one of each other
    assert int(sizes.max() - sizes.min()) <= (1 if n_steps else 0)
    np.testing.assert_array_equal(
        sizes, schedule_shard.shard_step_counts(n_steps, n_devices))


@st.composite
def sched_case(draw):
    n = draw(st.integers(24, 150))
    alpha = draw(st.sampled_from([0.6, 0.9, 1.2]))
    density = draw(st.sampled_from([0.02, 0.05, 0.12]))
    seed = draw(st.integers(0, 2**16))
    k = draw(st.sampled_from([8, 16, 33]))
    r = draw(st.sampled_from([4, 16]))
    d = draw(st.integers(1, 48))
    return n, density, alpha, seed, k, r, d


@settings(max_examples=25, deadline=None)
@given(sched_case())
def test_shard_work_within_one_step_budget_of_mean(case):
    """Steps are the schedule's equal-work unit, so per-shard issued work
    (steps × nnz_per_step slots) stays within one step budget of the mean
    — the device-level form of the paper's equal-work distribution — and
    the per-shard true nnz partitions the schedule's nnz exactly."""
    n, density, alpha, seed, k, r, d = case
    a = synth.power_law_adjacency(n, density, alpha, seed=seed)
    s = schedule.build_balanced_schedule(a, nnz_per_step=k,
                                         rows_per_window=r)
    counts = schedule_shard.shard_step_counts(s.n_steps, d)
    issued = counts * s.nnz_per_step
    mean = issued.mean()
    assert (np.abs(issued - mean) <= s.nnz_per_step).all()
    nnz = schedule_shard.shard_nnz(s, d)
    assert int(nnz.sum()) == s.nnz
    assert (nnz >= 0).all() and (nnz <= issued).all()


@settings(max_examples=15, deadline=None)
@given(sched_case())
def test_stacked_shards_conserve_slots(case):
    """The stacked [D, S, K] form re-packs every real slot exactly once:
    concatenating the shards' in-range steps reproduces the schedule's
    step-major arrays, and padding steps are all-zero."""
    n, density, alpha, seed, k, r, d = case
    a = synth.power_law_adjacency(n, density, alpha, seed=seed)
    s = schedule.build_balanced_schedule(a, nnz_per_step=k,
                                         rows_per_window=r)
    shards = schedule_shard.shard_schedule(s, d)
    sizes = shards.ranges[:, 1] - shards.ranges[:, 0]
    val = np.concatenate([shards.val[i, :sizes[i]] for i in range(d)])
    np.testing.assert_array_equal(val.reshape(-1),
                                  s.val)
    for i in range(d):
        assert not shards.val[i, sizes[i]:].any()
