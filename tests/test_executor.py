"""ScheduleExecutor: correctness vs the COO reference, fingerprint cache
semantics, zero host→device transfers on the cache-hit path, routing-path
equivalence (gather == one-hot, bit for bit on one schedule), and the
autotune-and-cache loop."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import csc as fmt, executor as exe, gcn, schedule, spmm
from repro.graphs import synth
from repro.kernels import spmm_pallas


@pytest.fixture(autouse=True)
def _fresh_caches():
    exe.clear_caches()
    yield
    exe.clear_caches()


def _graph(n=300, density=0.03, alpha=0.9, seed=7):
    return synth.power_law_adjacency(n, density, alpha, seed=seed)


def _b(n, k=12, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((n, k)).astype(np.float32))


# ---------------------------------------------------------------------------
# Correctness
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,density,alpha", [
    (64, 0.05, 0.8), (200, 0.02, 1.1), (123, 0.08, 0.6)])
def test_gather_executor_matches_coo(n, density, alpha):
    a = _graph(n, density, alpha, seed=n)
    b = _b(n, seed=n)
    ref = np.asarray(spmm.spmm_coo(a, b))
    ex = exe.get_executor(a, nnz_per_step=32, rows_per_window=16,
                          routing=exe.GATHER)
    np.testing.assert_allclose(np.asarray(ex.spmm(b)), ref, atol=1e-4)


def test_executor_handles_evil_rows():
    n = 64
    rng = np.random.default_rng(0)
    dense = np.zeros((n, n), np.float32)
    dense[5, :] = rng.standard_normal(n)
    dense[rng.integers(0, n, 40), rng.integers(0, n, 40)] = 1.0
    a = fmt.coo_from_dense(dense)
    ex = exe.get_executor(a, nnz_per_step=8, rows_per_window=8)
    assert ex.sched.n_evil_chunks > 0
    b = _b(n, 5)
    np.testing.assert_allclose(np.asarray(ex.spmm(b)),
                               dense @ np.asarray(b), atol=1e-4)


def test_onehot_executor_matches_gather():
    a = _graph(150, 0.05, 0.9, seed=3)
    b = _b(150, 9, seed=3)
    gather = exe.get_executor(a, nnz_per_step=16, rows_per_window=8,
                              routing=exe.GATHER)
    onehot = exe.get_executor(a, nnz_per_step=16, rows_per_window=8,
                              routing=exe.ONEHOT)
    np.testing.assert_allclose(np.asarray(gather.spmm(b)),
                               np.asarray(onehot.spmm(b)), atol=1e-5)


def test_executor_chunked_slot_stream():
    """Slot streams longer than slot_chunk take the fori_loop path."""
    a = _graph(400, 0.05, 0.9, seed=5)
    b = _b(400, 8, seed=5)
    ref = np.asarray(spmm.spmm_coo(a, b))
    ex = exe.ScheduleExecutor(
        schedule.build_balanced_schedule(a, 64, 32), slot_chunk=512)
    assert ex._n_chunks > 1
    np.testing.assert_allclose(np.asarray(ex.spmm(b)), ref, atol=1e-4)


def test_forward_awb_through_executor_matches_reference():
    ds = synth.make_dataset("cora", scale=4)
    cfg = gcn.GCNConfig(ds.num_features, 16, ds.num_classes)
    params = gcn.init_params(cfg, jax.random.PRNGKey(0))
    x = jnp.asarray(ds.features)
    ref = np.asarray(gcn.forward(params, ds.adj, x))
    # default path (fingerprint-cached executor)
    got = np.asarray(gcn.forward_awb(params, ds.adj, x))
    np.testing.assert_allclose(got, ref, atol=1e-3)
    # pinned-schedule path
    sched = schedule.build_balanced_schedule(ds.adj, 64, 32)
    got2 = np.asarray(gcn.forward_awb(params, ds.adj, x, sched))
    np.testing.assert_allclose(got2, ref, atol=1e-3)


# ---------------------------------------------------------------------------
# Cache semantics + zero transfers on the hot path
# ---------------------------------------------------------------------------

def test_fingerprint_cache_hits_and_misses():
    a = _graph(seed=1)
    ex1 = exe.get_executor(a)
    assert exe.get_executor(a) is ex1
    # same matrix content, different COO object → same fingerprint → hit
    a2 = fmt.COO(jnp.asarray(np.asarray(a.row).copy()),
                 jnp.asarray(np.asarray(a.col).copy()),
                 jnp.asarray(np.asarray(a.val).copy()), a.shape)
    assert exe.get_executor(a2) is ex1
    # different graph → miss
    assert exe.get_executor(_graph(seed=2)) is not ex1
    # different config → miss
    assert exe.get_executor(a, nnz_per_step=64) is not ex1


def test_schedule_pair_cache_dedupes_builds(monkeypatch):
    a = _graph(seed=3)
    calls = []
    orig = schedule.build_balanced_schedule

    def counting(*args, **kw):
        calls.append(1)
        return orig(*args, **kw)

    # the registry resolves the builder through the schedule module, so
    # patching it there intercepts every build path
    monkeypatch.setattr(schedule, "build_balanced_schedule", counting)
    exe.get_spmm_schedules(a, nnz_per_step=32, rows_per_window=16)
    assert len(calls) == 2  # one for A, one for Aᵀ
    # a second call site on the same graph rebuilds nothing
    s1, s1t = exe.get_spmm_schedules(a, nnz_per_step=32, rows_per_window=16)
    assert len(calls) == 2
    # and make_spmm_fn consumes the cached pair
    f = spmm_pallas.make_spmm_fn(a, nnz_per_step=32, rows_per_window=16,
                                 ktile=8)
    assert len(calls) == 2
    b = _b(a.shape[0], 6, seed=3)
    np.testing.assert_allclose(np.asarray(f(b)),
                               np.asarray(spmm.spmm_coo(a, b)), atol=1e-4)


def test_cache_hit_performs_zero_host_transfers(monkeypatch):
    """Acceptance: repeated executor calls move no schedule bytes — no
    jnp.asarray / device_put after the warm-up call."""
    a = _graph(seed=4)
    b = _b(a.shape[0], seed=4)
    ex = exe.get_executor(a, nnz_per_step=64, rows_per_window=32)
    ex.spmm(b).block_until_ready()  # trace + compile + upload

    transfers = []
    orig_asarray = jnp.asarray
    orig_put = jax.device_put

    def counting_asarray(*args, **kw):
        transfers.append(("asarray", args[0].__class__.__name__))
        return orig_asarray(*args, **kw)

    def counting_put(*args, **kw):
        transfers.append(("device_put", args[0].__class__.__name__))
        return orig_put(*args, **kw)

    monkeypatch.setattr(jnp, "asarray", counting_asarray)
    monkeypatch.setattr(jax, "device_put", counting_put)

    ex2 = exe.get_executor(a, nnz_per_step=64, rows_per_window=32)
    assert ex2 is ex
    for _ in range(3):
        ex2.spmm(b).block_until_ready()
    assert transfers == []


def test_executor_for_schedule_memoizes():
    a = _graph(seed=6)
    s = schedule.build_balanced_schedule(a, 64, 32)
    ex1 = exe.executor_for_schedule(s)
    assert exe.executor_for_schedule(s) is ex1


# ---------------------------------------------------------------------------
# Kernel routing paths: gather == one-hot bit for bit on one schedule
# ---------------------------------------------------------------------------

def test_kernel_routing_paths_bit_identical():
    a = _graph(150, 0.04, 1.0, seed=9)
    b = _b(150, 12, seed=9)
    s = schedule.build_balanced_schedule(a, 16, 8)
    onehot = np.asarray(spmm_pallas.spmm_balanced(s, b, ktile=8,
                                                  routing="onehot"))
    gather = np.asarray(spmm_pallas.spmm_balanced(s, b, ktile=8,
                                                  routing="gather"))
    np.testing.assert_array_equal(onehot, gather)  # bit-for-bit in f32
    np.testing.assert_allclose(gather, np.asarray(spmm.spmm_coo(a, b)),
                               atol=1e-4)


def test_kernel_capped_cb_matches_fullwidth():
    a = _graph(400, 0.04, 0.9, seed=10)
    b = _b(400, 10, seed=10)
    full = schedule.build_balanced_schedule(a, 16, 8)
    capped = schedule.build_balanced_schedule(a, 8, 8,
                                              cols_per_block="auto")
    assert capped.cols_per_block < a.shape[1]
    out_full = np.asarray(spmm_pallas.spmm_balanced(full, b, ktile=8,
                                                    routing="onehot"))
    out_capped = np.asarray(spmm_pallas.spmm_balanced(capped, b, ktile=8,
                                                      routing="onehot"))
    # different step partitions sum the same terms; f32 re-association
    # noise only
    np.testing.assert_allclose(out_capped, out_full, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(out_capped, np.asarray(spmm.spmm_coo(a, b)),
                               atol=1e-4)


def test_routing_cost_model_prefers_gather_for_wide_blocks():
    assert exe.select_routing(256, 58000, 64) == exe.GATHER
    assert exe.select_routing(256, 128, 64) == exe.ONEHOT
    costs = exe.routing_cost_model(256, 1024, 64)
    assert costs[exe.ONEHOT] > 0 and costs[exe.GATHER] > 0


# ---------------------------------------------------------------------------
# Autotune-and-cache
# ---------------------------------------------------------------------------

def test_autotune_returns_cached_config():
    a = _graph(seed=12)
    cfg = exe.autotune(a, (a.shape[1], 12), iters=1, warmup=1)
    assert cfg.measured_us > 0
    assert cfg.routing in (exe.GATHER, exe.ONEHOT)
    assert exe.autotune(a, (a.shape[1], 12), iters=1, warmup=1) is cfg
    # different measurement settings are a different cache entry, not a
    # stale hit
    assert exe.autotune(a, (a.shape[1], 12), iters=2, warmup=1) is not cfg
    ex = exe.autotuned_executor(a, (a.shape[1], 12))
    b = _b(a.shape[0], 12, seed=12)
    np.testing.assert_allclose(np.asarray(ex.spmm(b)),
                               np.asarray(spmm.spmm_coo(a, b)), atol=1e-4)


def test_fingerprint_ignores_padding():
    a = _graph(seed=14)
    padded = fmt.pad_coo(a, a.nnz + 64)
    assert exe.graph_fingerprint(a) == exe.graph_fingerprint(padded)
    assert exe.get_executor(padded) is exe.get_executor(a)


def test_autotuned_executor_honours_explicit_sweep_cb():
    """The returned executor runs exactly the measured-fastest candidate —
    an explicit cols_per_block is not rewritten to 'auto'."""
    a = _graph(600, 0.02, 0.9, seed=15)
    sweep = [dict(nnz_per_step=8, rows_per_window=16, cols_per_block=64,
                  window_nnz=80, routing=exe.ONEHOT)]
    cfg = exe.autotune(a, (600, 6), sweep=sweep, include_onehot=True,
                       iters=1, warmup=1)
    assert cfg.cols_per_block == 64
    ex = exe.autotuned_executor(a, (600, 6), sweep=sweep,
                                include_onehot=True, iters=1, warmup=1)
    assert ex.sched.cols_per_block == 64 == cfg.cols_per_block_resolved
    # off-TPU, an all-onehot sweep without the opt-in is a clear error
    with pytest.raises(ValueError, match="include_onehot"):
        exe.autotune(a, (600, 7), sweep=sweep)


def test_autotune_sweep_includes_capped_onehot_candidate():
    a = _graph(600, 0.02, 0.9, seed=13)
    cand = exe.default_sweep(a)
    routings = {c["routing"] for c in cand}
    assert routings == {exe.GATHER, exe.ONEHOT}
    cfg = exe.autotune(a, (600, 8), iters=1, warmup=1, include_onehot=True)
    assert cfg.measured_us > 0
