"""Roofline accounting: HLO collective parser + term math."""
import numpy as np

from repro.roofline import analysis as ra

HLO_FIXTURE = """
ENTRY main {
  %p0 = bf16[16,4096,512]{2,1,0} parameter(0)
  %ag = bf16[16,4096,512]{2,1,0} all-gather(%p0), replica_groups={{0,1,2,3}}, dimensions={2}
  %ar = f32[1024,1024]{1,0} all-reduce(%x), replica_groups=[16,2]<=[32] to_apply=%add
  %rs = f32[64,128]{1,0} reduce-scatter(%y), replica_groups={{0,1}}, dimensions={0}
  %cp = bf16[256]{0} collective-permute(%z), source_target_pairs={{0,1}}
  %a2a = s32[8,8]{1,0} all-to-all(%w), replica_groups={{0,1,2,3,4,5,6,7}}
}
"""


def test_collective_parser():
    out = ra.collective_bytes_from_hlo(HLO_FIXTURE)
    ag = 16 * 4096 * 512 * 2
    assert out["all-gather_bytes"] == ag
    assert np.isclose(out["all-gather_wire"], ag * 3 / 4)
    ar = 1024 * 1024 * 4
    assert out["all-reduce_bytes"] == ar
    assert np.isclose(out["all-reduce_wire"], 2 * ar * 1 / 2)  # groups of 2
    rs = 64 * 128 * 4
    assert np.isclose(out["reduce-scatter_wire"], rs * 1)  # (n-1)=1
    assert out["collective-permute_wire"] == 256 * 2
    a2a = 8 * 8 * 4
    assert np.isclose(out["all-to-all_wire"], a2a * 7 / 8)
    assert out["wire_bytes_total"] > 0


def test_parser_ignores_non_collectives():
    txt = "%d = f32[1000]{0} dot(%a, %b)\n%c = f32[10]{0} add(%d, %d)"
    out = ra.collective_bytes_from_hlo(txt)
    assert out["wire_bytes_total"] == 0


def test_roofline_terms():
    t = ra.roofline_terms(197e12, 819e9, 50e9)  # exactly 1s each
    assert np.isclose(t["compute_s"], 1.0)
    assert np.isclose(t["memory_s"], 1.0)
    assert np.isclose(t["collective_s"], 1.0)
    t2 = ra.roofline_terms(197e12, 8.19e9, 5e9)
    assert t2["dominant"] == "compute"
    assert np.isclose(t2["compute_roofline_fraction"], 1.0)
    t3 = ra.roofline_terms(1e12, 819e9, 50e9)
    assert t3["dominant"] in ("memory", "collective")


def test_model_flops():
    assert ra.model_flops(10, 10, 100, "train") == 6 * 10 * 100
    assert ra.model_flops(10, 4, 100, "prefill") == 2 * 4 * 100


def test_tpu_hbm_model():
    txt = """
  %p0 = bf16[1024,1024]{1,0} parameter(0)
  %p1 = bf16[1024,512]{1,0} parameter(1)
  %d = bf16[1024,512]{1,0} dot(%p0, %p1), lhs_contracting_dims={1}
  %c = f32[1024,512]{1,0} convert(%d)
  %b = f32[1024,512]{1,0} broadcast(%c)
  %ar = f32[64,64]{1,0} all-reduce(%x), replica_groups={{0,1}}
"""
    got = ra.tpu_hbm_bytes_from_hlo(txt)
    p0 = 1024 * 1024 * 2
    p1 = 1024 * 512 * 2
    d = 1024 * 512 * 2
    ar = 64 * 64 * 4
    # params + dot out + dot operands + collective out; convert/broadcast
    # (fusable elementwise) excluded
    assert got == p0 + p1 + d + (p0 + p1) + ar
