"""§Perf variants are numerically exact: chunked attention and grouped MoE
dispatch produce the same model outputs as the paper-faithful baseline."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.kernels import ref
from repro.models import transformer as tr


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "qwen3-moe-30b-a3b",
                                  "whisper-tiny"])
def test_opt_variant_matches_baseline(arch):
    cfg = configs.get_reduced_config(arch)
    if cfg.moe:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    copt = dataclasses.replace(cfg, attn_chunk=8, moe_groups=4)
    key = jax.random.PRNGKey(0)
    p = tr.init_params(cfg, key)
    batch = {"tokens": jax.random.randint(key, (2, 16), 0, cfg.vocab)}
    if cfg.encoder is not None:
        batch["source_embed"] = jax.random.normal(
            key, (2, cfg.encoder.max_source, cfg.d_model), jnp.float32)
    base, _ = tr.model_forward(cfg, p, batch, compute_dtype=jnp.float32)
    opt, _ = tr.model_forward(copt, p, batch, compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(opt), np.asarray(base), atol=2e-4)


@pytest.mark.parametrize("block_k", [8, 32, 100])
@pytest.mark.parametrize("causal", [True, False])
def test_chunked_attention_exact(block_k, causal):
    rng = np.random.default_rng(block_k)
    def t(s):
        return jnp.asarray(rng.standard_normal(s).astype(np.float32))
    q, k, v = t((2, 48, 4, 16)), t((2, 48, 2, 16)), t((2, 48, 2, 16))
    out = ref.attention_chunked(q, k, v, causal=causal, block_k=block_k)
    gold = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(gold), atol=2e-5)


def test_chunked_attention_decode_offset_and_window():
    rng = np.random.default_rng(1)
    def t(s):
        return jnp.asarray(rng.standard_normal(s).astype(np.float32))
    q, k, v = t((1, 8, 4, 16)), t((1, 64, 4, 16)), t((1, 64, 4, 16))
    for window in (None, 24):
        out = ref.attention_chunked(q, k, v, causal=True, window=window,
                                    block_k=16)
        gold = ref.attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(gold),
                                   atol=2e-5)
