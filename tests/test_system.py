"""End-to-end behaviour tests for the paper's system: the full AWB-GCN
pipeline from graph to balanced inference, and the serving engine."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import autotuner, gcn, profiler, schedule
from repro.graphs import synth
from repro.kernels import spmm_pallas
from repro.models.transformer_serve import ServeEngine
from repro import configs
from repro.models import transformer as tr


def test_awb_pipeline_end_to_end():
    """Profile → autotune (converge) → schedule → kernel → GCN output, all
    consistent with the dense reference."""
    ds = synth.make_dataset("nell", scale=16)
    prof = profiler.profile_matrix(ds.adj, "nell/16")
    # power-law imbalance present: hub rows dominate the mean
    assert prof.row_nnz_max / prof.row_nnz_mean > 20

    # the iterative autotuner improves utilization over baseline
    rn = np.asarray(np.bincount(np.asarray(ds.adj.row),
                                minlength=ds.num_nodes), np.float64)
    designs = autotuner.designs_for("nell")
    base, _ = autotuner.converged_utilization(rn, 128, designs["baseline"])
    full, _ = autotuner.converged_utilization(rn, 128, designs["D"])
    assert full > base

    # the static schedule realizes the same balance; kernel output correct
    sched = schedule.build_balanced_schedule(ds.adj, 32, 16)
    assert sched.utilization > 0.8
    cfg = gcn.GCNConfig(ds.num_features, 16, ds.num_classes)
    params = gcn.init_params(cfg, jax.random.PRNGKey(0))
    x = jnp.asarray(ds.features)
    ref = gcn.forward(params, ds.adj, x)
    via_kernel = gcn.forward(
        params, ds.adj, x,
        spmm_fn=lambda b: spmm_pallas.spmm_balanced(sched, b, ktile=8))
    np.testing.assert_allclose(np.asarray(via_kernel), np.asarray(ref),
                               atol=2e-3)


def test_lm_serving_engine():
    cfg = configs.get_reduced_config("qwen2-0.5b")
    params = tr.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_seq=32)
    outs = eng.generate([[1, 2, 3], [4, 5, 6, 7]], max_new_tokens=5)
    assert len(outs) == 2
    assert len(outs[0]) == 3 + 5 and len(outs[1]) == 4 + 5
    assert all(0 <= t < cfg.vocab for o in outs for t in o)


def test_serving_matches_forward_greedy():
    """Engine's greedy continuation equals argmax of the full forward."""
    cfg = configs.get_reduced_config("starcoder2-3b")
    params = tr.init_params(cfg, jax.random.PRNGKey(1))
    prompt = [3, 14, 15, 92, 6]
    eng = ServeEngine(cfg, params, max_seq=16)
    out = eng.generate([prompt], max_new_tokens=1)[0]
    logits, _ = tr.model_forward(
        cfg, params, {"tokens": jnp.asarray([prompt])},
        compute_dtype=jnp.float32)
    expect = int(jnp.argmax(logits[0, -1]))
    assert out[-1] == expect
