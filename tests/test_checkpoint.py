"""Checkpoint manager: atomicity, keep-k, resume equality, preemption,
pipeline determinism / elastic resharding."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.tokens import TokenPipeline
from repro.training.checkpoint import CheckpointManager


def _tree(seed):
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(rng.standard_normal((4, 4)).astype(np.float32)),
        "b16": jnp.asarray(rng.standard_normal(8), jnp.bfloat16),
        "nested": {"count": jnp.int32(seed)},
    }


def test_save_restore_exact(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = _tree(3)
    mgr.save(10, tree)
    got, meta = mgr.restore(tree)
    assert meta["step"] == 10
    for a, b in zip(np.asarray(got["w"]), np.asarray(tree["w"])):
        np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(
        np.asarray(got["b16"]).view(np.uint16),
        np.asarray(tree["b16"]).view(np.uint16))  # bf16 bit-exact


def test_keep_k_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in [1, 2, 3, 4]:
        mgr.save(s, _tree(s))
    steps = sorted(p.name for p in tmp_path.glob("step_*"))
    assert len(steps) == 2
    assert mgr.latest_step() == 4


def test_preemption_ignores_partial(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    mgr.save(5, _tree(5))
    # simulate a crash mid-write: stray .tmp dir newer than the last good one
    bad = tmp_path / "step_000000009.tmp"
    bad.mkdir()
    (bad / "arrays.npz").write_bytes(b"garbage")
    got, meta = mgr.restore(_tree(0))
    assert meta["step"] == 5
    assert int(got["nested"]["count"]) == 5


def test_async_writer(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_write=True)
    mgr.save(1, _tree(1), block=False)
    mgr.wait()
    import time
    for _ in range(100):
        if mgr.latest_step() == 1:
            break
        time.sleep(0.02)
    assert mgr.latest_step() == 1


def test_restore_missing_raises(tmp_path):
    mgr = CheckpointManager(tmp_path)
    with pytest.raises(FileNotFoundError):
        mgr.restore(_tree(0))


# ---- data pipeline -----------------------------------------------------------

def test_pipeline_deterministic_resume():
    p1 = TokenPipeline(100, 4, 16, seed=7)
    batches = [p1.next_batch() for _ in range(5)]
    state = p1.checkpoint_state()
    after = [p1.next_batch() for _ in range(3)]

    p2 = TokenPipeline(100, 4, 16, seed=7)
    p2.restore_state(state)
    resumed = [p2.next_batch() for _ in range(3)]
    for a, b in zip(after, resumed):
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        np.testing.assert_array_equal(a["labels"], b["labels"])


def test_pipeline_host_shards_differ():
    a = TokenPipeline(100, 4, 16, seed=1, host=0, num_hosts=2).next_batch()
    b = TokenPipeline(100, 4, 16, seed=1, host=1, num_hosts=2).next_batch()
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_pipeline_labels_are_next_tokens():
    b = TokenPipeline(50, 2, 12, seed=3).next_batch()
    # labels[t] is the stream's t+1 token: check the markov-predictable ones
    assert b["tokens"].shape == (2, 12)
    assert b["labels"].shape == (2, 12)
