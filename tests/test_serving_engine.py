"""GCNServingEngine acceptance: process-restart warm-start performs zero
measured sweeps and zero schedule rebuilds; corrupted store entries fall
back to re-tuning; LRU eviction keeps device-resident schedule bytes under
the budget with allclose results after re-admission; same-graph requests
batch into one jitted forward."""
import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core import executor as exe, gcn, schedule  # noqa: E402
from repro.graphs import synth  # noqa: E402
from repro.serving.gcn_engine import FlushError, GCNServingEngine  # noqa: E402
from repro.tuning import registry, runner  # noqa: E402
from repro.tuning.store import TuningStore  # noqa: E402

N_NODES = 220
N_FEATS = 20
N_CLASSES = 5

# a tiny 2-candidate sweep keeps engine tests fast; the engine folds the
# sweep identity into its store key, so warm-starts still hit
FAST_SWEEP = [
    dict(nnz_per_step=64, rows_per_window=32, cols_per_block=None,
         window_nnz=None, routing=exe.GATHER),
    dict(nnz_per_step=128, rows_per_window=64, cols_per_block=None,
         window_nnz=None, routing=exe.GATHER),
]
FAST_KW = dict(iters=1, warmup=1, sweep=FAST_SWEEP, bf16_report=False)


@pytest.fixture(autouse=True)
def _fresh_caches():
    registry.clear_caches()
    yield
    registry.clear_caches()


def _workload(seed):
    a = synth.power_law_adjacency(N_NODES, 0.03, 0.9, seed=seed)
    cfg = gcn.GCNConfig(N_FEATS, 16, N_CLASSES)
    params = gcn.init_params(cfg, jax.random.PRNGKey(seed))
    x = np.random.default_rng(seed).random((N_NODES, N_FEATS),
                                           ).astype(np.float32)
    return a, params, x


def _engine(root, **kw):
    kw.setdefault("autotune_kwargs", FAST_KW)
    return GCNServingEngine(store_root=root, **kw)


def test_add_and_serve_matches_reference(tmp_path):
    a, params, x = _workload(0)
    eng = _engine(tmp_path)
    rep = eng.add_graph("g", a, params)
    assert not rep.warm_start and rep.tune_seconds > 0
    ref = np.asarray(gcn.forward(params, a, jnp.asarray(x)))
    got = np.asarray(eng.infer("g", x))
    np.testing.assert_allclose(got, ref, atol=1e-3)
    # batch of perturbed requests: one jitted vmapped forward
    xs = [x, x * 0.5, x + 0.1]
    out = np.asarray(eng.serve_batch("g", xs))
    assert out.shape == (3, N_NODES, N_CLASSES)
    for i, xi in enumerate(xs):
        np.testing.assert_allclose(
            out[i], np.asarray(gcn.forward(params, a, jnp.asarray(xi))),
            atol=1e-3)
    with pytest.raises(ValueError, match="already registered"):
        eng.add_graph("g", a, params)


def test_restart_warm_start_zero_sweeps_zero_rebuilds(tmp_path, monkeypatch):
    """Acceptance: with a populated store, a fresh engine (fresh process
    simulated by cleared in-process caches) performs zero measured sweeps
    and zero schedule rebuilds — asserted by intercepting the runner and
    ``build_balanced_schedule``."""
    a, params, x = _workload(1)
    eng = _engine(tmp_path)
    eng.add_graph("g", a, params)
    ref = np.asarray(eng.infer("g", x))
    assert eng.counters["store_misses"] == 1

    registry.clear_caches()  # ≈ restart
    monkeypatch.setattr(runner, "measure_candidate",
                        lambda *a_, **k: pytest.fail("sweep on warm start"))
    monkeypatch.setattr(schedule, "build_balanced_schedule",
                        lambda *a_, **k: pytest.fail("rebuild on warm start"))
    eng2 = _engine(tmp_path)
    rep = eng2.add_graph("g", a, params)
    assert rep.warm_start and rep.tune_seconds == 0.0
    assert eng2.counters["store_hits"] == 1
    assert eng2.counters["store_misses"] == 0
    np.testing.assert_allclose(np.asarray(eng2.infer("g", x)), ref,
                               atol=1e-5)


def test_corrupted_store_entry_falls_back_to_retune(tmp_path):
    a, params, x = _workload(2)
    eng = _engine(tmp_path)
    eng.add_graph("g", a, params)
    ref = np.asarray(eng.infer("g", x))
    st = TuningStore(tmp_path)
    (entry,) = st.entries()
    st.path(entry).write_bytes(b"not an npz at all")

    registry.clear_caches()
    eng2 = _engine(tmp_path)
    with pytest.warns(UserWarning, match="corrupted"):
        rep = eng2.add_graph("g", a, params)
    assert not rep.warm_start          # re-tuned, did not crash
    assert eng2.counters["store_misses"] == 1
    np.testing.assert_allclose(np.asarray(eng2.infer("g", x)), ref,
                               atol=1e-5)
    assert st.entries()                # re-persisted for the next restart


def test_lru_eviction_respects_byte_budget(tmp_path, monkeypatch):
    """Acceptance: device-resident schedule bytes stay under the budget;
    evicted graphs re-admit (re-upload, never re-build) with allclose
    results."""
    graphs = {f"g{i}": _workload(10 + i) for i in range(3)}
    eng = _engine(tmp_path)
    refs = {}
    for gid, (a, params, x) in graphs.items():
        eng.add_graph(gid, a, params)
        refs[gid] = np.asarray(eng.infer(gid, x))
    per_graph = max(r.bytes for r in eng._graphs.values())

    registry.clear_caches()
    budget = int(per_graph * 2.2)  # room for ~2 of 3
    eng2 = _engine(tmp_path, device_budget_bytes=budget)
    for gid, (a, params, x) in graphs.items():
        eng2.add_graph(gid, a, params)
        assert eng2.device_bytes_in_use <= budget
    assert eng2.counters["evictions"] >= 1
    assert 1 <= len(eng2.resident_graphs) < 3
    # eviction drops device weights too (the budget meters both)
    victim = next(r for r in eng2._graphs.values() if r.executor is None)
    assert victim.params is None and victim.params_host is not None
    assert all(r.bytes > sum(np.asarray(w).nbytes
                             for w in r.params_host.values())
               for r in eng2._graphs.values() if r.executor is not None)

    # serving an evicted graph re-admits it — no schedule rebuild — and
    # the budget still holds afterwards
    monkeypatch.setattr(schedule, "build_balanced_schedule",
                        lambda *a_, **k: pytest.fail("rebuild on re-admit"))
    for gid, (a, params, x) in graphs.items():
        np.testing.assert_allclose(np.asarray(eng2.infer(gid, x)),
                                   refs[gid], atol=1e-5)
        assert eng2.device_bytes_in_use <= budget
    assert eng2.counters["readmissions"] >= 1
    assert eng2.stats()["n_resident"] == len(eng2.resident_graphs)


def test_budget_smaller_than_one_graph_keeps_active_resident(tmp_path):
    a, params, x = _workload(20)
    eng = _engine(tmp_path, device_budget_bytes=1)  # absurdly small
    eng.add_graph("g", a, params)
    # the active graph is never evicted, even over budget
    assert eng.resident_graphs == ["g"]
    out = np.asarray(eng.infer("g", x))
    np.testing.assert_allclose(
        out, np.asarray(gcn.forward(params, a, jnp.asarray(x))), atol=1e-3)


def test_submit_flush_batches_per_graph(tmp_path):
    g1, g2 = _workload(30), _workload(31)
    eng = _engine(tmp_path)
    eng.add_graph("g1", g1[0], g1[1])
    eng.add_graph("g2", g2[0], g2[1])
    with pytest.raises(KeyError):
        eng.submit("nope", g1[2])
    eng.submit("g1", g1[2])
    eng.submit("g1", g1[2] * 0.5)
    eng.submit("g2", g2[2])
    before = eng.counters["batches"]
    outs = eng.flush()
    assert eng.counters["batches"] == before + 2   # one batch per graph
    assert eng.counters["requests"] >= 3
    assert outs["g1"].shape == (2, N_NODES, N_CLASSES)
    assert outs["g2"].shape == (1, N_NODES, N_CLASSES)
    np.testing.assert_allclose(
        np.asarray(outs["g1"][1]),
        np.asarray(gcn.forward(g1[1], g1[0], jnp.asarray(g1[2] * 0.5))),
        atol=1e-3)
    assert eng.flush() == {}           # queue drained
    # malformed requests are rejected at submit time, never poisoning a
    # later flush
    with pytest.raises(ValueError, match="must be"):
        eng.submit("g1", g1[2][:-1])


def test_flush_failure_preserves_unserved_queues(tmp_path, monkeypatch):
    g1, g2 = _workload(32), _workload(33)
    eng = _engine(tmp_path)
    eng.add_graph("g1", g1[0], g1[1])
    eng.add_graph("g2", g2[0], g2[1])
    eng.submit("g1", g1[2])
    eng.submit("g2", g2[2])
    orig = eng._dispatch_batch

    def failing(graph_id, xs):
        if graph_id == "g2":
            raise RuntimeError("device fell over")
        return orig(graph_id, xs)

    monkeypatch.setattr(eng, "_dispatch_batch", failing)
    with pytest.raises(FlushError) as exc_info:
        eng.flush()
    err = exc_info.value
    # nothing lost: g1's computed logits ride on the exception, g2's
    # queue survived for retry
    assert err.partial["g1"].shape == (1, N_NODES, N_CLASSES)
    assert set(err.failures) == {"g2"}
    assert "g1" not in eng._pending
    assert len(eng._pending["g2"]) == 1
    monkeypatch.undo()
    outs = eng.flush()
    assert outs["g2"].shape == (1, N_NODES, N_CLASSES)


def test_cold_admission_does_not_pin_registry_caches(tmp_path):
    """Regression: the cold autotune sweep measures device-resident
    candidate executors through the registry; the engine must release them
    so its byte budget is the only thing pinning device memory."""
    a, params, x = _workload(60)
    eng = _engine(tmp_path)
    eng.add_graph("g", a, params)
    fp = registry.graph_fingerprint(a)
    for cache in (registry._EXECUTOR_CACHE, registry._SCHEDULE_CACHE):
        leaked = [k for k in cache
                  if (k[0] if isinstance(k[0], str) else k[0][0]) == fp]
        assert leaked == []
    # the engine still serves correctly from its own executor
    np.testing.assert_allclose(
        np.asarray(eng.infer("g", x)),
        np.asarray(gcn.forward(params, a, jnp.asarray(x))), atol=1e-3)


def test_eviction_is_lru_not_insertion_order(tmp_path):
    """Regression (ISSUE 5): the budget sweep must evict the least-
    recently-SERVED graph, never the first-inserted one. Constructed so
    the two orders disagree: g0 was admitted before g1, but serving g0
    makes g1 the LRU victim when g2's admission overflows the budget."""
    graphs = {f"g{i}": _workload(70 + i) for i in range(3)}
    eng = _engine(tmp_path)
    for gid, (a, params, x) in graphs.items():
        eng.add_graph(gid, a, params)
    per_graph = max(r.bytes for r in eng._graphs.values())

    registry.clear_caches()
    eng2 = _engine(tmp_path, device_budget_bytes=int(per_graph * 2.2))
    eng2.add_graph("g0", *graphs["g0"][:2])
    eng2.add_graph("g1", *graphs["g1"][:2])
    eng2.infer("g0", graphs["g0"][2])   # LRU order is now g1 < g0
    eng2.add_graph("g2", *graphs["g2"][:2])
    assert "g1" not in eng2.resident_graphs   # least recently served
    assert "g0" in eng2.resident_graphs       # served after g1: survives
    assert "g2" in eng2.resident_graphs
    # and the mirror scenario: touching g1 instead protects it
    registry.clear_caches()
    eng3 = _engine(tmp_path, device_budget_bytes=int(per_graph * 2.2))
    eng3.add_graph("g0", *graphs["g0"][:2])
    eng3.add_graph("g1", *graphs["g1"][:2])
    eng3.infer("g1", graphs["g1"][2])
    eng3.infer("g0", graphs["g0"][2])
    eng3.add_graph("g2", *graphs["g2"][:2])
    assert "g1" not in eng3.resident_graphs
    assert "g0" in eng3.resident_graphs


def test_direct_serve_batch_counts_only_completed(tmp_path, monkeypatch):
    """Regression (ISSUE 5): ``serve_batch`` used to count batches/
    requests at dispatch and never roll back when the async computation
    failed afterwards — only the queue path compensated. The invariant
    now holds on the direct path: a batch that fails after dispatch
    leaves the served-work counters (and service EWMAs) untouched."""
    import repro.serving.gcn_engine as ge

    a, params, x = _workload(80)
    eng = _engine(tmp_path)
    eng.add_graph("g", a, params)
    before = dict(eng.counters)

    def async_fault(out):
        raise RuntimeError("XlaRuntimeError stand-in: device OOM")

    monkeypatch.setattr(ge, "_block_until_ready", async_fault)
    with pytest.raises(RuntimeError, match="OOM"):
        eng.serve_batch("g", [x, x * 0.5])
    assert eng.counters["batches"] == before["batches"]
    assert eng.counters["requests"] == before["requests"]
    assert "g" not in eng._svc_ewma  # a failed batch is not a measurement
    monkeypatch.undo()

    eng.serve_batch("g", [x, x * 0.5])
    assert eng.counters["batches"] == before["batches"] + 1
    assert eng.counters["requests"] == before["requests"] + 2
    assert eng._svc_ewma["g"] > 0.0

    # dispatch-stage failure keeps the same invariant
    before = dict(eng.counters)
    monkeypatch.setattr(eng, "_dispatch_batch",
                        lambda *a_, **k: (_ for _ in ()).throw(
                            RuntimeError("bad dispatch")))
    with pytest.raises(RuntimeError, match="bad dispatch"):
        eng.serve_batch("g", [x])
    assert eng.counters["batches"] == before["batches"]
    assert eng.counters["requests"] == before["requests"]


def test_async_failure_in_flush_keeps_counters_honest(tmp_path, monkeypatch):
    """The queue path's counters obey the same count-only-completed rule
    when the failure happens at await time (after dispatch succeeded):
    queue restored, nothing counted, FlushError raised."""
    import repro.serving.gcn_engine as ge

    a, params, x = _workload(81)
    eng = _engine(tmp_path)
    eng.add_graph("g", a, params)
    eng.submit("g", x)
    before = dict(eng.counters)
    monkeypatch.setattr(ge, "_block_until_ready",
                        lambda out: (_ for _ in ()).throw(
                            RuntimeError("async fault")))
    with pytest.raises(FlushError):
        eng.flush()
    assert eng.counters["batches"] == before["batches"]
    assert eng.counters["requests"] == before["requests"]
    assert len(eng._pending["g"]) == 1   # restored for retry
    monkeypatch.undo()
    out = eng.flush()
    assert out["g"].shape == (1, N_NODES, N_CLASSES)
    assert eng.counters["batches"] == before["batches"] + 1


def test_remove_graph_releases_budget(tmp_path):
    a, params, x = _workload(40)
    eng = _engine(tmp_path)
    eng.add_graph("g", a, params)
    assert eng.device_bytes_in_use > 0
    eng.remove_graph("g")
    assert eng.device_bytes_in_use == 0
    assert eng.graphs == []
    with pytest.raises(KeyError):
        eng.infer("g", x)


def test_wrong_feature_rows_rejected(tmp_path):
    a, params, x = _workload(50)
    eng = _engine(tmp_path)
    eng.add_graph("g", a, params)
    with pytest.raises(ValueError, match="nodes"):
        eng.serve_batch("g", [x[:-1]])
