"""Locality-aware row remapping (islandization): permutation construction
and inversion, ``permute_coo``/``permute_csc`` against a dense reference,
bit-identical execution through the ``reorder`` axis on single-device,
replica-pinned, and sharded executors, the locality-aware cycle-model
pruner, store persistence of winning permutations (including corrupted
fallback), the sharded minimum-work gate, and serving-engine threading
(admission, streaming repair on the permuted twin, warm-start)."""
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core import csc, executor as exe, gcn, reorder, schedule  # noqa: E402
from repro.graphs import synth  # noqa: E402
from repro.serving.gcn_engine import GCNServingEngine  # noqa: E402
from repro.tuning import registry, runner, space  # noqa: E402
from repro.tuning.store import TuningStore  # noqa: E402

SRC = str(Path(__file__).resolve().parents[1] / "src")


@pytest.fixture(autouse=True)
def _fresh_caches():
    registry.clear_caches()
    yield
    registry.clear_caches()


def _graph(n=300, density=0.03, alpha=0.9, seed=7):
    return synth.power_law_adjacency(n, density, alpha, seed=seed)


def _b(n, k=12, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((n, k)).astype(np.float32))


def _shuffled(a, seed=3):
    """Randomly relabel vertices (rows AND columns) — destroys whatever
    incidental locality the generator's natural vertex order carries, so
    a locality permutation has real ground to recover."""
    m, n = a.shape
    assert m == n
    sigma = np.random.default_rng(seed).permutation(m).astype(np.int64)
    row = np.asarray(a.row)
    col = np.asarray(a.col)
    val = np.asarray(a.val)
    keep = row != csc.PAD_IDX
    return csc.coo_from_arrays(sigma[row[keep]], sigma[col[keep]], val[keep],
                               a.shape)


def _dense(coo):
    m, n = coo.shape
    d = np.zeros((m, n), np.float64)
    row = np.asarray(coo.row)
    keep = row != csc.PAD_IDX
    d[row[keep], np.asarray(coo.col)[keep]] = np.asarray(coo.val)[keep]
    return d


# ---------------------------------------------------------------------------
# permutation construction + inversion
# ---------------------------------------------------------------------------

def test_invert_permutation_roundtrip():
    perm = np.random.default_rng(0).permutation(37).astype(np.int32)
    inv = reorder.invert_permutation(perm)
    np.testing.assert_array_equal(inv[perm], np.arange(37))
    np.testing.assert_array_equal(perm[inv], np.arange(37))


@pytest.mark.parametrize("bad", [
    np.asarray([0, 0, 1], np.int32),      # duplicate
    np.asarray([0, 1, 3], np.int32),      # out of range
    np.asarray([-1, 0, 1], np.int32),     # negative
])
def test_invert_permutation_rejects_non_permutations(bad):
    with pytest.raises(ValueError, match="not a permutation"):
        reorder.invert_permutation(bad)


def test_degree_permutation_sorts_by_descending_nnz():
    a = _graph(seed=11)
    perm = reorder.degree_permutation(a)
    np.testing.assert_array_equal(np.sort(perm), np.arange(a.shape[0]))
    row, _ = reorder._clean_rows_cols(a)
    deg = np.bincount(row, minlength=a.shape[0])
    assert (np.diff(deg[perm]) <= 0).all()
    # stable: equal-degree rows keep ascending id order
    ties = np.flatnonzero(np.diff(deg[perm]) == 0)
    assert (perm[ties] < perm[ties + 1]).all()


def test_island_permutation_is_valid_and_deterministic():
    a = _graph(seed=12)
    perm = reorder.island_permutation(a)
    np.testing.assert_array_equal(np.sort(perm), np.arange(a.shape[0]))
    np.testing.assert_array_equal(perm, reorder.island_permutation(a))
    # the highest-degree vertex seeds the first island
    row, _ = reorder._clean_rows_cols(a)
    deg = np.bincount(row, minlength=a.shape[0])
    assert perm[0] == np.argsort(-deg, kind="stable")[0]


def test_island_permutation_respects_cap():
    a = _graph(n=200, seed=13)
    perm = reorder.island_permutation(a, island_cap=16)
    np.testing.assert_array_equal(np.sort(perm), np.arange(200))


def test_island_permutation_non_square_falls_back_to_degree():
    rng = np.random.default_rng(14)
    a = csc.coo_from_arrays(rng.integers(0, 40, 120),
                            rng.integers(0, 60, 120),
                            rng.random(120).astype(np.float32), (40, 60))
    np.testing.assert_array_equal(reorder.island_permutation(a),
                                  reorder.degree_permutation(a))


def test_permutation_dispatch():
    a = _graph(seed=15)
    assert reorder.permutation(a, "none") == (None, None)
    for strat in reorder.REORDER_STRATEGIES:
        perm, inv = reorder.permutation(a, strat)
        np.testing.assert_array_equal(inv[perm], np.arange(a.shape[0]))
    with pytest.raises(ValueError, match="unknown reorder strategy"):
        reorder.permutation(a, "zigzag")


# ---------------------------------------------------------------------------
# permute_coo / permute_csc
# ---------------------------------------------------------------------------

def test_permute_coo_matches_dense_reference():
    a = _graph(seed=16)
    perm = reorder.island_permutation(a)
    ap = csc.permute_coo(a, perm)
    np.testing.assert_array_equal(_dense(ap), _dense(a)[perm])
    # row-major sorted and PAD-free: a valid host COO for schedule building
    rows = np.asarray(ap.row)
    assert (rows != csc.PAD_IDX).all()
    order = np.lexsort((np.asarray(ap.col), rows))
    np.testing.assert_array_equal(order, np.arange(rows.shape[0]))


def test_permute_csc_matches_permute_coo():
    a = _graph(seed=17)
    perm = reorder.degree_permutation(a)
    got = csc.csc_to_coo(csc.permute_csc(csc.csc_from_coo(a), perm))
    np.testing.assert_array_equal(_dense(got), _dense(a)[perm])


def test_permute_coo_rejects_bad_permutations():
    a = _graph(seed=18)
    with pytest.raises(ValueError, match="permutation"):
        csc.permute_coo(a, np.arange(a.shape[0] - 1))
    bad = np.arange(a.shape[0])
    bad[0] = bad[1]
    with pytest.raises(ValueError, match="not a permutation"):
        csc.permute_coo(a, bad)


def test_schedule_locality_estimate_is_bounded():
    a = _graph(seed=19)
    for strat in ("none",) + reorder.REORDER_STRATEGIES:
        sched = registry.get_schedule(a, nnz_per_step=32, rows_per_window=16,
                                      reorder=strat)
        loc = reorder.schedule_locality(sched)
        assert 1.0 / 16 <= loc <= 1.0, (strat, loc)


# ---------------------------------------------------------------------------
# bit-identity through the executor boundary
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strat", reorder.REORDER_STRATEGIES)
@pytest.mark.parametrize("routing", [exe.GATHER, exe.ONEHOT])
def test_single_device_output_bit_identical(strat, routing):
    """Arbitrary f32 values: per-row accumulation order is permutation-
    invariant (ascending-column emission; evil-chunk boundaries depend only
    on per-row nnz), so reordered output rows are *bit*-equal, not merely
    close."""
    a = _shuffled(_graph(n=260, seed=20))
    b = _b(a.shape[0], seed=20)
    ident = registry.get_executor(a, nnz_per_step=32, rows_per_window=16,
                                  routing=routing)
    perm_ex = registry.get_executor(a, nnz_per_step=32, rows_per_window=16,
                                    routing=routing, reorder=strat)
    assert perm_ex is not ident  # distinct cache entries per reorder
    np.testing.assert_array_equal(np.asarray(perm_ex.spmm(b)),
                                  np.asarray(ident.spmm(b)))


def test_replica_pinned_executor_unpermutes(monkeypatch):
    """A device-pinned executor (the engine's replica clone path) carries
    the same un-permutation."""
    a = _graph(seed=25)
    b = _b(a.shape[0], seed=25)
    dev = jax.devices()[0]
    ident = registry.get_executor(a, nnz_per_step=32, rows_per_window=16)
    pinned = registry.get_executor(a, nnz_per_step=32, rows_per_window=16,
                                   device=dev, reorder="island")
    np.testing.assert_array_equal(np.asarray(pinned.spmm(b)),
                                  np.asarray(ident.spmm(b)))


def test_one_device_sharded_executor_unpermutes_exactly():
    """Sharded route (mesh of 1): exact-arithmetic values so the psum
    epilogue cannot introduce ulp noise — outputs must round-trip the
    permutation exactly."""
    a = _graph(seed=26)
    row = np.asarray(a.row)
    keep = row != csc.PAD_IDX
    a = csc.coo_from_arrays(row[keep], np.asarray(a.col)[keep],
                            np.ones(int(keep.sum()), np.float32), a.shape)
    rng = np.random.default_rng(26)
    b = jnp.asarray(rng.integers(-4, 5, (a.shape[0], 6)).astype(np.float32))
    ex = registry.get_executor(a, nnz_per_step=32, rows_per_window=16,
                               n_devices=1, reorder="island")
    assert isinstance(ex, exe.ShardedScheduleExecutor)
    np.testing.assert_array_equal(np.asarray(ex.spmm(b)),
                                  _dense(a) @ np.asarray(b))


# ---------------------------------------------------------------------------
# cycle-model pruner: the locality axis
# ---------------------------------------------------------------------------

def test_prune_sweep_drops_locality_dominated_reorderings(capsys):
    a = _graph(seed=28)
    base = dict(nnz_per_step=32, rows_per_window=16, cols_per_block=None,
                window_nnz=None, routing=exe.GATHER)
    cands = [dict(base)]
    cands += [dict(base, reorder=s) for s in reorder.REORDER_STRATEGIES]
    kept, n_pruned = runner.prune_sweep(a, cands, slack=1e9)
    out = capsys.readouterr().out
    assert "locality-dominated" in out
    # the identity candidate always survives (slack is effectively off,
    # so only the dominance rule can prune here)
    assert any(c.get("reorder", "none") == "none" for c in kept)
    # every surviving reorder candidate's model cost (issued slots ×
    # locality) strictly beats the identity twin's — dominated ones were
    # dropped without timing

    def _cost(strat):
        sched = registry.get_schedule(a, nnz_per_step=32, rows_per_window=16,
                                      reorder=strat)
        return sched.issued_slots * (
            0.5 + 0.5 * reorder.schedule_locality(sched))

    ident_cost = _cost("none")
    for c in kept:
        if c.get("reorder", "none") == "none":
            continue
        assert _cost(c["reorder"]) < ident_cost
    assert len(kept) + n_pruned == len(cands)


def test_default_sweep_carries_reorder_candidates():
    cands = space.default_sweep(_graph(seed=27))
    strats = {c.get("reorder", "none") for c in cands}
    assert strats == {"none", "degree", "island"}


def test_autotune_measures_reorder_axis_and_winner_serves():
    """End-to-end sweep over the reorder axis: whatever wins, the tuned
    executor's output matches the identity-order reference bit-exactly."""
    a = _shuffled(_graph(n=260, seed=29))
    b = _b(a.shape[0], seed=29)
    base = dict(nnz_per_step=32, rows_per_window=16, cols_per_block=None,
                window_nnz=None, routing=exe.GATHER)
    sweep = [dict(base)] + [dict(base, reorder=s)
                            for s in reorder.REORDER_STRATEGIES]
    cfg = runner.autotune(a, (a.shape[0], b.shape[1]), sweep=sweep,
                          iters=1, warmup=1, prune=False, bf16_report=False)
    assert cfg.reorder in ("none",) + reorder.REORDER_STRATEGIES
    ex = registry.get_executor(a, **cfg.as_executor_kwargs())
    ident = registry.get_executor(a, **base)
    np.testing.assert_array_equal(np.asarray(ex.spmm(b)),
                                  np.asarray(ident.spmm(b)))


# ---------------------------------------------------------------------------
# store: permutation persistence
# ---------------------------------------------------------------------------

def _island_entry(a, st):
    perm, _ = reorder.permutation(a, "island")
    ap = csc.permute_coo(a, perm)
    sched = schedule.build_balanced_schedule(ap, 32, 16)
    cfg = space.TunedConfig(nnz_per_step=32, rows_per_window=16,
                            cols_per_block=None, window_nnz=None, ktile=128,
                            routing=exe.GATHER, measured_us=10.0,
                            utilization=sched.utilization, reorder="island")
    key = st.key(registry.graph_fingerprint(a), 12)
    return key, cfg, sched, perm


def test_store_roundtrips_permutation(tmp_path):
    a = _graph(seed=30)
    st = TuningStore(tmp_path)
    key, cfg, sched, perm = _island_entry(a, st)
    st.save(key, cfg, sched, perm)
    got_cfg, got_sched, got_perm = st.load(key)
    assert got_cfg == cfg
    np.testing.assert_array_equal(got_perm, perm)
    assert got_perm.dtype == np.int32


def test_store_save_rejects_reorder_perm_mismatch(tmp_path):
    a = _graph(seed=31)
    st = TuningStore(tmp_path)
    key, cfg, sched, perm = _island_entry(a, st)
    with pytest.raises(ValueError, match="perm is missing"):
        st.save(key, cfg, sched)            # reorder=island, no perm
    import dataclasses
    none_cfg = dataclasses.replace(cfg, reorder="none")
    with pytest.raises(ValueError, match="perm is present"):
        st.save(key, none_cfg, schedule.build_balanced_schedule(a, 32, 16),
                perm)                        # reorder=none, stray perm


@pytest.mark.parametrize("corrupt", ["duplicate", "truncated", "missing"])
def test_store_corrupted_permutation_is_a_miss(tmp_path, corrupt):
    a = _graph(seed=32)
    st = TuningStore(tmp_path)
    key, cfg, sched, perm = _island_entry(a, st)
    path = st.save(key, cfg, sched, perm)
    with np.load(path, allow_pickle=False) as z:
        payload = {k: z[k] for k in z.files}
    if corrupt == "duplicate":
        payload["row_perm"] = payload["row_perm"].copy()
        payload["row_perm"][0] = payload["row_perm"][1]
    elif corrupt == "truncated":
        payload["row_perm"] = payload["row_perm"][:-3]
    else:
        del payload["row_perm"]              # reorder=island but no perm
    np.savez(path, **payload)
    with pytest.warns(UserWarning, match="corrupted"):
        assert st.load(key) is None
    assert not path.exists()                 # corpse dropped → re-tune


# ---------------------------------------------------------------------------
# satellite: the sharded minimum-work gate
# ---------------------------------------------------------------------------

def test_sharded_worth_it_thresholds():
    small = _graph(seed=33)                  # ~2.7K nnz — nowhere near
    assert not space.sharded_worth_it(small, 2)
    nnz = space.MIN_SHARDED_NNZ + 1024
    rng = np.random.default_rng(33)
    big = csc.coo_from_arrays(rng.integers(0, 4000, nnz),
                              rng.integers(0, 4000, nnz),
                              np.ones(nnz, np.float32), (4000, 4000))
    # duplicates collapse in coo_from_arrays; top back up if needed
    if np.asarray(big.row).shape[0] < space.MIN_SHARDED_NNZ:
        pytest.skip("synthetic graph collapsed below threshold")
    assert space.sharded_worth_it(big, 2)
    # step-count guard: enough nnz but too few steps per device
    assert not space.sharded_worth_it(
        big, 2, nnz_per_step=np.asarray(big.row).shape[0])


def test_sharded_sweep_gated_unless_forced():
    a = _graph(seed=34)
    assert space.sharded_sweep(a, (2, 4)) == []
    forced = space.sharded_sweep(a, (2, 4), force=True)
    assert {c["n_devices"] for c in forced} == {2, 4}


# ---------------------------------------------------------------------------
# serving engine: admission, streaming repair, warm-start
# ---------------------------------------------------------------------------

N_FEATS = 20
N_CLASSES = 5

ISLAND_SWEEP = [
    dict(nnz_per_step=64, rows_per_window=32, cols_per_block=None,
         window_nnz=None, routing=exe.GATHER, reorder="island"),
]
ISLAND_KW = dict(iters=1, warmup=1, sweep=ISLAND_SWEEP, bf16_report=False)


def _workload(seed, n=220):
    a = _shuffled(synth.power_law_adjacency(n, 0.03, 0.9, seed=seed),
                  seed=seed)
    cfg = gcn.GCNConfig(N_FEATS, 16, N_CLASSES)
    params = gcn.init_params(cfg, jax.random.PRNGKey(seed))
    x = np.random.default_rng(seed).random((n, N_FEATS)).astype(np.float32)
    return a, params, x


def _island_engine(root):
    return GCNServingEngine(store_root=root, autotune_kwargs=ISLAND_KW)


def test_engine_admits_and_serves_reordered_graph(tmp_path):
    a, params, x = _workload(40)
    eng = _island_engine(tmp_path)
    eng.add_graph("g", a, params)
    rec = eng._graphs["g"]
    assert rec.config.reorder == "island"
    assert rec.perm is not None and rec.inv is not None
    assert rec.pcoo is not None
    np.testing.assert_array_equal(_dense(rec.pcoo), _dense(rec.coo)[rec.perm])
    ref = np.asarray(gcn.forward(params, a, jnp.asarray(x)))
    np.testing.assert_allclose(np.asarray(eng.infer("g", x)), ref, atol=1e-5)
    # the persisted entry carries the permutation
    eng.drain_persists()
    st = TuningStore(tmp_path)
    (entry,) = st.entries()
    _, _, got_perm = st.load(entry)
    np.testing.assert_array_equal(got_perm, rec.perm)


def test_engine_warm_starts_reordered_graph(tmp_path):
    a, params, x = _workload(41)
    eng = _island_engine(tmp_path)
    eng.add_graph("g", a, params)
    ref = np.asarray(eng.infer("g", x))
    eng.drain_persists()

    registry.clear_caches()
    eng2 = _island_engine(tmp_path)
    rep = eng2.add_graph("g", a, params)
    assert rep.warm_start
    rec = eng2._graphs["g"]
    assert rec.config.reorder == "island" and rec.perm is not None
    np.testing.assert_allclose(np.asarray(eng2.infer("g", x)), ref,
                               atol=1e-5)


def test_engine_update_graph_repairs_permuted_twin(tmp_path):
    a, params, x = _workload(42)
    eng = _island_engine(tmp_path)
    eng.add_graph("g", a, params)
    rec = eng._graphs["g"]
    perm0 = rec.perm.copy()
    rng = np.random.default_rng(42)

    # a structural delta: inserts + a value overwrite + a removal
    row = np.asarray(rec.coo.row)
    col = np.asarray(rec.coo.col)
    hit = rng.choice(row.shape[0], 3, replace=False)
    drow = np.concatenate([row[hit], rng.integers(0, a.shape[0], 6)])
    dcol = np.concatenate([col[hit], rng.integers(0, a.shape[0], 6)])
    dval = (rng.random(drow.shape[0]) + 0.1).astype(np.float32)
    dval[0] = 0.0                           # remove an existing edge
    rep = eng.update_graph("g", csc.EdgeDelta(drow, dcol, dval))
    assert rep.repaired                     # incremental path, no re-tune

    rec = eng._graphs["g"]
    np.testing.assert_array_equal(rec.perm, perm0)  # repair keeps the perm
    # the permuted twin tracked the delta: still P·A of the updated graph
    np.testing.assert_array_equal(_dense(rec.pcoo),
                                  _dense(rec.coo)[rec.perm])
    ref = np.asarray(gcn.forward(params, rec.coo, jnp.asarray(x)))
    np.testing.assert_allclose(np.asarray(eng.infer("g", x)), ref, atol=1e-5)


def test_engine_update_errors_leave_permuted_state_unchanged(tmp_path):
    a, params, x = _workload(43)
    eng = _island_engine(tmp_path)
    eng.add_graph("g", a, params)
    rec = eng._graphs["g"]
    before = _dense(rec.pcoo)
    with pytest.raises(ValueError):
        eng.update_graph("g", csc.EdgeDelta(
            np.asarray([a.shape[0] + 5]), np.asarray([0]),
            np.asarray([1.0], np.float32)))
    np.testing.assert_array_equal(_dense(eng._graphs["g"].pcoo), before)


# ---------------------------------------------------------------------------
# multi-device: the un-permutation survives the psum epilogue
# ---------------------------------------------------------------------------

SCRIPT_REORDER_SHARDED = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, %r)
import numpy as np, jax, jax.numpy as jnp
from repro.core import csc as fmt, executor as exe
from repro.graphs import synth
assert len(jax.devices()) == 8

a = synth.power_law_adjacency(300, 0.03, 0.9, seed=7)
row = np.asarray(a.row); keep = row != fmt.PAD_IDX
# exact arithmetic: dyadic values + small-int B so psum order is invisible
a = fmt.coo_from_arrays(row[keep], np.asarray(a.col)[keep],
                        np.full(int(keep.sum()), 0.5, np.float32), a.shape)
rng = np.random.default_rng(0)
b = jnp.asarray(rng.integers(-4, 5, (300, 6)).astype(np.float32))
dense = np.zeros(a.shape, np.float64)
dense[np.asarray(a.row), np.asarray(a.col)] = np.asarray(a.val)
ref = dense @ np.asarray(b)
for strat in ("degree", "island"):
    for d in (2, 4, 8):
        ex = exe.get_executor(a, nnz_per_step=32, rows_per_window=16,
                              n_devices=d, reorder=strat)
        np.testing.assert_array_equal(np.asarray(ex.spmm(b)), ref,
                                      err_msg=f"{strat} x {d}")
print("REORDER SHARDED OK")
""" % (SRC,)


@pytest.mark.distributed
def test_sharded_reorder_round_trips_on_eight_devices():
    r = subprocess.run([sys.executable, "-c", SCRIPT_REORDER_SHARDED],
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, \
        f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    assert "REORDER SHARDED OK" in r.stdout
