"""The learned scheduling policy and the redesigned serving API surface:
``OnlineRidge`` convergence on synthetic linear service times, the
``LearnedServiceTimePolicy`` cold-start fallback to the heuristic EWMAs,
learned estimates flowing into shed/dueness/replication decisions, the
prediction-accuracy report, and the backward-compatible import paths of
the consolidated error/result types."""
import numpy as np
import pytest

from repro.serving.placement import SINGLE
from repro.serving.policy import (
    GraphState,
    HeuristicPolicy,
    LearnedServiceTimePolicy,
    OnlineRidge,
    PolicyState,
)


def G(gid="g", *, depth=0, ed=float("inf"), ewma=0.0, req_ewma=0.0,
      nnz=1_000_000, rows=1000):
    return GraphState(
        graph_id=gid, nnz=nnz, n_rows=rows, bytes=1 << 20, resident=True,
        kind=SINGLE, device_index=0, device_indices=(0,), queue_depth=depth,
        earliest_deadline=ed, svc_ewma=ewma, svc_req_ewma=req_ewma)


def S(graphs, *, now=1000.0):
    return PolicyState(
        now=now, n_devices=1, budget_bytes=64 << 20, used_bytes=(0,),
        outstanding_s=(0.0,), max_replicas=1, replicate_after_s=0.25,
        replica_shrink_after=3, max_batch=32,
        graphs={g.graph_id: g for g in graphs})


def _true_service(g, b):
    """Synthetic linear ground truth in the policy's feature basis."""
    return (0.003 + 0.001 * b + 0.010 * (g.nnz / 1e6)
            + 0.002 * b * (g.nnz / 1e6))


def _fit(pol, graphs, rng, n=200):
    for _ in range(n):
        g = graphs[int(rng.integers(0, len(graphs)))]
        b = int(rng.integers(1, 9))
        pol.observe_service(g.graph_id, b, _true_service(g, b), g)


# ---------------------------------------------------------------------------
# OnlineRidge
# ---------------------------------------------------------------------------

def test_ridge_recovers_linear_coefficients():
    rng = np.random.default_rng(0)
    theta_true = np.array([0.5, -1.25, 2.0])
    r = OnlineRidge(3, l2=1e-6)
    for _ in range(300):
        x = rng.normal(size=3)
        r.observe(x, float(x @ theta_true))
    np.testing.assert_allclose(r.theta, theta_true, atol=1e-6)
    x = rng.normal(size=3)
    assert r.predict(x) == pytest.approx(float(x @ theta_true), abs=1e-6)


def test_ridge_regularization_shrinks_toward_zero():
    r = OnlineRidge(2, l2=1e6)  # huge lambda: theta ~ 0 despite data
    for _ in range(50):
        r.observe(np.array([1.0, 2.0]), 10.0)
    assert np.all(np.abs(r.theta) < 0.1)
    assert r.n == 50


def test_ridge_theta_cache_invalidates_on_observe():
    r = OnlineRidge(1, l2=1e-8)
    r.observe(np.array([1.0]), 2.0)
    t1 = r.theta[0]
    r.observe(np.array([1.0]), 4.0)
    assert r.theta[0] != t1  # cached theta was refreshed


# ---------------------------------------------------------------------------
# LearnedServiceTimePolicy
# ---------------------------------------------------------------------------

def test_cold_start_falls_back_to_ewma():
    """Below min_samples the learned policy is the heuristic policy:
    every estimate comes from the EWMAs, decision-for-decision."""
    pol = LearnedServiceTimePolicy(min_samples=10)
    heur = HeuristicPolicy()
    g = G(depth=3, ewma=0.7, req_ewma=0.2)
    st = S([g])
    assert not pol.fitted
    assert pol._queue_est(st, g) == 0.7
    assert pol._req_est(st, g) == 0.2
    assert pol.predicted_wait(st, "g", 1001.0) == \
        heur.predicted_wait(st, "g", 1001.0)
    assert pol.shed_on_submit(st, "g", 1000.5).shed == \
        heur.shed_on_submit(st, "g", 1000.5).shed
    # 9 observations: still cold (min_samples=10)
    rng = np.random.default_rng(1)
    _fit(pol, [g], rng, n=9)
    assert not pol.fitted and pol._queue_est(st, g) == 0.7


def test_learned_estimates_converge_to_true_service_times():
    pol = LearnedServiceTimePolicy(min_samples=24)
    rng = np.random.default_rng(2)
    graphs = [G("a", nnz=500_000, rows=500), G("b", nnz=4_000_000, rows=4000)]
    _fit(pol, graphs, rng, n=300)
    assert pol.fitted
    for g0 in graphs:
        for depth in (1, 4, 8):
            g = G(g0.graph_id, depth=depth, nnz=g0.nnz, rows=g0.n_rows,
                  ewma=99.0, req_ewma=99.0)  # EWMAs are wildly wrong
            want = _true_service(g, depth)
            assert pol._queue_est(S([g]), g) == pytest.approx(want, rel=1e-4)
            assert pol._req_est(S([g]), g) == \
                pytest.approx(want / depth, rel=1e-4)
    rep = pol.prediction_report()
    assert rep["fitted"] and rep["n_samples"] == 300
    assert rep["n_scored"] == 300 - 24
    assert rep["mean_abs_rel_err"] < 0.05


def test_learned_model_generalizes_across_graphs():
    """A freshly admitted graph it never observed gets a sensible
    estimate from the shared nnz/rows features."""
    pol = LearnedServiceTimePolicy(min_samples=24)
    rng = np.random.default_rng(3)
    _fit(pol, [G("a", nnz=500_000, rows=500),
               G("b", nnz=4_000_000, rows=4000)], rng, n=300)
    fresh = G("new", depth=2, nnz=2_000_000, rows=2000, ewma=99.0)
    assert pol._queue_est(S([fresh]), fresh) == \
        pytest.approx(_true_service(fresh, 2), rel=1e-3)


def test_learned_estimate_drives_shed_decision():
    """EWMA says the deadline is fine; the fitted model knows better —
    the decision follows the model (and vice versa)."""
    pol = LearnedServiceTimePolicy(min_samples=24)
    rng = np.random.default_rng(4)
    big = G("big", nnz=8_000_000, rows=8000)
    _fit(pol, [big], rng, n=100)
    true_t = _true_service(big, 1)  # ~0.1 s
    g = G("big", depth=1, nnz=big.nnz, rows=big.n_rows, ewma=1e-6)
    st = S([g])
    # heuristic (EWMA ~ 0) would accept this deadline; learned sheds
    dl = st.now + true_t / 2
    assert not HeuristicPolicy().shed_on_submit(st, "big", dl).shed
    assert pol.shed_on_submit(st, "big", dl).shed
    assert not pol.shed_on_submit(st, "big", st.now + 2 * true_t).shed


def test_nonpositive_prediction_falls_back_and_counts():
    pol = LearnedServiceTimePolicy(min_samples=2)
    g = G(ewma=0.3, req_ewma=0.1)
    # two observations of a *negative* target drive predictions negative
    for _ in range(2):
        pol.observe_service("g", 1, -1.0, g)
    assert pol.fitted
    assert pol._queue_est(S([g]), g) == 0.3  # fell back to the EWMA
    assert pol.prediction_report()["fallbacks"] == 1


def test_reset_errors_keeps_model_but_zeroes_accuracy_window():
    pol = LearnedServiceTimePolicy(min_samples=4)
    rng = np.random.default_rng(5)
    g = G()
    _fit(pol, [g], rng, n=50)
    assert pol.prediction_report()["n_scored"] > 0
    pol.reset_errors()
    rep = pol.prediction_report()
    assert rep["n_scored"] == 0 and rep["mean_abs_rel_err"] == 0.0
    assert rep["n_samples"] == 50 and pol.fitted  # the model survived


def test_min_samples_validation():
    with pytest.raises(ValueError, match="min_samples"):
        LearnedServiceTimePolicy(min_samples=0)


# ---------------------------------------------------------------------------
# API surface: consolidated types + backward-compatible import paths
# ---------------------------------------------------------------------------

def test_errors_share_common_base_and_stdlib_parents():
    from repro.serving.errors import (
        FlushError,
        RequestFailure,
        ServingError,
        UnknownGraphError,
    )
    assert issubclass(UnknownGraphError, ServingError)
    assert issubclass(UnknownGraphError, KeyError)
    assert issubclass(RequestFailure, ServingError)
    assert issubclass(RequestFailure, RuntimeError)
    assert issubclass(FlushError, ServingError)
    assert issubclass(FlushError, RuntimeError)
    e = UnknownGraphError("gid", "submit")
    assert e.graph_id == "gid" and e.op == "submit" and "gid" in str(e)


def test_submit_ticket_moved_to_types():
    from repro.serving.types import ACCEPTED, REJECTED, SHED, SubmitTicket
    t = SubmitTicket(3, ACCEPTED)
    assert t.accepted and bool(t) and t.rid == 3
    assert not SubmitTicket(None, REJECTED, "full").accepted
    assert not bool(SubmitTicket(None, SHED, "late"))


def test_old_gcn_engine_import_paths_still_resolve():
    jax = pytest.importorskip("jax")  # noqa: F841 — engine imports jax
    from repro.serving import errors, types
    from repro.serving import gcn_engine as ge

    assert ge.UnknownGraphError is errors.UnknownGraphError
    assert ge.RequestFailure is errors.RequestFailure
    assert ge.FlushError is errors.FlushError
    assert ge.ServingError is errors.ServingError
    assert ge.SubmitTicket is types.SubmitTicket
    assert (ge.ACCEPTED, ge.REJECTED, ge.SHED) == \
        (types.ACCEPTED, types.REJECTED, types.SHED)


def test_serving_package_public_api():
    import repro.serving as serving

    # pure exports resolve without jax
    assert serving.HeuristicPolicy is HeuristicPolicy
    assert serving.LearnedServiceTimePolicy is LearnedServiceTimePolicy
    from repro.serving.errors import ServingError
    from repro.serving.placement import MeshPlacer
    from repro.serving.types import SubmitTicket

    assert serving.ServingError is ServingError
    assert serving.MeshPlacer is MeshPlacer
    assert serving.SubmitTicket is SubmitTicket
    assert "GCNServingEngine" in dir(serving)
    with pytest.raises(AttributeError):
        serving.NoSuchThing


def test_transformer_serve_engine_moved_with_shim():
    pytest.importorskip("jax")
    from repro.models.transformer_serve import ServeEngine as new_path
    from repro.serving.engine import ServeEngine as old_path

    assert old_path is new_path


def test_engine_policy_constructor_seam():
    pytest.importorskip("jax")
    from repro.serving.gcn_engine import GCNServingEngine
    import tempfile

    root = tempfile.mkdtemp(prefix="awb-policy-seam-")
    eng = GCNServingEngine(store_root=root)
    assert isinstance(eng.policy, HeuristicPolicy)
    pol = LearnedServiceTimePolicy()
    eng2 = GCNServingEngine(store_root=root, policy=pol)
    assert eng2.policy is pol
