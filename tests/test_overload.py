"""Admission control under overload: typed submit tickets, bounded
queues (REJECTED at ``max_queue_depth``), deadline-aware shedding driven
by the EDF load map's predicted wait (clock-injected, so the shed-iff
predicate is asserted exactly), the overload accounting identity
``submitted == queue_served + shed + rejected + pending``, the unified
``UnknownGraphError`` across every serve path, and the backpressure
surface in ``stats()``."""
import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core import executor as exe, gcn  # noqa: E402
from repro.graphs import synth  # noqa: E402
from repro.serving.gcn_engine import (ACCEPTED, REJECTED,  # noqa: E402
                                      SHED, GCNServingEngine,
                                      SubmitTicket, UnknownGraphError)
from repro.tuning import registry  # noqa: E402

N_NODES = 220
N_FEATS = 20
N_CLASSES = 5

FAST_SWEEP = [
    dict(nnz_per_step=64, rows_per_window=32, cols_per_block=None,
         window_nnz=None, routing=exe.GATHER),
    dict(nnz_per_step=128, rows_per_window=64, cols_per_block=None,
         window_nnz=None, routing=exe.GATHER),
]
FAST_KW = dict(iters=1, warmup=1, sweep=FAST_SWEEP, bf16_report=False)


@pytest.fixture(autouse=True)
def _fresh_caches():
    registry.clear_caches()
    yield
    registry.clear_caches()


def _workload(seed):
    a = synth.power_law_adjacency(N_NODES, 0.03, 0.9, seed=seed)
    cfg = gcn.GCNConfig(N_FEATS, 16, N_CLASSES)
    params = gcn.init_params(cfg, jax.random.PRNGKey(seed))
    x = np.random.default_rng(seed).random((N_NODES, N_FEATS),
                                           ).astype(np.float32)
    return a, params, x


def _engine(root, **kw):
    kw.setdefault("autotune_kwargs", FAST_KW)
    return GCNServingEngine(store_root=root, **kw)


def _identity(eng):
    st = eng.stats()
    assert st["submitted"] == (st["queue_served"] + st["shed"]
                               + st["rejected"] + st["pending_requests"]), st
    return st


def test_submit_tickets_and_reject_at_max_queue_depth(tmp_path):
    a, params, x = _workload(0)
    eng = _engine(tmp_path, max_queue_depth=2)
    eng.add_graph("g", a, params)
    t1 = eng.submit("g", x)
    t2 = eng.submit("g", x * 0.5)
    assert isinstance(t1, SubmitTicket)
    assert t1.status == ACCEPTED and t1.accepted and bool(t1)
    assert t1.rid is not None and t2.rid == t1.rid + 1
    t3 = eng.submit("g", x)
    assert t3.status == REJECTED and not t3.accepted and not t3
    assert t3.rid is None and "max_queue_depth" in t3.reason
    st = _identity(eng)
    assert st["submitted"] == 3 and st["rejected"] == 1
    assert st["pending_requests"] == 2
    # the rejected request was never queued: the flush serves exactly two
    out = eng.flush()
    assert out["g"].shape == (2, N_NODES, N_CLASSES)
    st = _identity(eng)
    assert st["queue_served"] == 2 and st["pending_requests"] == 0


def test_ctor_validates_admission_knobs(tmp_path):
    with pytest.raises(ValueError, match="max_queue_depth"):
        _engine(tmp_path, max_queue_depth=0)
    with pytest.raises(ValueError, match="max_dispatch_retries"):
        _engine(tmp_path, max_dispatch_retries=-1)


def test_shed_iff_predicted_wait_exceeds_deadline(tmp_path):
    """Clock-injected shed predicate on an empty engine: with the
    service EWMA pinned to 1.0 s, a deadline below the predicted wait
    sheds and one above it is accepted — exactly at the EWMA boundary."""
    a, params, x = _workload(1)
    eng = _engine(tmp_path, shed_unmeetable=True)
    eng.add_graph("g", a, params)
    eng._svc_ewma["g"] = 1.0
    eng._svc_req_ewma["g"] = 1.0 / 8
    now = 1000.0
    t = eng.submit("g", x, deadline_s=0.5, now=now)
    assert t.status == SHED and not t and t.rid is None
    assert "predicted wait" in t.reason
    t = eng.submit("g", x, deadline_s=1.5, now=now)
    assert t.status == ACCEPTED
    # deadline-free requests are never shed, whatever the EWMA says
    assert eng.submit("g", x, now=now).status == ACCEPTED
    st = _identity(eng)
    assert st["shed"] == 1 and st["pending_requests"] == 2


def test_shed_accumulates_edf_ahead_queues(tmp_path):
    """The shed predicate absorbs co-located queues that dispatch ahead
    of the candidate (EDF order): a deadline one queue's EWMA could meet
    sheds when an earlier-deadline neighbour serializes in front of it —
    and the same deadline is accepted once that neighbour is gone."""
    g1, g2 = _workload(2), _workload(3)
    eng = _engine(tmp_path, shed_unmeetable=True)
    eng.add_graph("g1", g1[0], g1[1])
    eng.add_graph("g2", g2[0], g2[1])
    now = 1000.0
    # queue a g1 request first (EWMAs still unset, so nothing sheds yet),
    # then pin both EWMAs to 1.0 s
    assert eng.submit("g1", g1[2], deadline_s=0.5, now=now).accepted
    for gid in ("g1", "g2"):
        eng._svc_ewma[gid] = 1.0
        eng._svc_req_ewma[gid] = 1.0 / 8
    # g2 deadline 1.5 s: g1's earlier deadline dispatches ahead and the
    # single device serializes, so predicted wait is 2.0 s -> shed
    t = eng.submit("g2", g2[2], deadline_s=1.5, now=now)
    assert t.status == SHED
    # 2.5 s clears the accumulated wait -> accepted
    assert eng.submit("g2", g2[2], deadline_s=2.5, now=now).accepted
    # with g1's queue gone, the same 1.5 s deadline is meetable: only
    # g2's own estimate remains in front of it
    eng._pending.pop("g1")
    assert eng.submit("g2", g2[2], deadline_s=1.5, now=now).accepted
    assert eng.counters["shed"] == 1


def test_reject_takes_precedence_over_shed(tmp_path):
    """A full queue REJECTS before the shed predicate runs — the bounded
    queue is the engine-overloaded signal, shedding is the per-request
    SLA signal."""
    a, params, x = _workload(4)
    eng = _engine(tmp_path, max_queue_depth=1, shed_unmeetable=True)
    eng.add_graph("g", a, params)
    eng._svc_ewma["g"] = 1.0
    now = 1000.0
    assert eng.submit("g", x, deadline_s=10.0, now=now).accepted
    t = eng.submit("g", x, deadline_s=0.1, now=now)
    assert t.status == REJECTED
    assert eng.counters["rejected"] == 1 and eng.counters["shed"] == 0


def test_dispatch_time_shed_on_stale_queue(tmp_path):
    """A request accepted in time can still become unmeetable while
    queued; the dispatcher sheds it at the last gate instead of burning
    device time on a guaranteed miss."""
    a, params, x = _workload(5)
    eng = _engine(tmp_path, shed_unmeetable=True)
    eng.add_graph("g", a, params)
    now = 1000.0
    assert eng.submit("g", x, deadline_s=0.05, now=now).accepted
    out = eng.poll(now=now + 0.2)   # deadline already passed
    assert out == {}
    st = _identity(eng)
    assert st["shed"] == 1 and st["pending_requests"] == 0
    assert st["queue_served"] == 0 and st["batches"] == 0


def test_overload_accounting_identity_mixed_outcomes(tmp_path):
    """One run mixing every admission outcome: accepted+served,
    rejected at the bound, shed at dispatch — the identity holds at
    every step and at the end."""
    g1, g2 = _workload(6), _workload(7)
    eng = _engine(tmp_path, max_queue_depth=2, shed_unmeetable=True)
    eng.add_graph("g1", g1[0], g1[1])
    eng.add_graph("g2", g2[0], g2[1])
    now = 1000.0
    assert eng.submit("g1", g1[2], deadline_s=50.0, now=now).accepted
    assert eng.submit("g1", g1[2] * 0.5, deadline_s=50.0, now=now).accepted
    assert eng.submit("g1", g1[2], deadline_s=50.0, now=now).status \
        == REJECTED
    assert eng.submit("g2", g2[2], deadline_s=0.01, now=now).accepted
    _identity(eng)
    # only g2 is due at now+0.5 — and its deadline has passed: shed
    out = eng.poll(now=now + 0.5)
    assert out == {}
    st = _identity(eng)
    assert st["shed"] == 1 and st["rejected"] == 1
    assert st["pending_requests"] == 2
    # serve the survivors (real clock from here on; their deadlines are
    # pinned-clock absolutes, so disable shedding for the drain)
    eng.shed_unmeetable = False
    out = eng.flush()
    assert out["g1"].shape == (2, N_NODES, N_CLASSES)
    st = _identity(eng)
    assert st["submitted"] == 4 and st["queue_served"] == 2
    assert st["pending_requests"] == 0


def test_threshold_autoflush_counts_queue_served(tmp_path):
    a, params, x = _workload(8)
    eng = _engine(tmp_path, max_batch=2)
    eng.add_graph("g", a, params)
    assert eng.submit("g", x).accepted
    t = eng.submit("g", x * 0.5)     # reaches max_batch: auto-flush
    assert t.accepted
    st = _identity(eng)
    assert st["queue_served"] == 2 and st["pending_requests"] == 0
    out = eng.poll()                 # picks up the auto-flushed batch
    assert out["g"].shape == (2, N_NODES, N_CLASSES)


def test_unknown_graph_error_unified_across_paths(tmp_path):
    eng = _engine(tmp_path)
    x = np.zeros((4, 4), np.float32)
    for op, call in [
        ("submit", lambda: eng.submit("nope", x)),
        ("serve", lambda: eng.serve_batch("nope", [x])),
        ("serve", lambda: eng.infer("nope", x)),
        ("remove_graph", lambda: eng.remove_graph("nope")),
    ]:
        with pytest.raises(UnknownGraphError) as ei:
            call()
        assert isinstance(ei.value, KeyError)   # backward compatible
        assert ei.value.graph_id == "nope" and ei.value.op == op
        assert "nope" in str(ei.value)


def test_stats_backpressure_surface(tmp_path):
    a, params, x = _workload(9)
    eng = _engine(tmp_path)
    eng.add_graph("g", a, params)
    eng.submit("g", x)
    eng._svc_ewma["g"] = 0.5
    st = eng.stats()
    assert st["queue_depth"] == {"g": 1}
    # queued backlog shows up as device saturation seconds
    assert st["saturation_s"][0] == pytest.approx(0.5)
    assert all("saturation_s" in row for row in st["per_device"])
    assert st["latency_us_p50"] == 0.0 and st["latency_n"] == 0
    eng.flush()
    for _ in range(3):
        eng.submit("g", x)
    eng.flush()
    st = eng.stats()
    assert st["queue_depth"] == {} and st["saturation_s"][0] < 0.5
    assert st["latency_n"] == 4
    assert 0.0 < st["latency_us_p50"] <= st["latency_us_p95"] \
        <= st["latency_us_p99"]
    _identity(eng)


def test_reset_stats_clears_latency_reservoir(tmp_path):
    a, params, x = _workload(10)
    eng = _engine(tmp_path)
    eng.add_graph("g", a, params)
    eng.submit("g", x)
    eng.flush()
    assert eng.stats()["latency_us_p50"] > 0.0
    eng.reset_stats()
    st = eng.stats()
    assert st["latency_us_p50"] == 0.0 and st["latency_n"] == 0
    assert st["submitted"] == 0
    _identity(eng)
