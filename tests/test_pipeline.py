"""Pipeline parallelism: GPipe schedule over a host-device axis equals the
sequential stack, and the bubble model is sane."""
import subprocess
import sys
from pathlib import Path

import pytest

from repro.sharding.pipeline import bubble_fraction

SRC = str(Path(__file__).resolve().parents[1] / "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, %r)
import numpy as np, jax, jax.numpy as jnp
from repro.sharding.pipeline import pipeline_apply

mesh = jax.make_mesh((4, 2), ("stage", "data"))
S, D = 4, 16
rng = np.random.default_rng(0)
ws = jnp.asarray(rng.standard_normal((S, D, D)).astype(np.float32) * 0.3)
bs = jnp.asarray(rng.standard_normal((S, D)).astype(np.float32) * 0.1)
x = jnp.asarray(rng.standard_normal((8, D)).astype(np.float32))

def stage_fn(p, h):
    w, b = p
    return jnp.tanh(h @ w + b)

out = pipeline_apply(stage_fn, (ws, bs), x, mesh=mesh, axis="stage",
                     n_micro=4)
ref = x
for s in range(S):
    ref = jnp.tanh(ref @ ws[s] + bs[s])
err = float(jnp.abs(out - ref).max())
print("ERR", err)
assert err < 1e-5, err
print("OK")
""" % (SRC,)


@pytest.mark.slow
def test_pipeline_matches_sequential():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=600)
    assert r.returncode == 0, f"{r.stdout}\n{r.stderr[-2000:]}"
    assert "OK" in r.stdout


def test_bubble_fraction():
    assert bubble_fraction(4, 4) == pytest.approx(3 / 7)
    assert bubble_fraction(2, 30) == pytest.approx(1 / 31)
    assert bubble_fraction(1, 8) == 0.0
