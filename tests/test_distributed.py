"""Distributed integration: run sharded programs on 8 host devices in a
subprocess (the unit-test process stays single-device) and compare with the
single-device reference."""
import subprocess
import sys
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")

SCRIPT_SHARDED_GCN = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, %r)
import numpy as np, jax, jax.numpy as jnp
from repro.core import gcn, schedule, spmm
from repro.graphs import synth
from repro.launch import steps
from repro.launch.mesh import make_local_mesh

mesh = make_local_mesh(model_axis=2)  # 4 data x 2 model
ds = synth.make_dataset("cora", scale=8)
s = schedule.build_balanced_schedule(ds.adj, 32, 16)
n_steps_padded = -(-s.n_steps // 4) * 4
feat_pad = -(-ds.num_features // 2) * 2
hid = 16
fn, specs = steps.make_gcn_step(mesh, ds.num_nodes, ds.num_features, hid,
                                ds.num_classes, s.n_steps, 32, 16)
# build real inputs padded to the spec shapes
rng = np.random.default_rng(0)
x = np.zeros(specs[0].shape, np.float32); x[:, :ds.num_features] = ds.features
w1 = rng.standard_normal(specs[1].shape).astype(np.float32)
w2 = rng.standard_normal(specs[2].shape).astype(np.float32)
def padded(a, shape, dtype):
    out = np.zeros(shape, dtype)
    sl = tuple(slice(0, d) for d in a.shape)
    out[sl] = a
    return out
val = padded(s.val.reshape(s.n_steps, -1), specs[3].shape, np.float32)
lrow = padded(s.local_row.reshape(s.n_steps, -1), specs[4].shape, np.int32)
# lcol in the sharded step is GLOBAL column id (cols_per_block == n)
lcol = padded(s.local_col.reshape(s.n_steps, -1), specs[5].shape, np.int32)
win = padded(s.win_id, specs[6].shape, np.int32)
# padded steps must write to a harmless window slot: keep win=0,val=0 ✓
cblk = padded(s.col_block, specs[7].shape, np.int32)
rmap = np.full(specs[8].shape, -1, np.int32)
rmap[:s.row_map.shape[0]] = s.row_map
out = np.asarray(fn(x, w1, w2, val, lrow, lcol, win, cblk, rmap))

# single-device reference
ref_h = np.maximum(np.asarray(spmm.spmm_coo(ds.adj, jnp.asarray(x @ w1))), 0)
# sharded fn applies relu between layers; second spmm on relu(h)
ref = np.asarray(spmm.spmm_coo(ds.adj, jnp.asarray(ref_h @ w2)))
err = np.abs(out - ref).max()
print("MAXERR", err)
assert err < 1e-3, err
print("OK devices", len(jax.devices()))
""" % (SRC,)

SCRIPT_SHARDED_TRAIN = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, %r)
import numpy as np, jax, jax.numpy as jnp
from repro import configs
from repro.launch import steps
from repro.launch.mesh import make_local_mesh
from repro.models import transformer as tr
from repro.training import optimizer as opt_mod

mesh = make_local_mesh(model_axis=2)
cfg = configs.get_reduced_config("qwen2-0.5b")
pipe_batch = {"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32),
              "labels": jax.ShapeDtypeStruct((8, 16), jnp.int32)}
fn, (pspecs, ospecs) = steps.make_train_step(cfg, mesh, pipe_batch)
key = jax.random.PRNGKey(0)
pf32 = tr.init_params(cfg, key)
params = jax.tree.map(lambda x: x.astype(jnp.bfloat16), pf32)
opt = opt_mod.adamw_init(params)
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)), jnp.int32),
         "labels": jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)), jnp.int32)}
l0 = None
for i in range(6):
    params, opt, metrics = fn(params, opt, batch)
    if l0 is None: l0 = float(metrics["loss"])
l1 = float(metrics["loss"])
print("LOSS", l0, "->", l1)
assert l1 < l0, (l0, l1)
print("OK devices", len(jax.devices()))
""" % (SRC,)


def _run(script: str) -> str:
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    return r.stdout


@pytest.mark.slow
def test_sharded_gcn_step_matches_reference():
    out = _run(SCRIPT_SHARDED_GCN)
    assert "OK devices 8" in out


@pytest.mark.slow
def test_sharded_train_step_runs_and_learns():
    out = _run(SCRIPT_SHARDED_TRAIN)
    assert "OK devices 8" in out
