"""Shared per-module AST model the passes build their checks on.

One ``ModuleInfo`` per analyzed file: the parse tree with parent links,
per-line comments (``tokenize`` — annotations like ``guarded-by:`` live
in comments, which ``ast`` drops), the import alias table, an index of
every function/method by qualified name, and the module-level globals
classified mutable or not.
"""

from __future__ import annotations

import ast
import dataclasses
import io
import tokenize
from typing import Dict, List, Optional

#: module-level bindings treated as mutable shared state when read from
#: jit-reachable code: container literals/comprehensions and calls to
#: the stdlib container constructors. Class/function aliases and scalar
#: constants stay out — reading those is not a tracing hazard.
_CONTAINER_CTORS = {
    "dict",
    "list",
    "set",
    "OrderedDict",
    "defaultdict",
    "deque",
    "Counter",
}

FuncNode = (ast.FunctionDef, ast.AsyncFunctionDef)


@dataclasses.dataclass
class FuncInfo:
    qualname: str
    node: ast.AST  # FunctionDef / AsyncFunctionDef / Lambda
    class_name: Optional[str]  # enclosing class, if a method


class ModuleInfo:
    def __init__(self, path: str, source: str):
        self.path = path
        self.source = source
        self.tree = ast.parse(source, filename=path)
        self.comments = self._scan_comments(source)
        self.parents: Dict[ast.AST, ast.AST] = {}
        for node in ast.walk(self.tree):
            for child in ast.iter_child_nodes(node):
                self.parents[child] = node
        self.import_aliases = self._scan_imports()
        self.functions: Dict[str, FuncInfo] = {}
        self.methods_by_name: Dict[str, List[FuncInfo]] = {}
        self._index_functions()
        self.mutable_globals = self._scan_mutable_globals()

    # ---- construction helpers ------------------------------------------

    @staticmethod
    def _scan_comments(source: str) -> Dict[int, str]:
        comments: Dict[int, str] = {}
        try:
            tokens = tokenize.generate_tokens(io.StringIO(source).readline)
            for tok in tokens:
                if tok.type == tokenize.COMMENT:
                    comments[tok.start[0]] = tok.string
        except tokenize.TokenError:  # pragma: no cover - ast.parse catches first
            pass
        return comments

    def _scan_imports(self) -> Dict[str, str]:
        """alias -> dotted module/name it binds (``np`` -> ``numpy``)."""
        aliases: Dict[str, str] = {}
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    aliases[a.asname or a.name.split(".")[0]] = a.name
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    aliases[a.asname or a.name] = f"{node.module}.{a.name}"
        return aliases

    def _index_functions(self) -> None:
        def visit(node: ast.AST, scope: List[str], class_name: Optional[str]):
            for child in ast.iter_child_nodes(node):
                if isinstance(child, FuncNode):
                    qual = ".".join(scope + [child.name])
                    if qual in self.functions:  # same-named siblings
                        qual = f"{qual}@{child.lineno}"
                    info = FuncInfo(qual, child, class_name)
                    self.functions[qual] = info
                    self.methods_by_name.setdefault(child.name, []).append(info)
                    visit(child, scope + [child.name], class_name)
                elif isinstance(child, ast.ClassDef):
                    visit(child, scope + [child.name], child.name)
                else:
                    visit(child, scope, class_name)

        visit(self.tree, [], None)

    def _scan_mutable_globals(self) -> set:
        mutable = set()
        for node in self.tree.body:
            if isinstance(node, ast.AnnAssign) and node.value is not None:
                targets = [node.target]
                value = node.value
            elif isinstance(node, ast.Assign):
                targets = node.targets
                value = node.value
            else:
                continue
            if not self._is_mutable_value(value):
                continue
            for t in targets:
                if isinstance(t, ast.Name):
                    mutable.add(t.id)
        return mutable

    @staticmethod
    def _is_mutable_value(value: ast.AST) -> bool:
        if isinstance(
            value, (ast.Dict, ast.List, ast.Set, ast.DictComp, ast.ListComp, ast.SetComp)
        ):
            return True
        if isinstance(value, ast.Call):
            fn = value.func
            name = fn.id if isinstance(fn, ast.Name) else getattr(fn, "attr", None)
            return name in _CONTAINER_CTORS
        return False

    # ---- queries --------------------------------------------------------

    def qualname_of(self, node: ast.AST) -> str:
        """Qualified name of the innermost def/class enclosing ``node``
        (``<module>`` at top level) — the waiver-matching symbol."""
        parts: List[str] = []
        cur: Optional[ast.AST] = node
        while cur is not None:
            if isinstance(cur, FuncNode + (ast.ClassDef,)):
                parts.append(cur.name)
            cur = self.parents.get(cur)
        return ".".join(reversed(parts)) or "<module>"

    def enclosing_function(self, node: ast.AST) -> Optional[ast.AST]:
        cur = self.parents.get(node)
        while cur is not None:
            if isinstance(cur, FuncNode):
                return cur
            cur = self.parents.get(cur)
        return None

    def comment_on(self, line: int) -> str:
        return self.comments.get(line, "")

    def resolves_to(self, node: ast.AST, dotted: str) -> bool:
        """Does ``node`` (Name/Attribute chain) denote ``dotted`` under
        this module's import aliases? ``jax.jit`` matches ``jax.jit``
        itself and any ``from jax import jit`` / ``import jax as j``
        spelling."""
        parts: List[str] = []
        cur = node
        while isinstance(cur, ast.Attribute):
            parts.append(cur.attr)
            cur = cur.value
        if not isinstance(cur, ast.Name):
            return False
        root = self.import_aliases.get(cur.id, cur.id)
        full = ".".join([root] + list(reversed(parts)))
        return full == dotted


def parse_module(path: str) -> ModuleInfo:
    with open(path, "r", encoding="utf-8") as fh:
        return ModuleInfo(path, fh.read())
