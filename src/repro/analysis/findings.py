"""Finding/waiver model and the TOML waiver file.

A waiver matches on ``(rule, path, symbol)`` — line numbers drift with
every edit, the enclosing symbol does not. Every waiver must carry a
one-line ``reason``; an entry that matches nothing is reported as stale
(warning, not failure) so the file stays honest.
"""

from __future__ import annotations

import dataclasses
from pathlib import PurePosixPath
from typing import Iterable, List, Sequence, Tuple

try:  # py3.11+
    import tomllib
except ModuleNotFoundError:  # pragma: no cover - py3.10 fallback
    import tomli as tomllib


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str  # posix-style, as passed on the command line
    line: int
    symbol: str  # enclosing qualified name, e.g. "Engine._admit"
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} [{self.symbol}] {self.message}"


@dataclasses.dataclass(frozen=True)
class Waiver:
    rule: str
    path: str
    symbol: str
    reason: str

    def matches(self, finding: Finding) -> bool:
        if self.rule != finding.rule or self.symbol != finding.symbol:
            return False
        # suffix match so waivers written repo-relative keep matching
        # when the analyzer is invoked from elsewhere with longer paths
        fp = PurePosixPath(finding.path.replace("\\", "/"))
        wp = PurePosixPath(self.path)
        return fp == wp or fp.as_posix().endswith("/" + wp.as_posix())


def load_waivers(path) -> List[Waiver]:
    with open(path, "rb") as fh:
        data = tomllib.load(fh)
    waivers = []
    for i, entry in enumerate(data.get("waiver", [])):
        missing = {"rule", "path", "symbol", "reason"} - set(entry)
        if missing:
            raise ValueError(
                f"waiver #{i + 1} in {path} is missing {sorted(missing)}"
            )
        if not str(entry["reason"]).strip():
            raise ValueError(f"waiver #{i + 1} in {path} has an empty reason")
        waivers.append(
            Waiver(
                rule=str(entry["rule"]),
                path=str(entry["path"]),
                symbol=str(entry["symbol"]),
                reason=str(entry["reason"]),
            )
        )
    return waivers


def split_findings(
    findings: Iterable[Finding], waivers: Sequence[Waiver]
) -> Tuple[List[Finding], List[Finding], List[Waiver]]:
    """Partition into (unwaived, waived) and return the stale waivers."""
    unwaived, waived = [], []
    used = set()
    for f in findings:
        hit = next((w for w in waivers if w.matches(f)), None)
        if hit is None:
            unwaived.append(f)
        else:
            waived.append(f)
            used.add(id(hit))
    stale = [w for w in waivers if id(w) not in used]
    return unwaived, waived, stale
