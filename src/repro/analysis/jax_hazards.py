"""JAX tracing-hazard pass over jit-reachable function bodies.

Rules (all scoped to bodies ``callgraph.jit_reachable`` proves a jit
decoration site can reach):

* ``jax-np-call`` — a ``np.*`` / ``numpy.*`` call: silently materializes
  the tracer to host, breaking tracing or forcing a sync.
* ``jax-traced-branch`` — Python ``if``/``while`` on a *traced* value:
  raises ``TracerBoolConversionError`` at trace time (or worse, bakes
  one branch in).
* ``jax-host-sync`` — ``.item()`` / ``float()`` / ``int()`` / ``bool()``
  on a traced value: a device→host sync in the hot path.
* ``jax-mutable-global`` — reading a module-level mutable container
  inside a jit body: the value is baked in at trace time, later host
  mutations are invisible to the compiled function.

Taint (≈ "traced"): a root's parameters minus its ``static_argnames``
and ``self``/``cls``; for helpers reached through the call graph,
positional parameters only — keyword-only helper parameters are bound
statically via ``functools.partial`` throughout this codebase (Pallas
kernel bodies), and ``self.*`` attributes are Python state, not
tracers. Static metadata (``x.shape`` / ``.ndim`` / ``.dtype`` /
``.size``, ``len()``) drops taint; assignment propagates it;
reassignment from an untainted value clears it.
"""

from __future__ import annotations

import ast
from typing import List, Set

from repro.analysis.callgraph import JitRoot, jit_reachable
from repro.analysis.findings import Finding
from repro.analysis.modules import FuncNode, ModuleInfo

RULE_NP_CALL = "jax-np-call"
RULE_TRACED_BRANCH = "jax-traced-branch"
RULE_HOST_SYNC = "jax-host-sync"
RULE_MUTABLE_GLOBAL = "jax-mutable-global"

#: attribute accesses on a traced value that yield static Python data
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "at"}

#: builtins whose result is host data (drop taint) without being a sync
_TAINT_SINKS = {"len", "range", "isinstance", "type", "getattr", "hasattr"}

_HOST_CASTS = {"float", "int", "bool"}


def _numpy_aliases(module: ModuleInfo) -> Set[str]:
    return {
        alias
        for alias, dotted in module.import_aliases.items()
        if dotted == "numpy" or dotted.startswith("numpy.")
    }


class _BodyChecker:
    def __init__(self, module: ModuleInfo, root: JitRoot, np_aliases: Set[str]):
        self.module = module
        self.root = root
        self.np_aliases = np_aliases
        self.findings: List[Finding] = []
        self.tainted: Set[str] = set()
        args = root.func.node.args
        for a in list(args.posonlyargs) + list(args.args):
            if a.arg not in ("self", "cls"):
                self.tainted.add(a.arg)
        if root.is_root:
            # a root's keyword-only params are caller-supplied (traced
            # unless static_argnames says otherwise); a helper's are
            # partial-bound statics in this codebase's Pallas idiom
            for a in args.kwonlyargs:
                self.tainted.add(a.arg)
        self.tainted -= set(root.static_argnames)

    # ---- taint -----------------------------------------------------------

    def is_tainted(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Name):
            return node.id in self.tainted
        if isinstance(node, ast.Attribute):
            if node.attr in _STATIC_ATTRS:
                return False
            return self.is_tainted(node.value)
        if isinstance(node, ast.Subscript):
            return self.is_tainted(node.value)
        if isinstance(node, ast.BinOp):
            return self.is_tainted(node.left) or self.is_tainted(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.is_tainted(node.operand)
        if isinstance(node, ast.BoolOp):
            return any(self.is_tainted(v) for v in node.values)
        if isinstance(node, ast.Compare):
            return self.is_tainted(node.left) or any(
                self.is_tainted(c) for c in node.comparators
            )
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(self.is_tainted(e) for e in node.elts)
        if isinstance(node, ast.IfExp):
            return self.is_tainted(node.body) or self.is_tainted(node.orelse)
        if isinstance(node, ast.Starred):
            return self.is_tainted(node.value)
        if isinstance(node, ast.Call):
            fn = node.func
            if isinstance(fn, ast.Name) and fn.id in _TAINT_SINKS | _HOST_CASTS:
                return False
            return any(self.is_tainted(a) for a in node.args) or any(
                self.is_tainted(k.value) for k in node.keywords
            )
        return False

    def _assign(self, target: ast.AST, tainted: bool) -> None:
        if isinstance(target, ast.Name):
            if tainted:
                self.tainted.add(target.id)
            else:
                self.tainted.discard(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for e in target.elts:
                self._assign(e, tainted)
        elif isinstance(target, ast.Starred):
            self._assign(target.value, tainted)

    # ---- walk ------------------------------------------------------------

    def run(self) -> List[Finding]:
        node = self.root.func.node
        body = node.body if isinstance(node.body, list) else [node.body]
        self._block(body)
        return self.findings

    def _block(self, stmts) -> None:
        for stmt in stmts:
            self._stmt(stmt)

    def _stmt(self, stmt: ast.AST) -> None:
        if isinstance(stmt, FuncNode + (ast.ClassDef,)):
            return  # nested defs analyzed via their own reachability
        if isinstance(stmt, ast.Assign):
            self._expr(stmt.value)
            tainted = self.is_tainted(stmt.value)
            for t in stmt.targets:
                self._assign(t, tainted)
            return
        if isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            self._expr(stmt.value)
            self._assign(stmt.target, self.is_tainted(stmt.value))
            return
        if isinstance(stmt, ast.AugAssign):
            self._expr(stmt.value)
            if self.is_tainted(stmt.value):
                self._assign(stmt.target, True)
            return
        if isinstance(stmt, (ast.If, ast.While)):
            self._expr(stmt.test)
            if self.is_tainted(stmt.test):
                kind = "if" if isinstance(stmt, ast.If) else "while"
                self._report(
                    RULE_TRACED_BRANCH,
                    stmt,
                    f"Python `{kind}` on traced value "
                    f"`{ast.unparse(stmt.test)}` — use jnp.where / lax.cond",
                )
            self._block(stmt.body)
            self._block(stmt.orelse)
            return
        if isinstance(stmt, ast.For):
            self._expr(stmt.iter)
            self._assign(stmt.target, self.is_tainted(stmt.iter))
            self._block(stmt.body)
            self._block(stmt.orelse)
            return
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                self._expr(item.context_expr)
            self._block(stmt.body)
            return
        if isinstance(stmt, ast.Try):
            self._block(stmt.body)
            for h in stmt.handlers:
                self._block(h.body)
            self._block(stmt.orelse)
            self._block(stmt.finalbody)
            return
        # everything else: scan contained expressions
        for node in ast.walk(stmt):
            if isinstance(node, (ast.Call, ast.Name)):
                self._expr_node(node)

    def _expr(self, expr: ast.AST) -> None:
        for node in ast.walk(expr):
            self._expr_node(node)

    def _expr_node(self, node: ast.AST) -> None:
        if isinstance(node, ast.Call):
            self._check_call(node)
        elif isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
            if node.id in self.module.mutable_globals:
                self._report(
                    RULE_MUTABLE_GLOBAL,
                    node,
                    f"reads mutable module global `{node.id}` inside a "
                    "jit-reachable body — the traced value is frozen at "
                    "compile time",
                )

    def _check_call(self, call: ast.Call) -> None:
        fn = call.func
        # np.* call
        if isinstance(fn, ast.Attribute):
            root = fn
            while isinstance(root.value, ast.Attribute):
                root = root.value
            if (
                isinstance(root.value, ast.Name)
                and root.value.id in self.np_aliases
            ):
                self._report(
                    RULE_NP_CALL,
                    call,
                    f"`{ast.unparse(fn)}(...)` in a jit-reachable body — "
                    "use jnp / lax equivalents",
                )
            if fn.attr == "item" and self.is_tainted(fn.value):
                self._report(
                    RULE_HOST_SYNC,
                    call,
                    f"`{ast.unparse(fn.value)}.item()` forces a device→host "
                    "sync under trace",
                )
        elif isinstance(fn, ast.Name) and fn.id in _HOST_CASTS and call.args:
            if self.is_tainted(call.args[0]):
                self._report(
                    RULE_HOST_SYNC,
                    call,
                    f"`{fn.id}({ast.unparse(call.args[0])})` concretizes a "
                    "traced value (host sync / TracerConversionError)",
                )

    def _report(self, rule: str, node: ast.AST, message: str) -> None:
        self.findings.append(
            Finding(
                rule=rule,
                path=self.module.path,
                line=getattr(node, "lineno", 0),
                symbol=self.root.func.qualname,
                message=message,
            )
        )


def check_module(module: ModuleInfo) -> List[Finding]:
    np_aliases = _numpy_aliases(module)
    findings: List[Finding] = []
    for root in jit_reachable(module).values():
        findings.extend(_BodyChecker(module, root, np_aliases).run())
    return findings
