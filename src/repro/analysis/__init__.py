"""repro-lint: AST-based invariant checks for the AWB-GCN reproduction.

The passes machine-check the invariants the codebase lives by (DESIGN.md
§14): no host syncs or tracer-dependent Python control flow inside
``@jax.jit``-reachable bodies, ``# guarded-by:`` lock discipline on the
serving engine's swap-protected state, a fixed lock-acquisition order,
and counters settled only through annotated settlement helpers or
``finally`` blocks. Findings gate CI against ``waivers.toml``.

Pure stdlib on purpose: the CI lint job runs without jax/numpy.

    python -m repro.analysis src benchmarks
"""

from repro.analysis.driver import run_analysis, self_check
from repro.analysis.findings import Finding, Waiver, load_waivers

__all__ = ["Finding", "Waiver", "load_waivers", "run_analysis", "self_check"]
