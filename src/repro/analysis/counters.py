"""Counter-settlement pass: ``stats()`` counters move exactly once.

Every mutation of a ``.counters[...]`` entry (or reassignment of the
whole ``.counters`` dict) must happen inside a ``finally:`` block or
inside a function annotated as a settlement helper — a comment on, or
directly above, its ``def`` line:

    # counter-settlement: <names or *>
    def _count(self, key, n=1): ...

The exactly-once discipline PRs 5–6 enforce by hand: served-work
counters settle only when completion is proven, failure counters settle
on the failure path — routing every bump through an annotated helper
(or a ``finally``) makes an ad-hoc ``self.counters["x"] += 1`` in new
code a lint failure instead of a review catch.
"""

from __future__ import annotations

import ast
import re
from typing import List, Optional, Tuple

from repro.analysis.findings import Finding
from repro.analysis.modules import FuncNode, ModuleInfo

RULE_SETTLEMENT = "counter-settlement"

_SETTLEMENT_RE = re.compile(r"counter-settlement(?::\s*(.*))?")


def _settlement_annotation(module: ModuleInfo, func: ast.AST) -> Optional[str]:
    """The annotation's name list, if the def (or the line above it,
    skipping decorators) carries one."""
    first = min(
        [func.lineno] + [d.lineno for d in getattr(func, "decorator_list", [])]
    )
    for line in (func.lineno, first, first - 1):
        m = _SETTLEMENT_RE.search(module.comment_on(line))
        if m:
            return (m.group(1) or "*").strip() or "*"
    return None


def _counter_target(node: ast.AST) -> Optional[Tuple[ast.AST, Optional[str]]]:
    """(node, key) when ``node`` is a ``<recv>.counters[...]`` subscript
    target; key is the literal string index when there is one."""
    if (
        isinstance(node, ast.Subscript)
        and isinstance(node.value, ast.Attribute)
        and node.value.attr == "counters"
    ):
        key = None
        if isinstance(node.slice, ast.Constant) and isinstance(node.slice.value, str):
            key = node.slice.value
        return node, key
    return None


def _in_finally(module: ModuleInfo, node: ast.AST) -> bool:
    cur = node
    parent = module.parents.get(cur)
    while parent is not None:
        if isinstance(parent, ast.Try) and any(
            cur is s or _contains(s, cur) for s in parent.finalbody
        ):
            return True
        cur, parent = parent, module.parents.get(parent)
    return False


def _contains(root: ast.AST, node: ast.AST) -> bool:
    return any(n is node for n in ast.walk(root))


def check_module(module: ModuleInfo) -> List[Finding]:
    findings: List[Finding] = []
    for node in ast.walk(module.tree):
        mutated: Optional[Tuple[ast.AST, Optional[str]]] = None
        if isinstance(node, ast.AugAssign):
            mutated = _counter_target(node.target)
        elif isinstance(node, ast.Assign):
            for t in node.targets:
                mutated = mutated or _counter_target(t)
                # whole-dict replacement also settles every counter
                if (
                    isinstance(t, ast.Attribute)
                    and t.attr == "counters"
                    and not isinstance(node.value, ast.Name)
                ):
                    mutated = mutated or (t, None)
        if mutated is None:
            continue
        target_node, key = mutated
        func = module.enclosing_function(node)
        if func is not None and func.name == "__init__":
            continue  # construction defines the counters, nothing settles
        if func is not None:
            names = _settlement_annotation(module, func)
            if names is not None:
                allowed = {n.strip() for n in names.split(",")}
                if "*" in allowed or key is None or key in allowed:
                    continue
        if _in_finally(module, node):
            continue
        what = f'counters[{key!r}]' if key is not None else "counters"
        findings.append(
            Finding(
                rule=RULE_SETTLEMENT,
                path=module.path,
                line=node.lineno,
                symbol=module.qualname_of(node),
                message=(
                    f"mutation of {what} outside a settlement helper or "
                    "`finally` block — annotate the helper with "
                    "`# counter-settlement: <names>` or route through one"
                ),
            )
        )
    return findings
