"""CLI driver: ``python -m repro.analysis [paths...]``.

Exit status 0 = no unwaived findings (and, with ``--self-check``, every
fixture still triggers exactly its stated rules); 1 otherwise. The CI
lint job runs both modes (DESIGN.md §14)."""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.analysis.driver import (
    ALL_RULES,
    render_report,
    run_analysis,
    self_check,
)

_DEFAULT_WAIVERS = Path(__file__).with_name("waivers.toml")
_DEFAULT_FIXTURES = Path("tests") / "fixtures" / "analysis"


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="AST-based JAX-hazard, lock-discipline and "
        "counter-settlement checks (DESIGN.md §14)",
    )
    parser.add_argument(
        "paths",
        nargs="*",
        default=["src", "benchmarks"],
        help="files or directories to analyze (default: src benchmarks)",
    )
    parser.add_argument(
        "--waivers",
        default=str(_DEFAULT_WAIVERS),
        help="waiver TOML (default: the committed analysis/waivers.toml)",
    )
    parser.add_argument(
        "--no-waivers",
        action="store_true",
        help="report every finding, waived or not",
    )
    parser.add_argument(
        "--self-check",
        action="store_true",
        help="verify every fixture still triggers exactly its stated rules",
    )
    parser.add_argument(
        "--fixtures",
        default=str(_DEFAULT_FIXTURES),
        help="fixture directory for --self-check",
    )
    parser.add_argument("--json", action="store_true", help="machine output")
    parser.add_argument(
        "--list-rules", action="store_true", help="print rule ids and exit"
    )
    parser.add_argument(
        "-v", "--verbose", action="store_true", help="also print waived findings"
    )
    args = parser.parse_args(argv)

    if args.list_rules:
        for rule in ALL_RULES:
            print(rule)
        return 0

    if args.self_check:
        problems = self_check(args.fixtures)
        for p in problems:
            print(p)
        if not problems:
            print("self-check: every fixture triggers exactly its stated rules")
        return 1 if problems else 0

    waivers_path = None if args.no_waivers else args.waivers
    report = run_analysis(args.paths, waivers_path)
    if args.json:
        print(
            json.dumps(
                {
                    "unwaived": [f.__dict__ for f in report.unwaived],
                    "waived": [f.__dict__ for f in report.waived],
                    "stale_waivers": [w.__dict__ for w in report.stale_waivers],
                    "errors": report.errors,
                },
                indent=2,
            )
        )
    else:
        for line in render_report(report, verbose=args.verbose):
            print(line)
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
