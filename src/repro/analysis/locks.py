"""Lock-discipline pass: ``guarded-by:`` annotations + acquisition order.

Annotation convention (DESIGN.md §14): a trailing comment on the line
that declares a field —

    executor: Optional[object] = None  # guarded-by: _swap_lock
    self._persist_thread = None  # guarded-by: _persist_spawn_lock

``lock-guard`` flags every read or write of an annotated attribute that
is not lexically inside ``with <recv>.<lock>:``. Receivers are resolved
by *type annotation*, the one piece of typing this codebase applies
consistently: a parameter annotated with the guarded class, a variable
assigned from a container attribute whose annotation names it, a
``for``-target iterating such a container's ``.values()`` / ``.items()``,
or the result of a helper return-annotated with the class. Objects
assigned straight from the class constructor are exempt — they are
thread-local until published. ``self.<field>`` accesses are checked when
the field was annotated on a ``self.`` assignment (outside
``__init__``, where the object is still under construction).

``lock-order`` derives the canonical order from the order the locks are
created in (``self.X = threading.Lock()`` source order) and flags any
``with`` that acquires an *earlier* lock while a later one is held.
"""

from __future__ import annotations

import ast
import re
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.findings import Finding
from repro.analysis.modules import FuncNode, ModuleInfo

RULE_GUARD = "lock-guard"
RULE_ORDER = "lock-order"

_GUARDED_RE = re.compile(r"guarded-by:\s*([A-Za-z_]\w*)")


def _guard_comment(module: ModuleInfo, stmt: ast.AST) -> Optional[str]:
    """Lock named by a ``guarded-by:`` comment on any line a (possibly
    multi-line, formatter-wrapped) declaration statement spans."""
    end = getattr(stmt, "end_lineno", None) or stmt.lineno
    for line in range(stmt.lineno, end + 1):
        m = _GUARDED_RE.search(module.comment_on(line))
        if m:
            return m.group(1)
    return None


def collect_guarded(module: ModuleInfo) -> Dict[str, Dict[str, str]]:
    """{class_name: {field: lock_name}} from annotation comments."""
    guarded: Dict[str, Dict[str, str]] = {}
    for cls in ast.walk(module.tree):
        if not isinstance(cls, ast.ClassDef):
            continue
        fields: Dict[str, str] = {}
        # dataclass-style field declarations in the class body
        for stmt in cls.body:
            target = None
            if isinstance(stmt, ast.AnnAssign) and isinstance(stmt.target, ast.Name):
                target = stmt.target.id
            elif (
                isinstance(stmt, ast.Assign)
                and len(stmt.targets) == 1
                and isinstance(stmt.targets[0], ast.Name)
            ):
                target = stmt.targets[0].id
            if target is None:
                continue
            lock = _guard_comment(module, stmt)
            if lock is not None:
                fields[target] = lock
        # ``self.x = ...`` annotations anywhere in the class's methods
        for node in ast.walk(cls):
            if not isinstance(node, (ast.Assign, ast.AnnAssign)):
                continue
            targets = node.targets if isinstance(node, ast.Assign) else [node.target]
            for t in targets:
                if (
                    isinstance(t, ast.Attribute)
                    and isinstance(t.value, ast.Name)
                    and t.value.id == "self"
                ):
                    lock = _guard_comment(module, node)
                    if lock is not None:
                        fields[t.attr] = lock
        if fields:
            guarded[cls.name] = fields
    return guarded


def lock_declaration_order(module: ModuleInfo) -> List[str]:
    """Lock attribute names in creation order (``threading.Lock()`` /
    ``RLock()`` assigned to ``self.<name>``)."""
    order: List[str] = []
    for node in ast.walk(module.tree):
        if not isinstance(node, ast.Assign):
            continue
        if not (
            isinstance(node.value, ast.Call)
            and (
                module.resolves_to(node.value.func, "threading.Lock")
                or module.resolves_to(node.value.func, "threading.RLock")
            )
        ):
            continue
        for t in node.targets:
            if (
                isinstance(t, ast.Attribute)
                and isinstance(t.value, ast.Name)
                and t.value.id == "self"
                and t.attr not in order
            ):
                order.append(t.attr)
    return order


def _annotation_names(node: Optional[ast.AST]) -> str:
    if node is None:
        return ""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value  # string annotation
    try:
        return ast.unparse(node)
    except Exception:  # pragma: no cover
        return ""


def _mentions(annotation: str, class_name: str) -> bool:
    return re.search(rf"\b{re.escape(class_name)}\b", annotation) is not None


class _FunctionScan:
    """Per-function receiver typing + guard checking."""

    def __init__(
        self,
        module: ModuleInfo,
        func: ast.AST,
        qualname: str,
        owner_class: Optional[str],
        guarded: Dict[str, Dict[str, str]],
        typed_attrs: Dict[str, Set[str]],
        typed_returns: Dict[str, Set[str]],
        lock_order: List[str],
    ):
        self.module = module
        self.func = func
        self.qualname = qualname
        self.owner_class = owner_class
        self.guarded = guarded
        self.typed_attrs = typed_attrs  # attr name -> classes its annotation names
        self.typed_returns = typed_returns  # func/method name -> classes
        self.lock_order = lock_order
        self.findings: List[Finding] = []
        #: local name -> guarded class it holds an instance of
        self.typed: Dict[str, str] = {}
        for a in list(func.args.posonlyargs) + list(func.args.args):
            classes = {
                c for c in guarded if _mentions(_annotation_names(a.annotation), c)
            }
            if classes:
                self.typed[a.arg] = sorted(classes)[0]

    # ---- typing ----------------------------------------------------------

    def _classes_of_expr(self, node: ast.AST) -> Optional[str]:
        """Guarded class an expression evaluates to, when derivable."""
        if isinstance(node, ast.Name):
            return self.typed.get(node.id)
        if isinstance(node, ast.Call):
            fn = node.func
            name = fn.id if isinstance(fn, ast.Name) else getattr(fn, "attr", None)
            if name in self.guarded:
                return None  # fresh construction: thread-local until published
            for cls in self.typed_returns.get(name or "", ()):
                return cls
            # dict.get / .pop on a typed container attribute
            if isinstance(fn, ast.Attribute) and fn.attr in ("get", "pop"):
                return self._container_value_class(fn.value)
            return None
        if isinstance(node, ast.Subscript):
            return self._container_value_class(node.value)
        return None

    def _container_value_class(self, node: ast.AST) -> Optional[str]:
        """Guarded class held by a container attribute (``self._graphs``
        annotated ``OrderedDict[str, _Resident]``)."""
        if isinstance(node, ast.Attribute):
            for cls in self.typed_attrs.get(node.attr, ()):
                return cls
        return None

    def _type_target(self, target: ast.AST, cls: Optional[str]) -> None:
        if isinstance(target, ast.Name):
            if cls is not None:
                self.typed[target.id] = cls
            else:
                self.typed.pop(target.id, None)
        elif isinstance(target, ast.Tuple) and cls is not None:
            # ``for gid, rec in ...items()``: the value is the last element
            if target.elts and isinstance(target.elts[-1], ast.Name):
                self.typed[target.elts[-1].id] = cls

    # ---- walk ------------------------------------------------------------

    def run(self) -> List[Finding]:
        self._block(self.func.body, held=())
        return self.findings

    def _block(self, stmts, held: Tuple[str, ...]) -> None:
        for stmt in stmts:
            self._stmt(stmt, held)

    def _stmt(self, stmt: ast.AST, held: Tuple[str, ...]) -> None:
        if isinstance(stmt, FuncNode + (ast.ClassDef,)):
            return  # nested scopes scanned separately
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            acquired = []
            for item in stmt.items:
                ctx = item.context_expr
                self._check_expr(ctx, held)
                lock = self._lock_name(ctx)
                if lock is not None:
                    self._check_order(lock, held, stmt)
                    acquired.append(lock)
            self._block(stmt.body, held + tuple(acquired))
            return
        if isinstance(stmt, ast.Assign):
            self._check_expr(stmt.value, held)
            for t in stmt.targets:
                self._check_store(t, held)
            cls = self._classes_of_expr(stmt.value)
            for t in stmt.targets:
                self._type_target(t, cls)
            return
        if isinstance(stmt, ast.For):
            self._check_expr(stmt.iter, held)
            self._type_target(stmt.target, self._iter_class(stmt.iter))
            self._block(stmt.body, held)
            self._block(stmt.orelse, held)
            return
        if isinstance(stmt, ast.If):
            self._check_expr(stmt.test, held)
            self._block(stmt.body, held)
            self._block(stmt.orelse, held)
            return
        if isinstance(stmt, ast.While):
            self._check_expr(stmt.test, held)
            self._block(stmt.body, held)
            self._block(stmt.orelse, held)
            return
        if isinstance(stmt, ast.Try):
            self._block(stmt.body, held)
            for h in stmt.handlers:
                self._block(h.body, held)
            self._block(stmt.orelse, held)
            self._block(stmt.finalbody, held)
            return
        # default (Expr/Return/Raise/AugAssign/...): expressions only
        self._check_expr(stmt, held)

    def _iter_class(self, it: ast.AST) -> Optional[str]:
        """Class yielded by iterating ``self.<attr>.values()/items()``."""
        if isinstance(it, ast.Call) and isinstance(it.func, ast.Attribute):
            if it.func.attr in ("values", "items"):
                return self._container_value_class(it.func.value)
        if isinstance(it, ast.Call) and isinstance(it.func, ast.Name):
            if it.func.id in ("list", "sorted", "tuple") and it.args:
                return self._iter_class(it.args[0])
        return None

    # ---- checks ----------------------------------------------------------

    def _lock_name(self, ctx: ast.AST) -> Optional[str]:
        if isinstance(ctx, ast.Attribute) and ctx.attr in self.lock_order:
            return ctx.attr
        return None

    def _check_order(
        self, lock: str, held: Tuple[str, ...], node: ast.AST
    ) -> None:
        idx = self.lock_order.index(lock)
        for h in held:
            if h in self.lock_order and self.lock_order.index(h) > idx:
                self._report(
                    RULE_ORDER,
                    node,
                    f"acquires `{lock}` while holding `{h}` — declared "
                    f"order is {' -> '.join(self.lock_order)}",
                )

    _COMPS = (ast.ListComp, ast.SetComp, ast.DictComp, ast.GeneratorExp)

    def _check_expr(self, expr: ast.AST, held: Tuple[str, ...]) -> None:
        """Recursive expression walk: comprehension targets get typed
        *before* their element expressions are checked."""
        if isinstance(expr, self._COMPS):
            for gen in expr.generators:
                self._check_expr(gen.iter, held)
                self._type_target(gen.target, self._iter_class(gen.iter))
                for cond in gen.ifs:
                    self._check_expr(cond, held)
            if isinstance(expr, ast.DictComp):
                self._check_expr(expr.key, held)
                self._check_expr(expr.value, held)
            else:
                self._check_expr(expr.elt, held)
            return
        if isinstance(expr, ast.Attribute):
            self._check_attribute(expr, held)
            self._check_expr(expr.value, held)
            return
        if isinstance(expr, (ast.Lambda,) + FuncNode + (ast.ClassDef,)):
            return
        for child in ast.iter_child_nodes(expr):
            self._check_expr(child, held)

    def _check_store(self, target: ast.AST, held: Tuple[str, ...]) -> None:
        self._check_expr(target, held)

    def _check_attribute(self, node: ast.Attribute, held: Tuple[str, ...]) -> None:
        recv = node.value
        cls: Optional[str] = None
        if isinstance(recv, ast.Name):
            if recv.id == "self" and self.owner_class in self.guarded:
                if node.attr in self.guarded[self.owner_class]:
                    cls = self.owner_class
            elif recv.id in self.typed:
                cand = self.typed[recv.id]
                if node.attr in self.guarded.get(cand, {}):
                    cls = cand
        if cls is None:
            return
        lock = self.guarded[cls][node.attr]
        if lock in held:
            return
        if self.func.name == "__init__":
            return  # construction: the object is thread-local
        access = "write" if isinstance(node.ctx, (ast.Store, ast.Del)) else "read"
        self._report(
            RULE_GUARD,
            node,
            f"{access} of `{ast.unparse(recv)}.{node.attr}` "
            f"(guarded by `{lock}`) outside `with ...{lock}:`",
        )

    def _report(self, rule: str, node: ast.AST, message: str) -> None:
        self.findings.append(
            Finding(
                rule=rule,
                path=self.module.path,
                line=getattr(node, "lineno", 0),
                symbol=self.qualname,
                message=message,
            )
        )


def check_module(module: ModuleInfo) -> List[Finding]:
    guarded = collect_guarded(module)
    lock_order = lock_declaration_order(module)
    if not guarded and len(lock_order) < 2:
        return []

    # attribute annotations: self.<attr> -> guarded classes its
    # annotation string mentions (``self._graphs: "OrderedDict[str,
    # _Resident]" = ...``)
    typed_attrs: Dict[str, Set[str]] = {}
    typed_returns: Dict[str, Set[str]] = {}
    for node in ast.walk(module.tree):
        if isinstance(node, ast.AnnAssign) and isinstance(node.target, ast.Attribute):
            ann = _annotation_names(node.annotation)
            classes = {c for c in guarded if _mentions(ann, c)}
            if classes:
                typed_attrs.setdefault(node.target.attr, set()).update(classes)
        elif isinstance(node, FuncNode):
            ann = _annotation_names(node.returns)
            classes = {c for c in guarded if _mentions(ann, c)}
            if classes:
                typed_returns.setdefault(node.name, set()).update(classes)

    findings: List[Finding] = []
    for info in module.functions.values():
        node = info.node
        if isinstance(node, ast.Lambda):
            continue
        scan = _FunctionScan(
            module,
            node,
            info.qualname,
            info.class_name,
            guarded,
            typed_attrs,
            typed_returns,
            lock_order,
        )
        findings.extend(scan.run())
    return findings
