"""Runtime race-assertion mode (DESIGN.md §14).

The static lock pass proves *lexical* discipline; this module checks the
same contract dynamically: inside a ``guarded(engine)`` scope, every
write to a ``guarded-by:``-annotated field of a *published* record must
happen on a thread that currently holds the named lock. The guarded
field map is parsed at runtime from the engine module's own source via
``locks.collect_guarded`` — one source of truth with the static pass,
so an annotation added to the engine is enforced by both without
touching this file.

Mechanics: the engine's lock attributes are swapped for ``OwnedLock``
wrappers that record the holder thread, and ``__setattr__`` on the
annotated classes is patched to consult them. Records still under
construction (not yet reachable from the engine's registry) are exempt,
mirroring the static pass's fresh-object rule. By default violations
are *recorded* (``.violations``) so a fuzz harness can drive many
threads and assert at the end; ``strict=True`` raises at the faulting
write, turning any reproduced race into a stack trace that names the
field and the missing lock.

Container *mutations* (``rec.replicas[d] = unit``) are attribute reads,
not writes — the static pass covers those; this mode catches the
torn-publication class of bug (field written without the swap lock).
"""

from __future__ import annotations

import dataclasses
import inspect
import sys
import threading
from typing import Dict, List, Optional, Tuple

from repro.analysis.locks import collect_guarded
from repro.analysis.modules import ModuleInfo


class OwnedLock:
    """A ``threading.Lock`` that knows which thread holds it."""

    def __init__(self):
        self._lock = threading.Lock()
        self._owner: Optional[int] = None

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        got = self._lock.acquire(blocking, timeout)
        if got:
            self._owner = threading.get_ident()
        return got

    def release(self) -> None:
        self._owner = None
        self._lock.release()

    def __enter__(self) -> "OwnedLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def locked(self) -> bool:
        return self._lock.locked()

    def held_by_me(self) -> bool:
        return self._owner == threading.get_ident()


class RaceViolation(AssertionError):
    pass


@dataclasses.dataclass
class Violation:
    thread: str
    cls: str
    field: str
    lock: str

    def render(self) -> str:
        return (
            f"thread {self.thread!r} wrote {self.cls}.{self.field} "
            f"without holding `{self.lock}`"
        )


class guarded:
    """Context manager arming the race assertions on one engine.

    ``with guarded(engine) as g: ... ; assert not g.violations``
    """

    def __init__(self, engine, *, strict: bool = False):
        self.engine = engine
        self.strict = strict
        self.violations: List[Violation] = []
        self._fields = self._guarded_fields(type(engine))
        self._patched: List[Tuple[type, Optional[object]]] = []
        self._saved_locks: Dict[str, object] = {}

    # ---- guarded-field map, from the engine module's annotations -------

    @staticmethod
    def _guarded_fields(engine_cls) -> Dict[str, Dict[str, str]]:
        mod = sys.modules[engine_cls.__module__]
        path = inspect.getsourcefile(mod)
        with open(path, "r", encoding="utf-8") as fh:
            info = ModuleInfo(path, fh.read())
        return collect_guarded(info)

    # ---- arm / disarm ---------------------------------------------------

    def __enter__(self) -> "guarded":
        engine = self.engine
        mod = sys.modules[type(engine).__module__]
        # swap every named lock for an owner-tracking wrapper
        for fields in self._fields.values():
            for lock_name in fields.values():
                if lock_name not in self._saved_locks and hasattr(
                    engine, lock_name
                ):
                    self._saved_locks[lock_name] = getattr(engine, lock_name)
                    object.__setattr__(engine, lock_name, OwnedLock())
        # patch __setattr__ on each annotated class found in the module
        for cls_name in self._fields:
            cls = getattr(mod, cls_name, None)
            if cls is None and cls_name == type(engine).__name__:
                cls = type(engine)
            if not isinstance(cls, type):
                continue
            self._patched.append((cls, cls.__dict__.get("__setattr__")))
            cls.__setattr__ = self._make_setattr(cls_name)
        return self

    def __exit__(self, *exc) -> None:
        for cls, original in self._patched:
            if original is None:
                del cls.__setattr__
            else:
                cls.__setattr__ = original
        self._patched.clear()
        for lock_name, lock in self._saved_locks.items():
            object.__setattr__(self.engine, lock_name, lock)
        self._saved_locks.clear()

    # ---- the check -------------------------------------------------------

    def _make_setattr(self, cls_name: str):
        fields = self._fields[cls_name]
        checker = self

        def guarded_setattr(obj, name, value):
            lock_name = fields.get(name)
            if (
                lock_name is not None
                and name not in checker._saved_locks
                and checker._published(obj)
            ):
                lock = getattr(checker.engine, lock_name, None)
                if isinstance(lock, OwnedLock) and not lock.held_by_me():
                    checker._violate(cls_name, name, lock_name)
            object.__setattr__(obj, name, value)

        return guarded_setattr

    def _published(self, obj) -> bool:
        """Is ``obj`` reachable by other threads? The engine itself
        always is; a record only once the engine registry holds it
        (constructor writes on a fresh record are thread-local)."""
        if obj is self.engine:
            return True
        registry = getattr(self.engine, "_graphs", None)
        if registry is None:
            return True  # unknown engine shape: err on checking
        try:
            return any(r is obj for r in list(registry.values()))
        except RuntimeError:  # registry resized mid-iteration: retry once
            return any(r is obj for r in list(registry.values()))

    def _violate(self, cls_name: str, field: str, lock_name: str) -> None:
        v = Violation(threading.current_thread().name, cls_name, field, lock_name)
        self.violations.append(v)
        if self.strict:
            raise RaceViolation(v.render())
