"""Jit-site discovery and the lightweight per-module call-graph walk.

Roots are every function a ``jax.jit`` decoration site names in the
module: ``@jax.jit`` / ``@functools.partial(jax.jit, ...)`` decorators,
``jax.jit(fn)`` / ``jax.jit(self._method)`` wrapping calls, lambdas
passed to ``jax.jit``, and ``jax.vmap`` chains inside the jit call
(``jax.jit(jax.vmap(ex._forward_impl, ...))``). ``static_argnames``
travel with each root — those parameters are Python values, not
tracers, so branching on them is legal.

Reachability is transitive over *references*, not just call
expressions: a reachable body that mentions a module-level function by
name (e.g. hands ``functools.partial(_kernel, ...)`` to
``pl.pallas_call``) pulls that function into the jit-reachable set, and
``self._method`` references pull in same-class methods. Cross-module
edges are intentionally not followed — the walk stays cheap and each
module is checked against its own jit sites.
"""

from __future__ import annotations

import ast
import dataclasses
from typing import Dict, FrozenSet, List, Optional, Set, Tuple

from repro.analysis.modules import FuncInfo, FuncNode, ModuleInfo


@dataclasses.dataclass
class JitRoot:
    func: FuncInfo
    static_argnames: FrozenSet[str]
    site_line: int
    #: True when named at a jit decoration site; False for helpers pulled
    #: in transitively (their keyword-only params are treated as
    #: partial-bound statics by the hazard pass)
    is_root: bool = True


def _static_argnames(call: ast.Call) -> FrozenSet[str]:
    for kw in call.keywords:
        if kw.arg != "static_argnames":
            continue
        names: Set[str] = set()
        value = kw.value
        elts = value.elts if isinstance(value, (ast.Tuple, ast.List)) else [value]
        for e in elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, str):
                names.add(e.value)
        return frozenset(names)
    return frozenset()


def _is_jit(module: ModuleInfo, node: ast.AST) -> bool:
    return module.resolves_to(node, "jax.jit")


def _partial_of_jit(module: ModuleInfo, node: ast.AST) -> Optional[ast.Call]:
    """``functools.partial(jax.jit, ...)`` → the partial call node."""
    if (
        isinstance(node, ast.Call)
        and module.resolves_to(node.func, "functools.partial")
        and node.args
        and _is_jit(module, node.args[0])
    ):
        return node
    return None


def _unwrap_transforms(module: ModuleInfo, node: ast.AST) -> ast.AST:
    """Peel ``jax.jit`` / ``jax.vmap`` / ``jax.grad`` / ``functools.
    partial`` wrappers off a function expression."""
    wrappers = (
        "jax.jit",
        "jax.vmap",
        "jax.grad",
        "jax.value_and_grad",
        "jax.custom_vjp",
        "functools.partial",
    )
    while (
        isinstance(node, ast.Call)
        and node.args
        and any(module.resolves_to(node.func, w) for w in wrappers)
    ):
        node = node.args[0]
    return node


def _resolve_target(
    module: ModuleInfo,
    node: ast.AST,
    enclosing_class: Optional[str],
    _visited: Optional[Set[str]] = None,
) -> List[FuncInfo]:
    """Function(s) a jit argument expression denotes within this module.

    Follows one level of dynamic method aliasing per step
    (``self._spmm_impl = self._gather_impl if ... else self._onehot_impl``
    then ``jax.jit(self._spmm_impl)``), bounded by a visited set."""
    visited = _visited if _visited is not None else set()
    node = _unwrap_transforms(module, node)
    if isinstance(node, ast.IfExp):
        return _resolve_target(
            module, node.body, enclosing_class, visited
        ) + _resolve_target(module, node.orelse, enclosing_class, visited)
    if isinstance(node, ast.BoolOp):
        out: List[FuncInfo] = []
        for v in node.values:
            out.extend(_resolve_target(module, v, enclosing_class, visited))
        return out
    if isinstance(node, ast.Name):
        info = module.functions.get(node.id)
        if info is not None:
            return [info]
        # nested/local function: match by bare name, conservatively
        return list(module.methods_by_name.get(node.id, []))
    if isinstance(node, ast.Attribute):
        # ``self._m`` prefers the enclosing class; ``ex._m`` (any other
        # receiver) conservatively maps to every method of that name
        candidates = module.methods_by_name.get(node.attr, [])
        if (
            isinstance(node.value, ast.Name)
            and node.value.id == "self"
            and enclosing_class is not None
        ):
            own = [c for c in candidates if c.class_name == enclosing_class]
            if own:
                return own
        if candidates:
            return list(candidates)
        return _resolve_attr_alias(module, node.attr, visited)
    if isinstance(node, ast.Lambda):
        qual = f"<lambda:{node.lineno}>"
        info = FuncInfo(qual, node, enclosing_class)
        module.functions.setdefault(qual, info)
        return [info]
    return []


def _resolve_attr_alias(
    module: ModuleInfo, attr: str, visited: Set[str]
) -> List[FuncInfo]:
    """Resolve a dynamically-bound callable attribute by following every
    ``<recv>.<attr> = <expr>`` assignment in the module."""
    if attr in visited:
        return []
    visited.add(attr)
    out: List[FuncInfo] = []
    for node in ast.walk(module.tree):
        if not isinstance(node, ast.Assign):
            continue
        for t in node.targets:
            if isinstance(t, ast.Attribute) and t.attr == attr:
                cls = None
                fn = module.enclosing_function(t)
                if fn is not None:
                    info = module.functions.get(module.qualname_of(fn))
                    cls = info.class_name if info else None
                out.extend(_resolve_target(module, node.value, cls, visited))
    return out


def find_jit_roots(module: ModuleInfo) -> List[JitRoot]:
    roots: List[JitRoot] = []
    seen: Set[Tuple[int, int]] = set()

    def add(info: FuncInfo, static: FrozenSet[str], line: int) -> None:
        key = (id(info.node), 0)
        if key in seen:
            return
        seen.add(key)
        roots.append(JitRoot(info, static, line))

    # decorator sites
    for info in module.functions.values():
        node = info.node
        for dec in getattr(node, "decorator_list", []):
            if _is_jit(module, dec):
                add(info, frozenset(), dec.lineno)
            elif isinstance(dec, ast.Call) and _is_jit(module, dec.func):
                add(info, _static_argnames(dec), dec.lineno)
            else:
                partial = _partial_of_jit(module, dec)
                if partial is not None:
                    add(info, _static_argnames(partial), dec.lineno)

    # wrapping-call sites: jax.jit(<fn expr>, ...)
    for node in ast.walk(module.tree):
        if not (isinstance(node, ast.Call) and _is_jit(module, node.func)):
            continue
        if not node.args:
            continue
        enclosing = module.enclosing_function(node)
        enclosing_class = None
        if enclosing is not None:
            qual = module.qualname_of(enclosing)
            info = module.functions.get(qual)
            enclosing_class = info.class_name if info else None
        static = _static_argnames(node)
        for target in _resolve_target(module, node.args[0], enclosing_class):
            add(target, static, node.lineno)
    return roots


def _referenced_functions(module: ModuleInfo, info: FuncInfo) -> Set[str]:
    """Qualnames of module functions the body of ``info`` references."""
    refs: Set[str] = set()
    body = info.node.body
    nodes = body if isinstance(body, list) else [body]  # Lambda body is an expr
    for stmt in nodes:
        for node in ast.walk(stmt):
            if isinstance(node, FuncNode):  # nested defs walk on their own
                continue
            if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
                target = module.functions.get(node.id)
                if target is not None and target.class_name is None:
                    refs.add(target.qualname)
            elif (
                isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and node.value.id in ("self", "cls")
            ):
                cands = [
                    c
                    for c in module.methods_by_name.get(node.attr, [])
                    if c.class_name == info.class_name
                ]
                if not cands:
                    # dynamically-bound alias (``self._spmm = jax.jit(...)``)
                    cands = _resolve_attr_alias(module, node.attr, set())
                for cand in cands:
                    refs.add(cand.qualname)
    return refs


def jit_reachable(module: ModuleInfo) -> Dict[str, JitRoot]:
    """qualname -> the root it is reachable from (first wins), closed
    transitively over same-module references."""
    reachable: Dict[str, JitRoot] = {}
    queue: List[Tuple[FuncInfo, JitRoot]] = []
    for root in find_jit_roots(module):
        if root.func.qualname not in reachable:
            reachable[root.func.qualname] = root
            queue.append((root.func, root))
    while queue:
        info, root = queue.pop()
        for qual in _referenced_functions(module, info):
            if qual in reachable:
                continue
            callee = module.functions[qual]
            # static_argnames do not propagate: a callee's params are
            # whatever the caller passed, traced until proven otherwise
            sub = JitRoot(callee, frozenset(), root.site_line, is_root=False)
            reachable[qual] = sub
            queue.append((callee, sub))
    return reachable
