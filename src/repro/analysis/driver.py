"""Pass orchestration: collect files, run every rule, apply waivers.

``--self-check`` mode re-runs the passes over the committed fixture
files (``tests/fixtures/analysis/``); each fixture's ``# expect:``
header states exactly which rules must fire on it, so a refactor that
silently blinds a rule fails CI the same way a real finding does.
"""

from __future__ import annotations

import dataclasses
import re
from pathlib import Path
from typing import Iterable, List, Sequence

from repro.analysis import counters, jax_hazards, locks
from repro.analysis.findings import Finding, Waiver, load_waivers, split_findings
from repro.analysis.modules import ModuleInfo, parse_module

_PASSES = (jax_hazards.check_module, locks.check_module, counters.check_module)

ALL_RULES = (
    jax_hazards.RULE_NP_CALL,
    jax_hazards.RULE_TRACED_BRANCH,
    jax_hazards.RULE_HOST_SYNC,
    jax_hazards.RULE_MUTABLE_GLOBAL,
    locks.RULE_GUARD,
    locks.RULE_ORDER,
    counters.RULE_SETTLEMENT,
)

_EXPECT_RE = re.compile(r"#\s*expect:\s*(.*)")


@dataclasses.dataclass
class Report:
    findings: List[Finding]
    unwaived: List[Finding]
    waived: List[Finding]
    stale_waivers: List[Waiver]
    errors: List[str]

    @property
    def ok(self) -> bool:
        return not self.unwaived and not self.errors


def collect_files(paths: Sequence[str]) -> List[Path]:
    files: List[Path] = []
    for raw in paths:
        p = Path(raw)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
        elif p.suffix == ".py":
            files.append(p)
    return files


def analyze_file(path: Path) -> List[Finding]:
    module = parse_module(str(path))
    findings: List[Finding] = []
    for check in _PASSES:
        findings.extend(check(module))
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule))


def run_analysis(paths: Sequence[str], waivers_path=None) -> Report:
    waivers = load_waivers(waivers_path) if waivers_path else []
    findings: List[Finding] = []
    errors: List[str] = []
    for path in collect_files(paths):
        try:
            findings.extend(analyze_file(path))
        except SyntaxError as e:  # pragma: no cover - tree is py-clean
            errors.append(f"{path}: syntax error: {e}")
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    unwaived, waived, stale = split_findings(findings, waivers)
    return Report(findings, unwaived, waived, stale, errors)


def self_check(fixtures_dir) -> List[str]:
    """Run every fixture and return mismatch descriptions (empty = pass).

    Fixture header: ``# expect: rule-a, rule-b`` or ``# expect: none``.
    The comparison is on the *set* of rules fired — a fixture that stops
    triggering its rule (or starts triggering another) fails."""
    problems: List[str] = []
    fixtures = sorted(Path(fixtures_dir).glob("*.py"))
    if not fixtures:
        return [f"no fixtures found under {fixtures_dir}"]
    for path in fixtures:
        header = path.read_text(encoding="utf-8").splitlines()
        expected: set = set()
        stated = False
        for line in header[:5]:
            m = _EXPECT_RE.search(line)
            if m:
                stated = True
                names = m.group(1).strip()
                if names.lower() != "none":
                    expected = {n.strip() for n in names.split(",") if n.strip()}
                break
        if not stated:
            problems.append(f"{path}: missing `# expect:` header")
            continue
        unknown = expected - set(ALL_RULES)
        if unknown:
            problems.append(f"{path}: unknown rules in header: {sorted(unknown)}")
            continue
        fired = {f.rule for f in analyze_file(path)}
        if fired != expected:
            problems.append(
                f"{path}: expected {sorted(expected) or ['none']}, "
                f"fired {sorted(fired) or ['none']}"
            )
    return problems


def render_report(report: Report, verbose: bool = False) -> Iterable[str]:
    for err in report.errors:
        yield f"ERROR: {err}"
    for f in report.unwaived:
        yield f.render()
    if verbose:
        for f in report.waived:
            yield f"waived: {f.render()}"
    for w in report.stale_waivers:
        yield (
            f"warning: stale waiver ({w.rule}, {w.path}, {w.symbol}) "
            "matches no finding — remove it"
        )
    yield (
        f"{len(report.findings)} finding(s): "
        f"{len(report.unwaived)} unwaived, {len(report.waived)} waived, "
        f"{len(report.stale_waivers)} stale waiver(s)"
    )
