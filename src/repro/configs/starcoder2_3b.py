"""starcoder2-3b — dense GQA transformer, LayerNorm + bias + GeLU MLP + RoPE.
[arXiv:2402.19173; hf] 30L d_model=3072 24H (kv=2) d_ff=12288 vocab=49152."""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    d_head=128,
    d_ff=12288,
    vocab=49152,
    segments=((("attn",), 30),),
    qkv_bias=True,
    rope=True,
    rope_theta=1e5,
    norm="layernorm",
    activation="gelu",
    glu=False,
)
