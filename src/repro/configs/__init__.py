"""Architecture registry: the 10 assigned archs + the paper's GCN datasets.

``get_config(name)`` returns the exact published configuration;
``get_reduced_config(name)`` shrinks every dimension for CPU smoke tests
while preserving the segment structure (same family, same code paths).
``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input of a shape cell — weak-type-correct, shardable, no allocation.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.transformer import (EncoderConfig, ModelConfig, MoEConfig,
                                      init_cache)

_ARCH_MODULES: Dict[str, str] = {
    "rwkv6-3b": "rwkv6_3b",
    "qwen2-72b": "qwen2_72b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "qwen2-0.5b": "qwen2_05b",
    "starcoder2-3b": "starcoder2_3b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "whisper-tiny": "whisper_tiny",
    "granite-moe-3b-a800m": "granite_moe_3b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b",
    "pixtral-12b": "pixtral_12b",
}

GCN_DATASETS = ("cora", "citeseer", "pubmed", "nell", "reddit")

# shape cells: name -> (seq_len, global_batch, kind)
SHAPES = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}


def list_archs():
    return list(_ARCH_MODULES)


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")
    return mod.CONFIG


def cell_supported(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    """Assignment skip rules (documented in DESIGN.md §6)."""
    if shape == "long_500k" and not cfg.sub_quadratic:
        return False, "long_500k needs sub-quadratic attention"
    return True, ""


def get_reduced_config(name: str) -> ModelConfig:
    """Same family/code paths, tiny dims — for CPU smoke tests."""
    cfg = get_config(name)
    segments = tuple((unit, min(rep, 2)) for unit, rep in cfg.segments)
    n_layers = sum(len(u) * r for u, r in segments)
    kv = min(cfg.n_kv_heads, 2)
    heads = max(4 // 1, kv * 2)
    moe = None
    if cfg.moe is not None:
        moe = MoEConfig(n_experts=8, top_k=2, d_expert=32,
                        capacity_factor=cfg.moe.capacity_factor)
    enc = None
    if cfg.encoder is not None:
        enc = EncoderConfig(n_layers=2, max_source=16)
    return dataclasses.replace(
        cfg, n_layers=n_layers, d_model=64, n_heads=heads, n_kv_heads=kv,
        d_head=16, d_ff=96, vocab=128, segments=segments, moe=moe,
        encoder=enc, window=(8 if cfg.window else None),
        d_rnn=(64 if cfg.d_rnn else 0), remat=False)


def input_specs(cfg: ModelConfig, shape: str) -> dict:
    """ShapeDtypeStruct stand-ins for every input of (arch × shape)."""
    seq, batch, kind = SHAPES[shape]
    tok = lambda b, s: jax.ShapeDtypeStruct((b, s), jnp.int32)  # noqa: E731
    specs: dict = {}
    if kind == "train":
        specs["tokens"] = tok(batch, seq)
        specs["labels"] = tok(batch, seq)
    elif kind == "prefill":
        specs["tokens"] = tok(batch, seq)
    else:  # decode: one new token against a seq-length cache
        specs["token"] = jax.ShapeDtypeStruct((batch,), jnp.int32)
        specs["pos"] = jax.ShapeDtypeStruct((), jnp.int32)
        specs["cache"] = jax.eval_shape(
            lambda: init_cache(cfg, batch, seq, jnp.bfloat16))
    if cfg.encoder is not None and kind != "decode":
        specs["source_embed"] = jax.ShapeDtypeStruct(
            (batch, cfg.encoder.max_source, cfg.d_model), jnp.bfloat16)
    return specs
