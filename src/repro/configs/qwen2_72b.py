"""qwen2-72b — dense GQA transformer with QKV bias.
[arXiv:2407.10671; hf] 80L d_model=8192 64H (kv=8) d_ff=29568 vocab=152064."""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=29568,
    vocab=152064,
    segments=((("attn",), 80),),
    qkv_bias=True,
    rope=True,
    rope_theta=1e6,
    norm="rmsnorm",
    activation="silu",
    glu=True,
)
