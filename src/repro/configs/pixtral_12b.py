"""pixtral-12b — VLM: pixtral-ViT frontend (STUB per assignment) +
mistral-nemo decoder backbone.
[hf:mistralai/Pixtral-12B-2409; unverified] 40L d_model=5120 32H (kv=8)
d_ff=14336 vocab=131072."""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=14336,
    vocab=131072,
    segments=((("attn",), 40),),
    rope=True,
    rope_theta=1e6,
    norm="rmsnorm",
    activation="silu",
    glu=True,
    frontend="vision",
)
