"""qwen2-0.5b — small dense GQA transformer, tied embeddings, QKV bias.
[arXiv:2407.10671; hf] 24L d_model=896 14H (kv=2) d_ff=4864 vocab=151936."""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_head=64,
    d_ff=4864,
    vocab=151936,
    segments=((("attn",), 24),),
    qkv_bias=True,
    rope=True,
    rope_theta=1e6,
    norm="rmsnorm",
    activation="silu",
    glu=True,
    tie_embeddings=True,
)
