"""recurrentgemma-2b — Griffin hybrid: RG-LRU + local attention, 2:1.
[arXiv:2402.19427; hf] 26L d_model=2560 10H (MQA kv=1) d_ff=7680
vocab=256000, window=2048. Pattern: (rec, rec, attn) × 8 + (rec, rec)."""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_head=256,
    d_ff=7680,
    vocab=256000,
    segments=((("rglru", "rglru", "local"), 8), (("rglru", "rglru"), 1)),
    rope=True,
    rope_theta=1e4,
    norm="rmsnorm",
    activation="gelu",   # GeGLU
    glu=True,
    window=2048,
    d_rnn=2560,
)
