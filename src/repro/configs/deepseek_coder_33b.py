"""deepseek-coder-33b — llama-architecture dense GQA transformer.
[arXiv:2401.14196; hf] 62L d_model=7168 56H (kv=8) d_ff=19200 vocab=32256."""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    family="dense",
    n_layers=62,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_head=128,
    d_ff=19200,
    vocab=32256,
    segments=((("attn",), 62),),
    rope=True,
    rope_theta=1e5,
    norm="rmsnorm",
    activation="silu",
    glu=True,
)
