"""rwkv6-3b — RWKV-6 "Finch" 3B: attention-free, data-dependent decay.
[arXiv:2404.05892; hf] 32L d_model=2560 d_ff=8960 vocab=65536."""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,          # 2560 / 64-dim wkv heads
    n_kv_heads=40,
    d_head=64,
    d_ff=8960,
    vocab=65536,
    segments=((("rwkv",), 32),),
    rope=False,
    norm="layernorm",    # RWKV uses LayerNorm
    glu=False,
    activation="relu2",  # ChannelMix uses squared ReLU internally
)
