"""granite-moe-3b-a800m — MoE, 40 experts top-8 (granite-3.0-3b-a800m).
[hf:ibm-granite; hf] 32L d_model=1536 24H (kv=8) expert d_ff=512 vocab=49155.

AWB-GCN applicability: PRIMARY — router histograms are power-law; the AWB
placement balancer (core/moe_balance.py) drives expert-parallel dispatch.
"""
from repro.models.transformer import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_head=64,
    d_ff=512,               # per-expert hidden
    vocab=49155,
    segments=((("attn_moe",), 32),),
    rope=True,
    rope_theta=1e4,
    norm="rmsnorm",
    activation="silu",
    glu=True,
    moe=MoEConfig(n_experts=40, top_k=8, d_expert=512),
)
