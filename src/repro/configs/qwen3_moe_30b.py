"""qwen3-moe-30b-a3b — MoE, 128 experts top-8, QK-norm.
[hf:Qwen/Qwen3-30B-A3B; hf] 48L d_model=2048 32H (kv=4) expert d_ff=768
vocab=151936.

AWB-GCN applicability: PRIMARY and the most representative assigned arch —
128 experts, power-law routing; hillclimb cell (EXPERIMENTS.md §Perf).
"""
from repro.models.transformer import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_head=128,
    qk_norm=True,
    d_ff=768,                # per-expert hidden
    vocab=151936,
    segments=((("attn_moe",), 48),),
    rope=True,
    rope_theta=1e6,
    norm="rmsnorm",
    activation="silu",
    glu=True,
    moe=MoEConfig(n_experts=128, top_k=8, d_expert=768),
)
