"""whisper-tiny — encoder-decoder; conv frontend is a STUB per assignment
(input_specs provides precomputed frame embeddings).
[arXiv:2212.04356; unverified] 4L d_model=384 6H d_ff=1536 vocab=51865.

Deviation note (DESIGN.md): learned absolute positions replaced by RoPE —
a positional-encoding substitute that keeps the backbone's shapes exact.
"""
from repro.models.transformer import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,            # decoder layers; encoder configured below
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_head=64,
    d_ff=1536,
    vocab=51865,
    segments=((("xattn",), 4),),
    rope=True,
    norm="layernorm",
    activation="gelu",
    glu=False,
    encoder=EncoderConfig(n_layers=4, max_source=1500),
    frontend="audio",
    tie_embeddings=True,
)
