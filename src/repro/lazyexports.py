"""PEP-562 lazy module re-exports.

Shared by the modules that forward moved symbols to ``repro.tuning``
(``core/executor.py``, ``core/__init__.py``) so the forwarding mechanism —
including its alias handling — lives in exactly one place.
"""
from __future__ import annotations

import importlib


def lazy_exports(module_name: str, mapping: dict, module_globals: dict):
    """Build a module's ``(__getattr__, __dir__)`` pair from ``mapping``.

    ``mapping`` sends attribute names to ``"module.path"`` (same attribute
    name there) or ``"module.path:attr"`` (alias) targets. Resolution is
    deferred to first access, so a module can forward to a package that
    itself imports the module without creating an import cycle."""

    def __getattr__(name: str):
        target = mapping.get(name)
        if target is None:
            raise AttributeError(
                f"module {module_name!r} has no attribute {name!r}")
        mod_path, _, attr = target.partition(":")
        return getattr(importlib.import_module(mod_path), attr or name)

    def __dir__():
        return sorted(list(module_globals) + list(mapping))

    return __getattr__, __dir__
