from repro.graphs.synth import (  # noqa: F401
    DATASET_STATS,
    GraphDataset,
    make_dataset,
    power_law_adjacency,
)
