"""Synthetic power-law graph generation calibrated to the paper's datasets.

The container is offline, so Cora/Citeseer/Pubmed/NELL/Reddit are synthesized
to match Table I of the paper: node count, feature width, adjacency density,
and X1 feature density — with a power-law out-degree sequence so the
workload-imbalance phenomenon the paper targets (evil rows, regional
clustering, Figs. 1/2/5) is reproduced. The paper evaluates utilization and
throughput, not accuracy, so matched sparsity *structure* is the faithful
axis; ``alpha`` is tuned per dataset so the static-baseline utilization
roughly reproduces Fig. 14's ordering (NELL pathological, Reddit benign).

Row degrees follow ``deg(rank) ∝ rank^-alpha`` exactly (shuffled over row
ids). Columns are sampled 60% uniform / 25% Zipf hubs / 15% local window —
rows drive PE workload imbalance, columns drive gather clustering.

``scale`` lets tests shrink every dataset by an integer factor while keeping
densities (and therefore imbalance shape) fixed.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np

from repro.core import csc as fmt

# name: (nodes, features, classes, hidden, density_A, density_X1, alpha,
#        max_degree) — nodes/features/densities from Table I; hidden dims
# follow the original GCN settings the paper cites ([29],[46],[47]);
# max_degree anchors the head of the degree distribution to the real graphs.
DATASET_STATS: Dict[str, Tuple[int, int, int, int, float, float, float, int]] = {
    "cora": (2708, 1433, 7, 16, 0.0018, 0.0127, 0.80, 170),
    "citeseer": (3327, 3703, 6, 16, 0.0011, 0.0085, 0.70, 100),
    "pubmed": (19717, 500, 3, 16, 0.00028, 0.10, 0.75, 172),
    "nell": (65755, 61278, 210, 64, 0.000073, 0.00011, 1.05, 1800),
    "reddit": (232965, 602, 41, 128, 0.00043, 0.516, 0.55, 21000),
}


@dataclasses.dataclass
class GraphDataset:
    name: str
    num_nodes: int
    num_features: int
    num_classes: int
    hidden: int
    adj: fmt.COO          # normalized adjacency Ã (power-law)
    features: np.ndarray  # [nodes, features] sparse-ish dense array (X1)
    labels: np.ndarray    # [nodes] int32

    @property
    def adj_csc(self) -> fmt.CSC:
        return fmt.csc_from_coo(self.adj)

    @property
    def adj_csr(self) -> fmt.CSR:
        return fmt.csr_from_coo(self.adj)


def _zipf_degrees(n: int, target_nnz: int, alpha: float,
                  rng: np.random.Generator,
                  max_degree: int | None = None) -> np.ndarray:
    """Exact power-law degree sequence: deg(rank) ∝ rank^-alpha, min 1,
    capped at max_degree (and n/2), scaled so the total ≈ target_nnz,
    shuffled over rows."""
    ranks = np.arange(1, n + 1, dtype=np.float64)
    w = ranks ** (-alpha)
    w /= w.sum()
    deg = np.maximum(1, np.round(w * target_nnz)).astype(np.int64)
    cap = n // 2 if max_degree is None else min(n // 2, max_degree)
    deg = np.minimum(deg, cap)
    rng.shuffle(deg)
    return deg


def power_law_adjacency(num_nodes: int, density: float, alpha: float,
                        seed: int = 0, normalize: bool = True,
                        max_degree: int | None = None) -> fmt.COO:
    """Random power-law adjacency (+ self loops, symmetric-normalized)."""
    rng = np.random.default_rng(seed)
    target = max(num_nodes, int(density * num_nodes * num_nodes))
    deg = _zipf_degrees(num_nodes, target, alpha, rng, max_degree)
    rows = np.repeat(np.arange(num_nodes, dtype=np.int64), deg)
    m = rows.shape[0]

    # column endpoints: 60% uniform, 25% zipf hub columns, 15% local window
    u = rng.random(m)
    cols = np.empty(m, np.int64)
    uni = u < 0.60
    hub = (u >= 0.60) & (u < 0.85)
    loc = u >= 0.85
    cols[uni] = rng.integers(0, num_nodes, int(uni.sum()))
    # zipf hub columns via inverse-CDF over a permuted id space
    ranks = np.arange(1, num_nodes + 1, dtype=np.float64)
    pw = ranks ** (-max(alpha, 0.8))
    cdf = np.cumsum(pw / pw.sum())
    perm = rng.permutation(num_nodes)
    cols[hub] = perm[np.searchsorted(cdf, rng.random(int(hub.sum())))]
    cols[loc] = np.clip(
        rows[loc] + rng.integers(-64, 65, int(loc.sum())), 0, num_nodes - 1)

    # self loops (the +I of the paper's normalization), then dedupe
    rows = np.concatenate([rows, np.arange(num_nodes, dtype=np.int64)])
    cols = np.concatenate([cols, np.arange(num_nodes, dtype=np.int64)])
    key = np.unique(rows * num_nodes + cols)
    rows = (key // num_nodes).astype(np.int64)
    cols = (key % num_nodes).astype(np.int64)
    vals = np.ones(rows.shape[0], np.float32)

    if normalize:
        # symmetric normalization D^-1/2 (A+I) D^-1/2 on total degree
        degree = (np.bincount(rows, minlength=num_nodes).astype(np.float64)
                  + np.bincount(cols, minlength=num_nodes))
        dinv = 1.0 / np.sqrt(np.maximum(degree, 1.0))
        vals = (dinv[rows] * dinv[cols]).astype(np.float32)

    return fmt.coo_from_arrays(rows, cols, vals, (num_nodes, num_nodes))


def sparse_features(num_nodes: int, num_features: int, density: float,
                    seed: int = 0) -> np.ndarray:
    """X1: sparse features stored dense (the paper's TDQ-1 operand),
    row-normalized as in the standard GCN pipelines (sum per row = 1)."""
    rng = np.random.default_rng(seed + 1)
    x = np.zeros((num_nodes, num_features), np.float32)
    nnz = int(density * num_nodes * num_features)
    r = rng.integers(0, num_nodes, nnz)
    c = rng.integers(0, num_features, nnz)
    x[r, c] = rng.random(nnz).astype(np.float32) + 0.1
    # guarantee no empty rows (every node has at least one feature)
    x[np.arange(num_nodes), rng.integers(0, num_features, num_nodes)] += 0.5
    x /= x.sum(axis=1, keepdims=True)
    return x


def teacher_labels(adj: fmt.COO, x: np.ndarray, classes: int,
                   seed: int = 0) -> np.ndarray:
    """Labels from a random *teacher GCN* — smooth over the graph and a
    function of the features, so a student GCN can actually learn them
    (random labels are unlearnable; the paper's datasets are, of course,
    learnable)."""
    rng = np.random.default_rng(seed + 3)
    import jax.numpy as jnp

    from repro.core import spmm

    w1 = rng.standard_normal((x.shape[1], 32)).astype(np.float32)
    w2 = rng.standard_normal((32, classes)).astype(np.float32)
    h = np.maximum(np.asarray(spmm.spmm_coo(adj, jnp.asarray(x @ w1))), 0)
    logits = np.asarray(spmm.spmm_coo(adj, jnp.asarray(h @ w2)))
    return logits.argmax(-1).astype(np.int32)


def make_dataset(name: str, seed: int = 0, scale: int = 1) -> GraphDataset:
    """Instantiate a (possibly scaled-down) synthetic dataset."""
    (nodes, feats, classes, hidden, dens_a, dens_x, alpha,
     max_deg) = DATASET_STATS[name]
    nodes = max(32, nodes // scale)
    feats = max(16, feats // scale)
    max_deg = max(16, max_deg // scale)
    adj = power_law_adjacency(nodes, dens_a, alpha, seed=seed,
                              max_degree=max_deg)
    x = sparse_features(nodes, feats, dens_x, seed=seed)
    labels = teacher_labels(adj, x, classes, seed)
    return GraphDataset(name, nodes, feats, classes, hidden, adj, x, labels)
