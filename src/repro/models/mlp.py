"""Dense MLP: GLU-gated (SwiGLU/GeGLU) or plain two-layer."""
from __future__ import annotations

import jax

from repro.models import common
from repro.sharding.hints import constrain


def init_mlp_params(key: jax.Array, d_model: int, d_ff: int,
                    glu: bool) -> dict:
    ks = jax.random.split(key, 3)
    p = {
        "w_in": common.dense_init(ks[0], (d_model, d_ff)),
        "w_out": common.dense_init(ks[1], (d_ff, d_model)),
    }
    if glu:
        p["w_gate"] = common.dense_init(ks[2], (d_model, d_ff))
    return p


def mlp_forward(p: dict, x: jax.Array, activation: str, glu: bool
                ) -> jax.Array:
    act = common.activation_fn(activation)
    h = constrain(x @ p["w_in"].astype(x.dtype), ("dp", None, "tp"))
    if glu:
        h = act(constrain(x @ p["w_gate"].astype(x.dtype),
                          ("dp", None, "tp"))) * h
    else:
        h = act(h)
    return constrain(h @ p["w_out"].astype(x.dtype), ("dp", None, None))
