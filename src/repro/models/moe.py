"""Mixture-of-Experts FFN with AWB-balanced dispatch.

Top-k routing with capacity-bounded sort-free dispatch (one-hot cumsum
position ranking + scatter into per-expert buffers), expert compute as
stacked einsums (EP: the expert dimension shards over the ``model`` mesh
axis), and gather-combine.

AWB integration (DESIGN.md §5): router histograms are power-law — a few
"evil" experts absorb most tokens. ``core.moe_balance`` converts a profiled
(EMA) load into an ``ExpertPlacement`` with hot-expert *replicas*; the
dispatch below accepts the placement as two traced tables and routes token i
of expert e to replica ``i % r_e`` — chunking an evil expert across devices
exactly like evil-row remapping chunks a row across PEs. The combine step's
weighted sum is the adder tree. With ``placement=None`` dispatch degenerates
to the standard static layout (the paper's baseline).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import common
from repro.sharding.hints import constrain


class MoEDims(NamedTuple):
    d_model: int
    d_ff: int          # per-expert hidden
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    activation: str = "silu"
    glu: bool = True
    n_slots: int = 0   # 0 => n_experts (no replication headroom)
    n_groups: int = 1  # EP dispatch groups (§Perf: set to the dp shard
    # count so ranking/capacity/buffers are group-local — GSPMD then keeps
    # dispatch on-shard instead of all-reducing a global capacity buffer)


class PlacementTables(NamedTuple):
    """Traced AWB placement: slot_of[e, r] = slot hosting replica r of e
    (padded by repeating replica 0); n_replicas[e] ≥ 1. Slots shard over the
    model axis; slot s holds expert expert_of[s]."""

    slot_of: jax.Array     # [E, max_rep] int32
    n_replicas: jax.Array  # [E] int32
    expert_of: jax.Array   # [n_slots] int32


def identity_placement(dims: MoEDims) -> PlacementTables:
    e = dims.n_experts
    return PlacementTables(
        slot_of=jnp.arange(e, dtype=jnp.int32)[:, None],
        n_replicas=jnp.ones((e,), jnp.int32),
        expert_of=jnp.arange(dims.n_slots or e, dtype=jnp.int32),
    )


def tables_from_placement(placement) -> PlacementTables:
    """Convert a ``core.moe_balance.ExpertPlacement`` to traced tables."""
    import numpy as np

    slots = np.asarray(placement.slots).reshape(-1)         # [n_slots]
    rrank = np.asarray(placement.replica_rank).reshape(-1)
    reps = np.asarray(placement.replica_count)
    e = reps.shape[0]
    max_rep = int(reps.max())
    slot_of = np.zeros((e, max_rep), np.int32)
    for s, (eid, r) in enumerate(zip(slots, rrank)):
        if eid >= 0:
            slot_of[eid, r] = s
    for eid in range(e):  # pad unused replica slots with replica 0
        slot_of[eid, reps[eid]:] = slot_of[eid, 0]
    return PlacementTables(jnp.asarray(slot_of), jnp.asarray(reps),
                           jnp.asarray(slots.astype(np.int32)))


def init_moe_params(key: jax.Array, dims: MoEDims) -> dict:
    e = dims.n_experts
    ks = jax.random.split(key, 4)
    p = {
        "router": common.dense_init(ks[0], (dims.d_model, e)),
        "w_in": jax.vmap(lambda k: common.dense_init(
            k, (dims.d_model, dims.d_ff)))(jax.random.split(ks[1], e)),
        "w_out": jax.vmap(lambda k: common.dense_init(
            k, (dims.d_ff, dims.d_model)))(jax.random.split(ks[2], e)),
    }
    if dims.glu:
        p["w_gate"] = jax.vmap(lambda k: common.dense_init(
            k, (dims.d_model, dims.d_ff)))(jax.random.split(ks[3], e))
    return p


def moe_forward(p: dict, dims: MoEDims, x: jax.Array,
                placement: Optional[PlacementTables] = None,
                capacity_override: Optional[int] = None,
                ) -> tuple[jax.Array, jax.Array]:
    """x: [B, S, d] -> (out, aux_loss). Capacity-dropped tokens pass through
    the residual (standard Switch behaviour). ``capacity_override`` forces a
    per-slot capacity (decode uses T*K: dropless)."""
    b, s, d = x.shape
    t = b * s
    e, k = dims.n_experts, dims.top_k
    n_slots = dims.n_slots or e
    g = dims.n_groups if t % max(dims.n_groups, 1) == 0 else 1
    tg = t // g
    xt = x.reshape(g, tg, d)
    act = common.activation_fn(dims.activation)

    logits = (xt @ p["router"].astype(xt.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                         # [G,Tg,E]
    gate_w, expert_ids = jax.lax.top_k(probs, k)                    # [G,Tg,K]
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # aux load-balance loss (Switch): E * sum_e f_e * p_e (global)
    me = probs.mean(axis=(0, 1))
    ce = jnp.zeros((e,), jnp.float32).at[expert_ids.reshape(-1)].add(
        1.0 / (t * k))
    aux = e * jnp.sum(me * ce)

    flat_e = expert_ids.reshape(g, tg * k)                          # [G,TKg]

    def rank_within(group_ids):
        """Arrival rank of each element within its (expert|slot) bucket,
        independently per dispatch group — sort-based, O(TK log TK),
        group-local so GSPMD keeps it on-shard."""
        order = jnp.argsort(group_ids, axis=-1, stable=True)
        sorted_g = jnp.take_along_axis(group_ids, order, axis=-1)
        seg_start = jax.vmap(
            lambda sg: jnp.searchsorted(sg, sg, side="left"))(sorted_g)
        pos_sorted = jnp.arange(group_ids.shape[-1])[None] - seg_start
        return jnp.zeros_like(pos_sorted).at[
            jnp.arange(g)[:, None], order].set(pos_sorted)

    pos_in_expert = rank_within(flat_e)                             # [G,TKg]

    if placement is None:
        placement = identity_placement(dims)
    # evil-expert chunking: replica r = arrival_rank % n_replicas
    reps = placement.n_replicas[flat_e]
    replica = pos_in_expert % reps
    max_rep = placement.slot_of.shape[1]
    flat_slot = placement.slot_of[flat_e, jnp.minimum(replica, max_rep - 1)]
    # rank within the *slot* (recount after replica assignment)
    pos_in_slot = rank_within(flat_slot)

    cap = capacity_override or max(1, int(
        dims.capacity_factor * tg * k / n_slots))
    keep = pos_in_slot < cap
    pos_c = jnp.minimum(pos_in_slot, cap - 1)

    # dispatch: buffers [G, n_slots, cap_g, d] — scatter stays group-local
    # (slots unsharded), then an explicit reshard moves slot shards to
    # their owner devices: the EP all-to-all (§Perf cell C; a scatter
    # straight into a tp-sharded dim makes GSPMD all-gather the updates
    # instead — 8× more wire)
    gi = jnp.broadcast_to(jnp.arange(g)[:, None], flat_slot.shape)
    buf = jnp.zeros((g, n_slots, cap, d), x.dtype)
    src = jnp.repeat(xt, k, axis=1) * keep[..., None].astype(x.dtype)
    if g > 1:
        buf = constrain(buf.at[gi, flat_slot, pos_c].add(src),
                        ("dp", None, None, None))
        buf = constrain(buf, ("dp", "tp", None, None))  # all-to-all
    else:  # baseline (paper-faithful global dispatch): direct EP scatter
        buf = constrain(buf.at[gi, flat_slot, pos_c].add(src),
                        (None, "tp", None, None))

    # expert compute with slot-gathered weights (replicas share weights);
    # the gather is static per placement and shards over the model axis
    w_in = p["w_in"][placement.expert_of].astype(x.dtype)
    w_out = p["w_out"][placement.expert_of].astype(x.dtype)
    h = constrain(jnp.einsum("gscd,sdf->gscf", buf, w_in),
                  ("dp", "tp", None, None))
    if dims.glu:
        w_gate = p["w_gate"][placement.expert_of].astype(x.dtype)
        h = act(constrain(jnp.einsum("gscd,sdf->gscf", buf, w_gate),
                          ("dp", "tp", None, None))) * h
    else:
        h = act(h)
    out_buf = constrain(jnp.einsum("gscf,sfd->gscd", h, w_out),
                        ("dp", "tp", None, None))                   # [G,S,C,d]
    if g > 1:
        out_buf = constrain(out_buf, ("dp", None, None, None))  # a2a back

    # combine (the adder tree): weighted gather back to tokens
    gathered = out_buf[gi, flat_slot, pos_c]                        # [G,TKg,d]
    gathered = gathered * (gate_w.reshape(g, tg * k)[..., None]
                           .astype(x.dtype)
                           * keep[..., None].astype(x.dtype))
    out = gathered.reshape(g, tg, k, d).sum(axis=2)
    return out.reshape(b, s, d), aux
