"""GQA attention layer: RoPE, optional QKV bias, QK-norm, local window,
KV cache for prefill/decode. Backend-switchable core (XLA / Pallas flash)."""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models import common
from repro.sharding.hints import constrain, get_flag


class AttnDims(NamedTuple):
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    qkv_bias: bool
    qk_norm: bool
    rope: bool
    rope_theta: float
    window: Optional[int]
    chunk: Optional[int] = None  # flash-style chunked XLA path (§Perf)


def init_attn_params(key: jax.Array, dims: AttnDims) -> dict:
    d, h, hkv, dh = dims.d_model, dims.n_heads, dims.n_kv_heads, dims.d_head
    ks = jax.random.split(key, 4)
    p = {
        "wq": common.dense_init(ks[0], (d, h * dh)),
        "wk": common.dense_init(ks[1], (d, hkv * dh)),
        "wv": common.dense_init(ks[2], (d, hkv * dh)),
        "wo": common.dense_init(ks[3], (h * dh, d)),
    }
    if dims.qkv_bias:
        p["bq"] = jnp.zeros((h * dh,), jnp.float32)
        p["bk"] = jnp.zeros((hkv * dh,), jnp.float32)
        p["bv"] = jnp.zeros((hkv * dh,), jnp.float32)
    if dims.qk_norm:
        p["q_norm"] = jnp.ones((dh,), jnp.float32)
        p["k_norm"] = jnp.ones((dh,), jnp.float32)
    return p


def _project_qkv(p: dict, dims: AttnDims, x: jax.Array, positions: jax.Array,
                 rope: bool = True):
    b, s, _ = x.shape
    q = x @ p["wq"].astype(x.dtype)
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if dims.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(b, s, dims.n_heads, dims.d_head)
    k = k.reshape(b, s, dims.n_kv_heads, dims.d_head)
    v = v.reshape(b, s, dims.n_kv_heads, dims.d_head)
    if dims.qk_norm:
        q = common.rmsnorm(q, p["q_norm"])
        k = common.rmsnorm(k, p["k_norm"])
    if dims.rope and rope:
        q = common.apply_rope(q, positions, dims.rope_theta)
        k = common.apply_rope(k, positions, dims.rope_theta)
    # canonical Megatron sharding: q heads over TP, kv replicated (GQA kv
    # counts rarely divide the model axis; scores inherit q's head sharding).
    # Decode with a sequence-sharded cache (distributed flash-decoding,
    # §Perf cell B) keeps q replicated so scores shard over the cache seq.
    if s == 1 and get_flag("kv_seq_shard"):
        q = constrain(q, ("dp", None, None, None))
    else:
        q = constrain(q, ("dp", None, "tp", None))
    k = constrain(k, ("dp", None, None, None))
    v = constrain(v, ("dp", None, None, None))
    return q, k, v


def attn_forward(p: dict, dims: AttnDims, x: jax.Array,
                 positions: Optional[jax.Array] = None,
                 causal: bool = True, backend: Optional[str] = None,
                 cross_kv: Optional[tuple] = None) -> jax.Array:
    """Full-sequence attention (training / encoder). x: [B, S, d].
    Cross-attention (cross_kv given) is position-free: no RoPE on q."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = _project_qkv(p, dims, x, positions, rope=cross_kv is None)
    if cross_kv is not None:
        k, v = cross_kv
        causal = False
    out = ops.attention(q, k, v, causal=causal, window=dims.window,
                        backend=backend, chunk=dims.chunk)
    out = constrain(out, ("dp", None, "tp", None))
    out = out.reshape(b, s, dims.n_heads * dims.d_head)
    return constrain(out @ p["wo"].astype(x.dtype), ("dp", None, None))


def cache_len(dims: AttnDims, max_seq: int) -> int:
    """Local-window layers keep a ring buffer of ``window`` entries — this
    is what makes hybrid archs (recurrentgemma) long_500k-capable."""
    return min(max_seq, dims.window) if dims.window else max_seq


def init_kv_cache(dims: AttnDims, batch: int, max_seq: int,
                  dtype=jnp.bfloat16) -> dict:
    shape = (batch, cache_len(dims, max_seq), dims.n_kv_heads, dims.d_head)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def attn_prefill(p: dict, dims: AttnDims, x: jax.Array, cache: dict,
                 backend: Optional[str] = None) -> tuple:
    """Prefill: attend causally over x, write K/V into the cache (ring
    layout for windowed layers: position s lives in slot s % W)."""
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = _project_qkv(p, dims, x, positions)
    w = cache["k"].shape[1]
    if s <= w:
        cache = {
            "k": jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0)),
            "v": jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0)),
        }
    else:  # keep the last w positions at slots (s % w) — static scatter
        idx = (jnp.arange(s - w, s) % w)
        cache = {
            "k": cache["k"].at[:, idx].set(k[:, -w:].astype(cache["k"].dtype)),
            "v": cache["v"].at[:, idx].set(v[:, -w:].astype(cache["v"].dtype)),
        }
    out = ops.attention(q, k, v, causal=True, window=dims.window,
                        backend=backend, chunk=dims.chunk)
    out = out.reshape(b, s, dims.n_heads * dims.d_head)
    return out @ p["wo"].astype(x.dtype), cache


def attn_decode(p: dict, dims: AttnDims, x: jax.Array, cache: dict,
                pos: jax.Array) -> tuple:
    """One-token decode. x: [B, 1, d]; ``pos`` scalar position. Attends over
    the static-length cache with position masking (the decode_32k lowering:
    full-cache attention every step). Windowed layers use the ring slot
    ``pos % W``; softmax is permutation-invariant so slot order is free."""
    b, _, _ = x.shape
    positions = jnp.broadcast_to(pos[None, None], (b, 1))
    q, k, v = _project_qkv(p, dims, x, positions)
    s_max = cache["k"].shape[1]
    slot = pos % s_max if dims.window else pos
    cache = {
        "k": jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0)),
        "v": jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0)),
    }
    kk, vv = cache["k"], cache["v"]
    groups = dims.n_heads // dims.n_kv_heads
    seq_sharded = bool(get_flag("kv_seq_shard")) and dims.window is None
    kk = jnp.repeat(kk, groups, axis=2).astype(jnp.float32)
    vv = jnp.repeat(vv, groups, axis=2).astype(jnp.float32)
    if seq_sharded:  # distributed flash-decoding: scores shard over seq
        kk = constrain(kk, ("dp", "tp", None, None))
        vv = constrain(vv, ("dp", "tp", None, None))
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kk)
    logits = logits * (dims.d_head ** -0.5)
    if seq_sharded:
        logits = constrain(logits, ("dp", None, None, "tp"))
    kpos = jnp.arange(s_max)
    # ring buffer: every written slot is within the window by construction;
    # `kpos <= pos` masks not-yet-written slots during warmup
    valid = kpos <= pos
    logits = jnp.where(valid[None, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vv).astype(x.dtype)
    out = out.reshape(b, 1, dims.n_heads * dims.d_head)
    return out @ p["wo"].astype(x.dtype), cache
