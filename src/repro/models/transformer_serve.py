"""Transformer serving: batched prefill + greedy decode over a static-shape KV
cache, mesh-ready (the decode path is the same ``decode_step`` the dry-run
lowers for the decode_32k / long_500k cells).
"""
from __future__ import annotations

from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tr


class ServeEngine:
    def __init__(self, cfg: tr.ModelConfig, params, max_seq: int = 256,
                 compute_dtype=jnp.float32):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.dtype = compute_dtype
        self._decode = jax.jit(
            lambda p, c, t, pos: tr.decode_step(
                cfg, p, c, t, pos, compute_dtype=compute_dtype))

    def generate(self, prompts: List[List[int]], max_new_tokens: int = 16,
                 source_embed: Optional[np.ndarray] = None,
                 ) -> List[List[int]]:
        """Greedy batched generation. Prompts are left-padded to a common
        length so positions align (static shapes end-to-end)."""
        b = len(prompts)
        plen = max(len(p) for p in prompts)
        toks = np.zeros((b, plen), np.int32)
        for i, p in enumerate(prompts):  # right-align
            toks[i, plen - len(p):] = p
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.encoder is not None:
            batch["source_embed"] = jnp.asarray(source_embed)

        logits, cache = tr.prefill(self.cfg, self.params, batch,
                                   max_seq=self.max_seq,
                                   compute_dtype=self.dtype)
        out = [list(p) for p in prompts]
        token = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        for step in range(max_new_tokens):
            for i in range(b):
                out[i].append(int(token[i]))
            if step == max_new_tokens - 1:
                break
            pos = jnp.int32(plen + step)
            logits, cache = self._decode(self.params, cache, token, pos)
            token = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return out
