"""Model assembly for the assigned architectures.

A model is a stack of *segments*; each segment is a repeating *unit* of
layers (e.g. RecurrentGemma's ``(rglru, rglru, attn) × 8``) scanned with
stacked parameters, so 80-layer models lower as one while-loop body. Layer
kinds:

  ``attn``      global causal GQA attention + dense MLP
  ``local``     windowed attention + dense MLP (hybrid archs)
  ``attn_moe``  attention + MoE FFN (AWB-balanced dispatch)
  ``rwkv``      RWKV-6 TimeMix + ChannelMix (attention-free)
  ``rglru``     RG-LRU recurrent block + dense MLP
  ``xattn``     decoder layer with cross-attention (enc-dec)
  ``enc``       bidirectional encoder layer + dense MLP

Three entry points per model: ``model_forward`` (training, full sequence),
``prefill`` (build cache), ``decode_step`` (one token). Caches are stacked
per segment so decode also scans.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import rwkv6 as rwkv_mod
from repro.models.attention import (AttnDims, attn_decode, attn_forward,
                                    attn_prefill, init_attn_params,
                                    init_kv_cache)
from repro.sharding.hints import constrain


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int
    capacity_factor: float = 1.25
    n_slots: int = 0  # 0 => n_experts; > n_experts enables AWB replication


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    n_layers: int
    max_source: int = 1500  # whisper audio frames after conv stem


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    segments: Tuple[Tuple[Tuple[str, ...], int], ...]
    d_head: int = 0              # 0 => d_model // n_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    rope: bool = True
    rope_theta: float = 1e4
    activation: str = "silu"
    glu: bool = True
    norm: str = "rmsnorm"
    moe: Optional[MoEConfig] = None
    window: Optional[int] = None
    encoder: Optional[EncoderConfig] = None
    frontend: Optional[str] = None   # audio | vision (stub per assignment)
    tie_embeddings: bool = False
    remat: bool = True
    d_rnn: int = 0               # 0 => d_model (rglru width)
    # §Perf knobs (paper-exact configs leave these at defaults)
    attn_chunk: Optional[int] = None   # flash-style chunked attention
    moe_groups: int = 1                # EP dispatch groups (≈ dp shards)
    sp_carry: bool = False             # shard the remat-saved residual
    # stream over the model axis (Megatron-SP-style activation memory)

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def rnn_width(self) -> int:
        return self.d_rnn or self.d_model

    @property
    def sub_quadratic(self) -> bool:
        """True when no layer needs unwindowed attention over the full
        sequence (long_500k eligibility)."""
        kinds = [k for unit, rep in self.segments for k in unit]
        return all(k in ("rwkv", "rglru", "local") for k in kinds)

    def attn_dims(self, window: Optional[int]) -> AttnDims:
        return AttnDims(self.d_model, self.n_heads, self.n_kv_heads,
                        self.head_dim, self.qkv_bias, self.qk_norm,
                        self.rope, self.rope_theta, window,
                        self.attn_chunk)

    @property
    def rwkv_dims(self) -> rwkv_mod.RWKVDims:
        return rwkv_mod.RWKVDims(self.d_model, self.n_heads, self.head_dim,
                                 self.d_ff)

    @property
    def rglru_dims(self) -> rglru_mod.RGLRUDims:
        return rglru_mod.RGLRUDims(self.d_model, self.rnn_width)

    @property
    def moe_dims(self) -> moe_mod.MoEDims:
        m = self.moe
        return moe_mod.MoEDims(self.d_model, m.d_expert, m.n_experts,
                               m.top_k, m.capacity_factor, self.activation,
                               self.glu, m.n_slots, self.moe_groups)


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def _init_layer(cfg: ModelConfig, kind: str, key: jax.Array) -> dict:
    ks = jax.random.split(key, 6)
    p = {"norm1": common.norm_params(cfg.norm, cfg.d_model)}
    if kind in ("attn", "attn_moe", "local", "xattn", "enc"):
        window = cfg.window if kind == "local" else None
        p["attn"] = init_attn_params(ks[0], cfg.attn_dims(window))
        p["norm2"] = common.norm_params(cfg.norm, cfg.d_model)
        if kind == "xattn":
            p["xnorm"] = common.norm_params(cfg.norm, cfg.d_model)
            p["xattn"] = init_attn_params(ks[1], cfg.attn_dims(None))
            p["norm3"] = common.norm_params(cfg.norm, cfg.d_model)
        if kind == "attn_moe":
            p["moe"] = moe_mod.init_moe_params(ks[2], cfg.moe_dims)
        else:
            p["mlp"] = mlp_mod.init_mlp_params(ks[2], cfg.d_model, cfg.d_ff,
                                               cfg.glu)
    elif kind == "rwkv":
        p["rwkv"] = rwkv_mod.init_rwkv_params(ks[0], cfg.rwkv_dims)
        p["norm2"] = common.norm_params(cfg.norm, cfg.d_model)
    elif kind == "rglru":
        p["rec"] = rglru_mod.init_rglru_params(ks[0], cfg.rglru_dims)
        p["norm2"] = common.norm_params(cfg.norm, cfg.d_model)
        p["mlp"] = mlp_mod.init_mlp_params(ks[1], cfg.d_model, cfg.d_ff,
                                           cfg.glu)
    else:
        raise ValueError(f"unknown layer kind {kind}")
    return p


def _init_unit(cfg: ModelConfig, unit: Tuple[str, ...], key: jax.Array
               ) -> dict:
    ks = jax.random.split(key, len(unit))
    return {f"l{i}": _init_layer(cfg, kind, ks[i])
            for i, kind in enumerate(unit)}


def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    ks = jax.random.split(key, len(cfg.segments) + 4)
    params = {
        "embed": jax.random.normal(ks[0], (cfg.vocab, cfg.d_model),
                                   jnp.float32) * 0.02,
        "final_norm": common.norm_params(cfg.norm, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = common.dense_init(ks[1], (cfg.d_model, cfg.vocab))
    for si, (unit, repeat) in enumerate(cfg.segments):
        seg_keys = jax.random.split(ks[2 + si], repeat)
        params[f"seg{si}"] = jax.vmap(
            lambda k, u=unit: _init_unit(cfg, u, k))(seg_keys)
    if cfg.encoder is not None:
        enc_unit = ("enc",)
        seg_keys = jax.random.split(ks[-1], cfg.encoder.n_layers)
        params["encoder"] = jax.vmap(
            lambda k: _init_unit(cfg, enc_unit, k))(seg_keys)
        params["enc_norm"] = common.norm_params(cfg.norm, cfg.d_model)
    return params


def param_specs(cfg: ModelConfig) -> dict:
    """ShapeDtypeStructs of the parameter pytree (no allocation)."""
    return jax.eval_shape(
        lambda k: init_params(cfg, k), jax.ShapeDtypeStruct((2,), jnp.uint32))


def count_params(cfg: ModelConfig) -> int:
    specs = param_specs(cfg)
    import numpy as np
    return int(sum(np.prod(s.shape) for s in jax.tree.leaves(specs)))


def active_params(cfg: ModelConfig) -> int:
    """Parameters touched per token (MoE: top_k of n_experts)."""
    total = count_params(cfg)
    if cfg.moe is None:
        return total
    m = cfg.moe
    n_moe_layers = sum(rep * sum(1 for k in unit if k == "attn_moe")
                      for unit, rep in cfg.segments)
    per_expert = cfg.d_model * m.d_expert * (3 if cfg.glu else 2)
    inactive = n_moe_layers * per_expert * (m.n_experts - m.top_k)
    return total - inactive


# ---------------------------------------------------------------------------
# Forward (training / full-sequence)
# ---------------------------------------------------------------------------

def _norm(cfg, p, x):
    return common.apply_norm(cfg.norm, x, p)


def _layer_fwd(cfg: ModelConfig, kind: str, p: dict, x: jax.Array,
               enc_out: Optional[jax.Array], backend: Optional[str]
               ) -> tuple[jax.Array, jax.Array]:
    aux = jnp.zeros((), jnp.float32)
    if kind in ("attn", "attn_moe", "local", "xattn", "enc"):
        window = cfg.window if kind == "local" else None
        causal = kind != "enc"
        h = attn_forward(p["attn"], cfg.attn_dims(window), _norm(cfg, p["norm1"], x),
                         causal=causal, backend=backend)
        x = x + h
        if kind == "xattn":
            b, s_enc, _ = enc_out.shape
            dims = cfg.attn_dims(None)
            enc_n = enc_out
            kproj = (enc_n @ p["xattn"]["wk"].astype(x.dtype)).reshape(
                b, s_enc, dims.n_kv_heads, dims.d_head)
            vproj = (enc_n @ p["xattn"]["wv"].astype(x.dtype)).reshape(
                b, s_enc, dims.n_kv_heads, dims.d_head)
            h = attn_forward(p["xattn"], dims, _norm(cfg, p["xnorm"], x),
                             causal=False, backend=backend,
                             cross_kv=(kproj, vproj))
            x = x + h
            mlp_norm = p["norm3"]
        else:
            mlp_norm = p["norm2"]
        if kind == "attn_moe":
            h, aux = moe_mod.moe_forward(p["moe"], cfg.moe_dims,
                                         _norm(cfg, mlp_norm, x))
        else:
            h = mlp_mod.mlp_forward(p["mlp"], _norm(cfg, mlp_norm, x),
                                    cfg.activation, cfg.glu)
        x = x + h
    elif kind == "rwkv":
        b = x.shape[0]
        st = rwkv_mod.init_rwkv_state(cfg.rwkv_dims, b)
        h, _, _ = rwkv_mod.rwkv_time_mix(p["rwkv"], cfg.rwkv_dims,
                                         _norm(cfg, p["norm1"], x),
                                         st["tm_x"], st["wkv"])
        x = x + h
        h, _ = rwkv_mod.rwkv_channel_mix(p["rwkv"], _norm(cfg, p["norm2"], x),
                                         st["cm_x"])
        x = x + h
    elif kind == "rglru":
        b = x.shape[0]
        st = rglru_mod.init_rglru_state(cfg.rglru_dims, b)
        h, _ = rglru_mod.rglru_forward(p["rec"], cfg.rglru_dims,
                                       _norm(cfg, p["norm1"], x), st)
        x = x + h
        h = mlp_mod.mlp_forward(p["mlp"], _norm(cfg, p["norm2"], x),
                                cfg.activation, cfg.glu)
        x = x + h
    else:
        raise ValueError(kind)
    return x, aux


def _unit_fwd(cfg: ModelConfig, unit: Tuple[str, ...], p: dict, x: jax.Array,
              enc_out, backend) -> tuple[jax.Array, jax.Array]:
    aux = jnp.zeros((), jnp.float32)
    for i, kind in enumerate(unit):
        x, a = _layer_fwd(cfg, kind, p[f"l{i}"], x, enc_out, backend)
        aux = aux + a
    if cfg.sp_carry:
        # remat saves the scan carry; shard it over the model axis so the
        # 80-layer activation stash is 1/TP the size (§Perf cell A)
        x = constrain(x, ("dp", None, "tp"))
    return x, aux


def _run_segments(cfg: ModelConfig, params: dict, x: jax.Array,
                  enc_out, backend) -> tuple[jax.Array, jax.Array]:
    aux_total = jnp.zeros((), jnp.float32)
    for si, (unit, repeat) in enumerate(cfg.segments):
        fn = functools.partial(_unit_fwd, cfg, unit, enc_out=enc_out,
                               backend=backend)

        def body(carry, seg_p, fn=fn):
            y, aux = fn(seg_p, carry)
            return y, aux

        if cfg.remat:
            body = jax.checkpoint(body)
        x, auxs = jax.lax.scan(lambda c, sp: body(c, sp), x,
                               params[f"seg{si}"])
        aux_total = aux_total + auxs.sum()
    return x, aux_total


def _encode(cfg: ModelConfig, params: dict, source_embed: jax.Array,
            backend) -> jax.Array:
    def body(carry, seg_p):
        y, _ = _unit_fwd(cfg, ("enc",), seg_p, carry, None, backend)
        return y, None

    x, _ = jax.lax.scan(body, source_embed, params["encoder"])
    return _norm(cfg, params["enc_norm"], x)


def _logits(cfg: ModelConfig, params: dict, x: jax.Array) -> jax.Array:
    x = _norm(cfg, params["final_norm"], x)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(x.dtype)
    return x @ head


def model_forward(cfg: ModelConfig, params: dict, batch: dict,
                  backend: Optional[str] = None,
                  compute_dtype=jnp.bfloat16) -> tuple[jax.Array, jax.Array]:
    """batch: {'tokens': [B,S] int32, optional 'source_embed': [B,T,d]}.
    Returns (logits [B,S,vocab], aux_loss)."""
    tokens = batch["tokens"]
    x = constrain(params["embed"].astype(compute_dtype)[tokens],
                  ("dp", None, None))
    enc_out = None
    if cfg.encoder is not None:
        enc_out = _encode(cfg, params,
                          batch["source_embed"].astype(compute_dtype),
                          backend)
    x, aux = _run_segments(cfg, params, x, enc_out, backend)
    return _logits(cfg, params, x), aux


# ---------------------------------------------------------------------------
# Cache init / prefill / decode
# ---------------------------------------------------------------------------

def _init_layer_cache(cfg: ModelConfig, kind: str, batch: int, max_seq: int,
                      dtype) -> dict:
    if kind in ("attn", "attn_moe", "local", "xattn", "enc"):
        window = cfg.window if kind == "local" else None
        seq = min(max_seq, cfg.window) if window else max_seq
        c = init_kv_cache(cfg.attn_dims(window), batch, max_seq, dtype)
        if kind == "xattn":
            src = cfg.encoder.max_source
            dims = cfg.attn_dims(None)
            c["xk"] = jnp.zeros((batch, src, dims.n_kv_heads, dims.d_head),
                                dtype)
            c["xv"] = jnp.zeros((batch, src, dims.n_kv_heads, dims.d_head),
                                dtype)
        return c
    if kind == "rwkv":
        return rwkv_mod.init_rwkv_state(cfg.rwkv_dims, batch)
    if kind == "rglru":
        return rglru_mod.init_rglru_state(cfg.rglru_dims, batch)
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> dict:
    cache = {}
    for si, (unit, repeat) in enumerate(cfg.segments):
        def one(_, unit=unit):
            return {f"l{i}": _init_layer_cache(cfg, kind, batch, max_seq,
                                               dtype)
                    for i, kind in enumerate(unit)}
        cache[f"seg{si}"] = jax.vmap(one)(jnp.arange(repeat))
    return cache


def _layer_prefill(cfg, kind, p, x, cache, enc_out, backend):
    if kind in ("attn", "attn_moe", "local", "xattn"):
        window = cfg.window if kind == "local" else None
        h, kv = attn_prefill(p["attn"], cfg.attn_dims(window),
                             _norm(cfg, p["norm1"], x),
                             {"k": cache["k"], "v": cache["v"]}, backend)
        cache = dict(cache, **kv)
        x = x + h
        if kind == "xattn":
            b, s_enc, _ = enc_out.shape
            dims = cfg.attn_dims(None)
            kproj = (enc_out @ p["xattn"]["wk"].astype(x.dtype)).reshape(
                b, s_enc, dims.n_kv_heads, dims.d_head)
            vproj = (enc_out @ p["xattn"]["wv"].astype(x.dtype)).reshape(
                b, s_enc, dims.n_kv_heads, dims.d_head)
            pad = cache["xk"].shape[1] - s_enc
            cache["xk"] = jnp.pad(kproj, ((0, 0), (0, pad), (0, 0), (0, 0))
                                  ).astype(cache["xk"].dtype)
            cache["xv"] = jnp.pad(vproj, ((0, 0), (0, pad), (0, 0), (0, 0))
                                  ).astype(cache["xv"].dtype)
            h = attn_forward(p["xattn"], dims, _norm(cfg, p["xnorm"], x),
                             causal=False, backend=backend,
                             cross_kv=(kproj, vproj))
            x = x + h
            mlp_norm = p["norm3"]
        else:
            mlp_norm = p["norm2"]
        if kind == "attn_moe":
            h, _ = moe_mod.moe_forward(p["moe"], cfg.moe_dims,
                                       _norm(cfg, mlp_norm, x))
        else:
            h = mlp_mod.mlp_forward(p["mlp"], _norm(cfg, mlp_norm, x),
                                    cfg.activation, cfg.glu)
        x = x + h
    elif kind == "rwkv":
        h, tm_x, wkv = rwkv_mod.rwkv_time_mix(
            p["rwkv"], cfg.rwkv_dims, _norm(cfg, p["norm1"], x),
            cache["tm_x"].astype(x.dtype), cache["wkv"])
        x = x + h
        h, cm_x = rwkv_mod.rwkv_channel_mix(
            p["rwkv"], _norm(cfg, p["norm2"], x),
            cache["cm_x"].astype(x.dtype))
        x = x + h
        cache = {"tm_x": tm_x.astype(jnp.float32),
                 "cm_x": cm_x.astype(jnp.float32), "wkv": wkv}
    elif kind == "rglru":
        h, st = rglru_mod.rglru_forward(p["rec"], cfg.rglru_dims,
                                        _norm(cfg, p["norm1"], x), cache)
        x = x + h
        h = mlp_mod.mlp_forward(p["mlp"], _norm(cfg, p["norm2"], x),
                                cfg.activation, cfg.glu)
        x = x + h
        cache = st
    else:
        raise ValueError(kind)
    return x, cache


def _seq_apply(cfg: ModelConfig, params: dict, cache: dict, x: jax.Array,
               enc_out, backend, layer_fn) -> tuple[jax.Array, dict]:
    """Scan ``layer_fn`` over every segment, threading caches."""
    new_cache = {}
    for si, (unit, repeat) in enumerate(cfg.segments):
        def body(carry, inp, unit=unit):
            seg_p, seg_c = inp
            y = carry
            out_c = {}
            for i, kind in enumerate(unit):
                y, c = layer_fn(cfg, kind, seg_p[f"l{i}"], y, seg_c[f"l{i}"],
                                enc_out, backend)
                out_c[f"l{i}"] = c
            return y, out_c

        x, seg_cache = jax.lax.scan(body, x,
                                    (params[f"seg{si}"], cache[f"seg{si}"]))
        new_cache[f"seg{si}"] = seg_cache
    return x, new_cache


def prefill(cfg: ModelConfig, params: dict, batch: dict,
            max_seq: int, backend: Optional[str] = None,
            compute_dtype=jnp.bfloat16) -> tuple[jax.Array, dict]:
    """Run the prompt, return (logits at last position, cache)."""
    tokens = batch["tokens"]
    b = tokens.shape[0]
    x = params["embed"].astype(compute_dtype)[tokens]
    enc_out = None
    if cfg.encoder is not None:
        enc_out = _encode(cfg, params,
                          batch["source_embed"].astype(compute_dtype),
                          backend)
    cache = init_cache(cfg, b, max_seq, compute_dtype)
    x, cache = _seq_apply(cfg, params, cache, x, enc_out, backend,
                          _layer_prefill)
    return _logits(cfg, params, x[:, -1:]), cache


def _layer_decode(cfg, kind, p, x, cache, pos, backend):
    if kind in ("attn", "attn_moe", "local", "xattn"):
        window = cfg.window if kind == "local" else None
        h, kv = attn_decode(p["attn"], cfg.attn_dims(window),
                            _norm(cfg, p["norm1"], x),
                            {"k": cache["k"], "v": cache["v"]}, pos)
        cache = dict(cache, **kv)
        x = x + h
        if kind == "xattn":
            dims = cfg.attn_dims(None)
            h = attn_forward(p["xattn"], dims, _norm(cfg, p["xnorm"], x),
                             causal=False, backend=backend,
                             cross_kv=(cache["xk"].astype(x.dtype),
                                       cache["xv"].astype(x.dtype)))
            x = x + h
            mlp_norm = p["norm3"]
        else:
            mlp_norm = p["norm2"]
        if kind == "attn_moe":
            b, s, _ = x.shape
            h, _ = moe_mod.moe_forward(
                p["moe"], cfg.moe_dims, _norm(cfg, mlp_norm, x),
                capacity_override=b * s * cfg.moe.top_k)  # decode: dropless
        else:
            h = mlp_mod.mlp_forward(p["mlp"], _norm(cfg, mlp_norm, x),
                                    cfg.activation, cfg.glu)
        x = x + h
        return x, cache
    # recurrent kinds: decode == prefill with S=1
    return _layer_prefill(cfg, kind, p, x, cache, None, backend)


def decode_step(cfg: ModelConfig, params: dict, cache: dict,
                token: jax.Array, pos: jax.Array,
                backend: Optional[str] = None,
                compute_dtype=jnp.bfloat16) -> tuple[jax.Array, dict]:
    """token: [B] int32; pos: scalar int32. Returns (logits [B,1,V], cache)."""
    x = params["embed"].astype(compute_dtype)[token][:, None]

    def layer_fn(cfg_, kind, p, y, c, enc_out, be):
        return _layer_decode(cfg_, kind, p, y, c, pos, be)

    x, cache = _seq_apply(cfg, params, cache, x, None, backend, layer_fn)
    return _logits(cfg, params, x), cache
