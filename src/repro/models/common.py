"""Shared model primitives: norms, RoPE, initializers."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * weight).astype(dtype)


def layernorm(x: jax.Array, weight: jax.Array, bias: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * weight + bias).astype(dtype)


def apply_norm(kind: str, x: jax.Array, p: dict) -> jax.Array:
    if kind == "rmsnorm":
        return rmsnorm(x, p["w"])
    return layernorm(x, p["w"], p["b"])


def norm_params(kind: str, d: int) -> dict:
    if kind == "rmsnorm":
        return {"w": jnp.ones((d,), jnp.float32)}
    return {"w": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}


def rope_freqs(d_head: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, jnp.float32) / d_head))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, D]; positions: [..., S] (broadcastable)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                        # [D/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, D/2]
    cos = jnp.cos(angles)[..., None, :]                 # [..., S, 1, D/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def dense_init(key: jax.Array, shape, scale: float | None = None) -> jax.Array:
    fan_in = shape[0]
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale)


def activation_fn(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": jax.nn.gelu,
        "relu": jax.nn.relu,
        "relu2": lambda x: jnp.square(jax.nn.relu(x)),
        "tanh": jnp.tanh,
    }[name]
