"""LM substrate: the assigned architectures as composable JAX modules."""
from repro.models.transformer import (  # noqa: F401
    ModelConfig,
    MoEConfig,
    EncoderConfig,
    init_params,
    model_forward,
    init_cache,
    prefill,
    decode_step,
    param_specs,
    count_params,
)
