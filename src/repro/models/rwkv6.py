"""RWKV-6 "Finch" block: data-dependent-decay linear attention (arXiv:
2404.05892). Attention-free: TimeMix (wkv recurrence) + ChannelMix.

The wkv state recurrence runs as ``lax.scan`` over time for training and a
single state update for decode (O(1) per token — this is why rwkv6 runs the
``long_500k`` cell). The state math per head (d_k = d_v = head dim):

    S_t = diag(w_t) S_{t-1} + k_tᵀ v_t
    o_t = r_t (S_{t-1} + diag(u) k_tᵀ v_t)

with w_t = exp(-exp(decay_t)) data-dependent per channel (DDLerp + LoRA).

Accounting note (EXPERIMENTS.md §Roofline): the scanned wkv body is <1% of
layer FLOPs (outer products, d_head² per token vs d·d_ff matmuls); the
dominant compute is the dense projections, which the roofline extrapolation
counts exactly.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import common
from repro.sharding.hints import constrain


class RWKVDims(NamedTuple):
    d_model: int
    n_heads: int
    d_head: int
    d_ff: int
    lora_r: int = 32


def init_rwkv_params(key: jax.Array, dims: RWKVDims) -> dict:
    d, h, dh = dims.d_model, dims.n_heads, dims.d_head
    ks = jax.random.split(key, 16)
    p = {
        # DDLerp mix coefficients (token-shift interpolation)
        "mu_x": jnp.full((d,), 0.5, jnp.float32),
        "mu": jnp.stack([jnp.full((d,), 0.5, jnp.float32)] * 5),  # r,k,v,w,g
        "lora_a": common.dense_init(ks[0], (d, 5 * dims.lora_r), 0.01),
        "lora_b": common.dense_init(ks[1], (5, dims.lora_r, d), 0.01),
        # projections
        "wr": common.dense_init(ks[2], (d, h * dh)),
        "wk": common.dense_init(ks[3], (d, h * dh)),
        "wv": common.dense_init(ks[4], (d, h * dh)),
        "wg": common.dense_init(ks[5], (d, h * dh)),
        "wo": common.dense_init(ks[6], (h * dh, d)),
        # decay: w0 + lora
        "w0": jnp.full((h * dh,), -5.0, jnp.float32),
        "wa": common.dense_init(ks[7], (d, dims.lora_r), 0.01),
        "wb": common.dense_init(ks[8], (dims.lora_r, h * dh), 0.01),
        # per-channel bonus
        "u": jnp.zeros((h, dh), jnp.float32),
        "ln_x": jnp.ones((h * dh,), jnp.float32),  # group-norm on output
        # channel mix
        "cm_mu_k": jnp.full((d,), 0.5, jnp.float32),
        "cm_mu_r": jnp.full((d,), 0.5, jnp.float32),
        "cm_wk": common.dense_init(ks[9], (d, dims.d_ff)),
        "cm_wv": common.dense_init(ks[10], (dims.d_ff, d)),
        "cm_wr": common.dense_init(ks[11], (d, d)),
    }
    return p


def _ddlerp(p, x, x_prev):
    """Data-dependent token-shift mixing -> (xr, xk, xv, xw, xg)."""
    delta = x_prev - x
    xx = x + delta * p["mu_x"].astype(x.dtype)
    lo = jnp.tanh(xx @ p["lora_a"].astype(x.dtype))        # [B,S,5r]
    b, s, _ = x.shape
    lo = lo.reshape(b, s, 5, -1)
    mixes = p["mu"].astype(x.dtype) + jnp.einsum(
        "bsfr,frd->bsfd", lo, p["lora_b"].astype(x.dtype))  # [B,S,5,d]
    return [x + delta * mixes[:, :, i] for i in range(5)]


def _wkv_scan(r, k, v, w, u, state):
    """r,k,v: [B,S,H,dh]; w: [B,S,H,dh] decay in (0,1); state [B,H,dh,dh].
    Returns (out [B,S,H,dh], new_state)."""
    def step(s_prev, inp):
        rt, kt, vt, wt = inp  # [B,H,dh]
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        out = jnp.einsum("bhk,bhkv->bhv", rt,
                         s_prev + u[None, :, :, None] * kv)
        s_new = wt[..., None] * s_prev + kv
        return s_new, out

    xs = (r.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
          v.transpose(1, 0, 2, 3), w.transpose(1, 0, 2, 3))
    state, out = jax.lax.scan(step, state, xs)
    return out.transpose(1, 0, 2, 3), state


def rwkv_time_mix(p: dict, dims: RWKVDims, x: jax.Array,
                  x_prev: jax.Array, state: jax.Array) -> tuple:
    """x: [B,S,d]; x_prev: [B,1,d] last token of previous chunk;
    state: [B,H,dh,dh]. Returns (out, new_x_prev, new_state)."""
    b, s, d = x.shape
    h, dh = dims.n_heads, dims.d_head
    shifted = jnp.concatenate([x_prev.astype(x.dtype), x[:, :-1]], axis=1)
    xr, xk, xv, xw, xg = _ddlerp(p, x, shifted)

    r = constrain((xr @ p["wr"].astype(x.dtype)).reshape(b, s, h, dh),
                  ("dp", None, "tp", None))
    k = constrain((xk @ p["wk"].astype(x.dtype)).reshape(b, s, h, dh),
                  ("dp", None, "tp", None))
    v = constrain((xv @ p["wv"].astype(x.dtype)).reshape(b, s, h, dh),
                  ("dp", None, "tp", None))
    g = jax.nn.silu(xg @ p["wg"].astype(x.dtype))
    decay = p["w0"].astype(jnp.float32) + (
        jnp.tanh(xw.astype(jnp.float32) @ p["wa"]) @ p["wb"])
    w = jnp.exp(-jnp.exp(decay)).reshape(b, s, h, dh).astype(jnp.float32)

    out, state = _wkv_scan(r.astype(jnp.float32), k.astype(jnp.float32),
                           v.astype(jnp.float32), w,
                           p["u"].astype(jnp.float32),
                           state.astype(jnp.float32))
    out = out.reshape(b, s, h * dh)
    # per-head group norm
    out = out.reshape(b, s, h, dh)
    out = out * jax.lax.rsqrt(jnp.mean(out * out, -1, keepdims=True) + 1e-6)
    out = out.reshape(b, s, h * dh) * p["ln_x"]
    out = (out.astype(x.dtype) * g) @ p["wo"].astype(x.dtype)
    return out, x[:, -1:], state


def rwkv_channel_mix(p: dict, x: jax.Array, x_prev: jax.Array) -> tuple:
    shifted = jnp.concatenate([x_prev.astype(x.dtype), x[:, :-1]], axis=1)
    xk = x + (shifted - x) * p["cm_mu_k"].astype(x.dtype)
    xr = x + (shifted - x) * p["cm_mu_r"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(constrain(
        xk @ p["cm_wk"].astype(x.dtype), ("dp", None, "tp"))))
    kv = constrain(k @ p["cm_wv"].astype(x.dtype), ("dp", None, None))
    return jax.nn.sigmoid(xr @ p["cm_wr"].astype(x.dtype)) * kv, x[:, -1:]


def init_rwkv_state(dims: RWKVDims, batch: int) -> dict:
    return {
        "tm_x": jnp.zeros((batch, 1, dims.d_model), jnp.float32),
        "cm_x": jnp.zeros((batch, 1, dims.d_model), jnp.float32),
        "wkv": jnp.zeros((batch, dims.n_heads, dims.d_head, dims.d_head),
                         jnp.float32),
    }
