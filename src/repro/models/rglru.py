"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Block: x -> (linear gate branch: GeLU) ⊙ (linear -> causal depthwise conv1d
width 4 -> RG-LRU) -> linear out.

RG-LRU per channel:
    r_t = σ(W_a x_t + b_a)        (recurrence gate)
    i_t = σ(W_x x_t + b_x)        (input gate)
    a_t = a^(c·r_t),  a = σ(Λ)    (c = 8)
    h_t = a_t h_{t-1} + sqrt(1 - a_t²) · (i_t ⊙ x_t)

Runs as ``lax.scan`` over time; O(1) state per token (long_500k-capable).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import common
from repro.sharding.hints import constrain

_C = 8.0
CONV_WIDTH = 4


class RGLRUDims(NamedTuple):
    d_model: int
    d_rnn: int


def init_rglru_params(key: jax.Array, dims: RGLRUDims) -> dict:
    d, dr = dims.d_model, dims.d_rnn
    ks = jax.random.split(key, 6)
    # Λ init so that a = σ(Λ)^c spreads over (0.9, 0.999)
    lam = jax.random.uniform(ks[0], (dr,), jnp.float32, 2.0, 6.0)
    return {
        "w_x": common.dense_init(ks[1], (d, dr)),
        "w_gate_branch": common.dense_init(ks[2], (d, dr)),
        "conv_w": common.dense_init(ks[3], (CONV_WIDTH, dr), 0.1),
        "conv_b": jnp.zeros((dr,), jnp.float32),
        "lam": lam,
        "w_a": common.dense_init(ks[4], (dr, dr)),
        "b_a": jnp.zeros((dr,), jnp.float32),
        "w_i": common.dense_init(ks[5], (dr, dr)),
        "b_i": jnp.zeros((dr,), jnp.float32),
        "w_out": common.dense_init(jax.random.fold_in(key, 7), (dr, d)),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 conv_state: jax.Array) -> tuple:
    """Depthwise causal conv width 4. x: [B,S,dr]; conv_state: [B,3,dr]
    (the previous 3 inputs). Returns (y, new_conv_state)."""
    xp = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
            for i in range(CONV_WIDTH))
    new_state = xp[:, -(CONV_WIDTH - 1):].astype(jnp.float32)
    return y + b.astype(x.dtype), new_state


def _lru_scan(xs: jax.Array, a_t: jax.Array, gated: jax.Array,
              h0: jax.Array) -> tuple:
    """h_t = a_t h_{t-1} + sqrt(1-a_t²) gated_t, scanned over S."""
    def step(h, inp):
        a, g = inp
        h = a * h + jnp.sqrt(jnp.maximum(1.0 - a * a, 0.0)) * g
        return h, h

    h_last, hs = jax.lax.scan(
        step, h0, (a_t.transpose(1, 0, 2), gated.transpose(1, 0, 2)))
    return hs.transpose(1, 0, 2), h_last


def rglru_forward(p: dict, dims: RGLRUDims, x: jax.Array,
                  state: dict) -> tuple:
    """x: [B,S,d]; state {'h': [B,dr], 'conv': [B,3,dr]}."""
    gate = jax.nn.gelu(constrain(x @ p["w_gate_branch"].astype(x.dtype),
                                 ("dp", None, "tp")))
    u = constrain(x @ p["w_x"].astype(x.dtype), ("dp", None, "tp"))
    u, conv_state = _causal_conv(u, p["conv_w"], p["conv_b"], state["conv"])

    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ p["w_a"] + p["b_a"])
    i = jax.nn.sigmoid(uf @ p["w_i"] + p["b_i"])
    log_a = -_C * r * jax.nn.softplus(p["lam"])   # log σ(Λ)^(c·r) (stable)
    a_t = jnp.exp(log_a)
    hs, h_last = _lru_scan(uf, a_t, i * uf, state["h"].astype(jnp.float32))

    out = (hs.astype(x.dtype) * gate) @ p["w_out"].astype(x.dtype)
    return out, {"h": h_last, "conv": conv_state}


def init_rglru_state(dims: RGLRUDims, batch: int) -> dict:
    return {
        "h": jnp.zeros((batch, dims.d_rnn), jnp.float32),
        "conv": jnp.zeros((batch, CONV_WIDTH - 1, dims.d_rnn), jnp.float32),
    }
