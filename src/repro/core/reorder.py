"""Locality-aware row remapping (islandization) ahead of schedule building.

AWB-GCN's third autotuning technique — row remapping — balances *load*;
I-GCN (PAPERS.md) shows remapping for *locality* (clustering connected hubs
into "islands") beats pure load balancing on power-law graphs, because the
gather path's cost is dominated by cache behavior: consecutive schedule
slots that fetch the same (or nearby) B rows hit cache, scattered ones
miss. This module produces **row permutations** the tuner can accept or
reject per graph (``tuning.space`` exposes them as the ``reorder`` axis):

* ``degree`` — rows sorted by descending nnz. Hub rows become adjacent, so
  their (heavily shared) hub neighborhoods are gathered close in time.
* ``island`` — BFS islandization: repeatedly seed an island at the
  highest-degree unvisited vertex and grow it breadth-first over the
  undirected structure (capped at ``ISLAND_CAP`` rows). Rows of one island
  share neighborhoods by construction — I-GCN's locality clustering,
  realized as a static permutation the schedule builder consumes.

Only **rows** are permuted (``A_p = P·A``); columns — and therefore the
dense operand — stay put. The executor un-permutes output rows with the
inverse permutation, so results are bit-identical to the unpermuted graph
(the balanced schedule emits each row's entries in ascending-column order
and evil-row chunk boundaries depend only on per-row nnz, so per-row f32
accumulation order is permutation-invariant; ``tests/test_reorder.py``
pins this).

Conventions: ``perm[new_row] = old_row`` (``A_p[i] = A[perm[i]]``) and
``inv[old_row] = new_row``; un-permuting an output is ``out_p[inv]``.
"""

from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.core import csc as fmt
from repro.core.schedule import Schedule

#: the reorder axis: identity plus the two permutation strategies.
REORDER_NONE = "none"
REORDER_DEGREE = "degree"
REORDER_ISLAND = "island"
REORDER_STRATEGIES = (REORDER_DEGREE, REORDER_ISLAND)

#: island size cap: bounds one BFS island so a giant connected component still
#: yields many cache-reach-sized clusters instead of one global BFS order.
ISLAND_CAP = 4096

#: f32 elements per 64-byte cache line — the granularity of the gather
#: locality estimate below.
_LINE_F32 = 16


def _clean_rows_cols(a: fmt.COO) -> Tuple[np.ndarray, np.ndarray]:
    row = np.asarray(a.row)
    col = np.asarray(a.col)
    keep = row != fmt.PAD_IDX
    if not keep.all():
        row, col = row[keep], col[keep]
    return row.astype(np.int64), col.astype(np.int64)


def invert_permutation(perm: np.ndarray) -> np.ndarray:
    """``inv`` with ``inv[perm] == arange`` (validates ``perm`` is a
    permutation — a corrupted store entry must fail here, not execute)."""
    perm = np.asarray(perm, np.int64)
    m = perm.shape[0]
    inv = np.full(m, -1, np.int32)
    if perm.size and (perm.min() < 0 or perm.max() >= m):
        raise ValueError("not a permutation: index out of range")
    inv[perm] = np.arange(m, dtype=np.int32)
    if (inv < 0).any():
        raise ValueError("not a permutation: duplicate/missing indices")
    return inv


def degree_permutation(a: fmt.COO) -> np.ndarray:
    """Rows by descending nnz, ties in ascending row id (stable — the
    permutation is a pure function of graph content)."""
    row, _ = _clean_rows_cols(a)
    deg = np.bincount(row, minlength=a.shape[0])
    return np.argsort(-deg, kind="stable").astype(np.int32)


def island_permutation(a: fmt.COO, island_cap: int = ISLAND_CAP) -> np.ndarray:
    """BFS islandization (I-GCN): seed at the highest-degree unvisited
    vertex, grow breadth-first over the undirected structure until the
    island holds ``island_cap`` rows, repeat. Frontier expansion is
    vectorized over the CSR neighbor lists; ties resolve in ascending id,
    so the permutation is deterministic. Falls back to the degree sort for
    non-square operands (no vertex identity to traverse)."""
    m, n = a.shape
    if m != n:
        return degree_permutation(a)
    row, col = _clean_rows_cols(a)
    # undirected neighbor structure: out- and in-edges both connect
    src = np.concatenate([row, col])
    dst = np.concatenate([col, row])
    order = np.argsort(src, kind="stable")
    src, dst = src[order], dst[order]
    counts = np.bincount(src, minlength=m)
    indptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
    deg = np.bincount(row, minlength=m)
    seeds = np.argsort(-deg, kind="stable")

    perm = np.empty(m, np.int32)
    visited = np.zeros(m, bool)
    pos = 0
    for s in seeds:
        if visited[s]:
            continue
        visited[s] = True
        perm[pos] = s
        pos += 1
        start = pos - 1
        frontier = np.asarray([s], np.int64)
        while frontier.size and pos - start < island_cap:
            cnt = counts[frontier]
            total = int(cnt.sum())
            if total == 0:
                break
            # gather the frontier's concatenated neighbor lists in one shot
            base = np.repeat(indptr[frontier], cnt)
            offs = np.arange(total, dtype=np.int64) - np.repeat(
                np.cumsum(cnt) - cnt, cnt
            )
            nbr = np.unique(dst[base + offs])
            nbr = nbr[~visited[nbr]]
            room = island_cap - (pos - start)
            nbr = nbr[:room]
            if nbr.size == 0:
                break
            visited[nbr] = True
            perm[pos : pos + nbr.size] = nbr
            pos += nbr.size
            frontier = nbr
    assert pos == m
    return perm


def permutation(
    a: fmt.COO, strategy: str
) -> Tuple[Optional[np.ndarray], Optional[np.ndarray]]:
    """(perm, inv) for one reorder strategy; ``(None, None)`` for
    ``"none"`` (identity — no permutation is applied at all)."""
    if strategy == REORDER_NONE:
        return None, None
    if strategy == REORDER_DEGREE:
        perm = degree_permutation(a)
    elif strategy == REORDER_ISLAND:
        perm = island_permutation(a)
    else:
        raise ValueError(
            f"unknown reorder strategy {strategy!r}; expected one of "
            f"{(REORDER_NONE,) + REORDER_STRATEGIES}"
        )
    return perm, invert_permutation(perm)


def schedule_locality(
    sched: Schedule, *, window: int = 256, max_windows: int = 64
) -> float:
    """Estimated distinct cache lines touched per gather slot, in
    ``[1/16, 1]`` — the locality term of the tuner's cycle model.

    Samples up to ``max_windows`` windows of ``window`` consecutive slots
    from the schedule's gather stream and counts distinct 64-byte lines of
    B (16 f32 rows… of the *row index space*: two slots within 16 rows of
    each other share a line for kdim=1 and still share L2 reach for real
    widths, and an *identical* row is a guaranteed hit at any width — both
    effects shrink this count). Lower is better; a permutation whose
    estimate does not beat the identity schedule's cannot pay for itself
    and is pruned before timing (``tuning.runner.prune_sweep``)."""
    k = sched.nnz_per_step
    cb = sched.cols_per_block
    cblk = np.repeat(sched.col_block.astype(np.int64), k)
    gcol = np.minimum(cblk * cb + sched.local_col, sched.shape[1] - 1)
    lines = gcol // _LINE_F32
    s = lines.shape[0]
    if s <= window:
        return len(np.unique(lines)) / max(1, s)
    n_win = int(min(max_windows, s // window))
    starts = np.linspace(0, s - window, n_win).astype(np.int64)
    total = sum(len(np.unique(lines[st : st + window])) for st in starts)
    return total / (n_win * window)
