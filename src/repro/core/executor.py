"""ScheduleExecutor — the converged AWB configuration as a first-class,
cached, device-resident artifact (DESIGN.md §3).

AWB-GCN's engine "converges, then reuses the ideal configuration" (§IV):
the balancing effort is paid once per graph, and every subsequent round and
layer replays the converged plan. The seed realization re-paid pieces of
that cost on every call — ``spmm_balanced`` re-converted numpy schedule
arrays to jnp per invocation, ``make_spmm_fn`` rebuilt both schedules per
call site, and the routing one-hots spanned the whole matrix width. This
module closes the loop:

* ``ScheduleExecutor`` uploads a ``Schedule``'s arrays to the device exactly
  once at construction and exposes jitted closures: ``spmm(b) = A @ b``
  (fused-gather VPU routing or step-scanned one-hot MXU routing, chosen by
  ``select_routing``'s cost model) and a jitted whole-GCN ``forward``.
* ``get_executor(a, ...)`` / ``get_schedule(a, ...)`` cache by **graph
  fingerprint** (shape, nnz, content hash of indices+values): repeated calls
  on the same graph hit the cache and perform zero schedule rebuilds and
  zero host→device transfers.
* ``autotune(a, b_shape)`` sweeps (nnz_per_step, rows_per_window,
  cols_per_block, ktile), measures the jitted executor on this host, picks
  the fastest configuration, and caches it alongside the schedule — the
  paper's autotuner loop with wall-clock as the objective.

Routing paths
-------------
``gather``  — per-slot ``jnp.take`` of B rows + one fused scatter-add
              straight into output rows (``row_map∘slot`` precomposed at
              upload time). Routing work scales with the slot count alone;
              the right choice for ultra-sparse operands and the only
              sensible choice off-TPU.
``onehot``  — a ``lax.scan`` over steps replaying the Pallas kernel's MXU
              contractions (one-hot gather [K, CB] @ B-block, one-hot
              scatter [K, R]ᵀ @ contributions). Routing work scales with
              K·CB per step — viable only with a capped ``cols_per_block``;
              kept exactly kernel-shaped so it doubles as the measurable
              stand-in for the dense-routing Pallas path in benchmarks and
              equivalence tests.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from collections import OrderedDict
from typing import Callable, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import csc as fmt
from repro.core.schedule import (Schedule, auto_cols_per_block,
                                 build_balanced_schedule,
                                 build_naive_schedule)

GATHER = "gather"
ONEHOT = "onehot"

# cost-model constants (v5e-class core): 128×128 MXU MAC/cycle, and a
# dynamic-gather bandwidth proxy for VMEM row fetches on the VPU path
_MXU_MACS_PER_CYCLE = 16384
_GATHER_BYTES_PER_CYCLE = 512


def routing_cost_model(k: int, cb: int, r: int, ktile: int = 128) -> dict:
    """Estimated per-step cycles of each routing path (relative units).

    one-hot: two MXU contractions, [K, CB] @ [CB, ktile] and
    [K, R]ᵀ @ [K, ktile] → K·(CB+R)·ktile MACs.
    gather: K dynamic row fetches of a ktile-wide f32 row (latency/bandwidth
    bound on the VPU) + the same one-hot scatter contraction.
    """
    onehot = k * (cb + r) * ktile / _MXU_MACS_PER_CYCLE
    gather = (k * ktile * 4 / _GATHER_BYTES_PER_CYCLE
              + k * r * ktile / _MXU_MACS_PER_CYCLE)
    return {ONEHOT: onehot, GATHER: gather}


def select_routing(k: int, cb: int, r: int, ktile: int = 128) -> str:
    """Pick the cheaper routing for one operand: one-hot MXU routing wins
    when the column block is capped small; gather wins when the block spans
    a wide (ultra-sparse) operand."""
    cost = routing_cost_model(k, cb, r, ktile)
    return ONEHOT if cost[ONEHOT] <= cost[GATHER] else GATHER


def graph_fingerprint(a: fmt.COO) -> str:
    """Content hash of a sparse operand — the schedule-cache key.

    Hashes shape, true nnz, and the index/value bytes of real (non-PAD)
    entries, so two COOs describing the same matrix — padded or not — map
    to the same converged configuration.
    """
    row = np.asarray(a.row)
    col = np.asarray(a.col)
    val = np.asarray(a.val)
    if (row == fmt.PAD_IDX).any():
        keep = row != fmt.PAD_IDX
        row, col, val = row[keep], col[keep], val[keep]
    h = hashlib.blake2b(digest_size=16)
    h.update(repr((a.shape, int(row.shape[0]))).encode())
    h.update(row.tobytes())
    h.update(col.tobytes())
    h.update(val.tobytes())
    return h.hexdigest()


# step-major device copies of schedule arrays, shared between
# ScheduleExecutor and the Pallas kernel wrapper so one schedule is
# uploaded once no matter who consumes it. Identity-keyed, bounded LRU.
_DEVICE_STEPS: "OrderedDict[int, tuple]" = OrderedDict()
_DEVICE_STEPS_CAP = 32


def device_step_arrays(sched: Schedule) -> dict:
    """Step-major jnp arrays of one schedule — ``val``/``lrow``/``lcol``
    reshaped [n_steps, K], ``win``/``cblk`` per step, ``row_map`` — uploaded
    to device once per schedule instance and memoized (bounded LRU)."""
    key = id(sched)
    hit = _DEVICE_STEPS.get(key)
    if hit is not None and hit[0] is sched:
        _DEVICE_STEPS.move_to_end(key)
        return hit[1]
    n_steps, k = sched.n_steps, sched.nnz_per_step
    arrs = {
        "val": jnp.asarray(sched.val.reshape(n_steps, k)),
        "lrow": jnp.asarray(sched.local_row.reshape(n_steps, k)),
        "lcol": jnp.asarray(sched.local_col.reshape(n_steps, k)),
        "win": jnp.asarray(sched.win_id),
        "cblk": jnp.asarray(sched.col_block),
        "row_map": jnp.asarray(sched.row_map),
    }
    _DEVICE_STEPS[key] = (sched, arrs)
    if len(_DEVICE_STEPS) > _DEVICE_STEPS_CAP:
        _DEVICE_STEPS.popitem(last=False)
    return arrs


class ScheduleExecutor:
    """Device-resident executor of one converged AWB schedule.

    Construction uploads every schedule array to the default device once;
    the jitted closures capture those arrays, so repeated ``spmm``/
    ``forward`` calls move only the dense operand.
    """

    def __init__(self, sched: Schedule, *, ktile: int = 128,
                 routing: Optional[str] = None,
                 slot_chunk: int = 1 << 18):
        self.sched = sched
        self.ktile = ktile
        m, n = sched.shape
        k = sched.nnz_per_step
        r = sched.rows_per_window
        cb = sched.cols_per_block
        self.routing = routing or select_routing(k, cb, r, ktile)

        # ---- one-time host-side precompute + host→device upload ----------
        # only the selected routing's representation is built/uploaded
        if self.routing == GATHER:
            # per-slot global column and output row (row_map ∘ slot
            # precomposed: the scatter epilogue folds into the main scatter
            # — padding slots carry val == 0, so a clamped target row
            # accumulates nothing)
            win_slot = np.repeat(sched.win_id.astype(np.int64), k)
            cblk_slot = np.repeat(sched.col_block.astype(np.int64), k)
            gcol = np.minimum(cblk_slot * cb + sched.local_col, n - 1)
            slot = win_slot * r + sched.local_row
            tgt = np.maximum(sched.row_map[slot], 0).astype(np.int32)

            # pad the flat slot stream to a whole number of chunks so the
            # fused gather path can bound its [chunk, kdim] intermediate
            s_total = gcol.shape[0]
            self._slot_chunk = int(min(slot_chunk, max(1, s_total)))
            pad = (-s_total) % self._slot_chunk
            self._n_chunks = (s_total + pad) // self._slot_chunk

            def _chunked(x, fill):
                return jnp.asarray(
                    np.concatenate([x, np.full(pad, fill, x.dtype)])
                    .reshape(self._n_chunks, self._slot_chunk))

            self._gcol = _chunked(gcol.astype(np.int32), 0)
            self._tgt = _chunked(tgt, 0)
            self._val = _chunked(sched.val, 0.0)
        else:
            # step-major arrays (shared with the Pallas kernel wrapper —
            # one upload per schedule no matter who consumes it)
            self._steps = device_step_arrays(sched)

        self._spmm_impl = (self._gather_impl if self.routing == GATHER
                           else self._onehot_impl)
        self._spmm = jax.jit(self._spmm_impl)
        self._forward = jax.jit(self._forward_impl)

    # ---- public API --------------------------------------------------------

    def spmm(self, b: jax.Array) -> jax.Array:
        """C = A @ B through the device-resident converged schedule."""
        if b.shape[0] != self.sched.shape[1]:
            raise ValueError(
                f"operand has {b.shape[0]} rows; schedule expects "
                f"{self.sched.shape[1]} (A is {self.sched.shape}) — XLA "
                "would silently clamp gather indices otherwise")
        return self._spmm(b)

    __call__ = spmm

    def forward(self, params: dict, x: jax.Array) -> jax.Array:
        """Whole-GCN forward ``softmax-free`` logits: every layer runs
        A × (X × W) through this executor inside one jit."""
        if x.shape[0] != self.sched.shape[1]:
            raise ValueError(
                f"features have {x.shape[0]} rows; schedule expects "
                f"{self.sched.shape[1]} (A is {self.sched.shape})")
        return self._forward(params, x)

    @property
    def utilization(self) -> float:
        return self.sched.utilization

    # ---- jitted bodies -----------------------------------------------------

    def _gather_impl(self, b: jax.Array) -> jax.Array:
        """Fused-gather routing: B-row gather per slot, one scatter-add into
        final output rows (row_map precomposed). Chunked over the slot
        stream so the [chunk, kdim] intermediate stays bounded on
        million-edge graphs."""
        m, _ = self.sched.shape
        kdim = b.shape[1]
        bf = b.astype(jnp.float32)
        out = jnp.zeros((m, kdim), jnp.float32)

        if self._n_chunks == 1:
            g = jnp.take(bf, self._gcol[0], axis=0) * self._val[0][:, None]
            out = out.at[self._tgt[0]].add(g)
        else:
            def body(i, acc):
                g = (jnp.take(bf, self._gcol[i], axis=0)
                     * self._val[i][:, None])
                return acc.at[self._tgt[i]].add(g)
            out = jax.lax.fori_loop(0, self._n_chunks, body, out)
        return out.astype(b.dtype)

    def _onehot_impl(self, b: jax.Array) -> jax.Array:
        """Dense-routing emulation: scan over steps, each step doing the
        Pallas kernel's two one-hot MXU contractions against the step's
        [CB, kdim] B-panel. The measurable XLA twin of the kernel."""
        m, n = self.sched.shape
        k = self.sched.nnz_per_step
        r = self.sched.rows_per_window
        cb = self.sched.cols_per_block
        kdim = b.shape[1]
        ncb = -(-n // cb)
        bp = jnp.pad(b.astype(jnp.float32), ((0, ncb * cb - n), (0, 0)))
        bp = bp.reshape(ncb, cb, kdim)

        def step(out_perm, s):
            win, cblk, val, lrow, lcol = s
            bb = bp[cblk]                                   # [CB, kdim]
            gather = (lcol[:, None] == jnp.arange(cb)[None, :]
                      ).astype(jnp.float32)                 # [K, CB]
            contrib = (gather @ bb) * val[:, None]          # [K, kdim]
            scatter = (lrow[:, None] == jnp.arange(r)[None, :]
                       ).astype(jnp.float32)                # [K, R]
            out_perm = out_perm.at[win].add(scatter.T @ contrib)
            return out_perm, None

        out_perm = jnp.zeros((self.sched.n_windows, r, kdim), jnp.float32)
        out_perm, _ = jax.lax.scan(
            step, out_perm,
            (self._steps["win"], self._steps["cblk"], self._steps["val"],
             self._steps["lrow"], self._steps["lcol"]))
        # scatter epilogue (adder tree): permuted window slots → matrix rows
        rm = self._steps["row_map"]
        valid = rm >= 0
        contrib = jnp.where(valid[:, None],
                            out_perm.reshape(-1, kdim), 0.0)
        out = jnp.zeros((m, kdim), jnp.float32).at[
            jnp.where(valid, rm, 0)].add(contrib)
        return out.astype(b.dtype)

    def _forward_impl(self, params: dict, x: jax.Array) -> jax.Array:
        h = x
        n_layers = len(params)
        for i in range(n_layers):
            h = self._spmm_impl(h @ params[f"w{i}"])  # A × (X × W)
            if i < n_layers - 1:
                h = jax.nn.relu(h)
        return h


# ---------------------------------------------------------------------------
# Caches: fingerprint → schedule / executor / tuned config
# ---------------------------------------------------------------------------

# fingerprint-keyed caches are deliberately unbounded: a serving system
# holds a handful of long-lived graphs, and the converged configuration is
# exactly what must persist. The identity-keyed per-schedule caches are
# bounded LRUs — workloads that build throwaway schedules per call must
# not retain every one forever.
_SCHEDULE_CACHE: dict = {}
_EXECUTOR_CACHE: dict = {}
_EXEC_BY_SCHEDULE: "OrderedDict[tuple, ScheduleExecutor]" = OrderedDict()
_EXEC_BY_SCHEDULE_CAP = 32
_AUTOTUNE_CACHE: dict = {}


def clear_caches() -> None:
    """Drop every cached schedule/executor/tuning result (tests)."""
    _SCHEDULE_CACHE.clear()
    _EXECUTOR_CACHE.clear()
    _EXEC_BY_SCHEDULE.clear()
    _AUTOTUNE_CACHE.clear()
    _DEVICE_STEPS.clear()


def _sched_key(fp: str, nnz_per_step, rows_per_window, cols_per_block,
               window_nnz, balanced):
    return (fp, nnz_per_step, rows_per_window, str(cols_per_block),
            window_nnz, balanced)


def get_schedule(a: fmt.COO, *, nnz_per_step: int = 256,
                 rows_per_window: int = 64,
                 cols_per_block=None, window_nnz: Optional[int] = None,
                 balanced: bool = True,
                 fingerprint: Optional[str] = None) -> Schedule:
    """Fingerprint-cached schedule build — the 'reuse the converged
    configuration' entry point."""
    fp = fingerprint or graph_fingerprint(a)
    key = _sched_key(fp, nnz_per_step, rows_per_window, cols_per_block,
                     window_nnz, balanced)
    sched = _SCHEDULE_CACHE.get(key)
    if sched is None:
        if balanced:
            sched = build_balanced_schedule(
                a, nnz_per_step, rows_per_window,
                cols_per_block=cols_per_block, window_nnz=window_nnz)
        else:
            sched = build_naive_schedule(a, nnz_per_step, rows_per_window,
                                         cols_per_block=cols_per_block)
        _SCHEDULE_CACHE[key] = sched
    return sched


def get_spmm_schedules(a: fmt.COO, *, nnz_per_step: int = 256,
                       rows_per_window: int = 64,
                       cols_per_block=None) -> Tuple[Schedule, Schedule]:
    """(schedule for A, schedule for Aᵀ), both fingerprint-cached — what a
    differentiable SpMM needs (d(A@B)/dB = Aᵀ @ dC). Call sites stop
    rebuilding both schedules per invocation."""
    fwd = get_schedule(a, nnz_per_step=nnz_per_step,
                       rows_per_window=rows_per_window,
                       cols_per_block=cols_per_block)
    a_t = fmt.transpose_coo(a)
    bwd = get_schedule(a_t, nnz_per_step=nnz_per_step,
                       rows_per_window=rows_per_window,
                       cols_per_block=cols_per_block)
    return fwd, bwd


def get_executor(a: fmt.COO, *, nnz_per_step: int = 256,
                 rows_per_window: int = 64, cols_per_block=None,
                 window_nnz: Optional[int] = None, ktile: int = 128,
                 routing: Optional[str] = None,
                 balanced: bool = True) -> ScheduleExecutor:
    """Fingerprint-cached executor: the first call converges (builds the
    schedule, uploads it); every later call with the same graph + config is
    a pure cache hit — no rebuild, no host→device transfer."""
    fp = graph_fingerprint(a)
    key = (_sched_key(fp, nnz_per_step, rows_per_window, cols_per_block,
                      window_nnz, balanced), ktile, routing)
    ex = _EXECUTOR_CACHE.get(key)
    if ex is None:
        sched = get_schedule(a, nnz_per_step=nnz_per_step,
                             rows_per_window=rows_per_window,
                             cols_per_block=cols_per_block,
                             window_nnz=window_nnz, balanced=balanced,
                             fingerprint=fp)
        ex = ScheduleExecutor(sched, ktile=ktile, routing=routing)
        _EXECUTOR_CACHE[key] = ex
    return ex


def executor_for_schedule(sched: Schedule, *, ktile: int = 128,
                          routing: Optional[str] = None) -> ScheduleExecutor:
    """Executor for a caller-built schedule, memoized per (schedule
    instance, ktile, routing) — identity-keyed, so rebuilding a schedule
    re-uploads while reusing one doesn't, and asking for a different
    routing/ktile never returns a mismatched cached executor."""
    routing = routing or select_routing(
        sched.nnz_per_step, sched.cols_per_block, sched.rows_per_window,
        ktile)
    key = (id(sched), ktile, routing)
    ex = _EXEC_BY_SCHEDULE.get(key)
    if ex is not None and ex.sched is sched:
        _EXEC_BY_SCHEDULE.move_to_end(key)
        return ex
    ex = ScheduleExecutor(sched, ktile=ktile, routing=routing)
    _EXEC_BY_SCHEDULE[key] = ex
    if len(_EXEC_BY_SCHEDULE) > _EXEC_BY_SCHEDULE_CAP:
        _EXEC_BY_SCHEDULE.popitem(last=False)
    return ex


# ---------------------------------------------------------------------------
# Autotune-and-cache: measured configuration search (paper Fig. 17/18 loop)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TunedConfig:
    """A measured-fastest executor configuration for one (graph, width).

    ``cols_per_block`` holds the sweep candidate's *request* verbatim
    (None | int | "auto") so ``get_executor(**as_executor_kwargs())``
    reproduces exactly the measured executor; ``cols_per_block_resolved``
    is the block width the schedule actually used."""
    nnz_per_step: int
    rows_per_window: int
    cols_per_block: Union[int, str, None]
    window_nnz: Optional[int]
    ktile: int
    routing: str
    measured_us: float
    utilization: float
    cols_per_block_resolved: int = 0

    def as_executor_kwargs(self) -> dict:
        return dict(nnz_per_step=self.nnz_per_step,
                    rows_per_window=self.rows_per_window,
                    cols_per_block=self.cols_per_block,
                    window_nnz=self.window_nnz, ktile=self.ktile,
                    routing=self.routing)


def _time_call(fn: Callable[[], jax.Array], iters: int, warmup: int) -> float:
    for _ in range(warmup):
        fn().block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn()
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def default_sweep(a: fmt.COO, rows_per_window=(32, 64)) -> list:
    """Candidate (k, r, cb, window_nnz, routing) points: the gather path at a
    few step granularities, plus a capped one-hot point whose nnz_per_step is
    density-matched (≈ nnz/m · r · cb / n rounded to a lane multiple)."""
    m, n = a.shape
    nnz = int(np.asarray(a.row).shape[0])
    cand = []
    for k in (128, 256):
        for r in rows_per_window:
            cand.append(dict(nnz_per_step=k, rows_per_window=r,
                             cols_per_block=None, window_nnz=None,
                             routing=GATHER))
    cb = auto_cols_per_block(n)
    if cb < n:
        for r in rows_per_window:
            cand.append(dict(nnz_per_step=density_matched_k(a, r, cb),
                             rows_per_window=r,
                             cols_per_block="auto", window_nnz=None,
                             routing=ONEHOT))
    return cand


def density_matched_k(a: fmt.COO, rows_per_window: int,
                      cols_per_block: int) -> int:
    """nnz_per_step for a capped one-hot schedule: the expected non-zero
    count of one (rows_per_window × cols_per_block) tile, rounded to a
    power of two ≥ 8 — each (window, block) step then carries ~K real
    slots instead of fragmenting."""
    m, n = a.shape
    nnz = int(np.asarray(a.row).shape[0])
    expect = max(1.0, nnz / m * rows_per_window * cols_per_block / n)
    return max(8, int(2 ** np.round(np.log2(expect))))


def autotune(a: fmt.COO, b_shape: Tuple[int, ...], *,
             sweep: Optional[list] = None, ktile: int = 128,
             iters: int = 3, warmup: int = 1, seed: int = 0,
             include_onehot: bool = False) -> TunedConfig:
    """Measure every sweep point's jitted executor on a random dense operand
    of ``b_shape`` and cache the fastest config by graph fingerprint.

    ``b_shape`` is (n, kdim) (only kdim matters for the cache key). One-hot
    candidates are skipped off-TPU unless ``include_onehot`` — the scan
    emulation is measurable but never competitive on CPU.
    """
    kdim = int(b_shape[-1])
    fp = graph_fingerprint(a)
    sweep_key = None if sweep is None else tuple(
        tuple(sorted(c.items())) for c in sweep)
    key = (fp, kdim, ktile, include_onehot, iters, warmup, sweep_key)
    hit = _AUTOTUNE_CACHE.get(key)
    if hit is not None:
        return hit

    rng = np.random.default_rng(seed)
    b = jnp.asarray(rng.standard_normal((a.shape[1], kdim)).astype(np.float32))
    best: Optional[TunedConfig] = None
    on_tpu = jax.default_backend() == "tpu"
    for cand in (sweep if sweep is not None else default_sweep(a)):
        if cand["routing"] == ONEHOT and not (on_tpu or include_onehot):
            continue
        ex = get_executor(a, ktile=ktile, **cand)
        us = _time_call(lambda: ex.spmm(b), iters, warmup)
        cfg = TunedConfig(
            nnz_per_step=cand["nnz_per_step"],
            rows_per_window=cand["rows_per_window"],
            cols_per_block=cand["cols_per_block"],
            window_nnz=cand["window_nnz"], ktile=ktile,
            routing=ex.routing, measured_us=us,
            utilization=ex.sched.utilization,
            cols_per_block_resolved=ex.sched.cols_per_block)
        if best is None or cfg.measured_us < best.measured_us:
            best = cfg
    if best is None:
        raise ValueError(
            "autotune sweep has no measurable candidate: every point was "
            "one-hot-routed and those are skipped off-TPU — pass "
            "include_onehot=True or add a gather candidate")
    _AUTOTUNE_CACHE[key] = best
    return best


def autotuned_executor(a: fmt.COO, b_shape: Tuple[int, ...],
                       **kw) -> ScheduleExecutor:
    """The executor for the measured-fastest configuration (both the tuning
    result and the executor itself are cached)."""
    cfg = autotune(a, b_shape, **kw)
    return get_executor(a, **cfg.as_executor_kwargs())
