"""ScheduleExecutor — the converged AWB configuration as a first-class,
cached, device-resident artifact (DESIGN.md §3).

AWB-GCN's engine "converges, then reuses the ideal configuration" (§IV):
the balancing effort is paid once per graph, and every subsequent round and
layer replays the converged plan. The seed realization re-paid pieces of
that cost on every call — ``spmm_balanced`` re-converted numpy schedule
arrays to jnp per invocation, ``make_spmm_fn`` rebuilt both schedules per
call site, and the routing one-hots spanned the whole matrix width. This
module closes the loop:

* ``ScheduleExecutor`` uploads a ``Schedule``'s arrays to the device exactly
  once at construction and exposes jitted closures: ``spmm(b) = A @ b``
  (fused-gather VPU routing or step-scanned one-hot MXU routing, chosen by
  ``select_routing``'s cost model) and a jitted whole-GCN ``forward``.
* ``get_executor(a, ...)`` / ``get_schedule(a, ...)`` cache by **graph
  fingerprint** (shape, nnz, content hash of indices+values): repeated calls
  on the same graph hit the cache and perform zero schedule rebuilds and
  zero host→device transfers.
* ``autotune(a, b_shape)`` sweeps (nnz_per_step, rows_per_window,
  cols_per_block, ktile), measures the jitted executor on this host, picks
  the fastest configuration, and caches it alongside the schedule — the
  paper's autotuner loop with wall-clock as the objective.

Routing paths
-------------
``gather``  — per-slot ``jnp.take`` of B rows + one fused scatter-add
              straight into output rows (``row_map∘slot`` precomposed at
              upload time). Routing work scales with the slot count alone;
              the right choice for ultra-sparse operands and the only
              sensible choice off-TPU.
``onehot``  — a ``lax.scan`` over steps replaying the Pallas kernel's MXU
              contractions (one-hot gather [K, CB] @ B-block, one-hot
              scatter [K, R]ᵀ @ contributions). Routing work scales with
              K·CB per step — viable only with a capped ``cols_per_block``;
              kept exactly kernel-shaped so it doubles as the measurable
              stand-in for the dense-routing Pallas path in benchmarks and
              equivalence tests.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from collections import OrderedDict
from typing import Callable, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import csc as fmt
from repro.core.schedule import (Schedule, auto_cols_per_block,
                                 build_balanced_schedule,
                                 build_naive_schedule)
from repro.sharding.schedule_shard import shard_schedule

GATHER = "gather"
ONEHOT = "onehot"

# cost-model constants (v5e-class core): 128×128 MXU MAC/cycle, and a
# dynamic-gather bandwidth proxy for VMEM row fetches on the VPU path
_MXU_MACS_PER_CYCLE = 16384
_GATHER_BYTES_PER_CYCLE = 512


def routing_cost_model(k: int, cb: int, r: int, ktile: int = 128) -> dict:
    """Estimated per-step cycles of each routing path (relative units).

    one-hot: two MXU contractions, [K, CB] @ [CB, ktile] and
    [K, R]ᵀ @ [K, ktile] → K·(CB+R)·ktile MACs.
    gather: K dynamic row fetches of a ktile-wide f32 row (latency/bandwidth
    bound on the VPU) + the same one-hot scatter contraction.
    """
    onehot = k * (cb + r) * ktile / _MXU_MACS_PER_CYCLE
    gather = (k * ktile * 4 / _GATHER_BYTES_PER_CYCLE
              + k * r * ktile / _MXU_MACS_PER_CYCLE)
    return {ONEHOT: onehot, GATHER: gather}


def select_routing(k: int, cb: int, r: int, ktile: int = 128) -> str:
    """Pick the cheaper routing for one operand: one-hot MXU routing wins
    when the column block is capped small; gather wins when the block spans
    a wide (ultra-sparse) operand."""
    cost = routing_cost_model(k, cb, r, ktile)
    return ONEHOT if cost[ONEHOT] <= cost[GATHER] else GATHER


def graph_fingerprint(a: fmt.COO) -> str:
    """Content hash of a sparse operand — the schedule-cache key.

    Hashes shape, true nnz, and the index/value bytes of real (non-PAD)
    entries, so two COOs describing the same matrix — padded or not — map
    to the same converged configuration.
    """
    row = np.asarray(a.row)
    col = np.asarray(a.col)
    val = np.asarray(a.val)
    if (row == fmt.PAD_IDX).any():
        keep = row != fmt.PAD_IDX
        row, col, val = row[keep], col[keep], val[keep]
    h = hashlib.blake2b(digest_size=16)
    h.update(repr((a.shape, int(row.shape[0]))).encode())
    h.update(row.tobytes())
    h.update(col.tobytes())
    h.update(val.tobytes())
    return h.hexdigest()


# step-major device copies of schedule arrays, shared between
# ScheduleExecutor and the Pallas kernel wrapper so one schedule is
# uploaded once no matter who consumes it. Identity-keyed, bounded LRU.
_DEVICE_STEPS: "OrderedDict[int, tuple]" = OrderedDict()
_DEVICE_STEPS_CAP = 32


def device_step_arrays(sched: Schedule) -> dict:
    """Step-major jnp arrays of one schedule — ``val``/``lrow``/``lcol``
    reshaped [n_steps, K], ``win``/``cblk`` per step, ``row_map`` — uploaded
    to device once per schedule instance and memoized (bounded LRU)."""
    key = id(sched)
    hit = _DEVICE_STEPS.get(key)
    if hit is not None and hit[0] is sched:
        _DEVICE_STEPS.move_to_end(key)
        return hit[1]
    n_steps, k = sched.n_steps, sched.nnz_per_step
    arrs = {
        "val": jnp.asarray(sched.val.reshape(n_steps, k)),
        "lrow": jnp.asarray(sched.local_row.reshape(n_steps, k)),
        "lcol": jnp.asarray(sched.local_col.reshape(n_steps, k)),
        "win": jnp.asarray(sched.win_id),
        "cblk": jnp.asarray(sched.col_block),
        "row_map": jnp.asarray(sched.row_map),
    }
    _DEVICE_STEPS[key] = (sched, arrs)
    if len(_DEVICE_STEPS) > _DEVICE_STEPS_CAP:
        _DEVICE_STEPS.popitem(last=False)
    return arrs


def _gather_slots(sched: Schedule):
    """Per-slot flat arrays of the fused-gather routing: global B-row
    ``gcol``, output row ``tgt`` (``row_map ∘ slot`` precomposed: the
    scatter epilogue folds into the main scatter — padding slots carry
    ``val == 0``, so a clamped target row accumulates nothing), and the
    slot values. All step-major, length ``n_steps * nnz_per_step``."""
    m, n = sched.shape
    k = sched.nnz_per_step
    r = sched.rows_per_window
    cb = sched.cols_per_block
    win_slot = np.repeat(sched.win_id.astype(np.int64), k)
    cblk_slot = np.repeat(sched.col_block.astype(np.int64), k)
    gcol = np.minimum(cblk_slot * cb + sched.local_col, n - 1)
    slot = win_slot * r + sched.local_row
    tgt = np.maximum(sched.row_map[slot], 0).astype(np.int32)
    return gcol.astype(np.int32), tgt, sched.val


class _ExecutorBase:
    """Shared surface of the single- and multi-device executors: operand
    validation, the jitted-closure call protocol, and the whole-GCN forward
    loop (every layer's A × (X × W) through ``self._spmm_impl``)."""

    sched: Schedule
    routing: str

    def spmm(self, b: jax.Array) -> jax.Array:
        """C = A @ b through the device-resident converged schedule."""
        if b.shape[0] != self.sched.shape[1]:
            raise ValueError(
                f"operand has {b.shape[0]} rows; schedule expects "
                f"{self.sched.shape[1]} (A is {self.sched.shape}) — XLA "
                "would silently clamp gather indices otherwise")
        return self._spmm(b)

    __call__ = spmm

    def forward(self, params: dict, x: jax.Array) -> jax.Array:
        """Whole-GCN forward ``softmax-free`` logits: every layer runs
        A × (X × W) through this executor inside one jit."""
        if x.shape[0] != self.sched.shape[1]:
            raise ValueError(
                f"features have {x.shape[0]} rows; schedule expects "
                f"{self.sched.shape[1]} (A is {self.sched.shape})")
        return self._forward(params, x)

    @property
    def utilization(self) -> float:
        return self.sched.utilization

    def _forward_impl(self, params: dict, x: jax.Array) -> jax.Array:
        h = x
        n_layers = len(params)
        for i in range(n_layers):
            h = self._spmm_impl(h @ params[f"w{i}"])  # A × (X × W)
            if i < n_layers - 1:
                h = jax.nn.relu(h)
        return h


class ScheduleExecutor(_ExecutorBase):
    """Device-resident executor of one converged AWB schedule.

    Construction uploads every schedule array to the default device once;
    the jitted closures capture those arrays, so repeated ``spmm``/
    ``forward`` calls move only the dense operand.
    """

    def __init__(self, sched: Schedule, *, ktile: int = 128,
                 routing: Optional[str] = None,
                 slot_chunk: int = 1 << 18):
        self.sched = sched
        self.ktile = ktile
        k = sched.nnz_per_step
        r = sched.rows_per_window
        cb = sched.cols_per_block
        self.routing = routing or select_routing(k, cb, r, ktile)

        # ---- one-time host-side precompute + host→device upload ----------
        # only the selected routing's representation is built/uploaded
        if self.routing == GATHER:
            gcol, tgt, val = _gather_slots(sched)

            # pad the flat slot stream to a whole number of chunks so the
            # fused gather path can bound its [chunk, kdim] intermediate
            s_total = gcol.shape[0]
            self._slot_chunk = int(min(slot_chunk, max(1, s_total)))
            pad = (-s_total) % self._slot_chunk
            self._n_chunks = (s_total + pad) // self._slot_chunk

            def _chunked(x, fill):
                return jnp.asarray(
                    np.concatenate([x, np.full(pad, fill, x.dtype)])
                    .reshape(self._n_chunks, self._slot_chunk))

            self._gcol = _chunked(gcol, 0)
            self._tgt = _chunked(tgt, 0)
            self._val = _chunked(val, 0.0)
        else:
            # step-major arrays (shared with the Pallas kernel wrapper —
            # one upload per schedule no matter who consumes it)
            self._steps = device_step_arrays(sched)

        self._spmm_impl = (self._gather_impl if self.routing == GATHER
                           else self._onehot_impl)
        self._spmm = jax.jit(self._spmm_impl)
        self._forward = jax.jit(self._forward_impl)

    # ---- jitted bodies -----------------------------------------------------

    def _gather_impl(self, b: jax.Array) -> jax.Array:
        """Fused-gather routing: B-row gather per slot, one scatter-add into
        final output rows (row_map precomposed). Chunked over the slot
        stream so the [chunk, kdim] intermediate stays bounded on
        million-edge graphs."""
        m, _ = self.sched.shape
        kdim = b.shape[1]
        bf = b.astype(jnp.float32)
        out = jnp.zeros((m, kdim), jnp.float32)

        if self._n_chunks == 1:
            g = jnp.take(bf, self._gcol[0], axis=0) * self._val[0][:, None]
            out = out.at[self._tgt[0]].add(g)
        else:
            def body(i, acc):
                g = (jnp.take(bf, self._gcol[i], axis=0)
                     * self._val[i][:, None])
                return acc.at[self._tgt[i]].add(g)
            out = jax.lax.fori_loop(0, self._n_chunks, body, out)
        return out.astype(b.dtype)

    def _onehot_impl(self, b: jax.Array) -> jax.Array:
        """Dense-routing emulation: scan over steps, each step doing the
        Pallas kernel's two one-hot MXU contractions against the step's
        [CB, kdim] B-panel. The measurable XLA twin of the kernel."""
        m, n = self.sched.shape
        k = self.sched.nnz_per_step
        r = self.sched.rows_per_window
        cb = self.sched.cols_per_block
        kdim = b.shape[1]
        ncb = -(-n // cb)
        bp = jnp.pad(b.astype(jnp.float32), ((0, ncb * cb - n), (0, 0)))
        bp = bp.reshape(ncb, cb, kdim)

        def step(out_perm, s):
            win, cblk, val, lrow, lcol = s
            bb = bp[cblk]                                   # [CB, kdim]
            gather = (lcol[:, None] == jnp.arange(cb)[None, :]
                      ).astype(jnp.float32)                 # [K, CB]
            contrib = (gather @ bb) * val[:, None]          # [K, kdim]
            scatter = (lrow[:, None] == jnp.arange(r)[None, :]
                       ).astype(jnp.float32)                # [K, R]
            out_perm = out_perm.at[win].add(scatter.T @ contrib)
            return out_perm, None

        out_perm = jnp.zeros((self.sched.n_windows, r, kdim), jnp.float32)
        out_perm, _ = jax.lax.scan(
            step, out_perm,
            (self._steps["win"], self._steps["cblk"], self._steps["val"],
             self._steps["lrow"], self._steps["lcol"]))
        # scatter epilogue (adder tree): permuted window slots → matrix rows
        rm = self._steps["row_map"]
        valid = rm >= 0
        contrib = jnp.where(valid[:, None],
                            out_perm.reshape(-1, kdim), 0.0)
        out = jnp.zeros((m, kdim), jnp.float32).at[
            jnp.where(valid, rm, 0)].add(contrib)
        return out.astype(b.dtype)


class ShardedScheduleExecutor(_ExecutorBase):
    """Multi-device executor of one converged AWB schedule.

    The schedule is split by ``sharding.schedule_shard`` into contiguous
    per-device step shards (steps are equal work, so equal counts are
    balanced devices — the paper's equal-work distribution across the PE
    array, lifted one level to the device mesh). Construction uploads each
    shard to its own device exactly once (``device_put`` with a
    ``P('dev', ...)`` sharding on the stacked step axis); ``spmm``/
    ``forward`` then run the routing body under ``shard_map`` and merge the
    per-device partial outputs with a ``psum`` — the distributed adder
    tree that also reunites evil-row chunks and boundary-straddling
    windows living on different devices.

    Both routing paths shard identically: the step axis is the shard axis,
    and each device executes exactly the single-device body over its own
    steps. Numerics therefore match the single-device executor up to f32
    re-association of the cross-device sum.
    """

    def __init__(self, sched: Schedule, *, n_devices: Optional[int] = None,
                 mesh: Optional[Mesh] = None, ktile: int = 128,
                 routing: Optional[str] = None, slot_chunk: int = 1 << 18):
        if mesh is None:
            devs = jax.devices()
            if n_devices is None:
                n_devices = len(devs)
            if not 1 <= n_devices <= len(devs):
                raise ValueError(
                    f"n_devices={n_devices} but this host exposes "
                    f"{len(devs)} device(s)")
            mesh = Mesh(np.asarray(devs[:n_devices]), ("dev",))
        else:
            if len(mesh.axis_names) != 1:
                raise ValueError(
                    "ShardedScheduleExecutor shards over one step axis and "
                    f"needs a 1-D mesh; got axes {mesh.axis_names}")
            if n_devices is not None and n_devices != mesh.devices.size:
                raise ValueError(
                    f"n_devices={n_devices} contradicts the given mesh of "
                    f"{mesh.devices.size} device(s); pass one or the other")
            n_devices = int(mesh.devices.size)
        self.mesh = mesh
        self.axis = mesh.axis_names[0]
        self.n_devices = n_devices
        self.sched = sched
        self.ktile = ktile
        k = sched.nnz_per_step
        r = sched.rows_per_window
        cb = sched.cols_per_block
        self.routing = routing or select_routing(k, cb, r, ktile)

        shards = shard_schedule(sched, n_devices)
        self.step_ranges = shards.ranges

        def put(x, *tail_spec):
            return jax.device_put(
                jnp.asarray(x), NamedSharding(mesh, P(self.axis, *tail_spec)))

        # ---- one-time host-side split + per-device upload ----------------
        if self.routing == GATHER:
            gcol, tgt, val = _gather_slots(sched)
            # per-device flat slot streams, padded to the common shard
            # length, then chunked so the [chunk, kdim] intermediate stays
            # bounded (same contract as the single-device executor)
            s_max = shards.steps_per_shard
            length = s_max * k
            self._slot_chunk = int(min(slot_chunk, max(1, length)))
            pad = (-length) % self._slot_chunk
            self._n_chunks = (length + pad) // self._slot_chunk

            def stack(x, fill):
                out = np.full((n_devices, length + pad), fill, x.dtype)
                for d, (lo, hi) in enumerate(shards.ranges):
                    out[d, :(hi - lo) * k] = x[lo * k:hi * k]
                return put(out.reshape(n_devices, self._n_chunks,
                                       self._slot_chunk))

            self._gcol = stack(gcol, 0)
            self._tgt = stack(tgt, 0)
            self._val = stack(val, 0.0)
        else:
            self._steps = {
                "val": put(shards.val), "lrow": put(shards.lrow),
                "lcol": put(shards.lcol), "win": put(shards.win),
                "cblk": put(shards.cblk),
                # replicated: the epilogue runs device-local, pre-psum
                "row_map": jax.device_put(jnp.asarray(sched.row_map),
                                          NamedSharding(mesh, P())),
            }

        self._spmm_impl = (self._sharded_gather_impl
                           if self.routing == GATHER
                           else self._sharded_onehot_impl)
        self._spmm = jax.jit(self._spmm_impl)
        self._forward = jax.jit(self._forward_impl)

    def _shard_map(self, body, in_specs):
        # check_rep=False: the bodies end in an explicit psum, which makes
        # the P() output replicated by construction; the static replication
        # checker has no rule for scatter-add on some jax versions.
        return shard_map(body, mesh=self.mesh, in_specs=in_specs,
                         out_specs=P(), check_rep=False)

    # ---- jitted bodies -----------------------------------------------------

    def _sharded_gather_impl(self, b: jax.Array) -> jax.Array:
        """Fused-gather routing per device shard + psum merge."""
        m, _ = self.sched.shape
        axis = self.axis
        n_chunks = self._n_chunks

        def body(gcol, tgt, val, bf):
            gcol, tgt, val = gcol[0], tgt[0], val[0]   # [n_chunks, chunk]
            out = jnp.zeros((m, bf.shape[1]), jnp.float32)
            if n_chunks == 1:
                g = jnp.take(bf, gcol[0], axis=0) * val[0][:, None]
                out = out.at[tgt[0]].add(g)
            else:
                def chunk(i, acc):
                    g = jnp.take(bf, gcol[i], axis=0) * val[i][:, None]
                    return acc.at[tgt[i]].add(g)
                out = jax.lax.fori_loop(0, n_chunks, chunk, out)
            return jax.lax.psum(out, axis)

        fn = self._shard_map(body, (P(axis), P(axis), P(axis), P()))
        out = fn(self._gcol, self._tgt, self._val, b.astype(jnp.float32))
        return out.astype(b.dtype)

    def _sharded_onehot_impl(self, b: jax.Array) -> jax.Array:
        """Per-device one-hot step scan + local scatter epilogue, then a
        psum of the per-device partial outputs."""
        m, n = self.sched.shape
        r = self.sched.rows_per_window
        cb = self.sched.cols_per_block
        n_windows = self.sched.n_windows
        axis = self.axis
        ncb = -(-n // cb)

        def body(win, cblk, val, lrow, lcol, rm, bf):
            win, cblk = win[0], cblk[0]                # [S] / [S, K]
            val, lrow, lcol = val[0], lrow[0], lcol[0]
            kdim = bf.shape[1]
            bp = jnp.pad(bf, ((0, ncb * cb - n), (0, 0)))
            bp = bp.reshape(ncb, cb, kdim)

            def step(out_perm, s):
                w, cblk_s, val_s, lrow_s, lcol_s = s
                bb = bp[cblk_s]                                 # [CB, kdim]
                gather = (lcol_s[:, None] == jnp.arange(cb)[None, :]
                          ).astype(jnp.float32)                 # [K, CB]
                contrib = (gather @ bb) * val_s[:, None]        # [K, kdim]
                scatter = (lrow_s[:, None] == jnp.arange(r)[None, :]
                           ).astype(jnp.float32)                # [K, R]
                out_perm = out_perm.at[w].add(scatter.T @ contrib)
                return out_perm, None

            out_perm = jnp.zeros((n_windows, r, kdim), jnp.float32)
            out_perm, _ = jax.lax.scan(step, out_perm,
                                       (win, cblk, val, lrow, lcol))
            # device-local scatter epilogue, then the cross-device adder
            # tree: one psum of [m, kdim] partials
            valid = rm >= 0
            contrib = jnp.where(valid[:, None],
                                out_perm.reshape(-1, kdim), 0.0)
            out = jnp.zeros((m, kdim), jnp.float32).at[
                jnp.where(valid, rm, 0)].add(contrib)
            return jax.lax.psum(out, axis)

        fn = self._shard_map(
            body, (P(axis), P(axis), P(axis), P(axis), P(axis), P(), P()))
        s = self._steps
        out = fn(s["win"], s["cblk"], s["val"], s["lrow"], s["lcol"],
                 s["row_map"], b.astype(jnp.float32))
        return out.astype(b.dtype)


# ---------------------------------------------------------------------------
# Caches: fingerprint → schedule / executor / tuned config
# ---------------------------------------------------------------------------

# fingerprint-keyed caches are deliberately unbounded: a serving system
# holds a handful of long-lived graphs, and the converged configuration is
# exactly what must persist. The identity-keyed per-schedule caches are
# bounded LRUs — workloads that build throwaway schedules per call must
# not retain every one forever.
_SCHEDULE_CACHE: dict = {}
_EXECUTOR_CACHE: dict = {}
_EXEC_BY_SCHEDULE: "OrderedDict[tuple, ScheduleExecutor]" = OrderedDict()
_EXEC_BY_SCHEDULE_CAP = 32
_AUTOTUNE_CACHE: dict = {}


def clear_caches() -> None:
    """Drop every cached schedule/executor/tuning result (tests)."""
    _SCHEDULE_CACHE.clear()
    _EXECUTOR_CACHE.clear()
    _EXEC_BY_SCHEDULE.clear()
    _AUTOTUNE_CACHE.clear()
    _DEVICE_STEPS.clear()


def _sched_key(fp: str, nnz_per_step, rows_per_window, cols_per_block,
               window_nnz, balanced):
    return (fp, nnz_per_step, rows_per_window, str(cols_per_block),
            window_nnz, balanced)


def mesh_fingerprint(mesh: Optional[Mesh] = None,
                     n_devices: Optional[int] = None):
    """Hashable identity of the requested device mesh — the second half of
    the ``(graph fingerprint, mesh)`` executor-cache key.

    ``None`` (no mesh, no device count) means the plain single-device
    ``ScheduleExecutor``; ``n_devices=1`` is a *distinct* entry (a 1-device
    sharded executor), so single- and multi-device executors coexist in the
    cache. Device ids are part of the key: the same shape on different
    devices is a different placement.
    """
    if mesh is None and n_devices is None:
        return None
    if mesh is not None:
        if n_devices is not None and n_devices != mesh.devices.size:
            raise ValueError(
                f"n_devices={n_devices} contradicts the given mesh of "
                f"{mesh.devices.size} device(s); pass one or the other")
        return (tuple(mesh.axis_names), tuple(mesh.devices.shape),
                tuple(int(d.id) for d in mesh.devices.flat))
    devs = jax.devices()
    if not 1 <= n_devices <= len(devs):
        raise ValueError(
            f"n_devices={n_devices} but this host exposes "
            f"{len(devs)} device(s)")
    devs = devs[:n_devices]
    return (("dev",), (len(devs),), tuple(int(d.id) for d in devs))


def get_schedule(a: fmt.COO, *, nnz_per_step: int = 256,
                 rows_per_window: int = 64,
                 cols_per_block=None, window_nnz: Optional[int] = None,
                 balanced: bool = True,
                 fingerprint: Optional[str] = None) -> Schedule:
    """Fingerprint-cached schedule build — the 'reuse the converged
    configuration' entry point."""
    fp = fingerprint or graph_fingerprint(a)
    key = _sched_key(fp, nnz_per_step, rows_per_window, cols_per_block,
                     window_nnz, balanced)
    sched = _SCHEDULE_CACHE.get(key)
    if sched is None:
        if balanced:
            sched = build_balanced_schedule(
                a, nnz_per_step, rows_per_window,
                cols_per_block=cols_per_block, window_nnz=window_nnz)
        else:
            sched = build_naive_schedule(a, nnz_per_step, rows_per_window,
                                         cols_per_block=cols_per_block)
        _SCHEDULE_CACHE[key] = sched
    return sched


def get_spmm_schedules(a: fmt.COO, *, nnz_per_step: int = 256,
                       rows_per_window: int = 64,
                       cols_per_block=None) -> Tuple[Schedule, Schedule]:
    """(schedule for A, schedule for Aᵀ), both fingerprint-cached — what a
    differentiable SpMM needs (d(A@B)/dB = Aᵀ @ dC). Call sites stop
    rebuilding both schedules per invocation."""
    fwd = get_schedule(a, nnz_per_step=nnz_per_step,
                       rows_per_window=rows_per_window,
                       cols_per_block=cols_per_block)
    a_t = fmt.transpose_coo(a)
    bwd = get_schedule(a_t, nnz_per_step=nnz_per_step,
                       rows_per_window=rows_per_window,
                       cols_per_block=cols_per_block)
    return fwd, bwd


def get_executor(a: fmt.COO, *, nnz_per_step: int = 256,
                 rows_per_window: int = 64, cols_per_block=None,
                 window_nnz: Optional[int] = None, ktile: int = 128,
                 routing: Optional[str] = None,
                 balanced: bool = True,
                 n_devices: Optional[int] = None,
                 mesh: Optional[Mesh] = None) -> _ExecutorBase:
    """Fingerprint-cached executor: the first call converges (builds the
    schedule, uploads it); every later call with the same graph + config is
    a pure cache hit — no rebuild, no host→device transfer.

    Pass ``n_devices`` (or a 1-D ``mesh``) for a ``ShardedScheduleExecutor``
    whose schedule shards live one-per-device; the cache keys on
    ``(graph fingerprint, mesh)``, so single- and multi-device executors of
    the same graph coexist.
    """
    fp = graph_fingerprint(a)
    mkey = mesh_fingerprint(mesh, n_devices)
    key = (_sched_key(fp, nnz_per_step, rows_per_window, cols_per_block,
                      window_nnz, balanced), ktile, routing, mkey)
    ex = _EXECUTOR_CACHE.get(key)
    if ex is None:
        sched = get_schedule(a, nnz_per_step=nnz_per_step,
                             rows_per_window=rows_per_window,
                             cols_per_block=cols_per_block,
                             window_nnz=window_nnz, balanced=balanced,
                             fingerprint=fp)
        if mkey is None:
            ex = ScheduleExecutor(sched, ktile=ktile, routing=routing)
        else:
            ex = ShardedScheduleExecutor(sched, n_devices=n_devices,
                                         mesh=mesh, ktile=ktile,
                                         routing=routing)
        _EXECUTOR_CACHE[key] = ex
    return ex


def executor_for_schedule(sched: Schedule, *, ktile: int = 128,
                          routing: Optional[str] = None,
                          n_devices: Optional[int] = None,
                          mesh: Optional[Mesh] = None) -> _ExecutorBase:
    """Executor for a caller-built schedule, memoized per (schedule
    instance, ktile, routing, mesh) — identity-keyed, so rebuilding a
    schedule re-uploads while reusing one doesn't, and asking for a
    different routing/ktile/mesh never returns a mismatched cached
    executor."""
    routing = routing or select_routing(
        sched.nnz_per_step, sched.cols_per_block, sched.rows_per_window,
        ktile)
    mkey = mesh_fingerprint(mesh, n_devices)
    key = (id(sched), ktile, routing, mkey)
    ex = _EXEC_BY_SCHEDULE.get(key)
    if ex is not None and ex.sched is sched:
        _EXEC_BY_SCHEDULE.move_to_end(key)
        return ex
    if mkey is None:
        ex = ScheduleExecutor(sched, ktile=ktile, routing=routing)
    else:
        ex = ShardedScheduleExecutor(sched, n_devices=n_devices, mesh=mesh,
                                     ktile=ktile, routing=routing)
    _EXEC_BY_SCHEDULE[key] = ex
    if len(_EXEC_BY_SCHEDULE) > _EXEC_BY_SCHEDULE_CAP:
        _EXEC_BY_SCHEDULE.popitem(last=False)
    return ex


# ---------------------------------------------------------------------------
# Autotune-and-cache: measured configuration search (paper Fig. 17/18 loop)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TunedConfig:
    """A measured-fastest executor configuration for one (graph, width).

    ``cols_per_block`` holds the sweep candidate's *request* verbatim
    (None | int | "auto") so ``get_executor(**as_executor_kwargs())``
    reproduces exactly the measured executor; ``cols_per_block_resolved``
    is the block width the schedule actually used. ``n_devices`` is None
    for the single-device executor and a device count for the sharded
    one (sharded candidates enter the sweep whenever the host exposes a
    multi-device mesh)."""
    nnz_per_step: int
    rows_per_window: int
    cols_per_block: Union[int, str, None]
    window_nnz: Optional[int]
    ktile: int
    routing: str
    measured_us: float
    utilization: float
    cols_per_block_resolved: int = 0
    n_devices: Optional[int] = None

    def as_executor_kwargs(self) -> dict:
        return dict(nnz_per_step=self.nnz_per_step,
                    rows_per_window=self.rows_per_window,
                    cols_per_block=self.cols_per_block,
                    window_nnz=self.window_nnz, ktile=self.ktile,
                    routing=self.routing, n_devices=self.n_devices)


def _time_call(fn: Callable[[], jax.Array], iters: int, warmup: int) -> float:
    for _ in range(warmup):
        fn().block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn()
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def default_sweep(a: fmt.COO, rows_per_window=(32, 64)) -> list:
    """Candidate (k, r, cb, window_nnz, routing) points: the gather path at a
    few step granularities, plus a capped one-hot point whose nnz_per_step is
    density-matched (≈ nnz/m · r · cb / n rounded to a lane multiple)."""
    m, n = a.shape
    nnz = int(np.asarray(a.row).shape[0])
    cand = []
    for k in (128, 256):
        for r in rows_per_window:
            cand.append(dict(nnz_per_step=k, rows_per_window=r,
                             cols_per_block=None, window_nnz=None,
                             routing=GATHER))
    cb = auto_cols_per_block(n)
    if cb < n:
        for r in rows_per_window:
            cand.append(dict(nnz_per_step=density_matched_k(a, r, cb),
                             rows_per_window=r,
                             cols_per_block="auto", window_nnz=None,
                             routing=ONEHOT))
    return cand


def sharded_device_counts(max_devices: Optional[int] = None) -> tuple:
    """Device counts the sharded sweep covers: powers of two in
    (1, available], capped at ``max_devices``. Empty on a single-device
    host — the sweep then degenerates to the single-device candidates."""
    n_avail = len(jax.devices())
    cap = n_avail if max_devices is None else min(max_devices, n_avail)
    counts = []
    d = 2
    while d <= cap:
        counts.append(d)
        d *= 2
    return tuple(counts)


def sharded_sweep(a: fmt.COO, device_counts: tuple,
                  rows_per_window=(32, 64)) -> list:
    """Sharded-executor candidates: the gather path at each device count
    (one-hot shards identically but is never competitive off-TPU, and on
    TPU the kernel sweep covers it)."""
    cand = []
    for d in device_counts:
        for r in rows_per_window:
            cand.append(dict(nnz_per_step=256, rows_per_window=r,
                             cols_per_block=None, window_nnz=None,
                             routing=GATHER, n_devices=d))
    return cand


def density_matched_k(a: fmt.COO, rows_per_window: int,
                      cols_per_block: int) -> int:
    """nnz_per_step for a capped one-hot schedule: the expected non-zero
    count of one (rows_per_window × cols_per_block) tile, rounded to a
    power of two ≥ 8 — each (window, block) step then carries ~K real
    slots instead of fragmenting."""
    m, n = a.shape
    nnz = int(np.asarray(a.row).shape[0])
    expect = max(1.0, nnz / m * rows_per_window * cols_per_block / n)
    return max(8, int(2 ** np.round(np.log2(expect))))


def autotune(a: fmt.COO, b_shape: Tuple[int, ...], *,
             sweep: Optional[list] = None, ktile: int = 128,
             iters: int = 3, warmup: int = 1, seed: int = 0,
             include_onehot: bool = False,
             max_devices: Optional[int] = None) -> TunedConfig:
    """Measure every sweep point's jitted executor on a random dense operand
    of ``b_shape`` and cache the fastest config by graph fingerprint.

    ``b_shape`` is (n, kdim) (only kdim matters for the cache key). One-hot
    candidates are skipped off-TPU unless ``include_onehot`` — the scan
    emulation is measurable but never competitive on CPU. When the host
    exposes more than one device the default sweep additionally measures
    the **sharded** executor at power-of-two device counts (capped by
    ``max_devices``); explicit ``sweep`` candidates may carry their own
    ``n_devices``.
    """
    kdim = int(b_shape[-1])
    fp = graph_fingerprint(a)
    sweep_key = None if sweep is None else tuple(
        tuple(sorted(c.items())) for c in sweep)
    key = (fp, kdim, ktile, include_onehot, iters, warmup, sweep_key,
           max_devices, len(jax.devices()))
    hit = _AUTOTUNE_CACHE.get(key)
    if hit is not None:
        return hit

    if sweep is None:
        sweep_eff = default_sweep(a) + sharded_sweep(
            a, sharded_device_counts(max_devices))
    else:
        sweep_eff = sweep

    rng = np.random.default_rng(seed)
    b = jnp.asarray(rng.standard_normal((a.shape[1], kdim)).astype(np.float32))
    best: Optional[TunedConfig] = None
    on_tpu = jax.default_backend() == "tpu"
    for cand in sweep_eff:
        if cand["routing"] == ONEHOT and not (on_tpu or include_onehot):
            continue
        ex = get_executor(a, ktile=ktile, **cand)
        us = _time_call(lambda: ex.spmm(b), iters, warmup)
        cfg = TunedConfig(
            nnz_per_step=cand["nnz_per_step"],
            rows_per_window=cand["rows_per_window"],
            cols_per_block=cand["cols_per_block"],
            window_nnz=cand["window_nnz"], ktile=ktile,
            routing=ex.routing, measured_us=us,
            utilization=ex.sched.utilization,
            cols_per_block_resolved=ex.sched.cols_per_block,
            n_devices=cand.get("n_devices"))
        if best is None or cfg.measured_us < best.measured_us:
            best = cfg
    if best is None:
        raise ValueError(
            "autotune sweep has no measurable candidate: every point was "
            "one-hot-routed and those are skipped off-TPU — pass "
            "include_onehot=True or add a gather candidate")
    _AUTOTUNE_CACHE[key] = best
    return best


def autotuned_executor(a: fmt.COO, b_shape: Tuple[int, ...],
                       **kw) -> _ExecutorBase:
    """The executor for the measured-fastest configuration (both the tuning
    result and the executor itself are cached)."""
    cfg = autotune(a, b_shape, **kw)
    return get_executor(a, **cfg.as_executor_kwargs())
