"""ScheduleExecutor — the converged AWB configuration as a first-class,
device-resident artifact (DESIGN.md §3).

AWB-GCN's engine "converges, then reuses the ideal configuration" (§IV):
the balancing effort is paid once per graph, and every subsequent round and
layer replays the converged plan. This module is purely the **execution
machinery** for that plan:

* ``ScheduleExecutor`` uploads a ``Schedule``'s arrays to the device exactly
  once at construction and exposes jitted closures: ``spmm(b) = A @ b``
  (fused-gather VPU routing or step-scanned one-hot MXU routing, chosen by
  ``select_routing``'s cost model) and a jitted whole-GCN ``forward``.
* ``ShardedScheduleExecutor`` runs the same plan across a 1-D device mesh
  (per-device step shards under ``shard_map``, psum merge — DESIGN.md §4).

Every caching/search concern that used to live here — fingerprint-keyed
schedule/executor caches, the measured autotune sweep, ``TunedConfig`` —
moved to the ``repro.tuning`` package (``registry``, ``runner``, ``space``,
``store``); this module lazily re-exports those names so existing call
sites (``executor.get_executor``, ``executor.autotune``, …) keep working.

Routing paths
-------------
``gather``  — per-slot ``jnp.take`` of B rows + one fused scatter-add
              straight into output rows (``row_map∘slot`` precomposed at
              upload time). Routing work scales with the slot count alone;
              the right choice for ultra-sparse operands and the only
              sensible choice off-TPU.
``onehot``  — a ``lax.scan`` over steps replaying the Pallas kernel's MXU
              contractions (one-hot gather [K, CB] @ B-block, one-hot
              scatter [K, R]ᵀ @ contributions). Routing work scales with
              K·CB per step — viable only with a capped ``cols_per_block``;
              kept exactly kernel-shaped so it doubles as the measurable
              stand-in for the dense-routing Pallas path in benchmarks and
              equivalence tests.

Both executors accept ``bf16_accumulate=True`` to run the routing bodies'
multiplies and accumulations in bfloat16 (a sweep axis — the autotuner
attaches an f32-vs-bf16 max-error report to the winning ``TunedConfig``).
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.schedule import Schedule
from repro.lazyexports import lazy_exports
from repro.sharding.schedule_shard import shard_schedule

GATHER = "gather"
ONEHOT = "onehot"

#: floor (total slot-array bytes) below which a repair re-uploads in full
#: instead of scatter-patching dirty slots on device. The scoped scatter
#: saves transfer bandwidth on accelerator-scale graphs but costs an XLA
#: scatter dispatch (and an occasional compile) that a small graph's plain
#: re-upload beats; tests pin this to 0 to exercise the scoped path.
SCOPED_UPLOAD_MIN_BYTES = 16 * 1024 * 1024


@jax.jit
def _scatter_set(dev: jax.Array, idx: jax.Array, v: jax.Array) -> jax.Array:
    """Copy-on-write point update of a chunked device array: one jitted
    (hence shape-cached) scatter instead of eager per-op dispatch — the
    value-patch fast lane calls this on every streaming update, so its
    dispatch overhead is on the repair-latency critical path."""
    return dev.reshape(-1).at[idx].set(v).reshape(dev.shape)


# cost-model constants (v5e-class core): 128×128 MXU MAC/cycle, and a
# dynamic-gather bandwidth proxy for VMEM row fetches on the VPU path
_MXU_MACS_PER_CYCLE = 16384
_GATHER_BYTES_PER_CYCLE = 512


def routing_cost_model(k: int, cb: int, r: int, ktile: int = 128) -> dict:
    """Estimated per-step cycles of each routing path (relative units).

    one-hot: two MXU contractions, [K, CB] @ [CB, ktile] and
    [K, R]ᵀ @ [K, ktile] → K·(CB+R)·ktile MACs.
    gather: K dynamic row fetches of a ktile-wide f32 row (latency/bandwidth
    bound on the VPU) + the same one-hot scatter contraction.
    """
    onehot = k * (cb + r) * ktile / _MXU_MACS_PER_CYCLE
    gather = (
        k * ktile * 4 / _GATHER_BYTES_PER_CYCLE + k * r * ktile / _MXU_MACS_PER_CYCLE
    )
    return {ONEHOT: onehot, GATHER: gather}


def select_routing(k: int, cb: int, r: int, ktile: int = 128) -> str:
    """Pick the cheaper routing for one operand: one-hot MXU routing wins
    when the column block is capped small; gather wins when the block spans
    a wide (ultra-sparse) operand."""
    cost = routing_cost_model(k, cb, r, ktile)
    return ONEHOT if cost[ONEHOT] <= cost[GATHER] else GATHER


class InjectedFault(RuntimeError):
    """Raised by ``FaultInjector.check`` at an armed seam (the default
    exception type; ``arm(exc=...)`` substitutes another)."""


#: wildcard filter value for FaultInjector.arm — matches any context
ANY = object()


class FaultInjector:
    """Deterministic failure injection for the executor/serving stack.

    Production code calls ``check(site, **ctx)`` at a few named seams;
    the call is free when nothing is armed, and raises when an armed
    fault matches. The seams:

    * ``"upload"``   — host→device array upload (``_placed``), context
      ``device=``: fails a device upload, e.g. mid re-admission.
    * ``"dispatch"`` — the serving engine's batch dispatch, context
      ``graph=``: fails the whole dispatch before any work is charged.
    * ``"replica_chunk"`` — one replica's sub-batch execution, context
      ``graph=``/``device=``: fails exactly one clone's chunk, leaving
      its siblings healthy.

    ``arm(site, times=n)`` fires the next ``n`` matching checks (filters
    ``graph=``/``device=`` restrict the match; default matches any).
    ``clear()`` disarms everything; ``fired`` logs each raised fault as
    ``(site, graph, device)`` for assertions. Test seam only — never arm
    in production code.
    """

    def __init__(self):
        self._armed: list = []
        self.fired: list = []

    def arm(
        self, site: str, *, times: int = 1, exc=None, graph=ANY, device=ANY
    ) -> None:
        self._armed.append(
            {
                "site": site,
                "times": int(times),
                "exc": exc,
                "graph": graph,
                "device": device,
            }
        )

    def clear(self) -> None:
        self._armed.clear()
        self.fired.clear()

    def check(self, site: str, *, graph=None, device=None) -> None:
        if not self._armed:
            return
        for f in self._armed:
            if f["site"] != site:
                continue
            if f["graph"] is not ANY and f["graph"] != graph:
                continue
            if f["device"] is not ANY and f["device"] != device:
                continue
            f["times"] -= 1
            if f["times"] <= 0:
                self._armed.remove(f)
            self.fired.append((site, graph, device))
            raise (
                f["exc"]
                if f["exc"] is not None
                else InjectedFault(
                    f"injected {site} fault (graph={graph!r}, "
                    f"device={device!r})"
                )
            )


#: process-wide injector instance the seams consult (tests arm/clear it)
FAULTS = FaultInjector()


# step-major device copies of schedule arrays, shared between
# ScheduleExecutor and the Pallas kernel wrapper so one schedule is
# uploaded once no matter who consumes it. Keyed on (schedule identity,
# placement device), bounded LRU — the serving tier places executors on
# specific mesh devices, and each placement owns its own copy.
_DEVICE_STEPS: "OrderedDict[tuple, tuple]" = OrderedDict()
_DEVICE_STEPS_CAP = 32


def _placed(x, device):
    """Upload ``x`` to ``device`` (None = jax's default placement)."""
    FAULTS.check("upload", device=device)
    if device is None:
        return jnp.asarray(x)
    return jax.device_put(jnp.asarray(x), device)


def device_step_arrays(sched: Schedule, device=None) -> dict:
    """Step-major jnp arrays of one schedule — ``val``/``lrow``/``lcol``
    reshaped [n_steps, K], ``win``/``cblk`` per step, ``row_map`` — uploaded
    once per (schedule instance, device) and memoized (bounded LRU)."""
    key = (id(sched), device)
    hit = _DEVICE_STEPS.get(key)
    if hit is not None and hit[0] is sched:
        _DEVICE_STEPS.move_to_end(key)
        return hit[1]
    n_steps, k = sched.n_steps, sched.nnz_per_step
    arrs = {
        "val": _placed(sched.val.reshape(n_steps, k), device),
        "lrow": _placed(sched.local_row.reshape(n_steps, k), device),
        "lcol": _placed(sched.local_col.reshape(n_steps, k), device),
        "win": _placed(sched.win_id, device),
        "cblk": _placed(sched.col_block, device),
        "row_map": _placed(sched.row_map, device),
    }
    _DEVICE_STEPS[key] = (sched, arrs)
    if len(_DEVICE_STEPS) > _DEVICE_STEPS_CAP:
        _DEVICE_STEPS.popitem(last=False)
    return arrs


#: sentinel for ``release_device_steps``: drop the copies on *every*
#: device (``None`` is a real placement handle — jax's default device —
#: so it cannot double as the catch-all)
ALL_DEVICES = object()


def release_device_steps(sched: Schedule, device=ALL_DEVICES) -> None:
    """Drop memoized device copies of one schedule's step arrays.

    The serving engine's eviction and ``tuning.registry.release_graph``
    call this so a one-hot executor's uploads don't outlive their owner —
    without it the identity-keyed LRU above keeps the arrays resident
    until 32 unrelated schedules displace them. Pass ``device`` (a
    placement handle, ``None`` meaning the default device) to drop only
    that device's copy — what dropping **one replica** of a multi-replica
    graph needs: the surviving replicas' uploads on other devices must
    stay resident."""
    sid = id(sched)
    if device is ALL_DEVICES:
        keys = [k for k in _DEVICE_STEPS if k[0] == sid]
    else:
        keys = [(sid, device)] if (sid, device) in _DEVICE_STEPS else []
    for key in keys:
        del _DEVICE_STEPS[key]


def _gather_slots(sched: Schedule):
    """Per-slot flat arrays of the fused-gather routing: global B-row
    ``gcol``, output row ``tgt`` (``row_map ∘ slot`` precomposed: the
    scatter epilogue folds into the main scatter — padding slots carry
    ``val == 0``, so a clamped target row accumulates nothing), and the
    slot values. All step-major, length ``n_steps * nnz_per_step``."""
    m, n = sched.shape
    k = sched.nnz_per_step
    r = sched.rows_per_window
    cb = sched.cols_per_block
    win_slot = np.repeat(sched.win_id.astype(np.int64), k)
    cblk_slot = np.repeat(sched.col_block.astype(np.int64), k)
    gcol = np.minimum(cblk_slot * cb + sched.local_col, n - 1)
    slot = win_slot * r + sched.local_row
    tgt = np.maximum(sched.row_map[slot], 0).astype(np.int32)
    return gcol.astype(np.int32), tgt, sched.val


def _gather_slots_steps(sched: Schedule, steps: np.ndarray):
    """``_gather_slots`` restricted to the given step indices — what the
    repair path computes for re-emitted steps only, instead of re-deriving
    the whole slot stream."""
    _, n = sched.shape
    k = sched.nnz_per_step
    r = sched.rows_per_window
    cb = sched.cols_per_block
    steps = np.asarray(steps, np.int64)
    sl = (steps[:, None] * k + np.arange(k, dtype=np.int64)).reshape(-1)
    win = np.repeat(sched.win_id[steps].astype(np.int64), k)
    cblk = np.repeat(sched.col_block[steps].astype(np.int64), k)
    gcol = np.minimum(cblk * cb + sched.local_col[sl], n - 1).astype(np.int32)
    tgt = np.maximum(sched.row_map[win * r + sched.local_row[sl]], 0).astype(np.int32)
    return gcol, tgt, sched.val[sl]


def _spliced_host_slots(old_host, new_sched: Schedule, repair):
    """Host gather-slot arrays of a repaired schedule, spliced from the old
    executor's retained host slots plus freshly derived slots for the
    re-emitted steps. Returns ``(gcol, tgt, val, moved)`` where ``moved``
    flags steps whose *device position or content* changed — the scoped
    re-upload set. Reused steps carry their slot payloads verbatim: the
    repair guarantees window-aligned steps keep identical ``gcol`` (same
    local cols/blocks), ``tgt`` (the new row_map holds the same row values
    at the remapped window slots) and ``val``."""
    og, ot, ov = old_host
    k = new_sched.nnz_per_step
    src = np.asarray(repair.step_src, np.int64)
    s_new = src.shape[0]
    if s_new != new_sched.n_steps:
        raise ValueError("step_src does not match the repaired schedule")
    moved = src != np.arange(s_new, dtype=np.int64)
    reused = src >= 0
    fresh = np.nonzero(~reused)[0]
    if fresh.size:
        fg, ft, fv = _gather_slots_steps(new_sched, fresh)
    else:
        fg = ft = fv = None

    def take(oa, fa, dtype):
        out = np.empty((s_new, k), dtype)
        out[reused] = oa.reshape(-1, k)[src[reused]]
        if fa is not None:
            out[~reused] = fa.reshape(-1, k)
        return out.reshape(-1)

    gcol = take(og, fg, np.int32)
    tgt = take(ot, ft, np.int32)
    val = take(ov, fv, ov.dtype)
    return gcol, tgt, val, moved


class _ExecutorBase:
    """Shared surface of the single- and multi-device executors: operand
    validation, the jitted-closure call protocol, and the whole-GCN forward
    loop (every layer's A × (X × W) through ``self._spmm_impl``)."""

    sched: Schedule
    routing: str
    bf16_accumulate: bool = False
    #: placement handle: the specific mesh device this executor's arrays
    #: live on (None = jax's default device; always None for the sharded
    #: executor, whose mesh is the placement).
    device = None

    @property
    def _acc_dtype(self):
        return jnp.bfloat16 if self.bf16_accumulate else jnp.float32

    def commit(self, x: jax.Array) -> jax.Array:
        """Commit a dense operand to this executor's placement device, so
        the jitted closures run where the schedule arrays already live (an
        uncommitted operand would pull the computation — and a copy of
        every captured array — onto jax's default device)."""
        if self.device is None:
            return x
        return jax.device_put(x, self.device)

    def spmm(self, b: jax.Array) -> jax.Array:
        """C = A @ b through the device-resident converged schedule."""
        if b.shape[0] != self.sched.shape[1]:
            raise ValueError(
                f"operand has {b.shape[0]} rows; schedule expects "
                f"{self.sched.shape[1]} (A is {self.sched.shape}) — XLA "
                "would silently clamp gather indices otherwise"
            )
        return self._spmm(self.commit(b))

    __call__ = spmm

    def forward(self, params: dict, x: jax.Array) -> jax.Array:
        """Whole-GCN forward ``softmax-free`` logits: every layer runs
        A × (X × W) through this executor inside one jit."""
        if x.shape[0] != self.sched.shape[1]:
            raise ValueError(
                f"features have {x.shape[0]} rows; schedule expects "
                f"{self.sched.shape[1]} (A is {self.sched.shape})"
            )
        if self.device is not None:
            params = jax.tree.map(self.commit, params)
        return self._forward(params, self.commit(x))

    @property
    def utilization(self) -> float:
        return self.sched.utilization

    def _forward_impl(self, params: dict, x: jax.Array) -> jax.Array:
        h = x
        n_layers = len(params)
        for i in range(n_layers):
            h = self._spmm_impl(h @ params[f"w{i}"])  # A × (X × W)
            if i < n_layers - 1:
                h = jax.nn.relu(h)
        return h


class ScheduleExecutor(_ExecutorBase):
    """Device-resident executor of one converged AWB schedule.

    Construction uploads every schedule array to one device once; the
    jitted closures capture those arrays, so repeated ``spmm``/``forward``
    calls move only the dense operand. ``device_bytes`` reports the
    resident footprint — what the serving engine's LRU budget meters.

    ``device`` is the placement handle: pass a specific ``jax.Device`` to
    pin the schedule arrays (and therefore the computation — operands are
    committed there by ``spmm``/``forward``) to one device of a mesh; the
    serving tier's ``MeshPlacer`` hands each graph such a handle. ``None``
    keeps jax's default placement.

    ``row_unperm`` supports locality-reordered schedules (core.reorder):
    when ``sched`` was built on a row-permuted graph, pass the inverse
    permutation (``inv[old_row] = new_row``) and every ``spmm``/``forward``
    output comes back in **original** row order — one fused gather per
    call, bit-identical to executing the unpermuted schedule.
    """

    def __init__(
        self,
        sched: Schedule,
        *,
        ktile: int = 128,
        routing: Optional[str] = None,
        bf16_accumulate: bool = False,
        slot_chunk: int = 1 << 18,
        device=None,
        row_unperm=None,
    ):
        self.sched = sched
        self.ktile = ktile
        self.bf16_accumulate = bf16_accumulate
        self.device = device
        self.row_unperm = (
            None if row_unperm is None else np.asarray(row_unperm, np.int32)
        )
        self._unperm = (
            None if self.row_unperm is None else _placed(self.row_unperm, device)
        )
        self._slot_chunk_arg = slot_chunk
        k = sched.nnz_per_step
        r = sched.rows_per_window
        cb = sched.cols_per_block
        self.routing = routing or select_routing(k, cb, r, ktile)
        #: set by the repair path: True when the last (re)construction
        #: uploaded only the dirty slot set instead of the full stream
        self.scoped_upload = False

        # ---- one-time host-side precompute + host→device upload ----------
        # only the selected routing's representation is built/uploaded
        if self.routing == GATHER:
            gcol, tgt, val = _gather_slots(sched)
            # host copies are retained so an incremental repair can splice
            # new slot streams without re-deriving every step (DESIGN.md §11)
            self._host = (gcol, tgt, val)

            # pad the flat slot stream to a whole number of chunks so the
            # fused gather path can bound its [chunk, kdim] intermediate
            s_total = gcol.shape[0]
            self._slot_chunk = int(min(slot_chunk, max(1, s_total)))
            pad = (-s_total) % self._slot_chunk
            self._n_chunks = (s_total + pad) // self._slot_chunk

            def _chunked(x, fill):
                return _placed(
                    np.concatenate([x, np.full(pad, fill, x.dtype)]).reshape(
                        self._n_chunks, self._slot_chunk
                    ),
                    device,
                )

            self._gcol = _chunked(gcol, 0)
            self._tgt = _chunked(tgt, 0)
            self._val = _chunked(val, 0.0)
            self.device_bytes = int(
                self._gcol.nbytes + self._tgt.nbytes + self._val.nbytes
            )
        else:
            # step-major arrays (shared with the Pallas kernel wrapper —
            # one upload per (schedule, device) no matter who consumes it)
            self._steps = device_step_arrays(sched, device)
            self.device_bytes = int(sum(v.nbytes for v in self._steps.values()))
        if self._unperm is not None:
            self.device_bytes += int(self._unperm.nbytes)

        self._spmm_impl = (
            self._gather_impl if self.routing == GATHER else self._onehot_impl
        )
        self._spmm = jax.jit(self._spmm_impl)
        self._forward = jax.jit(self._forward_impl)

    @classmethod
    def _from_repair(
        cls, old_ex: "ScheduleExecutor", new_sched: Schedule, repair
    ) -> "ScheduleExecutor":
        """Executor for a repaired schedule that reuses the old executor's
        device buffers wherever the repair left steps untouched.

        GATHER: the host slot stream is spliced (reused steps copy their old
        slot rows, re-emitted steps derive fresh ones), and when the chunk
        grid is unchanged only the *moved* slots are scattered into the old
        device arrays (`.at[idx].set` — copy-on-write, so the old executor
        keeps serving in-flight batches untouched). ONEHOT or any fallback
        repair rebuilds from scratch — a fresh full upload.

        The result is a **new** executor object with fresh jit closures;
        never mutates ``old_ex``. Device contents are bit-identical to a
        cold ``ScheduleExecutor(new_sched, ...)`` with the same kwargs.
        """
        if (
            old_ex.routing != GATHER
            or repair.fell_back
            or repair.step_src is None
            or getattr(old_ex, "_host", None) is None
        ):
            return cls(
                new_sched,
                ktile=old_ex.ktile,
                routing=old_ex.routing,
                bf16_accumulate=old_ex.bf16_accumulate,
                slot_chunk=old_ex._slot_chunk_arg,
                device=old_ex.device,
                row_unperm=old_ex.row_unperm,
            )
        self = cls.__new__(cls)
        self.sched = new_sched
        self.ktile = old_ex.ktile
        self.bf16_accumulate = old_ex.bf16_accumulate
        self.device = old_ex.device
        self.routing = GATHER
        self._slot_chunk_arg = old_ex._slot_chunk_arg
        self.row_unperm = old_ex.row_unperm
        self._unperm = old_ex._unperm

        k = new_sched.nnz_per_step
        gcol, tgt, val, moved = _spliced_host_slots(old_ex._host, new_sched, repair)
        self._host = (gcol, tgt, val)
        s_total = gcol.shape[0]
        self._slot_chunk = int(min(self._slot_chunk_arg, max(1, s_total)))
        pad = (-s_total) % self._slot_chunk
        self._n_chunks = (s_total + pad) // self._slot_chunk
        # scoped patch is sound only on an identical padded grid — same
        # slot count (so the old padding region still pads) and same
        # chunking (so accumulation order, hence bitwise output, matches a
        # cold build)
        same_grid = (
            s_total == old_ex._host[0].shape[0]
            and self._slot_chunk == old_ex._slot_chunk
            and self._n_chunks == old_ex._n_chunks
        )
        n_moved = int(np.count_nonzero(moved)) * k
        if same_grid and n_moved == 0:
            # content and layout identical: the old device arrays ARE the
            # new ones (jax arrays are immutable — sharing is safe)
            self._gcol, self._tgt = old_ex._gcol, old_ex._tgt
            self._val = old_ex._val
            self.scoped_upload = True
        elif (
            same_grid
            and 2 * n_moved <= s_total
            and s_total * 12 >= SCOPED_UPLOAD_MIN_BYTES
        ):
            FAULTS.check("upload", device=self.device)
            steps = np.nonzero(moved)[0]
            idx = (steps[:, None] * k + np.arange(k, dtype=np.int64)).reshape(-1)
            # pad the scatter index to a coarse bucket (repeating the
            # last slot — duplicate .set with an identical value is
            # harmless) so repeated small updates reuse a handful of
            # compiled scatters instead of recompiling per dirty-set size
            bucket = 1024
            while bucket < idx.size:
                bucket *= 4
            if bucket > idx.size:
                idx = np.concatenate(
                    [idx, np.full(bucket - idx.size, idx[-1], idx.dtype)]
                )
            jidx = jnp.asarray(idx.astype(np.int32))

            def _patch(dev, host):
                flat = dev.reshape(-1).at[jidx].set(jnp.asarray(host[idx]))
                return flat.reshape(self._n_chunks, self._slot_chunk)

            self._gcol = _patch(old_ex._gcol, gcol)
            self._tgt = _patch(old_ex._tgt, tgt)
            self._val = _patch(old_ex._val, val)
            self.scoped_upload = True
        else:

            def _chunked(x, fill):
                return _placed(
                    np.concatenate([x, np.full(pad, fill, x.dtype)]).reshape(
                        self._n_chunks, self._slot_chunk
                    ),
                    self.device,
                )

            self._gcol = _chunked(gcol, 0)
            self._tgt = _chunked(tgt, 0)
            self._val = _chunked(val, 0.0)
            self.scoped_upload = False
        self.device_bytes = int(self._gcol.nbytes + self._tgt.nbytes + self._val.nbytes)
        if self._unperm is not None:
            self.device_bytes += int(self._unperm.nbytes)
        self._spmm_impl = self._gather_impl
        self._spmm = jax.jit(self._spmm_impl)
        self._forward = jax.jit(self._forward_impl)
        return self

    @classmethod
    def _value_patched(
        cls,
        old_ex: "ScheduleExecutor",
        new_sched: Schedule,
        slots: np.ndarray,
        vals: np.ndarray,
    ) -> "ScheduleExecutor":
        """Executor for a *value-only* patched schedule: structure (and
        therefore the slot layout, chunk grid, gcol/tgt streams) is
        byte-identical to ``old_ex``; only ``val`` changed, at ``slots``.

        O(|delta|): shares the old device ``_gcol``/``_tgt`` arrays
        outright and scatters just the changed values into ``_val``
        (copy-on-write — the old executor keeps serving untouched). The
        scatter index is padded to a small fixed bucket so every update of
        a given size class reuses one compiled scatter."""
        if old_ex.routing != GATHER or getattr(old_ex, "_host", None) is None:
            return cls(
                new_sched,
                ktile=old_ex.ktile,
                routing=old_ex.routing,
                bf16_accumulate=old_ex.bf16_accumulate,
                slot_chunk=old_ex._slot_chunk_arg,
                device=old_ex.device,
                row_unperm=old_ex.row_unperm,
            )
        self = cls.__new__(cls)
        self.sched = new_sched
        self.ktile = old_ex.ktile
        self.bf16_accumulate = old_ex.bf16_accumulate
        self.device = old_ex.device
        self.routing = GATHER
        self._slot_chunk_arg = old_ex._slot_chunk_arg
        self._slot_chunk = old_ex._slot_chunk
        self._n_chunks = old_ex._n_chunks
        self.row_unperm = old_ex.row_unperm
        self._unperm = old_ex._unperm

        gcol, tgt, oval = old_ex._host
        val = oval.copy()
        val[slots] = np.asarray(vals, val.dtype)
        self._host = (gcol, tgt, val)
        self._gcol, self._tgt = old_ex._gcol, old_ex._tgt
        if slots.size == 0:
            self._val = old_ex._val
        else:
            FAULTS.check("upload", device=self.device)
            idx = np.asarray(slots, np.int64)
            bucket = 64
            while bucket < idx.size:
                bucket *= 4
            if bucket > idx.size:
                idx = np.concatenate(
                    [idx, np.full(bucket - idx.size, idx[-1], idx.dtype)]
                )
            self._val = _scatter_set(old_ex._val, idx.astype(np.int32), val[idx])
        self.scoped_upload = True
        self.device_bytes = old_ex.device_bytes
        self._spmm_impl = self._gather_impl
        self._spmm = jax.jit(self._spmm_impl)
        self._forward = jax.jit(self._forward_impl)
        return self

    # ---- jitted bodies -----------------------------------------------------

    def _gather_impl(self, b: jax.Array) -> jax.Array:
        """Fused-gather routing: B-row gather per slot, one scatter-add into
        final output rows (row_map precomposed). Chunked over the slot
        stream so the [chunk, kdim] intermediate stays bounded on
        million-edge graphs."""
        m, _ = self.sched.shape
        kdim = b.shape[-1]
        acc = self._acc_dtype
        bf = b.astype(acc)
        out = jnp.zeros((m, kdim), acc)

        if self._n_chunks == 1:
            g = jnp.take(bf, self._gcol[0], axis=0) * self._val[0].astype(acc)[:, None]
            out = out.at[self._tgt[0]].add(g)
        else:

            def body(i, a_):
                g = (
                    jnp.take(bf, self._gcol[i], axis=0)
                    * self._val[i].astype(acc)[:, None]
                )
                return a_.at[self._tgt[i]].add(g)

            out = jax.lax.fori_loop(0, self._n_chunks, body, out)
        if self._unperm is not None:
            out = jnp.take(out, self._unperm, axis=0)
        return out.astype(b.dtype)

    def _onehot_impl(self, b: jax.Array) -> jax.Array:
        """Dense-routing emulation: scan over steps, each step doing the
        Pallas kernel's two one-hot MXU contractions against the step's
        [CB, kdim] B-panel. The measurable XLA twin of the kernel."""
        m, n = self.sched.shape
        k = self.sched.nnz_per_step
        r = self.sched.rows_per_window
        cb = self.sched.cols_per_block
        kdim = b.shape[-1]
        acc = self._acc_dtype
        ncb = -(-n // cb)
        bp = jnp.pad(b.astype(acc), ((0, ncb * cb - n), (0, 0)))
        bp = bp.reshape(ncb, cb, kdim)

        def step(out_perm, s):
            win, cblk, val, lrow, lcol = s
            bb = bp[cblk]  # [CB, kdim]
            gather = (lcol[:, None] == jnp.arange(cb)[None, :]).astype(acc)  # [K, CB]
            contrib = (gather @ bb) * val.astype(acc)[:, None]  # [K, kdim]
            scatter = (lrow[:, None] == jnp.arange(r)[None, :]).astype(acc)  # [K, R]
            out_perm = out_perm.at[win].add(scatter.T @ contrib)
            return out_perm, None

        out_perm = jnp.zeros((self.sched.n_windows, r, kdim), acc)
        out_perm, _ = jax.lax.scan(
            step,
            out_perm,
            (
                self._steps["win"],
                self._steps["cblk"],
                self._steps["val"],
                self._steps["lrow"],
                self._steps["lcol"],
            ),
        )
        # scatter epilogue (adder tree): permuted window slots → matrix rows
        rm = self._steps["row_map"]
        valid = rm >= 0
        contrib = jnp.where(valid[:, None], out_perm.reshape(-1, kdim), 0.0)
        out = jnp.zeros((m, kdim), acc).at[jnp.where(valid, rm, 0)].add(contrib)
        if self._unperm is not None:
            out = jnp.take(out, self._unperm, axis=0)
        return out.astype(b.dtype)


class ShardedScheduleExecutor(_ExecutorBase):
    """Multi-device executor of one converged AWB schedule.

    The schedule is split by ``sharding.schedule_shard`` into contiguous
    per-device step shards (steps are equal work, so equal counts are
    balanced devices — the paper's equal-work distribution across the PE
    array, lifted one level to the device mesh). Construction uploads each
    shard to its own device exactly once (``device_put`` with a
    ``P('dev', ...)`` sharding on the stacked step axis); ``spmm``/
    ``forward`` then run the routing body under ``shard_map`` and merge the
    per-device partial outputs with a ``psum`` — the distributed adder
    tree that also reunites evil-row chunks and boundary-straddling
    windows living on different devices.

    Both routing paths shard identically: the step axis is the shard axis,
    and each device executes exactly the single-device body over its own
    steps. Numerics therefore match the single-device executor up to f32
    re-association of the cross-device sum.
    """

    def __init__(
        self,
        sched: Schedule,
        *,
        n_devices: Optional[int] = None,
        mesh: Optional[Mesh] = None,
        ktile: int = 128,
        routing: Optional[str] = None,
        bf16_accumulate: bool = False,
        slot_chunk: int = 1 << 18,
        row_unperm=None,
    ):
        if mesh is None:
            devs = jax.devices()
            if n_devices is None:
                n_devices = len(devs)
            if not 1 <= n_devices <= len(devs):
                raise ValueError(
                    f"n_devices={n_devices} but this host exposes "
                    f"{len(devs)} device(s)"
                )
            mesh = Mesh(np.asarray(devs[:n_devices]), ("dev",))
        else:
            if len(mesh.axis_names) != 1:
                raise ValueError(
                    "ShardedScheduleExecutor shards over one step axis and "
                    f"needs a 1-D mesh; got axes {mesh.axis_names}"
                )
            if n_devices is not None and n_devices != mesh.devices.size:
                raise ValueError(
                    f"n_devices={n_devices} contradicts the given mesh of "
                    f"{mesh.devices.size} device(s); pass one or the other"
                )
            n_devices = int(mesh.devices.size)
        self.mesh = mesh
        self.axis = mesh.axis_names[0]
        self.n_devices = n_devices
        self.sched = sched
        self.ktile = ktile
        self.bf16_accumulate = bf16_accumulate
        self._slot_chunk_arg = slot_chunk
        self.row_unperm = (
            None if row_unperm is None else np.asarray(row_unperm, np.int32)
        )
        # replicated — the un-permute runs on the psum-merged output
        self._unperm = (
            None
            if self.row_unperm is None
            else jax.device_put(
                jnp.asarray(self.row_unperm), NamedSharding(mesh, P())
            )
        )
        k = sched.nnz_per_step
        r = sched.rows_per_window
        cb = sched.cols_per_block
        self.routing = routing or select_routing(k, cb, r, ktile)
        #: set by the repair path: True when the last (re)construction
        #: re-uploaded only the device shards whose steps changed
        self.scoped_upload = False

        shards = shard_schedule(sched, n_devices)
        self.step_ranges = shards.ranges

        def put(x, *tail_spec):
            return jax.device_put(
                jnp.asarray(x), NamedSharding(mesh, P(self.axis, *tail_spec))
            )

        # ---- one-time host-side split + per-device upload ----------------
        if self.routing == GATHER:
            gcol, tgt, val = _gather_slots(sched)
            # retained for incremental repair splicing (DESIGN.md §11)
            self._host = (gcol, tgt, val)
            # per-device flat slot streams, padded to the common shard
            # length, then chunked so the [chunk, kdim] intermediate stays
            # bounded (same contract as the single-device executor)
            s_max = shards.steps_per_shard
            length = s_max * k
            self._slot_chunk = int(min(slot_chunk, max(1, length)))
            pad = (-length) % self._slot_chunk
            self._n_chunks = (length + pad) // self._slot_chunk

            def stack(x, fill):
                out = np.full((n_devices, length + pad), fill, x.dtype)
                for d, (lo, hi) in enumerate(shards.ranges):
                    out[d, : (hi - lo) * k] = x[lo * k : hi * k]
                return put(out.reshape(n_devices, self._n_chunks, self._slot_chunk))

            self._gcol = stack(gcol, 0)
            self._tgt = stack(tgt, 0)
            self._val = stack(val, 0.0)
            self.device_bytes = int(
                self._gcol.nbytes + self._tgt.nbytes + self._val.nbytes
            )
            if self._unperm is not None:
                self.device_bytes += int(self._unperm.nbytes)
        else:
            self._steps = {
                "val": put(shards.val),
                "lrow": put(shards.lrow),
                "lcol": put(shards.lcol),
                "win": put(shards.win),
                "cblk": put(shards.cblk),
                # replicated: the epilogue runs device-local, pre-psum
                "row_map": jax.device_put(
                    jnp.asarray(sched.row_map), NamedSharding(mesh, P())
                ),
            }
            self.device_bytes = int(sum(v.nbytes for v in self._steps.values()))
            if self._unperm is not None:
                self.device_bytes += int(self._unperm.nbytes)

        self._spmm_impl = (
            self._sharded_gather_impl
            if self.routing == GATHER
            else self._sharded_onehot_impl
        )
        self._spmm = jax.jit(self._spmm_impl)
        self._forward = jax.jit(self._forward_impl)

    @classmethod
    def _from_repair(
        cls, old_ex: "ShardedScheduleExecutor", new_sched: Schedule, repair
    ) -> "ShardedScheduleExecutor":
        """Sharded executor for a repaired schedule, re-uploading only the
        device shards whose step range contains a moved/re-emitted step.

        The step count must be unchanged (the linspace split is then
        identical, so each clean device's stacked shard is byte-identical);
        otherwise — or for ONEHOT routing or a fallback repair — this
        rebuilds from scratch. Clean devices keep their existing on-device
        shard buffers via ``make_array_from_single_device_arrays``; the new
        executor is a distinct object with fresh jit closures, and the old
        one keeps serving in-flight batches."""
        if (
            old_ex.routing != GATHER
            or repair.fell_back
            or repair.step_src is None
            or getattr(old_ex, "_host", None) is None
            or new_sched.n_steps != old_ex.sched.n_steps
        ):
            return cls(
                new_sched,
                mesh=old_ex.mesh,
                ktile=old_ex.ktile,
                routing=old_ex.routing,
                bf16_accumulate=old_ex.bf16_accumulate,
                slot_chunk=old_ex._slot_chunk_arg,
                row_unperm=old_ex.row_unperm,
            )
        self = cls.__new__(cls)
        self.mesh = old_ex.mesh
        self.axis = old_ex.axis
        self.n_devices = old_ex.n_devices
        self.sched = new_sched
        self.ktile = old_ex.ktile
        self.bf16_accumulate = old_ex.bf16_accumulate
        self.routing = GATHER
        self._slot_chunk_arg = old_ex._slot_chunk_arg
        self.row_unperm = old_ex.row_unperm
        self._unperm = old_ex._unperm
        # n_steps unchanged ⇒ the deterministic linspace split is identical
        self.step_ranges = old_ex.step_ranges
        self._slot_chunk = old_ex._slot_chunk
        self._n_chunks = old_ex._n_chunks

        k = new_sched.nnz_per_step
        gcol, tgt, val, moved = _spliced_host_slots(old_ex._host, new_sched, repair)
        self._host = (gcol, tgt, val)
        n_devices = self.n_devices
        row_len = self._n_chunks * self._slot_chunk
        dirty = [bool(np.any(moved[lo:hi])) for lo, hi in self.step_ranges]
        devices = list(self.mesh.devices.reshape(-1))
        sharding = NamedSharding(self.mesh, P(self.axis))
        gshape = (n_devices, self._n_chunks, self._slot_chunk)

        def _restack(old_arr, flat, fill):
            by_dev = {s.device: s.data for s in old_arr.addressable_shards}
            parts = []
            for d, dev in enumerate(devices):
                lo, hi = self.step_ranges[d]
                if not dirty[d]:
                    parts.append(by_dev[dev])
                    continue
                FAULTS.check("upload", device=dev)
                row = np.full((1, row_len), fill, flat.dtype)
                row[0, : (hi - lo) * k] = flat[lo * k : hi * k]
                parts.append(
                    jax.device_put(
                        jnp.asarray(row.reshape(1, self._n_chunks, self._slot_chunk)),
                        dev,
                    )
                )
            return jax.make_array_from_single_device_arrays(gshape, sharding, parts)

        self._gcol = _restack(old_ex._gcol, gcol, 0)
        self._tgt = _restack(old_ex._tgt, tgt, 0)
        self._val = _restack(old_ex._val, val, 0.0)
        self.scoped_upload = not all(dirty)
        self.dirty_devices = int(sum(dirty))
        self.device_bytes = int(self._gcol.nbytes + self._tgt.nbytes + self._val.nbytes)
        if self._unperm is not None:
            self.device_bytes += int(self._unperm.nbytes)
        self._spmm_impl = self._sharded_gather_impl
        self._spmm = jax.jit(self._spmm_impl)
        self._forward = jax.jit(self._forward_impl)
        return self

    @classmethod
    def _value_patched(
        cls,
        old_ex: "ShardedScheduleExecutor",
        new_sched: Schedule,
        slots: np.ndarray,
        vals: np.ndarray,
    ) -> "ShardedScheduleExecutor":
        """Sharded executor for a value-only patched schedule: slot layout
        and step split are identical to ``old_ex``, only ``val`` changed at
        ``slots``. Shares the global ``_gcol``/``_tgt`` arrays and re-uploads
        just the ``_val`` shards of devices whose step range contains a
        changed slot; clean devices keep their existing shard buffers."""
        if old_ex.routing != GATHER or getattr(old_ex, "_host", None) is None:
            return cls(
                new_sched,
                mesh=old_ex.mesh,
                ktile=old_ex.ktile,
                routing=old_ex.routing,
                bf16_accumulate=old_ex.bf16_accumulate,
                slot_chunk=old_ex._slot_chunk_arg,
                row_unperm=old_ex.row_unperm,
            )
        self = cls.__new__(cls)
        self.mesh = old_ex.mesh
        self.axis = old_ex.axis
        self.n_devices = old_ex.n_devices
        self.sched = new_sched
        self.ktile = old_ex.ktile
        self.bf16_accumulate = old_ex.bf16_accumulate
        self.routing = GATHER
        self._slot_chunk_arg = old_ex._slot_chunk_arg
        self.row_unperm = old_ex.row_unperm
        self._unperm = old_ex._unperm
        self.step_ranges = old_ex.step_ranges
        self._slot_chunk = old_ex._slot_chunk
        self._n_chunks = old_ex._n_chunks

        gcol, tgt, oval = old_ex._host
        val = oval.copy()
        val[slots] = np.asarray(vals, val.dtype)
        self._host = (gcol, tgt, val)
        self._gcol, self._tgt = old_ex._gcol, old_ex._tgt

        k = new_sched.nnz_per_step
        touched_steps = np.unique(np.asarray(slots, np.int64) // k)
        row_len = self._n_chunks * self._slot_chunk
        dirty = [
            bool(np.any((touched_steps >= lo) & (touched_steps < hi)))
            for lo, hi in self.step_ranges
        ]
        devices = list(self.mesh.devices.reshape(-1))
        sharding = NamedSharding(self.mesh, P(self.axis))
        gshape = (self.n_devices, self._n_chunks, self._slot_chunk)
        by_dev = {s.device: s.data for s in old_ex._val.addressable_shards}
        parts = []
        for d, dev in enumerate(devices):
            lo, hi = self.step_ranges[d]
            if not dirty[d]:
                parts.append(by_dev[dev])
                continue
            FAULTS.check("upload", device=dev)
            row = np.zeros((1, row_len), val.dtype)
            row[0, : (hi - lo) * k] = val[lo * k : hi * k]
            parts.append(
                jax.device_put(
                    jnp.asarray(row.reshape(1, self._n_chunks, self._slot_chunk)),
                    dev,
                )
            )
        self._val = jax.make_array_from_single_device_arrays(gshape, sharding, parts)
        self.scoped_upload = True
        self.dirty_devices = int(sum(dirty))
        self.device_bytes = old_ex.device_bytes
        self._spmm_impl = self._sharded_gather_impl
        self._spmm = jax.jit(self._spmm_impl)
        self._forward = jax.jit(self._forward_impl)
        return self

    def _shard_map(self, body, in_specs):
        # check_rep=False: the bodies end in an explicit psum, which makes
        # the P() output replicated by construction; the static replication
        # checker has no rule for scatter-add on some jax versions.
        return shard_map(
            body, mesh=self.mesh, in_specs=in_specs, out_specs=P(), check_rep=False
        )

    # ---- jitted bodies -----------------------------------------------------

    def _sharded_gather_impl(self, b: jax.Array) -> jax.Array:
        """Fused-gather routing per device shard + psum merge."""
        m, _ = self.sched.shape
        axis = self.axis
        acc = self._acc_dtype
        n_chunks = self._n_chunks

        def body(gcol, tgt, val, bf):
            gcol, tgt, val = gcol[0], tgt[0], val[0]  # [n_chunks, chunk]
            out = jnp.zeros((m, bf.shape[1]), acc)
            if n_chunks == 1:
                g = jnp.take(bf, gcol[0], axis=0) * val[0].astype(acc)[:, None]
                out = out.at[tgt[0]].add(g)
            else:

                def chunk(i, a_):
                    g = jnp.take(bf, gcol[i], axis=0) * val[i].astype(acc)[:, None]
                    return a_.at[tgt[i]].add(g)

                out = jax.lax.fori_loop(0, n_chunks, chunk, out)
            return jax.lax.psum(out, axis)

        fn = self._shard_map(body, (P(axis), P(axis), P(axis), P()))
        out = fn(self._gcol, self._tgt, self._val, b.astype(acc))
        if self._unperm is not None:
            out = jnp.take(out, self._unperm, axis=0)
        return out.astype(b.dtype)

    def _sharded_onehot_impl(self, b: jax.Array) -> jax.Array:
        """Per-device one-hot step scan + local scatter epilogue, then a
        psum of the per-device partial outputs."""
        m, n = self.sched.shape
        r = self.sched.rows_per_window
        cb = self.sched.cols_per_block
        n_windows = self.sched.n_windows
        axis = self.axis
        acc = self._acc_dtype
        ncb = -(-n // cb)

        def body(win, cblk, val, lrow, lcol, rm, bf):
            win, cblk = win[0], cblk[0]  # [S] / [S, K]
            val, lrow, lcol = val[0], lrow[0], lcol[0]
            kdim = bf.shape[1]
            bp = jnp.pad(bf, ((0, ncb * cb - n), (0, 0)))
            bp = bp.reshape(ncb, cb, kdim)

            def step(out_perm, s):
                w, cblk_s, val_s, lrow_s, lcol_s = s
                bb = bp[cblk_s]  # [CB, kdim]
                gather = (lcol_s[:, None] == jnp.arange(cb)[None, :]).astype(
                    acc
                )  # [K, CB]
                contrib = (gather @ bb) * val_s.astype(acc)[:, None]
                scatter = (lrow_s[:, None] == jnp.arange(r)[None, :]).astype(
                    acc
                )  # [K, R]
                out_perm = out_perm.at[w].add(scatter.T @ contrib)
                return out_perm, None

            out_perm = jnp.zeros((n_windows, r, kdim), acc)
            out_perm, _ = jax.lax.scan(step, out_perm, (win, cblk, val, lrow, lcol))
            # device-local scatter epilogue, then the cross-device adder
            # tree: one psum of [m, kdim] partials
            valid = rm >= 0
            contrib = jnp.where(valid[:, None], out_perm.reshape(-1, kdim), 0.0)
            out = jnp.zeros((m, kdim), acc).at[jnp.where(valid, rm, 0)].add(contrib)
            return jax.lax.psum(out, axis)

        fn = self._shard_map(
            body, (P(axis), P(axis), P(axis), P(axis), P(axis), P(), P())
        )
        s = self._steps
        out = fn(
            s["win"],
            s["cblk"],
            s["val"],
            s["lrow"],
            s["lcol"],
            s["row_map"],
            b.astype(acc),
        )
        if self._unperm is not None:
            out = jnp.take(out, self._unperm, axis=0)
        return out.astype(b.dtype)


def repaired_executor(old_ex, new_sched: Schedule, repair):
    """Executor for a repaired schedule, reusing ``old_ex``'s device
    buffers wherever the repair (``schedule.repair_schedule``) left steps
    untouched — the scoped re-upload path of DESIGN.md §11.

    Dispatches on the old executor's class; always returns a **new**
    executor object (fresh jit closures) and never mutates ``old_ex``, so
    the serving tier can atomically swap while in-flight batches finish on
    the old one. Guaranteed bit-identical device state to a cold build of
    the same class on ``new_sched`` with the same construction kwargs."""
    if isinstance(old_ex, ShardedScheduleExecutor):
        return ShardedScheduleExecutor._from_repair(old_ex, new_sched, repair)
    if isinstance(old_ex, ScheduleExecutor):
        return ScheduleExecutor._from_repair(old_ex, new_sched, repair)
    raise TypeError(f"unsupported executor type: {type(old_ex).__name__}")


def value_patched_executor(old_ex, new_sched: Schedule, slots, vals):
    """Executor for a schedule produced by ``schedule.value_patch_schedule``
    — structure unchanged, only ``val[slots]`` differ from ``old_ex.sched``.

    The O(|delta|) fast lane of DESIGN.md §11: gcol/tgt device arrays are
    shared with ``old_ex`` and only the changed values are scattered (or
    the dirty ``val`` shards re-uploaded, for the sharded class). Same
    contract as ``repaired_executor``: a new object with fresh jit
    closures, bit-identical device state to a cold build on ``new_sched``.
    """
    slots = np.asarray(slots, np.int64)
    vals = np.asarray(vals)
    if isinstance(old_ex, ShardedScheduleExecutor):
        return ShardedScheduleExecutor._value_patched(old_ex, new_sched, slots, vals)
    if isinstance(old_ex, ScheduleExecutor):
        return ScheduleExecutor._value_patched(old_ex, new_sched, slots, vals)
    raise TypeError(f"unsupported executor type: {type(old_ex).__name__}")


# ---------------------------------------------------------------------------
# Delegation: caching, fingerprints, and the autotune loop live in the
# repro.tuning package now. Resolved lazily (PEP 562) so importing this
# module never drags the tuning subsystem in — and so there is no import
# cycle (tuning.registry imports the executor classes above).
# ---------------------------------------------------------------------------

_TUNING_EXPORTS = {
    "graph_fingerprint": "repro.tuning.registry",
    "mesh_fingerprint": "repro.tuning.registry",
    "device_fingerprint": "repro.tuning.registry",
    "clear_caches": "repro.tuning.registry",
    "get_schedule": "repro.tuning.registry",
    "get_spmm_schedules": "repro.tuning.registry",
    "get_executor": "repro.tuning.registry",
    "executor_for_schedule": "repro.tuning.registry",
    "release_graph": "repro.tuning.registry",
    "TunedConfig": "repro.tuning.space",
    "default_sweep": "repro.tuning.space",
    "sharded_sweep": "repro.tuning.space",
    "sharded_device_counts": "repro.tuning.space",
    "density_matched_k": "repro.tuning.space",
    "autotune": "repro.tuning.runner",
    "autotuned_executor": "repro.tuning.runner",
    "warm_tuned_executor": "repro.tuning.runner",
    "time_call": "repro.tuning.runner",
}

__getattr__, __dir__ = lazy_exports(__name__, _TUNING_EXPORTS, globals())
