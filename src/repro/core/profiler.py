"""Workload profiling — the software analogue of AWB-GCN's online monitors.

The FPGA profiles via per-TQ pending-task counters and per-PE idle-cycle
counters. Here the same quantities are derived from the sparse operands and
a (possibly converged) schedule, and are exported to benchmarks, the device-
level balancer, and EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import csc as fmt
from repro.core.schedule import Schedule
from repro.sharding import schedule_shard


@dataclasses.dataclass(frozen=True)
class WorkloadProfile:
    name: str
    shape: tuple
    nnz: int
    density: float
    row_nnz_mean: float
    row_nnz_max: int
    row_nnz_p99: float
    gini: float              # inequality of the per-row workload
    evil_rows: int           # rows heavier than `evil_threshold`
    evil_share: float        # fraction of nnz they hold


def gini_coefficient(x: np.ndarray) -> float:
    """Gini index of a non-negative workload vector (0=balanced, →1=evil)."""
    x = np.sort(x.astype(np.float64))
    n = x.shape[0]
    if n == 0 or x.sum() == 0:
        return 0.0
    cum = np.cumsum(x)
    return float((n + 1 - 2 * (cum / cum[-1]).sum()) / n)


def profile_matrix(a: fmt.COO, name: str = "",
                   evil_threshold: int = 256) -> WorkloadProfile:
    m, n = a.shape
    rn = np.asarray(fmt.row_nnz(a))
    nnz = int(rn.sum())
    evil = rn > evil_threshold
    return WorkloadProfile(
        name=name,
        shape=(m, n),
        nnz=nnz,
        density=nnz / max(1, m * n),
        row_nnz_mean=float(rn.mean()),
        row_nnz_max=int(rn.max()),
        row_nnz_p99=float(np.percentile(rn, 99)),
        gini=gini_coefficient(rn),
        evil_rows=int(evil.sum()),
        evil_share=float(rn[evil].sum()) / max(1, nnz),
    )


def schedule_report(s: Schedule) -> dict:
    return {
        "n_steps": s.n_steps,
        "issued_slots": s.issued_slots,
        "nnz": s.nnz,
        "utilization": s.utilization,
        "evil_chunks": s.n_evil_chunks,
        "nnz_per_step": s.nnz_per_step,
        "rows_per_window": s.rows_per_window,
    }


def device_loads(s: Schedule, n_devices: int) -> np.ndarray:
    """Steps per device under the schedule's contiguous split (steps are
    equal work, so this is the device-level load vector)."""
    return schedule_shard.shard_step_counts(s.n_steps,
                                            n_devices).astype(np.float64)


def shard_report(s: Schedule, n_devices: int) -> list:
    """Per-device shard stats under the contiguous step split: steps, true
    nnz, issued slots, and slot utilization — the distributed analogue of
    ``schedule_report``. Steps and nnz sum to the full schedule's."""
    steps = schedule_shard.shard_step_counts(s.n_steps, n_devices)
    nnz = schedule_shard.shard_nnz(s, n_devices)
    out = []
    for d in range(n_devices):
        issued = int(steps[d]) * s.nnz_per_step
        out.append({
            "device": d,
            "steps": int(steps[d]),
            "nnz": int(nnz[d]),
            "issued_slots": issued,
            "utilization": int(nnz[d]) / max(1, issued),
        })
    return out


def naive_device_loads(a: fmt.COO, n_devices: int) -> np.ndarray:
    """nnz per device under uniform row sharding — the straggler profile a
    power-law graph induces without AWB."""
    m = a.shape[0]
    rn = np.asarray(fmt.row_nnz(a)).astype(np.float64)
    rows_per_dev = -(-m // n_devices)
    dev = np.arange(m) // rows_per_dev
    return np.bincount(dev, weights=rn, minlength=n_devices)
