"""AWB-GCN core: the paper's contribution as composable JAX modules."""
from repro.core import csc  # noqa: F401
from repro.core import spmm  # noqa: F401
from repro.core.executor import (  # noqa: F401
    ScheduleExecutor,
    autotune,
    autotuned_executor,
    get_executor,
    graph_fingerprint,
)
from repro.core.schedule import (  # noqa: F401
    Schedule,
    build_balanced_schedule,
    build_naive_schedule,
    execute_schedule_jnp,
)
