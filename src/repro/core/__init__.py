"""AWB-GCN core: the paper's contribution as composable JAX modules."""
from repro.core import csc  # noqa: F401
from repro.core import spmm  # noqa: F401
from repro.core.executor import ScheduleExecutor  # noqa: F401
from repro.core.schedule import (  # noqa: F401
    Schedule,
    build_balanced_schedule,
    build_naive_schedule,
    execute_schedule_jnp,
)
from repro.lazyexports import lazy_exports

# caching/tuning entry points live in repro.tuning now; resolved lazily
# (PEP 562) so `import repro.core` from inside the tuning package itself
# (registry → csc) never re-enters a partially-initialized module.
_TUNING_EXPORTS = {
    "autotune": "repro.tuning.runner",
    "autotuned_executor": "repro.tuning.runner",
    "get_executor": "repro.tuning.registry",
    "graph_fingerprint": "repro.tuning.registry",
}

__getattr__, __dir__ = lazy_exports(__name__, _TUNING_EXPORTS, globals())
