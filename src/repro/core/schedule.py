"""Balanced SpMM schedules — the TPU realization of AWB-GCN's autotuner.

A ``Schedule`` is the static artifact the FPGA autotuner *converges to*: a
partition of the sparse operand's non-zeros into fixed-size **steps** such
that

  * every step carries exactly ``nnz_per_step`` non-zero slots,
  * each step's output rows fall in one **window** of ``rows_per_window``
    output slots (the Pallas kernel accumulates a whole window in VMEM and
    addresses it as output block ``window_id`` — block-aligned by
    construction),
  * rows heavier than ``evil_threshold`` ("evil rows", §IV.C) are chunked
    across steps; every chunk gets a private slot in trailing windows and a
    scatter-add epilogue merges chunks into their owner rows (the Labor-PE
    adder tree). The same epilogue maps window slots back to matrix rows, so
    regular and evil output handling are unified,
  * optionally, each step's dense-operand rows fall in one column block of
    ``cols_per_block`` (paper Fig. 9 matrix blocking / TDQ-1). For
    ultra-sparse operands the default is a single block spanning all columns
    (the TDQ-2 path).

Because adjacency matrices are constant across rounds and layers (§II.A),
the schedule is built once per graph and reused — exactly the paper's
"converge, then reuse the ideal configuration".

Utilization semantics on TPU: grid steps execute sequentially on a core, so
imbalance does not idle "PEs" — it inflates *issued slots* (padding).
``utilization = nnz / issued_slots`` is therefore the exact analogue of the
paper's PE utilization: wasted slots are wasted MXU/VPU cycles.

Builders:
  * ``build_balanced_schedule`` — AWB: first-fit row windows holding
    ≤ nnz_per_step non-zeros (distribution smoothing + remote switching,
    converged) + evil-row chunking (row remapping).
  * ``build_naive_schedule`` — the paper's baseline (§III.B): uniform static
    row blocks, every block padded to the step count of the heaviest block
    (what a static-grid kernel without runtime rebalancing must issue).

Kernel contract (relied on by ``kernels/spmm_pallas.py``):
  * steps of one window are contiguous in step order, so the kernel's VMEM
    accumulator is zeroed on window entry and written back once per window;
  * padding slots have ``val == 0`` and in-range local indices (0), so they
    accumulate nothing;
  * ``row_map[slot] == -1`` marks padding slots of the permuted output.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from repro.core import csc as fmt


@dataclasses.dataclass(frozen=True)
class Schedule:
    """Static balanced execution plan for one sparse operand."""

    # per-step scalars (scalar-prefetch operands of the Pallas kernel)
    win_id: np.ndarray        # [n_steps] int32 output window of the step
    col_block: np.ndarray     # [n_steps] int32 dense-operand block id
    # packed nnz slots, length n_steps * nnz_per_step
    val: np.ndarray           # [S] float32 (0.0 in padding slots)
    local_row: np.ndarray     # [S] int32 in [0, rows_per_window)
    local_col: np.ndarray     # [S] int32 in [0, cols_per_block)
    # permuted-output → matrix-row map, length n_windows * rows_per_window;
    # -1 for unused slots. Multiple slots may map to one row (evil chunks);
    # the scatter-add epilogue is the paper's adder tree.
    row_map: np.ndarray       # [n_windows * rows_per_window] int32
    # geometry
    shape: Tuple[int, int]    # (m, n) of the sparse operand
    nnz_per_step: int
    rows_per_window: int
    cols_per_block: int
    nnz: int                  # true non-zero count
    n_evil_chunks: int

    @property
    def n_steps(self) -> int:
        return int(self.win_id.shape[0])

    @property
    def n_windows(self) -> int:
        return int(self.row_map.shape[0]) // self.rows_per_window

    @property
    def issued_slots(self) -> int:
        return self.n_steps * self.nnz_per_step

    @property
    def utilization(self) -> float:
        """Fraction of issued compute slots carrying real work — the TPU
        analogue of the paper's PE utilization."""
        return self.nnz / max(1, self.issued_slots)

    def device_step_ranges(self, n_devices: int) -> np.ndarray:
        """Split steps contiguously across devices; since steps are
        equal-work, equal step counts == balanced devices. Delegates to the
        shared splitter every shard consumer uses
        (``sharding.schedule_shard.split_step_ranges``)."""
        from repro.sharding.schedule_shard import split_step_ranges

        return split_step_ranges(self.n_steps, n_devices)


# ---------------------------------------------------------------------------
# Serialization — the tuning store persists converged schedules as plain
# arrays (one .npz per store entry) so serving restarts skip the rebuild.
# ---------------------------------------------------------------------------

#: bump when Schedule's on-disk layout changes — part of the store key, so
#: stale entries miss (and re-tune) instead of deserializing garbage.
SCHEDULE_FORMAT_VERSION = 1

_ARRAY_FIELDS = ("win_id", "col_block", "val", "local_row", "local_col",
                 "row_map")


def schedule_to_arrays(sched: Schedule) -> dict:
    """Flatten a Schedule into plain numpy arrays: the six schedule arrays
    plus an int64 ``meta`` vector of the scalar geometry. The inverse of
    ``schedule_from_arrays``; together they are the store's wire format."""
    out = {f: np.asarray(getattr(sched, f)) for f in _ARRAY_FIELDS}
    out["meta"] = np.asarray(
        [sched.shape[0], sched.shape[1], sched.nnz_per_step,
         sched.rows_per_window, sched.cols_per_block, sched.nnz,
         sched.n_evil_chunks], np.int64)
    return out


def schedule_from_arrays(arrays) -> Schedule:
    """Rebuild a Schedule from ``schedule_to_arrays`` output, validating
    internal consistency so a truncated or corrupted store entry raises
    ``ValueError`` (the store maps that to a re-tune) instead of producing
    an executor that silently computes garbage."""
    try:
        meta = np.asarray(arrays["meta"], np.int64)
        m, n, k, r, cb, nnz, n_evil = (int(v) for v in meta)
        fields = {f: np.asarray(arrays[f]) for f in _ARRAY_FIELDS}
    except (KeyError, TypeError, OverflowError) as e:
        raise ValueError(f"schedule entry missing/overflowing field: {e}")
    sched = Schedule(shape=(m, n), nnz_per_step=k, rows_per_window=r,
                     cols_per_block=cb, nnz=nnz, n_evil_chunks=n_evil,
                     win_id=fields["win_id"].astype(np.int32),
                     col_block=fields["col_block"].astype(np.int32),
                     val=fields["val"].astype(np.float32),
                     local_row=fields["local_row"].astype(np.int32),
                     local_col=fields["local_col"].astype(np.int32),
                     row_map=fields["row_map"].astype(np.int32))
    n_steps = sched.n_steps
    if (min(m, n, k, r, cb) <= 0 or nnz < 0 or n_evil < 0
            or sched.val.shape != (n_steps * k,)
            or sched.local_row.shape != (n_steps * k,)
            or sched.local_col.shape != (n_steps * k,)
            or sched.col_block.shape != (n_steps,)
            or sched.row_map.shape[0] % r != 0
            or nnz > n_steps * k):
        raise ValueError("inconsistent schedule geometry in stored entry")
    # both bounds matter: a negative index would silently wrap (NumPy/jnp
    # semantics) and compute garbage instead of failing over to a re-tune
    n_colblocks = -(-n // cb)
    if n_steps and (int(sched.win_id.min()) < 0
                    or int(sched.win_id.max()) >= sched.n_windows
                    or int(sched.col_block.min(initial=0)) < 0
                    or int(sched.col_block.max(initial=0)) >= n_colblocks
                    or int(sched.local_row.min(initial=0)) < 0
                    or int(sched.local_row.max(initial=0)) >= r
                    or int(sched.local_col.min(initial=0)) < 0
                    or int(sched.local_col.max(initial=0)) >= cb
                    or int(sched.row_map.min(initial=-1)) < -1
                    or int(sched.row_map.max(initial=-1)) >= m):
        raise ValueError("out-of-range indices in stored schedule entry")
    return sched


AUTO_COLS_PER_BLOCK = 256


def auto_cols_per_block(n_cols: int, target: int = AUTO_COLS_PER_BLOCK) -> int:
    """Capped dense-operand block width for one-hot routing.

    The Pallas kernel's one-hot gather matrix is ``[K, cols_per_block]``; the
    seed default (one block spanning all ``n`` columns) makes routing work
    scale with ``K·n``. Capping at ``target`` (a couple of MXU tiles) keeps
    routing at ``K·cb`` while the block B-panel stays VMEM-resident. Operands
    narrower than the cap keep a single full-width block (TDQ-2)."""
    return n_cols if n_cols <= target else target


def _resolve_cols_per_block(n: int, cols_per_block) -> int:
    if cols_per_block is None:
        return n
    if cols_per_block == "auto":
        return auto_cols_per_block(n)
    return int(cols_per_block)


def _group_layout(keys: np.ndarray, k: int, uniform: bool):
    """Chunk sorted groups into ≤k-slot steps.

    ``keys`` must already be sorted. Returns (step_of_elem, pos_in_step,
    head_elem_of_step, n_steps). ``uniform`` pads every group to the step
    count of the heaviest group (static-baseline issue model).
    """
    ne = keys.shape[0]
    if ne == 0:
        return (np.zeros(0, np.int64), np.zeros(0, np.int64),
                np.zeros(0, np.int64), 0)
    new_group = np.empty(ne, bool)
    new_group[0] = True
    np.not_equal(keys[1:], keys[:-1], out=new_group[1:])
    group_idx = np.cumsum(new_group, dtype=np.int32) - 1
    starts = np.nonzero(new_group)[0]          # [n_groups] first elem/group
    n_groups = starts.shape[0]
    pos_in_group = np.arange(ne, dtype=np.int64) - starts[group_idx]
    chunk_in_group, pos_in_chunk = np.divmod(pos_in_group, k)
    group_sizes = np.diff(np.append(starts, ne))
    group_chunks = -(-group_sizes // k)
    if uniform:
        per_group = int(group_chunks.max())
        step_of_elem = group_idx.astype(np.int64) * per_group + chunk_in_group
        n_steps = n_groups * per_group
        head_of_step = np.repeat(starts, per_group)
    else:
        chunk_offset = np.concatenate([[0], np.cumsum(group_chunks)[:-1]])
        step_of_elem = chunk_offset[group_idx] + chunk_in_group
        n_steps = int(group_chunks.sum())
        head_of_step = np.nonzero(pos_in_chunk == 0)[0]
    return step_of_elem, pos_in_chunk, head_of_step, n_steps


def _sorted_order(primary: np.ndarray, row: np.ndarray, col: np.ndarray,
                  n: int) -> np.ndarray:
    """argsort by ``(primary, row, col)``.

    Fast path: COO inputs from ``csc.coo_from_*`` are already (row, col)
    lexsorted, so one stable sort on ``primary`` yields the full order
    without the 3-key lexsort (the schedule-build hot spot on million-edge
    graphs)."""
    if row.size == 0:
        return np.zeros(0, np.int64)
    rc = row.astype(np.int64) * n + col
    if np.all(rc[1:] >= rc[:-1]):
        return np.argsort(primary, kind="stable")
    return np.lexsort((col, row, primary))


def _emit(row, col, val, shape, k, r, cb, window_of_row, window_start,
          evil_mask_row, uniform: bool) -> Schedule:
    """Pack non-zeros into steps obeying (window, col_block) purity.
    Regular steps first (sorted by (window, col_block)), then evil chunks."""
    m, n = shape
    n_colblocks = max(1, -(-n // cb))
    # single full-width block (the TDQ-2 default): every block id is 0, so
    # skip the per-nnz division and the key fold entirely
    one_block = n_colblocks == 1
    colblk = np.zeros(col.shape[0], np.int32) if one_block else col // cb
    is_evil = evil_mask_row[row]
    n_reg_windows = int(window_start.shape[0])

    # ---- regular rows ------------------------------------------------------
    reg = np.nonzero(~is_evil)[0]
    rwin = window_of_row[row[reg]]
    # int64 when folding in the block id: windows × n_colblocks can exceed
    # int32 on large blocked builds
    reg_key = (rwin if one_block
               else rwin.astype(np.int64) * n_colblocks + colblk[reg])
    order = _sorted_order(reg_key, row[reg], col[reg], n)
    reg = reg[order]
    r_step, r_pos, r_head, n_reg_steps = _group_layout(reg_key[order], k,
                                                       uniform)

    # ---- evil rows: group by (row, colblock) --------------------------------
    ev = np.nonzero(is_evil)[0]
    ev_key = (row[ev].astype(np.int64) if one_block
              else row[ev].astype(np.int64) * n_colblocks + colblk[ev])
    order = _sorted_order(ev_key, row[ev], col[ev], n)
    ev = ev[order]
    e_step, e_pos, e_head, n_evil_steps = _group_layout(ev_key[order], k,
                                                        False)
    n_evil_chunks = n_evil_steps  # one chunk == one step == one output slot

    n_steps = max(1, n_reg_steps + n_evil_steps)
    n_evil_windows = -(-max(1, n_evil_chunks) // r) if n_evil_chunks else 0
    n_windows = max(1, n_reg_windows + n_evil_windows)

    sval = np.zeros(n_steps * k, np.float32)
    srow = np.zeros(n_steps * k, np.int32)
    scol = np.zeros(n_steps * k, np.int32)
    step_win = np.zeros(n_steps, np.int32)
    step_cb = np.zeros(n_steps, np.int32)
    row_map = np.full(n_windows * r, -1, np.int32)

    if reg.size:
        slots = r_step * k + r_pos
        sval[slots] = val[reg]
        w = window_of_row[row[reg]]
        srow[slots] = (row[reg] - window_start[w]).astype(np.int32,
                                                          copy=False)
        scol[slots] = (col[reg] if one_block
                       else col[reg] - colblk[reg] * cb
                       ).astype(np.int32, copy=False)
        head = reg[r_head]
        step_win[:n_reg_steps] = window_of_row[row[head]]
        step_cb[:n_reg_steps] = colblk[head]

    # row_map for regular windows: slot (w, j) -> window_start[w] + j while
    # within the window's row range (and not an evil row, whose value comes
    # only from chunks). One fancy-indexed write over all (window, slot)
    # pairs instead of a per-window loop.
    if n_reg_windows:
        win_end = np.concatenate([window_start[1:],
                                  np.asarray([m], window_start.dtype)])
        cnt = np.clip(win_end - window_start, 0, r)
        w_ids = np.repeat(np.arange(n_reg_windows, dtype=np.int64), cnt)
        j = np.arange(int(cnt.sum()), dtype=np.int64) - \
            np.repeat(np.cumsum(cnt) - cnt, cnt)
        rows = window_start[w_ids] + j
        row_map[w_ids * r + j] = np.where(evil_mask_row[rows], -1,
                                          rows).astype(np.int32)

    if ev.size:
        slots = (n_reg_steps + e_step) * k + e_pos
        sval[slots] = val[ev]
        srow[slots] = (e_step % r).astype(np.int32)  # chunk slot in window
        scol[slots] = (col[ev] if one_block
                       else col[ev] - colblk[ev] * cb).astype(np.int32)
        step_win[n_reg_steps:] = (n_reg_windows + e_step[e_head] // r
                                  ).astype(np.int32)
        step_cb[n_reg_steps:] = colblk[ev[e_head]]
        # chunk c sits at padded slot n_reg_windows*r + c, owned by its row
        chunk_slot = n_reg_windows * r + np.arange(n_evil_chunks)
        row_map[chunk_slot] = row[ev[e_head]].astype(np.int32)

    return Schedule(
        win_id=step_win, col_block=step_cb, val=sval, local_row=srow,
        local_col=scol, row_map=row_map, shape=shape, nnz_per_step=k,
        rows_per_window=r, cols_per_block=cb, nnz=int(row.shape[0]),
        n_evil_chunks=int(n_evil_chunks),
    )


def _clean_coo(a: fmt.COO):
    row = np.asarray(a.row)
    col = np.asarray(a.col)
    val = np.asarray(a.val, np.float32)
    if (row == fmt.PAD_IDX).any():
        keep = row != fmt.PAD_IDX
        row, col, val = row[keep], col[keep], val[keep]
    # int32 indices stay int32 (million-edge builds are memory-bandwidth
    # bound); key arithmetic upcasts locally where overflow is possible.
    return row, col, val


def build_balanced_schedule(a: fmt.COO, nnz_per_step: int = 256,
                            rows_per_window: int = 64,
                            cols_per_block: int | None = None,
                            evil_threshold: int | None = None,
                            window_nnz: int | None = None) -> Schedule:
    """AWB schedule: first-fit contiguous row windows holding ≤ ``window_nnz``
    non-zeros and ≤ rows_per_window rows (distribution smoothing + remote
    switching, converged), evil rows chunked across steps (row remapping).

    ``cols_per_block=None`` (default) disables column blocking — right for
    ultra-sparse operands where blocking fragments steps (TDQ-2). Pass a
    block size to enable Fig.-9-style blocking (TDQ-1), or ``"auto"`` to cap
    the block at ``AUTO_COLS_PER_BLOCK`` so the kernel's one-hot routing
    cost scales with K·cb instead of K·n (see ``auto_cols_per_block``).

    ``window_nnz`` is the window's nnz budget; it defaults to
    ``nnz_per_step`` (every window drains in one full step when unblocked).
    With column blocking a window's non-zeros split across ~n_colblocks
    steps, so the budget auto-couples to ``nnz_per_step * n_colblocks`` in
    ``"auto"`` mode — each (window, block) step then still carries ~K slots
    of real work instead of fragmenting (the capped one-hot path needs a
    small ``nnz_per_step`` ≈ density·rows_per_window·cols_per_block, which
    ``executor.autotune`` selects).
    """
    m, n = a.shape
    row, col, val = _clean_coo(a)
    k, r = nnz_per_step, rows_per_window
    cb = _resolve_cols_per_block(n, cols_per_block)
    if window_nnz is None:
        n_colblocks = -(-n // cb)
        window_nnz = k * n_colblocks if cols_per_block == "auto" else k
    evil_t = evil_threshold if evil_threshold is not None else window_nnz

    per_row = np.bincount(row, minlength=m)
    evil_mask = per_row > evil_t

    # First-fit contiguous row windows over regular-row nnz: close a window
    # when adding the next row would exceed k nnz, or at r rows. The
    # candidate next boundary from *every* row is computed in one vectorized
    # searchsorted; following the boundary chain is then O(1) per window.
    reg_nnz = np.where(evil_mask, 0, per_row).astype(np.int64)
    cum = np.cumsum(reg_nnz)
    if m:
        prev = np.concatenate([[0], cum[:-1]])
        nxt = np.searchsorted(cum, prev + window_nnz, side="right")
        idx = np.arange(m, dtype=np.int64)
        nxt = np.minimum(np.minimum(np.maximum(nxt, idx + 1), idx + r), m)
        starts = [0]
        base = int(nxt[0])
        while base < m:
            starts.append(base)
            base = int(nxt[base])
        window_start = np.asarray(starts, np.int32)
        boundary = np.zeros(m, np.int32)
        boundary[window_start[1:]] = 1
        window_of_row = np.cumsum(boundary, dtype=np.int32)
    else:
        window_start = np.asarray([0], np.int32)
        window_of_row = np.zeros(0, np.int32)

    return _emit(row, col, val, (m, n), k, r, cb, window_of_row,
                 window_start, evil_mask, uniform=False)


def build_naive_schedule(a: fmt.COO, nnz_per_step: int = 256,
                         rows_per_window: int = 64,
                         cols_per_block: int | None = None) -> Schedule:
    """Paper baseline (§III.B): uniform static row partition, no rebalancing.
    Every row block issues the step count of the *heaviest* block — the
    static-grid cost of workload imbalance (idle PEs ≡ padded slots)."""
    m, n = a.shape
    row, col, val = _clean_coo(a)
    r = rows_per_window
    cb = _resolve_cols_per_block(n, cols_per_block)
    window_of_row = (np.arange(m, dtype=np.int32) //
                     np.int32(r)).astype(np.int32, copy=False)
    window_start = np.arange(0, max(m, 1), r, dtype=np.int32)
    evil_mask = np.zeros(m, bool)  # baseline has no evil-row handling
    return _emit(row, col, val, (m, n), nnz_per_step, r, cb, window_of_row,
                 window_start, evil_mask, uniform=True)


def scatter_epilogue(sched: Schedule, out_perm) -> "jax.Array":  # noqa: F821
    """Map the kernel's permuted-window output back to matrix rows.
    Evil chunks scatter-add into their owner rows — the adder tree."""
    import jax.numpy as jnp

    m = sched.shape[0]
    rm = jnp.asarray(sched.row_map)
    valid = rm >= 0
    tgt = jnp.where(valid, rm, 0)
    contrib = jnp.where(valid[:, None], out_perm, 0)
    return jnp.zeros((m, out_perm.shape[1]), out_perm.dtype).at[tgt].add(contrib)


def execute_schedule_jnp(sched: Schedule, b) -> "jax.Array":  # noqa: F821
    """Pure-jnp executor of a Schedule — the oracle the Pallas kernel is
    tested against, and itself tested against ``spmm.spmm_coo``."""
    import jax.numpy as jnp

    m, n = sched.shape
    k = sched.nnz_per_step
    r = sched.rows_per_window
    kdim = b.shape[1]
    n_steps = sched.n_steps

    val = jnp.asarray(sched.val)
    lrow = jnp.asarray(sched.local_row).reshape(n_steps, k)
    lcol = jnp.asarray(sched.local_col).reshape(n_steps, k)
    win = jnp.asarray(sched.win_id)
    cblk = jnp.asarray(sched.col_block)

    gcol = jnp.minimum(cblk[:, None] * sched.cols_per_block + lcol, n - 1)
    slot = (win[:, None] * r + lrow).reshape(-1)
    gathered = b[gcol.reshape(-1)] * val[:, None]
    out_perm = jnp.zeros((sched.n_windows * r, kdim), b.dtype)
    out_perm = out_perm.at[slot].add(gathered)
    return scatter_epilogue(sched, out_perm)
