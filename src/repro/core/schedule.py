"""Balanced SpMM schedules — the TPU realization of AWB-GCN's autotuner.

A ``Schedule`` is the static artifact the FPGA autotuner *converges to*: a
partition of the sparse operand's non-zeros into fixed-size **steps** such
that

  * every step carries exactly ``nnz_per_step`` non-zero slots,
  * each step's output rows fall in one **window** of ``rows_per_window``
    output slots (the Pallas kernel accumulates a whole window in VMEM and
    addresses it as output block ``window_id`` — block-aligned by
    construction),
  * rows heavier than ``evil_threshold`` ("evil rows", §IV.C) are chunked
    across steps; every chunk gets a private slot in trailing windows and a
    scatter-add epilogue merges chunks into their owner rows (the Labor-PE
    adder tree). The same epilogue maps window slots back to matrix rows, so
    regular and evil output handling are unified,
  * optionally, each step's dense-operand rows fall in one column block of
    ``cols_per_block`` (paper Fig. 9 matrix blocking / TDQ-1). For
    ultra-sparse operands the default is a single block spanning all columns
    (the TDQ-2 path).

Because adjacency matrices are constant across rounds and layers (§II.A),
the schedule is built once per graph and reused — exactly the paper's
"converge, then reuse the ideal configuration".

Utilization semantics on TPU: grid steps execute sequentially on a core, so
imbalance does not idle "PEs" — it inflates *issued slots* (padding).
``utilization = nnz / issued_slots`` is therefore the exact analogue of the
paper's PE utilization: wasted slots are wasted MXU/VPU cycles.

Builders:
  * ``build_balanced_schedule`` — AWB: first-fit row windows holding
    ≤ nnz_per_step non-zeros (distribution smoothing + remote switching,
    converged) + evil-row chunking (row remapping).
  * ``build_naive_schedule`` — the paper's baseline (§III.B): uniform static
    row blocks, every block padded to the step count of the heaviest block
    (what a static-grid kernel without runtime rebalancing must issue).

Kernel contract (relied on by ``kernels/spmm_pallas.py``):
  * steps of one window are contiguous in step order, so the kernel's VMEM
    accumulator is zeroed on window entry and written back once per window;
  * padding slots have ``val == 0`` and in-range local indices (0), so they
    accumulate nothing;
  * ``row_map[slot] == -1`` marks padding slots of the permuted output.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from repro.core import csc as fmt


@dataclasses.dataclass(frozen=True)
class Schedule:
    """Static balanced execution plan for one sparse operand."""

    # per-step scalars (scalar-prefetch operands of the Pallas kernel)
    win_id: np.ndarray  # [n_steps] int32 output window of the step
    col_block: np.ndarray  # [n_steps] int32 dense-operand block id
    # packed nnz slots, length n_steps * nnz_per_step
    val: np.ndarray  # [S] float32 (0.0 in padding slots)
    local_row: np.ndarray  # [S] int32 in [0, rows_per_window)
    local_col: np.ndarray  # [S] int32 in [0, cols_per_block)
    # permuted-output → matrix-row map, length n_windows * rows_per_window;
    # -1 for unused slots. Multiple slots may map to one row (evil chunks);
    # the scatter-add epilogue is the paper's adder tree.
    row_map: np.ndarray  # [n_windows * rows_per_window] int32
    # geometry
    shape: Tuple[int, int]  # (m, n) of the sparse operand
    nnz_per_step: int
    rows_per_window: int
    cols_per_block: int
    nnz: int  # true non-zero count
    n_evil_chunks: int

    @property
    def n_steps(self) -> int:
        return int(self.win_id.shape[0])

    @property
    def n_windows(self) -> int:
        return int(self.row_map.shape[0]) // self.rows_per_window

    @property
    def issued_slots(self) -> int:
        return self.n_steps * self.nnz_per_step

    @property
    def utilization(self) -> float:
        """Fraction of issued compute slots carrying real work — the TPU
        analogue of the paper's PE utilization."""
        return self.nnz / max(1, self.issued_slots)

    def device_step_ranges(self, n_devices: int) -> np.ndarray:
        """Split steps contiguously across devices; since steps are
        equal-work, equal step counts == balanced devices. Delegates to the
        shared splitter every shard consumer uses
        (``sharding.schedule_shard.split_step_ranges``)."""
        from repro.sharding.schedule_shard import split_step_ranges

        return split_step_ranges(self.n_steps, n_devices)


# ---------------------------------------------------------------------------
# Serialization — the tuning store persists converged schedules as plain
# arrays (one .npz per store entry) so serving restarts skip the rebuild.
# ---------------------------------------------------------------------------

#: bump when Schedule's on-disk layout changes — part of the store key, so
#: stale entries miss (and re-tune) instead of deserializing garbage.
SCHEDULE_FORMAT_VERSION = 1

#: bump when the *builder or repair logic* changes in a way that alters the
#: arrays a given (graph, config) pair produces — e.g. a different window
#: first-fit rule or evil-row chunking order. Entries persisted under an
#: older builder would deserialize fine (same wire format) yet disagree
#: with what ``repair_schedule`` expects to splice against, so the version
#: is folded into the store key *and* stamped into each payload: stale
#: entries miss / drop to a re-tune, never crash, never mix geometries.
SCHEDULE_BUILDER_VERSION = 1

_ARRAY_FIELDS = ("win_id", "col_block", "val", "local_row", "local_col", "row_map")


def schedule_to_arrays(sched: Schedule) -> dict:
    """Flatten a Schedule into plain numpy arrays: the six schedule arrays
    plus an int64 ``meta`` vector of the scalar geometry. The inverse of
    ``schedule_from_arrays``; together they are the store's wire format."""
    out = {f: np.asarray(getattr(sched, f)) for f in _ARRAY_FIELDS}
    out["meta"] = np.asarray(
        [
            sched.shape[0],
            sched.shape[1],
            sched.nnz_per_step,
            sched.rows_per_window,
            sched.cols_per_block,
            sched.nnz,
            sched.n_evil_chunks,
        ],
        np.int64,
    )
    return out


def schedule_from_arrays(arrays) -> Schedule:
    """Rebuild a Schedule from ``schedule_to_arrays`` output, validating
    internal consistency so a truncated or corrupted store entry raises
    ``ValueError`` (the store maps that to a re-tune) instead of producing
    an executor that silently computes garbage."""
    try:
        meta = np.asarray(arrays["meta"], np.int64)
        m, n, k, r, cb, nnz, n_evil = (int(v) for v in meta)
        fields = {f: np.asarray(arrays[f]) for f in _ARRAY_FIELDS}
    except (KeyError, TypeError, OverflowError) as e:
        raise ValueError(f"schedule entry missing/overflowing field: {e}")
    sched = Schedule(
        shape=(m, n),
        nnz_per_step=k,
        rows_per_window=r,
        cols_per_block=cb,
        nnz=nnz,
        n_evil_chunks=n_evil,
        win_id=fields["win_id"].astype(np.int32),
        col_block=fields["col_block"].astype(np.int32),
        val=fields["val"].astype(np.float32),
        local_row=fields["local_row"].astype(np.int32),
        local_col=fields["local_col"].astype(np.int32),
        row_map=fields["row_map"].astype(np.int32),
    )
    n_steps = sched.n_steps
    if (
        min(m, n, k, r, cb) <= 0
        or nnz < 0
        or n_evil < 0
        or sched.val.shape != (n_steps * k,)
        or sched.local_row.shape != (n_steps * k,)
        or sched.local_col.shape != (n_steps * k,)
        or sched.col_block.shape != (n_steps,)
        or sched.row_map.shape[0] % r != 0
        or nnz > n_steps * k
    ):
        raise ValueError("inconsistent schedule geometry in stored entry")
    # both bounds matter: a negative index would silently wrap (NumPy/jnp
    # semantics) and compute garbage instead of failing over to a re-tune
    n_colblocks = -(-n // cb)
    if n_steps and (
        int(sched.win_id.min()) < 0
        or int(sched.win_id.max()) >= sched.n_windows
        or int(sched.col_block.min(initial=0)) < 0
        or int(sched.col_block.max(initial=0)) >= n_colblocks
        or int(sched.local_row.min(initial=0)) < 0
        or int(sched.local_row.max(initial=0)) >= r
        or int(sched.local_col.min(initial=0)) < 0
        or int(sched.local_col.max(initial=0)) >= cb
        or int(sched.row_map.min(initial=-1)) < -1
        or int(sched.row_map.max(initial=-1)) >= m
    ):
        raise ValueError("out-of-range indices in stored schedule entry")
    return sched


AUTO_COLS_PER_BLOCK = 256


def auto_cols_per_block(n_cols: int, target: int = AUTO_COLS_PER_BLOCK) -> int:
    """Capped dense-operand block width for one-hot routing.

    The Pallas kernel's one-hot gather matrix is ``[K, cols_per_block]``; the
    seed default (one block spanning all ``n`` columns) makes routing work
    scale with ``K·n``. Capping at ``target`` (a couple of MXU tiles) keeps
    routing at ``K·cb`` while the block B-panel stays VMEM-resident. Operands
    narrower than the cap keep a single full-width block (TDQ-2)."""
    return n_cols if n_cols <= target else target


def _resolve_cols_per_block(n: int, cols_per_block) -> int:
    if cols_per_block is None:
        return n
    if cols_per_block == "auto":
        return auto_cols_per_block(n)
    return int(cols_per_block)


def _group_layout(keys: np.ndarray, k: int, uniform: bool):
    """Chunk sorted groups into ≤k-slot steps.

    ``keys`` must already be sorted. Returns (step_of_elem, pos_in_step,
    head_elem_of_step, n_steps). ``uniform`` pads every group to the step
    count of the heaviest group (static-baseline issue model).
    """
    ne = keys.shape[0]
    if ne == 0:
        return (np.zeros(0, np.int64), np.zeros(0, np.int64), np.zeros(0, np.int64), 0)
    new_group = np.empty(ne, bool)
    new_group[0] = True
    np.not_equal(keys[1:], keys[:-1], out=new_group[1:])
    group_idx = np.cumsum(new_group, dtype=np.int32) - 1
    starts = np.nonzero(new_group)[0]  # [n_groups] first elem/group
    n_groups = starts.shape[0]
    pos_in_group = np.arange(ne, dtype=np.int64) - starts[group_idx]
    chunk_in_group, pos_in_chunk = np.divmod(pos_in_group, k)
    group_sizes = np.diff(np.append(starts, ne))
    group_chunks = -(-group_sizes // k)
    if uniform:
        per_group = int(group_chunks.max())
        step_of_elem = group_idx.astype(np.int64) * per_group + chunk_in_group
        n_steps = n_groups * per_group
        head_of_step = np.repeat(starts, per_group)
    else:
        chunk_offset = np.concatenate([[0], np.cumsum(group_chunks)[:-1]])
        step_of_elem = chunk_offset[group_idx] + chunk_in_group
        n_steps = int(group_chunks.sum())
        head_of_step = np.nonzero(pos_in_chunk == 0)[0]
    return step_of_elem, pos_in_chunk, head_of_step, n_steps


def _sorted_order(
    primary: np.ndarray, row: np.ndarray, col: np.ndarray, n: int
) -> np.ndarray:
    """argsort by ``(primary, row, col)``.

    Fast path: COO inputs from ``csc.coo_from_*`` are already (row, col)
    lexsorted, so one stable sort on ``primary`` yields the full order
    without the 3-key lexsort (the schedule-build hot spot on million-edge
    graphs)."""
    if row.size == 0:
        return np.zeros(0, np.int64)
    rc = row.astype(np.int64) * n + col
    if np.all(rc[1:] >= rc[:-1]):
        return np.argsort(primary, kind="stable")
    return np.lexsort((col, row, primary))


def _emit(
    row,
    col,
    val,
    shape,
    k,
    r,
    cb,
    window_of_row,
    window_start,
    evil_mask_row,
    uniform: bool,
) -> Schedule:
    """Pack non-zeros into steps obeying (window, col_block) purity.
    Regular steps first (sorted by (window, col_block)), then evil chunks."""
    m, n = shape
    n_colblocks = max(1, -(-n // cb))
    # single full-width block (the TDQ-2 default): every block id is 0, so
    # skip the per-nnz division and the key fold entirely
    one_block = n_colblocks == 1
    colblk = np.zeros(col.shape[0], np.int32) if one_block else col // cb
    is_evil = evil_mask_row[row]
    n_reg_windows = int(window_start.shape[0])

    # ---- regular rows ------------------------------------------------------
    reg = np.nonzero(~is_evil)[0]
    rwin = window_of_row[row[reg]]
    # int64 when folding in the block id: windows × n_colblocks can exceed
    # int32 on large blocked builds
    reg_key = (rwin if one_block else rwin.astype(np.int64) * n_colblocks + colblk[reg])
    order = _sorted_order(reg_key, row[reg], col[reg], n)
    reg = reg[order]
    r_step, r_pos, r_head, n_reg_steps = _group_layout(reg_key[order], k, uniform)

    # ---- evil rows: group by (row, colblock) --------------------------------
    ev = np.nonzero(is_evil)[0]
    ev_key = (
        row[ev].astype(np.int64)
        if one_block
        else row[ev].astype(np.int64) * n_colblocks + colblk[ev]
    )
    order = _sorted_order(ev_key, row[ev], col[ev], n)
    ev = ev[order]
    e_step, e_pos, e_head, n_evil_steps = _group_layout(ev_key[order], k, False)
    n_evil_chunks = n_evil_steps  # one chunk == one step == one output slot

    n_steps = max(1, n_reg_steps + n_evil_steps)
    n_evil_windows = -(-max(1, n_evil_chunks) // r) if n_evil_chunks else 0
    n_windows = max(1, n_reg_windows + n_evil_windows)

    sval = np.zeros(n_steps * k, np.float32)
    srow = np.zeros(n_steps * k, np.int32)
    scol = np.zeros(n_steps * k, np.int32)
    step_win = np.zeros(n_steps, np.int32)
    step_cb = np.zeros(n_steps, np.int32)
    row_map = np.full(n_windows * r, -1, np.int32)

    if reg.size:
        slots = r_step * k + r_pos
        sval[slots] = val[reg]
        w = window_of_row[row[reg]]
        srow[slots] = (row[reg] - window_start[w]).astype(np.int32, copy=False)
        scol[slots] = (col[reg] if one_block else col[reg] - colblk[reg] * cb).astype(
            np.int32, copy=False
        )
        head = reg[r_head]
        step_win[:n_reg_steps] = window_of_row[row[head]]
        step_cb[:n_reg_steps] = colblk[head]

    # row_map for regular windows: slot (w, j) -> window_start[w] + j while
    # within the window's row range (and not an evil row, whose value comes
    # only from chunks). One fancy-indexed write over all (window, slot)
    # pairs instead of a per-window loop.
    if n_reg_windows:
        win_end = np.concatenate(
            [window_start[1:], np.asarray([m], window_start.dtype)]
        )
        cnt = np.clip(win_end - window_start, 0, r)
        w_ids = np.repeat(np.arange(n_reg_windows, dtype=np.int64), cnt)
        j = np.arange(int(cnt.sum()), dtype=np.int64) - np.repeat(
            np.cumsum(cnt) - cnt, cnt
        )
        rows = window_start[w_ids] + j
        row_map[w_ids * r + j] = np.where(evil_mask_row[rows], -1, rows).astype(
            np.int32
        )

    if ev.size:
        slots = (n_reg_steps + e_step) * k + e_pos
        sval[slots] = val[ev]
        srow[slots] = (e_step % r).astype(np.int32)  # chunk slot in window
        scol[slots] = (col[ev] if one_block else col[ev] - colblk[ev] * cb).astype(
            np.int32
        )
        step_win[n_reg_steps:] = (n_reg_windows + e_step[e_head] // r).astype(np.int32)
        step_cb[n_reg_steps:] = colblk[ev[e_head]]
        # chunk c sits at padded slot n_reg_windows*r + c, owned by its row
        chunk_slot = n_reg_windows * r + np.arange(n_evil_chunks)
        row_map[chunk_slot] = row[ev[e_head]].astype(np.int32)

    return Schedule(
        win_id=step_win,
        col_block=step_cb,
        val=sval,
        local_row=srow,
        local_col=scol,
        row_map=row_map,
        shape=shape,
        nnz_per_step=k,
        rows_per_window=r,
        cols_per_block=cb,
        nnz=int(row.shape[0]),
        n_evil_chunks=int(n_evil_chunks),
    )


def _resolve_geometry(
    n: int, nnz_per_step: int, cols_per_block, window_nnz, evil_threshold
):
    """Shared geometry resolution for ``build_balanced_schedule`` and
    ``repair_schedule`` — both must agree or repairs stop being
    bit-identical to rebuilds."""
    cb = _resolve_cols_per_block(n, cols_per_block)
    if window_nnz is None:
        n_colblocks = -(-n // cb)
        window_nnz = (
            nnz_per_step * n_colblocks if cols_per_block == "auto" else nnz_per_step
        )
    evil_t = evil_threshold if evil_threshold is not None else window_nnz
    return cb, window_nnz, evil_t


def _window_partition(
    per_row: np.ndarray, evil_mask: np.ndarray, window_nnz: int, r: int
):
    """First-fit contiguous row windows over regular-row nnz: close a window
    when adding the next row would exceed ``window_nnz`` non-zeros, or at
    ``r`` rows. The candidate next boundary from *every* row is computed in
    one vectorized searchsorted; following the boundary chain is then O(1)
    per window. Returns ``(window_start, window_of_row)``."""
    m = per_row.shape[0]
    reg_nnz = np.where(evil_mask, 0, per_row).astype(np.int64)
    cum = np.cumsum(reg_nnz)
    if not m:
        return np.asarray([0], np.int32), np.zeros(0, np.int32)
    prev = np.concatenate([[0], cum[:-1]])
    nxt = np.searchsorted(cum, prev + window_nnz, side="right")
    idx = np.arange(m, dtype=np.int64)
    nxt = np.minimum(np.minimum(np.maximum(nxt, idx + 1), idx + r), m)
    starts = [0]
    base = int(nxt[0])
    while base < m:
        starts.append(base)
        base = int(nxt[base])
    window_start = np.asarray(starts, np.int32)
    boundary = np.zeros(m, np.int32)
    boundary[window_start[1:]] = 1
    window_of_row = np.cumsum(boundary, dtype=np.int32)
    return window_start, window_of_row


def _clean_coo(a: fmt.COO):
    row = np.asarray(a.row)
    col = np.asarray(a.col)
    val = np.asarray(a.val, np.float32)
    if (row == fmt.PAD_IDX).any():
        keep = row != fmt.PAD_IDX
        row, col, val = row[keep], col[keep], val[keep]
    # int32 indices stay int32 (million-edge builds are memory-bandwidth
    # bound); key arithmetic upcasts locally where overflow is possible.
    return row, col, val


def build_balanced_schedule(
    a: fmt.COO,
    nnz_per_step: int = 256,
    rows_per_window: int = 64,
    cols_per_block: int | None = None,
    evil_threshold: int | None = None,
    window_nnz: int | None = None,
) -> Schedule:
    """AWB schedule: first-fit contiguous row windows holding ≤ ``window_nnz``
    non-zeros and ≤ rows_per_window rows (distribution smoothing + remote
    switching, converged), evil rows chunked across steps (row remapping).

    ``cols_per_block=None`` (default) disables column blocking — right for
    ultra-sparse operands where blocking fragments steps (TDQ-2). Pass a
    block size to enable Fig.-9-style blocking (TDQ-1), or ``"auto"`` to cap
    the block at ``AUTO_COLS_PER_BLOCK`` so the kernel's one-hot routing
    cost scales with K·cb instead of K·n (see ``auto_cols_per_block``).

    ``window_nnz`` is the window's nnz budget; it defaults to
    ``nnz_per_step`` (every window drains in one full step when unblocked).
    With column blocking a window's non-zeros split across ~n_colblocks
    steps, so the budget auto-couples to ``nnz_per_step * n_colblocks`` in
    ``"auto"`` mode — each (window, block) step then still carries ~K slots
    of real work instead of fragmenting (the capped one-hot path needs a
    small ``nnz_per_step`` ≈ density·rows_per_window·cols_per_block, which
    ``executor.autotune`` selects).
    """
    m, n = a.shape
    row, col, val = _clean_coo(a)
    k, r = nnz_per_step, rows_per_window
    cb, window_nnz, evil_t = _resolve_geometry(
        n, k, cols_per_block, window_nnz, evil_threshold
    )

    per_row = np.bincount(row, minlength=m)
    evil_mask = per_row > evil_t
    window_start, window_of_row = _window_partition(per_row, evil_mask, window_nnz, r)

    return _emit(
        row,
        col,
        val,
        (m, n),
        k,
        r,
        cb,
        window_of_row,
        window_start,
        evil_mask,
        uniform=False,
    )


@dataclasses.dataclass(frozen=True)
class RepairStats:
    """What ``repair_schedule`` reused vs. re-emitted — consumed by the
    executor's scoped re-upload path and surfaced through serving stats
    and the streaming benchmark."""

    fell_back: bool  # True: answered with a full rebuild
    reason: str  # why (empty when incremental)
    win_shift: int  # new_n_reg_windows - old_n_reg_windows
    reused_reg_steps: int  # regular steps copied from the old schedule
    emitted_reg_steps: int  # regular steps re-emitted
    old_reg_steps: int
    new_reg_steps: int
    old_evil_steps: int
    new_evil_steps: int
    evil_dirty: bool  # evil section re-emitted
    windows_reused: int  # regular windows aligned old<->new & untouched
    windows_total: int
    #: per new step, the old step index whose slot payload it carries
    #: verbatim, or -1 for re-emitted steps (None when fell_back)
    step_src: np.ndarray | None = None

    @property
    def steps_reused(self) -> int:
        evil = 0 if self.evil_dirty else self.old_evil_steps
        return self.reused_reg_steps + evil


def slot_entry_keys(sched: Schedule):
    """Sorted ``row * n + col`` key of every *real* slot in the packed
    stream, plus the matching slot positions — the O(d·log nnz) lookup
    index behind value-only schedule patching.

    Every non-zero occupies exactly one slot, and its global coordinates
    reconstruct from the slot fields the same way the executor's gather
    routing derives them (``row_map`` precomposed). Padding slots reuse
    ``local_row == local_col == 0`` and so *can* alias a real (row, col)
    pair — but they always carry ``val == 0``, which is what masks them
    out here (``apply_edge_delta`` never produces explicit-zero entries,
    so a zero value identifies padding; an explicit-zero entry in a
    hand-built graph simply misses the index, and callers fall back to
    the generic repair).

    Returns ``(keys, slots)``: ``keys`` ascending (-1 entries first — the
    padding), ``slots`` the flat slot index carrying each key. Lookup:
    ``slots[np.searchsorted(keys, want)]`` after verifying the key
    matches."""
    k = sched.nnz_per_step
    r = sched.rows_per_window
    cb = sched.cols_per_block
    n = sched.shape[1]
    slot = (np.repeat(sched.win_id.astype(np.int64), k) * r + sched.local_row)
    rowg = sched.row_map[slot].astype(np.int64)
    colg = (np.repeat(sched.col_block.astype(np.int64), k) * cb + sched.local_col)
    key = np.where(sched.val != 0.0, rowg * n + colg, np.int64(-1))
    order = np.argsort(key, kind="stable")
    return key[order], order


def value_patch_schedule(sched: Schedule, index, rows, cols, vals):
    """``sched`` with the slots holding entries ``(rows[i], cols[i])``
    overwritten to ``vals[i]`` — or ``None`` when any entry is absent
    from ``index`` (caller falls back to the generic repair). ``index``
    is a ``slot_entry_keys(sched)`` result; the patched schedule is
    bit-identical to a cold ``build_balanced_schedule`` on the
    value-mutated graph because a value change never moves an entry
    between slots. Also returns the patched flat slot positions:
    ``(schedule, slots)``."""
    keys, order = index
    n = sched.shape[1]
    want = np.asarray(rows, np.int64) * n + np.asarray(cols, np.int64)
    pos = np.searchsorted(keys, want)
    if np.any(pos >= keys.size) or np.any(keys[np.minimum(pos, keys.size - 1)] != want):
        return None
    slots = order[pos]
    val = sched.val.copy()
    val[slots] = np.asarray(vals, val.dtype)
    return dataclasses.replace(sched, val=val), slots


def _rebuild_fallback(a: fmt.COO, reason: str, **kwargs):
    sched = build_balanced_schedule(a, **kwargs)
    n_reg = sched.n_steps - sched.n_evil_chunks
    return sched, RepairStats(
        fell_back=True,
        reason=reason,
        win_shift=0,
        reused_reg_steps=0,
        emitted_reg_steps=n_reg,
        old_reg_steps=0,
        new_reg_steps=n_reg,
        old_evil_steps=0,
        new_evil_steps=sched.n_evil_chunks,
        evil_dirty=True,
        windows_reused=0,
        windows_total=sched.n_windows,
    )


def repair_schedule(
    old: Schedule,
    old_coo: fmt.COO | None,
    new_coo: fmt.COO,
    touched_rows,
    *,
    nnz_per_step: int = 256,
    rows_per_window: int = 64,
    cols_per_block: int | None = None,
    evil_threshold: int | None = None,
    window_nnz: int | None = None,
    per_row_old: np.ndarray | None = None,
    per_row_new: np.ndarray | None = None,
):
    """Incrementally repair a balanced schedule after an edge delta — the
    paper's runtime rebalancing moves applied as *delta operators* instead
    of a from-scratch build.

    The three moves map onto the three phases of the repair:

    * **distribution smoothing** — the first-fit window partition is
      recomputed for the mutated nnz histogram (vectorized, O(m)), then
      *aligned* against the old partition: any window whose (start, end)
      boundaries appear in both partitions and which contains no touched
      row is provably identical (the boundary chain is deterministic in
      the prefix sums, which agree outside touched rows), so its packed
      steps carry over. Deltas only unsync the chains locally — each
      touched cluster resyncs at the next boundary both chains share.
    * **remote switching** — non-zeros of the dirty windows are re-packed
      into fresh ≤k-slot steps by one ``_emit`` over just those entries;
      reused steps merge with re-emitted steps by a stable sort on the
      (window, col_block) step key — the same global order a cold build
      produces, since a step group never spans a clean/dirty boundary.
    * **row remapping** — ``row_map`` is regenerated from the new partition
      (one O(m) fancy-indexed write); evil-row chunks are re-emitted only
      if a touched row is evil in either the old or new schedule, else the
      old chunk steps are spliced through with their window ids shifted.

    Returns ``(schedule, RepairStats)``. The result is **bit-identical** to
    ``build_balanced_schedule(new_coo, ...)`` with the same kwargs — repairs
    never fork the geometry from what a cold rebuild would produce, so
    executors, stores and replicas can treat repaired and rebuilt schedules
    interchangeably. Degenerate cases (empty graphs, partitions that no
    longer match ``old``) fall back to a full rebuild, flagged in the stats.
    """
    m, n = old.shape
    if new_coo.shape != old.shape:
        raise ValueError(
            f"edge deltas cannot change shape: {old.shape} -> {new_coo.shape}"
        )
    k, r = nnz_per_step, rows_per_window
    cb, window_nnz, evil_t = _resolve_geometry(
        n, k, cols_per_block, window_nnz, evil_threshold
    )
    if (old.nnz_per_step, old.rows_per_window, old.cols_per_block) != (k, r, cb):
        raise ValueError(
            "repair kwargs disagree with the schedule being repaired: "
            f"({old.nnz_per_step}, {old.rows_per_window}, {old.cols_per_block})"
            f" != ({k}, {r}, {cb})"
        )
    kwargs = dict(
        nnz_per_step=k,
        rows_per_window=r,
        cols_per_block=cols_per_block,
        evil_threshold=evil_threshold,
        window_nnz=window_nnz,
    )

    touched = np.unique(np.asarray(touched_rows, np.int64))
    row_n, col_n, val_n = _clean_coo(new_coo)
    if touched.size == 0:
        old_reg = old.n_steps - old.n_evil_chunks
        stats = RepairStats(
            fell_back=False,
            reason="",
            win_shift=0,
            reused_reg_steps=old_reg,
            emitted_reg_steps=0,
            old_reg_steps=old_reg,
            new_reg_steps=old_reg,
            old_evil_steps=old.n_evil_chunks,
            new_evil_steps=old.n_evil_chunks,
            evil_dirty=False,
            windows_reused=old.n_windows,
            windows_total=old.n_windows,
            step_src=np.arange(old.n_steps, dtype=np.int64),
        )
        return old, stats
    if m == 0 or old.nnz == 0 or row_n.size == 0:
        return _rebuild_fallback(new_coo, "degenerate-size", **kwargs)
    if touched.min() < 0 or touched.max() >= m:
        raise ValueError("touched_rows out of range")

    # per-row histograms: callers that track them incrementally (the serving
    # engine, via DeltaReport) skip both O(nnz) bincounts — the repair hot
    # path is then O(m + dirty_nnz) plus pure memcpy
    per_row_o = per_row_old
    if per_row_o is None:
        if old_coo is None:
            raise ValueError("need old_coo or per_row_old")
        row_o, _, _ = _clean_coo(old_coo)
        per_row_o = np.bincount(row_o, minlength=m)
    per_row_n = per_row_new
    if per_row_n is None:
        per_row_n = np.bincount(row_n, minlength=m)
    evil_o = per_row_o > evil_t
    evil_n = per_row_n > evil_t
    ws_o, _ = _window_partition(per_row_o, evil_o, window_nnz, r)
    ws_n, wor_n = _window_partition(per_row_n, evil_n, window_nnz, r)

    old_evil_w = -(-max(1, old.n_evil_chunks) // r) if old.n_evil_chunks else 0
    if (
        old.n_windows - old_evil_w != ws_o.shape[0]
        or int(old.nnz) != int(per_row_o.sum())
        or int(per_row_n.sum()) != row_n.size
    ):
        # old_coo/per_row does not describe the schedule being repaired
        return _rebuild_fallback(new_coo, "partition-mismatch", **kwargs)

    evil_dirty = bool(np.any(evil_o[touched] | evil_n[touched]))
    n_colblocks = max(1, -(-n // cb))

    # ---- window alignment: (start, end) in both partitions + untouched ----
    ends_o = np.append(ws_o[1:], m).astype(np.int64)
    ends_n = np.append(ws_n[1:], m).astype(np.int64)
    _, io, jn = np.intersect1d(ws_o, ws_n, return_indices=True)
    cand = ends_o[io] == ends_n[jn]
    t_lo = np.searchsorted(touched, ws_o[io].astype(np.int64))
    t_hi = np.searchsorted(touched, ends_o[io])
    cand &= t_hi == t_lo
    old_clean = io[cand]  # increasing, and so is its new counterpart:
    new_clean = jn[cand]  # intersect1d walks both sorted start arrays
    win_shift = int(ws_n.shape[0] - ws_o.shape[0])

    # ---- re-emit dirty windows (plus the evil section when dirty) ---------
    clean_w_n = np.zeros(ws_n.shape[0], bool)
    clean_w_n[new_clean] = True
    sel = ~clean_w_n[wor_n[row_n]] & ~evil_n[row_n]
    if evil_dirty:
        sel |= evil_n[row_n]
    idx = np.nonzero(sel)[0]  # order-preserving: subset stays (row,col)-sorted
    mid = _emit(
        row_n[idx],
        col_n[idx],
        val_n[idx],
        (m, n),
        k,
        r,
        cb,
        wor_n,
        ws_n,
        evil_n,
        uniform=False,
    )
    if idx.size:
        mid_reg = mid.n_steps - mid.n_evil_chunks
        mid_evil = mid.n_evil_chunks
    else:
        mid_reg = mid_evil = 0  # _emit pads an empty input to one no-op step

    # ---- merge reused and re-emitted regular steps ------------------------
    old_reg_steps = old.n_steps - old.n_evil_chunks
    old_win_reg = old.win_id[:old_reg_steps]
    clean_w_o = np.zeros(ws_o.shape[0], bool)
    clean_w_o[old_clean] = True
    old_keep = np.nonzero(clean_w_o[old_win_reg])[0]
    remap = np.full(ws_o.shape[0], -1, np.int64)
    remap[old_clean] = new_clean
    kept_win = remap[old_win_reg[old_keep]]
    kept_cb = old.col_block[old_keep].astype(np.int64)
    mid_win = mid.win_id[:mid_reg].astype(np.int64)
    mid_cb = mid.col_block[:mid_reg].astype(np.int64)
    if n_colblocks == 1:
        keys = np.concatenate([kept_win, mid_win])
    else:
        keys = np.concatenate(
            [kept_win * n_colblocks + kept_cb, mid_win * n_colblocks + mid_cb]
        )
    # ties never straddle sources — a (window, col_block) step group lives
    # in exactly one window, which is either wholly clean or wholly dirty —
    # so a stable sort interleaves the two streams into cold-build order
    # while preserving each group's chunk order
    perm = np.argsort(keys, kind="stable")
    new_reg_steps = old_keep.size + mid_reg
    new_evil_steps = mid_evil if evil_dirty else old.n_evil_chunks
    if new_reg_steps + new_evil_steps == 0:
        return _rebuild_fallback(new_coo, "empty-schedule", **kwargs)

    win_reg = np.concatenate([kept_win, mid_win])[perm]
    cb_reg = np.concatenate([kept_cb, mid_cb])[perm]
    src_reg = np.concatenate([old_keep, np.full(mid_reg, -1, np.int64)])[perm]

    def merge_slots(old_a, mid_a):
        stacked = np.concatenate(
            [
                old_a[: old_reg_steps * k].reshape(old_reg_steps, k)[old_keep],
                mid_a[: mid_reg * k].reshape(mid_reg, k),
            ]
        )
        return stacked[perm].reshape(-1)

    val = merge_slots(old.val, mid.val)
    local_row = merge_slots(old.local_row, mid.local_row)
    local_col = merge_slots(old.local_col, mid.local_col)

    # ---- evil section ------------------------------------------------------
    if evil_dirty:
        win_ev = mid.win_id[mid_reg:].astype(np.int64)
        cb_ev = mid.col_block[mid_reg:].astype(np.int64)
        val_ev = mid.val[mid_reg * k :]
        lrow_ev = mid.local_row[mid_reg * k :]
        lcol_ev = mid.local_col[mid_reg * k :]
        src_ev = np.full(mid_evil, -1, np.int64)
    else:
        win_ev = old.win_id[old_reg_steps:].astype(np.int64) + win_shift
        cb_ev = old.col_block[old_reg_steps:].astype(np.int64)
        val_ev = old.val[old_reg_steps * k :]
        lrow_ev = old.local_row[old_reg_steps * k :]
        lcol_ev = old.local_col[old_reg_steps * k :]
        src_ev = np.arange(old_reg_steps, old.n_steps, dtype=np.int64)

    n_reg_w_new = int(ws_n.shape[0])
    if evil_dirty:
        evil_tail = mid.row_map[n_reg_w_new * r :]
    else:
        evil_tail = old.row_map[ws_o.shape[0] * r :]
    row_map = np.concatenate([mid.row_map[: n_reg_w_new * r], evil_tail])

    sched = Schedule(
        win_id=np.concatenate([win_reg, win_ev]).astype(np.int32),
        col_block=np.concatenate([cb_reg, cb_ev]).astype(np.int32),
        val=np.concatenate([val, val_ev]),
        local_row=np.concatenate([local_row, lrow_ev]),
        local_col=np.concatenate([local_col, lcol_ev]),
        row_map=row_map.astype(np.int32, copy=False),
        shape=(m, n),
        nnz_per_step=k,
        rows_per_window=r,
        cols_per_block=cb,
        nnz=int(row_n.size),
        n_evil_chunks=int(new_evil_steps),
    )
    if sched.val.shape[0] != sched.n_steps * k:
        return _rebuild_fallback(new_coo, "splice-length-mismatch", **kwargs)
    stats = RepairStats(
        fell_back=False,
        reason="",
        win_shift=win_shift,
        reused_reg_steps=int(old_keep.size),
        emitted_reg_steps=int(mid_reg),
        old_reg_steps=old_reg_steps,
        new_reg_steps=int(new_reg_steps),
        old_evil_steps=old.n_evil_chunks,
        new_evil_steps=int(new_evil_steps),
        evil_dirty=evil_dirty,
        windows_reused=int(old_clean.size),
        windows_total=sched.n_windows,
        step_src=np.concatenate([src_reg, src_ev]),
    )
    return sched, stats


def build_naive_schedule(
    a: fmt.COO,
    nnz_per_step: int = 256,
    rows_per_window: int = 64,
    cols_per_block: int | None = None,
) -> Schedule:
    """Paper baseline (§III.B): uniform static row partition, no rebalancing.
    Every row block issues the step count of the *heaviest* block — the
    static-grid cost of workload imbalance (idle PEs ≡ padded slots)."""
    m, n = a.shape
    row, col, val = _clean_coo(a)
    r = rows_per_window
    cb = _resolve_cols_per_block(n, cols_per_block)
    window_of_row = (np.arange(m, dtype=np.int32) // np.int32(r)).astype(
        np.int32, copy=False
    )
    window_start = np.arange(0, max(m, 1), r, dtype=np.int32)
    evil_mask = np.zeros(m, bool)  # baseline has no evil-row handling
    return _emit(
        row,
        col,
        val,
        (m, n),
        nnz_per_step,
        r,
        cb,
        window_of_row,
        window_start,
        evil_mask,
        uniform=True,
    )


def scatter_epilogue(sched: Schedule, out_perm) -> "jax.Array":  # noqa: F821
    """Map the kernel's permuted-window output back to matrix rows.
    Evil chunks scatter-add into their owner rows — the adder tree."""
    import jax.numpy as jnp

    m = sched.shape[0]
    rm = jnp.asarray(sched.row_map)
    valid = rm >= 0
    tgt = jnp.where(valid, rm, 0)
    contrib = jnp.where(valid[:, None], out_perm, 0)
    return jnp.zeros((m, out_perm.shape[1]), out_perm.dtype).at[tgt].add(contrib)


def execute_schedule_jnp(sched: Schedule, b) -> "jax.Array":  # noqa: F821
    """Pure-jnp executor of a Schedule — the oracle the Pallas kernel is
    tested against, and itself tested against ``spmm.spmm_coo``."""
    import jax.numpy as jnp

    m, n = sched.shape
    k = sched.nnz_per_step
    r = sched.rows_per_window
    kdim = b.shape[1]
    n_steps = sched.n_steps

    val = jnp.asarray(sched.val)
    lrow = jnp.asarray(sched.local_row).reshape(n_steps, k)
    lcol = jnp.asarray(sched.local_col).reshape(n_steps, k)
    win = jnp.asarray(sched.win_id)
    cblk = jnp.asarray(sched.col_block)

    gcol = jnp.minimum(cblk[:, None] * sched.cols_per_block + lcol, n - 1)
    slot = (win[:, None] * r + lrow).reshape(-1)
    gathered = b[gcol.reshape(-1)] * val[:, None]
    out_perm = jnp.zeros((sched.n_windows * r, kdim), b.dtype)
    out_perm = out_perm.at[slot].add(gathered)
    return scatter_epilogue(sched, out_perm)
