"""AWB-GCN's rebalancing applied to MoE expert parallelism.

Router→expert token counts in a large-expert-count MoE follow exactly the
power-law imbalance AWB-GCN targets (a few "evil" experts receive most
tokens). The paper's three techniques map onto expert-parallel placement:

  * distribution smoothing  → balanced assignment of experts to the device
    slots within a node/pod (local),
  * remote switching        → per-interval placement swaps between the most
    over-/under-loaded devices, driven by an EMA of observed loads,
  * evil row remapping      → hot experts get *replicas* on under-loaded
    devices; dispatch splits their tokens across replicas and the partial
    outputs merge in the combine step (the Labor-PE adder tree).

This is the same algorithmic object as ``schedule.build_balanced_schedule``
— profile a power-law workload, converge to a balanced static placement,
amortize it across steps — applied to the `(expert, device)` axis instead of
`(row, PE)`. The placement is recomputed every N steps from the EMA, mirroring
the per-round autotuner.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class ExpertPlacement:
    """slots[d, s] = expert id hosted in slot s of device d.
    replica_count[e] = number of replicas of expert e (≥1).
    replica_rank[d, s] = which replica of that expert this slot is."""

    slots: np.ndarray
    replica_count: np.ndarray
    replica_rank: np.ndarray

    @property
    def n_devices(self) -> int:
        return self.slots.shape[0]

    @property
    def slots_per_device(self) -> int:
        return self.slots.shape[1]


def static_placement(n_experts: int, n_devices: int) -> ExpertPlacement:
    """The baseline: expert e lives on device e // ceil(E/D) — no
    rebalancing. Non-divisible counts pad trailing slots with -1."""
    per = -(-n_experts // n_devices)
    slots = np.full(n_devices * per, -1, np.int32)
    slots[:n_experts] = np.arange(n_experts, dtype=np.int32)
    slots = slots.reshape(n_devices, per)
    return ExpertPlacement(slots,
                           np.ones(n_experts, np.int32),
                           np.zeros((n_devices, per), np.int32))


def balance_placement(expert_load: np.ndarray, n_devices: int,
                      slots_per_device: int | None = None) -> ExpertPlacement:
    """AWB placement: replicate hot experts into spare slots (evil-expert
    remapping), then LPT-assign replicas to devices (remote switching's
    converged state).

    ``expert_load`` is the profiled (EMA) token count per expert.
    """
    e = expert_load.shape[0]
    load = expert_load.astype(np.float64) + 1e-6
    spd = slots_per_device if slots_per_device else -(-e // n_devices)
    total_slots = n_devices * spd
    if total_slots < e:
        raise ValueError("not enough slots for one replica per expert")

    # --- evil-expert replication: hand spare slots to whichever expert
    # currently has the highest per-replica load ---------------------------
    replicas = np.ones(e, np.int64)
    heap = [(-load[i], i) for i in range(e)]
    heapq.heapify(heap)
    for _ in range(total_slots - e):
        neg, i = heapq.heappop(heap)
        replicas[i] += 1
        heapq.heappush(heap, (-(load[i] / replicas[i]), i))

    # --- LPT assignment of replicas to devices (longest processing time):
    # heaviest replica first onto the least-loaded device with a free slot --
    rep_ids = np.repeat(np.arange(e), replicas)
    rep_load = load[rep_ids] / replicas[rep_ids]
    order = np.argsort(-rep_load)
    dev_heap = [(0.0, d) for d in range(n_devices)]
    heapq.heapify(dev_heap)
    dev_fill = np.zeros(n_devices, np.int64)
    slots = np.full((n_devices, spd), -1, np.int32)
    rrank = np.zeros((n_devices, spd), np.int32)
    next_rank = np.zeros(e, np.int64)
    spill = []
    for ri in order:
        placed = False
        tmp = []
        while dev_heap:
            l, d = heapq.heappop(dev_heap)
            if dev_fill[d] < spd:
                eid = int(rep_ids[ri])
                slots[d, dev_fill[d]] = eid
                rrank[d, dev_fill[d]] = next_rank[eid]
                next_rank[eid] += 1
                dev_fill[d] += 1
                heapq.heappush(dev_heap, (l + float(rep_load[ri]), d))
                placed = True
                break
            tmp.append((l, d))
        for item in tmp:
            heapq.heappush(dev_heap, item)
        if not placed:
            spill.append(ri)
    assert not spill, "slot accounting failed"
    return ExpertPlacement(slots, replicas.astype(np.int32), rrank)


def device_loads(placement: ExpertPlacement,
                 expert_load: np.ndarray) -> np.ndarray:
    per_replica = expert_load.astype(np.float64) / placement.replica_count
    padded = np.concatenate([per_replica, [0.0]])  # -1 slots → 0 load
    return padded[placement.slots].sum(axis=1)


def imbalance(loads: np.ndarray) -> float:
    """max/mean — 1.0 is perfect; the EP step time scales with max."""
    return float(loads.max() / max(loads.mean(), 1e-9))


def zipf_expert_load(n_experts: int, n_tokens: int, alpha: float = 1.0,
                     seed: int = 0) -> np.ndarray:
    """Synthetic power-law router histogram for tests/benchmarks."""
    rng = np.random.default_rng(seed)
    w = np.arange(1, n_experts + 1, dtype=np.float64) ** (-alpha)
    w /= w.sum()
    rng.shuffle(w)
    return rng.multinomial(n_tokens, w).astype(np.float64)


def dispatch_plan(expert_assignment: np.ndarray, placement: ExpertPlacement
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Map each routed (token, expert) pair to a (device, slot).

    Tokens of a replicated expert round-robin across its replicas — the
    evil-row chunking. Returns (device, slot) per token. Host-side planning
    utility for the serving engine; the jit dispatch path uses capacities.
    """
    e = placement.replica_count.shape[0]
    # replica r of expert e lives at... build lookup [e, max_rep] -> (d, s)
    max_rep = int(placement.replica_count.max())
    loc = np.full((e, max_rep, 2), -1, np.int64)
    for d in range(placement.n_devices):
        for s in range(placement.slots_per_device):
            eid = placement.slots[d, s]
            if eid >= 0:
                loc[eid, placement.replica_rank[d, s]] = (d, s)
    counters = np.zeros(e, np.int64)
    n = expert_assignment.shape[0]
    dev = np.empty(n, np.int64)
    slot = np.empty(n, np.int64)
    for t in range(n):
        eid = int(expert_assignment[t])
        r = counters[eid] % placement.replica_count[eid]
        counters[eid] += 1
        dev[t], slot[t] = loc[eid, r]
    return dev, slot
