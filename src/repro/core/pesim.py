"""PE-array timing model of the AWB-GCN FPGA engine.

Used to reproduce the paper's evaluation figures (utilization waves, per-
design utilization/cycles, convergence, PE scaling) without Verilog. The
model is deliberately analytic:

* Each PE's raw work = non-zeros assigned to it (one MAC per non-zero per
  round — the paper's PEs process one non-zero pair per cycle).
* *Distribution smoothing* with hop distance ``h`` lets work flow to PEs at
  most ``h`` positions away (§IV.A: "direct neighbors, 2-hop ... but not
  farther"). The achievable makespan is then the interval bound

      makespan = max over intervals I of  ceil( sum(load[I]) / min(n, |I| + 2h) )

  — work inside I can recruit at most the ``h`` helpers on each side. With
  ``h = 0`` this degenerates to ``max(load)``: the static baseline.
* Utilization = total_work / (n_pe × makespan) — exactly what the paper's
  per-PE idle-cycle counters measure.

The interval bound is exact for divisible loads and a lower bound on real
makespan generally; the paper's queues approximate divisibility well because
tasks are single MACs.
"""
from __future__ import annotations

import numpy as np


def interval_makespan(load: np.ndarray, hops: int) -> float:
    """Max over intervals of sum/(len + 2*hops) — O(n²) via cumsum sweeps."""
    n = load.shape[0]
    if n == 0:
        return 0.0
    if hops == 0:
        return float(load.max())
    cum = np.concatenate([[0.0], np.cumsum(load, dtype=np.float64)])
    best = float(load.max()) / min(n, 1 + 2 * hops)
    for length in range(1, n + 1):
        ws = cum[length:] - cum[:-length]
        denom = min(n, length + 2 * hops)
        cand = float(ws.max()) / denom
        if cand > best:
            best = cand
        # prune: once length+2h == n the bound is total/n and can't grow
        if length + 2 * hops >= n:
            break
    return max(best, float(cum[-1]) / n)


def utilization(load: np.ndarray, hops: int) -> float:
    total = float(load.sum())
    if total == 0:
        return 1.0
    return total / (load.shape[0] * interval_makespan(load, hops))


def smoothed_finish_times(load: np.ndarray, hops: int,
                          iters: int = 2) -> np.ndarray:
    """Per-PE effective finish-time estimate after h-hop smoothing (box
    diffusion) — what the PESM's queue-empty XOR timestamps observe. Used by
    the autotuner to locate crests and troughs."""
    eff = load.astype(np.float64)
    if hops == 0:
        return eff
    width = 2 * hops + 1
    kernel = np.ones(width) / width
    for _ in range(iters):
        eff = np.convolve(eff, kernel, mode="same")
    return eff


def loads_from_assignment(row_nnz: np.ndarray, row_to_pe: np.ndarray,
                          n_pe: int,
                          split_rows: dict | None = None) -> np.ndarray:
    """Per-PE load given a row→PE map and optional evil-row splits.

    ``split_rows`` maps row id → (pe_ids array, fractions array); split rows
    must carry ``row_to_pe[row] == -1``.
    """
    sel = row_to_pe >= 0
    load = np.bincount(row_to_pe[sel], weights=row_nnz[sel],
                       minlength=n_pe).astype(np.float64)
    if split_rows:
        for row, (pes, fracs) in split_rows.items():
            load[pes] += row_nnz[row] * np.asarray(fracs)
    return load


def initial_assignment(n_rows: int, n_pe: int) -> np.ndarray:
    """Paper §III.B baseline: direct static contiguous row partition."""
    rows_per_pe = -(-n_rows // n_pe)
    return (np.arange(n_rows) // rows_per_pe).astype(np.int64)
