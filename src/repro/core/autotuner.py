"""The AWB-GCN runtime autotuner (paper §IV), faithful iterative version.

Reproduces the per-round rebalancing loop of the FPGA: each round (= one
output column of the column-wise-product SpMM) the Autotuner observes
per-PE finish times (PESM), then

  1. *remote switching* (§IV.B) — picks ``n_tuples`` (most-overloaded,
     most-underloaded) PE pairs at distinct crests/troughs and moves
     ``N_{i,j}`` rows between them (Eqs. 5/6, with feedback over a tracking
     window of 2 rounds),
  2. *evil row remapping* (§IV.C) — when the gap is too large for switching
     (a single row dominates the crest PE), partitions that row across
     ``n_labor`` under-loaded Labor-PEs,

while *distribution smoothing* (§IV.A) acts continuously inside the round
(modeled by ``pesim``'s h-hop interval bound).

The state after convergence — a row→PE map plus evil-row splits — is the
same object ``schedule.build_balanced_schedule`` constructs directly; the
test-suite asserts the two agree on achieved utilization. On TPU the
converged map is what we lower; the iterative path exists to reproduce the
paper's convergence dynamics (Figs. 3, 17) and per-design results (Fig. 14).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

from repro.core import pesim


@dataclasses.dataclass
class DesignConfig:
    """Paper §V.B design points: Baseline, (A), (B), (C), (D)."""

    name: str
    smoothing_hops: int = 0
    remote_switching: bool = False
    row_remapping: bool = False
    n_tuples: int = 4          # switch tuples per round (Fig. 13)
    n_labor: int = 4           # labor PEs per evil-row chunk group (Fig. 13)
    evil_slack: float = 1.5    # a row is evil when even fully smoothed it
    # exceeds evil_slack × mean load — too big for switching to handle


def designs_for(dataset: str) -> Dict[str, DesignConfig]:
    """The five evaluated designs; NELL uses 2/3-hop smoothing (§V.B)."""
    lo, hi = (2, 3) if dataset == "nell" else (1, 2)
    return {
        "baseline": DesignConfig("baseline"),
        "A": DesignConfig("A", smoothing_hops=lo),
        "B": DesignConfig("B", smoothing_hops=hi),
        "C": DesignConfig("C", smoothing_hops=lo, remote_switching=True,
                          row_remapping=True),
        "D": DesignConfig("D", smoothing_hops=hi, remote_switching=True,
                          row_remapping=True),
    }


@dataclasses.dataclass
class TunerState:
    row_to_pe: np.ndarray                 # [rows] int64, -1 for split rows
    split_rows: Dict[int, Tuple[np.ndarray, np.ndarray]]
    tracked: List[Tuple[int, int, float]]  # (over_pe, under_pe, G1) feedback

    def loads(self, row_nnz: np.ndarray, n_pe: int) -> np.ndarray:
        return pesim.loads_from_assignment(row_nnz, self.row_to_pe, n_pe,
                                           self.split_rows)


@dataclasses.dataclass
class RoundLog:
    round: int
    utilization: float
    makespan: float
    n_switches: int
    n_remaps: int


def _pick_extremes(eff: np.ndarray, k: int, lowest: bool,
                   min_separation: int) -> List[int]:
    """k extreme PEs at distinct crests/troughs (the arbiter skips
    neighbours of already-selected PEs, §IV.B)."""
    order = np.argsort(eff if lowest else -eff)
    picked: List[int] = []
    for pe in order:
        if all(abs(int(pe) - p) > min_separation for p in picked):
            picked.append(int(pe))
        if len(picked) >= k:
            break
    return picked


def run_autotuning(row_nnz: np.ndarray, n_pe: int, design: DesignConfig,
                   n_rounds: int = 12, seed: int = 0,
                   ) -> Tuple[TunerState, List[RoundLog]]:
    """Simulate ``n_rounds`` of autotuning; returns converged state + log."""
    n_rows = row_nnz.shape[0]
    rng = np.random.default_rng(seed)
    state = TunerState(pesim.initial_assignment(n_rows, n_pe), {}, [])
    rows_per_pe = -(-n_rows // n_pe)
    log: List[RoundLog] = []

    # rows owned by each PE, maintained incrementally
    rows_of_pe: List[List[int]] = [[] for _ in range(n_pe)]
    for r, pe in enumerate(state.row_to_pe):
        rows_of_pe[pe].append(r)

    for rnd in range(n_rounds):
        load = state.loads(row_nnz, n_pe)
        mk = pesim.interval_makespan(load, design.smoothing_hops)
        util = float(load.sum()) / max(1e-9, n_pe * mk)
        n_sw = n_rm = 0

        if design.remote_switching or design.row_remapping:
            # crest/trough selection reads exact per-PE pending work — the
            # PESM's queue counters (smoothed estimates shift crests at
            # boundaries and can exclude the true peak)
            eff = load
            sep = 2 * design.smoothing_hops + 1
            mean_load = float(load.sum()) / n_pe
            smooth_div = 1 + 2 * design.smoothing_hops

            # --- evil row remapping first (§IV.C): rows so heavy that even
            # full smoothing leaves them above the mean are partitioned
            # across Labor-PEs at the troughs (one Super-PE group per round
            # per crest, as on the FPGA) ---------------------------------
            if design.row_remapping:
                overs = _pick_extremes(eff, design.n_tuples, False, sep)
                for over in overs:
                    own = rows_of_pe[over]
                    if not own:
                        continue
                    nnz_own = row_nnz[own]
                    heavy = int(np.argmax(nnz_own))
                    hv = float(nnz_own[heavy])
                    if hv / smooth_div <= design.evil_slack * mean_load:
                        continue
                    row = own[heavy]
                    # enough labor PEs that each chunk sinks below the mean
                    # even before smoothing (the Super-PE sizes the split
                    # from its non-zero counter)
                    n_chunks = int(min(
                        max(design.n_labor, np.ceil(hv / max(mean_load, 1.0))),
                        max(4, n_pe // 8)))
                    labor = _pick_extremes(eff, n_chunks, True, 1)
                    fr = np.full(len(labor), 1.0 / len(labor))
                    state.split_rows[row] = (np.asarray(labor), fr)
                    state.row_to_pe[row] = -1
                    own.pop(heavy)
                    n_rm += 1
                if n_rm:
                    load = state.loads(row_nnz, n_pe)
                    eff = load

            # --- remote switching, Eq. 5/6 -------------------------------
            if design.remote_switching:
                overs = _pick_extremes(eff, design.n_tuples, False, sep)
                unders = _pick_extremes(eff, design.n_tuples, True, sep)
                g1 = None
                for over, under in zip(overs, unders):
                    gap = float(load[over] - load[under])
                    if gap <= 0:
                        continue
                    if g1 is None:
                        g1 = gap  # G_1: first-tuple gap this round (Eq. 5)
                    own = rows_of_pe[over]
                    if not own:
                        continue
                    n_init = max(1, int(round(gap / max(g1, 1e-9)
                                              * max(rows_per_pe / 2, 1.0))))
                    # move rows fitting a gap/2 budget (greedy heaviest-
                    # first without overshoot, so the under-PE never turns
                    # into a new crest — the anti-thrashing rule)
                    nnz_own = row_nnz[own]
                    order = np.argsort(-nnz_own)
                    budget = gap / 2
                    moved, acc, taken = [], 0.0, 0
                    for j in order:
                        if taken >= n_init or budget - acc <= 0:
                            break
                        if float(nnz_own[j]) <= budget - acc + 1e-9:
                            moved.append(int(j))
                            acc += float(nnz_own[j])
                            taken += 1
                    for j in sorted(moved, reverse=True):
                        row = own.pop(j)
                        state.row_to_pe[row] = under
                        rows_of_pe[under].append(row)
                    if moved:
                        n_sw += 1
                        load[over] -= acc
                        load[under] += acc
                    # feedback tracking (Eq. 6)
                    state.tracked = state.tracked[-(2 * design.n_tuples):]
                    state.tracked.append((over, under, gap))

        log.append(RoundLog(rnd, util, float(mk), n_sw, n_rm))
        if (not design.remote_switching and not design.row_remapping
                and rnd >= 1):
            # static designs don't change between rounds
            for r2 in range(rnd + 1, n_rounds):
                log.append(RoundLog(r2, util, float(mk), 0, 0))
            break

    return state, log


def converged_utilization(row_nnz: np.ndarray, n_pe: int,
                          design: DesignConfig, n_rounds: int = 12
                          ) -> Tuple[float, List[RoundLog]]:
    state, log = run_autotuning(row_nnz, n_pe, design, n_rounds)
    load = state.loads(row_nnz, n_pe)
    mk = pesim.interval_makespan(load, design.smoothing_hops)
    util = float(load.sum()) / max(1e-9, n_pe * mk)
    return util, log


def total_cycles(row_nnz: np.ndarray, n_pe: int, design: DesignConfig,
                 n_output_cols: int, n_rounds: int = 12) -> float:
    """End-to-end cycles of one SpMM: the first ``n_rounds`` columns run at
    the evolving per-round makespan, the rest reuse the converged config
    ("after converging, reuses the ideal configuration")."""
    state, log = run_autotuning(row_nnz, n_pe, design, n_rounds)
    load = state.loads(row_nnz, n_pe)
    mk_conv = pesim.interval_makespan(load, design.smoothing_hops)
    warm = sum(l.makespan for l in log[:min(n_rounds, n_output_cols)])
    rest = max(0, n_output_cols - n_rounds) * mk_conv
    return warm + rest
