"""GCN model (Kipf & Welling) on the AWB SpMM engine.

Two-layer spectral GCN: ``Z = softmax( Ã · ReLU( Ã · X · W1 ) · W2 )`` with
the paper's A×(X×W) execution order (§III.A) on every layer. The sparse
A·(XW) product runs through a ``Schedule`` (converged AWB configuration);
X·W runs dense on the MXU (TDQ-1 decision, DESIGN.md §2).

Inference is the paper's workload; training (cross-entropy + Adam) is
provided so the end-to-end train example and loss-decrease tests have a
real substrate.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import csc as fmt
from repro.core import spmm
from repro.core.schedule import Schedule, execute_schedule_jnp


@dataclasses.dataclass(frozen=True)
class GCNConfig:
    num_features: int
    hidden: int
    num_classes: int
    n_layers: int = 2


def init_params(cfg: GCNConfig, key: jax.Array) -> dict:
    dims = [cfg.num_features] + [cfg.hidden] * (cfg.n_layers - 1) + [cfg.num_classes]
    params = {}
    for i, (din, dout) in enumerate(zip(dims[:-1], dims[1:])):
        key, sub = jax.random.split(key)
        # Glorot as in Kipf & Welling
        lim = float(np.sqrt(6.0 / (din + dout)))
        params[f"w{i}"] = jax.random.uniform(sub, (din, dout), jnp.float32,
                                             -lim, lim)
    return params


def forward(params: dict, a: fmt.COO, x: jax.Array,
            spmm_fn: Optional[Callable] = None) -> jax.Array:
    """Logits. ``spmm_fn(b) -> A @ b`` defaults to the COO reference;
    pass a schedule- or pallas-backed closure to run the AWB engine."""
    if spmm_fn is None:
        spmm_fn = functools.partial(spmm.spmm_coo, a)
    h = x
    n_layers = len(params)
    for i in range(n_layers):
        h = spmm_fn(spmm.spmm_dense(h, params[f"w{i}"]))  # A × (X × W)
        if i < n_layers - 1:
            h = jax.nn.relu(h)
    return h


def make_schedule_spmm(sched: Schedule) -> Callable:
    return functools.partial(execute_schedule_jnp, sched)


def forward_awb(params: dict, a: fmt.COO, x: jax.Array,
                sched: Optional[Schedule] = None,
                executor: Optional["_ExecutorBase"] = None,  # noqa: F821
                n_devices: Optional[int] = None,
                mesh=None) -> jax.Array:
    """Forward pass through the converged AWB configuration.

    Runs on a ``core.executor.ScheduleExecutor`` — device-resident schedule
    arrays uploaded once, jitted whole-GCN forward, cached by graph
    fingerprint — so repeated inference on a fixed graph pays zero schedule
    rebuild/transfer cost (DESIGN.md §3). Pass ``sched`` to pin a
    caller-built schedule, or ``executor`` to bring your own.

    ``n_devices`` (or a 1-D ``mesh``) runs the layers' SpMMs on the
    **sharded** executor instead: per-device step shards under shard_map
    with a psum merge, cached by ``(graph fingerprint, mesh)`` (DESIGN.md
    §4).
    """
    from repro.tuning import registry as _reg

    if executor is None:
        if sched is None:
            executor = _reg.get_executor(a, n_devices=n_devices, mesh=mesh)
        else:
            executor = _reg.executor_for_schedule(sched, n_devices=n_devices,
                                                  mesh=mesh)
    return executor.forward(params, x)


def loss_fn(params: dict, a: fmt.COO, x: jax.Array, labels: jax.Array,
            mask: Optional[jax.Array] = None,
            spmm_fn: Optional[Callable] = None) -> jax.Array:
    logits = forward(params, a, x, spmm_fn)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()


def accuracy(params: dict, a: fmt.COO, x: jax.Array,
             labels: jax.Array) -> jax.Array:
    return (forward(params, a, x).argmax(-1) == labels).mean()
