"""Sparse matrix formats for AWB-GCN, in pure JAX.

JAX only ships BCOO; AWB-GCN's column-wise-product SpMM wants CSC (the paper
streams dense B and reuses sparse A per output column), the balanced Pallas
kernel wants a flat nnz-sorted COO ("packed" format), and the PE simulator
wants per-row nnz histograms (CSR-ish). We implement all of them as small
NamedTuples of jnp arrays with static shapes so they jit/shard cleanly.

Conventions
-----------
* All index arrays are int32.
* Padding entries use column/row index ``PAD_IDX == -1`` and value 0.0 so a
  padded SpMM contributes nothing (guarded gathers clamp the index).
* Shapes are static: ``nnz`` is the *padded* nnz capacity.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PAD_IDX = -1


class COO(NamedTuple):
    """Coordinate format, row-major sorted unless stated otherwise."""

    row: jax.Array  # [nnz] int32
    col: jax.Array  # [nnz] int32
    val: jax.Array  # [nnz] float
    shape: Tuple[int, int]

    @property
    def nnz(self) -> int:
        return self.row.shape[0]


class CSR(NamedTuple):
    indptr: jax.Array  # [m+1] int32
    indices: jax.Array  # [nnz] int32 column ids
    data: jax.Array  # [nnz]
    shape: Tuple[int, int]

    @property
    def nnz(self) -> int:
        return self.indices.shape[0]


class CSC(NamedTuple):
    """The paper's format for A: non-zeros contiguous per column."""

    indptr: jax.Array  # [n+1] int32
    indices: jax.Array  # [nnz] int32 row ids
    data: jax.Array  # [nnz]
    shape: Tuple[int, int]

    @property
    def nnz(self) -> int:
        return self.indices.shape[0]


class ELL(NamedTuple):
    """ELLPACK: fixed non-zeros per row, padded. Used by dense-ish operands."""

    indices: jax.Array  # [m, k] int32 column ids, PAD_IDX for padding
    data: jax.Array  # [m, k]
    shape: Tuple[int, int]


# ---------------------------------------------------------------------------
# Construction from dense / scipy-style triplets (host-side, numpy)
# ---------------------------------------------------------------------------


def coo_from_dense(a: np.ndarray) -> COO:
    r, c = np.nonzero(a)
    order = np.lexsort((c, r))
    r, c = r[order], c[order]
    return COO(
        jnp.asarray(r, jnp.int32),
        jnp.asarray(c, jnp.int32),
        jnp.asarray(a[r, c]),
        a.shape,
    )


def coo_from_arrays(
    row: np.ndarray, col: np.ndarray, val: np.ndarray, shape: Tuple[int, int]
) -> COO:
    order = np.lexsort((col, row))
    return COO(
        jnp.asarray(row[order], jnp.int32),
        jnp.asarray(col[order], jnp.int32),
        jnp.asarray(val[order]),
        shape,
    )


class EdgeDelta(NamedTuple):
    """A batch of edge mutations against a row-major COO.

    Each ``(row, col, val)`` triple is an *upsert*: the edge is inserted if
    absent, its value replaced if present — except ``val == 0.0``, which
    removes the edge (removing an absent edge is a no-op). Duplicate
    coordinates within one delta resolve last-write-wins, matching the
    semantics of applying the entries one at a time.
    """

    row: np.ndarray  # [d] int
    col: np.ndarray  # [d] int
    val: np.ndarray  # [d] float

    @property
    def n_edges(self) -> int:
        return int(np.asarray(self.row).shape[0])


class DeltaReport(NamedTuple):
    """Bookkeeping from ``apply_edge_delta``: which rows changed and by how
    many non-zeros — exactly what ``schedule.repair_schedule`` needs to
    update its cached per-row histogram without re-scanning the graph."""

    touched_rows: np.ndarray  # sorted unique rows named by the delta
    row_nnz_delta: np.ndarray  # per touched row, nnz(new) - nnz(old)
    n_added: int
    n_removed: int
    n_updated: int  # value-only overwrites of existing edges


def apply_edge_delta(a: COO, delta: EdgeDelta, *, with_report: bool = False):
    """Apply ``delta`` to a row-major-sorted COO; returns a host-resident
    (numpy-backed) row-major COO — or ``(coo, DeltaReport)`` when
    ``with_report`` is set.

    The merge exploits sortedness end to end: the delta is deduped and
    key-sorted (``O(d log d)``), overwritten/removed base entries are
    masked via a searchsorted probe, and insertions land at searchsorted
    positions via one ``np.insert`` pass per array — ``O(nnz)`` memcpy
    total, never a full lexsort. This is what keeps repeated small deltas
    cheap enough for the serving engine's incremental schedule repair.
    """
    m, n = a.shape
    row = np.asarray(a.row)
    keep = row != PAD_IDX
    col = np.asarray(a.col)
    val = np.asarray(a.val)
    if not keep.all():
        row, col, val = row[keep], col[keep], val[keep]
    drow = np.atleast_1d(np.asarray(delta.row, np.int64))
    dcol = np.atleast_1d(np.asarray(delta.col, np.int64))
    dval = np.atleast_1d(np.asarray(delta.val, val.dtype if val.size else np.float32))
    if not (drow.shape == dcol.shape == dval.shape):
        raise ValueError("EdgeDelta row/col/val shapes differ")
    if drow.size == 0:
        out = COO(row.astype(np.int32), col.astype(np.int32), val, a.shape)
        if with_report:
            z = np.zeros(0, np.int64)
            return out, DeltaReport(z, z.copy(), 0, 0, 0)
        return out
    if drow.min() < 0 or drow.max() >= m or dcol.min() < 0 or dcol.max() >= n:
        raise ValueError(f"EdgeDelta indices out of bounds for shape {a.shape}")
    touched = np.unique(drow)
    dkey = drow * n + dcol
    order = np.argsort(dkey, kind="stable")
    dkey, dval = dkey[order], dval[order]
    last = np.concatenate([dkey[1:] != dkey[:-1], [True]])  # last write wins
    dkey, dval = dkey[last], dval[last]
    key = row.astype(np.int64) * n + col
    # base entries whose coordinate the delta overwrites or removes
    pos = np.searchsorted(dkey, key)
    pos = np.minimum(pos, dkey.size - 1)
    survive = dkey[pos] != key
    # delta coordinates already present in the base
    bpos = np.minimum(np.searchsorted(key, dkey), max(key.size - 1, 0))
    existed = key[bpos] == dkey if key.size else np.zeros(dkey.size, bool)
    ins = dval != 0.0
    if not np.any(ins & ~existed) and not np.any(existed & ~ins):
        # pure value update (plus possibly no-op removals of absent
        # edges): the structure is untouched, so share the coordinate
        # arrays and overwrite values in place of a merge — O(d log nnz),
        # the steady-state cost of weight-only streaming deltas
        upd = ins
        val2 = val.copy()
        val2[bpos[upd]] = dval[upd]
        out = COO(np.asarray(row, np.int32), np.asarray(col, np.int32), val2, a.shape)
        if not with_report:
            return out
        report = DeltaReport(
            touched_rows=touched,
            row_nnz_delta=np.zeros(touched.size, np.int64),
            n_added=0,
            n_removed=0,
            n_updated=int(np.count_nonzero(upd)),
        )
        return out, report
    skey = key[survive]
    ikey = dkey[ins]
    mpos = np.searchsorted(skey, ikey)
    out = COO(
        np.insert(row[survive], mpos, (ikey // n)).astype(np.int32),
        np.insert(col[survive], mpos, (ikey % n)).astype(np.int32),
        np.insert(val[survive], mpos, dval[ins]),
        a.shape,
    )
    if not with_report:
        return out
    change = (ins & ~existed).astype(np.int64) - (~ins & existed)
    per_row = np.zeros(touched.size, np.int64)
    np.add.at(per_row, np.searchsorted(touched, dkey // n), change)
    report = DeltaReport(
        touched_rows=touched,
        row_nnz_delta=per_row,
        n_added=int(np.count_nonzero(ins & ~existed)),
        n_removed=int(np.count_nonzero(~ins & existed)),
        n_updated=int(np.count_nonzero(ins & existed)),
    )
    return out, report


def transpose_coo(a: COO) -> COO:
    """Aᵀ as a fresh row-major-sorted COO (padding entries dropped)."""
    row = np.asarray(a.col)
    col = np.asarray(a.row)
    val = np.asarray(a.val)
    keep = np.asarray(a.row) != PAD_IDX
    return coo_from_arrays(row[keep], col[keep], val[keep], (a.shape[1], a.shape[0]))


def permute_coo(a: COO, perm: np.ndarray) -> COO:
    """``P·A`` as a fresh row-major-sorted host (numpy-backed) COO: row
    ``i`` of the result is row ``perm[i]`` of ``a`` (``perm[new] = old``,
    the ``core.reorder`` convention; padding entries are dropped).

    Only rows move — columns are untouched, so the dense operand of an
    SpMM against the result needs no reordering; output rows come back
    permuted and are un-permuted with the inverse permutation at the
    executor boundary."""
    m, _ = a.shape
    perm = np.asarray(perm, np.int64)
    if perm.shape[0] != m:
        raise ValueError(f"permutation has {perm.shape[0]} entries; A has {m} rows")
    inv = np.full(m, -1, np.int64)
    inv[perm] = np.arange(m, dtype=np.int64)
    if (inv < 0).any():
        raise ValueError("not a permutation: duplicate/missing indices")
    row = np.asarray(a.row)
    col = np.asarray(a.col)
    val = np.asarray(a.val)
    keep = row != PAD_IDX
    if not keep.all():
        row, col, val = row[keep], col[keep], val[keep]
    return coo_from_arrays(inv[row.astype(np.int64)], col, val, a.shape)


def permute_csc(a: CSC, perm: np.ndarray) -> CSC:
    """``P·A`` in CSC: row ids remapped through the permutation and
    re-sorted within each column (same ``perm[new] = old`` convention as
    ``permute_coo``)."""
    return csc_from_coo(permute_coo(csc_to_coo(a), perm))


def _ptr_from_sorted(ids: np.ndarray, dim: int) -> np.ndarray:
    counts = np.bincount(ids, minlength=dim)
    return np.concatenate([[0], np.cumsum(counts)]).astype(np.int32)


def csr_from_coo(a: COO) -> CSR:
    row = np.asarray(a.row)
    col = np.asarray(a.col)
    val = np.asarray(a.val)
    order = np.lexsort((col, row))
    return CSR(
        jnp.asarray(_ptr_from_sorted(row[order], a.shape[0])),
        jnp.asarray(col[order], jnp.int32),
        jnp.asarray(val[order]),
        a.shape,
    )


def csc_from_coo(a: COO) -> CSC:
    row = np.asarray(a.row)
    col = np.asarray(a.col)
    val = np.asarray(a.val)
    order = np.lexsort((row, col))
    return CSC(
        jnp.asarray(_ptr_from_sorted(col[order], a.shape[1])),
        jnp.asarray(row[order], jnp.int32),
        jnp.asarray(val[order]),
        a.shape,
    )


def csc_from_dense(a: np.ndarray) -> CSC:
    return csc_from_coo(coo_from_dense(a))


def csr_from_dense(a: np.ndarray) -> CSR:
    return csr_from_coo(coo_from_dense(a))


def ell_from_dense(a: np.ndarray, width: int | None = None) -> ELL:
    m, _ = a.shape
    per_row = (a != 0).sum(axis=1)
    k = int(per_row.max()) if width is None else width
    idx = np.full((m, k), PAD_IDX, np.int32)
    dat = np.zeros((m, k), a.dtype)
    for i in range(m):
        cols = np.nonzero(a[i])[0][:k]
        idx[i, : len(cols)] = cols
        dat[i, : len(cols)] = a[i, cols]
    return ELL(jnp.asarray(idx), jnp.asarray(dat), a.shape)


# ---------------------------------------------------------------------------
# Conversions back to dense (jit-able; used by oracles/tests)
# ---------------------------------------------------------------------------


def coo_to_dense(a: COO) -> jax.Array:
    m, n = a.shape
    valid = a.row != PAD_IDX
    r = jnp.where(valid, a.row, 0)
    c = jnp.where(valid, a.col, 0)
    v = jnp.where(valid, a.val, 0.0)
    return jnp.zeros((m, n), a.val.dtype).at[r, c].add(v)


def csr_to_coo(a: CSR) -> COO:
    m, _ = a.shape
    row = jnp.asarray(
        np.repeat(np.arange(m, dtype=np.int32), np.diff(np.asarray(a.indptr)))
    )
    return COO(row, a.indices, a.data, a.shape)


def csc_to_coo(a: CSC) -> COO:
    _, n = a.shape
    col = jnp.asarray(
        np.repeat(np.arange(n, dtype=np.int32), np.diff(np.asarray(a.indptr)))
    )
    return COO(a.indices, col, a.data, a.shape)


def csc_to_dense(a: CSC) -> jax.Array:
    return coo_to_dense(csc_to_coo(a))


def csr_to_dense(a: CSR) -> jax.Array:
    return coo_to_dense(csr_to_coo(a))


def ell_to_dense(a: ELL) -> jax.Array:
    m, n = a.shape
    valid = a.indices != PAD_IDX
    c = jnp.where(valid, a.indices, 0)
    v = jnp.where(valid, a.data, 0.0)
    r = jnp.broadcast_to(jnp.arange(m, dtype=jnp.int32)[:, None], a.indices.shape)
    return jnp.zeros((m, n), a.data.dtype).at[r, c].add(v)


# ---------------------------------------------------------------------------
# Padding (static-shape friendliness for jit / pallas)
# ---------------------------------------------------------------------------


def pad_coo(a: COO, capacity: int) -> COO:
    """Pad nnz up to `capacity` with inert entries."""
    nnz = a.nnz
    if capacity < nnz:
        raise ValueError(f"capacity {capacity} < nnz {nnz}")
    pad = capacity - nnz
    return COO(
        jnp.concatenate([a.row, jnp.full((pad,), PAD_IDX, jnp.int32)]),
        jnp.concatenate([a.col, jnp.full((pad,), PAD_IDX, jnp.int32)]),
        jnp.concatenate([a.val, jnp.zeros((pad,), a.val.dtype)]),
        a.shape,
    )


def row_nnz(a: COO, num_rows: int | None = None) -> jax.Array:
    """Non-zeros per row (the workload histogram the paper's profiler tracks)."""
    m = a.shape[0] if num_rows is None else num_rows
    valid = a.row != PAD_IDX
    r = jnp.where(valid, a.row, 0)
    return jnp.zeros((m,), jnp.int32).at[r].add(valid.astype(jnp.int32))


def col_nnz(a: COO, num_cols: int | None = None) -> jax.Array:
    n = a.shape[1] if num_cols is None else num_cols
    valid = a.col != PAD_IDX
    c = jnp.where(valid, a.col, 0)
    return jnp.zeros((n,), jnp.int32).at[c].add(valid.astype(jnp.int32))
