"""Sparse matrix formats for AWB-GCN, in pure JAX.

JAX only ships BCOO; AWB-GCN's column-wise-product SpMM wants CSC (the paper
streams dense B and reuses sparse A per output column), the balanced Pallas
kernel wants a flat nnz-sorted COO ("packed" format), and the PE simulator
wants per-row nnz histograms (CSR-ish). We implement all of them as small
NamedTuples of jnp arrays with static shapes so they jit/shard cleanly.

Conventions
-----------
* All index arrays are int32.
* Padding entries use column/row index ``PAD_IDX == -1`` and value 0.0 so a
  padded SpMM contributes nothing (guarded gathers clamp the index).
* Shapes are static: ``nnz`` is the *padded* nnz capacity.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PAD_IDX = -1


class COO(NamedTuple):
    """Coordinate format, row-major sorted unless stated otherwise."""

    row: jax.Array  # [nnz] int32
    col: jax.Array  # [nnz] int32
    val: jax.Array  # [nnz] float
    shape: Tuple[int, int]

    @property
    def nnz(self) -> int:
        return self.row.shape[0]


class CSR(NamedTuple):
    indptr: jax.Array  # [m+1] int32
    indices: jax.Array  # [nnz] int32 column ids
    data: jax.Array  # [nnz]
    shape: Tuple[int, int]

    @property
    def nnz(self) -> int:
        return self.indices.shape[0]


class CSC(NamedTuple):
    """The paper's format for A: non-zeros contiguous per column."""

    indptr: jax.Array  # [n+1] int32
    indices: jax.Array  # [nnz] int32 row ids
    data: jax.Array  # [nnz]
    shape: Tuple[int, int]

    @property
    def nnz(self) -> int:
        return self.indices.shape[0]


class ELL(NamedTuple):
    """ELLPACK: fixed non-zeros per row, padded. Used by dense-ish operands."""

    indices: jax.Array  # [m, k] int32 column ids, PAD_IDX for padding
    data: jax.Array  # [m, k]
    shape: Tuple[int, int]


# ---------------------------------------------------------------------------
# Construction from dense / scipy-style triplets (host-side, numpy)
# ---------------------------------------------------------------------------

def coo_from_dense(a: np.ndarray) -> COO:
    r, c = np.nonzero(a)
    order = np.lexsort((c, r))
    r, c = r[order], c[order]
    return COO(
        jnp.asarray(r, jnp.int32),
        jnp.asarray(c, jnp.int32),
        jnp.asarray(a[r, c]),
        a.shape,
    )


def coo_from_arrays(row: np.ndarray, col: np.ndarray, val: np.ndarray,
                    shape: Tuple[int, int]) -> COO:
    order = np.lexsort((col, row))
    return COO(
        jnp.asarray(row[order], jnp.int32),
        jnp.asarray(col[order], jnp.int32),
        jnp.asarray(val[order]),
        shape,
    )


def transpose_coo(a: COO) -> COO:
    """Aᵀ as a fresh row-major-sorted COO (padding entries dropped)."""
    row = np.asarray(a.col)
    col = np.asarray(a.row)
    val = np.asarray(a.val)
    keep = np.asarray(a.row) != PAD_IDX
    return coo_from_arrays(row[keep], col[keep], val[keep],
                           (a.shape[1], a.shape[0]))


def _ptr_from_sorted(ids: np.ndarray, dim: int) -> np.ndarray:
    counts = np.bincount(ids, minlength=dim)
    return np.concatenate([[0], np.cumsum(counts)]).astype(np.int32)


def csr_from_coo(a: COO) -> CSR:
    row = np.asarray(a.row)
    col = np.asarray(a.col)
    val = np.asarray(a.val)
    order = np.lexsort((col, row))
    return CSR(
        jnp.asarray(_ptr_from_sorted(row[order], a.shape[0])),
        jnp.asarray(col[order], jnp.int32),
        jnp.asarray(val[order]),
        a.shape,
    )


def csc_from_coo(a: COO) -> CSC:
    row = np.asarray(a.row)
    col = np.asarray(a.col)
    val = np.asarray(a.val)
    order = np.lexsort((row, col))
    return CSC(
        jnp.asarray(_ptr_from_sorted(col[order], a.shape[1])),
        jnp.asarray(row[order], jnp.int32),
        jnp.asarray(val[order]),
        a.shape,
    )


def csc_from_dense(a: np.ndarray) -> CSC:
    return csc_from_coo(coo_from_dense(a))


def csr_from_dense(a: np.ndarray) -> CSR:
    return csr_from_coo(coo_from_dense(a))


def ell_from_dense(a: np.ndarray, width: int | None = None) -> ELL:
    m, _ = a.shape
    per_row = (a != 0).sum(axis=1)
    k = int(per_row.max()) if width is None else width
    idx = np.full((m, k), PAD_IDX, np.int32)
    dat = np.zeros((m, k), a.dtype)
    for i in range(m):
        cols = np.nonzero(a[i])[0][:k]
        idx[i, : len(cols)] = cols
        dat[i, : len(cols)] = a[i, cols]
    return ELL(jnp.asarray(idx), jnp.asarray(dat), a.shape)


# ---------------------------------------------------------------------------
# Conversions back to dense (jit-able; used by oracles/tests)
# ---------------------------------------------------------------------------

def coo_to_dense(a: COO) -> jax.Array:
    m, n = a.shape
    valid = a.row != PAD_IDX
    r = jnp.where(valid, a.row, 0)
    c = jnp.where(valid, a.col, 0)
    v = jnp.where(valid, a.val, 0.0)
    return jnp.zeros((m, n), a.val.dtype).at[r, c].add(v)


def csr_to_coo(a: CSR) -> COO:
    m, _ = a.shape
    row = jnp.asarray(
        np.repeat(np.arange(m, dtype=np.int32), np.diff(np.asarray(a.indptr)))
    )
    return COO(row, a.indices, a.data, a.shape)


def csc_to_coo(a: CSC) -> COO:
    _, n = a.shape
    col = jnp.asarray(
        np.repeat(np.arange(n, dtype=np.int32), np.diff(np.asarray(a.indptr)))
    )
    return COO(a.indices, col, a.data, a.shape)


def csc_to_dense(a: CSC) -> jax.Array:
    return coo_to_dense(csc_to_coo(a))


def csr_to_dense(a: CSR) -> jax.Array:
    return coo_to_dense(csr_to_coo(a))


def ell_to_dense(a: ELL) -> jax.Array:
    m, n = a.shape
    valid = a.indices != PAD_IDX
    c = jnp.where(valid, a.indices, 0)
    v = jnp.where(valid, a.data, 0.0)
    r = jnp.broadcast_to(jnp.arange(m, dtype=jnp.int32)[:, None], a.indices.shape)
    return jnp.zeros((m, n), a.data.dtype).at[r, c].add(v)


# ---------------------------------------------------------------------------
# Padding (static-shape friendliness for jit / pallas)
# ---------------------------------------------------------------------------

def pad_coo(a: COO, capacity: int) -> COO:
    """Pad nnz up to `capacity` with inert entries."""
    nnz = a.nnz
    if capacity < nnz:
        raise ValueError(f"capacity {capacity} < nnz {nnz}")
    pad = capacity - nnz
    return COO(
        jnp.concatenate([a.row, jnp.full((pad,), PAD_IDX, jnp.int32)]),
        jnp.concatenate([a.col, jnp.full((pad,), PAD_IDX, jnp.int32)]),
        jnp.concatenate([a.val, jnp.zeros((pad,), a.val.dtype)]),
        a.shape,
    )


def row_nnz(a: COO, num_rows: int | None = None) -> jax.Array:
    """Non-zeros per row (the workload histogram the paper's profiler tracks)."""
    m = a.shape[0] if num_rows is None else num_rows
    valid = a.row != PAD_IDX
    r = jnp.where(valid, a.row, 0)
    return jnp.zeros((m,), jnp.int32).at[r].add(valid.astype(jnp.int32))


def col_nnz(a: COO, num_cols: int | None = None) -> jax.Array:
    n = a.shape[1] if num_cols is None else num_cols
    valid = a.col != PAD_IDX
    c = jnp.where(valid, a.col, 0)
    return jnp.zeros((n,), jnp.int32).at[c].add(valid.astype(jnp.int32))
