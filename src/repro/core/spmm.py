"""Column-wise-product SpMM (the paper's §III.B execution order), pure JAX.

Given sparse S (m×n) and dense B (n×k):  C = S @ B, computed as
``C[:, j] = sum_c S[:, c] * B[c, j]`` — i.e. every non-zero (r, c, v) of S
contributes ``v * B[c, :]`` to row r of C. In JAX this is a gather of B rows
by the non-zeros' column indices followed by a segment-sum over their row
indices. These functions are the *reference* implementations (oracles for the
Pallas kernel) and the production fallback on non-TPU backends.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import csc as fmt


def spmm_coo(a: fmt.COO, b: jax.Array) -> jax.Array:
    """C = A @ B via column-wise product. Handles PAD_IDX entries."""
    m, n = a.shape
    valid = a.row != fmt.PAD_IDX
    col = jnp.where(valid, a.col, 0)
    row = jnp.where(valid, a.row, 0)
    val = jnp.where(valid, a.val, 0).astype(b.dtype)
    gathered = b[col] * val[:, None]  # [nnz, k] — the broadcast of Eq. (4)
    return jax.ops.segment_sum(gathered, row, num_segments=m)


def spmm_csc(a: fmt.CSC, b: jax.Array) -> jax.Array:
    return spmm_coo(fmt.csc_to_coo(a), b)


def spmm_dense(a_dense: jax.Array, b: jax.Array) -> jax.Array:
    """TDQ-1 path: on the MXU, computing the zeros beats skipping them for
    sparsity < ~99%; used for X·W where X is 'generally sparse'."""
    return a_dense @ b


def spmm_coo_blocked(a: fmt.COO, b: jax.Array, t: int = 4) -> jax.Array:
    """Matrix-blocking variant (paper Fig. 9): process B in t-column panels so
    each block of A is reused t times before eviction. Numerically identical;
    exists so tests can assert the blocked order is safe and benchmarks can
    model the bandwidth win."""
    m, n = a.shape
    k = b.shape[1]
    pad_k = (-k) % t
    bp = jnp.pad(b, ((0, 0), (0, pad_k)))
    panels = bp.reshape(n, (k + pad_k) // t, t).transpose(1, 0, 2)

    def one_panel(panel):  # [n, t]
        return spmm_coo(a, panel)

    out = jax.lax.map(one_panel, panels)  # [k/t, m, t]
    out = out.transpose(1, 0, 2).reshape(m, k + pad_k)
    return out[:, :k]


def gcn_layer_ref(a: fmt.COO, x: jax.Array, w: jax.Array,
                  activation=jax.nn.relu) -> jax.Array:
    """σ(A·(X·W)) with the paper's A×(X×W) ordering (§III.A, Table II)."""
    xw = spmm_dense(x, w)
    axw = spmm_coo(a, xw)
    return activation(axw) if activation is not None else axw


def flops_axw_orders(a_nnz: int, x_shape, w_shape, x_density: float = 1.0):
    """Operation counts for (A×X)×W vs A×(X×W) — reproduces Table II.

    Counts multiply ops on non-zeros only (the paper counts 'operations').
    """
    n_nodes, n_feat = x_shape
    _, n_hid = w_shape
    # (A×X)×W: A (nnz_a) times each of n_feat X columns -> dense (n,n_feat),
    # then dense (n,n_feat)x(n_feat,n_hid)
    ax = a_nnz * n_feat
    axw = n_nodes * n_feat * n_hid
    order1 = ax + axw
    # A×(X×W): sparse X (density) times W, then A times dense (n,n_hid)
    xw = int(n_nodes * n_feat * x_density) * n_hid
    a_xw = a_nnz * n_hid
    order2 = xw + a_xw
    return order1, order2
