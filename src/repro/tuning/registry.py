"""In-process caches: graph fingerprint → schedule / executor.

``core.executor`` used to own these dicts; they live here now so the
executor module is purely the execution machinery and every caching policy
(in-process here, on-disk in ``tuning.store``) sits in one subsystem.

The fingerprint-keyed caches are deliberately unbounded: a serving system
holds a handful of long-lived graphs, and the converged configuration is
exactly what must persist (bounded rotation across *thousands* of graphs is
the serving engine's job — ``serving.gcn_engine`` evicts device-resident
schedules under an LRU byte budget and bypasses these caches). The
identity-keyed per-schedule cache is a bounded LRU — workloads that build
throwaway schedules per call must not retain every one forever.
"""

from __future__ import annotations

import hashlib
from collections import OrderedDict
from typing import Optional, Tuple

import numpy as np

from repro.core import csc as fmt
from repro.core import executor as _exe
from repro.core import reorder as _reorder
from repro.core import schedule as _schedule
from repro.core.executor import (
    ScheduleExecutor,
    ShardedScheduleExecutor,
    _ExecutorBase,
    select_routing,
)
from repro.core.schedule import Schedule


def graph_fingerprint(a: fmt.COO) -> str:
    """Content hash of a sparse operand — the schedule-cache key and the
    graph half of the on-disk store key.

    Hashes shape, true nnz, and the index/value bytes of real (non-PAD)
    entries, so two COOs describing the same matrix — padded or not — map
    to the same converged configuration.
    """
    row = np.asarray(a.row)
    col = np.asarray(a.col)
    val = np.asarray(a.val)
    if (row == fmt.PAD_IDX).any():
        keep = row != fmt.PAD_IDX
        row, col, val = row[keep], col[keep], val[keep]
    h = hashlib.blake2b(digest_size=16)
    h.update(repr((a.shape, int(row.shape[0]))).encode())
    h.update(row.tobytes())
    h.update(col.tobytes())
    h.update(val.tobytes())
    return h.hexdigest()


def delta_fingerprint(parent_fp: str, delta, revision: int) -> str:
    """Chained identity of a streamed graph mutation: the parent's
    fingerprint hashed with the edge delta's bytes and the repair
    generation. O(|delta|) instead of the O(nnz) full-content hash — the
    streaming path's cheap lineage identity for logging and in-memory
    bookkeeping. Two graphs reached by the same delta sequence share it;
    unlike ``graph_fingerprint`` it is *not* content-canonical (different
    delta orders reaching the same matrix hash differently), so on-disk
    store entries keep using the content hash."""
    h = hashlib.blake2b(digest_size=16)
    h.update(parent_fp.encode())
    h.update(repr(int(revision)).encode())
    h.update(np.asarray(delta.row).tobytes())
    h.update(np.asarray(delta.col).tobytes())
    h.update(np.asarray(delta.val).tobytes())
    return h.hexdigest()


def mesh_fingerprint(mesh=None, n_devices: Optional[int] = None):
    """Hashable identity of the requested device mesh — the second half of
    the ``(graph fingerprint, mesh)`` executor-cache key.

    ``None`` (no mesh, no device count) means the plain single-device
    ``ScheduleExecutor``; ``n_devices=1`` is a *distinct* entry (a 1-device
    sharded executor), so single- and multi-device executors coexist in the
    cache. Device ids are part of the key: the same shape on different
    devices is a different placement.
    """
    import jax

    if mesh is None and n_devices is None:
        return None
    if mesh is not None:
        if n_devices is not None and n_devices != mesh.devices.size:
            raise ValueError(
                f"n_devices={n_devices} contradicts the given mesh of "
                f"{mesh.devices.size} device(s); pass one or the other"
            )
        return (
            tuple(mesh.axis_names),
            tuple(mesh.devices.shape),
            tuple(int(d.id) for d in mesh.devices.flat),
        )
    devs = jax.devices()
    if not 1 <= n_devices <= len(devs):
        raise ValueError(
            f"n_devices={n_devices} but this host exposes "
            f"{len(devs)} device(s)"
        )
    devs = devs[:n_devices]
    return (("dev",), (len(devs),), tuple(int(d.id) for d in devs))


def device_fingerprint(device) -> Optional[tuple]:
    """Hashable identity of a single-device placement handle — the third
    leg of the ``(graph fingerprint, mesh, device)`` executor-cache key.
    ``None`` (jax's default placement) stays ``None``, so existing
    un-pinned entries keep their keys; a pinned handle keys by platform +
    device id, letting **same-graph replicas on different devices
    coexist** in the cache instead of the last-built replica evicting the
    others."""
    if device is None:
        return None
    return (str(getattr(device, "platform", "?")), int(device.id))


_SCHEDULE_CACHE: dict = {}
_EXECUTOR_CACHE: dict = {}
_REORDER_CACHE: dict = {}
_EXEC_BY_SCHEDULE: "OrderedDict[tuple, _ExecutorBase]" = OrderedDict()
_EXEC_BY_SCHEDULE_CAP = 32


def clear_caches() -> None:
    """Drop every cached schedule/executor/tuning result (tests; also the
    closest thing to simulating a process restart in-process)."""
    from repro.tuning import runner

    _SCHEDULE_CACHE.clear()
    _EXECUTOR_CACHE.clear()
    _REORDER_CACHE.clear()
    _EXEC_BY_SCHEDULE.clear()
    _exe._DEVICE_STEPS.clear()
    runner._AUTOTUNE_CACHE.clear()


def _sched_key(
    fp: str,
    nnz_per_step,
    rows_per_window,
    cols_per_block,
    window_nnz,
    balanced,
    reorder="none",
):
    return (
        fp,
        nnz_per_step,
        rows_per_window,
        str(cols_per_block),
        window_nnz,
        balanced,
        reorder,
    )


def get_reorder(a: fmt.COO, strategy: str, fingerprint: Optional[str] = None):
    """Fingerprint-cached ``(perm, inv)`` for one reorder strategy
    (``core.reorder``) — the permutation is a pure function of graph
    content, so every schedule/executor variant of a graph shares one
    computation. ``(None, None)`` for ``"none"``."""
    if strategy == _reorder.REORDER_NONE:
        return None, None
    fp = fingerprint or graph_fingerprint(a)
    key = (fp, strategy)
    pair = _REORDER_CACHE.get(key)
    if pair is None:
        pair = _reorder.permutation(a, strategy)
        _REORDER_CACHE[key] = pair
    return pair


def adopt_reorder(fingerprint: str, strategy: str, perm: np.ndarray) -> None:
    """Seed the reorder cache with a store entry's persisted permutation,
    so the adopted schedule and the executor's un-permute stay consistent
    even when a fresh recompute would order ties differently (a repaired
    permutation persisted by serving is one such case — any valid
    permutation consistent with the adopted schedule is correct)."""
    if strategy == _reorder.REORDER_NONE or perm is None:
        return
    inv = _reorder.invert_permutation(perm)
    _REORDER_CACHE.setdefault(
        (fingerprint, strategy), (np.asarray(perm, np.int32), inv)
    )


def release_graph(fingerprint: str) -> None:
    """Drop every cached schedule/executor of one graph.

    The fingerprint caches are deliberately unbounded for long-lived
    serving graphs; a caller that sweeps *many* configurations of a graph
    it will not serve through the registry (the serving engine's cold
    autotune measures ~a dozen device-resident candidate executors) calls
    this afterwards so the sweep's uploads don't pin device memory
    forever."""
    for key in [k for k in _SCHEDULE_CACHE if k[0] == fingerprint]:
        # also drop the schedule's memoized device step arrays (one-hot
        # executors share them through the executor module's LRU)
        _exe.release_device_steps(_SCHEDULE_CACHE.pop(key))
    for key in [k for k in _EXECUTOR_CACHE if k[0][0] == fingerprint]:
        del _EXECUTOR_CACHE[key]
    for key in [k for k in _REORDER_CACHE if k[0] == fingerprint]:
        del _REORDER_CACHE[key]


def get_schedule(
    a: fmt.COO,
    *,
    nnz_per_step: int = 256,
    rows_per_window: int = 64,
    cols_per_block=None,
    window_nnz: Optional[int] = None,
    balanced: bool = True,
    reorder: str = "none",
    fingerprint: Optional[str] = None,
) -> Schedule:
    """Fingerprint-cached schedule build — the 'reuse the converged
    configuration' entry point.

    ``reorder`` selects a locality row remapping (``core.reorder``): the
    schedule is built on the row-permuted graph, and the matching executor
    (``get_executor`` with the same ``reorder``) un-permutes outputs so
    callers see original row order."""
    fp = fingerprint or graph_fingerprint(a)
    key = _sched_key(
        fp, nnz_per_step, rows_per_window, cols_per_block, window_nnz, balanced, reorder
    )
    sched = _SCHEDULE_CACHE.get(key)
    if sched is None:
        if reorder != _reorder.REORDER_NONE:
            perm, _ = get_reorder(a, reorder, fingerprint=fp)
            a = fmt.permute_coo(a, perm)
        if balanced:
            sched = _schedule.build_balanced_schedule(
                a,
                nnz_per_step,
                rows_per_window,
                cols_per_block=cols_per_block,
                window_nnz=window_nnz,
            )
        else:
            sched = _schedule.build_naive_schedule(
                a, nnz_per_step, rows_per_window, cols_per_block=cols_per_block
            )
        _SCHEDULE_CACHE[key] = sched
    return sched


def adopt_schedule(fingerprint: str, cfg, sched: Schedule) -> None:
    """Seed the schedule cache with a deserialized store entry, so the
    subsequent ``get_executor(a, **cfg.as_executor_kwargs())`` is a pure
    cache hit — **zero** ``build_balanced_schedule`` calls on the
    warm-start path."""
    key = _sched_key(
        fingerprint,
        cfg.nnz_per_step,
        cfg.rows_per_window,
        cfg.cols_per_block,
        cfg.window_nnz,
        True,
        getattr(cfg, "reorder", "none"),
    )
    _SCHEDULE_CACHE.setdefault(key, sched)


def get_spmm_schedules(
    a: fmt.COO,
    *,
    nnz_per_step: int = 256,
    rows_per_window: int = 64,
    cols_per_block=None,
) -> Tuple[Schedule, Schedule]:
    """(schedule for A, schedule for Aᵀ), both fingerprint-cached — what a
    differentiable SpMM needs (d(A@B)/dB = Aᵀ @ dC). Call sites stop
    rebuilding both schedules per invocation."""
    fwd = get_schedule(
        a,
        nnz_per_step=nnz_per_step,
        rows_per_window=rows_per_window,
        cols_per_block=cols_per_block,
    )
    a_t = fmt.transpose_coo(a)
    bwd = get_schedule(
        a_t,
        nnz_per_step=nnz_per_step,
        rows_per_window=rows_per_window,
        cols_per_block=cols_per_block,
    )
    return fwd, bwd


def _placement_key(mesh, n_devices, device):
    """(mesh fingerprint, device fingerprint) with the combination rules:
    ``device`` pins a single-device executor, so it contradicts a mesh."""
    if device is not None and (mesh is not None or n_devices is not None):
        raise ValueError(
            "device= pins a single-device executor to one placement; it "
            "cannot be combined with n_devices/mesh"
        )
    return mesh_fingerprint(mesh, n_devices), device_fingerprint(device)


def get_executor(
    a: fmt.COO,
    *,
    nnz_per_step: int = 256,
    rows_per_window: int = 64,
    cols_per_block=None,
    window_nnz: Optional[int] = None,
    ktile: int = 128,
    routing: Optional[str] = None,
    balanced: bool = True,
    bf16_accumulate: bool = False,
    n_devices: Optional[int] = None,
    mesh=None,
    device=None,
    reorder: str = "none",
) -> _ExecutorBase:
    """Fingerprint-cached executor: the first call converges (builds the
    schedule, uploads it); every later call with the same graph + config is
    a pure cache hit — no rebuild, no host→device transfer.

    Pass ``n_devices`` (or a 1-D ``mesh``) for a ``ShardedScheduleExecutor``
    whose schedule shards live one-per-device, or ``device`` (a
    ``jax.Device``) for a ``ScheduleExecutor`` pinned to one mesh device.
    The cache keys on ``(graph fingerprint, mesh, device)``, so single-,
    multi-device, and per-replica executors of the same graph coexist.
    """
    fp = graph_fingerprint(a)
    mkey, dkey = _placement_key(mesh, n_devices, device)
    key = (
        _sched_key(
            fp,
            nnz_per_step,
            rows_per_window,
            cols_per_block,
            window_nnz,
            balanced,
            reorder,
        ),
        ktile,
        routing,
        bf16_accumulate,
        mkey,
        dkey,
    )
    ex = _EXECUTOR_CACHE.get(key)
    if ex is None:
        sched = get_schedule(
            a,
            nnz_per_step=nnz_per_step,
            rows_per_window=rows_per_window,
            cols_per_block=cols_per_block,
            window_nnz=window_nnz,
            balanced=balanced,
            reorder=reorder,
            fingerprint=fp,
        )
        _, inv = get_reorder(a, reorder, fingerprint=fp)
        if mkey is None:
            ex = ScheduleExecutor(
                sched,
                ktile=ktile,
                routing=routing,
                bf16_accumulate=bf16_accumulate,
                device=device,
                row_unperm=inv,
            )
        else:
            ex = ShardedScheduleExecutor(
                sched,
                n_devices=n_devices,
                mesh=mesh,
                ktile=ktile,
                routing=routing,
                bf16_accumulate=bf16_accumulate,
                row_unperm=inv,
            )
        _EXECUTOR_CACHE[key] = ex
    return ex


def executor_for_schedule(
    sched: Schedule,
    *,
    ktile: int = 128,
    routing: Optional[str] = None,
    bf16_accumulate: bool = False,
    n_devices: Optional[int] = None,
    mesh=None,
    device=None,
) -> _ExecutorBase:
    """Executor for a caller-built schedule, memoized per (schedule
    instance, ktile, routing, mesh, device) — identity-keyed, so
    rebuilding a schedule re-uploads while reusing one doesn't, and
    asking for a different routing/ktile/mesh/device never returns a
    mismatched cached executor."""
    routing = routing or select_routing(
        sched.nnz_per_step, sched.cols_per_block, sched.rows_per_window, ktile
    )
    mkey, dkey = _placement_key(mesh, n_devices, device)
    key = (id(sched), ktile, routing, bf16_accumulate, mkey, dkey)
    ex = _EXEC_BY_SCHEDULE.get(key)
    if ex is not None and ex.sched is sched:
        _EXEC_BY_SCHEDULE.move_to_end(key)
        return ex
    if mkey is None:
        ex = ScheduleExecutor(
            sched,
            ktile=ktile,
            routing=routing,
            bf16_accumulate=bf16_accumulate,
            device=device,
        )
    else:
        ex = ShardedScheduleExecutor(
            sched,
            n_devices=n_devices,
            mesh=mesh,
            ktile=ktile,
            routing=routing,
            bf16_accumulate=bf16_accumulate,
        )
    _EXEC_BY_SCHEDULE[key] = ex
    if len(_EXEC_BY_SCHEDULE) > _EXEC_BY_SCHEDULE_CAP:
        _EXEC_BY_SCHEDULE.popitem(last=False)
    return ex
