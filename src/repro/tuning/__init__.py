"""The tuning subsystem: converge once, persist, reuse forever.

AWB-GCN's engine "after converging, reuses the ideal configuration" (§IV).
This package owns everything between a raw graph and a converged,
device-resident executor:

* ``space``    — the candidate search space the measured sweep explores
  (nnz_per_step × rows_per_window × cols_per_block × ktile × bf16
  accumulate, plus sharded variants), and ``TunedConfig``, the converged
  artifact.
* ``runner``   — the measured autotune loop: prune obviously-unbalanced
  candidates with the paper's cycle model, time the survivors' jitted
  executors, attach the f32-vs-bf16 error report, persist the winner.
* ``store``    — the persistent on-disk store: ``TunedConfig`` + prebuilt
  schedule arrays under ``~/.cache`` (or ``$REPRO_TUNING_STORE``), keyed by
  (graph fingerprint, device kind, mesh, code version), atomic writes,
  corrupted entries fall back to re-tuning.
* ``registry`` — the in-process caches (fingerprint → schedule / executor /
  tuned config) that ``core.executor`` delegated here.
"""
from repro.tuning.registry import (  # noqa: F401
    clear_caches,
    executor_for_schedule,
    get_executor,
    get_schedule,
    get_spmm_schedules,
    graph_fingerprint,
    mesh_fingerprint,
)
from repro.tuning.runner import (  # noqa: F401
    autotune,
    autotuned_executor,
    time_call,
    warm_tuned_executor,
)
from repro.tuning.space import (  # noqa: F401
    TunedConfig,
    default_sweep,
    density_matched_k,
    sharded_device_counts,
    sharded_sweep,
)
from repro.tuning.store import TuningStore, mesh_descriptor  # noqa: F401
