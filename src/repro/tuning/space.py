"""The autotune candidate space and its converged artifact (``TunedConfig``).

A candidate is a plain dict with the executor-configuration axes the sweep
explores:

    nnz_per_step, rows_per_window, cols_per_block, window_nnz, routing,
    and optionally ktile, bf16_accumulate, n_devices.

``default_sweep`` spans the single-device space — the gather path at a few
step granularities, capped one-hot points with density-matched K, **ktile**
variants (the kernel's k-tile width), and **bf16-accumulate** twins of the
strongest gather geometries (ROADMAP "Autotune breadth"). ``sharded_sweep``
adds multi-device gather candidates at power-of-two device counts.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

import numpy as np

from repro.core import csc as fmt
from repro.core.executor import GATHER, ONEHOT
from repro.core.schedule import auto_cols_per_block

DEFAULT_KTILE = 128
#: ktile widths the sweep explores. On the XLA executor twin ktile only
#: steers the routing cost model; on TPU it is the Pallas kernel's k-tile,
#: so the sweep carries it through to ``TunedConfig`` for the kernel path.
KTILE_CANDIDATES = (64, 128)


@dataclasses.dataclass(frozen=True)
class TunedConfig:
    """A measured-fastest executor configuration for one (graph, width).

    ``cols_per_block`` holds the sweep candidate's *request* verbatim
    (None | int | "auto") so ``get_executor(**as_executor_kwargs())``
    reproduces exactly the measured executor; ``cols_per_block_resolved``
    is the block width the schedule actually used. ``n_devices`` is None
    for the single-device executor and a device count for the sharded
    one (sharded candidates enter the sweep whenever the host exposes a
    multi-device mesh). ``bf16_accumulate`` selects the reduced-precision
    accumulation path; ``bf16_max_err`` reports max |f32 − bf16| of the
    winning geometry on the tuning probe (attached by the runner whether
    or not the bf16 twin won). ``reorder`` is the locality row-remapping
    strategy the sweep accepted (``"none" | "degree" | "island"``,
    ``core.reorder``); the executor un-permutes outputs so any accepted
    value is numerically invisible to callers."""

    nnz_per_step: int
    rows_per_window: int
    cols_per_block: Union[int, str, None]
    window_nnz: Optional[int]
    ktile: int
    routing: str
    measured_us: float
    utilization: float
    cols_per_block_resolved: int = 0
    n_devices: Optional[int] = None
    bf16_accumulate: bool = False
    bf16_max_err: Optional[float] = None
    reorder: str = "none"

    def as_executor_kwargs(self) -> dict:
        return dict(
            nnz_per_step=self.nnz_per_step,
            rows_per_window=self.rows_per_window,
            cols_per_block=self.cols_per_block,
            window_nnz=self.window_nnz,
            ktile=self.ktile,
            routing=self.routing,
            n_devices=self.n_devices,
            bf16_accumulate=self.bf16_accumulate,
            reorder=self.reorder,
        )

    def as_schedule_kwargs(self) -> dict:
        """The schedule-geometry subset — what ``get_schedule`` needs to
        reproduce (or cache-seed) the winning schedule."""
        return dict(
            nnz_per_step=self.nnz_per_step,
            rows_per_window=self.rows_per_window,
            cols_per_block=self.cols_per_block,
            window_nnz=self.window_nnz,
            reorder=self.reorder,
        )


def candidate_executor_kwargs(cand: dict, default_ktile: int = DEFAULT_KTILE) -> dict:
    """Normalize a sweep candidate into ``get_executor`` keyword arguments
    (optional axes fall back to their defaults)."""
    return dict(
        nnz_per_step=cand["nnz_per_step"],
        rows_per_window=cand["rows_per_window"],
        cols_per_block=cand["cols_per_block"],
        window_nnz=cand["window_nnz"],
        routing=cand["routing"],
        ktile=cand.get("ktile", default_ktile),
        bf16_accumulate=cand.get("bf16_accumulate", False),
        n_devices=cand.get("n_devices"),
        reorder=cand.get("reorder", "none"),
    )


def density_matched_k(a: fmt.COO, rows_per_window: int, cols_per_block: int) -> int:
    """nnz_per_step for a capped one-hot schedule: the expected non-zero
    count of one (rows_per_window × cols_per_block) tile, rounded to a
    power of two ≥ 8 — each (window, block) step then carries ~K real
    slots instead of fragmenting."""
    m, n = a.shape
    nnz = int(np.asarray(a.row).shape[0])
    expect = max(1.0, nnz / m * rows_per_window * cols_per_block / n)
    return max(8, int(2 ** np.round(np.log2(expect))))


def default_sweep(
    a: fmt.COO,
    rows_per_window=(32, 64),
    ktiles=KTILE_CANDIDATES,
    include_bf16: bool = True,
) -> list:
    """Single-device candidate points.

    Gather-path geometries at a few step granularities × the ktile axis,
    bf16-accumulate twins of every widest-ktile gather point, locality
    **reorder** twins (``core.reorder``: degree / island row remapping —
    the cycle-model pruner drops the ones whose gather locality does not
    beat the identity order before anything is timed), plus capped one-hot
    points whose nnz_per_step is density-matched
    (≈ nnz/m · r · cb / n rounded to a lane multiple)."""
    m, n = a.shape
    cand = []
    for k in (128, 256):
        for r in rows_per_window:
            for kt in ktiles:
                cand.append(
                    dict(
                        nnz_per_step=k,
                        rows_per_window=r,
                        cols_per_block=None,
                        window_nnz=None,
                        routing=GATHER,
                        ktile=kt,
                    )
                )
            if include_bf16:
                cand.append(
                    dict(
                        nnz_per_step=k,
                        rows_per_window=r,
                        cols_per_block=None,
                        window_nnz=None,
                        routing=GATHER,
                        ktile=max(ktiles),
                        bf16_accumulate=True,
                    )
                )
            for strat in ("degree", "island"):
                cand.append(
                    dict(
                        nnz_per_step=k,
                        rows_per_window=r,
                        cols_per_block=None,
                        window_nnz=None,
                        routing=GATHER,
                        ktile=max(ktiles),
                        reorder=strat,
                    )
                )
    cb = auto_cols_per_block(n)
    if cb < n:
        for r in rows_per_window:
            cand.append(
                dict(
                    nnz_per_step=density_matched_k(a, r, cb),
                    rows_per_window=r,
                    cols_per_block="auto",
                    window_nnz=None,
                    routing=ONEHOT,
                )
            )
    return cand


#: minimum-work thresholds below which a sharded candidate cannot win: the
#: psum of [m, kdim] partials plus per-device dispatch overhead dwarfs the
#: saved gather work on small graphs (BENCH_spmm.json's
#: ``sharded_spmm/powerlaw3000`` ran at 0.06–0.23× of single-device at 35K
#: nnz before this gate existed).
MIN_SHARDED_NNZ = 200_000
MIN_SHARDED_STEPS_PER_DEVICE = 64


def sharded_worth_it(a: fmt.COO, n_devices: int, nnz_per_step: int = 256) -> bool:
    """Whether a sharded candidate at ``n_devices`` clears the minimum-work
    thresholds for this graph: enough total nnz that the cross-device psum
    can pay for itself, and enough schedule steps that every device gets a
    meaningful shard. Perf-elective sharding (the autotune sweep) consults
    this; *byte-forced* sharding — a graph that simply does not fit one
    device's budget — must not (and does not)."""
    row = np.asarray(a.row)
    nnz = int(np.count_nonzero(row != fmt.PAD_IDX))
    if nnz < MIN_SHARDED_NNZ:
        return False
    steps = -(-nnz // nnz_per_step)
    return steps >= n_devices * MIN_SHARDED_STEPS_PER_DEVICE


def sharded_device_counts(max_devices: Optional[int] = None) -> Tuple[int, ...]:
    """Device counts the sharded sweep covers: powers of two in
    (1, available], capped at ``max_devices``. Empty on a single-device
    host — the sweep then degenerates to the single-device candidates."""
    import jax

    n_avail = len(jax.devices())
    cap = n_avail if max_devices is None else min(max_devices, n_avail)
    counts = []
    d = 2
    while d <= cap:
        counts.append(d)
        d *= 2
    return tuple(counts)


def sharded_sweep(
    a: fmt.COO, device_counts: tuple, rows_per_window=(32, 64), *, force: bool = False
) -> list:
    """Sharded-executor candidates: the gather path at each device count
    (one-hot shards identically but is never competitive off-TPU, and on
    TPU the kernel sweep covers it).

    Device counts that fail ``sharded_worth_it`` are dropped — a graph
    that fits one device never even fields a sharded candidate. ``force``
    skips that gate for byte-forced sharding (the serving engine's
    over-budget admission route, where single-device is not an option)."""
    cand = []
    for d in device_counts:
        if not force and not sharded_worth_it(a, d):
            continue
        for r in rows_per_window:
            cand.append(
                dict(
                    nnz_per_step=256,
                    rows_per_window=r,
                    cols_per_block=None,
                    window_nnz=None,
                    routing=GATHER,
                    n_devices=d,
                )
            )
    return cand
