"""The measured autotune loop (paper Fig. 17/18, wall-clock objective).

``autotune`` sweeps the candidate space from ``tuning.space``, prunes
obviously-unbalanced candidates with the paper's cycle model
(``core.autotuner.converged_utilization`` — §IV's converged configuration
sets the achievable-cycles floor) extended with a gather-locality estimate
(``core.reorder.schedule_locality`` — a row remapping whose locality does
not beat the identity order cannot pay for itself and is skipped before
timing), measures each survivor's jitted device-resident executor on a
random probe operand, attaches an f32-vs-bf16 max-error report to the
winner, and caches it — in-process by graph fingerprint, and on disk
through a ``tuning.store.TuningStore`` when one is passed (reorder winners
persist their row permutation alongside the schedule), so the *next
process* skips the sweep entirely.
"""

from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Callable, List, Optional, Tuple

import numpy as np

from repro.core import autotuner
from repro.core import csc as fmt
from repro.core import reorder as _reorder
from repro.core.executor import ONEHOT, _ExecutorBase
from repro.tuning import registry
from repro.tuning.space import (
    TunedConfig,
    candidate_executor_kwargs,
    default_sweep,
    sharded_device_counts,
    sharded_sweep,
)
from repro.tuning.store import TuningStore, mesh_descriptor

_AUTOTUNE_CACHE: dict = {}

#: pruning slack: a candidate is timed unless its locality-scaled cost
#: exceeds ``slack ×`` the larger of (best candidate's cost, the
#: paper-model converged-cycles floor). Generous by design — the pruner
#: must only drop *obviously*-unbalanced points, never the measured winner.
PRUNE_SLACK = 4.0

#: the §IV design the cycle-model floor runs: 1-hop smoothing + remote
#: switching + evil-row remapping (design "C" — what converged hardware
#: achieves without dataset-specific hop tuning).
PRUNE_DESIGN = autotuner.DesignConfig(
    "prune", smoothing_hops=1, remote_switching=True, row_remapping=True
)


#: measurement rounds per ``autotune`` — every candidate is timed once per
#: round, interleaved, and its minimum is kept (see the loop in
#: ``autotune`` for why sequential one-shot timing is not trustworthy)
AUTOTUNE_ROUNDS = 3

#: a reordered candidate must beat the best identity-order candidate by
#: this fraction to win the sweep. Adopting a permutation is not free —
#: the engine maintains a permuted twin across graph updates, the store
#: persists the permutation, and every spmm pays the un-permute epilogue
#: — so a within-noise "win" must resolve to identity, not to whichever
#: candidate got the luckier minimum
REORDER_MARGIN = 0.02


def time_call(
    fn: Callable[[], "jax.Array"],  # noqa: F821
    iters: int,
    warmup: int,
) -> float:
    """Mean wall-clock microseconds of ``fn`` over ``iters`` calls."""
    for _ in range(warmup):
        fn().block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn()
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def measure_candidate(ex: _ExecutorBase, b, iters: int, warmup: int) -> float:
    """Measured microseconds per spmm of one candidate's executor. The
    seam tests intercept to prove the warm-start path runs zero sweeps."""
    return time_call(lambda: ex.spmm(b), iters, warmup)


def _locality_cost(issued: float, locality: float) -> float:
    """Cycle-model cost of one candidate: issued slots scaled by the gather
    locality estimate. Locality is distinct-lines-per-slot in [1/16, 1]; a
    slot whose line is already resident costs far less than a miss, so cost
    interpolates between half price (perfect reuse) and full price (every
    slot a distinct line). Deliberately mild — ranking reorder variants is
    the pruner's job, the measured sweep decides the winner."""
    return issued * (0.5 + 0.5 * locality)


def _dominance_key(cand: dict) -> tuple:
    """The geometry identity a reorder candidate competes against: same
    schedule geometry + routing + device count, any ktile (locality and
    issued slots do not depend on ktile)."""
    return (
        cand["nnz_per_step"],
        cand["rows_per_window"],
        str(cand["cols_per_block"]),
        cand["window_nnz"],
        cand["routing"],
        cand.get("n_devices"),
    )


def prune_sweep(
    a: fmt.COO,
    cands: List[dict],
    *,
    slack: float = PRUNE_SLACK,
    design: Optional[autotuner.DesignConfig] = None,
    fingerprint: Optional[str] = None,
    verbose: bool = True,
) -> Tuple[List[dict], int]:
    """Skip timing candidates the paper's cycle model already condemns.

    On this TPU realization cycles ∝ issued slots (steps run sequentially;
    ``Schedule.utilization`` docs) scaled by gather locality (a resident
    cache line costs less than a miss — ``_locality_cost``). The floor is
    ``nnz / u*`` where ``u*`` is the §IV autotuner's *converged*
    utilization (``converged_utilization`` with remote switching + row
    remapping) at the PE count the best candidate's window partition
    emulates — what balanced hardware could achieve on this degree
    distribution — scaled by the sweep's best locality so a well-clustered
    sweep is not condemned against an unscaled floor. Candidates needing
    more than ``slack ×`` max(best candidate, floor) cost are obviously
    unbalanced and skipped before any jit/timing.

    Reorder candidates face one extra test: a row remapping is *hopeless*
    when its model cost (issued slots × locality) is no better than the
    matching identity-order candidate's — first-fit window packing depends
    on row order, so a permutation can win by packing fewer steps or by
    improving gather locality, but one that improves neither costs a
    permutation and buys nothing. Those are dropped without being timed.
    The pruned count is always logged — no silent caps.
    Returns (kept candidates, n_pruned).
    """
    if len(cands) <= 1:
        return cands, 0
    fp = fingerprint or registry.graph_fingerprint(a)
    issued = []
    locality = []
    for cand in cands:
        sched = registry.get_schedule(
            a,
            nnz_per_step=cand["nnz_per_step"],
            rows_per_window=cand["rows_per_window"],
            cols_per_block=cand["cols_per_block"],
            window_nnz=cand["window_nnz"],
            reorder=cand.get("reorder", "none"),
            fingerprint=fp,
        )
        issued.append(sched.issued_slots)
        locality.append(_reorder.schedule_locality(sched))

    # hopeless-permutation drop: a row remapping can win on two axes —
    # gather locality, and issued slots (first-fit window packing depends
    # on row order, so a permutation that clusters heavy rows packs fewer
    # steps). A reorder candidate whose model cost (issued × locality,
    # ``_locality_cost``) is no better than the matching identity-order
    # candidate's is dominated on both and cannot win — it costs a
    # permutation and buys nothing — so it is dropped without being timed.
    cand_cost = [
        _locality_cost(s, loc) for s, loc in zip(issued, locality)
    ]
    ident_cost = {
        _dominance_key(c): cost
        for c, cost in zip(cands, cand_cost)
        if c.get("reorder", "none") == "none"
    }
    hopeless = [
        c.get("reorder", "none") != "none"
        and _dominance_key(c) in ident_cost
        and cost >= ident_cost[_dominance_key(c)]
        for c, cost in zip(cands, cand_cost)
    ]

    m = a.shape[0]
    row = np.asarray(a.row)
    if (row == fmt.PAD_IDX).any():
        row = row[row != fmt.PAD_IDX]
    row_nnz = np.bincount(row, minlength=m).astype(np.float64)
    nnz = float(row.shape[0])

    costs = cand_cost
    best_i = int(np.argmin(costs))
    n_pe = max(1, -(-m // cands[best_i]["rows_per_window"]))
    u_star, _ = autotuner.converged_utilization(
        row_nnz, n_pe, design or PRUNE_DESIGN, n_rounds=8
    )
    floor_slots = _locality_cost(nnz / max(u_star, 1e-9), min(locality))
    threshold = slack * max(costs[best_i], floor_slots)

    kept = [
        c
        for c, cost, hop in zip(cands, costs, hopeless)
        if cost <= threshold and not hop
    ]
    n_pruned = len(cands) - len(kept)
    n_hopeless = int(sum(hopeless))
    if verbose:
        print(
            f"[autotune] cycle-model pruning: {n_pruned}/{len(cands)} "
            f"candidates skipped ({n_hopeless} locality-dominated "
            f"reorderings; converged-model floor {floor_slots:.0f} cost at "
            f"{n_pe} PEs, u*={u_star:.2f}, slack {slack:g}x, best "
            f"candidate cost {costs[best_i]:.0f})"
        )
    return kept, n_pruned


def _sweep_key(sweep: Optional[list]):
    return None if sweep is None else tuple(
        tuple(sorted(c.items())) for c in sweep
    )


def store_key(
    store: TuningStore,
    fingerprint: str,
    kdim: int,
    *,
    max_devices: Optional[int] = None,
    sweep: Optional[list] = None,
    include_onehot: bool = False,
    ktile: int = 128,
    allow_bf16: bool = False,
    revision: int = 0,
    **_ignored,
) -> str:
    """The on-disk key ``autotune`` files its result under.

    Non-default sweeps tune a *different* objective, so their identity is
    folded into the graph half of the key — a restricted sweep's winner
    never masquerades as the full sweep's, and an ``allow_bf16`` run's
    winner never reaches a default (f32-only) caller. ``revision`` is the
    streaming repair generation passed through to ``TuningStore.key``.
    Extra keyword arguments are accepted and ignored so a whole
    ``autotune``-kwargs dict can be passed through (the serving engine
    does)."""
    fp_store = fingerprint
    sk = _sweep_key(sweep)
    if sk is not None or include_onehot or ktile != 128 or allow_bf16:
        extra = hashlib.blake2b(
            repr((sk, include_onehot, ktile, allow_bf16)).encode(),
            digest_size=8,
        ).hexdigest()
        fp_store = f"{fingerprint}:{extra}"
    return store.key(
        fp_store, kdim, mesh=mesh_descriptor(max_devices), revision=revision
    )


def _winning_perm(
    a: fmt.COO, cfg: TunedConfig, fingerprint: str
) -> Optional[np.ndarray]:
    """The row permutation a store entry for ``cfg`` must carry (None for
    the identity order)."""
    if cfg.reorder == "none":
        return None
    perm, _ = registry.get_reorder(a, cfg.reorder, fingerprint=fingerprint)
    return perm


def _bf16_report(a: fmt.COO, best: TunedConfig, b) -> TunedConfig:
    """Attach max |f32 − bf16| of the winning geometry on the probe operand
    (computed whether or not the bf16 twin won the sweep).

    The twin of the winner is a **throwaway** executor — built directly,
    never cached — so the report doesn't double the winner's resident
    footprint in the registry for every tuned graph."""
    import jax.numpy as jnp

    from repro.core.executor import ScheduleExecutor, ShardedScheduleExecutor

    # the winner stays in the registry (it is what gets served); its
    # opposite-precision twin is built directly and garbage-collected
    out_base = registry.get_executor(a, **best.as_executor_kwargs()).spmm(b)
    sched = registry.get_schedule(a, **best.as_schedule_kwargs())
    _, inv = registry.get_reorder(a, best.reorder)
    twin_kw = dict(
        ktile=best.ktile,
        routing=best.routing,
        bf16_accumulate=not best.bf16_accumulate,
        row_unperm=inv,
    )
    if best.n_devices is None:
        twin = ScheduleExecutor(sched, **twin_kw)
    else:
        twin = ShardedScheduleExecutor(
            sched, n_devices=best.n_devices, **twin_kw
        )
    out_twin = twin.spmm(b)
    err = float(
        jnp.max(
            jnp.abs(
                out_base.astype(jnp.float32) - out_twin.astype(jnp.float32)
            )
        )
    )
    return dataclasses.replace(best, bf16_max_err=err)


def autotune(
    a: fmt.COO,
    b_shape: Tuple[int, ...],
    *,
    sweep: Optional[list] = None,
    ktile: int = 128,
    iters: int = 3,
    warmup: int = 1,
    rounds: Optional[int] = None,
    seed: int = 0,
    include_onehot: bool = False,
    max_devices: Optional[int] = None,
    prune: bool = True,
    prune_slack: float = PRUNE_SLACK,
    allow_bf16: bool = False,
    bf16_report: bool = True,
    store: Optional[TuningStore] = None,
) -> TunedConfig:
    """Measure the sweep's jitted executors on a random dense operand of
    ``b_shape`` and cache the fastest config by graph fingerprint.

    ``b_shape`` is (n, kdim) (only kdim matters for the cache key). One-hot
    candidates are skipped off-TPU unless ``include_onehot`` — the scan
    emulation is measurable but never competitive on CPU. When the host
    exposes more than one device the default sweep additionally measures
    the **sharded** executor at power-of-two device counts (capped by
    ``max_devices`` and by ``space.sharded_worth_it`` — a graph that fits
    one device never fields a sharded candidate); explicit ``sweep``
    candidates may carry their own ``n_devices``, ``ktile``,
    ``bf16_accumulate``, and ``reorder``.

    The default sweep includes locality **reorder** twins (degree/island
    row remapping, ``core.reorder``) of the gather geometries; the axis is
    accept-or-reject — a permutation wins only by measuring faster than
    the best identity candidate by ``REORDER_MARGIN``, and the pruner
    drops ones whose locality estimate cannot pay. Candidates are timed
    in ``rounds`` interleaved passes (default ``AUTOTUNE_ROUNDS``) and
    each keeps its minimum, so slow timing drift between candidates
    cancels instead of deciding the winner. bf16
    candidates enter the timed competition only with ``allow_bf16=True`` —
    a numerics change must be an explicit caller decision, never a
    timing-noise outcome. By default the winner's bf16 twin is evaluated
    for the ``bf16_max_err`` report only.

    ``store`` makes the result durable: a hit deserializes the winning
    config, schedule, *and row permutation* (zero sweeps, zero rebuilds —
    the restart path), a miss measures and persists. ``prune`` skips
    timing candidates the cycle model rules out (see ``prune_sweep``).
    """
    import jax
    import jax.numpy as jnp

    kdim = int(b_shape[-1])
    rounds = AUTOTUNE_ROUNDS if rounds is None else max(1, int(rounds))
    fp = registry.graph_fingerprint(a)
    # every argument that can change the result is part of the key — a
    # later call with different measurement/pruning/report settings must
    # re-run, not inherit a stale answer
    key = (
        fp,
        kdim,
        ktile,
        include_onehot,
        iters,
        warmup,
        rounds,
        seed,
        _sweep_key(sweep),
        max_devices,
        len(jax.devices()),
        prune,
        prune_slack,
        allow_bf16,
        bf16_report,
    )
    skey = None if store is None else store_key(
        store,
        fp,
        kdim,
        max_devices=max_devices,
        sweep=sweep,
        include_onehot=include_onehot,
        ktile=ktile,
        allow_bf16=allow_bf16,
    )
    hit = _AUTOTUNE_CACHE.get(key)
    if hit is not None:
        # an in-process hit must still leave the store populated — a second
        # engine/store on the same graph relies on it
        if store is not None and not store.path(skey).exists():
            sched = registry.get_schedule(
                a, **hit.as_schedule_kwargs(), fingerprint=fp
            )
            store.save(skey, hit, sched, _winning_perm(a, hit, fp))
        return hit

    if store is not None:
        entry = store.load(skey)
        if entry is not None:
            cfg, sched, perm = entry
            n_avail = len(jax.devices())
            # belt and braces: the allow_bf16 key-fold already separates
            # the entries, but never hand a bf16 config to an f32 caller;
            # and a caller asking for the bf16 error report must not be
            # served a report-less entry persisted by a bf16_report=False
            # run — re-tune, attach the report, re-save
            if (
                (cfg.n_devices is None or cfg.n_devices <= n_avail)
                and (allow_bf16 or not cfg.bf16_accumulate)
                and not (bf16_report and cfg.bf16_max_err is None)
            ):
                registry.adopt_reorder(fp, cfg.reorder, perm)
                registry.adopt_schedule(fp, cfg, sched)
                _AUTOTUNE_CACHE[key] = cfg
                return cfg
            # tuned for a bigger mesh than this host exposes: re-tune

    if sweep is None:
        sweep_eff = default_sweep(a) + sharded_sweep(
            a, sharded_device_counts(max_devices)
        )
    else:
        sweep_eff = list(sweep)

    # eligibility first, pruning second: the pruner must neither build
    # schedules for candidates that will never be timed (capped one-hot
    # builds are real work off-TPU) nor anchor its threshold to them
    on_tpu = jax.default_backend() == "tpu"
    sweep_eff = [
        c
        for c in sweep_eff
        if (c["routing"] != ONEHOT or on_tpu or include_onehot)
        and (allow_bf16 or not c.get("bf16_accumulate"))
    ]
    if not sweep_eff:
        raise ValueError(
            "autotune sweep has no measurable candidate: every point was "
            "one-hot-routed and those are skipped off-TPU — pass "
            "include_onehot=True or add a gather candidate"
        )

    if prune:
        sweep_eff, _ = prune_sweep(
            a, sweep_eff, slack=prune_slack, fingerprint=fp
        )

    rng = np.random.default_rng(seed)
    b = jnp.asarray(rng.standard_normal((a.shape[1], kdim)).astype(np.float32))
    # interleaved min-of-rounds timing: measure every candidate once (with
    # its warmup), then revisit the whole field ``rounds - 1`` more
    # times and keep each candidate's minimum. Back-to-back sequential
    # timing lets slow process-level drift (allocator state, frequency
    # scaling, first-measurements-run-hot) masquerade as a candidate
    # difference; a few-percent reorder effect cannot survive that, and
    # the min over interleaved rounds cancels it. The visit order rotates
    # per round — whichever candidate runs first after a round boundary
    # measures systematically differently, and a fixed order would bake
    # that position bias into the comparison.
    timed = []
    for cand in sweep_eff:
        kw = candidate_executor_kwargs(cand, ktile)
        ex = registry.get_executor(a, **kw)
        timed.append([cand, kw, ex, measure_candidate(ex, b, iters, warmup)])
    for r in range(1, rounds):
        k = r % len(timed)
        for rec in timed[k:] + timed[:k]:
            rec[3] = min(rec[3], measure_candidate(rec[2], b, iters, 0))
    best: Optional[TunedConfig] = None
    best_eff = float("inf")
    for cand, kw, ex, us in timed:
        cfg = TunedConfig(
            nnz_per_step=cand["nnz_per_step"],
            rows_per_window=cand["rows_per_window"],
            cols_per_block=cand["cols_per_block"],
            window_nnz=cand["window_nnz"],
            ktile=kw["ktile"],
            routing=ex.routing,
            measured_us=us,
            utilization=ex.sched.utilization,
            cols_per_block_resolved=ex.sched.cols_per_block,
            n_devices=cand.get("n_devices"),
            bf16_accumulate=kw["bf16_accumulate"],
            reorder=kw["reorder"],
        )
        eff = us * (1.0 + REORDER_MARGIN if cfg.reorder != "none" else 1.0)
        if best is None or eff < best_eff:
            best, best_eff = cfg, eff
    # sweep_eff was verified non-empty and the pruner always keeps its own
    # best candidate, so at least one point was measured
    assert best is not None
    if bf16_report:
        best = _bf16_report(a, best, b)
    if store is not None:
        sched = registry.get_schedule(
            a, **best.as_schedule_kwargs(), fingerprint=fp
        )
        store.save(skey, best, sched, _winning_perm(a, best, fp))
    _AUTOTUNE_CACHE[key] = best
    return best


def autotuned_executor(
    a: fmt.COO, b_shape: Tuple[int, ...], **kw
) -> _ExecutorBase:
    """The executor for the measured-fastest configuration (both the tuning
    result and the executor itself are cached)."""
    cfg = autotune(a, b_shape, **kw)
    return registry.get_executor(a, **cfg.as_executor_kwargs())


def warm_tuned_executor(
    a: fmt.COO,
    b_shape: Tuple[int, ...],
    *,
    store: TuningStore,
    **kw,
) -> Tuple[_ExecutorBase, TunedConfig]:
    """Store-backed ``autotuned_executor``: a populated store yields the
    executor with zero measured sweeps and zero schedule rebuilds; a miss
    tunes, persists, and returns the same."""
    cfg = autotune(a, b_shape, store=store, **kw)
    return registry.get_executor(a, **cfg.as_executor_kwargs()), cfg
