"""Persistent on-disk store of converged tuning artifacts.

The paper's engine "after converging, reuses the ideal configuration"; this
module makes that reuse survive process restarts. One entry holds a
``TunedConfig`` plus the winning schedule's prebuilt arrays, so a serving
restart warm-starts with **zero measured sweeps and zero schedule rebuilds**
— deserialize, upload, serve.

Layout
------
One ``.npz`` file per entry under ``<root>/v<version>/<key>.npz`` where
``root`` is, in priority order: the ``root`` argument, ``$REPRO_TUNING_STORE``,
``~/.cache/repro-awb-gcn/tuning``. Since v2, an entry whose config carries a
non-``"none"`` ``reorder`` axis also stores the winning **row permutation**
(``row_perm``), so serving re-applies the locality remapping at admission
with zero recompute. The key is a blake2b hash of

    (graph fingerprint, probe width kdim, device kind, mesh descriptor,
     store version, schedule format version, schedule builder version,
     schedule revision)

— a config tuned on one device kind or mesh never masquerades as another's,
and format *or builder* bumps miss cleanly instead of deserializing stale
bytes: entries persisted before a repair-logic change would deserialize
into geometry the new builder no longer produces, so the builder version
is both folded into the key (old entries become unreachable) and stamped
into the payload (entries written by other code lineages are dropped to a
re-tune at load, never returned). ``revision`` distinguishes streaming
repair generations of one graph (DESIGN.md §11); revision 0 is the cold
build.

Durability
----------
Writes are atomic: the entry is serialized to a same-directory temp file and
``os.replace``d into place, so a crashed writer never leaves a torn entry.
Reads treat *any* malformed entry (truncated, garbage, inconsistent
geometry) as a miss: ``load`` returns ``None`` and unlinks the corpse, and
the caller re-tunes.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
import warnings
from pathlib import Path
from typing import Optional, Tuple

import numpy as np

from repro.core.schedule import (
    SCHEDULE_BUILDER_VERSION,
    SCHEDULE_FORMAT_VERSION,
    Schedule,
    schedule_from_arrays,
    schedule_to_arrays,
)
from repro.tuning.space import TunedConfig

#: bump when the entry layout (not the schedule format) changes.
#: v2: the reorder axis — entries carry the winning row permutation.
STORE_VERSION = 2

ENV_ROOT = "REPRO_TUNING_STORE"


def default_root() -> Path:
    env = os.environ.get(ENV_ROOT)
    if env:
        return Path(env)
    return Path.home() / ".cache" / "repro-awb-gcn" / "tuning"


def device_kind() -> str:
    """Identity of the device the measurements ran on — measured wall-clock
    on one device kind says nothing about another."""
    import jax

    d = jax.devices()[0]
    return f"{d.platform}:{getattr(d, 'device_kind', d.platform)}"


def mesh_descriptor(max_devices: Optional[int] = None) -> str:
    """The mesh half of the store key: how many devices the sweep was
    allowed to span. ``max_devices=1`` pins the single-device sweep (what
    the serving engine uses); ``None`` means every visible device."""
    import jax

    n_avail = len(jax.devices())
    n = n_avail if max_devices is None else min(max_devices, n_avail)
    return f"{max(1, n)}dev"


class TuningStore:
    """Filesystem-backed map: store key → (TunedConfig, Schedule, perm)."""

    def __init__(self, root=None):
        self.root = Path(root) if root is not None else default_root()
        self.dir = self.root / f"v{STORE_VERSION}"

    # ---- keys --------------------------------------------------------------

    def key(
        self,
        fingerprint: str,
        kdim: int,
        *,
        device: Optional[str] = None,
        mesh: Optional[str] = None,
        revision: int = 0,
    ) -> str:
        """Entry key for (graph fingerprint, probe width) on this device/
        mesh at the current code version. ``revision`` is the streaming
        repair generation (0 = cold build): repaired schedules of one
        fingerprint persist side by side without clobbering the original."""
        ident = json.dumps(
            [
                fingerprint,
                int(kdim),
                device or device_kind(),
                mesh or mesh_descriptor(),
                STORE_VERSION,
                SCHEDULE_FORMAT_VERSION,
                SCHEDULE_BUILDER_VERSION,
                int(revision),
            ]
        )
        return hashlib.blake2b(ident.encode(), digest_size=16).hexdigest()

    def path(self, key: str) -> Path:
        return self.dir / f"{key}.npz"

    # ---- IO ----------------------------------------------------------------

    def save(
        self,
        key: str,
        cfg: TunedConfig,
        sched: Schedule,
        perm: Optional[np.ndarray] = None,
    ) -> Path:
        """Atomically persist one converged configuration + its schedule.

        ``perm`` is the locality row permutation the schedule was built
        under (``perm[new_row] = old_row``); required exactly when
        ``cfg.reorder != "none"`` — an entry claiming a reorder with no
        permutation (or vice versa) cannot be applied at admission."""
        reorder = getattr(cfg, "reorder", "none")
        if (perm is not None) != (reorder != "none"):
            raise ValueError(
                f"cfg.reorder={reorder!r} but perm is "
                f"{'present' if perm is not None else 'missing'}"
            )
        payload = schedule_to_arrays(sched)
        payload["config_json"] = np.asarray(json.dumps(dataclasses.asdict(cfg)))
        payload["builder_version"] = np.asarray(SCHEDULE_BUILDER_VERSION, np.int64)
        if perm is not None:
            payload["row_perm"] = np.asarray(perm, np.int32)
        self.dir.mkdir(parents=True, exist_ok=True)
        dst = self.path(key)
        fd, tmp = tempfile.mkstemp(dir=self.dir, suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                np.savez(f, **payload)
            os.replace(tmp, dst)  # atomic on POSIX: never a torn entry
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        return dst

    def load(
        self, key: str
    ) -> Optional[Tuple[TunedConfig, Schedule, Optional[np.ndarray]]]:
        """The entry for ``key`` as ``(cfg, sched, perm)``, or None.
        ``perm`` is the persisted row permutation (present exactly when
        ``cfg.reorder != "none"``; validated as a true permutation of the
        schedule's row count — a truncated or bit-rotted permutation would
        silently scramble output rows, so it is checked *here*, not at
        execution). A *malformed* entry (garbage bytes, truncated arrays,
        inconsistent geometry, unknown config fields, invalid permutation)
        is dropped and reported as a miss — the caller re-tunes instead of
        crashing. A transient I/O failure (EACCES, a flaky network mount)
        is also a miss but the entry is **kept**: healthy bytes must not be
        deleted for a read hiccup."""
        from repro.core.reorder import invert_permutation

        path = self.path(key)
        if not path.exists():
            return None
        try:
            with np.load(path, allow_pickle=False) as z:
                # an entry written by a different schedule-builder lineage
                # (or one predating the stamp) deserializes into geometry
                # the current builder no longer produces — drop to re-tune
                bv = int(z["builder_version"]) if "builder_version" in z else -1
                if bv != SCHEDULE_BUILDER_VERSION:
                    raise ValueError(
                        f"builder version {bv} != {SCHEDULE_BUILDER_VERSION}"
                    )
                cfg_d = json.loads(str(z["config_json"]))
                cfg = TunedConfig(**cfg_d)
                sched = schedule_from_arrays(z)
                perm = z["row_perm"] if "row_perm" in z else None
                if (perm is not None) != (cfg.reorder != "none"):
                    raise ValueError(
                        f"reorder={cfg.reorder!r} but row_perm is "
                        f"{'present' if perm is not None else 'missing'}"
                    )
                if perm is not None:
                    if perm.shape[0] != sched.shape[0]:
                        raise ValueError(
                            f"row_perm has {perm.shape[0]} entries for "
                            f"{sched.shape[0]} rows"
                        )
                    invert_permutation(perm)  # raises unless a permutation
        except OSError as e:
            warnings.warn(
                f"tuning store: unreadable entry {path.name} "
                f"(kept): {type(e).__name__}: {e}"
            )
            return None
        except Exception as e:  # malformed entry → drop + re-tune
            warnings.warn(
                f"tuning store: dropping corrupted entry "
                f"{path.name}: {type(e).__name__}: {e}"
            )
            try:
                path.unlink()
            except OSError:
                pass
            return None
        return cfg, sched, perm

    def invalidate(self, key: str) -> None:
        try:
            self.path(key).unlink()
        except OSError:
            pass

    def entries(self) -> list:
        """Keys currently on disk (current version only)."""
        if not self.dir.is_dir():
            return []
        return sorted(p.stem for p in self.dir.glob("*.npz"))

    def nbytes(self) -> int:
        if not self.dir.is_dir():
            return 0
        return sum(p.stat().st_size for p in self.dir.glob("*.npz"))
