"""Shared result types of the ``repro.serving`` surface.

The admission-ticket type and its status constants live here so the
public API (``serving/__init__.py``), the engine, and the policies all
import one definition; ``serving.gcn_engine`` re-exports them from their
historical import path.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

#: ``SubmitTicket.status`` values.
ACCEPTED = "accepted"
REJECTED = "rejected"  # queue at max_queue_depth — the engine is overloaded
SHED = "shed"  # deadline provably unmeetable under predicted wait


@dataclasses.dataclass(frozen=True)
class SubmitTicket:
    """Typed admission result of one ``submit`` call.

    ``status == ACCEPTED``: the request is queued under ``rid``.
    ``status == REJECTED``: the graph's queue sits at ``max_queue_depth``
    — the overloaded-engine signal; back off and retry.
    ``status == SHED``: the scheduling policy's predicted wait already
    exceeds the request's deadline, so serving it could only produce a
    deadline miss; it was dropped before costing any device time.
    ``rid`` is None unless accepted; ``reason`` says why not.
    """

    rid: Optional[int]
    status: str
    reason: str = ""

    @property
    def accepted(self) -> bool:
        return self.status == ACCEPTED

    def __bool__(self) -> bool:  # `if eng.submit(...):` reads naturally
        return self.accepted
