"""Typed errors of the ``repro.serving`` surface.

One module owns every exception the serving engine can raise, under a
common ``ServingError`` base so callers can catch the whole family with
one handler. Each concrete error keeps the stdlib superclass it has
always had (``UnknownGraphError`` is a ``KeyError``, the failure types
are ``RuntimeError``s), so pre-existing ``except`` clauses keep working;
``serving.gcn_engine`` re-exports all of them from their historical
import path.
"""

from __future__ import annotations


class ServingError(Exception):
    """Base of every typed error raised by the GCN serving engine."""


class UnknownGraphError(ServingError, KeyError):
    """A request named a graph this engine does not hold (never admitted,
    or removed). One typed error across every path — ``submit``,
    ``serve_batch``/``infer``, ``remove_graph``, and ``update_graph`` —
    so callers catch one thing. Subclasses ``KeyError`` for backward
    compatibility."""

    def __init__(self, graph_id: str, op: str = "serve"):
        super().__init__(f"unknown graph {graph_id!r} (op={op})")
        self.graph_id = graph_id
        self.op = op

    def __str__(self) -> str:  # KeyError quotes its arg; keep it readable
        return self.args[0]


class RequestFailure(ServingError, RuntimeError):
    """A direct ``serve_batch``/``infer`` call failed after exhausting
    every recovery path (sibling-replica retries, bounded dispatch
    retries). ``cause`` is the final underlying exception, ``n_failed``
    the number of requests affected, and ``partial`` the merged logits of
    the sub-batches that did succeed (None when none did). Served-work
    counters were not inflated; outstanding-work meters are settled."""

    def __init__(self, graph_id: str, cause: Exception, n_failed: int, partial=None):
        super().__init__(
            f"{n_failed} request(s) for graph {graph_id!r} failed after "
            f"retries: {cause!r}"
        )
        self.graph_id = graph_id
        self.cause = cause
        self.n_failed = n_failed
        self.partial = partial


class FlushError(ServingError, RuntimeError):
    """One or more per-graph batches failed during a flush/poll.

    Nothing is lost: ``partial`` holds the successfully served
    ``{graph_id: logits}``, ``failures`` the ``{graph_id: exception}``,
    and every failed *request* was restored to its queue (at the front,
    original order) for retry — when only some of a batch's replica
    chunks failed, the served chunks' logits still land in ``partial``
    and only the failed chunks' requests are restored."""

    def __init__(self, failures, partial):
        super().__init__(
            f"flush failed for graph(s) {sorted(failures)}; "
            f"{len(partial)} graph(s) served (see .partial), failed "
            f"queues restored for retry"
        )
        self.failures = failures
        self.partial = partial
