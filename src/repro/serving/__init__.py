"""Public surface of the ``repro.serving`` package.

One coherent import point for the GCN serving stack:

* ``GCNServingEngine`` — the mesh-wide deadline-aware engine, with
  ``GCNServingEngine(policy=...)`` as the scheduling seam;
* ``SchedulingPolicy`` / ``HeuristicPolicy`` / ``LearnedServiceTimePolicy``
  plus the policy state/decision types (``PolicyState``, ``GraphState``,
  ``PlaceDecision``, ``ReplicaDecision``, ``ShedDecision``,
  ``DispatchOrder``);
* ``MeshPlacer`` / ``Placement`` — placement bookkeeping;
* ``SubmitTicket`` with its ``ACCEPTED``/``REJECTED``/``SHED`` statuses;
* the typed error family under ``ServingError``.

Everything resolves lazily (PEP 562), so ``import repro.serving`` stays
cheap and the historical deep import paths
(``repro.serving.gcn_engine.UnknownGraphError`` etc.) keep working —
those modules re-export from their new homes.
"""

from __future__ import annotations

from repro.lazyexports import lazy_exports

__all__ = [
    "ACCEPTED",
    "AdmitReport",
    "DispatchOrder",
    "FlushError",
    "GCNServingEngine",
    "GraphState",
    "HeuristicPolicy",
    "LearnedServiceTimePolicy",
    "MeshPlacer",
    "Placement",
    "PlaceDecision",
    "PolicyState",
    "REJECTED",
    "ReplicaDecision",
    "RequestFailure",
    "SHED",
    "SchedulingPolicy",
    "ServingError",
    "ShedDecision",
    "SubmitTicket",
    "UnknownGraphError",
    "UpdateReport",
]

__getattr__, __dir__ = lazy_exports(
    __name__,
    {
        # engine
        "GCNServingEngine": "repro.serving.gcn_engine",
        "AdmitReport": "repro.serving.gcn_engine",
        "UpdateReport": "repro.serving.gcn_engine",
        # placement
        "MeshPlacer": "repro.serving.placement",
        "Placement": "repro.serving.placement",
        # scheduling policies
        "SchedulingPolicy": "repro.serving.policy",
        "HeuristicPolicy": "repro.serving.policy",
        "LearnedServiceTimePolicy": "repro.serving.policy",
        "PolicyState": "repro.serving.policy",
        "GraphState": "repro.serving.policy",
        "PlaceDecision": "repro.serving.policy",
        "ReplicaDecision": "repro.serving.policy",
        "ShedDecision": "repro.serving.policy",
        "DispatchOrder": "repro.serving.policy",
        # tickets + errors
        "SubmitTicket": "repro.serving.types",
        "ACCEPTED": "repro.serving.types",
        "REJECTED": "repro.serving.types",
        "SHED": "repro.serving.types",
        "ServingError": "repro.serving.errors",
        "UnknownGraphError": "repro.serving.errors",
        "RequestFailure": "repro.serving.errors",
        "FlushError": "repro.serving.errors",
    },
    globals(),
)
