"""Backward-compat shim: the transformer ``ServeEngine`` moved.

``repro.serving`` is the GCN serving stack; the transformer
prefill/decode engine that historically lived here is a *model*-side
utility and now resides at ``repro.models.transformer_serve``. This
module keeps the old import path resolving (lazily, PEP 562).
"""

from __future__ import annotations

from repro.lazyexports import lazy_exports

__getattr__, __dir__ = lazy_exports(
    __name__,
    {"ServeEngine": "repro.models.transformer_serve"},
    globals(),
)
