"""``SchedulingPolicy``: one pluggable seam for every scheduling decision.

AWB-GCN's core move is runtime rebalancing driven by continuously
monitored load signals. The serving stack makes the same kind of
decisions in software — where to place an admitted graph, when to grow
or shrink a hot graph's replica set, which requests to shed, and in what
order to dispatch queues — and this module is the single seam all of
them go through:

* ``PolicyState`` / ``GraphState`` — an immutable snapshot of everything
  a decision may read: per-device residency and outstanding work,
  per-graph queue depths and deadlines, service-time EWMAs, and graph
  features (nnz, rows, bytes, replica count).
* Typed decisions — ``PlaceDecision``, ``ReplicaDecision``,
  ``ShedDecision``, ``DispatchOrder`` — returned by the policy and
  *applied* by the engine. The policy never mutates engine state; the
  engine never second-guesses the policy (it only validates).
* ``SchedulingPolicy`` — the protocol every policy implements.
* ``HeuristicPolicy`` — the hand-tuned heuristics the engine grew over
  PRs 4–6, extracted decision-for-decision: worst-fit placement,
  EWMA×queue-depth replication with calm-poll hysteresis, EDF dispatch
  with 1.5× service headroom, and predicted-wait deadline shedding. The
  trace-equivalence suite pins this class to the pre-refactor behavior.
* ``LearnedServiceTimePolicy`` — the first learned policy: an online
  ridge-regression service-time predictor over graph/batch features,
  fitted incrementally from observed dispatch completions, whose
  predictions replace the EWMA estimates inside every decision that
  consumes a service time (shed, dispatch dueness, replication). It
  falls back to the heuristic EWMAs until enough samples accumulate.

Everything here is pure host-side python over plain numbers — no jax —
so policies are unit-testable without devices, exactly like
``serving.placement``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Mapping, Optional, Protocol, Tuple

import numpy as np

from repro.serving.placement import REPLICATED, SHARDED, SINGLE

#: deadline dispatch headroom: a queue is due at
#: ``deadline - SVC_SAFETY * est - SVC_FLOOR_S``. Dispatching at exactly
#: ``deadline - est`` lands completions *on* the deadline, where any
#: jitter is a miss; 50% service-time headroom plus a small floor turns
#: borderline batches into met deadlines at a modest batching cost.
SVC_SAFETY = 1.5
SVC_FLOOR_S = 0.010

#: ``ReplicaDecision.action`` values.
GROW = "grow"
SHRINK = "shrink"
HOLD = "hold"


# ---- state snapshot ---------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GraphState:
    """Everything a policy may read about one admitted graph.

    ``kind``/``device_index``/``device_indices`` mirror the graph's
    ``placement.Placement`` (``kind`` is None only in degenerate
    half-admitted states). ``svc_ewma`` is the measured per-*batch*
    service-time EWMA in seconds and ``svc_req_ewma`` the per-*request*
    one; both are 0.0 until the first completed batch (and after an
    eviction reset). ``earliest_deadline`` is +inf when no queued
    request carries a deadline. ``calm_polls`` is the engine-held
    shrink-hysteresis counter the replication decision reads and
    re-emits."""

    graph_id: str
    nnz: int
    n_rows: int
    bytes: int  # footprint, schedule + weights (last measured; 0 pre-admit)
    resident: bool
    kind: Optional[str]  # placement.SINGLE | SHARDED | REPLICATED
    device_index: Optional[int]  # primary device (None when sharded)
    device_indices: Tuple[int, ...]
    queue_depth: int
    earliest_deadline: float  # absolute monotonic seconds; +inf = none
    svc_ewma: float
    svc_req_ewma: float
    calm_polls: int = 0

    @property
    def n_replicas(self) -> int:
        return len(self.device_indices)


@dataclasses.dataclass(frozen=True)
class PolicyState:
    """Immutable snapshot the engine hands to every policy call.

    ``used_bytes[d]`` is device ``d``'s resident schedule+weight bytes,
    ``outstanding_s[d]`` its dispatched-but-incomplete work estimate in
    seconds. The engine's scheduling knobs (``max_replicas``,
    ``replicate_after_s``, ``replica_shrink_after``, ``max_batch``) ride
    along so the heuristic policy needs no constructor configuration —
    it reproduces whatever the engine was configured with."""

    now: float  # monotonic seconds (tests inject it)
    n_devices: int
    budget_bytes: int
    used_bytes: Tuple[int, ...]
    outstanding_s: Tuple[float, ...]
    max_replicas: int
    replicate_after_s: float
    replica_shrink_after: int
    max_batch: int
    graphs: Mapping[str, GraphState]

    def free_bytes(self, device_index: int) -> int:
        return self.budget_bytes - self.used_bytes[device_index]


# ---- typed decisions --------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PlaceDecision:
    """Admission placement: ``kind == SINGLE`` pins the graph to
    ``device_index``; ``kind == SHARDED`` spans the whole mesh
    (``device_index`` is None)."""

    kind: str
    device_index: Optional[int]


@dataclasses.dataclass(frozen=True)
class ReplicaDecision:
    """One replication step for one graph: GROW onto ``device_index``
    (None = no device fits, so nothing happens), SHRINK dropping
    ``device_index``'s clone, or HOLD. ``calm_polls`` is the new value
    of the shrink-hysteresis counter the engine should store (None =
    clear it)."""

    action: str
    device_index: Optional[int] = None
    calm_polls: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class ShedDecision:
    """Whether one deadline-carrying request should be shed.
    ``predicted_wait_s`` is the estimate the verdict was based on (at
    submit time: the full EDF-absorbed wait; at dispatch time: the
    graph's own batch estimate)."""

    shed: bool
    reason: str = ""
    predicted_wait_s: float = 0.0


@dataclasses.dataclass(frozen=True)
class DispatchOrder:
    """The order the named graphs' queues dispatch in."""

    graph_ids: Tuple[str, ...]


# ---- shared load-map math ---------------------------------------------------


def earliest_deadline(deadlines: Iterable[Optional[float]]) -> float:
    """Earliest deadline (+inf when none) — the EDF sort key."""
    dls = [d for d in deadlines if d is not None]
    return min(dls) if dls else float("inf")


def absorb_load(
    load: Dict[int, float], kind: str, device_indices: Tuple[int, ...], est: float
) -> float:
    """Fold one queue's service estimate into a per-device load map
    (cumulative busy seconds) and return its completion time:

    * a single-device queue stacks onto its device (co-located queues
      serialize);
    * a sharded queue starts when its *busiest* mesh device frees and
      advances every device to the common completion time (the psum
      synchronizes them);
    * a replicated queue splits across its clones: completion anchors on
      its **least-loaded replica**, and each replica absorbs an even
      share — never the whole batch on every clone.
    """
    if kind == REPLICATED:
        start = min(load.get(d, 0.0) for d in device_indices)
        done = start + est
        share = est / len(device_indices)
        for d in device_indices:
            load[d] = load.get(d, 0.0) + share
    else:
        start = max((load.get(d, 0.0) for d in device_indices), default=0.0)
        done = start + est
        for d in device_indices:
            load[d] = done
    return done


def _edf_order(state: PolicyState, ids: Iterable[str]) -> List[Tuple[str, GraphState]]:
    """(graph_id, GraphState) pairs in EDF order, ties by graph id."""
    pairs = [(g, state.graphs[g]) for g in ids if g in state.graphs]
    pairs.sort(key=lambda t: (t[1].earliest_deadline, t[0]))
    return pairs


# ---- the protocol -----------------------------------------------------------


class SchedulingPolicy(Protocol):
    """Every scheduling decision the engine delegates.

    The engine consults the policy at five choice points — admission
    placement, replica grow/shrink, submit-time shedding, dispatch-time
    shedding, and queue ordering/dueness — always passing an immutable
    ``PolicyState`` snapshot, and feeds completed batches back through
    ``observe_service`` so learned policies can fit online. Policies may
    hold internal state (a learned model); they must never reach into
    the engine."""

    def place(self, state: PolicyState, graph_id: str, nbytes: int) -> PlaceDecision:
        """Where a new graph of estimated footprint ``nbytes`` goes."""
        ...

    def replication(self, state: PolicyState, graph_id: str) -> ReplicaDecision:
        """Grow/shrink/hold the graph's replica set (called per poll)."""
        ...

    def shed_on_submit(
        self, state: PolicyState, graph_id: str, deadline: float
    ) -> ShedDecision:
        """Admission-time shed verdict for a deadline-carrying request."""
        ...

    def shed_at_dispatch(
        self, state: PolicyState, graph_id: str, deadline: float
    ) -> ShedDecision:
        """Last-gate shed verdict just before device time is spent."""
        ...

    def dispatch_order(
        self, state: PolicyState, graph_ids: Iterable[str]
    ) -> DispatchOrder:
        """The order the named non-empty queues dispatch in."""
        ...

    def due_queues(self, state: PolicyState) -> Tuple[str, ...]:
        """Queues whose deadlines make them due *now* (``poll``'s cut)."""
        ...

    def predicted_wait(
        self, state: PolicyState, graph_id: str, deadline: Optional[float] = None
    ) -> float:
        """Predicted completion delay (s) of a request submitted now."""
        ...

    def observe_service(
        self, graph_id: str, n_requests: int, service_s: float, graph: GraphState
    ) -> None:
        """Feedback: one batch of ``n_requests`` completed in
        ``service_s`` seconds (learned policies fit on this)."""
        ...


# ---- the extracted heuristics ----------------------------------------------


class HeuristicPolicy:
    """The hand-tuned policies the engine shipped with, behind the seam.

    Decision-for-decision identical to the pre-refactor inline code
    (pinned by the trace-equivalence suite):

    * **placement** — giant graphs (footprint over one device's budget,
      mesh wider than one device) go sharded; everything else worst-fit
      packs onto the device with the most free budget, ties to the
      lowest index;
    * **replication** — backlog = per-request service EWMA × queue
      depth; grow onto the coolest fitting device above
      ``replicate_after_s``, shrink the fullest secondary after
      ``replica_shrink_after`` consecutive calm polls below a quarter of
      it;
    * **shedding** — at submit, shed when the EDF-absorbed predicted
      wait exceeds the deadline; at dispatch, re-check against the
      graph's own batch estimate;
    * **dispatch** — EDF order (ties by graph id); a queue is due when
      its earliest deadline minus ``SVC_SAFETY ×`` its absorbed
      completion estimate (plus ``SVC_FLOOR_S``) has arrived.

    Subclasses customize the service-time model by overriding
    ``_queue_est`` / ``_req_est`` — every decision reads its estimates
    through those two hooks."""

    # -- service-time model (the learned policy overrides these) --

    def _queue_est(self, state: PolicyState, g: GraphState) -> float:
        """Estimated seconds to serve ``g``'s queue as one batch."""
        return g.svc_ewma

    def _req_est(self, state: PolicyState, g: GraphState) -> float:
        """Estimated seconds of service per queued request."""
        return g.svc_req_ewma

    # -- placement --

    def place(self, state: PolicyState, graph_id: str, nbytes: int) -> PlaceDecision:
        if nbytes > state.budget_bytes and state.n_devices > 1:
            return PlaceDecision(SHARDED, None)
        d = max(range(state.n_devices), key=lambda i: (state.free_bytes(i), -i))
        return PlaceDecision(SINGLE, d)

    # -- replication --

    def _replica_device(self, state: PolicyState, g: GraphState) -> Optional[int]:
        """The device the next replica should land on: coolest (most
        free budget, ties to the lowest index) device not already
        hosting one, with room for the clone's footprint — growth must
        never evict resident graphs to make space. None when nothing
        fits, the graph is not resident, or it is sharded."""
        if g.kind == SHARDED or not g.resident:
            return None
        free = [
            d
            for d in range(state.n_devices)
            if d not in g.device_indices and state.free_bytes(d) >= g.bytes
        ]
        if not free:
            return None
        return max(free, key=lambda d: (state.free_bytes(d), -d))

    def replication(self, state: PolicyState, graph_id: str) -> ReplicaDecision:
        g = state.graphs[graph_id]
        if g.kind is None or g.kind == SHARDED:
            return ReplicaDecision(HOLD)
        backlog = self._req_est(state, g) * g.queue_depth
        if backlog > state.replicate_after_s and g.n_replicas < state.max_replicas:
            return ReplicaDecision(GROW, self._replica_device(state, g))
        if g.n_replicas > 1 and backlog <= state.replicate_after_s / 4:
            calm = g.calm_polls + 1
            if calm >= state.replica_shrink_after:
                shed = max(
                    (d for d in g.device_indices if d != g.device_index),
                    key=lambda d: (state.used_bytes[d], d),
                )
                return ReplicaDecision(SHRINK, shed, calm_polls=0)
            return ReplicaDecision(HOLD, calm_polls=calm)
        return ReplicaDecision(HOLD)

    # -- shedding --

    def predicted_wait(
        self, state: PolicyState, graph_id: str, deadline: Optional[float] = None
    ) -> float:
        """Predicted completion delay (seconds from now) of a request
        submitted to ``graph_id`` now: every queue EDF-ahead of it is
        absorbed into the per-device load map — co-located queues
        serialize, replicated queues split — and the request's own
        graph's batch estimate completes on top."""
        g = state.graphs[graph_id]
        est = self._queue_est(state, g)
        if g.kind is None:
            return est
        my_key = g.earliest_deadline
        if deadline is not None:
            my_key = min(my_key, deadline)
        load: Dict[int, float] = {}
        ahead = (
            gid
            for gid, gs in state.graphs.items()
            if gs.queue_depth and gid != graph_id
        )
        for gid, gs in _edf_order(state, ahead):
            if (gs.earliest_deadline, gid) > (my_key, graph_id):
                continue  # EDF-behind: dispatches after us, cannot delay us
            if gs.kind is None:
                continue
            absorb_load(load, gs.kind, gs.device_indices, self._queue_est(state, gs))
        return absorb_load(load, g.kind, g.device_indices, est)

    def shed_on_submit(
        self, state: PolicyState, graph_id: str, deadline: float
    ) -> ShedDecision:
        wait = self.predicted_wait(state, graph_id, deadline)
        if state.now + wait > deadline:
            reason = (
                f"predicted wait {wait * 1e3:.1f} ms exceeds deadline "
                f"{(deadline - state.now) * 1e3:.1f} ms for graph "
                f"{graph_id!r}"
            )
            return ShedDecision(True, reason, predicted_wait_s=wait)
        return ShedDecision(False, predicted_wait_s=wait)

    def shed_at_dispatch(
        self, state: PolicyState, graph_id: str, deadline: float
    ) -> ShedDecision:
        est = self._queue_est(state, state.graphs[graph_id])
        if state.now + est > deadline:
            reason = (
                f"deadline unmeetable at dispatch: estimate "
                f"{est * 1e3:.1f} ms for graph {graph_id!r}"
            )
            return ShedDecision(True, reason, predicted_wait_s=est)
        return ShedDecision(False, predicted_wait_s=est)

    # -- dispatch ordering / dueness --

    def dispatch_order(
        self, state: PolicyState, graph_ids: Iterable[str]
    ) -> DispatchOrder:
        return DispatchOrder(tuple(g for g, _ in _edf_order(state, graph_ids)))

    def due_queues(self, state: PolicyState) -> Tuple[str, ...]:
        """The EDF prefix of queues due now: walk every non-empty queue
        in EDF order over the per-device load map; a queue is due when
        its earliest deadline minus ``SVC_SAFETY ×`` its absorbed
        completion estimate (plus ``SVC_FLOOR_S``) has arrived — and
        every EDF-predecessor dispatches with it."""
        pending = (g for g, gs in state.graphs.items() if gs.queue_depth)
        order = _edf_order(state, pending)
        load: Dict[int, float] = {}
        due_upto = -1
        for i, (gid, gs) in enumerate(order):
            done = absorb_load(
                load, gs.kind, gs.device_indices, self._queue_est(state, gs)
            )
            slack = SVC_SAFETY * done + SVC_FLOOR_S
            if gs.earliest_deadline - slack <= state.now:
                due_upto = i
        return tuple(g for g, _ in order[: due_upto + 1])

    # -- feedback --

    def observe_service(
        self, graph_id: str, n_requests: int, service_s: float, graph: GraphState
    ) -> None:
        """The heuristic learns nothing here — the engine's own EWMAs
        (already folded before this call) are its whole model."""


# ---- the learned policy -----------------------------------------------------


class OnlineRidge:
    """Tiny exact online ridge regression: ``A = λI + Σ xxᵀ``,
    ``b = Σ xy``, ``θ = A⁻¹ b`` solved on demand (d is single-digit, so
    the solve is microseconds). Numerically boring on purpose — the
    point is the seam, not the model."""

    def __init__(self, dim: int, l2: float = 1e-4):
        self.dim = int(dim)
        self.l2 = float(l2)
        self.A = np.eye(self.dim) * self.l2
        self.b = np.zeros(self.dim)
        self.n = 0
        self._theta: Optional[np.ndarray] = None

    def observe(self, x: np.ndarray, y: float) -> None:
        x = np.asarray(x, np.float64)
        self.A += np.outer(x, x)
        self.b += x * float(y)
        self.n += 1
        self._theta = None

    @property
    def theta(self) -> np.ndarray:
        if self._theta is None:
            try:
                self._theta = np.linalg.solve(self.A, self.b)
            except np.linalg.LinAlgError:
                self._theta = np.linalg.lstsq(self.A, self.b, rcond=None)[0]
        return self._theta

    def predict(self, x: np.ndarray) -> float:
        return float(np.asarray(x, np.float64) @ self.theta)


class LearnedServiceTimePolicy(HeuristicPolicy):
    """Heuristic decisions with a *learned* service-time model inside.

    Every decision of ``HeuristicPolicy`` that consumes a service-time
    estimate — predicted-wait shedding, dispatch dueness, replication
    backlog — reads it through ``_queue_est``/``_req_est``; this policy
    overrides those to predict with an online ridge regression over
    ``(batch size, graph nnz, graph rows)`` features, fitted from every
    completed batch the engine reports through ``observe_service``.
    Until ``min_samples`` observations accumulate (and whenever a
    prediction comes back non-finite or non-positive) it falls back to
    the heuristic EWMAs, so a cold policy behaves exactly like
    ``HeuristicPolicy``.

    The model is shared across graphs — nnz/rows features carry the
    cross-graph structure — so a freshly admitted graph benefits from
    every previously observed one. ``prediction_report()`` exposes the
    online accuracy (mean absolute relative error of warm predictions at
    observation time), which the open-loop head-to-head bench gates."""

    #: feature vector length of ``_features``
    DIM = 6

    def __init__(self, *, min_samples: int = 24, l2: float = 1e-4):
        if min_samples < 1:
            raise ValueError(f"min_samples must be >= 1, got {min_samples}")
        self.min_samples = int(min_samples)
        self.ridge = OnlineRidge(self.DIM, l2=l2)
        self._err_sum = 0.0
        self._err_n = 0
        self._fallbacks = 0

    @staticmethod
    def _features(g: GraphState, batch: int) -> np.ndarray:
        """Service-time featurization: affine in batch size and in the
        graph's nnz/row scale, plus the batch×size cross terms that
        dominate the gather path's work (slots × batch)."""
        b = float(max(1, batch))
        nnz_m = g.nnz / 1e6
        rows_k = g.n_rows / 1e3
        return np.array([1.0, b, nnz_m, b * nnz_m, rows_k, b * rows_k])

    @property
    def fitted(self) -> bool:
        return self.ridge.n >= self.min_samples

    def _predict(self, g: GraphState, batch: int) -> Optional[float]:
        if not self.fitted:
            return None
        y = self.ridge.predict(self._features(g, batch))
        if not np.isfinite(y) or y <= 0.0:
            self._fallbacks += 1
            return None
        return y

    def _queue_est(self, state: PolicyState, g: GraphState) -> float:
        # the engine dispatches at most max_batch requests per batch, so
        # the model is only ever *fitted* on batches in [1, max_batch];
        # clamp the query to that range — a deep queue drains in
        # max_batch-sized dispatches, and unclamped extrapolation walks
        # the affine model negative (then every estimate falls back)
        pred = self._predict(g, max(1, min(g.queue_depth, state.max_batch)))
        return g.svc_ewma if pred is None else pred

    def _req_est(self, state: PolicyState, g: GraphState) -> float:
        b = max(1, min(g.queue_depth, state.max_batch))
        pred = self._predict(g, b)
        return g.svc_req_ewma if pred is None else pred / b

    def observe_service(
        self, graph_id: str, n_requests: int, service_s: float, graph: GraphState
    ) -> None:
        x = self._features(graph, n_requests)
        if self.fitted and service_s > 0.0:
            pred = self.ridge.predict(x)
            if np.isfinite(pred):
                self._err_sum += abs(pred - service_s) / service_s
                self._err_n += 1
        self.ridge.observe(x, service_s)

    def prediction_report(self) -> dict:
        """Online accuracy: every warm prediction is scored against the
        actual service time at observation, *before* that observation
        updates the model."""
        return {
            "n_samples": self.ridge.n,
            "n_scored": self._err_n,
            "mean_abs_rel_err": (self._err_sum / self._err_n) if self._err_n else 0.0,
            "fallbacks": self._fallbacks,
            "fitted": self.fitted,
        }

    def reset_errors(self) -> None:
        """Zero the accuracy accumulators (benchmark sections measure a
        window; the model itself keeps learning)."""
        self._err_sum = 0.0
        self._err_n = 0
        self._fallbacks = 0
