"""Mesh-wide graph placement: which device(s) serve which resident graph.

AWB-GCN balances workload across the PE array *within* one graph; a serving
mesh faces the same problem one level up — many resident graphs, each a
fixed ``device_bytes`` footprint, competing for a row of devices with
bounded HBM. ``MeshPlacer`` is the single owner of that decision:

* **Bin-packing admission.** ``place`` assigns each graph to the device
  with the most free budget (worst-fit — the packing rule that *spreads*
  load, which is the goal here: idle devices are the wasted resource, not
  fragmentation). Per-device byte budgets mirror the engine's old
  single-device LRU budget, one per mesh device.
* **Sharded fallback for giant graphs.** A graph whose footprint exceeds
  any single device's budget cannot be packed; ``place`` routes it to a
  ``ShardedScheduleExecutor`` spanning the whole mesh instead. Its
  measured footprint is accounted as an even (ceil) split across every
  device — shards are padded to a common step count, so the even split
  *is* the per-device slice (``schedule_shard.shard_payload_bytes``
  models that slice and the tests pin it to the executor's real
  ``device_bytes``).
* **Replication for hot graphs.** When one graph saturates its device's
  throughput, the engine clones it: ``add_replica`` grows a
  ``REPLICATED`` placement — the *same* graph resident on several devices
  behind a load balancer (AWB-GCN's remote switching from a congested PE
  to an underloaded one, lifted to placement). The replica lands on the
  coolest device (most free budget, like admission), each replica's bytes
  are accounted to its own device, and ``drop_replica`` shrinks the set
  back — collapsing to ``SINGLE`` when only the primary remains.
* **Eviction-pressure rebalancing.** The placer counts evictions per
  device; when pressure concentrates on one device (≥ ``rebalance_after``
  evictions there and ≥ 2× the coolest device), ``rebalance_target``
  nominates a (hot, cool) device pair and the engine migrates one resident
  graph — the runtime-rebalancing loop of the paper, applied to placement
  instead of per-PE rows.

The placer is pure host-side bookkeeping over device *indices* — no jax
imports — so placement policy is unit-testable without a mesh; the engine
maps index → ``jax.Device``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

SINGLE = "single"
SHARDED = "sharded"
REPLICATED = "replicated"


@dataclasses.dataclass(frozen=True)
class Placement:
    """Where one graph lives on the mesh.

    ``kind == "single"``: the graph's executor and weights are pinned to
    ``mesh[device_index]``. ``kind == "sharded"``: the graph spans all
    ``n_devices`` mesh devices through a ``ShardedScheduleExecutor`` and
    ``device_index`` is None. ``kind == "replicated"``: independent full
    clones of the graph live on each device in ``replicas`` (primary
    first — ``device_index`` stays the primary, which is never dropped);
    any one replica can serve any request.
    """

    kind: str
    device_index: Optional[int]
    n_devices: int
    replicas: Tuple[int, ...] = ()

    @property
    def device_indices(self) -> Tuple[int, ...]:
        """Every mesh device this placement touches."""
        if self.kind == SINGLE:
            return (self.device_index,)
        if self.kind == REPLICATED:
            return self.replicas
        return tuple(range(self.n_devices))


class MeshPlacer:
    """Bin-packs admitted graphs onto a 1-D mesh under per-device budgets.

    The placer records decisions and byte accounting; the engine owns the
    executors, the LRU order, and performs the actual evictions/uploads.
    ``used[d]`` meters *resident* bytes only — an evicted graph keeps its
    placement (re-admission returns to the same device) until a rebalance
    moves it. Byte accounting is per (graph, device): a replicated graph
    carries one full footprint on **each** replica device, and dropping
    one replica frees exactly that device's share.
    """

    def __init__(
        self, n_devices: int, per_device_budget_bytes: int, *, rebalance_after: int = 4
    ):
        if n_devices < 1:
            raise ValueError(f"n_devices must be >= 1, got {n_devices}")
        self.n_devices = int(n_devices)
        self.budget = int(per_device_budget_bytes)
        self.rebalance_after = int(rebalance_after)
        self.used: List[int] = [0] * self.n_devices
        self.evictions: List[int] = [0] * self.n_devices
        self.placements: Dict[str, Placement] = {}
        #: per-graph map of device index → resident bytes on that device
        self._resident_bytes: Dict[str, Dict[int, int]] = {}
        self.n_rebalances = 0

    # ---- admission decisions ----------------------------------------------

    def free_bytes(self, device_index: int) -> int:
        return self.budget - self.used[device_index]

    def place(self, graph_id: str, nbytes: int, decision=None) -> Placement:
        """Decide (and record) where a new graph goes.

        Giant graphs — footprint over any single device's budget — go
        sharded across the whole mesh when it has more than one device;
        on a 1-device mesh they stay single (the engine's keep-active
        rule already degrades that to one-graph-at-a-time rotation).
        Everything else is worst-fit packed: the device with the most
        free budget, ties to the lowest index (deterministic).

        ``decision`` overrides the built-in rule with an externally-made
        placement: any object with ``.kind`` (``SINGLE``/``SHARDED``)
        and ``.device_index`` attributes — in practice a
        ``serving.policy.PlaceDecision`` (duck-typed so this module
        stays import-free of the policy layer). The placer validates it
        (sharded needs a multi-device mesh; the device index must be on
        the mesh) and records it verbatim.
        """
        if graph_id in self.placements:
            raise ValueError(f"graph {graph_id!r} already placed")
        if decision is None:
            if nbytes > self.budget and self.n_devices > 1:
                p = Placement(SHARDED, None, self.n_devices)
            else:
                d = max(range(self.n_devices), key=lambda i: (self.free_bytes(i), -i))
                p = Placement(SINGLE, d, 1)
        elif decision.kind == SHARDED:
            if self.n_devices < 2:
                raise ValueError(
                    f"graph {graph_id!r}: sharded placement needs a multi-device mesh"
                )
            p = Placement(SHARDED, None, self.n_devices)
        elif decision.kind == SINGLE:
            d = decision.device_index
            if d is None or not 0 <= int(d) < self.n_devices:
                raise ValueError(
                    f"graph {graph_id!r}: device_index {d!r} is not on "
                    f"this {self.n_devices}-device mesh"
                )
            p = Placement(SINGLE, int(d), 1)
        else:
            raise ValueError(
                f"graph {graph_id!r}: placement decision kind must be "
                f"{SINGLE!r} or {SHARDED!r}, got {decision.kind!r}"
            )
        self.placements[graph_id] = p
        return p

    def placement_of(self, graph_id: str) -> Optional[Placement]:
        return self.placements.get(graph_id)

    # ---- byte accounting (engine calls on upload/evict/remove) ------------

    def account(self, graph_id: str, nbytes: int) -> None:
        """Record ``nbytes`` device-resident for a placed graph (sharded
        graphs spread evenly across the mesh). Replica growth never goes
        through here — ``add_replica`` accounts its own device."""
        p = self.placements[graph_id]
        if graph_id in self._resident_bytes:
            raise ValueError(f"graph {graph_id!r} already accounted")
        if p.kind == REPLICATED:
            raise ValueError(
                f"graph {graph_id!r} is replicated; replicas account "
                "per-device through add_replica"
            )
        shares = self._shares(p, nbytes)
        self._resident_bytes[graph_id] = dict(zip(p.device_indices, shares))
        for d, share in zip(p.device_indices, shares):
            self.used[d] += share

    def unaccount(self, graph_id: str) -> None:
        """Release a graph's resident bytes on **every** device it
        occupies (full eviction or removal)."""
        per_dev = self._resident_bytes.pop(graph_id, None)
        if per_dev is None:
            return
        for d, share in per_dev.items():
            self.used[d] -= share

    def reaccount(self, graph_id: str, nbytes: int) -> None:
        """Adjust a *resident* graph's byte accounting in place — what a
        streaming ``update_graph`` needs when the repaired executor's
        footprint differs from the old one (the placement itself is
        sticky: repair never migrates a graph). Replicated graphs charge
        one full new footprint per replica device; sharded/single reuse
        the admission split."""
        per_dev = self._resident_bytes.get(graph_id)
        if per_dev is None:
            raise ValueError(f"graph {graph_id!r} is not resident")
        p = self.placements[graph_id]
        for d, share in per_dev.items():
            self.used[d] -= share
        if p.kind == REPLICATED:
            new = {d: int(nbytes) for d in per_dev}
        else:
            shares = self._shares(p, nbytes)
            new = dict(zip(p.device_indices, shares))
        self._resident_bytes[graph_id] = new
        for d, share in new.items():
            self.used[d] += share

    def forget(self, graph_id: str) -> None:
        """Drop a graph entirely (engine ``remove_graph``)."""
        self.unaccount(graph_id)
        self.placements.pop(graph_id, None)

    def is_resident(self, graph_id: str) -> bool:
        return graph_id in self._resident_bytes

    def resident_on(self, graph_id: str, device_index: int) -> bool:
        return device_index in self._resident_bytes.get(graph_id, {})

    @staticmethod
    def _shares(p: Placement, nbytes: int) -> List[int]:
        n = len(p.device_indices)
        share = -(-int(nbytes) // n)  # ceil: never under-account a device
        return [share] * n

    # ---- replication (engine calls when one graph saturates a device) ------

    def replica_candidate(
        self, graph_id: str, nbytes: Optional[int] = None
    ) -> Optional[int]:
        """The device the next replica of ``graph_id`` should land on —
        the coolest (most free budget, ties to the lowest index) device
        not already hosting a replica — or None when every mesh device
        already hosts one. Pass ``nbytes`` (the clone's footprint) to
        also require the device to have room for it: replication is a
        luxury, so growth must never evict resident graphs to make
        space (without the fit check a hot graph ping-pongs — grow onto
        a full device, budget sweep drops the clone, next poll re-grows
        it, one full upload per cycle). Sharded graphs cannot replicate
        (they already span the mesh); nor can a graph that is not
        resident."""
        p = self.placements[graph_id]
        if p.kind == SHARDED or not self.is_resident(graph_id):
            return None
        free = []
        for d in range(self.n_devices):
            if d in p.device_indices:
                continue
            if nbytes is not None and self.free_bytes(d) < nbytes:
                continue
            free.append(d)
        if not free:
            return None
        return max(free, key=lambda d: (self.free_bytes(d), -d))

    def add_replica(
        self, graph_id: str, nbytes: int, device_index: Optional[int] = None
    ) -> int:
        """Grow ``graph_id``'s replica set by one device and account
        ``nbytes`` (one full clone footprint) there. ``device_index``
        defaults to ``replica_candidate``; raises when the graph cannot
        replicate or the device already hosts it. Returns the device the
        replica landed on."""
        p = self.placements[graph_id]
        if p.kind == SHARDED:
            raise ValueError(
                f"graph {graph_id!r} is sharded across the mesh; "
                "sharded graphs cannot replicate"
            )
        if not self.is_resident(graph_id):
            raise ValueError(
                f"graph {graph_id!r} is not resident; admit it before replicating"
            )
        if device_index is None:
            device_index = self.replica_candidate(graph_id)
            if device_index is None:
                raise ValueError(
                    f"graph {graph_id!r} already has a replica on every "
                    f"device of this {self.n_devices}-device mesh"
                )
        device_index = int(device_index)
        if device_index in p.device_indices:
            raise ValueError(
                f"graph {graph_id!r} already has a replica on device {device_index}"
            )
        replicas = tuple(p.device_indices) + (device_index,)
        self.placements[graph_id] = Placement(REPLICATED, p.device_index, 1, replicas)
        self._resident_bytes[graph_id][device_index] = int(nbytes)
        self.used[device_index] += int(nbytes)
        return device_index

    def drop_replica(self, graph_id: str, device_index: int) -> Placement:
        """Shrink ``graph_id``'s replica set: free ``device_index``'s
        clone bytes and collapse back to ``SINGLE`` when only the primary
        remains. The primary replica can never be dropped (that is the
        engine's eviction, not a shrink)."""
        p = self.placements[graph_id]
        if p.kind != REPLICATED:
            raise ValueError(f"graph {graph_id!r} is not replicated")
        if device_index == p.device_index:
            raise ValueError(
                f"device {device_index} holds graph {graph_id!r}'s "
                "primary replica; evict the graph instead of dropping it"
            )
        if device_index not in p.replicas:
            raise ValueError(
                f"graph {graph_id!r} has no replica on device {device_index}"
            )
        nbytes = self._resident_bytes[graph_id].pop(device_index)
        self.used[device_index] -= nbytes
        rest = tuple(d for d in p.replicas if d != device_index)
        new = (
            Placement(SINGLE, p.device_index, 1)
            if len(rest) == 1
            else Placement(REPLICATED, p.device_index, 1, rest)
        )
        self.placements[graph_id] = new
        return new

    # ---- eviction pressure + rebalancing -----------------------------------

    def note_eviction(self, graph_id: str) -> None:
        """Count one eviction against every device the victim occupied."""
        for d in self.placements[graph_id].device_indices:
            self.evictions[d] += 1

    def rebalance_target(self) -> Optional[Tuple[int, int]]:
        """(hot_device, cool_device) when eviction pressure has concentrated
        — the hot device has absorbed ≥ ``rebalance_after`` evictions since
        the last rebalance *and* at least twice the coolest device's count —
        else None. The engine migrates one resident graph hot → cool and
        calls ``move``."""
        if self.n_devices < 2:
            return None
        hot = max(range(self.n_devices), key=lambda d: (self.evictions[d], d))
        cool = min(
            range(self.n_devices), key=lambda d: (self.evictions[d], self.used[d], d)
        )
        if hot == cool:
            return None
        if self.evictions[hot] < self.rebalance_after:
            return None
        if self.evictions[hot] < 2 * max(1, self.evictions[cool]):
            return None
        return hot, cool

    def move(self, graph_id: str, device_index: int) -> Placement:
        """Re-place a single-device graph onto ``device_index`` (the
        rebalance migration; also resets the pressure window so one hot
        stretch triggers one move, not a cascade)."""
        old = self.placements[graph_id]
        if old.kind != SINGLE:
            raise ValueError(
                f"cannot move {old.kind} graph {graph_id!r}; only "
                "single-device placements migrate"
            )
        per_dev = self._resident_bytes.get(graph_id)
        nbytes = None if per_dev is None else per_dev[old.device_index]
        self.unaccount(graph_id)
        new = Placement(SINGLE, int(device_index), 1)
        self.placements[graph_id] = new
        if nbytes is not None:
            self.account(graph_id, nbytes)
        self.evictions = [0] * self.n_devices
        self.n_rebalances += 1
        return new

    # ---- reporting ---------------------------------------------------------

    def device_report(self, extra: Optional[Dict[int, dict]] = None) -> List[dict]:
        """Per-device occupancy snapshot for ``stats()`` — replicated
        graphs appear on every device currently hosting one of their
        replicas. ``extra`` merges caller-side per-device fields into
        each row (the engine folds its saturation meters in this way;
        placement itself stays pure byte bookkeeping)."""
        graphs: List[List[str]] = [[] for _ in range(self.n_devices)]
        for gid, p in sorted(self.placements.items()):
            for d in p.device_indices:
                if self.resident_on(gid, d):
                    graphs[d].append(gid)
        rows = []
        for d in range(self.n_devices):
            row = {
                "device": d,
                "used_bytes": self.used[d],
                "budget_bytes": self.budget,
                "evictions": self.evictions[d],
                "resident": graphs[d],
            }
            if extra:
                row.update(extra.get(d, {}))
            rows.append(row)
        return rows
