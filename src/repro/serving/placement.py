"""Mesh-wide graph placement: which device serves which resident graph.

AWB-GCN balances workload across the PE array *within* one graph; a serving
mesh faces the same problem one level up — many resident graphs, each a
fixed ``device_bytes`` footprint, competing for a row of devices with
bounded HBM. ``MeshPlacer`` is the single owner of that decision:

* **Bin-packing admission.** ``place`` assigns each graph to the device
  with the most free budget (worst-fit — the packing rule that *spreads*
  load, which is the goal here: idle devices are the wasted resource, not
  fragmentation). Per-device byte budgets mirror the engine's old
  single-device LRU budget, one per mesh device.
* **Sharded fallback for giant graphs.** A graph whose footprint exceeds
  any single device's budget cannot be packed; ``place`` routes it to a
  ``ShardedScheduleExecutor`` spanning the whole mesh instead. Its
  measured footprint is accounted as an even (ceil) split across every
  device — shards are padded to a common step count, so the even split
  *is* the per-device slice (``schedule_shard.shard_payload_bytes``
  models that slice and the tests pin it to the executor's real
  ``device_bytes``).
* **Eviction-pressure rebalancing.** The placer counts evictions per
  device; when pressure concentrates on one device (≥ ``rebalance_after``
  evictions there and ≥ 2× the coolest device), ``rebalance_target``
  nominates a (hot, cool) device pair and the engine migrates one resident
  graph — the runtime-rebalancing loop of the paper, applied to placement
  instead of per-PE rows.

The placer is pure host-side bookkeeping over device *indices* — no jax
imports — so placement policy is unit-testable without a mesh; the engine
maps index → ``jax.Device``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

SINGLE = "single"
SHARDED = "sharded"


@dataclasses.dataclass(frozen=True)
class Placement:
    """Where one graph lives on the mesh.

    ``kind == "single"``: the graph's executor and weights are pinned to
    ``mesh[device_index]``. ``kind == "sharded"``: the graph spans all
    ``n_devices`` mesh devices through a ``ShardedScheduleExecutor`` and
    ``device_index`` is None.
    """
    kind: str
    device_index: Optional[int]
    n_devices: int

    @property
    def device_indices(self) -> Tuple[int, ...]:
        """Every mesh device this placement touches."""
        if self.kind == SINGLE:
            return (self.device_index,)
        return tuple(range(self.n_devices))


class MeshPlacer:
    """Bin-packs admitted graphs onto a 1-D mesh under per-device budgets.

    The placer records decisions and byte accounting; the engine owns the
    executors, the LRU order, and performs the actual evictions/uploads.
    ``used[d]`` meters *resident* bytes only — an evicted graph keeps its
    placement (re-admission returns to the same device) until a rebalance
    moves it.
    """

    def __init__(self, n_devices: int, per_device_budget_bytes: int, *,
                 rebalance_after: int = 4):
        if n_devices < 1:
            raise ValueError(f"n_devices must be >= 1, got {n_devices}")
        self.n_devices = int(n_devices)
        self.budget = int(per_device_budget_bytes)
        self.rebalance_after = int(rebalance_after)
        self.used: List[int] = [0] * self.n_devices
        self.evictions: List[int] = [0] * self.n_devices
        self.placements: Dict[str, Placement] = {}
        self._resident_bytes: Dict[str, int] = {}
        self.n_rebalances = 0

    # ---- admission decisions ----------------------------------------------

    def free_bytes(self, device_index: int) -> int:
        return self.budget - self.used[device_index]

    def place(self, graph_id: str, nbytes: int) -> Placement:
        """Decide (and record) where a new graph goes.

        Giant graphs — footprint over any single device's budget — go
        sharded across the whole mesh when it has more than one device;
        on a 1-device mesh they stay single (the engine's keep-active
        rule already degrades that to one-graph-at-a-time rotation).
        Everything else is worst-fit packed: the device with the most
        free budget, ties to the lowest index (deterministic).
        """
        if graph_id in self.placements:
            raise ValueError(f"graph {graph_id!r} already placed")
        if nbytes > self.budget and self.n_devices > 1:
            p = Placement(SHARDED, None, self.n_devices)
        else:
            d = max(range(self.n_devices),
                    key=lambda i: (self.free_bytes(i), -i))
            p = Placement(SINGLE, d, 1)
        self.placements[graph_id] = p
        return p

    def placement_of(self, graph_id: str) -> Optional[Placement]:
        return self.placements.get(graph_id)

    # ---- byte accounting (engine calls on upload/evict/remove) ------------

    def account(self, graph_id: str, nbytes: int) -> None:
        """Record ``nbytes`` device-resident for a placed graph (sharded
        graphs spread evenly across the mesh)."""
        p = self.placements[graph_id]
        if graph_id in self._resident_bytes:
            raise ValueError(f"graph {graph_id!r} already accounted")
        self._resident_bytes[graph_id] = int(nbytes)
        for d, share in zip(p.device_indices, self._shares(p, nbytes)):
            self.used[d] += share

    def unaccount(self, graph_id: str) -> None:
        """Release a graph's resident bytes (eviction or removal)."""
        nbytes = self._resident_bytes.pop(graph_id, None)
        if nbytes is None:
            return
        p = self.placements[graph_id]
        for d, share in zip(p.device_indices, self._shares(p, nbytes)):
            self.used[d] -= share

    def forget(self, graph_id: str) -> None:
        """Drop a graph entirely (engine ``remove_graph``)."""
        self.unaccount(graph_id)
        self.placements.pop(graph_id, None)

    def is_resident(self, graph_id: str) -> bool:
        return graph_id in self._resident_bytes

    @staticmethod
    def _shares(p: Placement, nbytes: int) -> List[int]:
        n = len(p.device_indices)
        share = -(-int(nbytes) // n)  # ceil: never under-account a device
        return [share] * n

    # ---- eviction pressure + rebalancing -----------------------------------

    def note_eviction(self, graph_id: str) -> None:
        """Count one eviction against every device the victim occupied."""
        for d in self.placements[graph_id].device_indices:
            self.evictions[d] += 1

    def rebalance_target(self) -> Optional[Tuple[int, int]]:
        """(hot_device, cool_device) when eviction pressure has concentrated
        — the hot device has absorbed ≥ ``rebalance_after`` evictions since
        the last rebalance *and* at least twice the coolest device's count —
        else None. The engine migrates one resident graph hot → cool and
        calls ``move``."""
        if self.n_devices < 2:
            return None
        hot = max(range(self.n_devices), key=lambda d: (self.evictions[d], d))
        cool = min(range(self.n_devices),
                   key=lambda d: (self.evictions[d], self.used[d], d))
        if hot == cool:
            return None
        if self.evictions[hot] < self.rebalance_after:
            return None
        if self.evictions[hot] < 2 * max(1, self.evictions[cool]):
            return None
        return hot, cool

    def move(self, graph_id: str, device_index: int) -> Placement:
        """Re-place a single-device graph onto ``device_index`` (the
        rebalance migration; also resets the pressure window so one hot
        stretch triggers one move, not a cascade)."""
        old = self.placements[graph_id]
        if old.kind != SINGLE:
            raise ValueError(f"cannot move sharded graph {graph_id!r}")
        nbytes = self._resident_bytes.get(graph_id)
        self.unaccount(graph_id)
        new = Placement(SINGLE, int(device_index), 1)
        self.placements[graph_id] = new
        if nbytes is not None:
            self.account(graph_id, nbytes)
        self.evictions = [0] * self.n_devices
        self.n_rebalances += 1
        return new

    # ---- reporting ---------------------------------------------------------

    def device_report(self) -> List[dict]:
        """Per-device occupancy snapshot for ``stats()``."""
        graphs: List[List[str]] = [[] for _ in range(self.n_devices)]
        for gid, p in sorted(self.placements.items()):
            if gid not in self._resident_bytes:
                continue
            for d in p.device_indices:
                graphs[d].append(gid)
        return [{"device": d, "used_bytes": self.used[d],
                 "budget_bytes": self.budget,
                 "evictions": self.evictions[d], "resident": graphs[d]}
                for d in range(self.n_devices)]
