"""Multi-graph GCN serving engine on the tuning store.

The paper's workload is inference on a fixed graph; a serving system holds
*many* such graphs — one converged configuration each — and rotates them
through bounded device memory. ``GCNServingEngine`` composes the tuning
subsystem into that shape:

* **Warm starts.** ``add_graph`` keys the ``TuningStore`` by graph
  fingerprint; a hit deserializes the ``TunedConfig`` *and* the prebuilt
  schedule arrays, so a process restart performs **zero measured sweeps and
  zero schedule rebuilds** — deserialize, upload, serve. A miss runs the
  measured sweep once (single-device, pruned by the paper's cycle model)
  and persists the winner, so the *next* restart is warm. A corrupted store
  entry is dropped and re-tuned, never crashed on.
* **Batching.** Same-graph feature requests batch into **one jitted
  forward**: the executor's whole-GCN body under ``jax.vmap`` over the
  request axis — one dispatch for the whole batch instead of one per
  request. ``submit``/``flush`` accumulate a per-graph queue;
  ``serve_batch`` is the direct path.
* **Bounded residency.** Each resident graph's device footprint — its
  executor's schedule arrays (``device_bytes``) *plus* its uploaded
  weights — counts against ``device_budget_bytes``. Admission beyond the
  budget evicts least-recently-served graphs: device arrays, weights, and
  jitted closures are dropped; the host-side schedule, config, and weight
  copies are kept, so re-admission is a re-upload — still no rebuild, no
  sweep — and thousands of graphs can rotate through a fixed HBM budget.

The engine deliberately bypasses ``tuning.registry``'s unbounded
fingerprint caches for its executors — eviction must actually free device
memory, so the engine's executor references are the only ones.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import csc as fmt
from repro.core.executor import ScheduleExecutor, release_device_steps
from repro.core.schedule import Schedule
from repro.tuning import registry, runner
from repro.tuning.space import TunedConfig
from repro.tuning.store import TuningStore


class FlushError(RuntimeError):
    """One or more per-graph batches failed during ``flush``.

    Nothing is lost: ``partial`` holds the successfully served
    ``{graph_id: logits}``, ``failures`` the ``{graph_id: exception}``,
    and every failed graph's queue was restored for retry."""

    def __init__(self, failures, partial):
        super().__init__(
            f"flush failed for graph(s) {sorted(failures)}; "
            f"{len(partial)} graph(s) served (see .partial), failed "
            f"queues restored for retry")
        self.failures = failures
        self.partial = partial


@dataclasses.dataclass
class AdmitReport:
    """What ``add_graph`` did for one graph."""
    graph_id: str
    warm_start: bool          # True: store hit — no sweep, no rebuild
    tune_seconds: float       # 0.0 on the warm path
    device_bytes: int         # resident footprint (schedule + weights)
    config: TunedConfig


@dataclasses.dataclass
class _Resident:
    graph_id: str
    fingerprint: str
    config: TunedConfig
    sched: Schedule                      # host copy — survives eviction
    params_host: dict                    # host copy — survives eviction
    params: Optional[dict] = None        # device-resident weight tree
    executor: Optional[ScheduleExecutor] = None
    fwd: Optional[callable] = None       # jitted vmapped whole-GCN forward
    bytes: int = 0                       # schedule + weight device bytes


class GCNServingEngine:
    """Serve batched GCN inference over many resident graphs concurrently.

    ``device_budget_bytes`` bounds the total device-resident schedule
    bytes; the graph being served is always kept resident, even if it
    alone exceeds the budget (a budget smaller than one graph cannot be
    honoured — it degrades to one-graph-at-a-time rotation).
    """

    def __init__(self, *, store: Optional[TuningStore] = None,
                 store_root=None,
                 device_budget_bytes: int = 64 << 20,
                 autotune_iters: int = 3, autotune_warmup: int = 1,
                 autotune_kwargs: Optional[dict] = None):
        self.store = store if store is not None else TuningStore(store_root)
        self.device_budget_bytes = int(device_budget_bytes)
        self._autotune_kwargs = dict(autotune_kwargs or {})
        reserved = {"max_devices", "store"} & set(self._autotune_kwargs)
        if reserved:
            raise ValueError(
                f"autotune_kwargs may not override {sorted(reserved)}: the "
                "engine pins max_devices=1 and its own store")
        self._autotune_kwargs.setdefault("iters", autotune_iters)
        self._autotune_kwargs.setdefault("warmup", autotune_warmup)
        self._graphs: "OrderedDict[str, _Resident]" = OrderedDict()
        self._pending: Dict[str, List[jax.Array]] = {}
        self.device_bytes_in_use = 0
        self.counters = {"store_hits": 0, "store_misses": 0,
                         "evictions": 0, "readmissions": 0,
                         "batches": 0, "requests": 0}

    # ---- admission ---------------------------------------------------------

    def add_graph(self, graph_id: str, a: fmt.COO, params: dict, *,
                  kdim: Optional[int] = None) -> AdmitReport:
        """Register a graph + trained weights and make it servable.

        ``kdim`` is the tuning probe width; it defaults to the first
        layer's output width (the width every A×(XW) product in the
        forward actually sees first)."""
        if graph_id in self._graphs:
            raise ValueError(f"graph {graph_id!r} already registered")
        if kdim is None:
            kdim = int(np.asarray(params["w0"]).shape[1])
        fp = registry.graph_fingerprint(a)
        # the engine serves single-device executors: pin the 1-device sweep
        # so the store key and the tuned mesh agree (and fold any custom
        # sweep identity exactly as autotune will)
        key = runner.store_key(self.store, fp, kdim, max_devices=1,
                               **self._autotune_kwargs)
        t0 = time.perf_counter()
        entry = self.store.load(key)
        warm = entry is not None
        if warm:
            self.counters["store_hits"] += 1
            cfg, sched = entry
            if cfg.n_devices is not None:
                raise ValueError(
                    f"GCNServingEngine serves single-device executors, but "
                    f"the stored config for {graph_id!r} requests "
                    f"n_devices={cfg.n_devices}")
            tune_s = 0.0
        executor = None
        if not warm:
            self.counters["store_misses"] += 1
            cfg = runner.autotune(a, (a.shape[1], kdim), max_devices=1,
                                  store=self.store, **self._autotune_kwargs)
            if cfg.n_devices is not None:
                raise ValueError(
                    f"GCNServingEngine serves single-device executors, but "
                    f"the tuned config for {graph_id!r} requests "
                    f"n_devices={cfg.n_devices} — remove sharded candidates "
                    f"from autotune_kwargs['sweep']")
            # take ownership of the winner's already-resident executor (the
            # sweep just measured it — no second _gather_slots precompute,
            # no second upload) ...
            executor = registry.get_executor(a, **cfg.as_executor_kwargs())
            sched = executor.sched
            # ... then release the graph from the registry's unbounded
            # caches: the sweep's ~dozen losing candidate executors must
            # not pin device memory, and *this* engine's byte budget
            # becomes the only thing keeping the winner resident
            registry.release_graph(fp)
            tune_s = time.perf_counter() - t0
        rec = _Resident(graph_id=graph_id, fingerprint=fp, config=cfg,
                        sched=sched, executor=executor,
                        params_host=jax.tree.map(np.asarray, params))
        self._graphs[graph_id] = rec
        self._admit(rec)
        return AdmitReport(graph_id=graph_id, warm_start=warm,
                           tune_seconds=tune_s, device_bytes=rec.bytes,
                           config=cfg)

    def remove_graph(self, graph_id: str) -> None:
        rec = self._graphs.pop(graph_id)
        self._pending.pop(graph_id, None)
        if rec.executor is not None:
            self.device_bytes_in_use -= rec.bytes
        release_device_steps(rec.sched)

    # ---- residency / eviction ----------------------------------------------

    def _admit(self, rec: _Resident) -> None:
        """Ensure ``rec`` is device-resident (LRU-touch + budget sweep).
        ``rec.executor`` may arrive pre-seeded (cold admission hands over
        the sweep's winner) — then only weights upload and jit remain."""
        if rec.fwd is None:
            first = rec.bytes == 0
            cfg = rec.config
            ex = rec.executor
            if ex is None:
                ex = ScheduleExecutor(rec.sched, ktile=cfg.ktile,
                                      routing=cfg.routing,
                                      bf16_accumulate=cfg.bf16_accumulate)
            rec.executor = ex
            rec.params = jax.tree.map(jnp.asarray, rec.params_host)
            # one jitted dispatch per (graph, batch size): the whole-GCN
            # body vmapped over the request axis
            rec.fwd = jax.jit(jax.vmap(ex._forward_impl, in_axes=(None, 0)))
            rec.bytes = ex.device_bytes + sum(
                int(x.nbytes) for x in jax.tree.leaves(rec.params))
            self.device_bytes_in_use += rec.bytes
            if not first:
                self.counters["readmissions"] += 1
        self._graphs.move_to_end(rec.graph_id)
        self._evict_over_budget(keep=rec.graph_id)

    def _evict(self, rec: _Resident) -> None:
        # dropping the executor, weights, and the jitted closure releases
        # the device arrays they capture; the host schedule/config/weights
        # stay for re-upload. One-hot executors also memoize their step
        # arrays in the executor module's LRU — purge that too, or the
        # bytes survive the eviction.
        rec.executor = None
        rec.params = None
        rec.fwd = None
        release_device_steps(rec.sched)
        self.device_bytes_in_use -= rec.bytes
        self.counters["evictions"] += 1

    def _evict_over_budget(self, keep: str) -> None:
        while self.device_bytes_in_use > self.device_budget_bytes:
            victim = next((r for r in self._graphs.values()
                           if r.executor is not None and r.graph_id != keep),
                          None)
            if victim is None:
                break  # only `keep` is resident; it is never evicted
            self._evict(victim)

    @property
    def resident_graphs(self) -> List[str]:
        return [g for g, r in self._graphs.items() if r.executor is not None]

    @property
    def graphs(self) -> List[str]:
        return list(self._graphs)

    # ---- serving -----------------------------------------------------------

    def serve_batch(self, graph_id: str, xs) -> jax.Array:
        """One jitted forward over a batch of same-graph feature matrices.

        ``xs`` is a sequence of ``[n, f]`` arrays (or a stacked
        ``[B, n, f]`` array); returns stacked ``[B, n, classes]`` logits."""
        rec = self._graphs[graph_id]
        xb = xs if hasattr(xs, "ndim") and xs.ndim == 3 else jnp.stack(
            [jnp.asarray(x) for x in xs])
        n = rec.sched.shape[1]
        if xb.shape[1] != n:
            raise ValueError(
                f"features have {xb.shape[1]} rows; graph {graph_id!r} "
                f"has {n} nodes")
        self._admit(rec)  # LRU touch + re-upload if evicted
        out = rec.fwd(rec.params, xb)
        # count only completed batches — a failed/retried batch must not
        # inflate the served-work stats
        self.counters["batches"] += 1
        self.counters["requests"] += int(xb.shape[0])
        return out

    def infer(self, graph_id: str, x) -> jax.Array:
        """Single-request forward (a batch of one)."""
        return self.serve_batch(graph_id, [x])[0]

    def submit(self, graph_id: str, x) -> None:
        """Queue one request; ``flush`` serves every queue in one jitted
        forward per graph. Shape is validated here so one malformed
        request can never poison a later ``flush``."""
        rec = self._graphs.get(graph_id)
        if rec is None:
            raise KeyError(f"unknown graph {graph_id!r}")
        x = jnp.asarray(x)
        n = rec.sched.shape[1]
        if x.ndim != 2 or x.shape[0] != n:
            raise ValueError(
                f"request for graph {graph_id!r} must be [n={n}, features]; "
                f"got shape {x.shape}")
        self._pending.setdefault(graph_id, []).append(x)

    def flush(self) -> Dict[str, jax.Array]:
        """Serve all queued requests, batched per graph. Returns
        ``{graph_id: [B, n, classes] logits}`` in submission order.

        A failing batch never takes the others down: every remaining
        graph is still served, the failed graphs' queues are restored for
        retry, and the raised ``FlushError`` carries the successful
        results in ``.partial`` — no computed logits are lost."""
        out, failures = {}, {}
        pending, self._pending = self._pending, {}
        for graph_id, xs in pending.items():
            try:
                out[graph_id] = self.serve_batch(graph_id, xs)
            except Exception as e:
                failures[graph_id] = e
                self._pending.setdefault(graph_id, []).extend(xs)
        if failures:
            raise FlushError(failures, out)
        return out

    def stats(self) -> dict:
        return dict(self.counters,
                    device_bytes_in_use=self.device_bytes_in_use,
                    device_budget_bytes=self.device_budget_bytes,
                    n_graphs=len(self._graphs),
                    n_resident=len(self.resident_graphs))
