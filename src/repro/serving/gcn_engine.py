"""Mesh-wide, deadline-aware GCN serving engine on the tuning store.

The paper's workload is inference on a fixed graph; a serving system holds
*many* such graphs — one converged configuration each — and rotates them
through bounded device memory across a mesh. ``GCNServingEngine`` composes
the tuning subsystem into that shape:

* **Warm starts.** ``add_graph`` keys the ``TuningStore`` by graph
  fingerprint *and mesh route*; a hit deserializes the ``TunedConfig`` and
  the prebuilt schedule arrays, so a process restart performs **zero
  measured sweeps and zero schedule rebuilds** — deserialize, upload,
  serve. A miss runs the measured sweep once and persists the winner
  (store keys already carry the mesh descriptor, so single-device and
  sharded entries coexist). A corrupted entry is dropped and re-tuned,
  never crashed on.
* **Mesh placement.** A ``serving.placement.MeshPlacer`` bin-packs each
  graph onto one device of a 1-D mesh (worst-fit by ``device_bytes``
  footprint, per-device LRU byte budgets — the paper's per-PE workload
  balancing at graph granularity). Graphs whose footprint exceeds any
  single device's budget route to a ``ShardedScheduleExecutor`` spanning
  the mesh. When eviction pressure concentrates on one device, the placer
  nominates a migration and the engine moves a resident graph to the
  coolest device (runtime rebalancing, lifted to placement).
* **Multi-replica hot graphs.** When a single graph saturates its
  device's throughput — detected from the per-request service-time EWMA ×
  queue depth the deadline scheduler already tracks — the engine **clones
  the graph onto the coolest device**: the replica reuses the
  already-deserialized ``TunedConfig`` and host schedule from the same
  ``TuningStore`` entry, so growth costs one upload and **zero sweeps,
  zero rebuilds**. Batches then split across replicas (least outstanding
  work first) and the sub-batches run concurrently; every replica is a
  bit-identical clone, so which replica serves a request is unobservable
  in the logits. When pressure subsides the replica set shrinks back
  (AWB-GCN's remote switching from a congested PE to an underloaded one,
  lifted to placement).
* **Deadline-aware batching.** ``submit(graph_id, x, deadline_s=...)``
  queues a request; queues auto-flush when a graph reaches the
  ``max_batch`` threshold, and ``poll()`` serves every queue whose
  earliest deadline is due (earliest-deadline-first across graphs; all
  batches are dispatched before any result is awaited, so batches placed
  on different devices run concurrently). Each graph's queue serves
  through **one jitted vmapped whole-GCN forward** per replica —
  bit-identical to the direct ``serve_batch`` path. Per-request latency
  and deadline hits/misses surface in ``stats()``; ``flush()`` remains
  the serve-everything-now path, in deterministic EDF order.
* **Bounded residency.** Each resident graph's device footprint — its
  executor's schedule arrays (``device_bytes``) *plus* its uploaded
  weights — counts against its device's budget, one full footprint per
  replica. Admission beyond the budget evicts least-recently-served
  graphs on that device (a hot graph's secondary replica is shed before
  any whole graph is evicted); the host-side schedule, config, and weight
  copies are kept, so re-admission is a re-upload — still no rebuild, no
  sweep.

* **Overload & fault robustness.** Arrivals don't wait: ``submit``
  returns a typed ``SubmitTicket`` and the engine bounds its queues —
  ``max_queue_depth`` **rejects** overflow instead of growing without
  bound, and (opt-in) ``shed_unmeetable`` **sheds** a request when the
  EDF load map's EWMA-predicted wait already proves its deadline
  unmeetable (cheaper to refuse now than to serve a guaranteed miss
  later). Devices fail mid-batch: a failed replica chunk retries on a
  sibling clone (bit-identical, so the retry is unobservable), transient
  dispatch failures retry with bounded exponential backoff, and a
  request that still cannot be served surfaces as a typed failure with
  every counter and outstanding-work meter consistent — never a hung
  future, never leaked charges. Backpressure (queue depths, shed/reject
  counts, per-device saturation seconds) surfaces in ``stats()``.
  ``core.executor.FAULTS`` is the test seam that injects these failures
  on demand.

The engine deliberately bypasses ``tuning.registry``'s unbounded
fingerprint caches for its executors — eviction must actually free device
memory, so the engine's executor references are the only ones.
"""

from __future__ import annotations

import dataclasses
import queue as queue_mod
import threading
import time
from collections import OrderedDict, deque
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import csc as fmt
from repro.core.executor import (
    FAULTS,
    ScheduleExecutor,
    ShardedScheduleExecutor,
    release_device_steps,
    repaired_executor,
    value_patched_executor,
)
from repro.core.schedule import (
    Schedule,
    repair_schedule,
    slot_entry_keys,
    value_patch_schedule,
)
from repro.serving.errors import (
    FlushError,
    RequestFailure,
    ServingError,
    UnknownGraphError,
)
from repro.serving.placement import REPLICATED, SHARDED, SINGLE, MeshPlacer, Placement
from repro.serving.policy import (
    GROW,
    SHRINK,
    SVC_FLOOR_S,
    SVC_SAFETY,
    GraphState,
    HeuristicPolicy,
    LearnedServiceTimePolicy,
    PolicyState,
    SchedulingPolicy,
)
from repro.serving.types import ACCEPTED, REJECTED, SHED, SubmitTicket
from repro.tuning import registry, runner, space
from repro.tuning.space import TunedConfig
from repro.tuning.store import TuningStore

#: pre-tune footprint estimate: ~16 bytes per non-zero covers the gather
#: path's 12 bytes/slot plus schedule padding slack — only used to route
#: giant graphs to the sharded path before their schedule exists.
_BYTES_PER_NNZ_EST = 16

#: historical aliases of the dispatch-headroom constants, which now live
#: with the scheduling policies in ``serving.policy``
_SVC_SAFETY = SVC_SAFETY
_SVC_FLOOR_S = SVC_FLOOR_S

#: test seam: the await used by the completion path (monkeypatched to
#: simulate an asynchronously-failing computation without a real device
#: fault).
_block_until_ready = jax.block_until_ready

#: test seam: the sleep used by dispatch-retry backoff (monkeypatched so
#: backoff tests record delays instead of waiting them out).
_sleep = time.sleep

#: bounded reservoir of recent per-request latencies (seconds) backing
#: the p50/p95/p99 percentiles in ``stats()``.
_LAT_RESERVOIR = 65536

# SubmitTicket / ACCEPTED / REJECTED / SHED and the typed errors
# (ServingError, UnknownGraphError, RequestFailure, FlushError) moved to
# ``serving.types`` / ``serving.errors``; re-exported above from their
# historical import path.
__all_reexports__ = (
    "ACCEPTED",
    "REJECTED",
    "SHED",
    "SubmitTicket",
    "ServingError",
    "UnknownGraphError",
    "RequestFailure",
    "FlushError",
)


@dataclasses.dataclass
class _PartFailure:
    """One sub-batch that stayed failed after sibling retries: the
    request-order slice it covered and the final exception."""
    offset: int
    n: int
    exc: Exception


@dataclasses.dataclass
class AdmitReport:
    """What ``add_graph`` did for one graph."""
    graph_id: str
    warm_start: bool  # True: store hit — no sweep, no rebuild
    tune_seconds: float  # 0.0 on the warm path
    device_bytes: int  # resident footprint (schedule + weights)
    config: TunedConfig
    placement: Placement  # which device(s) the graph serves from


@dataclasses.dataclass
class UpdateReport:
    """What ``update_graph`` did for one edge delta.

    ``repaired`` is True on the incremental path (schedule patched in
    place, scoped re-upload) and False when cumulative drift forced the
    full re-tune fallback. ``fingerprint`` is the content hash of the
    mutated graph (what a fresh ``add_graph`` would compute) — on the
    incremental path it is ``""`` because the O(nnz) hash + store write
    run on the async persist worker (``drain_persists()`` then
    ``engine._graphs[gid].fingerprint`` to observe it); ``lineage`` is
    the cheap chained delta fingerprint, available on every path.
    ``steps_reused``/
    ``windows_reused`` quantify how much of the old schedule carried
    over, and ``scoped_upload`` reports whether the executor patched
    only dirty device slots instead of re-uploading everything."""

    graph_id: str
    repaired: bool
    revision: int
    fingerprint: str
    lineage: str
    drift: float
    nnz: int
    update_seconds: float
    steps_reused: int = 0
    windows_reused: int = 0
    windows_total: int = 0
    scoped_upload: bool = False
    fell_back: bool = False  # repair degenerated to a full rebuild


@dataclasses.dataclass
class _Request:
    """One queued inference request."""
    rid: int
    x: jax.Array
    submit_t: float  # monotonic seconds
    deadline: Optional[float]  # absolute monotonic; None = no SLA


@dataclasses.dataclass
class _Unit:
    """One device-resident serving clone of a graph (the primary or a
    replica): a pinned executor, the uploaded weights, and the jitted
    vmapped whole-GCN forward that serves batches through them."""
    device_index: Optional[int]  # None: sharded (spans the mesh)
    executor: object
    fwd: callable
    params: dict
    bytes: int


@dataclasses.dataclass
class _Part:
    """One dispatched sub-batch of a serve call: either an async
    jit dispatch (``out``) or a thread-pool future (``future``) when the
    batch split across replicas. ``est`` is the outstanding-work charge
    held against ``device_index`` until completion. ``unit``/``chunk``/
    ``offset`` let the completion path retry this exact sub-batch on a
    sibling replica and map a terminal failure back to the request-order
    slice it covered."""
    device_index: Optional[int]
    n: int
    est: float
    out: object = None
    future: object = None
    unit: Optional[_Unit] = None
    chunk: object = None
    offset: int = 0


@dataclasses.dataclass
class _Resident:
    graph_id: str
    fingerprint: str  # guarded-by: _swap_lock (persist worker back-fills)
    config: TunedConfig
    sched: Schedule  # host copy — survives eviction
    params_host: dict  # host copy — survives eviction
    params: Optional[dict] = None  # device weight tree; guarded-by: _swap_lock
    #: ScheduleExecutor or ShardedScheduleExecutor (None while evicted)
    executor: Optional[object] = None  # guarded-by: _swap_lock
    fwd: Optional[callable] = None  # jitted vmapped fwd; guarded-by: _swap_lock
    bytes: int = 0  # schedule + weight device bytes; guarded-by: _swap_lock
    #: secondary replicas by device index (the primary lives in the
    #: fields above, on the placement's ``device_index``)
    replicas: Dict[int, _Unit] = dataclasses.field(
        default_factory=dict
    )  # guarded-by: _swap_lock
    # ---- streaming-update state (DESIGN.md §11) ----
    #: host numpy COO of the graph as currently served (PAD-stripped,
    #: row-major) — the base ``update_graph`` applies edge deltas to
    coo: Optional[fmt.COO] = None
    #: cached per-row nnz histogram, updated incrementally from each
    #: ``DeltaReport`` so repair never re-scans the graph
    per_row: Optional[np.ndarray] = None
    kdim: int = 0  # tuning probe width (re-tune fallback reuses it)
    revision: int = 0  # repair generation, 0 = cold; guarded-by: _swap_lock
    orig_nnz: int = 0  # nnz at the last full (re-)tune
    drift_nnz: int = 0  # cumulative delta entries since then
    #: chained delta fingerprint — the deterministic lineage anchor for
    #: the next update. Decoupled from ``fingerprint`` because content
    #: fingerprints of async-persisted revisions land *after* the swap;
    #: chaining on them would make the lineage timing-dependent.
    lineage: str = ""
    #: lazily-built ``slot_entry_keys`` index of ``sched`` for the
    #: value-only O(|delta|) update path; cleared whenever a swap changes
    #: the schedule *structure* (a value patch keeps the layout, so the
    #: index survives it)
    slot_cache: Optional[tuple] = None
    # ---- locality reorder state (core.reorder) ----
    #: the row permutation ``sched`` was built under (``perm[new] = old``)
    #: and its inverse; both None for the identity order. Executors built
    #: from ``sched`` un-permute with ``inv`` so outputs stay in original
    #: row order.
    perm: Optional[np.ndarray] = None
    inv: Optional[np.ndarray] = None
    #: permuted-row twin of ``coo`` (row ``inv[r]`` holds original row
    #: ``r``) — the base schedule repair operates on; ``coo`` itself stays
    #: in original order because content fingerprints and delta lineage
    #: must not depend on the accepted permutation. None when no reorder.
    pcoo: Optional[fmt.COO] = None


#: ``_swap_in`` sentinel: leave the record's reorder fields untouched
#: (repairs keep the admission permutation; only a re-tune replaces it).
_KEEP = object()


def _geometry_kwargs(cfg: TunedConfig) -> dict:
    """``as_schedule_kwargs`` minus the ``reorder`` axis — what
    ``repair_schedule`` accepts (the repair already runs in the permuted
    row space; re-stating the permutation would double-apply it)."""
    kw = cfg.as_schedule_kwargs()
    kw.pop("reorder", None)
    return kw


def _dedup_value_delta(delta: fmt.EdgeDelta, n: int):
    """The delta's effective value writes: last-write-wins per ``(row,
    col)`` (matching ``csc.apply_edge_delta``), with ``val == 0`` entries
    dropped — on the pure-value path those are no-op removals of absent
    edges (an actual removal would have taken the structural path)."""
    rows = np.asarray(delta.row, np.int64)
    cols = np.asarray(delta.col, np.int64)
    vals = np.asarray(delta.val)
    key = rows * n + cols
    order = np.argsort(key, kind="stable")
    ks = key[order]
    last = np.ones(ks.size, bool)
    last[:-1] = ks[1:] != ks[:-1]
    keep = order[last]
    m = vals[keep] != 0.0
    keep = keep[m]
    return rows[keep], cols[keep], vals[keep]


def _earliest_deadline(queue: List[_Request]) -> float:
    """Earliest deadline in a queue (+inf when no request carries one) —
    the EDF sort key across graphs."""
    dls = [r.deadline for r in queue if r.deadline is not None]
    return min(dls) if dls else float("inf")


class GCNServingEngine:
    """Serve batched GCN inference over many resident graphs on a mesh.

    ``devices`` selects the mesh: None (default) serves on jax's first
    device exactly like the old single-device engine; an int ``n`` takes
    ``jax.devices()[:n]``; a list of ``jax.Device`` uses those. With a
    multi-device mesh, each admitted graph is bin-packed onto one device
    (``serving.placement.MeshPlacer``), graphs too big for any single
    device's ``device_budget_bytes`` serve through a
    ``ShardedScheduleExecutor`` spanning the whole mesh, and a graph hot
    enough to saturate its device replicates onto up to ``max_replicas``
    devices (grown when its queue backlog — per-request service-time EWMA
    × queue depth — exceeds ``replicate_after_s`` seconds; shrunk after
    ``replica_shrink_after`` consecutive calm ``poll``s below a quarter of
    that).

    ``device_budget_bytes`` bounds each device's resident schedule+weight
    bytes; the graph being served is always kept resident, even if it
    alone exceeds the budget (a budget smaller than one graph cannot be
    honoured — it degrades to one-graph-at-a-time rotation).

    ``policy`` plugs a ``serving.policy.SchedulingPolicy`` into every
    scheduling choice point — admission placement, replica grow/shrink,
    submit-time and dispatch-time shedding, and queue ordering/dueness.
    The default ``HeuristicPolicy()`` reproduces the engine's historical
    behavior decision-for-decision; ``LearnedServiceTimePolicy()`` swaps
    the EWMA service-time model for an online-fitted predictor.

    Admission control: ``max_queue_depth`` bounds every per-graph queue
    (``submit`` returns a REJECTED ``SubmitTicket`` at the bound; None =
    unbounded, the historical behaviour). ``shed_unmeetable=True`` turns
    on deadline-aware shedding: a request whose deadline the EDF load
    map's EWMA-predicted wait already rules out is dropped — at submit
    time and again at dispatch time — instead of burning device time on
    a guaranteed miss. Both knobs are plain attributes and may be
    retuned between calls. Transient dispatch failures retry up to
    ``max_dispatch_retries`` times with exponential backoff starting at
    ``retry_backoff_s`` seconds (validation errors never retry).
    """

    def __init__(
        self,
        *,
        store: Optional[TuningStore] = None,
        store_root=None,
        policy: Optional[SchedulingPolicy] = None,
        device_budget_bytes: int = 64 << 20,
        devices=None,
        max_batch: int = 32,
        rebalance_after: int = 4,
        max_replicas: Optional[int] = None,
        replicate_after_s: float = 0.25,
        replica_shrink_after: int = 3,
        max_queue_depth: Optional[int] = None,
        shed_unmeetable: bool = False,
        max_dispatch_retries: int = 2,
        retry_backoff_s: float = 0.02,
        repair_drift_threshold: float = 0.25,
        autotune_iters: int = 3,
        autotune_warmup: int = 1,
        autotune_kwargs: Optional[dict] = None,
    ):
        self.store = store if store is not None else TuningStore(store_root)
        #: the scheduling seam: every placement, replication, shedding,
        #: and dispatch-ordering decision goes through this object (see
        #: ``serving.policy``); default is the extracted heuristics
        self.policy: SchedulingPolicy = (
            policy if policy is not None else HeuristicPolicy()
        )
        self.device_budget_bytes = int(device_budget_bytes)
        self.max_batch = int(max_batch)
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if devices is None:
            self.devices = [jax.devices()[0]]
        elif isinstance(devices, int):
            avail = jax.devices()
            if not 1 <= devices <= len(avail):
                raise ValueError(
                    f"devices={devices} but this host exposes "
                    f"{len(avail)} device(s)"
                )
            self.devices = list(avail[:devices])
        else:
            self.devices = list(devices)
        self.n_devices = len(self.devices)
        if self.n_devices > 1:
            from jax.sharding import Mesh

            self._mesh = Mesh(np.asarray(self.devices), ("dev",))
        else:
            self._mesh = None
        self.placer = MeshPlacer(
            self.n_devices, self.device_budget_bytes, rebalance_after=rebalance_after
        )
        if max_replicas is not None and max_replicas < 1:
            raise ValueError(f"max_replicas must be >= 1, got {max_replicas}")
        self.max_replicas = (
            self.n_devices
            if max_replicas is None
            else min(int(max_replicas), self.n_devices)
        )
        self.replicate_after_s = float(replicate_after_s)
        self.replica_shrink_after = int(replica_shrink_after)
        if max_queue_depth is not None and max_queue_depth < 1:
            raise ValueError(
                f"max_queue_depth must be >= 1 or None, got {max_queue_depth}"
            )
        self.max_queue_depth = None if max_queue_depth is None else int(max_queue_depth)
        self.shed_unmeetable = bool(shed_unmeetable)
        if max_dispatch_retries < 0:
            raise ValueError(
                f"max_dispatch_retries must be >= 0, got {max_dispatch_retries}"
            )
        self.max_dispatch_retries = int(max_dispatch_retries)
        self.retry_backoff_s = float(retry_backoff_s)
        if repair_drift_threshold <= 0:
            raise ValueError(
                f"repair_drift_threshold must be > 0, got "
                f"{repair_drift_threshold}"
            )
        self.repair_drift_threshold = float(repair_drift_threshold)
        #: serializes executor swaps against unit snapshots: a dispatch
        #: reading ``_units`` either sees the whole old executor set or
        #: the whole new one, never a mix — the zero-gap guarantee of
        #: ``update_graph`` (in-flight parts hold their own unit refs)
        self._swap_lock = threading.Lock()
        #: async schedule-persist pipeline: content fingerprint + store
        #: write of a repaired revision run on a worker thread, off the
        #: update hot path (both are O(nnz); the repair itself is O(Δ))
        self._persist_q: "queue_mod.Queue" = queue_mod.Queue()
        self._persist_thread: Optional[threading.Thread] = (
            None  # guarded-by: _persist_spawn_lock
        )
        self._persist_spawn_lock = threading.Lock()
        self._autotune_kwargs = dict(autotune_kwargs or {})
        reserved = {"max_devices", "store"} & set(self._autotune_kwargs)
        if reserved:
            raise ValueError(
                f"autotune_kwargs may not override {sorted(reserved)}: the "
                "engine pins the mesh route and its own store"
            )
        self._autotune_kwargs.setdefault("iters", autotune_iters)
        self._autotune_kwargs.setdefault("warmup", autotune_warmup)
        self._graphs: "OrderedDict[str, _Resident]" = OrderedDict()
        self._pending: Dict[str, List[_Request]] = {}
        #: batches completed by a threshold-triggered auto-flush, awaiting
        #: pickup by the next poll()/flush()
        self._ready: Dict[str, List[jax.Array]] = {}
        self._svc_ewma: Dict[str, float] = {}  # per-graph batch seconds
        #: per-graph per-*request* EWMA seconds — the saturation signal
        #: (× queue depth = backlog a single replica would need)
        self._svc_req_ewma: Dict[str, float] = {}
        #: consecutive calm polls per replicated graph (shrink hysteresis)
        self._calm_polls: Dict[str, int] = {}
        #: device index → estimated seconds of dispatched-but-incomplete
        #: work (the least-outstanding-work replica balancer's meter)
        self._dev_outstanding: Dict[int, float] = {}
        self._pool: Optional[ThreadPoolExecutor] = None
        self._next_rid = 0
        self.device_bytes_in_use = 0
        self._lat_n, self._lat_total, self._lat_max = 0, 0.0, 0.0
        #: bounded reservoir of recent request latencies (seconds) for
        #: the percentile figures in stats()
        self._lat_samples: "deque[float]" = deque(maxlen=_LAT_RESERVOIR)
        # the overload accounting identity over the queue path:
        #   submitted == queue_served + shed + rejected + dropped + pending
        # (`requests` also counts direct serve_batch work, so the queue
        # path gets its own served counter; `dropped` settles requests a
        # remove_graph failed while still queued)
        self.counters = {
            "store_hits": 0,
            "store_misses": 0,
            "evictions": 0,
            "readmissions": 0,
            "rebalances": 0,
            "batches": 0,
            "requests": 0,
            "deadline_met": 0,
            "deadline_misses": 0,
            "replicas_added": 0,
            "replicas_dropped": 0,
            "submitted": 0,
            "queue_served": 0,
            "shed": 0,
            "rejected": 0,
            "dropped": 0,
            "request_failures": 0,
            "dispatch_retries": 0,
            "chunk_retries": 0,
            "graph_updates": 0,
            "update_retunes": 0,
        }

    # ---- policy state snapshot ---------------------------------------------

    def _graph_state(self, gid: str, rec: "Optional[_Resident]" = None) -> GraphState:
        """One graph's immutable policy-visible state (see
        ``serving.policy.GraphState``). ``rec`` may be None for a queue
        whose graph record is absent (scheduler-only test stubs build
        such states); its graph features degrade to zeros."""
        if rec is None:
            rec = self._graphs.get(gid)
        p = self.placer.placement_of(gid)
        q = self._pending.get(gid) or []
        has_coo = rec is not None and rec.coo is not None
        with self._swap_lock:
            rec_bytes = 0 if rec is None else int(rec.bytes)
        return GraphState(
            graph_id=gid,
            nnz=int(np.asarray(rec.coo.row).shape[0]) if has_coo else 0,
            n_rows=int(rec.coo.shape[0]) if has_coo else 0,
            bytes=rec_bytes,
            resident=self.placer.is_resident(gid),
            kind=None if p is None else p.kind,
            device_index=None if p is None else p.device_index,
            device_indices=() if p is None else tuple(p.device_indices),
            queue_depth=len(q),
            earliest_deadline=_earliest_deadline(q),
            svc_ewma=self._svc_ewma.get(gid, 0.0),
            svc_req_ewma=self._svc_req_ewma.get(gid, 0.0),
            calm_polls=self._calm_polls.get(gid, 0),
        )

    def _policy_state(self, now: Optional[float] = None) -> PolicyState:
        """Snapshot everything a scheduling decision may read. Rebuilt
        before every policy consultation — decisions that mutate engine
        state (a replica grown, a queue popped) never leak into a stale
        snapshot."""
        if now is None:
            now = time.monotonic()
        return PolicyState(
            now=now,
            n_devices=self.n_devices,
            budget_bytes=self.placer.budget,
            used_bytes=tuple(self.placer.used),
            outstanding_s=tuple(
                self._dev_outstanding.get(d, 0.0) for d in range(self.n_devices)
            ),
            max_replicas=self.max_replicas,
            replicate_after_s=self.replicate_after_s,
            replica_shrink_after=self.replica_shrink_after,
            max_batch=self.max_batch,
            # every admitted graph, plus any queue without a graph record
            # (scheduler-only stubs hand-build those)
            graphs={
                g: self._graph_state(g)
                for g in [
                    *self._graphs,
                    *(q for q in self._pending if q not in self._graphs),
                ]
            },
        )

    # ---- admission ---------------------------------------------------------

    def _estimate_bytes(self, a: fmt.COO, params: dict) -> int:
        """Pre-tune footprint estimate (schedule + weights) — routes giant
        graphs to the sharded path before any sweep runs."""
        nnz = int(np.asarray(a.row).shape[0])
        weights = sum(int(np.asarray(w).nbytes) for w in jax.tree.leaves(params))
        return nnz * _BYTES_PER_NNZ_EST + weights

    def _sharded_autotune_kwargs(self, a: fmt.COO) -> dict:
        """The autotune kwargs of the sharded route: every sweep candidate
        pinned to the full mesh width (a caller-supplied sweep keeps its
        geometries; the default uses the sharded gather candidates)."""
        kw = dict(self._autotune_kwargs)
        base = kw.pop("sweep", None)
        if base is None:
            # force=True: this route exists because the graph does NOT fit
            # one device — the perf-elective minimum-work gate
            # (space.sharded_worth_it) must not empty the sweep here
            kw["sweep"] = space.sharded_sweep(a, (self.n_devices,), force=True)
        else:
            kw["sweep"] = [dict(c, n_devices=self.n_devices) for c in base]
        return kw

    def add_graph(
        self, graph_id: str, a: fmt.COO, params: dict, *, kdim: Optional[int] = None
    ) -> AdmitReport:
        """Register a graph + trained weights and make it servable.

        The routing decision tree: estimate the footprint; if it exceeds
        one device's budget on a multi-device mesh, the graph takes the
        **sharded route** (store key + sweep at the full mesh width),
        otherwise the **single-device route** (store key + sweep pinned to
        one device, then bin-packed placement). Either route warm-starts
        from the store when populated. ``kdim`` is the tuning probe width;
        it defaults to the first layer's output width."""
        if graph_id in self._graphs:
            raise ValueError(f"graph {graph_id!r} already registered")
        if kdim is None:
            kdim = int(np.asarray(params["w0"]).shape[1])
        fp = registry.graph_fingerprint(a)
        est = self._estimate_bytes(a, params)
        sharded_route = est > self.device_budget_bytes and self.n_devices > 1
        if sharded_route:
            tune_kw = self._sharded_autotune_kwargs(a)
            max_devices = self.n_devices
        else:
            tune_kw = self._autotune_kwargs
            max_devices = 1
        key = runner.store_key(self.store, fp, kdim, max_devices=max_devices, **tune_kw)
        t0 = time.perf_counter()
        entry = self.store.load(key)
        warm = entry is not None
        if warm:
            self._count("store_hits")
            cfg, sched, perm = entry
            self._check_route(graph_id, cfg, sharded_route, "stored")
            # the entry's permutation is adopted verbatim — it is the one
            # the persisted schedule was built under, which a fresh
            # recompute is not guaranteed to reproduce after repairs
            registry.adopt_reorder(fp, cfg.reorder, perm)
            perm, inv = registry.get_reorder(a, cfg.reorder, fingerprint=fp)
            tune_s = 0.0
        else:
            self._count("store_misses")
            cfg = runner.autotune(
                a,
                (a.shape[1], kdim),
                max_devices=max_devices,
                store=self.store,
                **tune_kw,
            )
            self._check_route(graph_id, cfg, sharded_route, "tuned")
            sched = registry.get_schedule(a, **cfg.as_schedule_kwargs(), fingerprint=fp)
            perm, inv = registry.get_reorder(a, cfg.reorder, fingerprint=fp)
            # release the graph from the registry's unbounded caches: the
            # sweep's ~dozen losing candidate executors must not pin device
            # memory, and *this* engine's per-device budgets become the
            # only thing keeping anything resident (perm/inv above are
            # plain refs — purging the cache does not invalidate them)
            registry.release_graph(fp)
            tune_s = time.perf_counter() - t0
        # host-resident base for streaming updates: PAD-stripped numpy
        # COO + its per-row nnz histogram (kept current by DeltaReports)
        row = np.asarray(a.row)
        keep = row != fmt.PAD_IDX
        col, val = np.asarray(a.col), np.asarray(a.val)
        if not keep.all():
            row, col, val = row[keep], col[keep], val[keep]
        host_coo = fmt.COO(row.astype(np.int32), col.astype(np.int32), val, a.shape)
        rec = _Resident(
            graph_id=graph_id,
            fingerprint=fp,
            lineage=fp,
            config=cfg,
            sched=sched,
            params_host=jax.tree.map(np.asarray, params),
            coo=host_coo,
            per_row=np.bincount(row.astype(np.int64), minlength=a.shape[0]),
            kdim=int(kdim),
            orig_nnz=int(row.shape[0]),
            perm=perm,
            inv=inv,
            pcoo=None if perm is None else fmt.permute_coo(host_coo, perm),
        )
        self._graphs[graph_id] = rec
        decision = self.policy.place(self._policy_state(), graph_id, est)
        placement = self.placer.place(graph_id, est, decision=decision)
        self._admit(rec)
        return AdmitReport(
            graph_id=graph_id,
            warm_start=warm,
            tune_seconds=tune_s,
            device_bytes=rec.bytes,
            config=cfg,
            placement=placement,
        )

    def _check_route(
        self, graph_id: str, cfg: TunedConfig, sharded_route: bool, origin: str
    ) -> None:
        if sharded_route:
            if cfg.n_devices != self.n_devices:
                raise ValueError(
                    f"graph {graph_id!r} takes the sharded route on this "
                    f"{self.n_devices}-device mesh, but the {origin} config "
                    f"requests n_devices={cfg.n_devices}"
                )
        elif cfg.n_devices is not None:
            raise ValueError(
                f"graph {graph_id!r} takes the single-device route, but "
                f"the {origin} config requests n_devices={cfg.n_devices} — "
                "remove sharded candidates from autotune_kwargs['sweep']"
            )

    def remove_graph(self, graph_id: str) -> None:
        """Drop a graph entirely: executors, replicas, placement, queues.

        Pending queued requests cannot be served once the graph is gone;
        silently discarding them would break the accounting identity
        (``submitted == queue_served + shed + rejected + dropped +
        pending``), so they are **failed**: settled exactly once into the
        ``dropped`` counter and surfaced as one typed ``RequestFailure``
        raised *after* the removal fully completed — the engine state is
        clean whether or not the caller catches it."""
        if graph_id not in self._graphs:
            raise UnknownGraphError(graph_id, "remove_graph")
        rec = self._graphs.pop(graph_id)
        with self._swap_lock:
            replica_devs = list(rec.replicas)
        for d in replica_devs:
            self._drop_replica(rec, d, shrink=False)
        dropped = self._pending.pop(graph_id, None) or []
        self._ready.pop(graph_id, None)
        self._svc_ewma.pop(graph_id, None)
        self._svc_req_ewma.pop(graph_id, None)
        self._calm_polls.pop(graph_id, None)
        with self._swap_lock:
            freed = rec.bytes if rec.executor is not None else 0
        self.device_bytes_in_use -= freed
        self.placer.forget(graph_id)
        release_device_steps(rec.sched)
        if dropped:
            self._count("dropped", len(dropped))
            raise RequestFailure(
                graph_id,
                RuntimeError("graph removed while requests were queued"),
                len(dropped),
            )

    # ---- streaming updates (DESIGN.md §11) ---------------------------------

    @staticmethod
    def _weight_bytes(params) -> int:
        return sum(int(x.nbytes) for x in jax.tree.leaves(params))

    def _fresh_executor(
        self,
        sched: Schedule,
        cfg: TunedConfig,
        device_index: Optional[int],
        row_unperm: Optional[np.ndarray] = None,
    ):
        """Cold executor for one serving clone (the re-tune fallback's
        builder — full upload, fresh jit closures)."""
        if device_index is None:  # sharded: spans the mesh
            return ShardedScheduleExecutor(
                sched,
                mesh=self._mesh,
                ktile=cfg.ktile,
                routing=cfg.routing,
                bf16_accumulate=cfg.bf16_accumulate,
                row_unperm=row_unperm,
            )
        _, handle = self._unit_handle(device_index)
        return ScheduleExecutor(
            sched,
            ktile=cfg.ktile,
            routing=cfg.routing,
            bf16_accumulate=cfg.bf16_accumulate,
            device=handle,
            row_unperm=row_unperm,
        )

    def _rebuilt_units(self, rec: _Resident, p: Placement, build):
        """New executor + jitted forward for every resident clone of one
        graph — primary and secondary replicas — via ``build(old_executor,
        device_index)``. Runs *outside* the swap lock: device memory
        transiently holds old and new copies while in-flight batches keep
        serving on the old closures. Weights are reused in place (an edge
        delta never changes them), so no weight re-upload."""
        with self._swap_lock:
            old_ex, params = rec.executor, rec.params
            old_reps = dict(rec.replicas)
        primary_dev = None if p.kind == SHARDED else p.device_index
        ex = build(old_ex, primary_dev)
        fwd = jax.jit(jax.vmap(ex._forward_impl, in_axes=(None, 0)))
        primary = _Unit(
            primary_dev,
            ex,
            fwd,
            params,
            ex.device_bytes + self._weight_bytes(params),
        )
        reps = {}
        for d, unit in old_reps.items():
            rex = build(unit.executor, d)
            rfwd = jax.jit(jax.vmap(rex._forward_impl, in_axes=(None, 0)))
            reps[d] = _Unit(
                d,
                rex,
                rfwd,
                unit.params,
                rex.device_bytes + self._weight_bytes(unit.params),
            )
        return primary, reps

    def _swap_in(
        self,
        rec: _Resident,
        units,
        *,
        coo,
        per_row,
        sched: Schedule,
        fingerprint: Optional[str],
        lineage: Optional[str] = None,
        config: Optional[TunedConfig] = None,
        reset_drift: bool = False,
        keep_slot_cache: bool = False,
        pcoo=None,
        perm=_KEEP,
        inv=_KEEP,
    ) -> None:
        """Atomically publish a graph's new host state and (when resident)
        its rebuilt executor set — the versioned swap protocol: new
        dispatches snapshot the new units, in-flight batches finish on the
        old executors their ``_Part``s still reference, and no request
        ever observes a missing executor.

        ``fingerprint=None`` defers the content fingerprint: the async
        persist worker fills it in (under this same lock) once computed,
        provided the revision hasn't moved on by then.

        ``pcoo`` is the new permuted-row COO twin (None for the identity
        order); ``perm``/``inv`` default to the ``_KEEP`` sentinel — a
        repair keeps the admission permutation, only the re-tune path
        passes a replacement."""
        old_sched = rec.sched
        with self._swap_lock:
            resident = rec.fwd is not None and units is not None
            rec.coo = coo
            rec.per_row = per_row
            rec.sched = sched
            rec.pcoo = pcoo
            if perm is not _KEEP:
                rec.perm = perm
                rec.inv = inv
            if fingerprint is not None:
                rec.fingerprint = fingerprint
            if lineage is not None:
                rec.lineage = lineage
            if not keep_slot_cache:
                rec.slot_cache = None
            rec.revision += 1
            if config is not None:
                rec.config = config
            if reset_drift:
                rec.orig_nnz = int(np.asarray(coo.row).shape[0])
                rec.drift_nnz = 0
            if resident:
                primary, reps = units
                old_total = rec.bytes + sum(u.bytes for u in rec.replicas.values())
                rec.executor, rec.fwd = primary.executor, primary.fwd
                rec.params, rec.bytes = primary.params, primary.bytes
                rec.replicas = reps
                new_total = primary.bytes + sum(u.bytes for u in reps.values())
        # old-schedule cleanup + byte accounting happen outside the lock:
        # they touch no field a dispatch snapshot reads
        release_device_steps(old_sched)
        if resident:
            self.placer.reaccount(rec.graph_id, primary.bytes)
            self.device_bytes_in_use += new_total - old_total
            self._evict_over_budget(keep=rec.graph_id)

    def update_graph(self, graph_id: str, delta: fmt.EdgeDelta) -> UpdateReport:
        """Apply a batch of edge mutations to a served graph with
        incremental schedule repair — AWB-GCN's runtime rebalancing moves
        (distribution smoothing, remote switching, row remapping) applied
        as *delta operators* on the converged schedule instead of a
        from-scratch rebuild.

        The incremental path patches the host COO (``csc.
        apply_edge_delta``), repairs the balanced schedule in place
        (``schedule.repair_schedule`` — bit-identical to a cold
        ``build_balanced_schedule`` on the mutated graph), splices every
        resident clone's executor with a scoped re-upload of just the
        dirty step slices (``executor.repaired_executor``; the sharded
        variant re-uploads only affected device shards), persists the
        repaired schedule under the mutated graph's content fingerprint
        (a restart warm-starts it with zero sweeps), and atomically swaps
        — in-flight batches finish on the old executors, new dispatches
        route to the new ones, zero serving gap.

        Past ``repair_drift_threshold`` (cumulative delta nnz vs. the
        nnz at the last full tune), repeated repairs have drifted the
        schedule's geometry assumptions far enough that re-tuning is
        worth the cost: the update falls back to a **full re-tune** of
        the mutated graph (measured sweep unless the store already holds
        the answer), published through the same swap protocol. The
        re-tune runs synchronously here — single-process engine — but
        the swap protocol is exactly what lets a deployment run it on a
        background thread: serving continues on the repaired executors
        until the tuned replacement swaps in.

        An **evicted** graph updates host-side only (COO, histogram,
        schedule, fingerprint); its next re-admission uploads the
        repaired schedule fresh. Weights are untouched either way.
        Raises ``UnknownGraphError`` for an unknown graph and
        ``ValueError`` for an out-of-bounds delta (state unchanged)."""
        rec = self._graphs.get(graph_id)
        if rec is None:
            raise UnknownGraphError(graph_id, "update_graph")
        t0 = time.perf_counter()
        new_coo, report = fmt.apply_edge_delta(rec.coo, delta, with_report=True)
        per_row = rec.per_row
        if report.touched_rows.size:
            per_row = per_row.copy()
            per_row[report.touched_rows] += report.row_nnz_delta
        self._count("graph_updates")
        rec.drift_nnz += report.n_added + report.n_removed + report.n_updated
        drift = rec.drift_nnz / max(1, rec.orig_nnz)
        lineage = registry.delta_fingerprint(rec.lineage, delta, rec.revision + 1)
        if drift > self.repair_drift_threshold:
            return self._retune_updated(rec, new_coo, per_row, drift, lineage, t0)
        # a reordered graph repairs on its *permuted* side: the delta's
        # rows compose with the admission permutation (``inv[old] = new``),
        # the permuted COO twin absorbs it, and the repair sees the same
        # row space the schedule was built in. Content fingerprint and
        # lineage above stay on the original-order COO — they must not
        # depend on which permutation the sweep happened to accept.
        if rec.perm is not None:
            pdelta = fmt.EdgeDelta(
                rec.inv[np.asarray(delta.row, np.int64)],
                np.asarray(delta.col),
                np.asarray(delta.val),
            )
            new_pcoo, preport = fmt.apply_edge_delta(
                rec.pcoo, pdelta, with_report=True
            )
            touched = preport.touched_rows
            per_row_old_s, per_row_new_s = rec.per_row[rec.perm], per_row[rec.perm]
            repair_base = new_pcoo
        else:
            new_pcoo = None
            touched = report.touched_rows
            per_row_old_s, per_row_new_s = rec.per_row, per_row
            repair_base = new_coo
        patched = None
        if report.n_added == 0 and report.n_removed == 0:
            # pure value update: structure (hence slot layout) unchanged —
            # the O(|delta|) lane patches just the affected ``val`` slots
            if rec.slot_cache is None:
                rec.slot_cache = slot_entry_keys(rec.sched)
            rows, cols, vals = _dedup_value_delta(delta, rec.coo.shape[1])
            if rec.perm is not None:
                rows = rec.inv[rows]
            patched = value_patch_schedule(rec.sched, rec.slot_cache, rows, cols, vals)
        if patched is not None:
            new_sched, slots = patched
            units = None
            if rec.fwd is not None:
                units = self._rebuilt_units(
                    rec,
                    self.placer.placement_of(graph_id),
                    lambda old_ex, _d: value_patched_executor(
                        old_ex, new_sched, slots, new_sched.val[slots]
                    ),
                )
            self._swap_in(
                rec,
                units,
                coo=new_coo,
                per_row=per_row,
                sched=new_sched,
                fingerprint=None,
                lineage=lineage,
                keep_slot_cache=True,
                pcoo=new_pcoo,
            )
            self._enqueue_persist(rec, new_coo, rec.config, new_sched)
            scoped = (
                units is not None
                and bool(getattr(units[0].executor, "scoped_upload", False))
            )
            nw = new_sched.n_windows
            return UpdateReport(
                graph_id=graph_id,
                repaired=True,
                revision=rec.revision,
                fingerprint="",
                lineage=lineage,
                drift=drift,
                nnz=int(np.asarray(new_coo.row).shape[0]),
                update_seconds=time.perf_counter() - t0,
                steps_reused=new_sched.n_steps,
                windows_reused=nw,
                windows_total=nw,
                scoped_upload=scoped,
                fell_back=False,
            )
        new_sched, stats = repair_schedule(
            rec.sched,
            None,
            repair_base,
            touched,
            per_row_old=per_row_old_s,
            per_row_new=per_row_new_s,
            **_geometry_kwargs(rec.config),
        )
        units = None
        if rec.fwd is not None:
            units = self._rebuilt_units(
                rec,
                self.placer.placement_of(graph_id),
                lambda old_ex, _d: repaired_executor(old_ex, new_sched, stats),
            )
        self._swap_in(
            rec,
            units,
            coo=new_coo,
            per_row=per_row,
            sched=new_sched,
            fingerprint=None,
            lineage=lineage,
            pcoo=new_pcoo,
        )
        self._enqueue_persist(rec, new_coo, rec.config, new_sched)
        scoped = (
            units is not None
            and bool(getattr(units[0].executor, "scoped_upload", False))
        )
        return UpdateReport(
            graph_id=graph_id,
            repaired=True,
            revision=rec.revision,
            fingerprint="",
            lineage=lineage,
            drift=drift,
            nnz=int(np.asarray(new_coo.row).shape[0]),
            update_seconds=time.perf_counter() - t0,
            steps_reused=int(stats.steps_reused),
            windows_reused=int(stats.windows_reused),
            windows_total=int(stats.windows_total),
            scoped_upload=scoped,
            fell_back=bool(stats.fell_back),
        )

    def _persist_entry(
        self,
        rec: _Resident,
        coo,
        fingerprint: str,
        cfg: TunedConfig,
        sched: Schedule,
        perm: Optional[np.ndarray],
    ) -> None:
        """File one schedule under the mutated graph's content
        fingerprint (revision 0 — the key a fresh ``add_graph`` of this
        exact graph computes), so a restart warm-starts the repaired
        state with zero sweeps and zero rebuilds."""
        p = self.placer.placement_of(rec.graph_id)
        sharded = p is not None and p.kind == SHARDED
        if sharded:
            tune_kw = self._sharded_autotune_kwargs(coo)
            max_devices = self.n_devices
        else:
            tune_kw = self._autotune_kwargs
            max_devices = 1
        key = runner.store_key(
            self.store, fingerprint, rec.kdim, max_devices=max_devices, **tune_kw
        )
        self.store.save(key, cfg, sched, perm)

    def _enqueue_persist(
        self, rec: _Resident, coo, cfg: TunedConfig, sched: Schedule
    ) -> None:
        """Queue the content fingerprint + store write of a just-swapped
        revision for the background worker — both are O(nnz), everything
        the update hot path still does is O(|delta|). The worker also
        back-fills ``rec.fingerprint`` (under the swap lock) unless a
        later revision swapped in first. The permutation is snapshotted
        here — a later re-tune may replace ``rec.perm`` before the worker
        runs, and the persisted schedule belongs with *this* one."""
        with self._swap_lock:
            snapshot = (rec, coo, cfg, sched, rec.perm, rec.revision)
        self._persist_q.put(snapshot)
        with self._persist_spawn_lock:
            if self._persist_thread is None:
                t = threading.Thread(target=self._persist_worker, daemon=True)
                self._persist_thread = t
                t.start()

    def _persist_worker(self) -> None:
        while True:
            try:
                task = self._persist_q.get(timeout=5.0)
            except queue_mod.Empty:
                # idle: let the thread die; the next enqueue respawns it
                with self._persist_spawn_lock:
                    if self._persist_q.empty():
                        self._persist_thread = None
                        return
                continue
            rec, coo, cfg, sched, perm, revision = task
            try:
                with self._swap_lock:
                    superseded = rec.revision != revision
                if superseded:
                    # a later update already swapped in and queued its
                    # own persist — skip the stale snapshot
                    continue
                fp2 = registry.graph_fingerprint(coo)
                self._persist_entry(rec, coo, fp2, cfg, sched, perm)
                with self._swap_lock:
                    if rec.revision == revision:
                        rec.fingerprint = fp2
            except Exception:
                pass  # persistence is best-effort off the hot path
            finally:
                self._persist_q.task_done()

    def drain_persists(self, timeout: float = 60.0) -> None:
        """Block until every queued async schedule persist has completed
        (the store then reflects the latest swapped revisions — what a
        clean shutdown or a test wanting warm-restart guarantees calls)."""
        q = self._persist_q
        deadline = time.monotonic() + timeout
        with q.all_tasks_done:
            while q.unfinished_tasks:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise TimeoutError("async persist drain timed out")
                q.all_tasks_done.wait(remaining)

    def _retune_updated(
        self, rec: _Resident, new_coo, per_row, drift: float, lineage: str, t0: float
    ) -> UpdateReport:
        """The drift fallback: full re-tune of the mutated graph (store
        warm-start when available), published through the same atomic
        swap. Resets the drift accumulator — the new schedule is the new
        baseline."""
        self._count("update_retunes")
        gid = rec.graph_id
        fp2 = registry.graph_fingerprint(new_coo)
        p = self.placer.placement_of(gid)
        sharded = p is not None and p.kind == SHARDED
        if sharded:
            tune_kw = self._sharded_autotune_kwargs(new_coo)
            max_devices = self.n_devices
        else:
            tune_kw = self._autotune_kwargs
            max_devices = 1
        key = runner.store_key(
            self.store, fp2, rec.kdim, max_devices=max_devices, **tune_kw
        )
        entry = self.store.load(key)
        if entry is not None:
            self._count("store_hits")
            cfg, sched, perm2 = entry
            self._check_route(gid, cfg, sharded, "stored")
            registry.adopt_reorder(fp2, cfg.reorder, perm2)
            perm2, inv2 = registry.get_reorder(
                new_coo, cfg.reorder, fingerprint=fp2
            )
        else:
            self._count("store_misses")
            cfg = runner.autotune(
                new_coo,
                (new_coo.shape[1], rec.kdim),
                max_devices=max_devices,
                store=self.store,
                **tune_kw,
            )
            self._check_route(gid, cfg, sharded, "tuned")
            sched = registry.get_schedule(
                new_coo, **cfg.as_schedule_kwargs(), fingerprint=fp2
            )
            perm2, inv2 = registry.get_reorder(
                new_coo, cfg.reorder, fingerprint=fp2
            )
            registry.release_graph(fp2)
        units = None
        if rec.fwd is not None:
            units = self._rebuilt_units(
                rec,
                p,
                lambda _old, d: self._fresh_executor(sched, cfg, d, inv2),
            )
        self._swap_in(
            rec,
            units,
            coo=new_coo,
            per_row=per_row,
            sched=sched,
            fingerprint=fp2,
            lineage=fp2,
            config=cfg,
            reset_drift=True,
            pcoo=None if perm2 is None else fmt.permute_coo(new_coo, perm2),
            perm=perm2,
            inv=inv2,
        )
        return UpdateReport(
            graph_id=gid,
            repaired=False,
            revision=rec.revision,
            fingerprint=fp2,
            lineage=lineage,
            drift=drift,
            nnz=int(np.asarray(new_coo.row).shape[0]),
            update_seconds=time.perf_counter() - t0,
        )

    # ---- residency / eviction / replication / rebalance --------------------

    def _unit_handle(self, device_index: int):
        """(jax device, placement handle) of one mesh device. The
        process-default device keeps a None placement handle: executors
        the registry/kernel paths build for the same schedule share the
        (schedule, None) upload cache instead of paying a duplicate
        pinned copy, and the single-device engine behaves exactly as it
        always did; only non-default mesh devices pin."""
        dev = self.devices[device_index]
        return dev, (None if dev == jax.devices()[0] else dev)

    def _build_unit(self, rec: _Resident, device_index: int) -> _Unit:
        """One serving clone of ``rec`` on a specific mesh device — built
        from the already-converged config and the host schedule, so it
        costs one upload and zero sweeps, zero rebuilds (what makes a
        replica cheap)."""
        cfg = rec.config
        dev, handle = self._unit_handle(device_index)
        ex = ScheduleExecutor(
            rec.sched,
            ktile=cfg.ktile,
            routing=cfg.routing,
            bf16_accumulate=cfg.bf16_accumulate,
            device=handle,
            row_unperm=rec.inv,
        )
        if handle is None:
            params = jax.tree.map(jnp.asarray, rec.params_host)
        else:
            params = jax.device_put(rec.params_host, dev)
        # one jitted dispatch per (clone, batch size): the whole-GCN body
        # vmapped over the request axis
        fwd = jax.jit(jax.vmap(ex._forward_impl, in_axes=(None, 0)))
        nbytes = ex.device_bytes + sum(int(x.nbytes) for x in jax.tree.leaves(params))
        return _Unit(device_index, ex, fwd, params, nbytes)

    def _admit(self, rec: _Resident) -> None:
        """Ensure ``rec`` is device-resident on its placement (LRU-touch +
        per-device budget sweep + rebalance check)."""
        with self._swap_lock:
            evicted = rec.fwd is None
            first = rec.bytes == 0
        if evicted:
            cfg = rec.config
            p = self.placer.placement_of(rec.graph_id)
            # the upload runs outside the swap lock (it is O(bytes) slow);
            # the four unit fields then publish atomically under it
            if p.kind == SHARDED:
                ex = ShardedScheduleExecutor(
                    rec.sched,
                    mesh=self._mesh,
                    ktile=cfg.ktile,
                    routing=cfg.routing,
                    bf16_accumulate=cfg.bf16_accumulate,
                    row_unperm=rec.inv,
                )
                params = jax.tree.map(jnp.asarray, rec.params_host)
                fwd = jax.jit(jax.vmap(ex._forward_impl, in_axes=(None, 0)))
                w_bytes = sum(int(x.nbytes) for x in jax.tree.leaves(params))
                nbytes = ex.device_bytes + w_bytes
            else:
                unit = self._build_unit(rec, p.device_index)
                ex, fwd = unit.executor, unit.fwd
                params, nbytes = unit.params, unit.bytes
            with self._swap_lock:
                rec.executor, rec.fwd = ex, fwd
                rec.params, rec.bytes = params, nbytes
            self.placer.account(rec.graph_id, nbytes)
            self.device_bytes_in_use += nbytes
            if not first:
                self._count("readmissions")
        self._graphs.move_to_end(rec.graph_id)
        self._evict_over_budget(keep=rec.graph_id)
        self._maybe_rebalance(keep=rec.graph_id)

    def _evict(self, rec: _Resident, *, pressure: bool = True) -> None:
        # dropping the executor, weights, and the jitted closure releases
        # the device arrays they capture; the host schedule/config/weights
        # stay for re-upload. One-hot executors also memoize their step
        # arrays in the executor module's LRU — purge that too, or the
        # bytes survive the eviction. A replicated victim first sheds its
        # secondary replicas (collapsing its placement to SINGLE, so
        # re-admission restores one clone and replication re-grows on
        # demand). ``pressure=False`` is the rebalance migration: it must
        # not feed the pressure counter it answers.
        with self._swap_lock:
            replica_devs = list(rec.replicas)
        for d in replica_devs:
            self._drop_replica(rec, d, shrink=False)
        if pressure:
            self.placer.note_eviction(rec.graph_id)
            self._count("evictions")
        self.placer.unaccount(rec.graph_id)
        with self._swap_lock:
            freed = rec.bytes
            rec.executor = None
            rec.params = None
            rec.fwd = None
        release_device_steps(rec.sched)
        self.device_bytes_in_use -= freed
        # service EWMAs were measured under this residency (device,
        # replica set, possibly a different route after rebalance); a
        # re-admitted graph must re-measure instead of shedding requests
        # off stale predictions
        self._svc_ewma.pop(rec.graph_id, None)
        self._svc_req_ewma.pop(rec.graph_id, None)
        self._calm_polls.pop(rec.graph_id, None)

    def _grow_replica(self, rec: _Resident, device_index: Optional[int] = None) -> bool:
        """Clone ``rec`` onto ``device_index`` (the policy's pick; None
        falls back to the placer's coolest-fitting candidate — a device
        that doesn't yet host it AND has budget room for the clone).
        Replication never evicts resident graphs to make space (a
        replica is a luxury; forcing it onto a full device would just
        get it shed by the next budget sweep and re-grown by the next
        poll, one upload per cycle). Warm by construction: the clone
        reuses the converged config and host schedule already in memory
        (same ``TuningStore`` entry), so growth is one upload — no
        sweep, no rebuild."""
        with self._swap_lock:
            resident, nbytes = rec.fwd is not None, rec.bytes
        if not resident:
            return False
        d = device_index
        if d is None:
            d = self.placer.replica_candidate(rec.graph_id, nbytes)
        if d is None:
            return False
        unit = self._build_unit(rec, d)
        self.placer.add_replica(rec.graph_id, unit.bytes, device_index=d)
        with self._swap_lock:
            rec.replicas[d] = unit
        self.device_bytes_in_use += unit.bytes
        self._count("replicas_added")
        return True

    def _drop_replica(
        self, rec: _Resident, device_index: int, *, shrink: bool = True
    ) -> None:
        """Release one secondary replica: its executor, weights, jitted
        closure, and — for one-hot executors — exactly its own device's
        memoized step arrays (surviving replicas keep theirs)."""
        with self._swap_lock:
            unit = rec.replicas.pop(device_index)
        p = self.placer.drop_replica(rec.graph_id, device_index)
        _, handle = self._unit_handle(device_index)
        release_device_steps(rec.sched, device=handle)
        self.device_bytes_in_use -= unit.bytes
        if shrink:
            self._count("replicas_dropped")
        if p.kind == SINGLE:
            # collapsed back to one clone: the EWMAs were measured with
            # batches split across replicas, so they underestimate
            # single-replica service time — re-measure from scratch
            self._svc_ewma.pop(rec.graph_id, None)
            self._svc_req_ewma.pop(rec.graph_id, None)

    def _update_replication(self, now: Optional[float] = None) -> None:
        """Consult the policy for one grow/shrink/hold step per graph
        (runs at every ``poll`` and threshold auto-flush).

        The default ``HeuristicPolicy`` signal: **per-request
        service-time EWMA × queue depth** — the backlog seconds a single
        replica would need to drain the queue. Above ``replicate_after_s``
        the graph grows one replica (onto the coolest fitting device);
        below a quarter of that for ``replica_shrink_after`` consecutive
        polls, a replicated graph sheds one (from the fullest device,
        relieving the most memory pressure). Sharded graphs never
        replicate — they already span the mesh. The policy returns the
        new calm-poll hysteresis counter; the engine stores it (None
        clears it). The snapshot is rebuilt per graph: each applied
        decision changes device occupancy, which the next graph's
        decision must see."""
        if self.n_devices < 2:
            return
        for gid, rec in list(self._graphs.items()):
            p = self.placer.placement_of(gid)
            if p is None or p.kind == SHARDED:
                continue
            dec = self.policy.replication(self._policy_state(now), gid)
            if dec.action == GROW:
                if dec.device_index is not None:
                    self._grow_replica(rec, dec.device_index)
            elif dec.action == SHRINK:
                self._drop_replica(rec, dec.device_index)
            if dec.calm_polls is None:
                self._calm_polls.pop(gid, None)
            else:
                self._calm_polls[gid] = int(dec.calm_polls)

    def _evict_over_budget(self, keep: str) -> None:
        """Per-device budget sweep: every over-budget device sheds
        resident graphs, least-recently-served first, until under budget
        (the kept graph is never evicted). ``self._graphs`` is maintained
        in least-recently-*served* order — every serve and (re)admission
        ``move_to_end``s its graph — so scanning it front-to-back visits
        true LRU order, not insertion order. A replicated victim whose
        stake on the device is a secondary replica sheds just that
        replica (cheaper than evicting a whole graph; its other clones
        keep serving)."""
        for d in range(self.n_devices):
            while self.placer.used[d] > self.placer.budget:
                # cheapest first: shed a secondary replica living on this
                # device (LRU graph first) — its graph's other clones
                # keep serving, no re-admission cost for anyone
                with self._swap_lock:
                    rep = next(
                        (
                            r
                            for r in self._graphs.values()
                            if r.graph_id != keep and d in r.replicas
                        ),
                        None,
                    )
                if rep is not None:
                    self._drop_replica(rep, d)
                    continue
                with self._swap_lock:
                    victim = next(
                        (
                            r
                            for r in self._graphs.values()
                            if r.executor is not None
                            and r.graph_id != keep
                            and self.placer.resident_on(r.graph_id, d)
                        ),
                        None,
                    )
                if victim is None:
                    break  # only `keep` holds this device; never evicted
                self._evict(victim)

    def _maybe_rebalance(self, keep: str) -> None:
        """When eviction pressure concentrates on one device, migrate its
        least-recently-served single-device graph to the coolest device
        (replicated graphs are pinned by their own heat; sharded ones
        span the mesh — neither migrates)."""
        target = self.placer.rebalance_target()
        if target is None:
            return
        hot, cool = target
        victim = next(
            (
                r
                for r in self._graphs.values()
                if r.graph_id != keep
                and self.placer.placements[r.graph_id].kind == SINGLE
                and self.placer.placements[r.graph_id].device_index == hot
            ),
            None,
        )
        if victim is None:
            return
        with self._swap_lock:
            resident = victim.executor is not None
        if resident:
            self._evict(victim, pressure=False)
        self.placer.move(victim.graph_id, cool)
        self._count("rebalances")

    @property
    def resident_graphs(self) -> List[str]:
        with self._swap_lock:
            return [g for g, r in self._graphs.items() if r.executor is not None]

    @property
    def graphs(self) -> List[str]:
        return list(self._graphs)

    # ---- dispatch machinery (replica routing + async/threaded execution) ---

    def _units(self, rec: _Resident) -> List[_Unit]:
        """All resident serving clones of one admitted graph, primary
        first. Snapshotted under the swap lock: a concurrent
        ``update_graph`` either hasn't swapped yet (every unit is the old
        executor set) or has fully swapped (every unit is the new set) —
        never a mix, and never a missing executor."""
        with self._swap_lock:
            p = self.placer.placement_of(rec.graph_id)
            primary_dev = None if p.kind == SHARDED else p.device_index
            primary = _Unit(primary_dev, rec.executor, rec.fwd, rec.params, rec.bytes)
            return [primary] + [rec.replicas[d] for d in sorted(rec.replicas)]

    def _outstanding_key(self, unit: _Unit):
        d = unit.device_index
        return (
            self._dev_outstanding.get(d, 0.0) if d is not None else 0.0,
            -1 if d is None else d,
        )

    def _run_unit(self, unit: _Unit, graph_id: str, chunk):
        """Run one sub-batch on one serving clone to completion — the
        single execution body behind both the worker-thread path and the
        sibling-replica retry path (so the ``replica_chunk`` fault seam
        covers both)."""
        FAULTS.check("replica_chunk", graph=graph_id, device=unit.device_index)
        out = unit.fwd(unit.params, unit.executor.commit(chunk))
        _block_until_ready(out)
        return out

    def _pool_run(self, unit: _Unit, graph_id: str, chunk):
        if self._pool is None:
            self._pool = ThreadPoolExecutor(
                max_workers=self.n_devices, thread_name_prefix="awb-replica"
            )
        return self._pool.submit(self._run_unit, unit, graph_id, chunk)

    def _dispatch_batch(self, graph_id: str, xs) -> List[_Part]:
        """Validate + stack ``xs``, ensure residency (LRU touch,
        re-upload if evicted), route across replicas, and dispatch —
        **counting nothing**: served-work counters and service EWMAs move
        only when the completion path proves the computation finished.

        A single-clone graph dispatches one async jit call (awaited
        later, so batches of different graphs still overlap). A
        replicated graph splits the batch into contiguous even chunks —
        one per replica, least-outstanding-work replicas first — and runs
        each chunk on its own thread: sub-batches of the *same* graph
        then execute concurrently on their devices, which is where
        replica throughput scaling comes from. Every replica is a
        bit-identical clone, so the split is invisible in the logits."""
        rec = self._graphs.get(graph_id)
        if rec is None:
            raise UnknownGraphError(graph_id, "serve")
        FAULTS.check("dispatch", graph=graph_id)
        if hasattr(xs, "ndim") and xs.ndim == 3:
            xb = xs
        else:
            xb = jnp.stack([jnp.asarray(x) for x in xs])
        n = rec.sched.shape[1]
        if xb.shape[1] != n:
            raise ValueError(
                f"features have {xb.shape[1]} rows; graph {graph_id!r} has {n} nodes"
            )
        self._admit(rec)  # LRU touch + re-upload if evicted
        b = int(xb.shape[0])
        units = sorted(self._units(rec), key=self._outstanding_key)
        per_req = self._svc_req_ewma.get(graph_id, 0.0)
        if len(units) == 1 or b == 1:
            unit = units[0]
            out = unit.fwd(unit.params, unit.executor.commit(xb))
            part = _Part(
                unit.device_index, b, per_req * b, out=out, unit=unit, chunk=xb
            )
            self._charge(part, +1)
            return [part]
        k = min(len(units), b)
        units = units[:k]
        base, rem = divmod(b, len(units))
        parts, offset = [], 0
        for i, unit in enumerate(units):
            size = base + (1 if i < rem else 0)
            end = offset + size
            chunk = xb[offset:end]
            part = _Part(
                unit.device_index,
                size,
                per_req * size,
                future=self._pool_run(unit, graph_id, chunk),
                unit=unit,
                chunk=chunk,
                offset=offset,
            )
            offset += size
            self._charge(part, +1)
            parts.append(part)
        return parts

    def _dispatch_with_retry(self, graph_id: str, xs) -> List[_Part]:
        """Dispatch with bounded retry + exponential backoff for
        *transient* failures (device hiccups, injected faults). A failed
        attempt charges nothing, so retrying is free of bookkeeping.
        Validation errors — unknown graph, wrong shape — are permanent
        and re-raise immediately; after ``max_dispatch_retries`` retries
        the last transient error propagates to the caller as the typed
        outcome of the serve path it came in on."""
        delay = self.retry_backoff_s
        for attempt in range(self.max_dispatch_retries + 1):
            try:
                return self._dispatch_batch(graph_id, xs)
            except (KeyError, ValueError, TypeError):
                raise
            except Exception:
                if attempt >= self.max_dispatch_retries:
                    raise
                self._count("dispatch_retries")
                _sleep(delay)
                delay *= 2

    def _charge(self, part: _Part, sign: int) -> None:
        d = part.device_index
        if d is not None and part.est:
            self._dev_outstanding[d] = max(
                0.0, self._dev_outstanding.get(d, 0.0) + sign * part.est
            )

    def _retry_part(
        self, graph_id: str, part: _Part, exc: Exception
    ) -> Tuple[object, Exception]:
        """Retry one failed sub-batch on the graph's sibling replicas,
        least outstanding work first. Every replica is a bit-identical
        clone, so a sibling's output is indistinguishable from the
        original's — the fault stays unobservable in the logits. Each
        attempt charges and settles its own outstanding-work meter;
        returns ``(out, None)`` on success or ``(None, last_exc)`` when
        every sibling failed too (or there were none to try)."""
        rec = self._graphs.get(graph_id)
        if rec is None or part.unit is None or part.chunk is None:
            return None, exc
        units = self._units(rec)
        siblings = [u for u in units if u.executor is not part.unit.executor]
        for unit in sorted(siblings, key=self._outstanding_key):
            self._count("chunk_retries")
            retry = _Part(unit.device_index, part.n, part.est)
            self._charge(retry, +1)
            try:
                out = self._run_unit(unit, graph_id, part.chunk)
                return out, None
            except Exception as e:
                exc = e
            finally:
                self._charge(retry, -1)
        return None, exc

    def _await_batch(
        self, graph_id: str, parts: List[_Part]
    ) -> Tuple[object, List[_PartFailure]]:
        """Block until every part of one dispatched batch settles, then
        merge the successful sub-batch logits back in request order (on
        the primary replica's device).

        Returns ``(out, failures)``: ``out`` is the merged logits of the
        parts that completed (None when none did) and ``failures`` names
        the request-order slices that stayed failed after sibling-replica
        retries — the caller maps those back to individual requests
        instead of poisoning the whole batch. Every part settles its
        outstanding-work charge exactly once, success or failure; no
        future is left unawaited and the served-work counters are
        untouched here."""
        outs: List[Tuple[int, object]] = []
        failures: List[_PartFailure] = []
        settled = set()
        try:
            for part in parts:
                try:
                    out = part.future.result() if part.future is not None else part.out
                    _block_until_ready(out)
                except Exception as e:
                    self._charge(part, -1)
                    settled.add(id(part))
                    out, e = self._retry_part(graph_id, part, e)
                    if out is None:
                        failures.append(_PartFailure(part.offset, part.n, e))
                        continue
                else:
                    self._charge(part, -1)
                    settled.add(id(part))
                outs.append((part.offset, out))
        finally:
            # an unexpected escape (e.g. KeyboardInterrupt) must still
            # settle every remaining charge — never a leaked meter
            for part in parts:
                if id(part) not in settled:
                    self._charge(part, -1)
        if not outs:
            return None, failures
        outs.sort(key=lambda t: t[0])
        p = self.placer.placement_of(graph_id)
        if len(outs) == 1 and not failures:
            # a replicated graph's output always lands committed to the
            # primary's device, even when a single least-loaded secondary
            # (or a sibling retry) served the whole batch — which replica
            # served must stay unobservable, placement included
            if p.kind == REPLICATED:
                out0 = jax.device_put(outs[0][1], self.devices[p.device_index])
                return out0, failures
            return outs[0][1], failures
        target = self.devices[p.device_index]
        merged = jnp.concatenate([jax.device_put(o, target) for _, o in outs], axis=0)
        return merged, failures

    def _note_service(self, gid: str, svc_s: float, n_requests: int) -> None:
        """Fold one completed batch into the per-batch and per-request
        service-time EWMAs (the deadline scheduler's dispatch estimate
        and the replication saturation signal), then feed the completion
        to the policy — learned policies fit their service-time model on
        exactly these observations."""
        old = self._svc_ewma.get(gid)
        self._svc_ewma[gid] = svc_s if old is None else 0.5 * old + 0.5 * svc_s
        per = svc_s / max(1, n_requests)
        old = self._svc_req_ewma.get(gid)
        self._svc_req_ewma[gid] = per if old is None else 0.5 * old + 0.5 * per
        rec = self._graphs.get(gid)
        if rec is not None:
            self.policy.observe_service(
                gid, n_requests, svc_s, self._graph_state(gid, rec)
            )

    # ---- direct serving ----------------------------------------------------

    def serve_batch(self, graph_id: str, xs) -> jax.Array:
        """One jitted forward over a batch of same-graph feature matrices.

        ``xs`` is a sequence of ``[n, f]`` arrays (or a stacked
        ``[B, n, f]`` array); returns stacked ``[B, n, classes]`` logits.
        The deadline scheduler serves queues through this same dispatch
        path, so auto-flushed batches are bit-identical to direct calls.
        ``batches``/``requests`` count **only after the computation
        completes** — a dispatch that fails asynchronously leaves the
        served-work stats untouched (same invariant as the queue path).
        Transient dispatch failures retry with bounded backoff and a
        failed replica chunk retries on a sibling clone; a batch that
        still cannot complete raises a typed ``RequestFailure`` (the
        direct path is all-or-nothing — ``.partial`` carries any
        successful sub-batches, but nothing is counted served)."""
        t0 = time.monotonic()
        parts = self._dispatch_with_retry(graph_id, xs)
        out, part_failures = self._await_batch(graph_id, parts)
        if part_failures:
            n_failed = sum(f.n for f in part_failures)
            self._count("request_failures", n_failed)
            raise RequestFailure(graph_id, part_failures[-1].exc, n_failed, partial=out)
        self._count("batches")
        self._count("requests", sum(p.n for p in parts))
        self._note_service(graph_id, time.monotonic() - t0, sum(p.n for p in parts))
        return out

    def infer(self, graph_id: str, x) -> jax.Array:
        """Single-request forward (a batch of one)."""
        return self.serve_batch(graph_id, [x])[0]

    # ---- deadline-aware queueing -------------------------------------------

    def submit(
        self,
        graph_id: str,
        x,
        *,
        deadline_s: Optional[float] = None,
        now: Optional[float] = None,
    ) -> SubmitTicket:
        """Queue one request; returns a typed ``SubmitTicket``.

        ``deadline_s`` is the SLA in seconds from now (None = no deadline;
        the request serves on the next ``flush()`` or when its graph's
        queue reaches ``max_batch`` — which auto-flushes that graph
        immediately). Shape is validated here so one malformed request can
        never poison a later flush — malformed submissions *raise*
        (``UnknownGraphError``/``ValueError``: caller bugs, not load).

        Admission control runs before anything is queued: a queue at
        ``max_queue_depth`` returns a REJECTED ticket, and with
        ``shed_unmeetable`` on, a deadline the EDF load map's
        EWMA-predicted wait already rules out returns a SHED ticket (see
        ``_predicted_wait``). ``now`` injects the arrival clock — tests
        pin it, and an open-loop driver passes the *intended* arrival
        time so latency and deadlines measure from the schedule, not
        from when the driver got around to calling."""
        rec = self._graphs.get(graph_id)
        if rec is None:
            raise UnknownGraphError(graph_id, "submit")
        x = jnp.asarray(x)
        n = rec.sched.shape[1]
        if x.ndim != 2 or x.shape[0] != n:
            raise ValueError(
                f"request for graph {graph_id!r} must be [n={n}, features]; "
                f"got shape {x.shape}"
            )
        if now is None:
            now = time.monotonic()
        self._count("submitted")
        depth = len(self._pending.get(graph_id) or ())
        if self.max_queue_depth is not None and depth >= self.max_queue_depth:
            self._count("rejected")
            return SubmitTicket(
                None,
                REJECTED,
                f"queue for graph {graph_id!r} is at max_queue_depth="
                f"{self.max_queue_depth}",
            )
        deadline = None if deadline_s is None else now + float(deadline_s)
        if self.shed_unmeetable and deadline is not None:
            dec = self.policy.shed_on_submit(
                self._policy_state(now), graph_id, deadline
            )
            if dec.shed:
                self._count("shed")
                return SubmitTicket(None, SHED, dec.reason)
        rid = self._next_rid
        self._next_rid += 1
        self._pending.setdefault(graph_id, []).append(
            _Request(rid=rid, x=x, submit_t=now, deadline=deadline)
        )
        if len(self._pending[graph_id]) >= self.max_batch:
            # a queue hot enough to hit the threshold is the saturation
            # signal's strongest form — give replication a chance to grow
            # before the batch serves
            self._update_replication(now)
            served = self._serve_queues([graph_id], now=now)
            for gid, out in served.items():
                self._ready.setdefault(gid, []).append(out)
        return SubmitTicket(rid, ACCEPTED)

    def _absorb(self, load: Dict[int, float], p: Placement, est: float) -> float:
        """Fold one queue's service estimate into a per-device load map
        (cumulative busy seconds) and return its completion time:

        * a single-device queue stacks onto its device (co-located
          queues serialize);
        * a sharded queue starts when its *busiest* mesh device frees
          and advances every device to the common completion time (the
          psum synchronizes them);
        * a replicated queue splits across its clones: completion
          anchors on its **least-loaded replica**, and each replica
          absorbs an even share — never the whole batch on every clone.
        """
        devs = p.device_indices
        if p.kind == REPLICATED:
            start = min(load.get(d, 0.0) for d in devs)
            done = start + est
            share = est / len(devs)
            for d in devs:
                load[d] = load.get(d, 0.0) + share
        else:
            start = max((load.get(d, 0.0) for d in devs), default=0.0)
            done = start + est
            for d in devs:
                load[d] = done
        return done

    def _predicted_wait(self, graph_id: str, deadline: Optional[float] = None) -> float:
        """Policy-predicted completion delay (seconds from now) of a
        request submitted to ``graph_id`` now (see
        ``serving.policy.HeuristicPolicy.predicted_wait``: every queue
        EDF-ahead of it is absorbed into the per-device load map and the
        request's own graph's batch estimate completes on top). This is
        the admission controller's shed predicate: a deadline below this
        wait cannot be met, so serving the request could only buy a
        deadline miss. Kept as a thin delegate for callers and tests
        that probe the predicate directly."""
        return self.policy.predicted_wait(self._policy_state(), graph_id, deadline)

    def poll(self, now: Optional[float] = None) -> Dict[str, jax.Array]:
        """Serve every queue that is *due* and return its batched logits
        (merged with any batches a ``max_batch`` threshold already
        auto-flushed).

        A queue is due when its earliest deadline, minus 1.5× its
        estimated completion time (plus a small floor), has arrived. The
        completion estimate walks the queues in EDF order over a
        **per-device load map** — each device's cumulative busy seconds:

        * a single-device queue stacks onto its device (co-located
          queues serialize, so the tail queue's dispatch must absorb
          everything EDF-ahead of it on that device);
        * a sharded queue starts when its *busiest* mesh device frees and
          advances every device to the common completion time (the psum
          synchronizes them);
        * a replicated queue splits across its clones: its completion
          anchors on its **least-loaded replica**, and each replica
          absorbs an even share — never the whole batch on every clone.

        When a queue is due, every EDF-predecessor serves with it. Call
        this from the serving loop; ``now`` defaults to
        ``time.monotonic()`` (tests inject a clock). Replica sets grow or
        shrink here too (see ``_update_replication``)."""
        if now is None:
            now = time.monotonic()
        self._update_replication(now)
        due = set(self.policy.due_queues(self._policy_state(now)))
        # max_batch threshold queues serve regardless of deadlines — the
        # batching bound is the engine's, not the policy's
        due |= {g for g, q in self._pending.items() if len(q) >= self.max_batch}
        return self._drain(self._serve_queues(list(due), now=now))

    def flush(self) -> Dict[str, jax.Array]:
        """Serve all queued requests, batched per graph. Returns
        ``{graph_id: [B, n, classes] logits}``.

        Queues serve in deterministic earliest-deadline-first order
        (deadline-free graphs last, ties broken by graph id — never by
        insertion order). A failing batch never takes the others down:
        every remaining graph is still served, the failed graphs' queues
        are restored **at the front, in original order** for retry (safe
        when multiple graphs fail in one flush), and the raised
        ``FlushError`` carries the successful results in ``.partial`` —
        no computed logits are lost."""
        return self._drain(
            self._serve_queues([g for g, q in self._pending.items() if q])
        )

    def _drain(self, served: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
        """Merge freshly served batches with threshold-auto-flushed ones
        awaiting pickup."""
        ready, self._ready = self._ready, {}
        for gid, parts in ready.items():
            if gid in served:
                parts = parts + [served[gid]]
            if len(parts) == 1:
                served[gid] = parts[0]
            else:
                served[gid] = jnp.concatenate(parts, axis=0)
        return served

    def _serve_queues(
        self, graph_ids, now: Optional[float] = None
    ) -> Dict[str, jax.Array]:
        """Serve the named graphs' queues: EDF dispatch order, then await.

        All batches are **dispatched** (async jit calls; per-replica
        sub-batches on worker threads) before any result is awaited, so
        batches placed on different mesh devices execute concurrently;
        awaiting then happens in the same EDF order. ``batches``/
        ``requests``/``queue_served`` count a batch only once its
        completion is proven — a dispatch that fails later never inflates
        the served-work stats.

        With ``shed_unmeetable`` on, requests whose deadline even the
        graph's own batch estimate can no longer meet are shed here —
        the last gate before device time is spent. Failures surface
        per-request: a batch whose every recovery path (bounded dispatch
        retries, sibling-replica chunk retries) was exhausted gets
        exactly its failed requests restored at the queue front — served
        chunks still deliver — and one ``FlushError`` reports all failed
        graphs after every healthy graph was served."""
        if now is None:
            now = time.monotonic()
        # one snapshot serves every ordering + shed decision of this
        # cycle: EWMAs and queues only mutate in the await loop below,
        # after all dispatch decisions are made
        state = self._policy_state(now)
        order = self.policy.dispatch_order(
            state, [g for g in graph_ids if self._pending.get(g)]
        ).graph_ids
        served: Dict[str, jax.Array] = {}
        failures: Dict[str, Exception] = {}
        inflight = []

        def restore(gid, reqs):
            self._pending[gid] = reqs + self._pending.get(gid, [])

        for gid in order:
            reqs = self._pending.pop(gid)
            if self.shed_unmeetable:
                keep = []
                for r in reqs:
                    if (
                        r.deadline is not None
                        and self.policy.shed_at_dispatch(state, gid, r.deadline).shed
                    ):
                        self._count("shed")
                    else:
                        keep.append(r)
                reqs = keep
                if not reqs:
                    continue
            t_disp = time.monotonic()
            try:
                parts = self._dispatch_with_retry(gid, [r.x for r in reqs])
            except Exception as e:
                failures[gid] = e
                restore(gid, reqs)
                continue
            inflight.append((gid, reqs, parts, t_disp))
        t_prev = None
        for gid, reqs, parts, t_disp in inflight:
            try:
                out, part_failures = self._await_batch(gid, parts)
            except Exception as e:
                failures[gid] = e
                restore(gid, reqs)
                continue
            ok_reqs = reqs
            if part_failures:
                failed_idx = set()
                for f in part_failures:
                    failed_idx.update(range(f.offset, f.offset + f.n))
                failed = [r for i, r in enumerate(reqs) if i in failed_idx]
                ok_reqs = [r for i, r in enumerate(reqs) if i not in failed_idx]
                restore(gid, failed)
                self._count("request_failures", len(failed))
                failures[gid] = part_failures[-1].exc
            if out is None:
                continue
            t_done = time.monotonic()
            self._count("batches")
            self._count("requests", len(ok_reqs))
            self._count("queue_served", len(ok_reqs))
            # service EWMAs fold the *incremental* completion time of this
            # batch: everything was dispatched before anything was
            # awaited, so on shared devices a later batch's await-since-
            # dispatch span contains every earlier batch's compute —
            # folding that cumulative span would inflate every EWMA
            # toward the whole cycle's cost, and the shed predicate
            # (which already sums EDF-ahead queues itself) would double-
            # count the serialization and shed far too eagerly
            svc_t0 = t_disp if t_prev is None else max(t_disp, t_prev)
            self._note_served(gid, ok_reqs, svc_t0, t_done)
            t_prev = t_done
            served[gid] = out
        if failures:
            raise FlushError(failures, served)
        return served

    def _note_served(
        self, gid: str, reqs: List[_Request], t_disp: float, t_done: float
    ) -> None:
        """Record per-request latency + deadline outcome, and fold the
        batch service time into the graph's EWMAs (what ``poll`` subtracts
        from deadlines to dispatch early enough, and what the replication
        policy multiplies by queue depth)."""
        for r in reqs:
            lat = t_done - r.submit_t
            self._lat_n += 1
            self._lat_total += lat
            self._lat_max = max(self._lat_max, lat)
            self._lat_samples.append(lat)
            if r.deadline is not None:
                key = "deadline_met" if t_done <= r.deadline else "deadline_misses"
                self._count(key)
        self._note_service(gid, t_done - t_disp, len(reqs))

    # counter-settlement: *
    def _count(self, key: str, n: int = 1) -> None:
        """Single settlement point for ``self.counters`` (the
        counter-settlement rule of ``repro.analysis`` enforces that every
        mutation goes through here, a ``finally`` block, or an annotated
        settlement helper — so a raise mid-path cannot leave the overload
        accounting identity half-applied)."""
        self.counters[key] += n

    # counter-settlement: *
    def reset_stats(self) -> None:
        """Zero the counters and latency aggregates (benchmark sections
        and ops dashboards measure deltas; residency state is untouched)."""
        self.counters = {k: 0 for k in self.counters}
        self._lat_n, self._lat_total, self._lat_max = 0, 0.0, 0.0
        self._lat_samples.clear()

    def latency_percentiles(self) -> Dict[str, float]:
        """p50/p95/p99 of the recent-request latency reservoir, in
        microseconds (zeros before any request was served)."""
        if not self._lat_samples:
            return {"latency_us_p50": 0.0, "latency_us_p95": 0.0, "latency_us_p99": 0.0}
        lat = np.asarray(self._lat_samples)
        p50, p95, p99 = np.percentile(lat, (50.0, 95.0, 99.0)) * 1e6
        return {
            "latency_us_p50": float(p50),
            "latency_us_p95": float(p95),
            "latency_us_p99": float(p99),
        }

    def saturation(self) -> Dict[int, float]:
        """Per-device saturation: estimated busy seconds already
        committed to each device — outstanding dispatched-but-incomplete
        work plus the queued backlog the EDF load map assigns it. The
        backpressure signal a dispatcher upstream would shed against."""
        load: Dict[int, float] = {}
        for gid, q in sorted(self._pending.items()):
            if not q:
                continue
            p = self.placer.placement_of(gid)
            if p is None:
                continue
            self._absorb(load, p, self._svc_ewma.get(gid, 0.0))
        return {
            d: self._dev_outstanding.get(d, 0.0) + load.get(d, 0.0)
            for d in range(self.n_devices)
        }

    def stats(self) -> dict:
        replicas = {
            g: list(self.placer.placement_of(g).device_indices)
            for g in self._graphs
            if self.placer.placement_of(g) is not None
            and self.placer.placement_of(g).kind == REPLICATED
        }
        sat = self.saturation()
        return dict(
            self.counters,
            device_bytes_in_use=self.device_bytes_in_use,
            device_budget_bytes=self.device_budget_bytes,
            n_devices=self.n_devices,
            n_graphs=len(self._graphs),
            n_resident=len(self.resident_graphs),
            pending_requests=sum(len(q) for q in self._pending.values()),
            queue_depth={g: len(q) for g, q in self._pending.items() if q},
            saturation_s=sat,
            latency_n=self._lat_n,
            latency_us_mean=(
                self._lat_total / self._lat_n * 1e6 if self._lat_n else 0.0
            ),
            latency_us_max=self._lat_max * 1e6,
            **self.latency_percentiles(),
            replicas=replicas,
            per_device=self.placer.device_report(
                extra={d: {"saturation_s": s} for d, s in sat.items()}
            ),
        )
