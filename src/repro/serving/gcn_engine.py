"""Mesh-wide, deadline-aware GCN serving engine on the tuning store.

The paper's workload is inference on a fixed graph; a serving system holds
*many* such graphs — one converged configuration each — and rotates them
through bounded device memory across a mesh. ``GCNServingEngine`` composes
the tuning subsystem into that shape:

* **Warm starts.** ``add_graph`` keys the ``TuningStore`` by graph
  fingerprint *and mesh route*; a hit deserializes the ``TunedConfig`` and
  the prebuilt schedule arrays, so a process restart performs **zero
  measured sweeps and zero schedule rebuilds** — deserialize, upload,
  serve. A miss runs the measured sweep once and persists the winner
  (store keys already carry the mesh descriptor, so single-device and
  sharded entries coexist). A corrupted entry is dropped and re-tuned,
  never crashed on.
* **Mesh placement.** A ``serving.placement.MeshPlacer`` bin-packs each
  graph onto one device of a 1-D mesh (worst-fit by ``device_bytes``
  footprint, per-device LRU byte budgets — the paper's per-PE workload
  balancing at graph granularity). Graphs whose footprint exceeds any
  single device's budget route to a ``ShardedScheduleExecutor`` spanning
  the mesh. When eviction pressure concentrates on one device, the placer
  nominates a migration and the engine moves a resident graph to the
  coolest device (runtime rebalancing, lifted to placement).
* **Deadline-aware batching.** ``submit(graph_id, x, deadline_s=...)``
  queues a request; queues auto-flush when a graph reaches the
  ``max_batch`` threshold, and ``poll()`` serves every queue whose
  earliest deadline is due (earliest-deadline-first across graphs; all
  batches are dispatched before any result is awaited, so batches placed
  on different devices run concurrently). Each graph's queue serves
  through **one jitted vmapped whole-GCN forward** — bit-identical to the
  direct ``serve_batch`` path. Per-request latency and deadline
  hits/misses surface in ``stats()``; ``flush()`` remains the serve-
  everything-now path, in deterministic EDF order.
* **Bounded residency.** Each resident graph's device footprint — its
  executor's schedule arrays (``device_bytes``) *plus* its uploaded
  weights — counts against its device's budget. Admission beyond the
  budget evicts least-recently-served graphs on that device; the host-side
  schedule, config, and weight copies are kept, so re-admission is a
  re-upload — still no rebuild, no sweep.

The engine deliberately bypasses ``tuning.registry``'s unbounded
fingerprint caches for its executors — eviction must actually free device
memory, so the engine's executor references are the only ones.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import csc as fmt
from repro.core.executor import (ScheduleExecutor, ShardedScheduleExecutor,
                                 release_device_steps)
from repro.core.schedule import Schedule
from repro.serving.placement import SHARDED, MeshPlacer, Placement
from repro.tuning import registry, runner, space
from repro.tuning.space import TunedConfig
from repro.tuning.store import TuningStore

#: pre-tune footprint estimate: ~16 bytes per non-zero covers the gather
#: path's 12 bytes/slot plus schedule padding slack — only used to route
#: giant graphs to the sharded path before their schedule exists.
_BYTES_PER_NNZ_EST = 16

#: deadline dispatch headroom: a queue is due at
#: ``deadline - SAFETY * est - FLOOR``. Dispatching at exactly
#: ``deadline - est`` lands completions *on* the deadline, where any
#: jitter is a miss; 50% service-time headroom plus a small floor turns
#: borderline batches into met deadlines at a modest batching cost.
_SVC_SAFETY = 1.5
_SVC_FLOOR_S = 0.010


class FlushError(RuntimeError):
    """One or more per-graph batches failed during a flush/poll.

    Nothing is lost: ``partial`` holds the successfully served
    ``{graph_id: logits}``, ``failures`` the ``{graph_id: exception}``,
    and every failed graph's queue was restored (at the front, original
    order) for retry."""

    def __init__(self, failures, partial):
        super().__init__(
            f"flush failed for graph(s) {sorted(failures)}; "
            f"{len(partial)} graph(s) served (see .partial), failed "
            f"queues restored for retry")
        self.failures = failures
        self.partial = partial


@dataclasses.dataclass
class AdmitReport:
    """What ``add_graph`` did for one graph."""
    graph_id: str
    warm_start: bool          # True: store hit — no sweep, no rebuild
    tune_seconds: float       # 0.0 on the warm path
    device_bytes: int         # resident footprint (schedule + weights)
    config: TunedConfig
    placement: Placement      # which device(s) the graph serves from


@dataclasses.dataclass
class _Request:
    """One queued inference request."""
    rid: int
    x: jax.Array
    submit_t: float                    # monotonic seconds
    deadline: Optional[float]          # absolute monotonic; None = no SLA


@dataclasses.dataclass
class _Resident:
    graph_id: str
    fingerprint: str
    config: TunedConfig
    sched: Schedule                      # host copy — survives eviction
    params_host: dict                    # host copy — survives eviction
    params: Optional[dict] = None        # device-resident weight tree
    #: ScheduleExecutor or ShardedScheduleExecutor (None while evicted)
    executor: Optional[object] = None
    fwd: Optional[callable] = None       # jitted vmapped whole-GCN forward
    bytes: int = 0                       # schedule + weight device bytes


def _earliest_deadline(queue: List[_Request]) -> float:
    """Earliest deadline in a queue (+inf when no request carries one) —
    the EDF sort key across graphs."""
    dls = [r.deadline for r in queue if r.deadline is not None]
    return min(dls) if dls else float("inf")


class GCNServingEngine:
    """Serve batched GCN inference over many resident graphs on a mesh.

    ``devices`` selects the mesh: None (default) serves on jax's first
    device exactly like the old single-device engine; an int ``n`` takes
    ``jax.devices()[:n]``; a list of ``jax.Device`` uses those. With a
    multi-device mesh, each admitted graph is bin-packed onto one device
    (``serving.placement.MeshPlacer``), and graphs too big for any single
    device's ``device_budget_bytes`` serve through a
    ``ShardedScheduleExecutor`` spanning the whole mesh.

    ``device_budget_bytes`` bounds each device's resident schedule+weight
    bytes; the graph being served is always kept resident, even if it
    alone exceeds the budget (a budget smaller than one graph cannot be
    honoured — it degrades to one-graph-at-a-time rotation).
    """

    def __init__(self, *, store: Optional[TuningStore] = None,
                 store_root=None,
                 device_budget_bytes: int = 64 << 20,
                 devices=None,
                 max_batch: int = 32,
                 rebalance_after: int = 4,
                 autotune_iters: int = 3, autotune_warmup: int = 1,
                 autotune_kwargs: Optional[dict] = None):
        self.store = store if store is not None else TuningStore(store_root)
        self.device_budget_bytes = int(device_budget_bytes)
        self.max_batch = int(max_batch)
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if devices is None:
            self.devices = [jax.devices()[0]]
        elif isinstance(devices, int):
            avail = jax.devices()
            if not 1 <= devices <= len(avail):
                raise ValueError(
                    f"devices={devices} but this host exposes "
                    f"{len(avail)} device(s)")
            self.devices = list(avail[:devices])
        else:
            self.devices = list(devices)
        self.n_devices = len(self.devices)
        if self.n_devices > 1:
            from jax.sharding import Mesh

            self._mesh = Mesh(np.asarray(self.devices), ("dev",))
        else:
            self._mesh = None
        self.placer = MeshPlacer(self.n_devices, self.device_budget_bytes,
                                 rebalance_after=rebalance_after)
        self._autotune_kwargs = dict(autotune_kwargs or {})
        reserved = {"max_devices", "store"} & set(self._autotune_kwargs)
        if reserved:
            raise ValueError(
                f"autotune_kwargs may not override {sorted(reserved)}: the "
                "engine pins the mesh route and its own store")
        self._autotune_kwargs.setdefault("iters", autotune_iters)
        self._autotune_kwargs.setdefault("warmup", autotune_warmup)
        self._graphs: "OrderedDict[str, _Resident]" = OrderedDict()
        self._pending: Dict[str, List[_Request]] = {}
        #: batches completed by a threshold-triggered auto-flush, awaiting
        #: pickup by the next poll()/flush()
        self._ready: Dict[str, List[jax.Array]] = {}
        self._svc_ewma: Dict[str, float] = {}  # per-graph batch seconds
        self._next_rid = 0
        self.device_bytes_in_use = 0
        self._lat_n, self._lat_total, self._lat_max = 0, 0.0, 0.0
        self.counters = {"store_hits": 0, "store_misses": 0,
                         "evictions": 0, "readmissions": 0,
                         "rebalances": 0, "batches": 0, "requests": 0,
                         "deadline_met": 0, "deadline_misses": 0}

    # ---- admission ---------------------------------------------------------

    def _estimate_bytes(self, a: fmt.COO, params: dict) -> int:
        """Pre-tune footprint estimate (schedule + weights) — routes giant
        graphs to the sharded path before any sweep runs."""
        nnz = int(np.asarray(a.row).shape[0])
        weights = sum(int(np.asarray(w).nbytes)
                      for w in jax.tree.leaves(params))
        return nnz * _BYTES_PER_NNZ_EST + weights

    def _sharded_autotune_kwargs(self, a: fmt.COO) -> dict:
        """The autotune kwargs of the sharded route: every sweep candidate
        pinned to the full mesh width (a caller-supplied sweep keeps its
        geometries; the default uses the sharded gather candidates)."""
        kw = dict(self._autotune_kwargs)
        base = kw.pop("sweep", None)
        if base is None:
            kw["sweep"] = space.sharded_sweep(a, (self.n_devices,))
        else:
            kw["sweep"] = [dict(c, n_devices=self.n_devices) for c in base]
        return kw

    def add_graph(self, graph_id: str, a: fmt.COO, params: dict, *,
                  kdim: Optional[int] = None) -> AdmitReport:
        """Register a graph + trained weights and make it servable.

        The routing decision tree: estimate the footprint; if it exceeds
        one device's budget on a multi-device mesh, the graph takes the
        **sharded route** (store key + sweep at the full mesh width),
        otherwise the **single-device route** (store key + sweep pinned to
        one device, then bin-packed placement). Either route warm-starts
        from the store when populated. ``kdim`` is the tuning probe width;
        it defaults to the first layer's output width."""
        if graph_id in self._graphs:
            raise ValueError(f"graph {graph_id!r} already registered")
        if kdim is None:
            kdim = int(np.asarray(params["w0"]).shape[1])
        fp = registry.graph_fingerprint(a)
        est = self._estimate_bytes(a, params)
        sharded_route = (est > self.device_budget_bytes
                         and self.n_devices > 1)
        if sharded_route:
            tune_kw = self._sharded_autotune_kwargs(a)
            max_devices = self.n_devices
        else:
            tune_kw = self._autotune_kwargs
            max_devices = 1
        key = runner.store_key(self.store, fp, kdim,
                               max_devices=max_devices, **tune_kw)
        t0 = time.perf_counter()
        entry = self.store.load(key)
        warm = entry is not None
        if warm:
            self.counters["store_hits"] += 1
            cfg, sched = entry
            self._check_route(graph_id, cfg, sharded_route, "stored")
            tune_s = 0.0
        else:
            self.counters["store_misses"] += 1
            cfg = runner.autotune(a, (a.shape[1], kdim),
                                  max_devices=max_devices,
                                  store=self.store, **tune_kw)
            self._check_route(graph_id, cfg, sharded_route, "tuned")
            sched = registry.get_schedule(a, **cfg.as_schedule_kwargs(),
                                          fingerprint=fp)
            # release the graph from the registry's unbounded caches: the
            # sweep's ~dozen losing candidate executors must not pin device
            # memory, and *this* engine's per-device budgets become the
            # only thing keeping anything resident
            registry.release_graph(fp)
            tune_s = time.perf_counter() - t0
        rec = _Resident(graph_id=graph_id, fingerprint=fp, config=cfg,
                        sched=sched,
                        params_host=jax.tree.map(np.asarray, params))
        self._graphs[graph_id] = rec
        placement = self.placer.place(graph_id, est)
        self._admit(rec)
        return AdmitReport(graph_id=graph_id, warm_start=warm,
                           tune_seconds=tune_s, device_bytes=rec.bytes,
                           config=cfg, placement=placement)

    def _check_route(self, graph_id: str, cfg: TunedConfig,
                     sharded_route: bool, origin: str) -> None:
        if sharded_route:
            if cfg.n_devices != self.n_devices:
                raise ValueError(
                    f"graph {graph_id!r} takes the sharded route on this "
                    f"{self.n_devices}-device mesh, but the {origin} config "
                    f"requests n_devices={cfg.n_devices}")
        elif cfg.n_devices is not None:
            raise ValueError(
                f"graph {graph_id!r} takes the single-device route, but "
                f"the {origin} config requests n_devices={cfg.n_devices} — "
                "remove sharded candidates from autotune_kwargs['sweep']")

    def remove_graph(self, graph_id: str) -> None:
        rec = self._graphs.pop(graph_id)
        self._pending.pop(graph_id, None)
        self._ready.pop(graph_id, None)
        self._svc_ewma.pop(graph_id, None)
        if rec.executor is not None:
            self.device_bytes_in_use -= rec.bytes
        self.placer.forget(graph_id)
        release_device_steps(rec.sched)

    # ---- residency / eviction / rebalance ----------------------------------

    def _admit(self, rec: _Resident) -> None:
        """Ensure ``rec`` is device-resident on its placement (LRU-touch +
        per-device budget sweep + rebalance check)."""
        if rec.fwd is None:
            first = rec.bytes == 0
            cfg = rec.config
            p = self.placer.placement_of(rec.graph_id)
            if p.kind == SHARDED:
                ex = ShardedScheduleExecutor(
                    rec.sched, mesh=self._mesh, ktile=cfg.ktile,
                    routing=cfg.routing,
                    bf16_accumulate=cfg.bf16_accumulate)
                rec.params = jax.tree.map(jnp.asarray, rec.params_host)
            else:
                dev = self.devices[p.device_index]
                # the process-default device keeps a None placement
                # handle: executors the registry/kernel paths build for
                # the same schedule share the (schedule, None) upload
                # cache instead of paying a duplicate pinned copy, and
                # the single-device engine behaves exactly as it always
                # did; only non-default mesh devices pin
                handle = None if dev == jax.devices()[0] else dev
                ex = ScheduleExecutor(rec.sched, ktile=cfg.ktile,
                                      routing=cfg.routing,
                                      bf16_accumulate=cfg.bf16_accumulate,
                                      device=handle)
                if handle is None:
                    rec.params = jax.tree.map(jnp.asarray, rec.params_host)
                else:
                    rec.params = jax.device_put(rec.params_host, dev)
            rec.executor = ex
            # one jitted dispatch per (graph, batch size): the whole-GCN
            # body vmapped over the request axis
            rec.fwd = jax.jit(jax.vmap(ex._forward_impl, in_axes=(None, 0)))
            rec.bytes = ex.device_bytes + sum(
                int(x.nbytes) for x in jax.tree.leaves(rec.params))
            self.placer.account(rec.graph_id, rec.bytes)
            self.device_bytes_in_use += rec.bytes
            if not first:
                self.counters["readmissions"] += 1
        self._graphs.move_to_end(rec.graph_id)
        self._evict_over_budget(keep=rec.graph_id)
        self._maybe_rebalance(keep=rec.graph_id)

    def _evict(self, rec: _Resident, *, pressure: bool = True) -> None:
        # dropping the executor, weights, and the jitted closure releases
        # the device arrays they capture; the host schedule/config/weights
        # stay for re-upload. One-hot executors also memoize their step
        # arrays in the executor module's LRU — purge that too, or the
        # bytes survive the eviction. ``pressure=False`` is the rebalance
        # migration: it must not feed the pressure counter it answers.
        if pressure:
            self.placer.note_eviction(rec.graph_id)
            self.counters["evictions"] += 1
        self.placer.unaccount(rec.graph_id)
        rec.executor = None
        rec.params = None
        rec.fwd = None
        release_device_steps(rec.sched)
        self.device_bytes_in_use -= rec.bytes

    def _evict_over_budget(self, keep: str) -> None:
        """Per-device budget sweep: every device sheds least-recently-
        served graphs until under budget (the kept graph is never
        evicted)."""
        for d in range(self.n_devices):
            while self.placer.used[d] > self.placer.budget:
                victim = next(
                    (r for r in self._graphs.values()
                     if r.executor is not None and r.graph_id != keep
                     and d in self.placer.placements[r.graph_id]
                     .device_indices),
                    None)
                if victim is None:
                    break  # only `keep` holds this device; never evicted
                self._evict(victim)

    def _maybe_rebalance(self, keep: str) -> None:
        """When eviction pressure concentrates on one device, migrate its
        least-recently-served single-device graph to the coolest device."""
        target = self.placer.rebalance_target()
        if target is None:
            return
        hot, cool = target
        victim = next(
            (r for r in self._graphs.values()
             if r.graph_id != keep
             and self.placer.placements[r.graph_id].kind != SHARDED
             and self.placer.placements[r.graph_id].device_index == hot),
            None)
        if victim is None:
            return
        if victim.executor is not None:
            self._evict(victim, pressure=False)
        self.placer.move(victim.graph_id, cool)
        self.counters["rebalances"] += 1

    @property
    def resident_graphs(self) -> List[str]:
        return [g for g, r in self._graphs.items() if r.executor is not None]

    @property
    def graphs(self) -> List[str]:
        return list(self._graphs)

    # ---- direct serving ----------------------------------------------------

    def serve_batch(self, graph_id: str, xs) -> jax.Array:
        """One jitted forward over a batch of same-graph feature matrices.

        ``xs`` is a sequence of ``[n, f]`` arrays (or a stacked
        ``[B, n, f]`` array); returns stacked ``[B, n, classes]`` logits.
        The deadline scheduler serves queues through this same path, so
        auto-flushed batches are bit-identical to direct calls."""
        rec = self._graphs[graph_id]
        xb = xs if hasattr(xs, "ndim") and xs.ndim == 3 else jnp.stack(
            [jnp.asarray(x) for x in xs])
        n = rec.sched.shape[1]
        if xb.shape[1] != n:
            raise ValueError(
                f"features have {xb.shape[1]} rows; graph {graph_id!r} "
                f"has {n} nodes")
        self._admit(rec)  # LRU touch + re-upload if evicted
        out = rec.fwd(rec.params, rec.executor.commit(xb))
        # count only completed batches — a failed/retried batch must not
        # inflate the served-work stats
        self.counters["batches"] += 1
        self.counters["requests"] += int(xb.shape[0])
        return out

    def infer(self, graph_id: str, x) -> jax.Array:
        """Single-request forward (a batch of one)."""
        return self.serve_batch(graph_id, [x])[0]

    # ---- deadline-aware queueing -------------------------------------------

    def submit(self, graph_id: str, x, *,
               deadline_s: Optional[float] = None) -> int:
        """Queue one request; returns its request id.

        ``deadline_s`` is the SLA in seconds from now (None = no deadline;
        the request serves on the next ``flush()`` or when its graph's
        queue reaches ``max_batch`` — which auto-flushes that graph
        immediately). Shape is validated here so one malformed request can
        never poison a later flush."""
        rec = self._graphs.get(graph_id)
        if rec is None:
            raise KeyError(f"unknown graph {graph_id!r}")
        x = jnp.asarray(x)
        n = rec.sched.shape[1]
        if x.ndim != 2 or x.shape[0] != n:
            raise ValueError(
                f"request for graph {graph_id!r} must be [n={n}, features]; "
                f"got shape {x.shape}")
        now = time.monotonic()
        rid = self._next_rid
        self._next_rid += 1
        deadline = None if deadline_s is None else now + float(deadline_s)
        self._pending.setdefault(graph_id, []).append(
            _Request(rid=rid, x=x, submit_t=now, deadline=deadline))
        if len(self._pending[graph_id]) >= self.max_batch:
            served = self._serve_queues([graph_id])
            for gid, out in served.items():
                self._ready.setdefault(gid, []).append(out)
        return rid

    def poll(self, now: Optional[float] = None) -> Dict[str, jax.Array]:
        """Serve every queue that is *due* and return its batched logits
        (merged with any batches a ``max_batch`` threshold already
        auto-flushed).

        A queue is due when its earliest deadline, minus 1.5× the
        *cumulative* smoothed service time of everything EDF-ahead of it
        on its device (plus a small floor), has arrived — co-located
        batches serialize on their device, so the tail graph's dispatch
        must leave room for the queue ahead of it, not just its own
        batch. When a queue is due, every EDF-predecessor serves with it
        (they would block the device anyway). Call this from the serving
        loop; ``now`` defaults to ``time.monotonic()`` (tests inject a
        clock)."""
        if now is None:
            now = time.monotonic()
        order = sorted(((g, q) for g, q in self._pending.items() if q),
                       key=lambda t: (_earliest_deadline(t[1]), t[0]))
        load: Dict[int, float] = {}  # device -> cumulative est seconds
        threshold, due_upto = [], -1
        for i, (gid, q) in enumerate(order):
            est = self._svc_ewma.get(gid, 0.0)
            devs = self.placer.placement_of(gid).device_indices
            ahead = max((load.get(d, 0.0) for d in devs), default=0.0)
            for d in devs:
                load[d] = ahead + est
            if len(q) >= self.max_batch:
                threshold.append(gid)
            slack = _SVC_SAFETY * (ahead + est) + _SVC_FLOOR_S
            if _earliest_deadline(q) - slack <= now:
                due_upto = i
        due = {g for g, _ in order[:due_upto + 1]} | set(threshold)
        return self._drain(self._serve_queues(list(due)))

    def flush(self) -> Dict[str, jax.Array]:
        """Serve all queued requests, batched per graph. Returns
        ``{graph_id: [B, n, classes] logits}``.

        Queues serve in deterministic earliest-deadline-first order
        (deadline-free graphs last, ties broken by graph id — never by
        insertion order). A failing batch never takes the others down:
        every remaining graph is still served, the failed graphs' queues
        are restored **at the front, in original order** for retry (safe
        when multiple graphs fail in one flush), and the raised
        ``FlushError`` carries the successful results in ``.partial`` —
        no computed logits are lost."""
        return self._drain(
            self._serve_queues([g for g, q in self._pending.items() if q]))

    def _drain(self, served: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
        """Merge freshly served batches with threshold-auto-flushed ones
        awaiting pickup."""
        ready, self._ready = self._ready, {}
        for gid, parts in ready.items():
            if gid in served:
                parts = parts + [served[gid]]
            served[gid] = parts[0] if len(parts) == 1 else jnp.concatenate(
                parts, axis=0)
        return served

    def _serve_queues(self, graph_ids) -> Dict[str, jax.Array]:
        """Serve the named graphs' queues: EDF dispatch order, then await.

        All batches are **dispatched** (async jit calls) before any result
        is awaited, so batches placed on different mesh devices execute
        concurrently; awaiting then happens in the same EDF order. Failed
        graphs get their queue restored at the front and are reported
        together in one ``FlushError`` after every healthy graph was
        served."""
        order = sorted(
            (g for g in graph_ids if self._pending.get(g)),
            key=lambda g: (_earliest_deadline(self._pending[g]), g))
        served: Dict[str, jax.Array] = {}
        failures: Dict[str, Exception] = {}
        inflight = []

        def restore(gid, reqs):
            self._pending[gid] = reqs + self._pending.get(gid, [])

        for gid in order:
            reqs = self._pending.pop(gid)
            t_disp = time.monotonic()
            try:
                out = self.serve_batch(gid, [r.x for r in reqs])
            except Exception as e:
                failures[gid] = e
                restore(gid, reqs)
                continue
            inflight.append((gid, reqs, out, t_disp))
        for gid, reqs, out, t_disp in inflight:
            try:
                jax.block_until_ready(out)
            except Exception as e:
                failures[gid] = e
                # serve_batch counted this batch at dispatch; it produced
                # nothing and will be retried — keep the served-work
                # counters honest (their count-only-completed invariant)
                self.counters["batches"] -= 1
                self.counters["requests"] -= len(reqs)
                restore(gid, reqs)
                continue
            t_done = time.monotonic()
            self._note_served(gid, reqs, t_disp, t_done)
            served[gid] = out
        if failures:
            raise FlushError(failures, served)
        return served

    def _note_served(self, gid: str, reqs: List[_Request],
                     t_disp: float, t_done: float) -> None:
        """Record per-request latency + deadline outcome, and fold the
        batch service time into the graph's EWMA (what ``poll`` subtracts
        from deadlines to dispatch early enough)."""
        for r in reqs:
            lat = t_done - r.submit_t
            self._lat_n += 1
            self._lat_total += lat
            self._lat_max = max(self._lat_max, lat)
            if r.deadline is not None:
                key = ("deadline_met" if t_done <= r.deadline
                       else "deadline_misses")
                self.counters[key] += 1
        svc = t_done - t_disp
        old = self._svc_ewma.get(gid)
        self._svc_ewma[gid] = svc if old is None else 0.5 * old + 0.5 * svc

    def reset_stats(self) -> None:
        """Zero the counters and latency aggregates (benchmark sections
        and ops dashboards measure deltas; residency state is untouched)."""
        self.counters = {k: 0 for k in self.counters}
        self._lat_n, self._lat_total, self._lat_max = 0, 0.0, 0.0

    def stats(self) -> dict:
        return dict(
            self.counters,
            device_bytes_in_use=self.device_bytes_in_use,
            device_budget_bytes=self.device_budget_bytes,
            n_devices=self.n_devices,
            n_graphs=len(self._graphs),
            n_resident=len(self.resident_graphs),
            pending_requests=sum(len(q) for q in self._pending.values()),
            latency_n=self._lat_n,
            latency_us_mean=(self._lat_total / self._lat_n * 1e6
                             if self._lat_n else 0.0),
            latency_us_max=self._lat_max * 1e6,
            per_device=self.placer.device_report(),
        )
