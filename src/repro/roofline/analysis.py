"""Roofline accounting from compiled dry-run artifacts (TPU v5e terms).

    compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory term     = HLO_bytes / (chips × HBM_bw)
    collective term = collective_bytes / (chips × link_bw)

``compiled.cost_analysis()`` on an SPMD module reports *per-device* flops
and bytes, so the per-chip division is already applied; the collective
parser below also works on the per-device SPMD module.

Wire-byte model per collective (ring algorithms, per participant):
    all-reduce       2·(n-1)/n · bytes(out)
    all-gather         (n-1)/n · bytes(out)
    reduce-scatter     (n-1)   · bytes(out)      (operand = n·out)
    all-to-all         (n-1)/n · bytes(out)
    collective-permute            bytes(out)

Scan caveat: XLA counts a while-loop body once. Stacks of layers lower as
scans, so per-cell totals are extrapolated: lower each segment's unit
standalone (same shardings) and add (repeat−1) × unit cost. EXPERIMENTS.md
§Roofline carries an unrolled-vs-extrapolated validation on qwen2-0.5b.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

# TPU v5e, per chip
@dataclasses.dataclass(frozen=True)
class _HW:
    peak_flops_bf16: float = 197e12   # FLOP/s
    hbm_bw: float = 819e9             # B/s
    ici_bw: float = 50e9              # B/s per link
    hbm_bytes: float = 16e9


HW = _HW()

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|([a-z0-9_]+\[[0-9,]*\][^ ]*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9_]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, float]:
    """Per-device wire bytes by collective kind, plus raw output bytes."""
    out: Dict[str, float] = {}
    wire_total = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        tuple_shapes, single_shape, kind = m.groups()
        nbytes = _shape_bytes(tuple_shapes or single_shape)
        gm = _GROUPS_RE.search(line)
        if gm:
            n = len(gm.group(1).split(","))
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            n = int(gi.group(2)) if gi else 2
        n = max(n, 2)
        # XLA:CPU promotes bf16 all-reduces to f32 ("to_apply=%add..._promoted");
        # TPU reduces bf16 natively, so count promoted ARs at their bf16 width
        if kind == "all-reduce" and "promoted" in line:
            nbytes //= 2
        if kind == "all-reduce":
            wire = 2 * (n - 1) / n * nbytes
        elif kind == "all-gather":
            wire = (n - 1) / n * nbytes
        elif kind == "reduce-scatter":
            wire = (n - 1) * nbytes
        elif kind == "all-to-all":
            wire = (n - 1) / n * nbytes
        else:  # collective-permute
            wire = nbytes
        out[f"{kind}_bytes"] = out.get(f"{kind}_bytes", 0.0) + nbytes
        out[f"{kind}_wire"] = out.get(f"{kind}_wire", 0.0) + wire
        out[f"{kind}_count"] = out.get(f"{kind}_count", 0) + 1
        wire_total += wire
    out["wire_bytes_total"] = wire_total
    return out


_DEF_RE = re.compile(r"^\s*%?([a-zA-Z0-9_.\-]+) = ([a-z0-9_]+\[[0-9,]*\])")
_HBM_OPS = re.compile(
    r"= (?:\(([^)]*)\)|([a-z0-9_]+\[[0-9,]*\][^ ]*))\s+"
    r"(dot|convolution|all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute|scatter|gather|dynamic-update-slice|dynamic-slice)"
    r"(?:-start)?\(([^)]*)\)")


def tpu_hbm_bytes_from_hlo(hlo_text: str) -> float:
    """TPU-fused HBM-traffic model (memory term v2).

    XLA:CPU fuses far less than the TPU backend, so raw ``bytes accessed``
    counts elementwise convert/broadcast/multiply chains that never touch
    HBM on TPU. This model counts only traffic that *must* cross HBM:
    parameters, dot/conv operands+outputs, collective outputs, and
    scatter/gather/dynamic-slice outputs+inputs. It is a lower bound the
    same way raw bytes is an upper bound; EXPERIMENTS.md reports both.
    """
    defs = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            defs[m.group(1)] = _shape_bytes(m.group(2))
    total = 0.0
    for line in hlo_text.splitlines():
        if " parameter(" in line:
            m = _DEF_RE.match(line)
            if m:
                total += defs.get(m.group(1), 0)
            continue
        m = _HBM_OPS.search(line)
        if not m:
            continue
        tuple_shapes, single_shape, kind, operands = m.groups()
        out_b = _shape_bytes(tuple_shapes or single_shape)
        total += out_b
        if kind in ("dot", "convolution", "scatter", "gather",
                    "dynamic-update-slice", "dynamic-slice"):
            for op in operands.split(","):
                name = op.strip().lstrip("%").split(" ")[0]
                total += defs.get(name, 0)
    return total


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   wire_bytes_per_dev: float, hw: _HW = HW) -> dict:
    compute_s = flops_per_dev / hw.peak_flops_bf16
    memory_s = bytes_per_dev / hw.hbm_bw
    collective_s = wire_bytes_per_dev / hw.ici_bw
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dom = max(terms, key=terms.get)
    bound = max(compute_s, memory_s, collective_s)
    terms.update({
        "dominant": dom.replace("_s", ""),
        "bound_s": bound,
        # fraction of peak the dominant-term-bound execution achieves
        "compute_roofline_fraction": compute_s / bound if bound else 0.0,
    })
    return terms


def model_flops(n_params: int, n_active_params: int, tokens: int,
                kind: str) -> float:
    """6·N·D (train) / 2·N·D (forward) with MoE active params."""
    n = n_active_params
    return (6.0 if kind == "train" else 2.0) * n * tokens
