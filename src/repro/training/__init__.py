from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update  # noqa: F401
