"""AdamW with fp32 master weights — mixed-precision + ZeRO-1 friendly.

State = {master fp32, m, v, count}; the *working* parameters handed to the
model are bf16 casts of the master. All state tensors inherit the params'
fully sharded PartitionSpecs (FSDP sharding == ZeRO-1 sharding, so optimizer
state is sharded across both mesh axes with no extra machinery).

Optional int8 gradient compression with error feedback lives in
``sharding/collectives.py`` and wraps the grads before the update.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: Optional[float] = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    t = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params) -> dict:
    f32 = lambda t: jax.tree.map(  # noqa: E731
        lambda x: x.astype(jnp.float32), t)
    zeros = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)
    return {
        "master": f32(params),
        "m": zeros,
        "v": jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, grads, state, param_dtype=jnp.bfloat16):
    """Returns (new_working_params, new_state, metrics)."""
    count = state["count"] + 1
    gnorm = global_norm(grads)
    if cfg.grad_clip is not None:
        scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)
    else:
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)

    lr = lr_schedule(cfg, count)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    new_m = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                         state["m"], grads)
    new_v = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g,
                         state["v"], grads)

    def upd(p, m, v):
        step = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        return p - lr * (step + cfg.weight_decay * p)

    new_master = jax.tree.map(upd, state["master"], new_m, new_v)
    new_params = jax.tree.map(lambda p: p.astype(param_dtype), new_master)
    new_state = {"master": new_master, "m": new_m, "v": new_v,
                 "count": count}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
