"""Checkpointing with fault-tolerance semantics.

Design for 1000+-node operation (DESIGN.md §4):

* **Atomic**: write to ``step_N.tmp/``, fsync, rename — a crash mid-write
  never corrupts the latest checkpoint; restore picks the newest complete
  directory.
* **Keep-k** garbage collection.
* **Async**: a background writer thread drains a depth-1 queue so the train
  loop donates buffers and keeps stepping (snapshot is taken on the host
  before enqueue, so there is no race with donation).
* **Elastic remesh**: tensors are saved as full (host-replicated) numpy
  arrays with their pytree structure; restore re-shards onto *any* mesh /
  device count via ``jax.device_put`` with the target shardings — scale the
  job up or down between restarts without conversion tools.
* **Data-pipeline state** (shard cursor, RNG) rides along, so restart
  resumes the exact batch stream.

On a real multi-host cluster the np.save writes go to a per-process path on
shared storage and only process 0 writes replicated tensors; this container
is single-process, so that branch is a no-op guard.
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        out[key] = leaf
    return out


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3,
                 async_write: bool = False):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._queue: "queue.Queue" = queue.Queue(maxsize=1)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        if async_write:
            self._thread = threading.Thread(target=self._writer, daemon=True)
            self._thread.start()

    # -- write ------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[dict] = None,
             block: bool = True) -> None:
        """Snapshot to host, then write (sync) or enqueue (async)."""
        host = jax.tree.map(lambda x: np.asarray(x), tree)
        if self._thread is None or block:
            self._write(step, host, extra or {})
        else:
            if self._error:
                raise RuntimeError("async checkpoint writer failed") \
                    from self._error
            self._queue.put((step, host, extra or {}))

    def _writer(self):
        while True:
            item = self._queue.get()
            if item is None:
                return
            try:
                self._write(*item)
            except BaseException as e:  # surfaced on next save()
                self._error = e

    def _write(self, step: int, host_tree: dict, extra: dict) -> None:
        tmp = self.dir / f"step_{step:09d}.tmp"
        final = self.dir / f"step_{step:09d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        flat = _flatten(host_tree)
        # npz can't round-trip ml_dtypes (bfloat16 etc.) — store bit views
        dtypes = {}
        stored = {}
        for k, v in flat.items():
            v = np.asarray(v)
            dtypes[k] = str(v.dtype)
            if v.dtype.kind == "V" or "bfloat16" in str(v.dtype) \
                    or "float8" in str(v.dtype):
                v = v.view(np.uint8 if v.dtype.itemsize == 1 else np.uint16)
            stored[k] = v
        np.savez(tmp / "arrays.npz", **stored)
        (tmp / "meta.json").write_text(json.dumps(
            {"step": step, "time": time.time(), "extra": extra,
             "keys": sorted(flat), "dtypes": dtypes}))
        # fsync the directory entry then atomically rename
        fd = os.open(tmp, os.O_RDONLY)
        try:
            os.fsync(fd)
        finally:
            os.close(fd)
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        done = sorted(p for p in self.dir.glob("step_*")
                      if not p.name.endswith(".tmp"))
        for old in done[: max(0, len(done) - self.keep)]:
            shutil.rmtree(old)

    def wait(self):
        """Drain the async queue (call before exit)."""
        if self._thread is not None:
            while not self._queue.empty():
                time.sleep(0.01)
        if self._error:
            raise RuntimeError("async checkpoint writer failed") \
                from self._error

    # -- read -------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        done = sorted(p for p in self.dir.glob("step_*")
                      if not p.name.endswith(".tmp"))
        if not done:
            return None
        return int(done[-1].name.split("_")[1])

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None) -> tuple:
        """Restore into ``template``'s pytree structure. ``shardings`` (a
        matching pytree of NamedShardings) re-shards onto the current mesh —
        the elastic-scaling path."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        path = self.dir / f"step_{step:09d}"
        meta = json.loads((path / "meta.json").read_text())
        arrays = np.load(path / "arrays.npz")
        dtypes = meta.get("dtypes", {})
        import ml_dtypes

        flat_t, treedef = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        for kpath, _ in flat_t:
            key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                           for k in kpath)
            arr = arrays[key]
            want = dtypes.get(key)
            if want and str(arr.dtype) != want:
                arr = arr.view(np.dtype(getattr(ml_dtypes, want, want)))
            leaves.append(arr)
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s), tree, shardings)
        return tree, meta


def simulate_preemption_restart(manager: CheckpointManager, template,
                                shardings=None):
    """Test/ops helper: pretend the job died and came back — restore the
    newest complete checkpoint (ignoring any half-written .tmp dirs)."""
    return manager.restore(template, shardings=shardings)
