"""Training driver: runs on whatever devices exist (CPU here, a pod in
production) with the same step factory the dry-run lowers.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
        --reduced --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt

Fault tolerance: atomic keep-k checkpoints (params, optimizer, data cursor)
every ``--ckpt-every`` steps; rerunning the same command resumes from the
newest complete checkpoint (kill it mid-run to test).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs as cfgs
from repro.data.tokens import TokenPipeline
from repro.launch import steps
from repro.launch.mesh import make_local_mesh
from repro.models import transformer as tr
from repro.training import optimizer as opt_mod
from repro.training.checkpoint import CheckpointManager


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = (cfgs.get_reduced_config(args.arch) if args.reduced
           else cfgs.get_config(args.arch))
    mesh = make_local_mesh(args.model_axis)
    opt_cfg = opt_mod.AdamWConfig(lr=args.lr, warmup_steps=10,
                                  total_steps=max(args.steps, 11))

    pipe = TokenPipeline(cfg.vocab, args.batch, args.seq, seed=args.seed)
    batch_specs = jax.eval_shape(lambda: pipe.next_batch())
    if cfg.encoder is not None:
        batch_specs["source_embed"] = jax.ShapeDtypeStruct(
            (args.batch, cfg.encoder.max_source, cfg.d_model), jnp.float32)
    train_step, (param_specs, opt_specs) = steps.make_train_step(
        cfg, mesh, batch_specs, opt_cfg=opt_cfg)

    key = jax.random.PRNGKey(args.seed)
    params_f32 = tr.init_params(cfg, key)
    params = jax.tree.map(lambda x: x.astype(jnp.bfloat16), params_f32)
    opt_state = opt_mod.adamw_init(params_f32)
    del params_f32

    mgr = None
    start_step = 0
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, keep=2)
        if mgr.latest_step() is not None:
            (params, opt_state), meta = mgr.restore((params, opt_state))
            pipe.restore_state(meta["extra"]["pipeline"])
            start_step = meta["step"]
            print(f"resumed from step {start_step}")

    losses = []
    t0 = time.time()
    for step in range(start_step, args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}
        if cfg.encoder is not None:
            batch["source_embed"] = jnp.zeros(
                (args.batch, cfg.encoder.max_source, cfg.d_model),
                jnp.float32)
        params, opt_state, metrics = train_step(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.time() - t0
            print(f"step {step:5d} loss {losses[-1]:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} ({dt:.1f}s)", flush=True)
        if mgr and (step + 1) % args.ckpt_every == 0:
            mgr.save(step + 1, (params, opt_state),
                     extra={"pipeline": pipe.checkpoint_state()})
    if mgr:
        mgr.save(args.steps, (params, opt_state),
                 extra={"pipeline": pipe.checkpoint_state()})
    print(f"first-loss {losses[0] if start_step == 0 else float('nan'):.4f} "
          f"last-loss {losses[-1]:.4f}")
    return losses


if __name__ == "__main__":
    main()
