"""Serving driver: batched greedy generation on whatever devices exist.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
        --prompts "1 2 3;4 5" --max-new 8

Loads a checkpoint if given (``--ckpt-dir``), otherwise serves random
weights (useful for throughput measurement); the decode path is the same
``decode_step`` the multi-pod dry-run lowers for decode_32k / long_500k.
"""
from __future__ import annotations

import argparse
import time

import jax

from repro import configs as cfgs
from repro.models import transformer as tr
from repro.models.transformer_serve import ServeEngine
from repro.training.checkpoint import CheckpointManager


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--prompts", default="1 2 3;7 8")
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args(argv)

    cfg = (cfgs.get_reduced_config(args.arch) if args.reduced
           else cfgs.get_config(args.arch))
    params = tr.init_params(cfg, jax.random.PRNGKey(0))
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir)
        # train.py checkpoints store (params, opt_state); restore params only
        opt_template = None
        try:
            from repro.training import optimizer as opt_mod
            opt_template = opt_mod.adamw_init(params)
            (params, _), meta = mgr.restore((params, opt_template))
        except Exception:
            (params,), meta = mgr.restore((params,))
        print(f"restored step {meta['step']}")
    prompts = [[int(t) for t in p.split()] for p in args.prompts.split(";")]

    eng = ServeEngine(cfg, params, max_seq=args.max_seq)
    t0 = time.time()
    outs = eng.generate(prompts, max_new_tokens=args.max_new)
    dt = time.time() - t0
    n_tok = sum(args.max_new for _ in prompts)
    for i, o in enumerate(outs):
        print(f"[{i}] {o}")
    print(f"{n_tok} tokens in {dt:.2f}s ({n_tok / dt:.1f} tok/s)")
    return outs


if __name__ == "__main__":
    main()
