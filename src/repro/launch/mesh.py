"""Production meshes.

Kept as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single pod (256 chips) or 2×16×16 (2 pods, 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_axis: int = 1):
    """Whatever this host has — for tests and examples."""
    n = len(jax.devices())
    assert n % model_axis == 0
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))


def dp_axes(mesh) -> tuple:
    """Data-parallel axes: ('pod','data') on the multi-pod mesh."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
