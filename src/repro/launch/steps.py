"""Train / prefill / decode step factories with production shardings.

``make_*`` return a jitted function plus the ShapeDtypeStruct input specs —
the same objects serve real execution (CPU/TPU) and the multi-pod dry-run
(``.lower().compile()`` with no allocation).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models import transformer as tr
from repro.sharding import partition
from repro.sharding.hints import hints
from repro.training import optimizer as opt_mod


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _to_dtype_specs(tree, dtype):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype), tree)


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Memory-lean CE: logsumexp + label gather — never materializes an
    fp32 log-softmax of the (huge, vocab-sharded) logits."""
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    lab = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return (lse - lab.astype(jnp.float32)).mean()


def model_shardings(cfg: tr.ModelConfig, mesh):
    """(param_specs_bf16, param_shardings) for the working (bf16) params."""
    specs = tr.param_specs(cfg)
    pspecs = partition.param_pspecs(cfg, specs, mesh)
    return _to_dtype_specs(specs, jnp.bfloat16), _named(mesh, pspecs)


def make_train_step(cfg: tr.ModelConfig, mesh, batch_specs,
                    opt_cfg: Optional[opt_mod.AdamWConfig] = None,
                    aux_weight: float = 0.01,
                    donate: bool = True):
    """Returns (train_step, (param_specs, opt_specs)) —
    args = (params, opt_state, batch)."""
    opt_cfg = opt_cfg or opt_mod.AdamWConfig()
    from repro.launch.mesh import dp_axes

    dp = dp_axes(mesh)
    dp = dp[0] if len(dp) == 1 else dp

    def loss_fn(params, batch):
        logits, aux = tr.model_forward(cfg, params, batch)
        logits = jax.lax.with_sharding_constraint(
            logits, NamedSharding(mesh, P(dp, None, "model")))
        return cross_entropy(logits, batch["labels"]) + aux_weight * aux

    def train_step(params, opt_state, batch):
        with hints(mesh, dp, "model"):
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            params, opt_state, metrics = opt_mod.adamw_update(
                opt_cfg, grads, opt_state)
        return params, opt_state, dict(metrics, loss=loss)

    param_specs, param_sh = model_shardings(cfg, mesh)
    opt_specs = jax.eval_shape(opt_mod.adamw_init, param_specs)
    pspecs = partition.param_pspecs(cfg, tr.param_specs(cfg), mesh)
    opt_sh = _named(mesh, partition.opt_state_pspecs(pspecs))
    batch_sh = _named(mesh, partition.batch_pspecs(batch_specs, mesh))

    fn = jax.jit(
        train_step,
        in_shardings=(param_sh, opt_sh, batch_sh),
        out_shardings=(param_sh, opt_sh, None),
        donate_argnums=(0, 1) if donate else (),
    )
    return fn, (param_specs, opt_specs)


def make_prefill_step(cfg: tr.ModelConfig, mesh, batch_specs, max_seq: int):
    from repro.launch.mesh import dp_axes

    dp = dp_axes(mesh)
    dp = dp[0] if len(dp) == 1 else dp

    def prefill_step(params, batch):
        with hints(mesh, dp, "model"):
            logits, cache = tr.prefill(cfg, params, batch, max_seq=max_seq)
        return logits, cache

    param_specs, param_sh = model_shardings(cfg, mesh)
    batch_sh = _named(mesh, partition.batch_pspecs(batch_specs, mesh))
    cache_specs = jax.eval_shape(
        lambda: tr.init_cache(cfg, batch_specs["tokens"].shape[0], max_seq,
                              jnp.bfloat16))
    cache_sh = _named(mesh, partition.cache_pspecs(cfg, cache_specs, mesh))
    fn = jax.jit(prefill_step, in_shardings=(param_sh, batch_sh),
                 out_shardings=(None, cache_sh))
    return fn, (param_specs,)


def make_decode_step(cfg: tr.ModelConfig, mesh, batch: int, max_seq: int,
                     donate: bool = True, seq_shard_kv: bool = False):
    """serve_step: one new token against a seq-length KV cache.
    ``seq_shard_kv`` enables distributed flash-decoding (§Perf cell B)."""
    from repro.launch.mesh import dp_axes

    dp = dp_axes(mesh)
    dp = dp[0] if len(dp) == 1 else dp

    def decode(params, cache, token, pos):
        with hints(mesh, dp, "model", kv_seq_shard=seq_shard_kv):
            return tr.decode_step(cfg, params, cache, token, pos)

    param_specs, param_sh = model_shardings(cfg, mesh)
    cache_specs = jax.eval_shape(
        lambda: tr.init_cache(cfg, batch, max_seq, jnp.bfloat16))
    cache_sh = _named(mesh, partition.cache_pspecs(
        cfg, cache_specs, mesh, seq_shard=seq_shard_kv))
    _, dp_size = partition._dp_of(mesh)
    tok_sh = NamedSharding(mesh, P(dp if batch % dp_size == 0 else None))
    pos_sh = NamedSharding(mesh, P())

    fn = jax.jit(
        decode,
        in_shardings=(param_sh, cache_sh, tok_sh, pos_sh),
        out_shardings=(None, cache_sh),
        donate_argnums=(1,) if donate else (),
    )
    return fn, (param_specs, cache_specs)


# ---------------------------------------------------------------------------
# GCN (the paper's own workload) on the production mesh
# ---------------------------------------------------------------------------

def make_gcn_step(mesh, n_nodes: int, n_feat: int, hidden: int,
                  n_classes: int, n_steps: int, nnz_per_step: int,
                  rows_per_window: int):
    """Sharded 2-layer GCN inference through an AWB schedule: schedule steps
    (equal work) shard over the data axis — the device-level realization of
    the paper's balanced PE partition; features/hidden shard over model.

    Returns (fn, arg_specs): args = (x, w1, w2, val, lrow, lcol, win, cblk,
    row_map). Lowering only needs shapes, so the dry-run can size ``n_steps``
    from dataset stats without materializing the graph.
    """
    from repro.launch.mesh import dp_axes

    dp = dp_axes(mesh)
    dp_size = partition._dp_of(mesh)[1]
    model_size = mesh.shape["model"]
    dp = dp[0] if len(dp) == 1 else dp
    r = rows_per_window
    k = nnz_per_step

    def pad_to(x, m):
        return -(-x // m) * m

    # pad spec dims to mesh-divisible sizes (production pads the arrays)
    n_feat = pad_to(n_feat, model_size)
    hidden = pad_to(hidden, model_size)
    n_steps = pad_to(n_steps, dp_size)

    def spmm(val, lrow, lcol, win, b, row_map):
        # balanced steps over dp; each step's gather+scatter is local, the
        # scatter-add across devices is the reduce the paper's ACC buffers do
        gcol = jnp.minimum(lcol, b.shape[0] - 1)
        slot = win[:, None] * r + lrow
        gathered = b[gcol.reshape(-1)] * val.reshape(-1)[:, None]
        gathered = jax.lax.with_sharding_constraint(
            gathered.reshape(val.shape[0], k, -1),
            NamedSharding(mesh, P(dp, None, "model")))
        n_windows = row_map.shape[0] // r
        out_perm = jnp.zeros((n_windows * r, b.shape[1]), b.dtype)
        out_perm = out_perm.at[slot.reshape(-1)].add(
            gathered.reshape(-1, b.shape[1]))
        valid = row_map >= 0
        tgt = jnp.where(valid, row_map, 0)
        out = jnp.zeros((n_nodes, b.shape[1]), b.dtype)
        return out.at[tgt].add(jnp.where(valid[:, None], out_perm, 0))

    def gcn_infer(x, w1, w2, val, lrow, lcol, win, cblk, row_map):
        h = jax.nn.relu(spmm(val, lrow, lcol, win, x @ w1, row_map))
        return spmm(val, lrow, lcol, win, h @ w2, row_map)

    f32 = jnp.float32
    i32 = jnp.int32
    specs = (
        jax.ShapeDtypeStruct((n_nodes, n_feat), f32),       # x
        jax.ShapeDtypeStruct((n_feat, hidden), f32),        # w1
        jax.ShapeDtypeStruct((hidden, n_classes), f32),     # w2
        jax.ShapeDtypeStruct((n_steps, k), f32),            # val
        jax.ShapeDtypeStruct((n_steps, k), i32),            # lrow (slot-local)
        jax.ShapeDtypeStruct((n_steps, k), i32),            # lcol (global col)
        jax.ShapeDtypeStruct((n_steps,), i32),              # win
        jax.ShapeDtypeStruct((n_steps,), i32),              # cblk
        jax.ShapeDtypeStruct((n_steps * r,), i32),          # row_map (≥)
    )
    sh = (
        NamedSharding(mesh, P(None, "model")),
        NamedSharding(mesh, P("model", None)),
        NamedSharding(mesh, P(None, None)),
        NamedSharding(mesh, P(dp, None)),
        NamedSharding(mesh, P(dp, None)),
        NamedSharding(mesh, P(dp, None)),
        NamedSharding(mesh, P(dp)),
        NamedSharding(mesh, P(dp)),
        NamedSharding(mesh, P(None)),
    )
    fn = jax.jit(gcn_infer, in_shardings=sh)
    return fn, specs
