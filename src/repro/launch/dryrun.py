import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × shape × mesh)
cell on the production meshes, record memory/cost/collective analysis.

The two lines above MUST run before any other import (jax locks the device
count at first init). Do not set that flag globally — smoke tests and
benches see 1 device.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-0.5b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all   # every cell

Outputs one JSON per cell under results/dryrun/ (cached; --force to redo)
plus a summary table. ``roofline`` totals use the scan-extrapolation of
EXPERIMENTS.md §Roofline: total = full_program + Σ_seg (repeat−1) × unit.
"""
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro import configs as cfgs  # noqa: E402
from repro.launch import steps  # noqa: E402
from repro.launch.mesh import dp_axes, make_production_mesh  # noqa: E402
from repro.models import transformer as tr  # noqa: E402
from repro.roofline import analysis as ra  # noqa: E402
from repro.sharding import partition  # noqa: E402
from repro.sharding.hints import hints  # noqa: E402

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _mem_record(compiled) -> dict:
    m = compiled.memory_analysis()
    return {
        "argument_bytes": m.argument_size_in_bytes,
        "output_bytes": m.output_size_in_bytes,
        "temp_bytes": m.temp_size_in_bytes,
        "alias_bytes": m.alias_size_in_bytes,
        "peak_bytes_est": m.argument_size_in_bytes
        + m.output_size_in_bytes + m.temp_size_in_bytes
        - m.alias_size_in_bytes,
    }


def _cost_record(compiled) -> dict:
    c = compiled.cost_analysis()
    return {"flops": float(c.get("flops", 0.0)),
            "bytes": float(c.get("bytes accessed", 0.0))}


def _analyze(lowered, compiled) -> dict:
    rec = {**_mem_record(compiled), **_cost_record(compiled)}
    txt = compiled.as_text()
    rec["collectives"] = ra.collective_bytes_from_hlo(txt)
    rec["hbm_bytes_model"] = ra.tpu_hbm_bytes_from_hlo(txt)
    return rec


# ---------------------------------------------------------------------------
# Full-program lowering per cell
# ---------------------------------------------------------------------------

def lower_full(cfg, shape: str, mesh, opt: bool = False) -> dict:
    seq, batch, kind = cfgs.SHAPES[shape]
    specs = cfgs.input_specs(cfg, shape)
    t0 = time.time()
    if kind == "train":
        fn, (param_specs, opt_specs) = steps.make_train_step(cfg, mesh,
                                                             specs)
        lowered = fn.lower(param_specs, opt_specs, specs)
    elif kind == "prefill":
        fn, (param_specs,) = steps.make_prefill_step(cfg, mesh, specs,
                                                     max_seq=seq)
        lowered = fn.lower(param_specs, specs)
    else:  # decode
        fn, (param_specs, cache_specs) = steps.make_decode_step(
            cfg, mesh, batch=batch, max_seq=seq, seq_shard_kv=opt)
        lowered = fn.lower(param_specs, cache_specs, specs["token"],
                           specs["pos"])
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    rec = _analyze(lowered, compiled)
    rec.update({"lower_s": t_lower, "compile_s": t_compile})
    return rec


# ---------------------------------------------------------------------------
# Per-unit lowering (scan-body extrapolation for §Roofline)
# ---------------------------------------------------------------------------

def _unit_param_specs(cfg, unit):
    return jax.eval_shape(
        lambda k: tr._init_unit(cfg, unit, k),
        jax.ShapeDtypeStruct((2,), jnp.uint32))


def lower_unit(cfg, unit, mesh, kind: str, batch: int, seq: int,
               opt: bool = False) -> dict:
    """Lower one segment unit standalone with matching shardings."""
    dp, dp_size = partition._dp_of(mesh)
    if batch % dp_size != 0:
        dp = None  # long_500k: B=1 cannot shard over data
    pspec_tree = partition.param_pspecs(
        cfg, _unit_param_specs(cfg, unit), mesh)
    p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspec_tree,
                        is_leaf=lambda x: isinstance(x, P))
    p_specs = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16),
        _unit_param_specs(cfg, unit))
    x_sh = NamedSharding(mesh, P(dp, None, None))
    if kind != "train" and all(kd == "enc" for kd in unit):
        # encoder layers have no cache; lower plain forward
        def f(p, x):
            with hints(mesh, dp, "model"):
                y, _ = tr._unit_fwd(cfg, unit, p, x, None, None)
            return y
        x_spec = jax.ShapeDtypeStruct((batch, seq, cfg.d_model),
                                      jnp.bfloat16)
        lowered = jax.jit(f, in_shardings=(p_sh, x_sh)).lower(
            p_specs, x_spec)
        return _analyze(lowered, lowered.compile())
    needs_enc = "xattn" in unit
    enc_spec = (jax.ShapeDtypeStruct(
        (batch, cfg.encoder.max_source, cfg.d_model), jnp.bfloat16)
        if needs_enc else None)
    enc_sh = x_sh if needs_enc else None

    if kind == "train":
        def fwd(p, x, enc):
            with hints(mesh, dp, "model"):
                y, aux = tr._unit_fwd(cfg, unit, p, x, enc, None)
            return y.astype(jnp.float32).sum() + aux
        if cfg.remat:
            fwd = jax.checkpoint(fwd)
        f = lambda p, x, enc: jax.grad(  # noqa: E731
            fwd, argnums=(0, 1))(p, x, enc)
        x_spec = jax.ShapeDtypeStruct((batch, seq, cfg.d_model),
                                      jnp.bfloat16)
        lowered = jax.jit(f, in_shardings=(p_sh, x_sh, enc_sh)).lower(
            p_specs, x_spec, enc_spec)
    elif kind == "prefill":
        def f(p, c, x, enc):
            y = x
            out_c = {}
            with hints(mesh, dp, "model"):
                for i, kd in enumerate(unit):
                    y, cc = tr._layer_prefill(cfg, kd, p[f"l{i}"], y,
                                              c[f"l{i}"], enc, None)
                    out_c[f"l{i}"] = cc
            return y, out_c
        c_specs = jax.eval_shape(
            lambda: {f"l{i}": tr._init_layer_cache(cfg, kd, batch, seq,
                                                   jnp.bfloat16)
                     for i, kd in enumerate(unit)})
        c_sh = jax.tree.map(
            lambda s: NamedSharding(mesh, s),
            partition.cache_pspecs(cfg, c_specs, mesh, stacked=False),
            is_leaf=lambda x: isinstance(x, P))
        x_spec = jax.ShapeDtypeStruct((batch, seq, cfg.d_model),
                                      jnp.bfloat16)
        lowered = jax.jit(f, in_shardings=(p_sh, c_sh, x_sh, enc_sh)).lower(
            p_specs, c_specs, x_spec, enc_spec)
    else:  # decode
        def f(p, c, x, pos):
            y = x
            out_c = {}
            with hints(mesh, dp, "model", kv_seq_shard=opt):
                for i, kd in enumerate(unit):
                    y, cc = tr._layer_decode(cfg, kd, p[f"l{i}"], y,
                                             c[f"l{i}"], pos, None)
                    out_c[f"l{i}"] = cc
            return y, out_c
        c_specs = jax.eval_shape(
            lambda: {f"l{i}": tr._init_layer_cache(cfg, kd, batch, seq,
                                                   jnp.bfloat16)
                     for i, kd in enumerate(unit)})
        c_sh = jax.tree.map(
            lambda s: NamedSharding(mesh, s),
            partition.cache_pspecs(cfg, c_specs, mesh, stacked=False,
                                   seq_shard=opt),
            is_leaf=lambda x: isinstance(x, P))
        x_spec = jax.ShapeDtypeStruct((batch, 1, cfg.d_model), jnp.bfloat16)
        pos_spec = jax.ShapeDtypeStruct((), jnp.int32)
        lowered = jax.jit(f, in_shardings=(p_sh, c_sh, x_sh, None)).lower(
            p_specs, c_specs, x_spec, pos_spec)
    compiled = lowered.compile()
    return _analyze(lowered, compiled)


def extrapolated_totals(cfg, shape: str, mesh, full_rec: dict,
                        opt: bool = False) -> dict:
    """total = full_program + Σ_seg (repeat−1) × unit (+ encoder layers)."""
    seq, batch, kind = cfgs.SHAPES[shape]
    eff_batch = batch if kind != "decode" else batch  # x batch dim
    eff_seq = seq if kind != "decode" else seq        # cache length
    flops = full_rec["flops"]
    bytes_ = full_rec["bytes"]
    hbm = full_rec.get("hbm_bytes_model", 0.0)
    wire = full_rec["collectives"].get("wire_bytes_total", 0.0)
    units = []
    for unit, repeat in cfg.segments:
        if repeat <= 1:
            units.append(None)
            continue
        u = lower_unit(cfg, unit, mesh, kind, eff_batch, eff_seq, opt=opt)
        units.append(u)
        flops += (repeat - 1) * u["flops"]
        bytes_ += (repeat - 1) * u["bytes"]
        hbm += (repeat - 1) * u.get("hbm_bytes_model", 0.0)
        wire += (repeat - 1) * u["collectives"].get("wire_bytes_total", 0.0)
    if cfg.encoder is not None and kind != "decode" \
            and cfg.encoder.n_layers > 1:
        u = lower_unit(cfg, ("enc",), mesh, "train" if kind == "train"
                       else "prefill", eff_batch, cfg.encoder.max_source)
        flops += (cfg.encoder.n_layers - 1) * u["flops"]
        bytes_ += (cfg.encoder.n_layers - 1) * u["bytes"]
        hbm += (cfg.encoder.n_layers - 1) * u.get("hbm_bytes_model", 0.0)
        wire += (cfg.encoder.n_layers - 1) * u["collectives"].get(
            "wire_bytes_total", 0.0)
    return {"flops_extrap": flops, "bytes_extrap": bytes_,
            "hbm_extrap": hbm, "wire_extrap": wire}


# ---------------------------------------------------------------------------
# GCN cells (the paper's own workload on the production mesh)
# ---------------------------------------------------------------------------

def lower_gcn(dataset: str, mesh) -> dict:
    from repro.graphs.synth import DATASET_STATS

    nodes, feats, classes, hidden, dens_a, _, _, _ = DATASET_STATS[dataset]
    nnz = max(nodes, int(dens_a * nodes * nodes)) + nodes
    k, r = 256, 64
    n_steps = int(nnz / k * 1.08) + 2
    fn, specs = steps.make_gcn_step(mesh, nodes, feats, hidden, classes,
                                    n_steps, k, r)
    t0 = time.time()
    lowered = fn.lower(*specs)
    compiled = lowered.compile()
    rec = _analyze(lowered, compiled)
    rec["compile_s"] = time.time() - t0
    return rec


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape: str, mesh_kind: str, force: bool = False,
             extrapolate: bool = True, variant: str = "base") -> dict:
    RESULTS.mkdir(parents=True, exist_ok=True)
    suffix = "" if variant == "base" else f"__{variant}"
    out_path = RESULTS / f"{arch}__{shape}__{mesh_kind}{suffix}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    opt = variant == "opt"
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.size
    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
           "chips": n_chips, "status": "ok", "variant": variant}
    try:
        if arch.startswith("gcn-"):
            full = lower_gcn(arch[4:], mesh)
            rec.update(full)
            rec["flops_extrap"] = full["flops"]
            rec["bytes_extrap"] = full["bytes"]
            rec["wire_extrap"] = full["collectives"]["wire_bytes_total"]
        else:
            cfg = cfgs.get_config(arch)
            if opt:
                cfg = dataclasses.replace(cfg, attn_chunk=1024,
                                          moe_groups=16, sp_carry=True)
            ok, why = cfgs.cell_supported(cfg, shape)
            if not ok:
                rec.update({"status": "skipped", "reason": why})
                out_path.write_text(json.dumps(rec, indent=1))
                return rec
            full = lower_full(cfg, shape, mesh, opt=opt)
            rec.update(full)
            if extrapolate:
                rec.update(extrapolated_totals(cfg, shape, mesh, full,
                                               opt=opt))
            rec["n_params"] = tr.count_params(cfg)
            rec["n_active_params"] = tr.active_params(cfg)
        # roofline terms from the extrapolated per-device numbers
        terms = ra.roofline_terms(
            rec.get("flops_extrap", rec.get("flops", 0.0)),
            rec.get("bytes_extrap", rec.get("bytes", 0.0)),
            rec.get("wire_extrap", 0.0))
        hbm = rec.get("hbm_extrap", rec.get("hbm_bytes_model", 0.0))
        terms["memory_v2_s"] = hbm / ra.HW.hbm_bw
        terms["bound_v2_s"] = max(terms["compute_s"], terms["memory_v2_s"],
                                  terms["collective_s"])
        terms["roofline_fraction_v2"] = (terms["compute_s"]
                                         / terms["bound_v2_s"]
                                         if terms["bound_v2_s"] else 0.0)
        rec["roofline"] = terms
    except Exception as e:  # record failures — they are findings
        rec.update({"status": "error", "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-2000:]})
    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--gcn", action="store_true", help="include GCN cells")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-extrap", action="store_true")
    ap.add_argument("--variant", default="base", choices=["base", "opt"])
    args = ap.parse_args()

    archs = (cfgs.list_archs() if args.arch == "all" or args.all
             else args.arch.split(","))
    if args.gcn:
        archs = archs + [f"gcn-{d}" for d in cfgs.GCN_DATASETS]
    shapes = (list(cfgs.SHAPES) if args.shape == "all" or args.all
              else args.shape.split(","))
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    rows = []
    for arch in archs:
        for shape in shapes:
            if arch.startswith("gcn-") and shape != "train_4k":
                continue  # GCN cells are shape-free; run once
            for mk in meshes:
                t0 = time.time()
                rec = run_cell(arch, shape, mk, force=args.force,
                               extrapolate=not args.no_extrap,
                               variant=args.variant)
                dt = time.time() - t0
                status = rec["status"]
                if status == "ok":
                    r = rec["roofline"]
                    mem = rec.get("peak_bytes_est", 0) / 1e9
                    print(f"{arch:22s} {shape:12s} {mk:6s} ok "
                          f"mem={mem:6.2f}GB/dev "
                          f"compute={r['compute_s']*1e3:8.2f}ms "
                          f"memory={r['memory_s']*1e3:8.2f}ms "
                          f"coll={r['collective_s']*1e3:8.2f}ms "
                          f"dom={r['dominant']:10s} ({dt:.0f}s)",
                          flush=True)
                elif status == "skipped":
                    print(f"{arch:22s} {shape:12s} {mk:6s} SKIP "
                          f"({rec['reason']})", flush=True)
                else:
                    print(f"{arch:22s} {shape:12s} {mk:6s} ERROR "
                          f"{rec['error'][:120]}", flush=True)
                rows.append(rec)
    n_ok = sum(r["status"] == "ok" for r in rows)
    n_skip = sum(r["status"] == "skipped" for r in rows)
    n_err = sum(r["status"] == "error" for r in rows)
    print(f"\n{n_ok} ok, {n_skip} skipped, {n_err} errors "
          f"of {len(rows)} cells")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
