"""Deterministic, resumable synthetic token pipeline.

Stands in for a sharded webdataset reader: every batch is a pure function
of ``(seed, step, host_shard)``, so (a) restarts resume mid-stream from the
checkpointed cursor with zero duplication, (b) elastic re-sharding (changing
host count between restarts) re-partitions the stream deterministically,
(c) tests can assert exact batch equality across simulated failures.

The synthetic distribution is a Zipf unigram stream with Markov structure
(so small LMs can visibly learn — loss decreases in the examples/tests).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass
class TokenPipelineState:
    seed: int
    step: int
    host: int
    num_hosts: int


class TokenPipeline:
    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0,
                 host: int = 0, num_hosts: int = 1):
        self.vocab = vocab
        self.batch = batch
        self.seq = seq
        self.state = TokenPipelineState(seed, 0, host, num_hosts)
        # fixed Markov mixture params derived from the seed
        rng = np.random.default_rng(seed)
        self._shift = int(rng.integers(1, max(2, vocab // 2)))

    def checkpoint_state(self) -> dict:
        return dataclasses.asdict(self.state)

    def restore_state(self, d: dict) -> None:
        self.state = TokenPipelineState(**d)

    def _batch_rng(self, step: int) -> np.random.Generator:
        s = self.state
        return np.random.default_rng(
            (s.seed * 1_000_003 + step) * 4099 + s.host * 7 + s.num_hosts)

    def next_batch(self) -> dict:
        rng = self._batch_rng(self.state.step)
        b, t, v = self.batch, self.seq, self.vocab
        # zipf-ish unigram base
        base = rng.zipf(1.3, size=(b, t + 1)).astype(np.int64)
        base = np.minimum(base - 1, v - 1)
        # markov structure: even positions predict next = (x + shift) % v
        predictable = rng.random((b, t + 1)) < 0.7
        for j in range(1, t + 1):
            base[:, j] = np.where(predictable[:, j],
                                  (base[:, j - 1] + self._shift) % v,
                                  base[:, j])
        self.state.step += 1
        return {"tokens": base[:, :t].astype(np.int32),
                "labels": base[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[dict]:
        while True:
            yield self.next_batch()
