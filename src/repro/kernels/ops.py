"""Jit'd public wrappers for the Pallas kernels.

Backend selection: on a real TPU the Pallas path compiles natively
(``interpret=False``); everywhere else (this CPU container, the multi-pod
dry-run on host devices) the framework uses either the interpret-mode kernel
(tests) or the mathematically identical XLA path (``*_xla``) that the model
code lowers for the dry-run. ``default_backend()`` picks automatically.
"""
from __future__ import annotations


import jax

from repro.core import csc as fmt
from repro.core import spmm as spmm_ref_mod
from repro.core.schedule import Schedule
from repro.kernels import flash_attention as _fa
from repro.kernels import spmm_pallas as _sp
from repro.kernels import ref as _ref


def default_backend() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "xla"


# ---------------------------------------------------------------------------
# SpMM
# ---------------------------------------------------------------------------

def spmm(sched: Schedule, b: jax.Array, *, backend: str | None = None,
         ktile: int = 128, routing: str = "auto") -> jax.Array:
    """C = A @ B through the converged AWB schedule.

    The XLA path runs on the schedule's cached ``ScheduleExecutor`` (device-
    resident arrays, jitted fused-gather routing); the Pallas paths pass
    ``routing`` through to the kernel ("onehot"/"gather"/"auto")."""
    backend = backend or default_backend()
    if backend == "pallas":
        return _sp.spmm_balanced(sched, b, ktile=ktile, interpret=False,
                                 routing=routing)
    if backend == "pallas_interpret":
        return _sp.spmm_balanced(sched, b, ktile=ktile, interpret=True,
                                 routing=routing)
    from repro.core.executor import executor_for_schedule
    return executor_for_schedule(sched, ktile=ktile).spmm(b)


def spmm_coo(a: fmt.COO, b: jax.Array) -> jax.Array:
    """Schedule-free reference path."""
    return spmm_ref_mod.spmm_coo(a, b)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: int | None = None,
              scale: float | None = None, backend: str | None = None,
              block_q: int = 128, block_k: int = 128,
              chunk: int | None = None) -> jax.Array:
    """Multi-head attention, q [B,Sq,H,D], kv [B,Sk,Hkv,D] (GQA).
    ``chunk`` selects the flash-style chunked XLA path (§Perf)."""
    backend = backend or default_backend()
    if chunk is not None and backend not in ("pallas", "pallas_interpret"):
        return _ref.attention_chunked(q, k, v, causal=causal, window=window,
                                      scale=scale, block_k=chunk)
    if backend == "pallas":
        return _fa.flash_attention(q, k, v, causal=causal, window=window,
                                   scale=scale, block_q=block_q,
                                   block_k=block_k, interpret=False)
    if backend == "pallas_interpret":
        return _fa.flash_attention(q, k, v, causal=causal, window=window,
                                   scale=scale, block_q=block_q,
                                   block_k=block_k, interpret=True)
    return _ref.attention_ref(q, k, v, causal=causal, window=window,
                              scale=scale)
