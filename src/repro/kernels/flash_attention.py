"""Flash attention Pallas TPU kernel (causal / local-window / GQA).

The LM-side compute hot-spot (prefill_32k cells). Standard IO-aware tiling:
online softmax with running (m, l) statistics in VMEM scratch, one KV block
per inner grid step, output written on the last KV block. GQA is handled in
the BlockSpec index maps (no KV head replication in HBM).

Grid: ``(batch*heads, q_blocks, kv_blocks)``; kv innermost sequential, the
rest parallel. Causal/window-masked KV blocks are skipped via ``pl.when``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams → CompilerParams across versions; take
# whichever this install provides
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                 *, scale: float, causal: bool, window: int | None,
                 sq: int, sk: int, block_q: int, block_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    n_kv = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # global positions (q offset by sk - sq: decode-style alignment)
    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0) + (sk - sq)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)

    # block-level visibility (skip fully masked blocks)
    q_max = qi * block_q + block_q - 1 + (sk - sq)
    q_min = qi * block_q + (sk - sq)
    k_min = ki * block_k
    k_max = ki * block_k + block_k - 1
    visible = jnp.asarray(True)
    if causal:
        visible = jnp.logical_and(visible, k_min <= q_max)
    if window is not None:
        visible = jnp.logical_and(visible, k_max > q_min - window)

    @pl.when(visible)
    def _compute():
        q = q_ref[0].astype(jnp.float32)            # [bq, d]
        k = k_ref[0].astype(jnp.float32)            # [bk, d]
        v = v_ref[0].astype(jnp.float32)            # [bk, d]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # [bq, bk]

        mask = k_pos < sk                            # padded kv
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window is not None:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                          # [bq, 1]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = corr * l_ref[...] + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = corr * acc_ref[...] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "scale", "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    scale: float | None = None, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True) -> jax.Array:
    """q: [B, Sq, H, D]; k, v: [B, Sk, Hkv, D] (GQA when Hkv < H).
    Returns [B, Sq, H, D]."""
    b, sq, h, d = q.shape
    _, sk, hkv, _ = k.shape
    assert h % hkv == 0
    group = h // hkv
    if scale is None:
        scale = d ** -0.5

    bq = min(block_q, max(8, sq))
    bk = min(block_k, max(8, sk))
    pad_q = (-sq) % bq
    pad_k = (-sk) % bk

    qt = jnp.pad(q.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, pad_q), (0, 0))
                 ).reshape(b * h, sq + pad_q, d)
    kt = jnp.pad(k.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, pad_k), (0, 0))
                 ).reshape(b * hkv, sk + pad_k, d)
    vt = jnp.pad(v.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, pad_k), (0, 0))
                 ).reshape(b * hkv, sk + pad_k, d)

    grid = (b * h, (sq + pad_q) // bq, (sk + pad_k) // bk)

    def kv_index(bh, qi, ki):
        # bh = bi * h + hi ; kv row = bi * hkv + hi // group
        return ((bh // h) * hkv + (bh % h) // group, ki, 0)

    out = pl.pallas_call(
        functools.partial(_attn_kernel, scale=scale, causal=causal,
                          window=window, sq=sq, sk=sk, block_q=bq,
                          block_k=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, d), kv_index),
            pl.BlockSpec((1, bk, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq + pad_q, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(qt, kt, vt)

    out = out.reshape(b, h, sq + pad_q, d)[:, :, :sq].transpose(0, 2, 1, 3)
    return out
